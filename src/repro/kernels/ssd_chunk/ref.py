"""Pure-jnp oracle for the SSD chunk kernel: the exact sequential recurrence.

h_t = exp(a_t) · h_{t-1} + b_t ⊗ xdt_t        (h: (N, P))
y_t = c_t · h_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(a: jax.Array, xdt: jax.Array, b: jax.Array, c: jax.Array):
    """a: (BH, S) log-decay; xdt: (BH, S, P); b/c: (BH, S, N).

    Returns (y (BH, S, P), h_final (BH, N, P)) in f32."""
    bh, s = a.shape
    n, p = b.shape[-1], xdt.shape[-1]

    def per_seq(a1, x1, b1, c1):
        def step(h, t):
            h = jnp.exp(a1[t]) * h + jnp.outer(b1[t], x1[t])
            return h, c1[t] @ h

        h0 = jnp.zeros((n, p), jnp.float32)
        hf, ys = jax.lax.scan(step, h0, jnp.arange(s))
        return ys, hf

    return jax.vmap(per_seq)(a.astype(jnp.float32), xdt.astype(jnp.float32),
                             b.astype(jnp.float32), c.astype(jnp.float32))
