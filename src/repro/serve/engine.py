"""The sparse serving engine: submit()/flush() over bucketed batched scenes.

Ties the subsystem together (DESIGN: ISSUE 2 tentpole):

* requests (variable-size scenes) queue in a ``SceneBatcher`` and pack FIFO
  into capacity-bucketed batched ``SparseTensor``s with declared bounds —
  every served batch takes the single-argsort packed-key mapping path;
* each bucket capacity owns two pre-jitted stages: a **map builder**
  (``build_maps`` under one trace, so the per-trace ``MapCache`` shares
  sorted tables across the layer pyramid) and an **executor** (the model
  forward in inference-mode normalization).  Static bucket shapes bound jit
  recompiles to one per (bucket, stage) for the engine's lifetime;
* built kernel maps are reused **across requests** at two granularities:
  whole batches are keyed by a content digest of their packed coordinates
  (a small LRU maps digest → device-resident map stack, so exact replays
  skip mapping entirely), and — under the plan's ``"composed"`` /
  ``"incremental"`` table strategies — *scenes* are keyed individually: a
  per-scene store caches each scene's kernel-map stack and sorted table
  ladder, and batch maps are **merge-composed** from the cached per-scene
  stacks (host-side concatenation with index offsets; bit-identical to a
  fresh build because batch bits keep scenes disjoint).  Under churning
  batch composition — the common case in real traffic — only cold scenes
  ever build maps, at their own size (Minuet §4 proper).  ``"incremental"``
  additionally lets streaming frames (``submit_delta``) update their scene
  table by an O(r+a) sorted delta-merge instead of a fresh argsort;
* flushes are triggered explicitly, by queue depth (``flush_count``), or by
  a latency deadline (``max_wait_ms`` — the oldest queued scene's age;
  check via ``poll()`` or any ``submit``), with deadline-triggered flushes
  counted in the engine stats;
* the engine executes a compiled ``core.plan.NetworkPlan`` — the same
  artifact the models and the training stack run — loaded from a
  ``PlanRegistry`` at startup when one was persisted (tune once, serve
  forever; v1 assignment-only files recompile the plan from the model
  declaration) and re-tuned in place by ``tune()``;
* latency/throughput stats: per-scene p50/p95, scenes/s, recompile and
  map-cache counters.

The correctness contract — asserted in tests/test_serving.py — is that the
batched engine output is bit-identical to the per-scene forward at the same
bucket capacity: batching only ever adds rows whose keys can't collide with
another scene's (batch index is packed into every voxel key) and
inference-mode normalization keeps every output row a function of its own
scene's rows.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import dataflows as df
from repro.core import hashing
from repro.core.autotuner import timeit_fn
from repro.core.kmap import SceneEntry, compose_kmaps
from repro.core.plan import (KmapSpec, NetworkPlan, PlanTuner,
                             scene_entry_arrays, scene_entry_from_arrays)
from repro.core.sparse_conv import TrainDataflowConfig
from repro.core.sparse_tensor import INVALID_COORD, SparseTensor
from repro.models import centerpoint, minkunet
from repro.serve.batcher import (PackedBatch, Scene, SceneBatcher, SceneDelta,
                                 SceneResult, apply_delta)
from repro.serve.bucketing import BucketLadder
from repro.serve.plans import PlanRegistry


@dataclasses.dataclass(frozen=True)
class ArchBinding:
    """Everything the engine needs to serve one sparse architecture."""

    name: str
    model: object                       # module: init_params/build_maps/apply/layer_signatures
    default_config: object
    out_stride_of: Callable[[object], int]
    outputs_of: Callable[[object, SparseTensor, dict, jax.Array], tuple]
    in_channels_of: Callable[[object], int]


def _minkunet_outputs(cfg, st, maps, feats):
    # logits are per input voxel: rows align with the stride-1 input coords
    return st.coords, feats, st.num_valid


def _centerpoint_outputs(cfg, st, maps, feats):
    s = 2 ** len(cfg.channels)
    km = maps[("sub", s)]
    return km.out_coords, feats, km.n_out


def _arch_bindings() -> Dict[str, ArchBinding]:
    from repro.configs import centerpoint_waymo, minkunet_kitti

    return {
        "minkunet_kitti": ArchBinding(
            name="minkunet_kitti", model=minkunet,
            default_config=minkunet_kitti.CONFIG_BENCH,
            out_stride_of=lambda cfg: 1,
            outputs_of=_minkunet_outputs,
            in_channels_of=lambda cfg: cfg.in_channels),
        "centerpoint_waymo": ArchBinding(
            name="centerpoint_waymo", model=centerpoint,
            default_config=centerpoint_waymo.CONFIG_BENCH,
            out_stride_of=lambda cfg: 2 ** len(cfg.channels),
            outputs_of=_centerpoint_outputs,
            in_channels_of=lambda cfg: cfg.in_channels),
    }


ARCHS = _arch_bindings()

DEFAULT_LADDER = BucketLadder.geometric(base=512, steps=3, max_batch=4)
DEFAULT_SPATIAL_BOUND = 256


#: per-scene latencies kept for percentile stats; bounded so a
#: tune-once-serve-forever process doesn't grow memory with uptime
LATENCY_WINDOW = 8192

#: per-phase duration samples kept per phase name (same rationale)
PHASE_WINDOW = 4096


def percentiles_ms(values) -> Tuple[Optional[float], Optional[float]]:
    """(p50, p95) of a latency window — ``(None, None)`` when nothing was
    recorded, so an idle worker is distinguishable from an infinitely fast
    one (the old ``np.zeros(1)`` placeholder fabricated ``0.0`` ms)."""
    if not len(values):
        return (None, None)
    lat = np.asarray(values, dtype=np.float64)
    return (float(np.percentile(lat, 50)), float(np.percentile(lat, 95)))


def summarize_phases(windows: Dict[str, Sequence[float]]) -> Dict[str, dict]:
    """Fold per-phase duration windows into {phase: count/p50/p95} — the
    ``summary()['phases']`` block, shared by Engine and Router stats."""
    out = {}
    for name, window in sorted(windows.items()):
        p50, p95 = percentiles_ms(window)
        out[name] = {"count": len(window), "p50_ms": p50, "p95_ms": p95}
    return out


@dataclasses.dataclass
class EngineStats:
    submitted: int = 0
    completed: int = 0
    batches: int = 0
    routed_batches: int = 0      # batches assigned by a DeviceRouter
    flushes: int = 0
    busy_s: float = 0.0
    latencies_ms: "collections.deque" = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=LATENCY_WINDOW))
    recompiles: Dict[int, int] = dataclasses.field(default_factory=dict)
    map_compiles: Dict[int, int] = dataclasses.field(default_factory=dict)
    map_hits: int = 0
    map_misses: int = 0
    # scene-granular reuse (composed/incremental table strategies)
    scene_compiles: Dict[int, int] = dataclasses.field(default_factory=dict)
    scene_hits: int = 0          # batch slots served from the scene store
    scene_misses: int = 0        # cold scenes that built their own stack
    composed_batches: int = 0    # batch map stacks merge-composed, not built
    delta_merges: int = 0        # streaming frames that delta-merged a table
    # flush triggers beyond the explicit flush() call
    deadline_flushes: int = 0    # max_wait_ms expiries
    count_flushes: int = 0       # flush_count threshold crossings
    # per-phase duration windows (queue_wait/pack/map/execute/unpack/…) —
    # always on (a perf_counter pair + deque append per phase), independent
    # of whether the tracer is enabled
    phases: Dict[str, "collections.deque"] = dataclasses.field(
        default_factory=dict)
    # SLO accounting: requests measured against the deadline (max_wait_ms)
    slo_deadline_ms: Optional[float] = None
    slo_measured: int = 0
    slo_miss_count: int = 0

    def observe(self, phase: str, ms: float) -> None:
        window = self.phases.get(phase)
        if window is None:
            window = self.phases[phase] = collections.deque(
                maxlen=PHASE_WINDOW)
        window.append(ms)

    def slo_observe(self, latency_ms: float, deadline_ms: float) -> None:
        """Score one completed request against its latency deadline."""
        self.slo_deadline_ms = deadline_ms
        self.slo_measured += 1
        if latency_ms > deadline_ms:
            self.slo_miss_count += 1

    def summary(self) -> dict:
        p50, p95 = percentiles_ms(self.latencies_ms)
        return {
            "scenes": self.completed,
            "batches": self.batches,
            "routed_batches": self.routed_batches,
            "p50_ms": p50,
            "p95_ms": p95,
            "scenes_per_s": self.completed / self.busy_s if self.busy_s else 0.0,
            "recompiles": dict(self.recompiles),
            "map_compiles": dict(self.map_compiles),
            "map_cache": {"hits": self.map_hits, "misses": self.map_misses},
            "scene_tables": {"hits": self.scene_hits,
                             "misses": self.scene_misses,
                             "composed_batches": self.composed_batches,
                             "delta_merges": self.delta_merges,
                             "compiles": dict(self.scene_compiles)},
            "deadline_flushes": self.deadline_flushes,
            "count_flushes": self.count_flushes,
            "phases": summarize_phases(self.phases),
            "slo": {"deadline_ms": self.slo_deadline_ms,
                    "measured": self.slo_measured,
                    "misses": self.slo_miss_count,
                    "miss_rate": (self.slo_miss_count / self.slo_measured
                                  if self.slo_measured else None)},
        }


class Engine:
    """Front end: ``submit()`` scenes, ``flush()`` to run queued work.

    arch: "minkunet_kitti" | "centerpoint_waymo" (see ``ARCHS``).
    plans: a PlanRegistry (or path to one) holding tuned per-group dataflow
        assignments; missing entries fall back to the default config.
    map_strategy: coordinate-table strategy override ("sort" / "composed" /
        "incremental"); None follows the plan's declared ``KmapSpec.table``
        axis.  "sort" is the PR-2 whole-batch-digest behavior; "composed"
        adds scene-granular map reuse; "incremental" also enables
        ``submit_delta`` streaming-table merges.
    max_wait_ms / flush_count: latency-deadline and queue-depth triggers for
        automatic flushes (None disables each); auto-flushed results are
        returned by the next ``flush()``/``poll()``.
    scene_cache_size: LRU bound of the per-scene store.  Entries are
        host-resident numpy map stacks (~ refs x KD x scene-rung int32
        words each), so size this by host RAM, not device memory.
    device: pin this engine to one jax device — params and every packed
        batch are ``jax.device_put`` there, so each compiled rung's
        executor runs on that device.  None (default) follows jax's default
        placement.  This is how the ``DeviceRouter`` builds one worker per
        device.
    plan_key: the PlanRegistry name to read/write plans under (defaults to
        ``arch``; the router routes per-device entries like ``arch@dev2``
        here — see ``serve.plans.device_key``).
    """

    def __init__(self, arch: str, ladder: BucketLadder = DEFAULT_LADDER,
                 spatial_bound: int = DEFAULT_SPATIAL_BOUND,
                 model_config=None, params=None,
                 plans: Optional[PlanRegistry] = None,
                 maps_cache_size: int = 32, seed: int = 0,
                 precision=None, map_strategy: Optional[str] = None,
                 scene_cache_size: int = 64,
                 max_wait_ms: Optional[float] = None,
                 flush_count: Optional[int] = None,
                 device: Optional[jax.Device] = None,
                 plan_key: Optional[str] = None):
        if arch not in ARCHS:
            raise ValueError(f"unknown arch {arch!r}; have {sorted(ARCHS)}")
        self.binding = ARCHS[arch]
        self.arch = arch
        self.device = device
        self.cfg = model_config if model_config is not None else self.binding.default_config
        self.params = params if params is not None else self.binding.model.init_params(
            self.cfg, jax.random.PRNGKey(seed))
        if device is not None:
            self.params = jax.device_put(self.params, device)
        self.ladder = ladder
        self.batcher = SceneBatcher(ladder, spatial_bound)
        if isinstance(plans, str):
            plans = PlanRegistry.load(plans)
        self.plans = plans or PlanRegistry()
        self.plan_key = plan_key or arch
        self.assignment = self.plans.get(self.plan_key)
        # The compiled artifact every stage shares: a persisted NetworkPlan
        # is used as-is when it still matches this engine's model config
        # (same layer names + ConvSpecs); otherwise — v1 files, or a plan
        # tuned under a different width/depth — one is recompiled from the
        # model declaration with the registry's assignment.
        nplan = self.plans.network(self.plan_key)
        compiled = self.binding.model.network_plan(self.cfg,
                                                   assignment=self.assignment)
        if nplan is None or [(lp.name, lp.spec) for lp in nplan.layers] != \
                [(lp.name, lp.spec) for lp in compiled.layers]:
            nplan = compiled
        if precision is not None:
            nplan = nplan.with_precision(precision)
        self.nplan: NetworkPlan = nplan
        self.out_stride = self.binding.out_stride_of(self.cfg)
        self.map_strategy = (map_strategy if map_strategy is not None
                             else self.nplan.table_strategy)
        assert self.map_strategy in KmapSpec.TABLE_STRATEGIES, self.map_strategy
        self.max_wait_ms = max_wait_ms
        self.flush_count = flush_count
        self.stats = EngineStats()
        self.maps_cache_size = maps_cache_size
        self.scene_cache_size = scene_cache_size
        self._queue: List[tuple] = []       # (ticket, Scene, t_submit)
        self._next_ticket = 0
        self._ready: Dict[int, SceneResult] = {}   # auto-flushed results
        self._map_store: "collections.OrderedDict[str, dict]" = collections.OrderedDict()
        # The scene store is device-agnostic (host numpy), so a DeviceRouter
        # shares ONE store — and its lock — across all its workers; the lock
        # only guards dict mutation, never a build (concurrent builds of the
        # same digest are idempotent: entries are bit-identical).
        self._scene_lock = threading.Lock()
        self._scene_store: "collections.OrderedDict[str, SceneEntry]" = collections.OrderedDict()
        # stream id -> last scene, LRU-bounded: serve-forever processes see
        # ephemeral stream ids, and each entry pins a full host-side Scene
        self._streams: "collections.OrderedDict[str, Scene]" = collections.OrderedDict()
        self.stream_cache_size = 1024
        self._builders: Dict[int, Callable] = {}
        self._executors: Dict[int, Callable] = {}
        self._scene_builders: Dict[int, Callable] = {}
        self._scene_delta_builders: Dict[int, Callable] = {}
        #: (kind, rung) marks queued by trace-time side effects, drained by
        #: the jit wrappers into structured ``compile`` trace events
        self._compile_marks: List[tuple] = []
        # per-scene builds jit once per rung of a small capacity ladder
        # (scene sizes vary request to request; exact-size eager builds
        # would recompile every op per distinct size)
        caps = [min(64, ladder.capacities[0])]
        while caps[-1] < ladder.max_capacity:
            caps.append(caps[-1] * 2)
        self._scene_ladder = BucketLadder(tuple(caps), max_batch=1)

    # -------------------------------------------------------- observability
    @property
    def device_name(self) -> str:
        """The device identity compile events are keyed by (the pinned
        device, or jax's default placement when the engine floats)."""
        d = self.device if self.device is not None else jax.devices()[0]
        return str(d)

    @contextlib.contextmanager
    def _phase(self, name: str, **attrs):
        """Time one phase of the hot path into BOTH sinks: a tracer span
        (rich, nestable, exportable — no-op singleton when disabled) and
        the always-on ``EngineStats.phases`` histogram window."""
        t0 = time.perf_counter()
        with obs.span(name, **attrs) as sp:
            yield sp
        self.stats.observe(name, (time.perf_counter() - t0) * 1e3)

    def _jit_counting(self, fn, kind: str, counter_attr: str,
                      cap: int) -> Callable:
        """jit ``fn`` with the trace-time side effect that counts *actual*
        recompiles (not calls) into ``stats.<counter_attr>[cap]``, plus a
        structured ``compile`` trace event carrying (kind, rung, device,
        wall time).  The side effect fires mid-trace, where the compile's
        duration is unknowable, so it queues a mark; the wrapper drains
        marks after the triggering call returns and stamps the event with
        that call's wall time (trace + compile + first execution)."""
        def traced(*args):
            counters = getattr(self.stats, counter_attr)
            counters[cap] = counters.get(cap, 0) + 1
            self._compile_marks.append((kind, cap))
            return fn(*args)

        jfn = jax.jit(traced)

        def wrapper(*args):
            n0 = len(self._compile_marks)
            t0 = time.perf_counter()
            out = jfn(*args)
            if len(self._compile_marks) > n0:
                wall_ms = (time.perf_counter() - t0) * 1e3
                marks = self._compile_marks[n0:]
                del self._compile_marks[n0:]
                for k, c in marks:
                    obs.event("compile", kind=k, rung=c,
                              device=self.device_name,
                              wall_ms=round(wall_ms, 3))
            return out

        return wrapper

    # ------------------------------------------------------------------ jit
    def _builder_for(self, cap: int) -> Callable:
        fn = self._builders.get(cap)
        if fn is None:
            nplan = self.nplan
            fn = self._jit_counting(nplan.build_maps, "map_builder",
                                    "map_compiles", cap)
            self._builders[cap] = fn
        return fn

    def _executor_for(self, cap: int) -> Callable:
        fn = self._executors.get(cap)
        if fn is None:
            binding, cfg, nplan = self.binding, self.cfg, self.nplan

            def run(params, st, maps):
                feats = nplan.apply(params, st, maps, bn_mode="affine")
                return binding.outputs_of(cfg, st, maps, feats)

            fn = self._jit_counting(run, "executor", "recompiles", cap)
            self._executors[cap] = fn
        return fn

    # ------------------------------------------------------ scene-granular
    def _scene_tensor(self, scene: Scene, cap: int) -> SparseTensor:
        """Single-scene tensor (batch column 0) padded to a scene-ladder
        capacity, with declared bounds matching the packed batches — so its
        KeySpec, and therefore its sorted tables and maps, compose
        bit-identically into batch ones.  Features are irrelevant to
        mapping; a 1-channel zero column keeps the trace tiny."""
        n = scene.num_points
        coords = np.full((cap, 1 + scene.coords.shape[1]), int(INVALID_COORD),
                         np.int32)
        coords[:n, 0] = 0
        coords[:n, 1:] = scene.coords
        st = SparseTensor(coords=jnp.asarray(coords),
                          feats=jnp.zeros((cap, 1), jnp.float32),
                          num_valid=jnp.asarray(n, jnp.int32), stride=1,
                          batch_bound=self.ladder.max_batch,
                          spatial_bound=self.batcher.spatial_bound)
        return st if self.device is None else jax.device_put(st, self.device)

    def _scene_builder_for(self, cap: int) -> Callable:
        fn = self._scene_builders.get(cap)
        if fn is None:
            specs = self.nplan.map_specs
            fn = self._jit_counting(lambda st: scene_entry_arrays(specs, st),
                                    "scene_builder", "scene_compiles", cap)
            self._scene_builders[cap] = fn
        return fn

    def _scene_delta_builder_for(self, cap: int) -> Callable:
        """Like the scene builder, but adopting a delta-merged root table
        (passed as arrays, padded to ``cap``) so the build skips the scene
        argsort."""
        fn = self._scene_delta_builders.get(cap)
        if fn is None:
            specs = self.nplan.map_specs

            def build(st, keys, order):
                spec = hashing.key_spec_for(st.ndim_space, st.batch_bound,
                                            st.spatial_bound)
                maps, k, o = scene_entry_arrays(
                    specs, st, root_table=hashing.CoordTable(spec, keys, order))
                return maps, k, o

            fn = self._jit_counting(build, "scene_delta_builder",
                                    "scene_compiles", cap)
            self._scene_delta_builders[cap] = fn
        return fn

    def _store_scene(self, digest: str, entry: SceneEntry) -> None:
        with self._scene_lock:
            self._scene_store[digest] = entry
            while len(self._scene_store) > self.scene_cache_size:
                self._scene_store.popitem(last=False)

    def _scene_entry(self, scene: Scene) -> SceneEntry:
        with self._scene_lock:
            ent = self._scene_store.get(scene.digest)
            if ent is not None:
                self.stats.scene_hits += 1
                self._scene_store.move_to_end(scene.digest)
                return ent
        self.stats.scene_misses += 1
        cap = self._scene_ladder.select(scene.num_points)
        with self._phase("scene_build", cap=cap, points=scene.num_points):
            maps, keys, order = self._scene_builder_for(cap)(
                self._scene_tensor(scene, cap))
            ent = scene_entry_from_arrays(self.nplan.map_specs, maps,
                                          scene.num_points, keys, order)
        self._store_scene(scene.digest, ent)
        return ent

    def _maps_for(self, batch: PackedBatch,
                  scenes: Optional[Sequence[Scene]] = None) -> dict:
        maps = self._map_store.get(batch.digest)
        if maps is not None:
            self.stats.map_hits += 1
            self._map_store.move_to_end(batch.digest)
            return maps
        self.stats.map_misses += 1
        maps = None
        if scenes is not None and self.map_strategy in ("composed",
                                                        "incremental"):
            # includes nested scene_build spans for any cold scenes
            with self._phase("compose_kmaps", bucket=batch.bucket,
                             scenes=len(scenes)):
                entries = [self._scene_entry(s) for s in scenes]
                maps = compose_kmaps(entries, batch.bucket)
            if maps is not None:
                self.stats.composed_batches += 1
        if maps is None:
            with self._phase("map_build", bucket=batch.bucket):
                maps = self._builder_for(batch.bucket)(batch.st)
        self._map_store[batch.digest] = maps
        while len(self._map_store) > self.maps_cache_size:
            self._map_store.popitem(last=False)
        return maps

    # ------------------------------------------------------------------ api
    def submit(self, scene: Scene, stream: Optional[str] = None) -> int:
        """Enqueue one scene; returns a ticket resolved by the next flush.

        stream: optional stream id — remembers the scene as the stream's
        latest frame so later frames can arrive as ``submit_delta`` updates.
        Submitting may trigger an automatic flush (queue depth reaching
        ``flush_count``, or the oldest queued scene exceeding
        ``max_wait_ms``); those results are held for the next ``flush()``
        or ``poll()``.
        """
        if scene.num_points > self.ladder.max_capacity:
            raise ValueError(f"scene of {scene.num_points} rows exceeds the "
                             f"largest bucket ({self.ladder.max_capacity})")
        t = self._next_ticket
        self._next_ticket += 1
        self._queue.append((t, scene, time.perf_counter()))
        self.stats.submitted += 1
        if stream is not None:
            self._streams[stream] = scene
            self._streams.move_to_end(stream)
            while len(self._streams) > self.stream_cache_size:
                self._streams.popitem(last=False)
        self._autoflush()
        return t

    def submit_delta(self, stream: str, delta: SceneDelta) -> int:
        """Enqueue a streaming frame as a delta of the stream's last scene.

        Under the ``"incremental"`` strategy the scene's cached sorted table
        is **delta-merged** (O(r+a) merge, no argsort of the full cloud) and
        the scene's map stack is rebuilt on the merged table, so the frame
        composes into batches like any warm scene; other strategies just
        apply the delta and submit the full scene.
        """
        return self.submit(self._merge_delta(stream, delta), stream=stream)

    def _merge_delta(self, stream: str, delta: SceneDelta) -> Scene:
        """Apply ``delta`` to the stream's last scene and (incremental
        strategy) delta-merge its cached table into a fresh SceneEntry.
        Host-side work only — the router calls this on one worker and the
        resulting store entry composes on every device."""
        prev = self._streams.get(stream)
        if prev is None:
            raise KeyError(f"unknown stream {stream!r}; seed it with "
                           f"submit(scene, stream=...) first")
        if (delta.added_coords.size and
                int(np.abs(delta.added_coords).max()) > self.batcher.spatial_bound):
            # the same declared-bound promise pack() enforces — reject here,
            # BEFORE an out-of-range coord could mis-pack into a cached
            # scene table (host-side np_pack_keys has no PAD sentinel)
            raise ValueError(
                f"delta adds a coord violating declared spatial_bound "
                f"{self.batcher.spatial_bound}: max |coord| = "
                f"{np.abs(delta.added_coords).max()}")
        scene = apply_delta(prev, delta)
        if (self.map_strategy == "incremental"
                and scene.digest not in self._scene_store):
            with self._scene_lock:
                prev_ent = self._scene_store.get(prev.digest)
            if prev_ent is not None:
                with self._phase("delta_merge", stream=stream,
                                 added=int(delta.added_coords.shape[0]),
                                 removed=int(delta.removed.shape[0])):
                    spec = hashing.key_spec_for(scene.coords.shape[1],
                                                self.ladder.max_batch,
                                                self.batcher.spatial_bound)
                    # host-side O(r+a) sorted merge of the cached scene table
                    mkeys, morder = hashing.np_delta_merge(
                        spec, prev_ent.root_keys, prev_ent.root_order,
                        np.concatenate([np.zeros((delta.removed.shape[0], 1),
                                                 np.int32), delta.removed], 1),
                        np.concatenate([np.zeros((delta.added_coords.shape[0], 1),
                                                 np.int32), delta.added_coords], 1))
                    # pad the merged table up to the scene rung — identical to
                    # a fresh build of the padded scene tensor (PAD keys sort
                    # last, pad rows in slot order), so the jitted builder
                    # adopts it transparently
                    n = scene.num_points
                    cap = self._scene_ladder.select(n)
                    pad = (cap - n,) + mkeys.shape[1:]
                    keys = np.concatenate([
                        mkeys, np.full(pad, np.iinfo(np.int32).max, np.int32)])
                    order = np.concatenate([
                        morder, np.arange(n, cap, dtype=np.int32)])
                    maps, k, o = self._scene_delta_builder_for(cap)(
                        self._scene_tensor(scene, cap), jnp.asarray(keys),
                        jnp.asarray(order))
                    ent = scene_entry_from_arrays(self.nplan.map_specs, maps,
                                                  n, k, o)
                    self._store_scene(scene.digest, ent)
                    self.stats.delta_merges += 1
        return scene

    def _deadline_due(self) -> bool:
        return (self.max_wait_ms is not None and bool(self._queue) and
                (time.perf_counter() - self._queue[0][2]) * 1e3
                >= self.max_wait_ms)

    def _autoflush(self) -> None:
        if self.flush_count is not None and len(self._queue) >= self.flush_count:
            self.stats.count_flushes += 1
            self._ready.update(self._run_queue())
        elif self._deadline_due():
            self.stats.deadline_flushes += 1
            self._ready.update(self._run_queue())

    def poll(self) -> Dict[int, SceneResult]:
        """Deadline hook for timer-driven callers: flush iff the oldest
        queued scene has waited past ``max_wait_ms``, then drain any results
        completed by automatic flushes."""
        if self._deadline_due():
            self.stats.deadline_flushes += 1
            self._ready.update(self._run_queue())
        out, self._ready = self._ready, {}
        return out

    def flush(self) -> Dict[int, SceneResult]:
        """Pack and run everything queued; returns {ticket: SceneResult}
        (including results completed earlier by automatic flushes)."""
        out, self._ready = self._ready, {}
        out.update(self._run_queue())
        return out

    def _dispatch_group(self, scenes: Sequence[Scene]) -> Tuple[PackedBatch, tuple]:
        """Pack ``scenes``, resolve their maps, and dispatch the executor on
        this engine's device *without* blocking — pair with
        ``_finish_group``.  The dispatch/finish split is what lets the
        ``DeviceRouter`` overlap one worker's host-side packing with another
        worker's device execution."""
        with self._phase("pack", scenes=len(scenes)) as sp:
            batch = self.batcher.pack(scenes)
            sp.set(bucket=batch.bucket)
            if self.device is not None:
                batch = dataclasses.replace(
                    batch, st=jax.device_put(batch.st, self.device))
        with self._phase("map", bucket=batch.bucket):
            maps = self._maps_for(batch, scenes)
        with self._phase("dispatch", bucket=batch.bucket,
                         device=self.device_name):
            out = self._executor_for(batch.bucket)(self.params, batch.st, maps)
        return batch, out

    def _finish_group(self, batch: PackedBatch, out) -> List[SceneResult]:
        """Block on a dispatched batch and unpack it into per-scene rows."""
        with self._phase("execute", bucket=batch.bucket,
                         device=self.device_name):
            out_coords, out_feats, n_out = jax.block_until_ready(out)
        with self._phase("unpack", bucket=batch.bucket,
                         scenes=batch.num_scenes):
            per_scene = self.batcher.unpack(batch, out_coords, out_feats,
                                            int(n_out), self.out_stride)
        self.stats.batches += 1
        self.stats.completed += batch.num_scenes
        return per_scene

    def _run_queue(self) -> Dict[int, SceneResult]:
        if not self._queue:
            return {}
        queue, self._queue = self._queue, []
        t0 = time.perf_counter()
        with obs.span("flush", scenes=len(queue), device=self.device_name):
            # queue wait = submit → flush start; submit stamped the same
            # monotonic clock the tracer uses, so the interval replays
            # exactly in the trace timeline
            t0_ns = time.perf_counter_ns()
            for ticket, _, t_sub in queue:
                wait_ms = (t0 - t_sub) * 1e3
                self.stats.observe("queue_wait", wait_ms)
                obs.record_span("queue_wait", int(t_sub * 1e9), t0_ns,
                                ticket=ticket)
            results: Dict[int, SceneResult] = {}
            groups = self.batcher.plan([s.num_points for _, s, _ in queue])
            for group in groups:
                batch, out = self._dispatch_group(
                    [queue[i][1] for i in group])
                per_scene = self._finish_group(batch, out)
                t_done = time.perf_counter()
                t_done_ns = time.perf_counter_ns()
                for slot, i in enumerate(group):
                    ticket, _, t_sub = queue[i]
                    results[ticket] = per_scene[slot]
                    lat_ms = (t_done - t_sub) * 1e3
                    self.stats.latencies_ms.append(lat_ms)
                    obs.record_span("request", int(t_sub * 1e9), t_done_ns,
                                    ticket=ticket, bucket=batch.bucket)
                    if self.max_wait_ms is not None:
                        # max_wait_ms doubles as the per-request latency SLO
                        self.stats.slo_observe(lat_ms, self.max_wait_ms)
        self.stats.busy_s += time.perf_counter() - t0
        self.stats.flushes += 1
        return results

    def serve(self, scenes: Sequence[Scene],
              flush_every: int = 0) -> List[SceneResult]:
        """Convenience driver: submit all, flush (in chunks), return in order."""
        out: Dict[int, SceneResult] = {}
        tickets = []
        for i, s in enumerate(scenes):
            tickets.append(self.submit(s))
            if flush_every and (i + 1) % flush_every == 0:
                out.update(self.flush())
        out.update(self.flush())
        return [out[t] for t in tickets]

    def warmup(self, channels: Optional[int] = None) -> None:
        """Compile every bucket once on synthetic single-scene batches so the
        request stream never pays a trace.  Under the composed/incremental
        strategies this also traces the per-scene builders for every rung of
        the scene-capacity ladder (and the delta builders, for streaming)."""
        c = channels or self.binding.in_channels_of(self.cfg)
        if self.map_strategy in ("composed", "incremental"):
            for cap in self._scene_ladder.capacities:
                rng = np.random.default_rng(cap)
                coords = np.unique(rng.integers(
                    -self.batcher.spatial_bound, self.batcher.spatial_bound,
                    size=(2 * cap, 3), dtype=np.int32), axis=0)[:cap]
                st = self._scene_tensor(
                    Scene(coords=coords,
                          feats=np.zeros((coords.shape[0], c), np.float32)),
                    cap)
                maps, keys, order = jax.block_until_ready(
                    self._scene_builder_for(cap)(st))
                if self.map_strategy == "incremental":
                    # the fresh table doubles as a valid adopted-table input
                    jax.block_until_ready(
                        self._scene_delta_builder_for(cap)(st, keys, order))
        for cap in self.ladder.capacities:
            n = cap   # fill the bucket exactly so every rung compiles
            rng = np.random.default_rng(cap)
            coords = rng.integers(-self.batcher.spatial_bound,
                                  self.batcher.spatial_bound, size=(n, 3),
                                  dtype=np.int32)
            scene = Scene(coords=coords, feats=rng.normal(size=(n, c)).astype(np.float32))
            # go through the REAL dispatch path: it commits the packed batch
            # to this engine's device, and a warmup executed with any other
            # input placement compiles a *different* executable — the first
            # live batch would silently pay a second compile per rung
            batch, out = self._dispatch_group([scene])
            assert batch.bucket == cap, (batch.bucket, cap)
            jax.block_until_ready(out)

    # ------------------------------------------------------------- autotune
    def tune(self, sample_scenes: Sequence[Scene],
             space: Optional[Sequence[df.DataflowConfig]] = None,
             iters: int = 2, save: bool = True) -> Dict[tuple, TrainDataflowConfig]:
        """Run the group-based Sparse Autotuner on a representative packed
        batch and persist the winning *NetworkPlan* to the PlanRegistry.

        Measurement is end-to-end engine-forward latency of each candidate
        plan (paper §4: never per-kernel time).  Existing executors are
        dropped so the tuned plan takes effect on the next flush.  Returns
        the per-group assignment for inspection; the serialized plan (and
        its v1-compatible assignment block) lands in the registry.
        """
        space = list(space or [df.DataflowConfig("gather_scatter"),
                               df.DataflowConfig("implicit_gemm", n_splits=1)])
        sample_scenes = list(sample_scenes)
        # measure on the first bucket-fitting FIFO group of the sample
        group = self.batcher.plan([s.num_points for s in sample_scenes])[0]
        group_scenes = [sample_scenes[i] for i in group]
        batch = self.batcher.pack(group_scenes)
        maps = self._maps_for(batch, group_scenes)

        def measure(candidate: NetworkPlan) -> float:
            fn = jax.jit(lambda p, st, m: candidate.apply(p, st, m,
                                                          bn_mode="affine"))
            return timeit_fn(lambda: jax.block_until_ready(
                fn(self.params, batch.st, maps)), warmup=1, iters=iters)

        tuned = PlanTuner(self.nplan, space, measure).tune()
        self.nplan = tuned
        self.assignment = tuned.assignment()
        self.plans.set(self.plan_key, self.assignment, network=tuned)
        if save and self.plans.path:
            self.plans.save()
        self._executors.clear()   # recompile with the tuned plan
        return dict(self.assignment)
