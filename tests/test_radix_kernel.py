"""Pallas O(N) radix argsort vs the stable comparison-argsort oracle
(interpret mode on CPU; the kernel targets TPU).  The permutation contract
is *bit*-identity: same layout as ``jnp.argsort(stable=True)`` /
``lex_argsort`` including tie order, MISS (-1, sorts first) and the PAD
tail (int32 max, sorts last)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hashing
from repro.kernels.radix_sort.ops import radix_argsort
from repro.kernels.radix_sort.radix_sort import radix_argsort_bits_pallas
from repro.kernels.radix_sort.ref import radix_argsort_ref


def _keys(seed, spec, n=90, cap=128, extent=8, lo=-4, batch=2):
    rng = np.random.default_rng(seed)
    coords = np.concatenate([rng.integers(0, batch, (n, 1)),
                             rng.integers(lo, extent, (n, 3))], axis=1)
    coords = np.concatenate([coords, np.zeros((cap - n, 4), np.int32)])
    valid = np.arange(cap) < n
    keys = hashing.pack_keys(jnp.asarray(coords, jnp.int32), spec,
                             valid=jnp.asarray(valid))
    kn = np.array(keys)
    kn[40:50] = kn[0:10]     # duplicates: tie order must survive
    return jnp.asarray(kn)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_radix_kernel_matches_ref_one_word(seed):
    spec = hashing.key_spec_for(3, batch_bound=2, spatial_bound=8)
    assert spec.words == 1 and not spec.raw
    keys = _keys(seed, spec)
    got = radix_argsort(keys, spec, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(radix_argsort_ref(keys)))


@pytest.mark.parametrize("seed", [3, 4])
def test_radix_kernel_matches_ref_two_word(seed):
    spec = hashing.key_spec_for(3, batch_bound=500, spatial_bound=12000)
    assert spec.words == 2 and not spec.raw
    keys = _keys(seed, spec)
    got = radix_argsort(keys, spec, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(radix_argsort_ref(keys)))


def test_radix_kernel_matches_xla_twin():
    """Kernel and XLA fallback are the same algorithm — identical output."""
    spec = hashing.key_spec_for(3, batch_bound=4, spatial_bound=20)
    keys = _keys(5, spec)
    np.testing.assert_array_equal(
        np.asarray(radix_argsort(keys, spec, interpret=True)),
        np.asarray(hashing.radix_argsort_keys(keys, spec)))


def test_radix_kernel_bits_core_matches_stable_argsort():
    rng = np.random.default_rng(6)
    vals = rng.integers(0, 1 << 10, 257).astype(np.int32)
    vals[30:60] = vals[0:30]     # duplicates
    got = radix_argsort_bits_pallas(jnp.asarray(vals), nbits=10,
                                    interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.argsort(vals, kind="stable"))


def test_radix_kernel_rejects_raw_specs_and_handles_empty():
    raw = hashing.key_spec_for(3)     # unknown bounds → raw columns
    with pytest.raises(ValueError):
        radix_argsort(jnp.zeros((4, 4), jnp.int32), raw)
    spec = hashing.key_spec_for(3, batch_bound=2, spatial_bound=8)
    out = radix_argsort(jnp.zeros((0,), jnp.int32), spec, interpret=True)
    assert out.shape == (0,)
