"""Serving-engine throughput/latency: bucketed batching + map reuse.

The production question behind the ROADMAP north star: what does the sparse
stack sustain under mixed-size request traffic?  For each arch
(MinkUNet-KITTI segmentation, CenterPoint-Waymo detection) this suite
drives the same synthetic stream through:

* ``batched``   — the serving engine with its bucket ladder (warm, jitted);
* ``unbatched`` — the same engine restricted to one scene per batch
  (the "per-request forward" a naive deployment does);
* ``repeat``    — the stream replayed through the warm engine: identical
  packed batches hit the cross-request map cache, so the second epoch skips
  kernel-map construction entirely (hit rate in the derived column).

Emits scenes/s and p50/p95 per-scene latency.  ``--tiny`` shrinks the
stream and ladder for CI smoke coverage.
"""
from __future__ import annotations

import argparse

from benchmarks import common
from repro.serve.bucketing import BucketLadder
from repro.serve.engine import ARCHS, Engine, EngineStats
from repro.serve.workload import lidar_stream


def _drive(arch: str, scenes, bound: int, ladder: BucketLadder,
           flush_every: int, tag: str, epochs: int = 1):
    eng = Engine(arch, ladder=ladder, spatial_bound=bound)
    eng.warmup()
    eng.stats = EngineStats()   # steady state only: warmup compiles excluded,
    for _ in range(epochs):     # so recompiles should stay 0
        eng.serve(scenes, flush_every=flush_every)
    s = eng.stats.summary()
    mc = s["map_cache"]
    hit_rate = mc["hits"] / max(mc["hits"] + mc["misses"], 1)
    derived = (f"scenes_per_s={s['scenes_per_s']:.2f};p95_ms={s['p95_ms']:.1f};"
               f"recompiles={sum(s['recompiles'].values())};"
               f"map_hit_rate={hit_rate:.2f}")
    common.emit(f"serving/{arch}/{tag}/p50", s["p50_ms"] * 1e3, derived)
    return s


def run(tiny: bool = False):
    if tiny:
        count, n_range, ladder = 6, (80, 400), BucketLadder((256, 512), max_batch=3)
        flush_every = 3
    else:
        count, n_range = 24, (200, 1200)
        ladder = BucketLadder((512, 1024, 2048), max_batch=4)
        flush_every = 8

    for arch in sorted(ARCHS):
        channels = ARCHS[arch].in_channels_of(ARCHS[arch].default_config)
        scenes, bound = lidar_stream(0, count, channels, n_range=n_range)
        batched = _drive(arch, scenes, bound, ladder, flush_every, "batched")
        single = BucketLadder(ladder.capacities, max_batch=1)
        unbatched = _drive(arch, scenes, bound, single, 1, "unbatched")
        speedup = (batched["scenes_per_s"] /
                   max(unbatched["scenes_per_s"], 1e-9))
        common.emit(f"serving/{arch}/batched_vs_unbatched", 0.0,
                    f"throughput_ratio={speedup:.2f}x")

        _drive(arch, scenes, bound, ladder, flush_every, "repeat", epochs=2)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="reduced stream for CI smoke runs")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(tiny=args.tiny)
