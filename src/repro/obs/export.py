"""Trace exporters: Chrome trace-event JSON and flat JSONL.

Two artifact formats for one ``Tracer``:

* **Chrome trace-event JSON** (``chrome_trace`` / path without ``.jsonl``)
  — loadable in Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``.
  Spans become complete (``"ph": "X"``) events, instant events become
  ``"ph": "i"``, and thread-name metadata rows give one swimlane per
  engine/worker thread, so nested queue/pack/map/execute/unpack phases
  render as stacked slices per thread.  Spans/events carrying a ``host``
  attr (the fleet front end stamps its RPC spans and liveness events with
  the worker host's label) are additionally grouped into one synthetic
  *process* lane per host — the per-host swimlanes of a fleet trace — with
  ``process_name`` metadata rows naming each ``host hN`` lane.
* **JSONL** (``.jsonl`` path) — one JSON object per line (``type`` is
  ``span`` / ``event``), closed by a ``snapshot`` line carrying the
  counters/gauges; trivially greppable and streamable.

Timestamps are monotonic-clock microseconds (Chrome) / nanoseconds
(JSONL) — relative within the trace, not wall-clock.
"""
from __future__ import annotations

import json
import os
from typing import List

from repro.obs.trace import Tracer


def chrome_trace(tracer: Tracer) -> dict:
    """The trace as a Chrome trace-event dict (``traceEvents`` schema)."""
    pid = os.getpid()
    events: List[dict] = [
        {"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
         "args": {"name": "repro"}},
    ]
    named_tids = set()
    host_pids = {}

    def pid_of(attrs) -> int:
        """The lane a record renders in: the front-end process by default,
        a synthetic per-host process when the record names a fleet host."""
        host = attrs.get("host")
        if host is None:
            return pid
        hpid = host_pids.get(host)
        if hpid is None:
            # deterministic synthetic pids, far from real ones
            hpid = host_pids[host] = 1_000_000 + len(host_pids)
            events.append({"name": "process_name", "ph": "M", "pid": hpid,
                           "tid": 0, "args": {"name": f"host {host}"}})
        return hpid

    def thread_meta(p: int, tid: int, thread: str) -> None:
        if (p, tid) not in named_tids:
            named_tids.add((p, tid))
            events.append({"name": "thread_name", "ph": "M", "pid": p,
                           "tid": tid, "args": {"name": thread}})

    for rec in tracer.spans():
        p = pid_of(rec.attrs)
        thread_meta(p, rec.tid, rec.thread)
        events.append({
            "name": rec.name, "cat": "phase", "ph": "X",
            "ts": rec.t0_ns / 1e3, "dur": (rec.t1_ns - rec.t0_ns) / 1e3,
            "pid": p, "tid": rec.tid, "args": dict(rec.attrs)})
    for rec in tracer.events():
        p = pid_of(rec.attrs)
        thread_meta(p, rec.tid, rec.thread)
        events.append({
            "name": rec.name, "cat": "event", "ph": "i", "s": "t",
            "ts": rec.t_ns / 1e3, "pid": p, "tid": rec.tid,
            "args": dict(rec.attrs)})
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": tracer.snapshot()}


def export_chrome(tracer: Tracer, path: str) -> str:
    with open(path, "w") as f:
        json.dump(chrome_trace(tracer), f)
        f.write("\n")
    return path


def export_jsonl(tracer: Tracer, path: str) -> str:
    with open(path, "w") as f:
        for rec in tracer.spans():
            f.write(json.dumps({
                "type": "span", "name": rec.name, "t0_ns": rec.t0_ns,
                "t1_ns": rec.t1_ns, "dur_ms": rec.dur_ms, "tid": rec.tid,
                "thread": rec.thread, "depth": rec.depth,
                "attrs": dict(rec.attrs)}) + "\n")
        for rec in tracer.events():
            f.write(json.dumps({
                "type": "event", "name": rec.name, "t_ns": rec.t_ns,
                "tid": rec.tid, "thread": rec.thread,
                "attrs": dict(rec.attrs)}) + "\n")
        f.write(json.dumps({"type": "snapshot", **tracer.snapshot()}) + "\n")
    return path


def export(tracer: Tracer, path: str) -> str:
    """Write the artifact format the extension asks for: ``*.jsonl`` → the
    flat event log, anything else → Chrome trace JSON."""
    if path.endswith(".jsonl"):
        return export_jsonl(tracer, path)
    return export_chrome(tracer, path)
