"""The consolidated perf artifact (benchmarks/run.py --out BENCH_CI.json):
row parsing, median folding, and environment metadata — the pieces CI
relies on to accumulate the perf trajectory."""
import json

from benchmarks import common
from benchmarks.run import _metadata, _row_dict


def test_emit_records_structured_rows():
    start = len(common.RECORDS)
    try:
        # names/derived may legally contain commas ("splits={1,2}"), which
        # is exactly why the artifact reads RECORDS, not the CSV lines
        common.emit("tab5/SK-M/splits={1,2}", 68243.1, "x=1,y=2")
        r = _row_dict(common.RECORDS[-1])
    finally:
        del common.RECORDS[start:], common.ROWS[start:]
    assert r["name"] == "tab5/SK-M/splits={1,2}"
    assert r["us_per_call"] == 68243.1
    assert r["derived"] == "x=1,y=2"


def test_metadata_is_json_serializable_and_complete():
    meta = _metadata(tiny=True)
    assert meta["tiny"] is True
    for key in ("timestamp_utc", "git_sha", "jax", "backend",
                "device_count", "python", "platform"):
        assert key in meta, key
    json.dumps(meta)   # artifact must serialize as-is
