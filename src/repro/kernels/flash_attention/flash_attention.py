"""Blockwise causal flash attention (forward) as a Pallas TPU kernel.

Used by the LM stack of the framework (the assigned architectures); VMEM
tiling follows the classic FlashAttention recipe: Q tile resident, K/V
streamed block-by-block with an online-softmax accumulator.  Causal blocks
above the diagonal are skipped via the grid index map (no masked compute at
all for fully-masked tiles — the same "skip empty tiles" economics as the
sparse-conv occupancy masks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, block_q: int, block_k: int, causal: bool, kv_len: int):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    qb = pl.program_id(1)
    q_start = qb * block_q
    k_start = kb * block_k

    def compute():
        q = q_ref[0].astype(jnp.float32) * scale           # (bq, d)
        k = k_ref[0].astype(jnp.float32)                    # (bk, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)
        if causal:
            offset = kv_len - pl.num_programs(1) * block_q
            rows = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(cols <= rows + offset, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_scr[...] + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
            p, v_ref[0].astype(jnp.float32), preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    if causal:
        offset = kv_len - pl.num_programs(1) * block_q

        @pl.when(k_start <= q_start + offset + block_q - 1)
        def _run():
            compute()
    else:
        compute()

    @pl.when(kb == pl.num_programs(2) - 1)
    def _flush():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, block_q: int = 128,
                           block_k: int = 128, interpret: bool = True) -> jax.Array:
    """q: (BH, S, D); k/v: (BH, T, D) — heads pre-flattened/broadcast."""
    bh, s, d = q.shape
    t = k.shape[1]
    assert s % block_q == 0 and t % block_k == 0
    scale = d ** -0.5
    grid = (bh, s // block_q, t // block_k)
    kernel = functools.partial(_kernel, scale=scale, block_q=block_q,
                               block_k=block_k, causal=causal, kv_len=t)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=common.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
            interpret=interpret),
    )(q, k, v)
