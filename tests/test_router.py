"""Multi-device sharded serving tier (serve/router.py).

The contract under test:

* routing is **deterministic** — the same stream always yields the same
  device assignment (route_log equality);
* the load score **balances** — on uniform streams no device exceeds its
  fair share by more than one batch (round-robin tie-break);
* sharding is **transparent** — router outputs are bit-identical to the
  single-device engine on the same stream, and a one-worker router
  degenerates to the plain engine;
* compile churn stays bounded: ≤1 executor compile per (rung, worker)
  after warmup.

Most tests shard across *workers pinned to the same device* (a
device-count-independent way to exercise the routing/merging machinery in
the single-device tier-1 run); the ``@needs_multidevice`` cases run in the
CI multi-device job under
``XLA_FLAGS=--xla_force_host_platform_device_count=4``.
"""
import jax
import numpy as np
import pytest

from repro.launch import mesh
from repro.serve import (BucketLadder, DeviceRouter, Engine, PlanRegistry,
                         Scene, device_key)
from repro.serve.workload import lidar_stream

needs_multidevice = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs 4 devices (CI multi-device job sets "
           "XLA_FLAGS=--xla_force_host_platform_device_count=4)")

ARCH = "minkunet_kitti"
LADDER = BucketLadder((128, 256), max_batch=2)


def _stream(count=8, seed=0, n_range=(40, 100)):
    return lidar_stream(seed, count, 4, n_range=n_range)


def _same_device_router(n_workers, **kw):
    """A router whose workers share device 0 — exercises routing, thread
    merging, and shared stores without needing virtual devices."""
    dev = jax.devices()[0]
    return DeviceRouter(ARCH, devices=[dev] * n_workers, ladder=LADDER,
                        **kw)


# ---------------------------------------------------------------- routing

def test_route_uniform_stream_round_robin_fair_share():
    scenes, bound = _stream()
    r = _same_device_router(3, spatial_bound=bound)
    counts = [0, 0, 0]
    for _ in range(10):                      # 10 uniform batches of 128 rows
        counts[r._route(128)] += 1
    assert max(counts) - min(counts) <= 1, counts
    assert sum(counts) == 10


def test_route_prefers_least_loaded_device():
    scenes, bound = _stream()
    r = _same_device_router(2, spatial_bound=bound)
    first = r._route(256)                    # one big batch
    # the next two small batches go to the OTHER worker until loads even out
    assert r._route(128) == 1 - first
    assert r._route(128) == 1 - first
    assert r.outstanding_rows[first] == 256
    assert r.outstanding_rows[1 - first] == 256


def test_route_log_deterministic_same_stream():
    _, bound = _stream()
    rows = [128, 256, 128, 128, 256, 128, 128, 128]
    logs = []
    for _ in range(2):
        r = _same_device_router(3, spatial_bound=bound)
        for n in rows:
            r._route(n)
        logs.append(list(r.stats.route_log))
    assert logs[0] == logs[1]
    assert [n for _, n in logs[0]] == rows


# ----------------------------------------------------- per-device plans

def test_plan_registry_device_key_resolution(tmp_path):
    reg = PlanRegistry()
    reg.set(ARCH, {})
    reg.set(device_key(ARCH, 1), {})
    assert reg.resolve_key(ARCH) == ARCH
    assert reg.resolve_key(ARCH, 0) == ARCH                # no entry: shared
    assert reg.resolve_key(ARCH, 1) == device_key(ARCH, 1)
    # per-device names are ordinary schema-v2 entries: round-trips
    path = reg.save(str(tmp_path / "plans.json"))
    loaded = PlanRegistry.load(path)
    assert loaded.resolve_key(ARCH, 1) == f"{ARCH}@dev1"


def test_serving_devices_error_names_the_flag():
    n = jax.device_count() + 1
    with pytest.raises(RuntimeError, match="xla_force_host_platform"):
        mesh.serving_devices(n)
    assert len(mesh.serving_devices(1)) == 1
    assert mesh.make_serving_mesh(1).axis_names == ("serve",)


# ------------------------------------------------- end-to-end contracts

def _assert_results_equal(got, want):
    assert len(got) == len(want)
    for a, b in zip(got, want):
        np.testing.assert_array_equal(a.coords, b.coords)
        np.testing.assert_array_equal(a.feats, b.feats)
        assert a.stride == b.stride


def test_single_worker_router_degenerates_to_engine():
    scenes, bound = _stream(count=6)
    eng = Engine(ARCH, ladder=LADDER, spatial_bound=bound)
    ref = eng.serve(scenes, flush_every=3)
    r = _same_device_router(1, spatial_bound=bound)
    got = r.serve(scenes, flush_every=3)
    _assert_results_equal(got, ref)
    s = r.stats.summary()
    assert s["scenes"] == 6
    assert s["batches"] == s["routed_batches"] == sum(
        d["routed_batches"] for d in s["devices"].values())


def test_sharded_router_bit_identical_and_bounded_compiles():
    scenes, bound = _stream(count=8)
    eng = Engine(ARCH, ladder=LADDER, spatial_bound=bound)
    ref = eng.serve(scenes, flush_every=4)

    r = _same_device_router(2, spatial_bound=bound)
    r.warmup()
    got = r.serve(scenes, flush_every=4)
    _assert_results_equal(got, ref)

    s = r.stats.summary()
    # every worker was used and nobody exceeded fair share by > 1 batch
    per_dev = [d["routed_batches"] for d in s["devices"].values()]
    assert min(per_dev) >= 1
    assert max(per_dev) - min(per_dev) <= 1, per_dev
    # ≤1 executor compile per (rung, worker), all during warmup
    assert all(n == 1 for n in s["recompiles"].values()), s["recompiles"]
    # replay the same stream: routing repeats, so per-worker digest caches hit
    r.serve(scenes, flush_every=4)
    s2 = r.stats.summary()
    assert s2["recompiles"] == s["recompiles"]          # no new traces
    assert s2["map_cache"]["hits"] > 0


def test_router_workers_share_scene_store():
    scenes, bound = _stream(count=6, n_range=(40, 80))
    r = _same_device_router(2, spatial_bound=bound)
    assert r.workers[0]._scene_store is r.workers[1]._scene_store
    r.serve(scenes, flush_every=2)
    r.serve(scenes, flush_every=2)          # warm replay composes from store
    s = r.stats.summary()
    st = s["scene_tables"]
    assert st["misses"] <= len(scenes)      # each scene built at most once…
    assert st["hits"] > 0                   # …then reused across workers
    assert st["composed_batches"] > 0


def test_router_flush_count_autoflush():
    scenes, bound = _stream(count=4)
    r = _same_device_router(2, spatial_bound=bound, flush_count=2)
    t0, t1 = r.submit(scenes[0]), r.submit(scenes[1])   # triggers at depth 2
    assert r.stats.count_flushes == 1
    out = r.flush()
    assert set(out) == {t0, t1}
    assert r.stats.summary()["scenes"] == 2


# ------------------------------------------------------ real multi-device

@needs_multidevice
def test_router_four_devices_bit_identical_and_all_used():
    scenes, bound = _stream(count=12)
    eng = Engine(ARCH, ladder=LADDER, spatial_bound=bound)
    ref = eng.serve(scenes, flush_every=6)

    r = DeviceRouter(ARCH, devices=4, ladder=LADDER, spatial_bound=bound)
    assert len({str(w.device) for w in r.workers}) == 4
    r.warmup()
    got = r.serve(scenes, flush_every=6)
    _assert_results_equal(got, ref)
    s = r.stats.summary()
    per_dev = [d["routed_batches"] for d in s["devices"].values()]
    assert min(per_dev) >= 1, per_dev
    assert all(n == 1 for n in s["recompiles"].values()), s["recompiles"]


@needs_multidevice
def test_router_four_devices_deterministic_assignment():
    scenes, bound = _stream(count=10, seed=3)
    logs = []
    for _ in range(2):
        r = DeviceRouter(ARCH, devices=4, ladder=LADDER, spatial_bound=bound)
        r.serve(scenes, flush_every=5)
        logs.append(list(r.stats.route_log))
    assert logs[0] == logs[1]
    assert len(logs[0]) == r.stats.summary()["routed_batches"]
