"""Paper Fig. 15 + Fig. 22 + §5 — training-step latency: bound vs decoupled
fwd/dgrad/wgrad dataflows (two binding schemes), and the mixed-precision
training path (bf16 compute / fp32 accumulate / fp32 master weights)
against full fp32 on the same plan-driven workload.

``--tiny`` runs the mixed-precision A/B alone on a reduced scene for CI
smoke coverage (the tuner sweeps re-jit per candidate and dominate wall
clock).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import dataflows as df
from repro.core import precision as prec
from repro.core.autotuner import timeit_fn
from repro.core.plan import TrainingPlanTuner
from repro.core.sparse_conv import TrainDataflowConfig
from repro.models import minkunet
from repro.train import optimizer as opt


def _train_step_fn(nplan, stx, maps, labels, ocfg):
    """One full train step (fwd + dgrad + wgrad + optimizer) on prebuilt
    kernel maps — maps are dtype-independent int32 work shared by every
    precision variant (and cached across steps by real pipelines), so they
    stay outside the timed variant comparison, as in the seed bench."""
    def loss(p):
        lg = nplan.apply(p, stx, maps).astype(jnp.float32)
        ls = jax.nn.log_softmax(lg)[jnp.arange(stx.capacity), labels]
        return -jnp.sum(jnp.where(stx.valid_mask, ls, 0))

    @jax.jit
    def step(p, state):
        l, g = jax.value_and_grad(loss)(p)
        p2, s2, _ = opt.adamw_update(p, g, state, ocfg)
        return p2, s2, l

    return step


def run_mixed_precision(cfg, stx, iters: int):
    """fp32 vs bf16 full train step (fwd + dgrad + wgrad + optimizer) under
    identical plans — the paper's §5 claim at reduced scale.

    The bf16 variant uses the backend-appropriate recipe
    (``precision.bf16_training_policy``): full bf16 storage on
    accelerators, autocast-style (bf16-rounded GEMM operands, fp32
    storage) on CPU — both are bf16-compute / fp32-accumulate numerics.

    The two variants are measured *interleaved* (one fp32 step, one bf16
    step, repeat; best-of per variant): on a shared/noisy host, sequential
    A-then-B timing lets load drift between the variants dominate the
    ratio, while paired alternation exposes both to the same environment."""
    import time

    params0 = minkunet.init_params(cfg, jax.random.PRNGKey(0))
    labels = jax.random.randint(jax.random.PRNGKey(1), (stx.capacity,), 0,
                                cfg.num_classes)
    maps = minkunet.build_maps(stx)
    steps = {}
    for name, policy in (("fp32", prec.FP32),
                         ("bf16", prec.bf16_training_policy())):
        nplan = minkunet.network_plan(cfg, precision=policy)
        params = nplan.cast_params(params0)
        ocfg = opt.AdamWConfig(lr=1e-3, weight_decay=0.0,
                               master_weights=policy.master_weights)
        state = opt.init_opt_state(params, ocfg)
        step = _train_step_fn(nplan, stx, maps, labels, ocfg)
        jax.block_until_ready(step(params, state)[2])   # compile
        jax.block_until_ready(step(params, state)[2])   # warm
        steps[name] = (step, params, state)

    lats = {name: float("inf") for name in steps}
    for r in range(iters):
        order = list(steps) if r % 2 == 0 else list(steps)[::-1]
        for name in order:    # rotate order: no variant always runs cache-warm
            step, params, state = steps[name]
            t0 = time.perf_counter()
            jax.block_until_ready(step(params, state)[2])
            lats[name] = min(lats[name], (time.perf_counter() - t0) * 1e6)
    for name, us in lats.items():
        common.emit(f"train/step/{name}", us, "")
    ratio = lats["fp32"] / max(lats["bf16"], 1e-9)
    common.emit("train/step/speedup", 0.0, f"bf16_vs_fp32={ratio:.2f}x")
    return lats


def run_binding_schemes(cfg, stx, iters: int):
    """Fig. 15/22: bound vs decoupled dataflows via the training plan tuner."""
    params = minkunet.init_params(cfg, jax.random.PRNGKey(0))
    maps = minkunet.build_maps(stx)
    labels = jax.random.randint(jax.random.PRNGKey(1), (stx.capacity,), 0,
                                cfg.num_classes)

    def train_step(nplan):
        def loss(p):
            lg = nplan.apply(p, stx, maps)
            ls = jax.nn.log_softmax(lg)[jnp.arange(stx.capacity), labels]
            return -jnp.sum(jnp.where(stx.valid_mask, ls, 0))

        return jax.jit(lambda p: jax.grad(loss)(p))

    lats = {}
    base = minkunet.network_plan(cfg)
    for name, c in common.SYSTEMS.items():
        amap = {lp.sig: TrainDataflowConfig.bind_all(c) for lp in base.layers}
        fn = train_step(base.with_assignment(amap))
        lats[f"bound/{name}"] = common.time_fn(lambda: fn(params), iters=iters)

    # decoupled: tuned with each binding scheme (paper Fig. 13 / Fig. 22).
    # Two-candidate space keeps the CPU-container tuning time sane; the
    # ranking logic is identical at larger |space|.
    space = [df.DataflowConfig("gather_scatter"),
             df.DataflowConfig("implicit_gemm", n_splits=1)]

    def measure(candidate):
        fn = train_step(candidate)
        return timeit_fn(lambda: jax.block_until_ready(fn(params)),
                         warmup=1, iters=iters)

    for scheme in ("bind_all", "bind_fwd_dgrad", "bind_dgrad_wgrad"):
        tuned = TrainingPlanTuner(base, space, measure, scheme).tune()
        fn = train_step(tuned)
        lats[f"tuned/{scheme}"] = common.time_fn(lambda: fn(params), iters=iters)

    worst = max(lats.values())
    for name, us in lats.items():
        common.emit(f"fig15/SK-M-train/{name}", us, f"speedup_vs_worst={worst / us:.2f}x")


def run(tiny: bool = False):
    if tiny:
        cfg = minkunet.MinkUNetConfig(width=0.25, blocks_per_stage=1, num_classes=8)
        stx = common.seg_scene(n=800, cap=1024)
        run_mixed_precision(cfg, stx, iters=6)
        return
    cfg = minkunet.MinkUNetConfig(width=0.25, blocks_per_stage=1, num_classes=8)
    stx = common.seg_scene(n=1500)
    run_mixed_precision(cfg, stx, iters=6)
    run_binding_schemes(cfg, stx, iters=2)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="mixed-precision A/B only, reduced scene (CI smoke)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(tiny=args.tiny)
