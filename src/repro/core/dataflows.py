"""The three sparse-convolution dataflows behind one config (paper Fig. 9).

Every dataflow computes the same math (Equation 1); they differ in *where*
redundant work and memory traffic land:

* ``gather_scatter``   — weight-stationary, vendor-library (here: XLA) GEMMs,
                         gather/scatter buffers in DRAM, no overlap. Cheap to
                         maintain, fundamentally latency-bound (paper §2.2.1).
* ``fetch_on_demand``  — fused weight-stationary Pallas kernel, zero redundant
                         compute, Σ|M_δ| write-back amplification (§2.2.2).
* ``implicit_gemm``    — output-stationary Pallas kernel, minimal write-back,
                         tile-granular redundant compute, tunable mask
                         splits/sorting (§2.2.3, §4.1).

``backend='xla'`` runs mathematically-identical jnp paths (used on CPU and in
the distributed dry-run, where the roofline is derived from HLO);
``backend='pallas'`` dispatches the hand-tiled kernels (validated in
interpret mode on CPU, native on TPU).

Every dataflow additionally honours a ``PrecisionPolicy``
(``core/precision.py``): GEMM operands are cast to ``policy.compute``
(bf16 under the mixed-precision policy), partial sums accumulate in
``policy.accum`` (fp32 — the Pallas kernels already keep an fp32 VMEM
accumulator, so operand-level casting composes), and results come out in
``policy.output`` (or the input features' dtype when unset).  The default
FP32 policy is bit-identical to the pre-policy behaviour.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.kmap import KernelMap, SplitPlan, make_split_plan
from repro.core.precision import FP32, PrecisionPolicy, gemm_operand
from repro.kernels.fetch_on_demand.ops import fetch_on_demand as fod_pallas_op
from repro.kernels.fetch_on_demand.ref import fetch_on_demand_ref
from repro.kernels.implicit_gemm.ops import implicit_gemm as igemm_pallas_op
from repro.kernels.implicit_gemm.ref import implicit_gemm_ref

DATAFLOWS = ("gather_scatter", "fetch_on_demand", "implicit_gemm")


@dataclasses.dataclass(frozen=True)
class DataflowConfig:
    """One point in the Sparse Autotuner design space (paper Fig. 9)."""

    dataflow: str = "implicit_gemm"
    n_splits: int = 1          # 0 = unsorted (paper Fig. 5); ≥1 = sorted splits
    tile_m: int = 128
    tile_n: int = 128
    backend: str = "xla"       # 'xla' | 'pallas'
    worklist: bool = False     # pallas implicit GEMM: launch over the
    #                            compacted occupied-(tile, δ) worklist
    #                            instead of the dense grid (tile skipping)

    def __post_init__(self):
        assert self.dataflow in DATAFLOWS, self.dataflow

    @property
    def sorted(self) -> bool:
        return self.n_splits >= 1

    @property
    def effective_splits(self) -> int:
        return max(1, self.n_splits)

    def effective_backend(self, kernel: str = "fwd") -> str:
        """The backend that *actually executes* this config for ``kernel``
        (fwd/dgrad/wgrad) — not the one requested.  A ``backend='pallas'``
        request silently runs the XLA path for dataflows that have no
        Pallas kernel (gather_scatter fwd, every dgrad), and the tuner /
        PlanRegistry must record what ran, not what was asked for."""
        if self.backend != "pallas":
            return "xla"
        if kernel == "fwd":
            return "pallas" if self.dataflow in ("implicit_gemm",
                                                 "fetch_on_demand") else "xla"
        if kernel == "dgrad":
            return "xla"    # dgrad is always the XLA scan (see sparse_conv_dgrad)
        if kernel == "wgrad":
            return "pallas"
        raise ValueError(f"unknown kernel {kernel!r}")

    def to_dict(self) -> dict:
        """JSON-safe dict (all fields are ints/strs).  Round-trips through
        ``from_dict`` — the serving engine's PlanRegistry persists tuned
        assignments with this.  Carries a derived ``effective_backend``
        stamp (what actually executes the forward) so persisted plans can't
        claim pallas where xla ran; ``from_dict`` drops it."""
        d = dataclasses.asdict(self)
        d["effective_backend"] = self.effective_backend("fwd")
        return d

    @staticmethod
    def from_dict(d: dict) -> "DataflowConfig":
        d = dict(d)
        d.pop("effective_backend", None)   # derived stamp, not a field
        unknown = set(d) - {f.name for f in dataclasses.fields(DataflowConfig)}
        if unknown:
            raise ValueError(f"unknown DataflowConfig fields: {sorted(unknown)}")
        return DataflowConfig(**d)


DEFAULT_CONFIG = DataflowConfig()


def default_serving_space(include_pallas: Optional[bool] = None) -> Tuple[DataflowConfig, ...]:
    """The serving tuner's default search space: all three dataflows on the
    XLA backend plus — when the installed jax can run them (interpret mode
    on CPU, native on TPU) — the same three on the Pallas backend.

    include_pallas: force the Pallas axis on/off; None probes
    ``kernels.common.pallas_supported()``.
    """
    if include_pallas is None:
        from repro.kernels.common import pallas_supported
        include_pallas = pallas_supported()
    space = [DataflowConfig("gather_scatter"),
             DataflowConfig("fetch_on_demand"),
             DataflowConfig("implicit_gemm", n_splits=1)]
    if include_pallas:
        from repro.kernels.common import default_interpret
        # Interpret mode unrolls the per-row DMA bodies at trace time, so
        # CPU containers search small tiles (the math — and therefore the
        # tuner's dataflow ranking — is tile-independent); real TPUs keep
        # the MXU-shaped defaults.
        tm, tn = (16, 128) if default_interpret() else (128, 128)
        pallas = [dataclasses.replace(cfg, backend="pallas", tile_m=tm,
                                      tile_n=tn) for cfg in space]
        pallas.append(DataflowConfig("implicit_gemm", n_splits=1, tile_m=tm,
                                     tile_n=tn, backend="pallas",
                                     worklist=True))
        space += pallas
    return tuple(space)


def plan_for(kmap: KernelMap, cfg: DataflowConfig) -> SplitPlan:
    tile_m = None
    if cfg.backend == "pallas" and cfg.dataflow == "implicit_gemm" \
            and cfg.worklist:
        # fuse the per-(split, tile, δ) occupancy into the plan pass — the
        # worklist kernel compacts its launch grid from it
        tile_m = math.gcd(cfg.tile_m, kmap.capacity)
    return make_split_plan(kmap, cfg.effective_splits, sort=cfg.sorted,
                           tile_m=tile_m)


def _gather_scatter_xla(x, w, kmap: KernelMap,
                        precision: PrecisionPolicy = FP32) -> jax.Array:
    """Vanilla gather-GEMM-scatter via lax.scan over stacked per-δ maps.

    TorchSparse v1's "adaptive grouping" batches offsets with similar |M_δ|;
    with static capacities every offset already has an identical shape, so the
    scan *is* the grouped batched GEMM (DESIGN.md §2, sequential host loop →
    scan)."""
    cap_out = kmap.capacity
    ct, at = precision.compute_dtype, precision.accum_dtype
    # round/cast the loop-invariant operands ONCE, not per δ iteration
    xq, wq = gemm_operand(x, ct, at), gemm_operand(w, ct, at)

    def body(acc, inputs):
        wk, i_in, i_out = inputs
        rows = jnp.where((i_in >= 0)[:, None], xq[jnp.clip(i_in, 0)], 0)
        y = jnp.dot(rows, wk, preferred_element_type=at)
        return acc.at[i_out].add(y, mode="drop"), None

    acc0 = jnp.zeros((cap_out, w.shape[-1]), at)
    acc, _ = jax.lax.scan(body, acc0, (wq, kmap.ws_in, kmap.ws_out))
    return acc.astype(precision.output_dtype(x.dtype))


def _implicit_gemm_xla(x, w, kmap: KernelMap,
                       precision: PrecisionPolicy = FP32) -> jax.Array:
    """Output-stationary jnp path (splits/sorting are a no-op for the math)."""
    return implicit_gemm_ref(x, w, kmap.m_out,
                             acc_dtype=precision.accum_dtype,
                             compute_dtype=precision.compute_dtype,
                             out_dtype=precision.output_dtype(x.dtype))


def _pallas_operands(x, w, precision: PrecisionPolicy):
    """Operand-level mixed precision for the Pallas kernels: they already
    keep an fp32 VMEM accumulator (preferred_element_type=f32) and emit
    ``x.dtype``, so casting the operands is the whole policy."""
    return x.astype(precision.compute_dtype), w.astype(precision.compute_dtype)


def sparse_conv_forward(x: jax.Array, w: jax.Array, kmap: KernelMap,
                        cfg: DataflowConfig = DEFAULT_CONFIG,
                        plan: Optional[SplitPlan] = None,
                        precision: PrecisionPolicy = FP32) -> jax.Array:
    """Dispatch one sparse convolution. x: (N_in_cap, Cin), w: (KD, Cin, Cout).

    Returns (N_out_cap, Cout) in ``precision.output`` (input dtype by
    default)."""
    if cfg.backend == "pallas":
        out = precision.output_dtype(x.dtype)
        if cfg.dataflow == "implicit_gemm":
            if plan is None:
                plan = plan_for(kmap, cfg)
            xc, wc = _pallas_operands(x, w, precision)
            return igemm_pallas_op(xc, wc, kmap, plan, tile_m=cfg.tile_m,
                                   tile_n=cfg.tile_n,
                                   worklist=cfg.worklist).astype(out)
        if cfg.dataflow == "fetch_on_demand":
            xc, wc = _pallas_operands(x, w, precision)
            return fod_pallas_op(xc, wc, kmap, tile_r=cfg.tile_m).astype(out)
        return _gather_scatter_xla(x, w, kmap, precision)  # g-g-s *is* the vendor path
    # XLA backend
    if cfg.dataflow == "implicit_gemm":
        return _implicit_gemm_xla(x, w, kmap, precision)
    if cfg.dataflow == "fetch_on_demand":
        return fetch_on_demand_ref(x, w, kmap.ws_in, kmap.ws_out, kmap.capacity,
                                   acc_dtype=precision.accum_dtype,
                                   compute_dtype=precision.compute_dtype,
                                   out_dtype=precision.output_dtype(x.dtype))
    return _gather_scatter_xla(x, w, kmap, precision)


def sparse_conv_dgrad(dy: jax.Array, w: jax.Array, kmap: KernelMap,
                      cfg: DataflowConfig = DEFAULT_CONFIG,
                      in_capacity: Optional[int] = None,
                      precision: PrecisionPolicy = FP32) -> jax.Array:
    """Input-feature gradient: a sparse conv over the *transposed* map with
    W^T per offset — expressed weight-stationarily by swapping the pair lists
    (so any dataflow config applies; the autotuner tunes it separately).

    ``in_capacity`` is the *input* tensor's row capacity.  The pair lists are
    sized at the output capacity, which differs from the input capacity for
    strided/transposed maps — callers that know the input shape (e.g. the
    custom_vjp in sparse_conv.py) must pass it so gradients scatter into a
    correctly-sized accumulator instead of being silently dropped."""
    if in_capacity is not None:
        cap_in = in_capacity
    else:
        cap_in = int(jnp.shape(kmap.ws_in)[1])  # submanifold: == out capacity
    ct, at = precision.compute_dtype, precision.accum_dtype
    dyq, wq = gemm_operand(dy, ct, at), gemm_operand(w, ct, at)

    def body(acc, inputs):
        wk, i_in, i_out = inputs
        rows = jnp.where((i_out >= 0)[:, None], dyq[jnp.clip(i_out, 0)], 0)
        g = jnp.dot(rows, wk.T, preferred_element_type=at)
        return acc.at[i_in].add(g, mode="drop"), None

    acc0 = jnp.zeros((cap_in, w.shape[1]), at)
    acc, _ = jax.lax.scan(body, acc0, (wq, kmap.ws_in, kmap.ws_out))
    return acc.astype(precision.output_dtype(dy.dtype))


def sparse_conv_wgrad(x: jax.Array, dy: jax.Array, kmap: KernelMap,
                      cfg: DataflowConfig = DEFAULT_CONFIG,
                      precision: PrecisionPolicy = FP32) -> jax.Array:
    """Weight gradient: per-δ  gather(X)ᵀ @ gather(dY) — a GEMM with *two*
    sparse iterators (the reason the paper tunes wgrad separately: its K loop
    runs over N_out, so reordering/pair layout dominates).

    Partial sums accumulate in ``precision.accum`` (fp32) and round at most
    once at the end; the custom_vjp caller re-casts to the weight dtype so
    the cotangent always matches the parameter leaf."""
    if cfg.backend == "pallas":
        from repro.kernels.wgrad.ops import wgrad as wgrad_kernel

        xc, yc = (x.astype(precision.compute_dtype),
                  dy.astype(precision.compute_dtype))
        return wgrad_kernel(xc, yc, kmap,
                            tile_r=cfg.tile_m).astype(precision.output_dtype(x.dtype))
    ct, at = precision.compute_dtype, precision.accum_dtype
    xq, dyq = gemm_operand(x, ct, at), gemm_operand(dy, ct, at)

    def body(_, inputs):
        i_in, i_out = inputs
        xs = jnp.where((i_in >= 0)[:, None], xq[jnp.clip(i_in, 0)], 0)
        ys = jnp.where((i_out >= 0)[:, None], dyq[jnp.clip(i_out, 0)], 0)
        return None, jnp.dot(xs.T, ys, preferred_element_type=at)

    _, dw = jax.lax.scan(body, None, (kmap.ws_in, kmap.ws_out))
    return dw.astype(precision.output_dtype(x.dtype))
