"""GPipe-style pipeline parallelism over a dedicated mesh axis.

The graded production mesh is (pod, data, model) — PP is OFF there — but a
1000+-node deployment of the deepest cells (llama-vision 100L) would add a
``pipe`` axis; this module provides the schedule, tested on 8 host devices
(tests/test_distributed.py).

Implementation: the classic `shard_map` + `ppermute` loop.  Layers are split
into S stages (stacked-params leading dim), the global batch into M
microbatches.  Each loop iteration runs every stage on its resident
microbatch and rotates activations with ``collective_permute``; after
S + M - 1 ticks all microbatches have traversed all stages.  Bubble fraction
is (S-1)/(S+M-1), the GPipe figure.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # jax ≥ 0.6 moved shard_map to jax.*
    from jax import shard_map as _shard_map

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                          check_vma=False)
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map_old

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map_old(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                              check_rep=False)


def pipelined_apply(stage_fn: Callable, mesh, axis: str, n_microbatches: int):
    """Build ``f(stage_params, x) → y`` running layers pipelined over ``axis``.

    stage_fn(stage_params, x_mb) applies ONE stage's layers to one microbatch.
    stage_params: pytree whose leaves have leading dim = n_stages (sharded
    over ``axis``).  x: (n_microbatches·mb, ...) global batch.
    """
    n_stages = mesh.shape[axis]

    def per_device(stage_params, x):
        # stage_params leaves: (1, ...) local stage slice; x: local microbatches
        sp = jax.tree.map(lambda a: a[0], stage_params)
        stage = jax.lax.axis_index(axis)
        mb = x.shape[0] // n_microbatches
        xs = x.reshape((n_microbatches, mb) + x.shape[1:])

        n_ticks = n_stages + n_microbatches - 1
        buf = jnp.zeros((mb,) + x.shape[1:], x.dtype)          # resident activation
        outs = jnp.zeros_like(xs)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (if any remain)
            mb_in = xs[jnp.clip(t, 0, n_microbatches - 1)]
            buf = jnp.where(stage == 0, jnp.where(t < n_microbatches, mb_in, buf), buf)
            buf = stage_fn(sp, buf)
            # last stage retires microbatch t - (S-1)
            ridx = t - (n_stages - 1)
            outs = jnp.where(
                (stage == n_stages - 1) & (ridx >= 0),
                outs.at[jnp.clip(ridx, 0, n_microbatches - 1)].set(buf), outs)
            # rotate stage s → s+1
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            buf = jax.lax.ppermute(buf, axis, perm)
            return (buf, outs), None

        (_, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(n_ticks))
        # retired microbatches accumulate on the last stage's device;
        # broadcast them to everyone.
        outs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)), axis)
        return outs.reshape(x.shape)

    in_specs = (P(axis), P())       # stage dim sharded; batch replicated
    out_specs = P()
    return shard_map(per_device, mesh, in_specs, out_specs)
