"""Sparse Autotuner (paper §4): group-based greedy configuration tuning.

Key paper observations baked in:

* Layers sharing the same kernel map form a **group** and must run the same
  dataflow (different dataflows need different map structures; generating
  both costs ~3-4 conv layers of latency — §4.2).
* The objective is **end-to-end latency** of the whole network, never
  per-kernel time: mapping overhead (bitmask building, sorting, reordering)
  makes kernel-time rankings unreliable (Tables 3 vs 4).
* Greedy group-by-group search is linear in the design space because group
  latencies are independent; groups may be non-consecutive in U-Nets, which
  is why each measurement is still end-to-end.
* Training tunes three kernels (fwd/dgrad/wgrad) with **partial binding**
  (Fig. 13) in two re-uses of the same group tuner — O(K), not O(K³).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

from repro import obs
from repro.core import dataflows as df
from repro.core.sparse_conv import TrainDataflowConfig


def timeit_fn(fn: Callable[[], object], warmup: int = 1, iters: int = 3) -> float:
    """Best-of-n wall-clock seconds of a nullary (already jitted) callable."""
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


@dataclasses.dataclass
class GroupInfo:
    """A set of layers sharing one kernel map (same in/out coords, stride, K)."""

    name: str
    layer_names: List[str]


def partition_groups(layer_signatures: Dict[str, tuple]) -> List[GroupInfo]:
    """Group layers by map signature: (in_stride, out_stride, kernel_size,
    transposed-source).  Matches the paper's Fig. 12 partition."""
    by_sig: Dict[tuple, List[str]] = {}
    for name, sig in layer_signatures.items():
        by_sig.setdefault(sig, []).append(name)
    return [GroupInfo(name=f"g{i}_{sig}", layer_names=layers)
            for i, (sig, layers) in enumerate(sorted(by_sig.items(), key=str))]


class Autotuner:
    """Greedy group tuner.

    measure(assignment) must return *end-to-end* latency (seconds) of the
    workload when group g uses dataflow config assignment[g.name].
    """

    def __init__(self, groups: Sequence[GroupInfo],
                 space: Sequence[df.DataflowConfig],
                 measure: Callable[[Dict[str, object]], float],
                 default: Optional[df.DataflowConfig] = None):
        self.groups = list(groups)
        self.space = list(space)
        self.measure = measure
        self.default = default or df.DEFAULT_CONFIG
        self.log: List[tuple] = []

    def tune(self) -> Dict[str, df.DataflowConfig]:
        best: Dict[str, df.DataflowConfig] = {g.name: self.default for g in self.groups}
        for g in self.groups:
            results = []
            for cand in self.space:
                trial = dict(best)
                trial[g.name] = cand
                with obs.span("tune_candidate", group=g.name,
                              candidate=str(cand)) as sp:
                    lat = self.measure(trial)
                    sp.set(latency_ms=lat * 1e3)
                    # what actually ran: a "pallas" request silently runs
                    # XLA for dataflows with no Pallas kernel (gather/
                    # scatter) — the sweep log must record the effective
                    # backend, not the requested one
                    eff = getattr(cand, "effective_backend", None)
                    if callable(eff):
                        sp.set(effective_backend=eff("fwd"))
                results.append((lat, cand))
                self.log.append((g.name, cand, lat))
            lat, cand = min(results, key=lambda r: r[0])
            best[g.name] = cand
        return best


class TrainingAutotuner:
    """Two-pass training tuner with partial parameter binding (Fig. 13).

    scheme='bind_fwd_dgrad'  : workload-pattern oriented (low-parallelism
        devices — 2080 Ti class);
    scheme='bind_dgrad_wgrad': sparse-mapping oriented (high-parallelism
        devices — A100 class; mapping overhead dominates so dgrad+wgrad share
        maps/params).
    measure(assignment) gets Dict[group, TrainDataflowConfig] and returns
    end-to-end train-step latency.
    """

    def __init__(self, groups, space, measure, scheme: str = "bind_dgrad_wgrad"):
        assert scheme in ("bind_fwd_dgrad", "bind_dgrad_wgrad", "bind_all")
        self.groups, self.space, self.measure, self.scheme = list(groups), list(space), measure, scheme

    @staticmethod
    def choose_scheme(high_parallelism: bool) -> str:
        return "bind_dgrad_wgrad" if high_parallelism else "bind_fwd_dgrad"

    def tune(self) -> Dict[str, TrainDataflowConfig]:
        if self.scheme == "bind_all":
            tuner = Autotuner(self.groups, self.space,
                              lambda a: self.measure({k: TrainDataflowConfig.bind_all(v)
                                                      for k, v in a.items()}))
            return {k: TrainDataflowConfig.bind_all(v) for k, v in tuner.tune().items()}

        if self.scheme == "bind_fwd_dgrad":
            # pass 1: tune the (fwd,dgrad) pair with default wgrad
            t1 = Autotuner(self.groups, self.space,
                           lambda a: self.measure({k: TrainDataflowConfig.bind_fwd_dgrad(v, df.DEFAULT_CONFIG)
                                                   for k, v in a.items()}))
            bound = t1.tune()
            # pass 2: tune wgrad given the fixed pair
            t2 = Autotuner(self.groups, self.space,
                           lambda a: self.measure({k: TrainDataflowConfig.bind_fwd_dgrad(bound[k], a[k])
                                                   for k in a}))
            wg = t2.tune()
            return {k: TrainDataflowConfig.bind_fwd_dgrad(bound[k], wg[k]) for k in bound}

        # bind_dgrad_wgrad
        t1 = Autotuner(self.groups, self.space,
                       lambda a: self.measure({k: TrainDataflowConfig.bind_all(v)
                                               for k, v in a.items()}))
        fwd = t1.tune()
        t2 = Autotuner(self.groups, self.space,
                       lambda a: self.measure({k: TrainDataflowConfig.bind_dgrad_wgrad(fwd[k], a[k])
                                               for k in a}))
        bw = t2.tune()
        return {k: TrainDataflowConfig.bind_dgrad_wgrad(fwd[k], bw[k]) for k in fwd}
