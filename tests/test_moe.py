"""MoE dispatch invariants: the token→expert kernel map is conservation-law
territory (every kept assignment routed exactly once, combine weights sum to
1), and the two dataflows must agree when nothing is dropped."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from conftest import property_test

from repro.models.lm_common import ArchConfig, MoECfg, NO_SHARD
from repro.models import moe as moe_mod


def make_cfg(n_experts=8, top_k=2, capacity_factor=8.0):
    return ArchConfig(name="t", family="moe", n_layers=1, d_model=16,
                      n_heads=2, kv_heads=2, d_ff=32, vocab=64,
                      moe=MoECfg(n_experts=n_experts, top_k=top_k,
                                 d_ff_expert=32, capacity_factor=capacity_factor))


def test_dataflows_agree_when_capacity_ample():
    cfg = make_cfg(capacity_factor=16.0)
    p = moe_mod.moe_init(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 16))
    y_gs = moe_mod.moe_apply(cfg, p, x, NO_SHARD, dataflow="gather_scatter")
    y_oh = moe_mod.moe_apply(cfg, p, x, NO_SHARD, dataflow="dense_onehot")
    np.testing.assert_allclose(y_gs, y_oh, rtol=2e-4, atol=2e-5)


@property_test(
    "seed,e,k",
    cases=[(0, 4, 1), (1, 8, 2), (2, 4, 2), (3, 8, 1)],
    strategies=lambda st: dict(seed=st.integers(0, 1000),
                               e=st.sampled_from([4, 8]),
                               k=st.sampled_from([1, 2])),
    max_examples=10)
def test_property_dispatch_conservation(seed, e, k):
    cfg = make_cfg(n_experts=e, top_k=k, capacity_factor=float(e))
    p = moe_mod.moe_init(cfg, jax.random.PRNGKey(seed), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, 16, 16))
    y_gs = moe_mod.moe_apply(cfg, p, x, NO_SHARD, dataflow="gather_scatter")
    y_oh = moe_mod.moe_apply(cfg, p, x, NO_SHARD, dataflow="dense_onehot")
    np.testing.assert_allclose(y_gs, y_oh, rtol=5e-4, atol=5e-5)


def test_capacity_drops_reduce_output_energy():
    """With tiny capacity most assignments are dropped → output shrinks but
    stays finite (dropped tokens pass through the residual)."""
    cfg_full = make_cfg(capacity_factor=16.0)
    cfg_tight = dataclasses.replace(
        cfg_full, moe=dataclasses.replace(cfg_full.moe, capacity_factor=0.1))
    p = moe_mod.moe_init(cfg_full, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 16))
    y_full = moe_mod.moe_apply(cfg_full, p, x, NO_SHARD)
    y_tight = moe_mod.moe_apply(cfg_tight, p, x, NO_SHARD)
    assert bool(jnp.isfinite(y_tight).all())
    assert float(jnp.linalg.norm(y_tight)) < float(jnp.linalg.norm(y_full))


def test_moe_is_differentiable():
    cfg = make_cfg()
    p = moe_mod.moe_init(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 16))

    def f(p):
        return jnp.sum(moe_mod.moe_apply(cfg, p, x, NO_SHARD) ** 2)

    g = jax.grad(f)(p)
    total = sum(float(jnp.sum(jnp.abs(v))) for v in jax.tree.leaves(g))
    assert np.isfinite(total) and total > 0
