"""CenterPoint sparse-conv backbone (SECOND-style 3D detection encoder).

The paper's detection workload (Waymo/nuScenes-CenterPoint).  Only the
SparseConv layers are timed in the paper's detection benchmarks, so this is
the backbone alone: 4 stages of [stride-2 conv + submanifold convs],
channel ladder 16→32→64→128.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.kmap import MapCache, build_kmap
from repro.core.sparse_conv import ConvSpec, TrainDataflowConfig, apply_conv, init_conv
from repro.core.sparse_tensor import SparseTensor
from repro.models.minkunet import _bn_relu, _bn_relu_init


@dataclasses.dataclass(frozen=True)
class CenterPointConfig:
    in_channels: int = 5
    channels: tuple = (16, 32, 64, 128)
    sub_convs_per_stage: int = 2
    width: float = 1.0

    def ch(self, c):
        return max(8, int(c * self.width))


def init_params(cfg: CenterPointConfig, key) -> dict:
    keys = iter(jax.random.split(key, 64))
    p = {}
    c0 = cfg.ch(cfg.channels[0])
    p["stem"] = init_conv(next(keys), ConvSpec(cfg.in_channels, c0, 3))
    p["stem_bn"] = _bn_relu_init(c0)
    cin = c0
    for i, c in enumerate(cfg.channels):
        c = cfg.ch(c)
        p[f"down{i}"] = init_conv(next(keys), ConvSpec(cin, c, 2, stride=2))
        p[f"down{i}_bn"] = _bn_relu_init(c)
        for b in range(cfg.sub_convs_per_stage):
            p[f"sub{i}_{b}"] = init_conv(next(keys), ConvSpec(c, c, 3))
            p[f"sub{i}_{b}_bn"] = _bn_relu_init(c)
        cin = c
    return p


def layer_signatures(cfg: CenterPointConfig) -> Dict[str, tuple]:
    sigs = {"stem": (1, 3, "sub")}
    for i in range(len(cfg.channels)):
        sigs[f"down{i}"] = (2 ** i, 2, "down")
        for b in range(cfg.sub_convs_per_stage):
            sigs[f"sub{i}_{b}"] = (2 ** (i + 1), 3, "sub")
    return sigs


def build_maps(st: SparseTensor, engine: str = "packed",
               cache: Optional[MapCache] = None) -> dict:
    """One ``MapCache`` across the stage ladder: the stem/submanifold and
    strided convs at each stride share a sorted coordinate table, and each
    downsample adopts its output table for the next stage.  A prebuilt warm
    ``cache`` may be passed (serving engine); never reuse one across ``jit``
    traces.

    ``engine="legacy"`` rebuilds every table per layer with the seed path —
    only for the benchmark A/B (benchmarks/bench_kmap.py); goes away with
    the legacy engine."""
    if cache is None:
        cache = MapCache.for_tensor(st) if engine == "packed" else None
    maps = {("sub", 1): build_kmap(st, 3, 1, cache=cache, engine=engine)}
    cur, stride = st, 1
    for i in range(4):
        kd = build_kmap(cur, 2, 2, cache=cache, engine=engine)
        maps[("down", stride)] = kd
        cur = SparseTensor(coords=kd.out_coords,
                           feats=jnp.zeros((kd.capacity, 1), st.feats.dtype),
                           num_valid=kd.n_out, stride=kd.out_stride,
                           batch_bound=st.batch_bound, spatial_bound=st.spatial_bound)
        stride *= 2
        maps[("sub", stride)] = build_kmap(cur, 3, 1, cache=cache, engine=engine)
    return maps


def apply(params, st: SparseTensor, cfg: CenterPointConfig,
          maps: Optional[dict] = None,
          assignment: Optional[Dict[tuple, TrainDataflowConfig]] = None,
          bn_mode: str = "batch") -> jax.Array:
    maps = maps or build_maps(st)
    assignment = assignment or {}

    def cfg_for(sig):
        return assignment.get(sig, TrainDataflowConfig())

    x = apply_conv(params["stem"], st, maps[("sub", 1)], cfg_for((1, 3, "sub")))
    x = _bn_relu(params["stem_bn"], x, mode=bn_mode)
    stride = 1
    for i in range(len(cfg.channels)):
        x = apply_conv(params[f"down{i}"], x, maps[("down", stride)], cfg_for((stride, 2, "down")))
        x = _bn_relu(params[f"down{i}_bn"], x, mode=bn_mode)
        stride *= 2
        for b in range(cfg.sub_convs_per_stage):
            x = apply_conv(params[f"sub{i}_{b}"], x, maps[("sub", stride)], cfg_for((stride, 3, "sub")))
            x = _bn_relu(params[f"sub{i}_{b}_bn"], x, mode=bn_mode)
    return x.feats
