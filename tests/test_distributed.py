"""Multi-device tests: each case runs in a subprocess with 8 forced host
devices (the main pytest process must keep a single device for everything
else).  Covers pjit train-step parity, compressed all-reduce, pipeline
parallelism, and elastic checkpoint resharding."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_sub(body: str, n_dev: int = 8, timeout=600):
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_dev}"
        import jax
        jax.config.update("jax_platforms", "cpu")
        import jax.numpy as jnp
        import numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
    """) + textwrap.dedent(body)
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=timeout)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_pjit_train_step_matches_single_device():
    run_sub("""
        from repro.configs import base
        from repro.models import api
        from repro.launch import mesh as meshlib
        from repro.train import optimizer as opt

        cfg = base.reduced(base.get_arch("qwen1_5_0_5b"), d_model=64, n_heads=4,
                           kv_heads=4, vocab=128)
        key = jax.random.PRNGKey(0)
        params = api.init_params(cfg, key)
        b, s = 4, 32
        toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}

        loss_1dev = float(api.loss_fn(cfg, params, batch))

        mesh = meshlib.make_mesh((2, 2, 2), ("pod", "data", "model"))
        ctx = meshlib.make_ctx(mesh)
        pspecs = api.param_pspecs(cfg, params, ctx)
        shd = jax.tree.map(lambda sp: NamedSharding(mesh, sp), pspecs,
                           is_leaf=lambda x: isinstance(x, P))
        params_sharded = jax.device_put(params, shd)
        bspec = NamedSharding(mesh, P(("pod", "data"), None))
        batch_sh = jax.device_put(batch, bspec)
        loss_sharded = float(jax.jit(
            lambda p, bt: api.loss_fn(cfg, p, bt, ctx))(params_sharded, batch_sh))
        assert abs(loss_1dev - loss_sharded) < 2e-3 * max(1.0, abs(loss_1dev)), (loss_1dev, loss_sharded)
        print("pjit parity ok", loss_1dev, loss_sharded)
    """)


def test_compressed_allreduce_close_to_exact():
    run_sub("""
        from repro.launch import mesh as meshlib
        from repro.train.compression import compressed_all_reduce_mean
        from jax.experimental.shard_map import shard_map

        mesh = meshlib.make_mesh((8,), ("pod",))
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 1000))

        exact = jnp.mean(x, axis=0)
        f = shard_map(lambda xs: compressed_all_reduce_mean(xs[0], "pod")[None],
                      mesh=mesh, in_specs=P("pod"), out_specs=P("pod"))
        approx = f(x)
        err = float(jnp.abs(approx[0] - exact).max())
        scale = float(jnp.abs(exact).max())
        assert err < 0.05 * scale + 0.02, (err, scale)
        # every pod shard got the same answer
        for i in range(8):
            np.testing.assert_allclose(approx[i], approx[0], atol=1e-7)
        print("compressed allreduce ok, err=", err)
    """)


def test_error_feedback_improves_over_steps():
    run_sub("""
        from repro.launch import mesh as meshlib
        from repro.train.compression import ef_compressed_all_reduce_mean
        from jax.experimental.shard_map import shard_map

        mesh = meshlib.make_mesh((8,), ("pod",))

        def step(x, e):
            return ef_compressed_all_reduce_mean(x[0], e[0], "pod")

        f = shard_map(lambda x, e: tuple(z[None] for z in step(x, e)),
                      mesh=mesh, in_specs=(P("pod"), P("pod")), out_specs=P("pod"))
        # same gradient every step: with error feedback the *accumulated*
        # applied update converges to the true mean
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 512))
        exact = jnp.mean(g, axis=0)
        e = jnp.zeros_like(g)
        acc = jnp.zeros((512,))
        for t in range(8):
            r, e = f(g, e)
            acc = acc + r[0]
        err = float(jnp.abs(acc / 8 - exact).max()) / (float(jnp.abs(exact).max()) + 1e-9)
        assert err < 0.03, err
        print("error feedback ok", err)
    """)


def test_pipeline_parallel_matches_sequential():
    run_sub("""
        from repro.launch import mesh as meshlib
        from repro.train.pipeline import pipelined_apply

        mesh = meshlib.make_mesh((4,), ("pipe",))
        n_stages, mb, n_micro, d = 4, 2, 4, 16
        key = jax.random.PRNGKey(0)
        ws = jax.random.normal(key, (n_stages, d, d)) * 0.3

        def stage_fn(sp, x):
            return jnp.tanh(x @ sp)

        x = jax.random.normal(jax.random.PRNGKey(1), (mb * n_micro, d))
        seq = x
        for i in range(n_stages):
            seq = stage_fn(ws[i], seq)
        f = pipelined_apply(stage_fn, mesh, "pipe", n_micro)
        out = jax.jit(f)({"w": ws}["w"], x) if False else f(ws, x)
        np.testing.assert_allclose(out, seq, rtol=1e-4, atol=1e-5)
        print("pipeline ok")
    """)


def test_elastic_reshard_restore():
    run_sub("""
        import tempfile
        from repro.launch import mesh as meshlib
        from repro.train import checkpoint as ckpt

        tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (16, 8)),
                "b": jnp.arange(8, dtype=jnp.float32)}
        d = tempfile.mkdtemp()

        # save while sharded over an 8-device mesh
        mesh8 = meshlib.make_mesh((8,), ("data",))
        sh8 = {"w": NamedSharding(mesh8, P("data", None)),
               "b": NamedSharding(mesh8, P())}
        tree8 = jax.device_put(tree, sh8)
        ckpt.save(d, 3, tree8)

        # "lose half the fleet": restore onto a 4-device mesh (elastic)
        import numpy as _np
        devs = _np.array(jax.devices()[:4])
        mesh4 = jax.sharding.Mesh(devs, ("data",))
        sh4 = {"w": NamedSharding(mesh4, P("data", None)),
               "b": NamedSharding(mesh4, P())}
        restored, step, _ = ckpt.restore(d, None, tree, shardings=sh4)
        assert step == 3
        np.testing.assert_allclose(restored["w"], tree["w"])
        assert restored["w"].sharding.num_devices == 4
        print("elastic reshard ok")
    """)


def test_moe_expert_parallel_lowering():
    """EP sharding of the MoE dispatch lowers + runs on a small mesh."""
    run_sub("""
        from repro.configs import base
        from repro.models import api
        from repro.launch import mesh as meshlib

        cfg = base.reduced(base.get_arch("kimi_k2_1t_a32b"), d_model=64,
                           n_heads=4, kv_heads=4, vocab=128)
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        mesh = meshlib.make_mesh((2, 4), ("data", "model"))
        ctx = meshlib.make_ctx(mesh)
        pspecs = api.param_pspecs(cfg, params, ctx)
        shd = jax.tree.map(lambda sp: NamedSharding(mesh, sp), pspecs,
                           is_leaf=lambda x: isinstance(x, P))
        params = jax.device_put(params, shd)
        b, s = 4, 16
        toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
        batch = jax.device_put({"tokens": toks, "labels": toks},
                               NamedSharding(mesh, P("data", None)))
        loss = jax.jit(lambda p, bt: api.loss_fn(cfg, p, bt, ctx))(params, batch)
        assert np.isfinite(float(loss))
        print("moe EP ok", float(loss))
    """)
