"""Jit'd wrapper: pad the pair lists to the row tile and dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.kmap import KernelMap
from repro.kernels.common import default_interpret
from repro.kernels.wgrad.wgrad import wgrad_pallas


def wgrad(x: jax.Array, dy: jax.Array, kmap: KernelMap, *, tile_r: int = 128,
          interpret: bool | None = None) -> jax.Array:
    if interpret is None:
        interpret = default_interpret()
    kd, cap = kmap.ws_in.shape
    pad = (-cap) % tile_r
    ws_in = jnp.pad(kmap.ws_in, ((0, 0), (0, pad)), constant_values=-1)
    ws_out = jnp.pad(kmap.ws_out, ((0, 0), (0, pad)), constant_values=-1)
    return wgrad_pallas(ws_in, ws_out, x, dy, tile_r=tile_r, interpret=interpret)
