"""The sparse serving engine: submit()/flush() over bucketed batched scenes.

Ties the subsystem together (DESIGN: ISSUE 2 tentpole):

* requests (variable-size scenes) queue in a ``SceneBatcher`` and pack FIFO
  into capacity-bucketed batched ``SparseTensor``s with declared bounds —
  every served batch takes the single-argsort packed-key mapping path;
* each bucket capacity owns two pre-jitted stages: a **map builder**
  (``build_maps`` under one trace, so the per-trace ``MapCache`` shares
  sorted tables across the layer pyramid) and an **executor** (the model
  forward in inference-mode normalization).  Static bucket shapes bound jit
  recompiles to one per (bucket, stage) for the engine's lifetime;
* built kernel maps are reused **across requests** at two granularities:
  whole batches are keyed by a content digest of their packed coordinates
  (a small LRU maps digest → device-resident map stack, so exact replays
  skip mapping entirely), and — under the plan's ``"composed"`` /
  ``"incremental"`` table strategies — *scenes* are keyed individually: a
  per-scene store caches each scene's kernel-map stack and sorted table
  ladder, and batch maps are **merge-composed** from the cached per-scene
  stacks (host-side concatenation with index offsets; bit-identical to a
  fresh build because batch bits keep scenes disjoint).  Under churning
  batch composition — the common case in real traffic — only cold scenes
  ever build maps, at their own size (Minuet §4 proper).  ``"incremental"``
  additionally lets streaming frames (``submit_delta``) update their scene
  table by an O(r+a) sorted delta-merge instead of a fresh argsort;
* flushes are triggered explicitly, by queue depth (``flush_count``), or by
  a latency deadline (``max_wait_ms`` — the oldest queued scene's age;
  check via ``poll()`` or any ``submit``), with deadline-triggered flushes
  counted in the engine stats;
* flushes run **pipelined**: while batch k executes on device, the host
  builds scene entries, composes maps/plans and packs batch k+1
  (``jax.block_until_ready`` is deferred to result drain, bounded by
  ``max_inflight`` dispatched-but-undrained batches — jax's async dispatch
  makes the overlap real on every backend).  Sorted-dataflow executor
  inputs (``SplitPlan``s) are merge-composed from per-scene cached orders
  the same way kernel maps are, so no per-batch bitmask argsort runs on
  the hot path.  With ``deadline_margin`` set, admission is deadline-aware:
  the engine predicts service time from its own phase medians and flushes
  / drains / cuts batches early when the oldest request's ``max_wait_ms``
  budget is about to be blown;
* the engine executes a compiled ``core.plan.NetworkPlan`` — the same
  artifact the models and the training stack run — loaded from a
  ``PlanRegistry`` at startup when one was persisted (tune once, serve
  forever; v1 assignment-only files recompile the plan from the model
  declaration) and re-tuned in place by ``tune()``;
* latency/throughput stats: per-scene p50/p95, scenes/s, recompile and
  map-cache counters.

The correctness contract — asserted in tests/test_serving.py — is that the
batched engine output is bit-identical to the per-scene forward at the same
bucket capacity: batching only ever adds rows whose keys can't collide with
another scene's (batch index is packed into every voxel key) and
inference-mode normalization keeps every output row a function of its own
scene's rows.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import dataflows as df
from repro.core import hashing
from repro.core.autotuner import timeit_fn
from repro.core.kmap import (SceneEntry, cell_ladder, cell_ladder_delta,
                             compose_kmaps, compose_split_plans, ladder_tables)
from repro.core.plan import (KmapSpec, NetworkPlan, PlanTuner,
                             scene_entry_arrays, scene_entry_from_arrays)
from repro.core.sparse_conv import TrainDataflowConfig
from repro.core.sparse_tensor import INVALID_COORD, SparseTensor
from repro.models import centerpoint, minkunet
from repro.serve.batcher import (PackedBatch, Scene, SceneBatcher, SceneDelta,
                                 SceneResult, apply_delta)
from repro.serve.bucketing import BucketLadder
from repro.serve.plans import PlanRegistry
from repro.serve.service import (STATS_SCHEMA_VERSION, ServiceConfig,
                                 resolve_config)


@dataclasses.dataclass(frozen=True)
class ArchBinding:
    """Everything the engine needs to serve one sparse architecture."""

    name: str
    model: object                       # module: init_params/build_maps/apply/layer_signatures
    default_config: object
    out_stride_of: Callable[[object], int]
    outputs_of: Callable[[object, SparseTensor, dict, jax.Array], tuple]
    in_channels_of: Callable[[object], int]


def _minkunet_outputs(cfg, st, maps, feats):
    # logits are per input voxel: rows align with the stride-1 input coords
    return st.coords, feats, st.num_valid


def _centerpoint_outputs(cfg, st, maps, feats):
    s = 2 ** len(cfg.channels)
    km = maps[("sub", s)]
    return km.out_coords, feats, km.n_out


def _arch_bindings() -> Dict[str, ArchBinding]:
    from repro.configs import centerpoint_waymo, minkunet_kitti

    return {
        "minkunet_kitti": ArchBinding(
            name="minkunet_kitti", model=minkunet,
            default_config=minkunet_kitti.CONFIG_BENCH,
            out_stride_of=lambda cfg: 1,
            outputs_of=_minkunet_outputs,
            in_channels_of=lambda cfg: cfg.in_channels),
        "centerpoint_waymo": ArchBinding(
            name="centerpoint_waymo", model=centerpoint,
            default_config=centerpoint_waymo.CONFIG_BENCH,
            out_stride_of=lambda cfg: 2 ** len(cfg.channels),
            outputs_of=_centerpoint_outputs,
            in_channels_of=lambda cfg: cfg.in_channels),
    }


ARCHS = _arch_bindings()

DEFAULT_LADDER = BucketLadder.geometric(base=512, steps=3, max_batch=4)
DEFAULT_SPATIAL_BOUND = 256


#: per-scene latencies kept for percentile stats; bounded so a
#: tune-once-serve-forever process doesn't grow memory with uptime
LATENCY_WINDOW = 8192

#: per-phase duration samples kept per phase name (same rationale)
PHASE_WINDOW = 4096


def percentiles_ms(values) -> Tuple[Optional[float], Optional[float]]:
    """(p50, p95) of a latency window — ``(None, None)`` when nothing was
    recorded, so an idle worker is distinguishable from an infinitely fast
    one (the old ``np.zeros(1)`` placeholder fabricated ``0.0`` ms)."""
    if not len(values):
        return (None, None)
    lat = np.asarray(values, dtype=np.float64)
    return (float(np.percentile(lat, 50)), float(np.percentile(lat, 95)))


def summarize_phases(windows: Dict[str, Sequence[float]]) -> Dict[str, dict]:
    """Fold per-phase duration windows into {phase: count/p50/p95} — the
    ``summary()['phases']`` block, shared by Engine and Router stats."""
    out = {}
    for name, window in sorted(windows.items()):
        p50, p95 = percentiles_ms(window)
        out[name] = {"count": len(window), "p50_ms": p50, "p95_ms": p95}
    return out


def _overlap_ns(host_ivs: Sequence[tuple], dev_ivs: Sequence[tuple]):
    """(host_total, device_total, overlap) in ns of two interval sets, each
    union-merged first — the pipeline's host-busy/device-busy/overlap
    accounting (overlap ≈ 0 for a serial depth-1 loop by construction)."""
    def merge(ivs):
        out: List[list] = []
        for a, b in sorted(ivs):
            if out and a <= out[-1][1]:
                out[-1][1] = max(out[-1][1], b)
            else:
                out.append([a, b])
        return out

    h, d = merge(host_ivs), merge(dev_ivs)
    ht = sum(b - a for a, b in h)
    dt = sum(b - a for a, b in d)
    ov = 0
    i = j = 0
    while i < len(h) and j < len(d):
        lo, hi = max(h[i][0], d[j][0]), min(h[i][1], d[j][1])
        if hi > lo:
            ov += hi - lo
        if h[i][1] < d[j][1]:
            i += 1
        else:
            j += 1
    return ht, dt, ov


@dataclasses.dataclass
class EngineStats:
    submitted: int = 0
    completed: int = 0
    batches: int = 0
    routed_batches: int = 0      # batches assigned by a DeviceRouter
    flushes: int = 0
    busy_s: float = 0.0
    latencies_ms: "collections.deque" = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=LATENCY_WINDOW))
    recompiles: Dict[int, int] = dataclasses.field(default_factory=dict)
    map_compiles: Dict[int, int] = dataclasses.field(default_factory=dict)
    plan_compiles: Dict[int, int] = dataclasses.field(default_factory=dict)
    map_hits: int = 0
    map_misses: int = 0
    # scene-granular reuse (composed/incremental table strategies)
    scene_compiles: Dict[int, int] = dataclasses.field(default_factory=dict)
    scene_hits: int = 0          # batch slots served from the scene store
    scene_misses: int = 0        # cold scenes that built their own stack
    composed_batches: int = 0    # batch map stacks merge-composed, not built
    delta_merges: int = 0        # streaming frames that delta-merged a table
    # flush triggers beyond the explicit flush() call
    deadline_flushes: int = 0    # max_wait_ms expiries
    count_flushes: int = 0       # flush_count threshold crossings
    deadline_cuts: int = 0       # batches cut early by deadline admission
    # pipelined-flush accounting (summary()['pipeline'])
    inflight_peak: int = 0       # max dispatched-but-undrained batches seen
    host_busy_s: float = 0.0     # union of host pack/map/dispatch/unpack time
    device_busy_s: float = 0.0   # union of dispatch→ready device windows
    overlap_s: float = 0.0       # host-busy ∩ device-busy
    # per-phase duration windows (queue_wait/pack/map/execute/unpack/…) —
    # always on (a perf_counter pair + deque append per phase), independent
    # of whether the tracer is enabled
    phases: Dict[str, "collections.deque"] = dataclasses.field(
        default_factory=dict)
    # SLO accounting: requests measured against the deadline (max_wait_ms)
    slo_deadline_ms: Optional[float] = None
    slo_measured: int = 0
    slo_miss_count: int = 0

    def observe(self, phase: str, ms: float) -> None:
        window = self.phases.get(phase)
        if window is None:
            window = self.phases[phase] = collections.deque(
                maxlen=PHASE_WINDOW)
        window.append(ms)

    def slo_observe(self, latency_ms: float, deadline_ms: float) -> None:
        """Score one completed request against its latency deadline."""
        self.slo_deadline_ms = deadline_ms
        self.slo_measured += 1
        if latency_ms > deadline_ms:
            self.slo_miss_count += 1

    def summary(self) -> dict:
        p50, p95 = percentiles_ms(self.latencies_ms)
        return {
            "schema_version": STATS_SCHEMA_VERSION,
            "scenes": self.completed,
            "batches": self.batches,
            "routed_batches": self.routed_batches,
            "p50_ms": p50,
            "p95_ms": p95,
            "scenes_per_s": self.completed / self.busy_s if self.busy_s else 0.0,
            "recompiles": dict(self.recompiles),
            "map_compiles": dict(self.map_compiles),
            "plan_compiles": dict(self.plan_compiles),
            "map_cache": {"hits": self.map_hits, "misses": self.map_misses},
            "scene_tables": {"hits": self.scene_hits,
                             "misses": self.scene_misses,
                             "composed_batches": self.composed_batches,
                             "delta_merges": self.delta_merges,
                             "compiles": dict(self.scene_compiles)},
            "deadline_flushes": self.deadline_flushes,
            "count_flushes": self.count_flushes,
            "deadline_cuts": self.deadline_cuts,
            "pipeline": {
                "inflight_peak": self.inflight_peak,
                "host_busy_s": self.host_busy_s,
                "device_busy_s": self.device_busy_s,
                "overlap_s": self.overlap_s,
                "overlap_frac": (self.overlap_s / self.device_busy_s
                                 if self.device_busy_s else 0.0)},
            "phases": summarize_phases(self.phases),
            "slo": {"deadline_ms": self.slo_deadline_ms,
                    "measured": self.slo_measured,
                    "misses": self.slo_miss_count,
                    "miss_rate": (self.slo_miss_count / self.slo_measured
                                  if self.slo_measured else None)},
        }


class Engine:
    """Front end: ``submit()`` scenes, ``flush()`` to run queued work.

    arch: "minkunet_kitti" | "centerpoint_waymo" (see ``ARCHS``).
    plans: a PlanRegistry (or path to one) holding tuned per-group dataflow
        assignments; missing entries fall back to the default config.
    map_strategy: coordinate-table strategy override ("sort" / "composed" /
        "incremental"); None follows the plan's declared ``KmapSpec.table``
        axis.  "sort" is the PR-2 whole-batch-digest behavior; "composed"
        adds scene-granular map reuse; "incremental" also enables
        ``submit_delta`` streaming-table merges.
    max_wait_ms / flush_count: latency-deadline and queue-depth triggers for
        automatic flushes (None disables each); auto-flushed results are
        returned by the next ``flush()``/``poll()``.
    scene_cache_size: LRU bound of the per-scene store.  Entries are
        host-resident numpy map stacks (~ refs x KD x scene-rung int32
        words each), so size this by host RAM, not device memory.
    scene_cache_bytes: optional byte bound on the same store — eviction by
        the actual ``SceneEntry.nbytes`` footprint (split-order and ladder
        caches included), which tracks residency far better than an entry
        count when scene sizes span rungs.  Both bounds apply when set.
    max_inflight: dispatched-but-undrained batch window of a pipelined
        flush.  1 restores the strictly serial dispatch→block loop; the
        default 2 double-buffers host mapping/packing against device
        execution.  Outputs are bit-identical at any depth — batches are
        independent and drain in FIFO order.
    deadline_margin: None (default) keeps deadline handling purely
        age-based (flush when the oldest request has waited max_wait_ms).
        A float enables deadline-*aware* admission: the engine predicts
        remaining service time as ``margin ×`` the median of its own
        pack/map/dispatch/execute/unpack phases and (a) auto-flushes early
        so requests finish inside the budget, (b) drains the in-flight
        window before dispatching more when the head batch is about to
        miss, and (c) cuts the first batch of a flush down to the urgent
        scene instead of co-batching it with fresh work.
    device: pin this engine to one jax device — params and every packed
        batch are ``jax.device_put`` there, so each compiled rung's
        executor runs on that device.  None (default) follows jax's default
        placement.  This is how the ``DeviceRouter`` builds one worker per
        device.
    plan_key: the PlanRegistry name to read/write plans under (defaults to
        ``arch``; the router routes per-device entries like ``arch@dev2``
        here — see ``serve.plans.device_key``).

    All behavioral knobs above (ladder, spatial_bound, seed, map_strategy,
    caches, deadlines, …) now live in one serializable ``ServiceConfig`` —
    pass ``config=ServiceConfig(...)``.  The historical per-kwarg spelling
    keeps working through ``resolve_config`` (one DeprecationWarning per
    process); ``model_config`` / ``params`` / ``plans`` / ``precision`` /
    ``device`` stay direct arguments because they are runtime objects, not
    serializable configuration.
    """

    def __init__(self, arch: str, config: Optional[ServiceConfig] = None,
                 model_config=None, params=None,
                 plans: Optional[PlanRegistry] = None,
                 precision=None,
                 device: Optional[jax.Device] = None, **legacy):
        if arch not in ARCHS:
            raise ValueError(f"unknown arch {arch!r}; have {sorted(ARCHS)}")
        if isinstance(config, BucketLadder):   # Engine(arch, ladder) callers
            legacy.setdefault("ladder", config)
            config = None
        self.config = resolve_config(config, legacy)
        cfg_s = self.config
        self.binding = ARCHS[arch]
        self.arch = arch
        self.device = device
        self.cfg = model_config if model_config is not None else self.binding.default_config
        self.params = params if params is not None else self.binding.model.init_params(
            self.cfg, jax.random.PRNGKey(cfg_s.seed))
        if device is not None:
            self.params = jax.device_put(self.params, device)
        self.ladder = cfg_s.ladder()
        self.batcher = SceneBatcher(self.ladder, cfg_s.spatial_bound)
        if isinstance(plans, str):
            plans = PlanRegistry.load(plans)
        self.plans = plans or PlanRegistry()
        self.plan_key = cfg_s.plan_key or arch
        self.assignment = self.plans.get(self.plan_key)
        # The compiled artifact every stage shares: a persisted NetworkPlan
        # is used as-is when it still matches this engine's model config
        # (same layer names + ConvSpecs); otherwise — v1 files, or a plan
        # tuned under a different width/depth — one is recompiled from the
        # model declaration with the registry's assignment.
        nplan = self.plans.network(self.plan_key)
        compiled = self.binding.model.network_plan(self.cfg,
                                                   assignment=self.assignment)
        if nplan is None or [(lp.name, lp.spec) for lp in nplan.layers] != \
                [(lp.name, lp.spec) for lp in compiled.layers]:
            nplan = compiled
        if precision is not None:
            nplan = nplan.with_precision(precision)
        self.nplan: NetworkPlan = nplan
        self.out_stride = self.binding.out_stride_of(self.cfg)
        self.map_strategy = (cfg_s.map_strategy
                             if cfg_s.map_strategy is not None
                             else self.nplan.table_strategy)
        assert self.map_strategy in KmapSpec.TABLE_STRATEGIES, self.map_strategy
        self.max_wait_ms = cfg_s.max_wait_ms
        self.flush_count = cfg_s.flush_count
        assert cfg_s.max_inflight >= 1, cfg_s.max_inflight
        self.max_inflight = cfg_s.max_inflight
        self.deadline_margin = cfg_s.deadline_margin
        self.stats = EngineStats()
        self.maps_cache_size = cfg_s.maps_cache_size
        self.scene_cache_size = cfg_s.scene_cache_size
        self.scene_cache_bytes = cfg_s.scene_cache_bytes
        self._queue: List[tuple] = []       # (ticket, Scene, t_submit)
        self._next_ticket = 0
        self._ready: Dict[int, SceneResult] = {}   # auto-flushed results
        self._map_store: "collections.OrderedDict[str, dict]" = collections.OrderedDict()
        # The scene store is device-agnostic (host numpy), so a DeviceRouter
        # shares ONE store — and its lock — across all its workers; the lock
        # only guards dict mutation, never a build (concurrent builds of the
        # same digest are idempotent: entries are bit-identical).
        self._scene_lock = threading.Lock()
        self._scene_store: "collections.OrderedDict[str, SceneEntry]" = collections.OrderedDict()
        # stream id -> last scene, LRU-bounded: serve-forever processes see
        # ephemeral stream ids, and each entry pins a full host-side Scene
        self._streams: "collections.OrderedDict[str, Scene]" = collections.OrderedDict()
        self.stream_cache_size = 1024
        self._builders: Dict[int, Callable] = {}
        self._executors: Dict[int, Callable] = {}
        self._plan_builders: Dict[int, Callable] = {}
        self._scene_builders: Dict[int, Callable] = {}
        self._scene_delta_builders: Dict[int, Callable] = {}
        #: down-map out-strides, ascending — the cell ladder's levels
        self._down_strides = tuple(sorted(
            ms.tensor_stride * ms.stride for ms in self.nplan.map_specs
            if ms.kind == "down"))
        #: (kind, rung) marks queued by trace-time side effects, drained by
        #: the jit wrappers into structured ``compile`` trace events
        self._compile_marks: List[tuple] = []
        # per-scene builds jit once per rung of a small capacity ladder
        # (scene sizes vary request to request; exact-size eager builds
        # would recompile every op per distinct size)
        caps = [min(64, self.ladder.capacities[0])]
        while caps[-1] < self.ladder.max_capacity:
            caps.append(caps[-1] * 2)
        self._scene_ladder = BucketLadder(tuple(caps), max_batch=1)

    # -------------------------------------------------------- observability
    @property
    def device_name(self) -> str:
        """The device identity compile events are keyed by (the pinned
        device, or jax's default placement when the engine floats)."""
        d = self.device if self.device is not None else jax.devices()[0]
        return str(d)

    @contextlib.contextmanager
    def _phase(self, name: str, **attrs):
        """Time one phase of the hot path into BOTH sinks: a tracer span
        (rich, nestable, exportable — no-op singleton when disabled) and
        the always-on ``EngineStats.phases`` histogram window."""
        t0 = time.perf_counter()
        with obs.span(name, **attrs) as sp:
            yield sp
        self.stats.observe(name, (time.perf_counter() - t0) * 1e3)

    def _jit_counting(self, fn, kind: str, counter_attr: str,
                      cap: int) -> Callable:
        """jit ``fn`` with the trace-time side effect that counts *actual*
        recompiles (not calls) into ``stats.<counter_attr>[cap]``, plus a
        structured ``compile`` trace event carrying (kind, rung, device,
        wall time).  The side effect fires mid-trace, where the compile's
        duration is unknowable, so it queues a mark; the wrapper drains
        marks after the triggering call returns and stamps the event with
        that call's wall time (trace + compile + first execution)."""
        def traced(*args):
            counters = getattr(self.stats, counter_attr)
            counters[cap] = counters.get(cap, 0) + 1
            self._compile_marks.append((kind, cap))
            return fn(*args)

        jfn = jax.jit(traced)

        def wrapper(*args):
            n0 = len(self._compile_marks)
            t0 = time.perf_counter()
            out = jfn(*args)
            if len(self._compile_marks) > n0:
                wall_ms = (time.perf_counter() - t0) * 1e3
                marks = self._compile_marks[n0:]
                del self._compile_marks[n0:]
                for k, c in marks:
                    obs.event("compile", kind=k, rung=c,
                              device=self.device_name,
                              wall_ms=round(wall_ms, 3))
            return out

        return wrapper

    # ------------------------------------------------------------------ jit
    def _builder_for(self, cap: int) -> Callable:
        fn = self._builders.get(cap)
        if fn is None:
            nplan = self.nplan
            fn = self._jit_counting(nplan.build_maps, "map_builder",
                                    "map_compiles", cap)
            self._builders[cap] = fn
        return fn

    def _executor_for(self, cap: int) -> Callable:
        fn = self._executors.get(cap)
        if fn is None:
            binding, cfg, nplan = self.binding, self.cfg, self.nplan

            def run(params, st, maps, plans):
                feats = nplan.apply(params, st, maps, bn_mode="affine",
                                    plans=plans)
                return binding.outputs_of(cfg, st, maps, feats)

            fn = self._jit_counting(run, "executor", "recompiles", cap)
            self._executors[cap] = fn
        return fn

    def _plan_builder_for(self, cap: int) -> Callable:
        """Jitted fresh split-plan build (the cold-batch fallback when no
        per-scene orders exist to compose) — counted separately from map
        compiles so the per-rung map/executor compile contracts hold."""
        fn = self._plan_builders.get(cap)
        if fn is None:
            nplan = self.nplan
            fn = self._jit_counting(nplan.build_split_plans, "plan_builder",
                                    "plan_compiles", cap)
            self._plan_builders[cap] = fn
        return fn

    # ------------------------------------------------------ scene-granular
    def _scene_tensor(self, scene: Scene, cap: int) -> SparseTensor:
        """Single-scene tensor (batch column 0) padded to a scene-ladder
        capacity, with declared bounds matching the packed batches — so its
        KeySpec, and therefore its sorted tables and maps, compose
        bit-identically into batch ones.  Features are irrelevant to
        mapping; a 1-channel zero column keeps the trace tiny."""
        n = scene.num_points
        coords = np.full((cap, 1 + scene.coords.shape[1]), int(INVALID_COORD),
                         np.int32)
        coords[:n, 0] = 0
        coords[:n, 1:] = scene.coords
        st = SparseTensor(coords=jnp.asarray(coords),
                          feats=jnp.zeros((cap, 1), jnp.float32),
                          num_valid=jnp.asarray(n, jnp.int32), stride=1,
                          batch_bound=self.ladder.max_batch,
                          spatial_bound=self.batcher.spatial_bound)
        return st if self.device is None else jax.device_put(st, self.device)

    def _scene_builder_for(self, cap: int) -> Callable:
        fn = self._scene_builders.get(cap)
        if fn is None:
            specs = self.nplan.map_specs
            fn = self._jit_counting(lambda st: scene_entry_arrays(specs, st),
                                    "scene_builder", "scene_compiles", cap)
            self._scene_builders[cap] = fn
        return fn

    def _scene_delta_builder_for(self, cap: int) -> Callable:
        """Like the scene builder, but adopting a delta-merged root table
        (passed as arrays, padded to ``cap``) so the build skips the scene
        argsort — and, when the stream's cell ladder is live, adopting the
        incrementally-updated down-level tables (``lkeys``/``lns``, also
        padded to ``cap``) so no per-level masked-key argsort runs either:
        the whole delta rebuild is binary searches over adopted tables."""
        fn = self._scene_delta_builders.get(cap)
        if fn is None:
            specs = self.nplan.map_specs

            def build(st, keys, order, lkeys, lns):
                spec = hashing.key_spec_for(st.ndim_space, st.batch_bound,
                                            st.spatial_bound)
                tables = {s: (lkeys[s], None, lns[s]) for s in lkeys}
                maps, k, o = scene_entry_arrays(
                    specs, st, root_table=hashing.CoordTable(spec, keys, order),
                    tables=tables)
                return maps, k, o

            fn = self._jit_counting(build, "scene_delta_builder",
                                    "scene_compiles", cap)
            self._scene_delta_builders[cap] = fn
        return fn

    def _key_spec(self, ndim_space: int) -> hashing.KeySpec:
        """The packed-key spec every scene/batch table of this engine uses
        (bounds are the engine's declared promises)."""
        return hashing.key_spec_for(ndim_space, self.ladder.max_batch,
                                    self.batcher.spatial_bound)

    def _store_scene(self, digest: str, entry: SceneEntry) -> None:
        with self._scene_lock:
            self._scene_store[digest] = entry
            if self.scene_cache_bytes is not None:
                # byte-aware eviction: keep at least the entry just stored
                while (len(self._scene_store) > 1 and
                       sum(e.nbytes for e in self._scene_store.values())
                       > self.scene_cache_bytes):
                    self._scene_store.popitem(last=False)
            while len(self._scene_store) > self.scene_cache_size:
                self._scene_store.popitem(last=False)

    def _scene_entry(self, scene: Scene) -> SceneEntry:
        with self._scene_lock:
            ent = self._scene_store.get(scene.digest)
            if ent is not None:
                self.stats.scene_hits += 1
                self._scene_store.move_to_end(scene.digest)
                return ent
        self.stats.scene_misses += 1
        cap = self._scene_ladder.select(scene.num_points)
        with self._phase("scene_build", cap=cap, points=scene.num_points):
            maps, keys, order = self._scene_builder_for(cap)(
                self._scene_tensor(scene, cap))
            ent = scene_entry_from_arrays(self.nplan.map_specs, maps,
                                          scene.num_points, keys, order)
            if self.map_strategy == "incremental":
                # seed the stream's cell ladder so later deltas propagate
                # down the pyramid incrementally instead of re-deriving it
                ent.ladder = cell_ladder(
                    self._key_spec(scene.coords.shape[1]), ent.root_keys,
                    self._down_strides)
        self._store_scene(scene.digest, ent)
        return ent

    def _maps_for(self, batch: PackedBatch,
                  scenes: Optional[Sequence[Scene]] = None) -> Tuple[dict, dict]:
        """Batch kernel maps + pre-built executor split plans (``{}`` when no
        layer consumes one).  Composed batches also *compose* their plans —
        per-scene stable-sorted bitmask orders merge host-side, so sorted
        dataflows stop paying a per-batch argsort; cold fallbacks build the
        plans jitted alongside the maps."""
        cached = self._map_store.get(batch.digest)
        if cached is not None:
            self.stats.map_hits += 1
            self._map_store.move_to_end(batch.digest)
            return cached
        self.stats.map_misses += 1
        pspecs = self.nplan.split_plan_specs()
        maps = None
        plans: dict = {}
        if scenes is not None and self.map_strategy in ("composed",
                                                        "incremental"):
            # includes nested scene_build spans for any cold scenes
            with self._phase("compose_kmaps", bucket=batch.bucket,
                             scenes=len(scenes)):
                entries = [self._scene_entry(s) for s in scenes]
                maps = compose_kmaps(entries, batch.bucket)
            if maps is not None:
                self.stats.composed_batches += 1
                if pspecs:
                    with self._phase("compose_plans", bucket=batch.bucket):
                        for ref, ns, srt in pspecs:
                            plans[(ref, ns, srt)] = compose_split_plans(
                                entries, ref, ns, srt, batch.bucket)
        if maps is None:
            with self._phase("map_build", bucket=batch.bucket):
                maps = self._builder_for(batch.bucket)(batch.st)
            if pspecs:
                with self._phase("plan_build", bucket=batch.bucket):
                    plans = self._plan_builder_for(batch.bucket)(maps)
        self._map_store[batch.digest] = (maps, plans)
        while len(self._map_store) > self.maps_cache_size:
            self._map_store.popitem(last=False)
        return maps, plans

    # ------------------------------------------------------------------ api
    def submit(self, scene: Scene, stream: Optional[str] = None) -> int:
        """Enqueue one scene; returns a ticket resolved by the next flush.

        stream: optional stream id — remembers the scene as the stream's
        latest frame so later frames can arrive as ``submit_delta`` updates.
        Submitting may trigger an automatic flush (queue depth reaching
        ``flush_count``, or the oldest queued scene exceeding
        ``max_wait_ms``); those results are held for the next ``flush()``
        or ``poll()``.
        """
        if scene.num_points > self.ladder.max_capacity:
            raise ValueError(f"scene of {scene.num_points} rows exceeds the "
                             f"largest bucket ({self.ladder.max_capacity})")
        t = self._next_ticket
        self._next_ticket += 1
        self._queue.append((t, scene, time.perf_counter()))
        self.stats.submitted += 1
        if stream is not None:
            self._streams[stream] = scene
            self._streams.move_to_end(stream)
            while len(self._streams) > self.stream_cache_size:
                self._streams.popitem(last=False)
        self._autoflush()
        return t

    def submit_delta(self, stream: str, delta: SceneDelta) -> int:
        """Enqueue a streaming frame as a delta of the stream's last scene.

        Under the ``"incremental"`` strategy the scene's cached sorted table
        is **delta-merged** (O(r+a) merge, no argsort of the full cloud) and
        the scene's map stack is rebuilt on the merged table, so the frame
        composes into batches like any warm scene; other strategies just
        apply the delta and submit the full scene.
        """
        return self.submit(self._merge_delta(stream, delta), stream=stream)

    def _merge_delta(self, stream: str, delta: SceneDelta) -> Scene:
        """Apply ``delta`` to the stream's last scene and (incremental
        strategy) delta-merge its cached table into a fresh SceneEntry.
        Host-side work only — the router calls this on one worker and the
        resulting store entry composes on every device."""
        prev = self._streams.get(stream)
        if prev is None:
            raise KeyError(f"unknown stream {stream!r}; seed it with "
                           f"submit(scene, stream=...) first")
        if (delta.added_coords.size and
                int(np.abs(delta.added_coords).max()) > self.batcher.spatial_bound):
            # the same declared-bound promise pack() enforces — reject here,
            # BEFORE an out-of-range coord could mis-pack into a cached
            # scene table (host-side np_pack_keys has no PAD sentinel)
            raise ValueError(
                f"delta adds a coord violating declared spatial_bound "
                f"{self.batcher.spatial_bound}: max |coord| = "
                f"{np.abs(delta.added_coords).max()}")
        scene = apply_delta(prev, delta)
        if (self.map_strategy == "incremental"
                and scene.digest not in self._scene_store):
            with self._scene_lock:
                prev_ent = self._scene_store.get(prev.digest)
            if prev_ent is not None:
                with self._phase("delta_merge", stream=stream,
                                 added=int(delta.added_coords.shape[0]),
                                 removed=int(delta.removed.shape[0])):
                    spec = self._key_spec(scene.coords.shape[1])
                    rm_rows = np.concatenate(
                        [np.zeros((delta.removed.shape[0], 1), np.int32),
                         delta.removed], 1)
                    ad_rows = np.concatenate(
                        [np.zeros((delta.added_coords.shape[0], 1), np.int32),
                         delta.added_coords], 1)
                    # host-side O(r+a) sorted merge of the cached scene table
                    mkeys, morder = hashing.np_delta_merge(
                        spec, prev_ent.root_keys, prev_ent.root_order,
                        rm_rows, ad_rows)
                    # pad the merged table up to the scene rung — identical to
                    # a fresh build of the padded scene tensor (PAD keys sort
                    # last, pad rows in slot order), so the jitted builder
                    # adopts it transparently
                    n = scene.num_points
                    cap = self._scene_ladder.select(n)
                    pad = (cap - n,) + mkeys.shape[1:]
                    keys = np.concatenate([
                        mkeys, np.full(pad, np.iinfo(np.int32).max, np.int32)])
                    order = np.concatenate([
                        morder, np.arange(n, cap, dtype=np.int32)])
                    # propagate the delta through the cached cell ladder —
                    # every down level's table updates in O(r+a+cells), so
                    # the rebuild below adopts tables at EVERY pyramid level
                    # (no per-level masked-key argsort on the merged root)
                    if prev_ent.ladder:
                        lad = cell_ladder_delta(
                            spec, prev_ent.ladder,
                            hashing.np_pack_keys(rm_rows, spec),
                            hashing.np_pack_keys(ad_rows, spec))
                    else:
                        lad = cell_ladder(spec, mkeys, self._down_strides)
                    tabs = ladder_tables(spec, lad, cap)
                    maps, k, o = self._scene_delta_builder_for(cap)(
                        self._scene_tensor(scene, cap), jnp.asarray(keys),
                        jnp.asarray(order),
                        {s: jnp.asarray(t[0]) for s, t in tabs.items()},
                        {s: jnp.asarray(t[2], jnp.int32)
                         for s, t in tabs.items()})
                    ent = scene_entry_from_arrays(self.nplan.map_specs, maps,
                                                  n, k, o)
                    ent.ladder = lad
                    self._store_scene(scene.digest, ent)
                    self.stats.delta_merges += 1
        return scene

    def _predicted_service_ms(self) -> float:
        """Predicted service time of one batch: the sum of this engine's own
        median pack/map/dispatch/execute/unpack phase durations (0.0 until
        warm — deadline awareness then degrades to pure age checks)."""
        total = 0.0
        for name in ("pack", "map", "dispatch", "execute", "unpack"):
            window = self.stats.phases.get(name)
            if window:
                total += float(np.median(window))
        return total

    def _deadline_budget_ms(self) -> Optional[float]:
        """The age at which a queued request must start service: plain
        ``max_wait_ms`` by default, shrunk by the predicted service time
        (× ``deadline_margin``) under deadline-aware admission."""
        if self.max_wait_ms is None:
            return None
        if self.deadline_margin is None:
            return self.max_wait_ms
        return self.max_wait_ms - (self.deadline_margin *
                                   self._predicted_service_ms())

    def _deadline_due(self) -> bool:
        budget = self._deadline_budget_ms()
        return (budget is not None and bool(self._queue) and
                (time.perf_counter() - self._queue[0][2]) * 1e3 >= budget)

    def _deadline_cut(self, queue: Sequence[tuple]) -> Optional[int]:
        """Deadline-aware batch cutting: when the oldest request's budget is
        (nearly) blown at flush start, serve it alone instead of co-batching
        it with fresh arrivals — returns the first-group scene cap for
        ``SceneBatcher.plan``."""
        if self.deadline_margin is None or self.max_wait_ms is None:
            return None
        if len(queue) <= 1:
            return None
        age_ms = (time.perf_counter() - queue[0][2]) * 1e3
        if age_ms >= self._deadline_budget_ms():
            self.stats.deadline_cuts += 1
            return 1
        return None

    def _autoflush(self) -> None:
        if self.flush_count is not None and len(self._queue) >= self.flush_count:
            self.stats.count_flushes += 1
            self._ready.update(self._run_queue())
        elif self._deadline_due():
            self.stats.deadline_flushes += 1
            self._ready.update(self._run_queue())

    def poll(self) -> Dict[int, SceneResult]:
        """Deadline hook for timer-driven callers: flush iff the oldest
        queued scene has waited past ``max_wait_ms``, then drain any results
        completed by automatic flushes."""
        if self._deadline_due():
            self.stats.deadline_flushes += 1
            self._ready.update(self._run_queue())
        out, self._ready = self._ready, {}
        return out

    def flush(self) -> Dict[int, SceneResult]:
        """Pack and run everything queued; returns {ticket: SceneResult}
        (including results completed earlier by automatic flushes)."""
        out, self._ready = self._ready, {}
        out.update(self._run_queue())
        return out

    def _dispatch_group(self, scenes: Sequence[Scene]) -> Tuple[PackedBatch, tuple]:
        """Pack ``scenes``, resolve their maps, and dispatch the executor on
        this engine's device *without* blocking — pair with
        ``_finish_group``.  The dispatch/finish split is what lets the
        ``DeviceRouter`` overlap one worker's host-side packing with another
        worker's device execution."""
        with self._phase("pack", scenes=len(scenes)) as sp:
            batch = self.batcher.pack(scenes)
            sp.set(bucket=batch.bucket)
            if self.device is not None:
                batch = dataclasses.replace(
                    batch, st=jax.device_put(batch.st, self.device))
        with self._phase("map", bucket=batch.bucket):
            maps, plans = self._maps_for(batch, scenes)
        with self._phase("dispatch", bucket=batch.bucket,
                         device=self.device_name):
            out = self._executor_for(batch.bucket)(self.params, batch.st,
                                                   maps, plans)
        return batch, out

    def _finish_group(self, batch: PackedBatch, out,
                      t_disp_ns: Optional[int] = None):
        """Block on a dispatched batch and unpack it into per-scene rows.
        Returns ``(ready_timestamp_ns, per_scene_results)``.

        ``t_disp_ns`` (pipelined drains) backdates the "execute" span to
        dispatch-return so it covers the device-side window the host
        overlapped — recorded retroactively via ``obs.record_span`` because
        the host was busy with batch k+1 while it ran."""
        t0 = time.perf_counter_ns()
        out_coords, out_feats, n_out = jax.block_until_ready(out)
        t1 = time.perf_counter_ns()
        start = t0 if t_disp_ns is None else t_disp_ns
        self.stats.observe("execute", (t1 - start) / 1e6)
        obs.record_span("execute", start, t1, bucket=batch.bucket,
                        device=self.device_name)
        with self._phase("unpack", bucket=batch.bucket,
                         scenes=batch.num_scenes):
            per_scene = self.batcher.unpack(batch, out_coords, out_feats,
                                            int(n_out), self.out_stride)
        self.stats.batches += 1
        self.stats.completed += batch.num_scenes
        return t1, per_scene

    def _run_pipeline(self, scene_groups: Sequence[Sequence[Scene]],
                      on_done: Callable,
                      urgent: Optional[Callable[[int], bool]] = None) -> None:
        """Double-buffered group execution: dispatch group k+1 (host pack /
        map compose / executor call — all non-blocking under jax async
        dispatch) while group k executes on device; drain FIFO, bounded by
        ``max_inflight`` dispatched-but-undrained batches.

        Bit-identical to the serial loop at any depth: grouping, packing,
        composition and unpacking are untouched — only the position of
        ``block_until_ready`` moves, and batches are independent.

        on_done(group_index, batch, per_scene) fires at each drain, in
        group order.  urgent(head_group_index) — deadline admission — forces
        draining the oldest in-flight batch before the next dispatch.
        """
        inflight: "collections.deque" = collections.deque()
        host_ivs: List[tuple] = []
        dev_ivs: List[tuple] = []

        def drain_one():
            gi, batch, out, t_disp = inflight.popleft()
            t_ready, per_scene = self._finish_group(batch, out, t_disp)
            dev_ivs.append((t_disp, t_ready))
            host_ivs.append((t_ready, time.perf_counter_ns()))  # unpack
            on_done(gi, batch, per_scene)

        for gi, scenes in enumerate(scene_groups):
            while inflight and (len(inflight) >= self.max_inflight or
                                (urgent is not None and urgent(inflight[0][0]))):
                drain_one()
            h0 = time.perf_counter_ns()
            batch, out = self._dispatch_group(scenes)
            t_disp = time.perf_counter_ns()
            host_ivs.append((h0, t_disp))
            inflight.append((gi, batch, out, t_disp))
            if len(inflight) > self.stats.inflight_peak:
                self.stats.inflight_peak = len(inflight)
        while inflight:
            drain_one()
        ht, dt, ov = _overlap_ns(host_ivs, dev_ivs)
        self.stats.host_busy_s += ht / 1e9
        self.stats.device_busy_s += dt / 1e9
        self.stats.overlap_s += ov / 1e9

    def _run_queue(self) -> Dict[int, SceneResult]:
        if not self._queue:
            return {}
        queue, self._queue = self._queue, []
        t0 = time.perf_counter()
        with obs.span("flush", scenes=len(queue), device=self.device_name,
                      max_inflight=self.max_inflight):
            # queue wait = submit → flush start; submit stamped the same
            # monotonic clock the tracer uses, so the interval replays
            # exactly in the trace timeline
            t0_ns = time.perf_counter_ns()
            for ticket, _, t_sub in queue:
                wait_ms = (t0 - t_sub) * 1e3
                self.stats.observe("queue_wait", wait_ms)
                obs.record_span("queue_wait", int(t_sub * 1e9), t0_ns,
                                ticket=ticket)
            results: Dict[int, SceneResult] = {}
            groups = self.batcher.plan([s.num_points for _, s, _ in queue],
                                       cut_first=self._deadline_cut(queue))

            def on_done(gi, batch, per_scene):
                t_done = time.perf_counter()
                t_done_ns = time.perf_counter_ns()
                for slot, i in enumerate(groups[gi]):
                    ticket, _, t_sub = queue[i]
                    results[ticket] = per_scene[slot]
                    lat_ms = (t_done - t_sub) * 1e3
                    self.stats.latencies_ms.append(lat_ms)
                    obs.record_span("request", int(t_sub * 1e9), t_done_ns,
                                    ticket=ticket, bucket=batch.bucket)
                    if self.max_wait_ms is not None:
                        # max_wait_ms doubles as the per-request latency SLO
                        self.stats.slo_observe(lat_ms, self.max_wait_ms)

            urgent = None
            if self.deadline_margin is not None and self.max_wait_ms is not None:
                def urgent(gi):
                    oldest = min(queue[i][2] for i in groups[gi])
                    age_ms = (time.perf_counter() - oldest) * 1e3
                    return age_ms >= self._deadline_budget_ms()

            self._run_pipeline([[queue[i][1] for i in g] for g in groups],
                               on_done, urgent)
        self.stats.busy_s += time.perf_counter() - t0
        self.stats.flushes += 1
        return results

    def serve(self, scenes: Sequence[Scene],
              flush_every: int = 0) -> List[SceneResult]:
        """Convenience driver: submit all, flush (in chunks), return in order."""
        out: Dict[int, SceneResult] = {}
        tickets = []
        for i, s in enumerate(scenes):
            tickets.append(self.submit(s))
            if flush_every and (i + 1) % flush_every == 0:
                out.update(self.flush())
        out.update(self.flush())
        return [out[t] for t in tickets]

    def warmup(self, channels: Optional[int] = None) -> None:
        """Compile every bucket once on synthetic single-scene batches so the
        request stream never pays a trace.  Under the composed/incremental
        strategies this also traces the per-scene builders for every rung of
        the scene-capacity ladder (and the delta builders, for streaming)."""
        c = channels or self.binding.in_channels_of(self.cfg)
        if self.map_strategy in ("composed", "incremental"):
            for cap in self._scene_ladder.capacities:
                rng = np.random.default_rng(cap)
                coords = np.unique(rng.integers(
                    -self.batcher.spatial_bound, self.batcher.spatial_bound,
                    size=(2 * cap, 3), dtype=np.int32), axis=0)[:cap]
                st = self._scene_tensor(
                    Scene(coords=coords,
                          feats=np.zeros((coords.shape[0], c), np.float32)),
                    cap)
                maps, keys, order = jax.block_until_ready(
                    self._scene_builder_for(cap)(st))
                if self.map_strategy == "incremental":
                    # the fresh table doubles as a valid adopted-table input;
                    # derive its cell ladder so the traced pytree structure
                    # matches live delta-merge calls exactly
                    m = coords.shape[0]
                    spec = self._key_spec(coords.shape[1])
                    lad = cell_ladder(spec, np.asarray(keys)[:m],
                                      self._down_strides)
                    tabs = ladder_tables(spec, lad, cap)
                    jax.block_until_ready(
                        self._scene_delta_builder_for(cap)(
                            st, keys, order,
                            {s: jnp.asarray(t[0]) for s, t in tabs.items()},
                            {s: jnp.asarray(t[2], jnp.int32)
                             for s, t in tabs.items()}))
        for cap in self.ladder.capacities:
            n = cap   # fill the bucket exactly so every rung compiles
            rng = np.random.default_rng(cap)
            coords = rng.integers(-self.batcher.spatial_bound,
                                  self.batcher.spatial_bound, size=(n, 3),
                                  dtype=np.int32)
            scene = Scene(coords=coords, feats=rng.normal(size=(n, c)).astype(np.float32))
            # go through the REAL dispatch path: it commits the packed batch
            # to this engine's device, and a warmup executed with any other
            # input placement compiles a *different* executable — the first
            # live batch would silently pay a second compile per rung
            batch, out = self._dispatch_group([scene])
            assert batch.bucket == cap, (batch.bucket, cap)
            jax.block_until_ready(out)

    # ------------------------------------------------------------- autotune
    def tune(self, sample_scenes: Sequence[Scene],
             space: Optional[Sequence[df.DataflowConfig]] = None,
             iters: int = 2, save: bool = True,
             resolve_tiles: bool = False) -> Dict[tuple, TrainDataflowConfig]:
        """Run the group-based Sparse Autotuner on a representative packed
        batch and persist the winning *NetworkPlan* to the PlanRegistry.

        Measurement is end-to-end engine-forward latency of each candidate
        plan (paper §4: never per-kernel time).  Existing executors are
        dropped so the tuned plan takes effect on the next flush.  Returns
        the per-group assignment for inspection; the serialized plan (and
        its v1-compatible assignment block) lands in the registry.

        ``resolve_tiles=True`` adds a measured tile-resolution pass over the
        winner's Pallas implicit-GEMM groups (each candidate (tile_m,
        tile_n) timed end-to-end like the dataflow sweep).  Off by default:
        it multiplies tuning wall-clock by the tile-menu size and only
        matters when the winning assignment uses the Pallas tier.
        """
        space = list(space or df.default_serving_space())
        sample_scenes = list(sample_scenes)
        # measure on the first bucket-fitting FIFO group of the sample
        group = self.batcher.plan([s.num_points for s in sample_scenes])[0]
        group_scenes = [sample_scenes[i] for i in group]
        batch = self.batcher.pack(group_scenes)
        maps, _ = self._maps_for(batch, group_scenes)

        def measure(candidate: NetworkPlan) -> float:
            fn = jax.jit(lambda p, st, m: candidate.apply(p, st, m,
                                                          bn_mode="affine"))
            return timeit_fn(lambda: jax.block_until_ready(
                fn(self.params, batch.st, maps)), warmup=1, iters=iters)

        tuned = PlanTuner(self.nplan, space, measure,
                          maps=maps if resolve_tiles else None).tune()
        self.nplan = tuned
        self.assignment = tuned.assignment()
        self.plans.set(self.plan_key, self.assignment, network=tuned)
        self.plans.set_service(self.plan_key, self.config)
        if save and self.plans.path:
            self.plans.save()
        self._executors.clear()     # recompile with the tuned plan
        self._plan_builders.clear()  # split-plan specs may have changed
        return dict(self.assignment)
