"""Coordinate hashing and sort-based lookup — int32-only, collision-free.

The paper builds kernel maps with a GPU hash table.  The TPU-idiomatic (and
JAX-native) equivalent is a *sorted binary search*: treat the (batch, x, y,
z) coordinate columns as lexicographic sort words, sort once per map group,
and answer each of the K^D shifted queries with a vectorized binary search
(O(log N) gathers, fully static shapes).  PointAcc (the ASIC the paper
compares against) makes the same observation — point-cloud mapping operators
reduce to sort/merge primitives.

Everything is int32 (x64 stays disabled framework-wide); no bit packing means
no coordinate-range limits and no hash collisions.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def lex_argsort(words: jax.Array) -> jax.Array:
    """Stable lexicographic argsort of rows. words: (N, W) int32 → (N,) int32."""
    n, w = words.shape
    order = jnp.arange(n, dtype=jnp.int32)
    # least-significant word first; stable sorts compose lexicographically
    for col in range(w - 1, -1, -1):
        order = order[jnp.argsort(words[order, col], stable=True)]
    return order


def rows_equal(a: jax.Array, b: jax.Array) -> jax.Array:
    """Elementwise row equality for (N, W) word matrices → (N,) bool."""
    return jnp.all(a == b, axis=-1)


def _lex_less(row_a, row_b):
    """row_a < row_b lexicographically; rows are (..., W)."""
    w = row_a.shape[-1]
    lt = row_a[..., 0] < row_b[..., 0]
    eq = row_a[..., 0] == row_b[..., 0]
    for c in range(1, w):
        lt = lt | (eq & (row_a[..., c] < row_b[..., c]))
        eq = eq & (row_a[..., c] == row_b[..., c])
    return lt


class SortedCoords:
    """Sorted coordinate table answering batched exact-match queries."""

    def __init__(self, coords: jax.Array, valid_mask: jax.Array):
        big = jnp.int32(jnp.iinfo(jnp.int32).max)
        words = jnp.where(valid_mask[:, None], coords.astype(jnp.int32), big)
        self.order = lex_argsort(words)
        self.sorted_words = words[self.order]
        self.n = coords.shape[0]

    def lookup(self, query_coords: jax.Array) -> jax.Array:
        """Index of each query row in the original array, or -1 if absent."""
        q = query_coords.astype(jnp.int32)
        m = q.shape[0]
        lo = jnp.zeros((m,), jnp.int32)
        hi = jnp.full((m,), self.n, jnp.int32)
        iters = max(1, math.ceil(math.log2(max(self.n, 2))) + 1)
        for _ in range(iters):
            mid = (lo + hi) // 2
            mid_rows = self.sorted_words[jnp.clip(mid, 0, self.n - 1)]
            less = _lex_less(mid_rows, q)
            lo = jnp.where(less, mid + 1, lo)
            hi = jnp.where(less, hi, mid)
        pos = jnp.clip(lo, 0, self.n - 1)
        hit = rows_equal(self.sorted_words[pos], q)
        return jnp.where(hit, self.order[pos], -1).astype(jnp.int32)
