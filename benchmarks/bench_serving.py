"""Serving-engine throughput/latency: bucketed batching + map reuse.

The production question behind the ROADMAP north star: what does the sparse
stack sustain under mixed-size request traffic?  For each arch
(MinkUNet-KITTI segmentation, CenterPoint-Waymo detection) this suite
drives the same synthetic stream through:

* ``batched``   — the serving engine with its bucket ladder (warm, jitted);
* ``unbatched`` — the same engine restricted to one scene per batch
  (the "per-request forward" a naive deployment does);
* ``repeat``    — the stream replayed through the warm engine: identical
  packed batches hit the cross-request map cache, so the second epoch skips
  kernel-map construction entirely (hit rate in the derived column);
* ``pipelined``  — the same warm stream through a depth-2 double-buffered
  engine vs the serial (depth-1) engine, interleaved epochs: host
  scene-build/compose/pack of batch k+1 overlaps device execution of batch
  k, reported with the overlap fraction from ``summary()['pipeline']``;
* ``plan_compose`` — the executor-input composition in isolation: batch
  ``_maps_for`` (kernel maps + ``SplitPlan``s for a pallas implicit-GEMM
  assignment) under the composed strategy (host-side merge of cached
  per-scene orders) vs the jitted fallback that argsorts per batch;
* ``saturated``  — overload with a deadline: requests arrive faster than
  they are served against a deadline ≈ 3× the warm batch service time.
  Run twice — legacy age-based flushing, then deadline-aware admission
  (``deadline_margin``), which flushes early enough that service completes
  inside the budget; the two SLO miss rates are the contract;
* ``sharded``   — with ``--devices N`` (or several visible jax devices):
  the replayed stream through a ``DeviceRouter`` sharding the same ladder
  over N devices vs the single-device engine.  CPU CI uses host-platform
  virtual devices (``XLA_FLAGS=--xla_force_host_platform_device_count=N``);
  on one shared CPU the speedup is pipelining (one worker's host packing
  overlapping another's compute), on real accelerators it is parallelism.

Emits scenes/s and p50/p95 per-scene latency.  ``--tiny`` shrinks the
stream and ladder for CI smoke coverage.
"""
from __future__ import annotations

import argparse
import statistics
import time

import jax

from benchmarks import common
from repro import obs
from repro.serve.bucketing import BucketLadder
from repro.serve.engine import ARCHS, Engine, EngineStats
from repro.serve.router import DeviceRouter
from repro.serve.workload import lidar_stream


def _ms(v) -> str:
    """Derived-column formatting for maybe-None millisecond stats."""
    return "none" if v is None else f"{v:.1f}"


def _emit_phases(arch: str, tag: str, s: dict) -> None:
    """One row per recorded phase (median µs) — the per-phase trend lines
    check_regression.py gates on."""
    for name, ph in s.get("phases", {}).items():
        if ph["p50_ms"] is None:
            continue
        common.emit(f"serving/{arch}/{tag}/phase/{name}",
                    ph["p50_ms"] * 1e3,
                    f"count={ph['count']};p95_ms={_ms(ph['p95_ms'])}")


def _drive(arch: str, scenes, bound: int, ladder: BucketLadder,
           flush_every: int, tag: str, epochs: int = 1):
    eng = Engine(arch, ladder=ladder, spatial_bound=bound)
    eng.warmup()
    eng.stats = EngineStats()   # steady state only: warmup compiles excluded,
    for _ in range(epochs):     # so recompiles should stay 0
        eng.serve(scenes, flush_every=flush_every)
    s = eng.stats.summary()
    mc = s["map_cache"]
    hit_rate = mc["hits"] / max(mc["hits"] + mc["misses"], 1)
    derived = (f"scenes_per_s={s['scenes_per_s']:.2f};p95_ms={_ms(s['p95_ms'])};"
               f"recompiles={sum(s['recompiles'].values())};"
               f"map_hit_rate={hit_rate:.2f}")
    common.emit(f"serving/{arch}/{tag}/p50", (s["p50_ms"] or 0.0) * 1e3,
                derived)
    if tag == "batched":
        _emit_phases(arch, tag, s)
    return s


def _pipelined_leg(arch: str, scenes, bound: int, ladder: BucketLadder,
                   reps: int):
    """Warm replayed stream, depth-2 pipelined engine vs the serial
    (depth-1) engine — interleaved alternating-order epochs, best-of
    timing.  The two engines run the identical workload, so scheduler
    noise is strictly additive and the min is the clean estimate of each
    path's cost (medians at this epoch length still wobble a few percent
    either way on a loaded core).  Each epoch submits the full stream and
    flushes once, so every flush holds several groups for the in-flight
    window to overlap."""
    serial = Engine(arch, ladder=ladder, spatial_bound=bound, max_inflight=1)
    pipe = Engine(arch, ladder=ladder, spatial_bound=bound, max_inflight=2)
    for eng in (serial, pipe):
        eng.warmup()
        eng.serve(scenes, flush_every=0)        # warm maps/digests
        eng.stats = EngineStats()
    s_times, p_times = [], []
    for rep in range(max(reps, 11)):
        # alternate within-pair order so frequency/cache drift across the
        # run cancels out of the pair
        pair = ((serial, s_times), (pipe, p_times))
        for eng, sink in (pair if rep % 2 == 0 else pair[::-1]):
            t0 = time.perf_counter()
            eng.serve(scenes, flush_every=0)
            sink.append(time.perf_counter() - t0)
    n = len(scenes)
    s_sps = n / min(s_times)
    p_sps = n / min(p_times)
    ratio = p_sps / s_sps
    s = pipe.stats.summary()
    pl = s["pipeline"]
    common.emit(
        f"serving/{arch}/pipelined/epoch",
        min(p_times) * 1e6,
        f"scenes_per_s={p_sps:.2f};serial_scenes_per_s={s_sps:.2f};"
        f"overlap_frac={pl['overlap_frac']:.2f};"
        f"inflight_peak={pl['inflight_peak']};"
        f"recompiles={sum(s['recompiles'].values())}")
    common.emit(f"serving/{arch}/pipelined_vs_serial", 0.0,
                f"throughput_ratio={ratio:.2f}x;"
                f"overlap_s={pl['overlap_s']:.3f};"
                f"device_busy_s={pl['device_busy_s']:.3f}")
    _emit_phases(arch, "pipelined", s)
    _emit_phases(arch, "serial", serial.stats.summary())


def _plan_compose_leg(arch: str, scenes, bound: int, ladder: BucketLadder,
                      reps: int):
    """The executor-input composition in isolation: per-batch ``SplitPlan``
    build for a pallas implicit-GEMM assignment, merge-composing the cached
    per-scene stable orders on the host vs the jitted builder that re-runs
    the bitmask argsorts on every batch.  Same composed batch maps for
    both; plans are built, never executed, so the leg runs everywhere."""
    from repro.core import dataflows as df
    from repro.core.kmap import compose_kmaps, compose_split_plans
    from repro.core.sparse_conv import TrainDataflowConfig
    from repro.serve.plans import PlanRegistry

    reg = PlanRegistry()
    reg.set(arch, {(1, 3, "sub"): TrainDataflowConfig.bind_all(
        df.DataflowConfig("implicit_gemm", n_splits=2, backend="pallas"))})
    eng = Engine(arch, ladder=ladder, spatial_bound=bound, plans=reg,
                 map_strategy="composed")
    specs = eng.nplan.split_plan_specs()
    assert specs, "pallas igemm assignment lost"
    # first bucket-fitting FIFO group, exactly as a flush would form it
    group_idx = eng.batcher.plan([s.num_points for s in scenes])[0]
    group = [scenes[i] for i in group_idx]
    batch = eng.batcher.pack(group)
    entries = [eng._scene_entry(s) for s in group]
    maps = compose_kmaps(entries, batch.bucket)
    builder = eng._plan_builder_for(batch.bucket)

    def composed():
        return [compose_split_plans(entries, ref, ns, srt, batch.bucket)
                for ref, ns, srt in specs]

    def jitted():
        return builder(maps)

    jax.block_until_ready(jax.tree.leaves(composed()))  # warm: caches the
    jax.block_until_ready(jax.tree.leaves(jitted()))    # runs / the trace
    # interleaved best-of (timeit convention): both builders are a few
    # hundred µs, where scheduler noise is strictly additive — the min is
    # the clean measurement, and interleaving exposes both paths to the
    # same machine state
    t = {"composed": [], "jitted": []}
    for _ in range(max(reps, 15)):
        for tag, fn in (("composed", composed), ("jitted", jitted)):
            t0 = time.perf_counter()
            jax.block_until_ready(jax.tree.leaves(fn()))
            t[tag].append(time.perf_counter() - t0)
    times = {tag: min(v) for tag, v in t.items()}
    common.emit(
        f"serving/{arch}/plan_compose/batch", times["composed"] * 1e6,
        f"jitted_us={times['jitted'] * 1e6:.1f};"
        f"speedup={times['jitted'] / max(times['composed'], 1e-12):.2f}x;"
        f"specs={len(specs)}")


def _pallas_leg(arch: str, scenes, bound: int, ladder: BucketLadder,
                reps: int):
    """The Pallas kernel tier vs the XLA dataflow on one packed batch's
    stem layer: dense-grid implicit GEMM and the tile-skipping worklist
    variant, with the *effective* backend of each config in the derived
    column.  On CPU containers the Pallas numbers are interpret-mode
    (kernel logic under the Pallas interpreter — orders slower than XLA,
    and the ratio is informational only); the leg's job in CI is to pin
    the tier as measurable and bit-exact everywhere, so the same sweep
    reports real MXU ratios the day it lands on a TPU."""
    from repro.core import dataflows as df
    from repro.kernels.common import default_interpret

    eng = Engine(arch, ladder=ladder, spatial_bound=bound)
    group = eng.batcher.plan([s.num_points for s in scenes])[0]
    gs = [scenes[i] for i in group]
    batch = eng.batcher.pack(gs)
    maps, _ = eng._maps_for(batch, gs)
    lp = eng.nplan.layers[0]
    kmap = maps[lp.map_ref]
    w = eng.params[lp.name]["w"]
    x = batch.st.feats
    tm = 16 if default_interpret() else 128
    cfgs = {
        "xla": df.DataflowConfig("implicit_gemm", n_splits=1),
        "pallas": df.DataflowConfig("implicit_gemm", n_splits=1,
                                    backend="pallas", tile_m=tm),
        "pallas_worklist": df.DataflowConfig("implicit_gemm", n_splits=1,
                                             backend="pallas", tile_m=tm,
                                             worklist=True),
    }
    times = {}
    for tag, cfg in cfgs.items():
        plan = df.plan_for(kmap, cfg)   # eager: worklist needs concrete occ
        call = lambda cfg=cfg, plan=plan: df.sparse_conv_forward(
            x, w, kmap, cfg, plan=plan)
        fn = call if cfg.worklist else jax.jit(call)
        times[tag] = common.time_fn(fn, warmup=1, iters=reps)
        common.emit(f"serving/{arch}/kernel_tier/{tag}", times[tag],
                    f"effective_backend={cfg.effective_backend('fwd')}")
    common.emit(
        f"serving/{arch}/kernel_tier_ratio", 0.0,
        f"pallas_vs_xla={times['xla'] / max(times['pallas'], 1e-9):.2f}x;"
        f"worklist_vs_dense="
        f"{times['pallas'] / max(times['pallas_worklist'], 1e-9):.2f}x;"
        f"interpret={default_interpret()}")


def _drive_deadline(eng: Engine, scenes, deadline_ms: float) -> dict:
    """Poll-driven overload: arrivals every 0.25×deadline, so the queue
    always holds work while a batch is in service and every flush is
    deadline-triggered (no flush_count, no manual flush)."""
    results = {}
    gap_s = 0.25 * deadline_ms / 1e3
    for s in scenes:
        eng.submit(s)
        t_end = time.perf_counter() + gap_s
        while time.perf_counter() < t_end:
            results.update(eng.poll())
            time.sleep(0.02 * deadline_ms / 1e3)
    while len(results) < len(scenes):
        results.update(eng.poll())
        time.sleep(0.05 * deadline_ms / 1e3)
    return results


def _saturating_leg(arch: str, scenes, bound: int, ladder: BucketLadder):
    """Overload against an *achievable* deadline (≈3× the warm batch
    service time), twice: legacy age-based flushing first — the head
    request starts service only once its whole budget is spent, so adding
    service time blows the SLO — then deadline-aware admission
    (``deadline_margin``), which subtracts predicted service from the
    budget and cuts batches for about-to-expire heads.  The pair of miss
    rates is the acceptance contract (aware < legacy)."""
    stats = {}
    for tag, margin in (("saturated", None), ("saturated_margin", 1.5)):
        eng = Engine(arch, ladder=ladder, spatial_bound=bound,
                     deadline_margin=margin)
        eng.warmup()
        eng.serve(scenes, flush_every=0)        # warm maps + phase windows
        deadline_ms = 3.0 * eng._predicted_service_ms()
        eng.max_wait_ms = deadline_ms           # SLO armed after warm-in
        n0, m0 = eng.stats.slo_measured, eng.stats.slo_miss_count
        results = _drive_deadline(eng, scenes, deadline_ms)
        assert len(results) == len(scenes)
        s = eng.stats.summary()
        measured = eng.stats.slo_measured - n0
        misses = eng.stats.slo_miss_count - m0
        miss_rate = misses / max(measured, 1)
        stats[tag] = miss_rate
        common.emit(
            f"serving/{arch}/{tag}/p95",
            (s["p95_ms"] or 0.0) * 1e3,
            f"scenes_per_s={s['scenes_per_s']:.2f};"
            f"slo_deadline_ms={deadline_ms:.1f};"
            f"slo_miss_rate={miss_rate:.2f};"
            f"slo_misses={misses};slo_measured={measured};"
            f"deadline_flushes={s['deadline_flushes']};"
            f"deadline_cuts={s['deadline_cuts']}")
    common.emit(f"serving/{arch}/saturated_margin_vs_legacy", 0.0,
                f"legacy_miss_rate={stats['saturated']:.2f};"
                f"aware_miss_rate={stats['saturated_margin']:.2f}")
    return stats


def _sharded_leg(arch: str, scenes, bound: int, ladder: BucketLadder,
                 n_dev: int, reps: int):
    """Replayed-stream throughput, DeviceRouter over ``n_dev`` devices vs
    the single-device engine at the SAME serving config.

    Both variants are co-resident and their replay epochs interleave
    (engine, router, engine, router, …) with the ratio taken over medians —
    the same drift-cancelling protocol bench_streaming uses; sequential
    whole-variant timing on a shared CPU box swung ±2× run to run.  Each
    epoch submits the full stream and flushes once, so every batch in the
    queue is a routable unit.
    """
    eng = Engine(arch, ladder=ladder, spatial_bound=bound)
    rt = DeviceRouter(arch, devices=n_dev, ladder=ladder, spatial_bound=bound)
    eng.warmup()
    rt.warmup()
    eng.serve(scenes, flush_every=0)    # warm-in replay: scene builds,
    rt.serve(scenes, flush_every=0)     # digest caches, routing state
    eng.stats = EngineStats()           # steady state only below: reported
    for w in rt.workers:                # recompiles/routed_batches cover the
        w.stats = EngineStats()         # measured epochs, not warmup
    rt.stats.busy_s, rt.stats.flushes = 0.0, 0
    rt.stats.route_log.clear()
    e_times, r_times = [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        eng.serve(scenes, flush_every=0)
        e_times.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        rt.serve(scenes, flush_every=0)
        r_times.append(time.perf_counter() - t0)
    n = len(scenes)
    e_sps = n / statistics.median(e_times)
    r_sps = n / statistics.median(r_times)
    s = rt.stats.summary()
    routed = ",".join(str(d["routed_batches"]) for d in s["devices"].values())
    common.emit(
        f"serving/{arch}/sharded_d{n_dev}/epoch",
        statistics.median(r_times) * 1e6,
        f"scenes_per_s={r_sps:.2f};single_scenes_per_s={e_sps:.2f};"
        f"recompiles={sum(s['recompiles'].values())};routed_batches={routed}")
    common.emit(f"serving/{arch}/sharded_vs_single", 0.0,
                f"throughput_ratio={r_sps / e_sps:.2f}x;devices={n_dev}")


def run_fleet(tiny: bool = False, hosts: int = 2):
    """Fleet-tier leg (its own suite — it spawns worker *processes*): the
    same replayed warm stream through ``FleetFrontend`` over N localhost
    workers vs a ``DeviceRouter`` vs the single-device ``Engine``, all at
    the same ``ServiceConfig``.  Epochs interleave across the three tiers
    (drift-cancelling, as in the sharded leg) and the fleet results are
    asserted bit-identical to the engine's before any timing is reported —
    the RPC boundary must not change a single row.  On one localhost box
    the fleet ratio prices the wire codec + socket hop; across real hosts
    the same rows measure scale-out."""
    import numpy as np

    from repro.serve.fleet import FleetFrontend
    from repro.serve.service import ServiceConfig

    arch = "minkunet_kitti"
    if tiny:
        count, n_range = 6, (80, 400)
        ladder = BucketLadder((256, 512), max_batch=3)
        reps = 5
    else:
        count, n_range = 24, (200, 1200)
        ladder = BucketLadder((512, 1024, 2048), max_batch=4)
        reps = 3
    channels = ARCHS[arch].in_channels_of(ARCHS[arch].default_config)
    scenes, bound = lidar_stream(0, count, channels, n_range=n_range)
    cfg = ServiceConfig.from_ladder(ladder, spatial_bound=bound)
    eng = Engine(arch, config=cfg)
    rt = DeviceRouter(arch, devices=jax.device_count(), config=cfg)
    fl = FleetFrontend(arch, hosts=hosts, config=cfg)
    try:
        warm = {}
        for tag, svc in (("engine", eng), ("router", rt), ("fleet", fl)):
            svc.warmup()
            warm[tag] = svc.serve(scenes, flush_every=0)
        for a, b in zip(warm["fleet"], warm["engine"]):
            np.testing.assert_array_equal(a.coords, b.coords)
            np.testing.assert_array_equal(a.feats, b.feats)
        times = {"engine": [], "router": [], "fleet": []}
        for _ in range(reps):
            for tag, svc in (("engine", eng), ("router", rt), ("fleet", fl)):
                t0 = time.perf_counter()
                svc.serve(scenes, flush_every=0)
                times[tag].append(time.perf_counter() - t0)
        n = len(scenes)
        sps = {tag: n / statistics.median(v) for tag, v in times.items()}
        s = fl.stats.summary()
        common.emit(
            f"serving/{arch}/fleet_h{hosts}/epoch",
            statistics.median(times["fleet"]) * 1e6,
            f"scenes_per_s={sps['fleet']:.2f};"
            f"router_scenes_per_s={sps['router']:.2f};"
            f"engine_scenes_per_s={sps['engine']:.2f};"
            f"schema_version={s['schema_version']};"
            f"live_hosts={s['fleet']['live']};"
            f"failovers={s['fleet']['failovers']}")
        common.emit(
            f"serving/{arch}/fleet_vs_router_vs_engine", 0.0,
            f"fleet_vs_engine={sps['fleet'] / sps['engine']:.2f}x;"
            f"fleet_vs_router={sps['fleet'] / sps['router']:.2f}x;"
            f"hosts={hosts};bit_identical=True")
        _emit_phases(arch, f"fleet_h{hosts}", s)
    finally:
        fl.close()


def run(tiny: bool = False, devices: int = 0):
    if tiny:
        count, n_range, ladder = 6, (80, 400), BucketLadder((256, 512), max_batch=3)
        flush_every = 3
    else:
        count, n_range = 24, (200, 1200)
        ladder = BucketLadder((512, 1024, 2048), max_batch=4)
        flush_every = 8

    for arch in sorted(ARCHS):
        channels = ARCHS[arch].in_channels_of(ARCHS[arch].default_config)
        scenes, bound = lidar_stream(0, count, channels, n_range=n_range)
        batched = _drive(arch, scenes, bound, ladder, flush_every, "batched")
        single = BucketLadder(ladder.capacities, max_batch=1)
        unbatched = _drive(arch, scenes, bound, single, 1, "unbatched")
        speedup = (batched["scenes_per_s"] /
                   max(unbatched["scenes_per_s"], 1e-9))
        common.emit(f"serving/{arch}/batched_vs_unbatched", 0.0,
                    f"throughput_ratio={speedup:.2f}x")

        _drive(arch, scenes, bound, ladder, flush_every, "repeat", epochs=2)

        _pipelined_leg(arch, scenes, bound, ladder, reps=17 if tiny else 7)
        _plan_compose_leg(arch, scenes, bound, ladder, reps=7 if tiny else 5)
        _pallas_leg(arch, scenes, bound, ladder, reps=2 if tiny else 3)

        _saturating_leg(arch, scenes, bound, ladder)

        n_dev = devices if devices else jax.device_count()
        if n_dev > 1:
            if jax.device_count() < n_dev:
                raise RuntimeError(
                    f"--devices {n_dev} needs XLA_FLAGS="
                    f"--xla_force_host_platform_device_count={n_dev}")
            # the sharded leg replays the stream in the warm-traffic regime
            # the router targets (maps cached, executors hot), one scene
            # per batch: the batch is the routing granularity, so this is
            # the request-parallel deployment a device fleet serves
            _sharded_leg(arch, scenes, bound, single, n_dev,
                         reps=5 if tiny else 3)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="reduced stream for CI smoke runs")
    ap.add_argument("--devices", type=int, default=0,
                    help="run the sharded leg across N devices "
                         "(0 = every visible device; sharded leg is skipped "
                         "when only one is attached)")
    ap.add_argument("--trace", default=None, metavar="OUT",
                    help="trace the benchmark run: Chrome trace-event JSON "
                         "(Perfetto) or .jsonl event log")
    args = ap.parse_args()
    if args.trace:
        obs.enable()
    print("name,us_per_call,derived")
    run(tiny=args.tiny, devices=args.devices)
    if args.trace:
        path = obs.export(obs.get_tracer(), args.trace)
        snap = obs.get_tracer().snapshot()
        print(f"# trace: {snap['spans']} spans + {snap['events']} events "
              f"-> {path}")
