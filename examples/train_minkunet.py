"""End-to-end driver: train a MinkUNet segmentation model on synthetic
LiDAR scenes for a few hundred steps, with the full production substrate —
AdamW, grad clipping, async checkpointing, resume, straggler watchdog —
executing through a compiled ``core.plan.NetworkPlan``.

    PYTHONPATH=src python examples/train_minkunet.py --steps 300 --width 1.0
    PYTHONPATH=src python examples/train_minkunet.py --precision bf16

(~100M-param model at --width 2.6; the default keeps CPU runtime sane.
``--precision bf16`` runs the paper's mixed-precision recipe: bf16 conv
params/activations, fp32 accumulation, fp32 master weights in AdamW.)
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core import dataflows as df
from repro.core import precision as prec
from repro.core.sparse_conv import TrainDataflowConfig
from repro.data.synthetic import lidar_scene
from repro.models import minkunet
from repro.train import optimizer as opt
from repro.train.loop import LoopConfig, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--width", type=float, default=0.25)
    ap.add_argument("--points", type=int, default=1500)
    ap.add_argument("--capacity", type=int, default=2048)
    ap.add_argument("--classes", type=int, default=19)
    ap.add_argument("--ckpt-dir", default="/tmp/minkunet_ckpt")
    ap.add_argument("--dataflow", default="implicit_gemm", choices=df.DATAFLOWS)
    ap.add_argument("--precision", default="fp32", choices=sorted(prec.POLICIES),
                    help="numeric policy: fp32, or bf16 (bf16 compute / fp32 "
                         "accumulate / fp32 master weights)")
    args = ap.parse_args()

    cfg = minkunet.MinkUNetConfig(in_channels=4, num_classes=args.classes,
                                  width=args.width, blocks_per_stage=1)
    policy = prec.POLICIES[args.precision]
    nplan = minkunet.network_plan(cfg, precision=policy)
    nplan = nplan.with_assignment(
        {lp.sig: TrainDataflowConfig.bind_all(df.DataflowConfig(args.dataflow))
         for lp in nplan.layers})
    params = nplan.cast_params(minkunet.init_params(cfg, jax.random.PRNGKey(0)))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"MinkUNet width={args.width}: {n_params / 1e6:.1f}M params "
          f"({args.precision}, master_weights={policy.master_weights})")

    ocfg = opt.AdamWConfig(lr=2e-3, weight_decay=0.01,
                           master_weights=policy.master_weights)
    state = opt.init_opt_state(params, ocfg)

    def data():
        i = 0
        while True:
            st = lidar_scene(jax.random.PRNGKey(i), args.points, args.capacity,
                             4, extent=40.0, voxel=0.5)
            # synthetic labels: height-band segmentation (learnable signal)
            z = st.coords[:, 3]
            labels = jnp.clip(z // 2, 0, args.classes - 1).astype(jnp.int32)
            yield {"scene": st, "labels": labels}
            i += 1

    @jax.jit
    def step(params, state, batch):
        st, labels = batch["scene"], batch["labels"]

        def loss_fn(p):
            lg = nplan.apply(p, st).astype(jnp.float32)
            ls = jax.nn.log_softmax(lg)[jnp.arange(st.capacity), labels]
            return -jnp.sum(jnp.where(st.valid_mask, ls, 0)) / jnp.maximum(st.num_valid, 1)

        l, g = jax.value_and_grad(loss_fn)(params)
        p2, s2, gn = opt.adamw_update(params, g, state, ocfg)
        return p2, s2, {"loss": l, "grad_norm": gn}

    lcfg = LoopConfig(total_steps=args.steps, ckpt_every=50,
                      ckpt_dir=args.ckpt_dir, log_every=10)
    params, state, report = train_loop(step, params, state, data(), lcfg)
    print(f"finished {report.steps_run} steps "
          f"(resumed_from={report.resumed_from}); final {report.last_metrics}")


if __name__ == "__main__":
    main()
