"""Pipelined serving (double-buffered flushes): bit-identity to the serial
path on mixed-size and streaming-delta streams, bounded in-flight depth,
trace-visible host/device overlap, and the composed executor inputs —
merge-composed ``SplitPlan``s and the incremental down-ladder — matching
their from-scratch builds bit-for-bit."""
import numpy as np
import pytest

from conftest import property_test

from repro import obs
from repro.core import dataflows as df
from repro.core import hashing
from repro.core.kmap import (cell_ladder, cell_ladder_delta,
                             compose_split_plans, ladder_tables,
                             make_split_plan)
from repro.core.sparse_conv import TrainDataflowConfig
from repro.serve import BucketLadder, Engine, PlanRegistry, Scene
from repro.serve.workload import churned_stream


@pytest.fixture(autouse=True)
def _tracer_off():
    obs.disable()
    yield
    obs.disable()


def _mk_scene(n, channels, seed, bound=60):
    rng = np.random.default_rng(seed)
    coords = np.unique(rng.integers(-bound, bound, size=(n, 3),
                                    dtype=np.int32), axis=0)
    return Scene(coords=coords,
                 feats=rng.normal(size=(coords.shape[0], channels)).astype(np.float32))


def _engine(max_inflight, **kw):
    kw.setdefault("ladder", BucketLadder((256, 512), max_batch=2))
    return Engine("minkunet_kitti", spatial_bound=64,
                  max_inflight=max_inflight, **kw)


# ------------------------------------------------------- bit-identity

@property_test(
    "sizes,seed",
    cases=[((50, 120, 30, 200, 80, 60), 0),
           ((40, 45, 240, 10, 90, 200, 35), 1),
           ((200, 30, 150, 60, 20), 2)],
    strategies=lambda st: {
        "sizes": st.lists(st.integers(min_value=10, max_value=250),
                          min_size=3, max_size=8).map(tuple),
        "seed": st.integers(min_value=0, max_value=2**16)},
    max_examples=5)
def test_pipelined_bit_identical_to_serial_mixed_sizes(sizes, seed):
    """The tentpole contract: only the position of block_until_ready moves,
    so a depth-3 pipeline serves exactly the bits of the depth-1 (serial)
    engine on the same mixed-size stream — same params, same grouping, same
    ≤1-executor-compile-per-rung bound."""
    serial, pipe = _engine(1), _engine(3)
    scenes = [_mk_scene(n, 4, seed=seed * 1000 + i)
              for i, n in enumerate(sizes)]
    r0 = serial.serve(scenes)           # one flush at the end → many groups
    r1 = pipe.serve(scenes)
    assert serial.stats.inflight_peak == 1
    for a, b in zip(r0, r1):
        np.testing.assert_array_equal(a.coords, b.coords)
        assert a.feats.dtype == b.feats.dtype
        np.testing.assert_array_equal(a.feats, b.feats)   # bit-identical
    # pipelining never costs extra traces
    assert pipe.stats.recompiles == serial.stats.recompiles
    assert all(n == 1 for n in pipe.stats.recompiles.values())


def test_pipelined_bit_identical_on_streaming_deltas():
    """Same contract under the incremental strategy: delta-merged frames
    composed into pipelined batches equal the serial engine's outputs."""
    kw = dict(ladder=BucketLadder((512,), max_batch=2), spatial_bound=64,
              map_strategy="incremental")
    serial = Engine("centerpoint_waymo", max_inflight=1, **kw)
    pipe = Engine("centerpoint_waymo", max_inflight=2, **kw)
    frames, bound = churned_stream(7, streams=4, frames=3, channels=5,
                                   n_range=(40, 80), extent=16.0, voxel=0.4)
    assert bound <= 64
    for frame in frames:
        tickets = []
        for sid, scene, delta in frame:
            for eng in (serial, pipe):
                if delta is not None:
                    t = eng.submit_delta(sid, delta)
                else:
                    t = eng.submit(scene, stream=sid)
            tickets.append(t)           # same submission order → same tickets
        out_s, out_p = serial.flush(), pipe.flush()
        for t in tickets:
            np.testing.assert_array_equal(out_s[t].coords, out_p[t].coords)
            np.testing.assert_array_equal(out_s[t].feats, out_p[t].feats)
    assert serial.stats.delta_merges > 0 and pipe.stats.delta_merges > 0
    assert pipe.stats.inflight_peak == 2


# ------------------------------------------------- depth bound + overlap

def test_inflight_window_bounded_by_max_inflight():
    """Never more than ``max_inflight`` dispatched-but-undrained batches,
    and the window actually fills when the stream is deep enough."""
    eng = _engine(2, ladder=BucketLadder((256,), max_batch=1))
    scenes = [_mk_scene(60, 4, seed=i) for i in range(6)]   # 6 groups
    eng.serve(scenes)
    assert eng.stats.inflight_peak == 2
    s = eng.stats.summary()["pipeline"]
    assert s["inflight_peak"] == 2
    assert s["host_busy_s"] > 0 and s["device_busy_s"] > 0
    assert 0.0 <= s["overlap_frac"] <= 1.0


def test_overlap_host_spans_inside_prior_execute_span():
    """Trace evidence of the double-buffer: batch k+1's host-side pack/map
    spans are time-contained within the device ``execute`` span of batch k
    (the execute span runs dispatch-return → drain-ready, and the window
    only drains after the next dispatch when depth permits)."""
    tr = obs.enable()
    try:
        eng = _engine(2, ladder=BucketLadder((256,), max_batch=1))
        eng.serve([_mk_scene(60, 4, seed=10 + i) for i in range(4)])
    finally:
        obs.disable()
    execs = [s for s in tr.spans() if s.name == "execute"]
    hosts = [s for s in tr.spans() if s.name in ("pack", "map")]
    assert execs and hosts
    contained = [(e, h) for e in execs for h in hosts
                 if e.t0_ns < h.t0_ns and h.t1_ns <= e.t1_ns]
    # strict <: batch k's own pack/map end before its dispatch returns, so
    # any contained host span belongs to a *later* batch
    assert contained, "no host span overlapped a device execute span"


def test_serial_depth_one_reproduces_legacy_span_order():
    """max_inflight=1 is the serial engine: every batch drains before the
    next dispatch, so no host span can sit inside a foreign execute span."""
    tr = obs.enable()
    try:
        eng = _engine(1, ladder=BucketLadder((256,), max_batch=1))
        eng.serve([_mk_scene(60, 4, seed=20 + i) for i in range(3)])
    finally:
        obs.disable()
    execs = [s for s in tr.spans() if s.name == "execute"]
    hosts = [s for s in tr.spans() if s.name in ("pack", "map")]
    assert not [(e, h) for e in execs for h in hosts
                if e.t0_ns < h.t0_ns and h.t1_ns <= e.t1_ns]


# ------------------------------------------- composed executor inputs

def _pallas_igemm_engine(n_splits, map_strategy, tmp_path):
    reg = PlanRegistry()
    assignment = {(1, 3, "sub"): TrainDataflowConfig.bind_all(
        df.DataflowConfig("implicit_gemm", n_splits=n_splits,
                          backend="pallas"))}
    reg.set("minkunet_kitti", assignment)
    path = reg.save(str(tmp_path / "plans.json"))
    return Engine("minkunet_kitti", ladder=BucketLadder((256, 512),
                                                        max_batch=3),
                  spatial_bound=64, plans=path, map_strategy=map_strategy)


@pytest.mark.parametrize("n_splits", [1, 2, 4])
def test_composed_split_plans_match_jitted_build(n_splits, tmp_path):
    """compose_split_plans (host-side merge of cached per-scene stable
    orders) is bit-identical to make_split_plan on the composed batch map —
    the per-batch argsort leaves the hot path without changing a bit."""
    eng = _pallas_igemm_engine(n_splits, "composed", tmp_path)
    specs = eng.nplan.split_plan_specs()
    assert specs and all(ns == n_splits and srt for _, ns, srt in specs)
    scenes = [_mk_scene(n, 4, seed=30 + n) for n in (50, 120, 80)]
    batch = eng.batcher.pack(scenes)
    maps, plans = eng._maps_for(batch, scenes)
    assert eng.stats.composed_batches == 1
    assert set(plans) == {(ref, ns, srt) for ref, ns, srt in specs}
    for (ref, ns, srt), sp in plans.items():
        ref_sp = make_split_plan(maps[ref], ns, sort=srt)
        assert sp.ranges == ref_sp.ranges and sp.sorted_ == ref_sp.sorted_
        np.testing.assert_array_equal(np.asarray(sp.order),
                                      np.asarray(ref_sp.order))
        np.testing.assert_array_equal(np.asarray(sp.inv_order),
                                      np.asarray(ref_sp.inv_order))
    # replay: whole-batch cache returns the identical (maps, plans) pair
    maps2, plans2 = eng._maps_for(eng.batcher.pack(scenes), scenes)
    assert plans2 is plans and eng.stats.map_hits == 1
    # composition is pure host work: no plan-builder traces
    assert eng.stats.plan_compiles == {}


def test_fallback_plan_builder_traces_once_per_rung(tmp_path):
    """The cold path ("sort" strategy) builds plans jitted next to the maps:
    one plan-builder trace per rung, counted separately so the exact
    map-compile contracts stay intact."""
    eng = _pallas_igemm_engine(2, "sort", tmp_path)
    scenes = [_mk_scene(n, 4, seed=40 + n) for n in (50, 120)]
    batch = eng.batcher.pack(scenes)
    maps, plans = eng._maps_for(batch, scenes)
    assert set(plans) == set(
        (ref, ns, srt) for ref, ns, srt in eng.nplan.split_plan_specs())
    assert eng.stats.plan_compiles == {256: 1}
    for (ref, ns, srt), sp in plans.items():
        ref_sp = make_split_plan(maps[ref], ns, sort=srt)
        np.testing.assert_array_equal(np.asarray(sp.order),
                                      np.asarray(ref_sp.order))
    # a second distinct batch at the same rung reuses the traced builder
    more = [_mk_scene(n, 4, seed=50 + n) for n in (60, 110)]
    eng._maps_for(eng.batcher.pack(more), more)
    assert eng.stats.plan_compiles == {256: 1}


# ------------------------------------------- incremental down-ladder

def _packed_rows(spec, coords):
    rows = np.concatenate(
        [np.zeros((coords.shape[0], 1), np.int32), coords], axis=1)
    keys = hashing.np_pack_keys(rows, spec)
    order = (np.argsort(keys, kind="stable") if keys.ndim == 1
             else hashing.lex_argsort_np(keys))
    return keys[order]


def test_cell_ladder_delta_matches_fresh_derivation():
    """Propagating a root delta through the cell ladder yields exactly the
    ladder a fresh derivation of the merged cloud produces — per level the
    same sorted unique cells and the same per-cell occupancy counts."""
    rng = np.random.default_rng(3)
    pool = np.unique(rng.integers(-60, 60, size=(500, 3), dtype=np.int32),
                     axis=0)
    scene, added = pool[:300], pool[300:360]
    removed, kept = scene[:40], scene[40:]
    spec = hashing.key_spec_for(3, 4, 64)
    assert not spec.raw
    down = (2, 4, 8)
    lad0 = cell_ladder(spec, _packed_rows(spec, scene), down)
    assert set(lad0) == set(down)
    lad_delta = cell_ladder_delta(spec, lad0,
                                  _packed_rows(spec, removed),
                                  _packed_rows(spec, added))
    merged = np.concatenate([kept, added])
    lad_fresh = cell_ladder(spec, _packed_rows(spec, merged), down)
    for s in down:
        np.testing.assert_array_equal(lad_delta[s][0], lad_fresh[s][0])
        np.testing.assert_array_equal(lad_delta[s][1], lad_fresh[s][1])
        assert int(lad_fresh[s][1].sum()) == merged.shape[0]
    # unfolded adoption tables agree too (PAD-padded, sorted, exact n)
    t_d, t_f = (ladder_tables(spec, l, 512) for l in (lad_delta, lad_fresh))
    for s in down:
        np.testing.assert_array_equal(t_d[s][0], t_f[s][0])
        assert t_d[s][2] == t_f[s][2] == lad_fresh[s][0].shape[0]


def test_cell_ladder_counts_track_cells_exactly():
    """A cell leaves a level exactly when its last root row leaves: remove
    every row of one stride-8 cell and the delta ladder drops that cell."""
    rng = np.random.default_rng(11)
    scene = np.unique(rng.integers(-60, 60, size=(200, 3), dtype=np.int32),
                      axis=0)
    spec = hashing.key_spec_for(3, 4, 64)
    lad0 = cell_ladder(spec, _packed_rows(spec, scene), (8,))
    cell_of = scene >> 3                     # stride-8 grid cell per row
    target = cell_of[0]
    removed = scene[(cell_of == target).all(axis=1)]
    lad = cell_ladder_delta(spec, lad0, _packed_rows(spec, removed),
                            _packed_rows(spec, np.zeros((0, 3), np.int32)))
    assert lad[8][0].shape[0] == lad0[8][0].shape[0] - 1
    assert int(lad[8][1].sum()) == scene.shape[0] - removed.shape[0]
