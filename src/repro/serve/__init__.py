"""Sparse serving engine: bucketed dynamic batching, scene-granular and
streaming map reuse, persisted tuned plans, and the multi-device routed
tier (see engine.py and router.py for the architecture)."""
from repro.serve.batcher import (PackedBatch, Scene, SceneBatcher, SceneDelta,
                                 SceneResult, apply_delta, scene_from_tensor)
from repro.serve.bucketing import BucketLadder
from repro.serve.engine import ARCHS, Engine, EngineStats
from repro.serve.plans import PlanRegistry, device_key
from repro.serve.router import DeviceRouter, RouterStats

__all__ = ["ARCHS", "BucketLadder", "DeviceRouter", "Engine", "EngineStats",
           "PackedBatch", "PlanRegistry", "RouterStats", "Scene",
           "SceneBatcher", "SceneDelta", "SceneResult", "apply_delta",
           "device_key", "scene_from_tensor"]
