"""Pure-JAX optimizers (no optax in this environment).

AdamW with decoupled weight decay and global-norm clipping; mixed-precision
posture: params may be bf16 while the first/second moments — and, with
``master_weights=True``, an fp32 **master copy** of the parameters — stay
fp32.  The master copy is what makes the ``core.precision.BF16`` policy a
real training recipe rather than a forward-only cast: per-step updates are
routinely smaller than one bf16 ulp of the weight, so updating bf16 weights
in place silently drops them; instead the fp32 master accumulates the
update and the bf16 working copy is re-derived from it each step.  A
factored second-moment option (Adafactor-style) exists for the 1T-param
cells where full Adam state cannot fit the mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    factored: bool = False       # factored 2nd moment for giant models
    state_dtype: Any = jnp.float32
    master_weights: bool = False  # keep an fp32 master copy of (bf16) params


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def _factored_dims(shape):
    """Pick the two largest trailing dims for row/col factoring (≥2D only)."""
    if len(shape) < 2:
        return None
    return len(shape) - 2, len(shape) - 1


def init_opt_state(params, cfg: AdamWConfig):
    def per_leaf(p):
        if cfg.factored and _factored_dims(p.shape) is not None:
            r, c = _factored_dims(p.shape)
            vr = jnp.zeros(p.shape[:c] , cfg.state_dtype)           # reduce over c
            vc = jnp.zeros(p.shape[:r] + p.shape[r + 1:], cfg.state_dtype)  # reduce over r
            return {"m": jnp.zeros_like(p, cfg.state_dtype), "vr": vr, "vc": vc}
        return {"m": jnp.zeros_like(p, cfg.state_dtype),
                "v": jnp.zeros_like(p, cfg.state_dtype)}

    state = {"mu": jax.tree.map(per_leaf, params),
             "step": jnp.zeros((), jnp.int32)}
    if cfg.master_weights:
        state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return state


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """One AdamW step.  With ``cfg.master_weights`` the update applies to
    the fp32 master copy in ``state["master"]`` and the returned params are
    the master re-cast to the working dtype (bf16 under the mixed policy) —
    updates smaller than a bf16 ulp accumulate instead of vanishing."""
    step = state["step"] + 1
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def per_leaf(p, g, s, master=None):
        g32 = g.astype(jnp.float32)
        m = cfg.b1 * s["m"].astype(jnp.float32) + (1 - cfg.b1) * g32
        if "v" in s:
            v = cfg.b2 * s["v"].astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g32)
            vhat = v / b2c
            new_s = {"m": m.astype(cfg.state_dtype), "v": v.astype(cfg.state_dtype)}
        else:
            r, c = _factored_dims(p.shape)
            g2 = jnp.square(g32)
            vr = cfg.b2 * s["vr"].astype(jnp.float32) + (1 - cfg.b2) * jnp.mean(g2, axis=c)
            vc = cfg.b2 * s["vc"].astype(jnp.float32) + (1 - cfg.b2) * jnp.mean(g2, axis=r)
            denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), 1e-30)
            vhat = (jnp.expand_dims(vr, c) * jnp.expand_dims(vc, r)
                    / jnp.expand_dims(denom, r)) / b2c
            new_s = {"m": m.astype(cfg.state_dtype), "vr": vr.astype(cfg.state_dtype),
                     "vc": vc.astype(cfg.state_dtype)}
        ref = p.astype(jnp.float32) if master is None else master
        upd = (m / b1c) / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * ref
        new_master = ref - cfg.lr * upd
        return new_master.astype(p.dtype), new_s, new_master

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_s = tdef.flatten_up_to(state["mu"])
    flat_m = (tdef.flatten_up_to(state["master"]) if "master" in state
              else [None] * len(flat_p))
    out = [per_leaf(p, g, s, m) for p, g, s, m in zip(flat_p, flat_g, flat_s, flat_m)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    new_state = {"mu": new_mu, "step": step}
    if "master" in state:
        new_state["master"] = tdef.unflatten([o[2] for o in out])
    return new_params, new_state, gnorm


def opt_state_pspecs(param_pspecs, cfg: AdamWConfig):
    """Optimizer-state partition specs mirror the parameter specs."""
    from jax.sharding import PartitionSpec as P

    def per_leaf(spec):
        if cfg.factored:
            # best effort: factored leaves drop the reduced axis; replicate
            return {"m": spec, "vr": P(), "vc": P()}
        return {"m": spec, "v": spec}

    specs = {"mu": jax.tree.map(per_leaf, param_pspecs,
                                is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)),
             "step": jax.sharding.PartitionSpec()}
    if cfg.master_weights:
        specs["master"] = param_pspecs  # master copy shards like the params
    return specs
