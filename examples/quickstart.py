"""Quickstart: sparse convolution on a synthetic point cloud, three
dataflows, one autotuned hybrid.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import dataflows as df
from repro.core import hashing
from repro.core import kmap as km
from repro.core.autotuner import timeit_fn
from repro.data.synthetic import lidar_scene


def main():
    # 1. a LiDAR-like scene, voxelized into a capacity-padded SparseTensor.
    #    lidar_scene declares batch/spatial bounds on the tensor — the
    #    promise the mapping engine turns into a packed single-word key, so
    #    kernel-map construction below is a single argsort (not one stable
    #    sort per coordinate column).
    st = lidar_scene(jax.random.PRNGKey(0), n_points=2000, capacity=2048,
                     channels=16, extent=50.0, voxel=0.4)
    spec = hashing.key_spec_for(st.ndim_space, st.batch_bound, st.spatial_bound)
    print(f"scene: {int(st.num_valid)} voxels (capacity {st.capacity})")
    print(f"declared bounds: batch<{st.batch_bound}, |coord|<={st.spatial_bound} "
          f"-> {'raw multi-word' if spec.raw else f'{spec.words}-word packed'} keys "
          f"({spec.total_bits} bits)")

    # 2. the kernel map: ONE argsort builds the table, all K³ shifted
    #    queries answered as one flattened batched binary search
    kmap = km.build_kmap(st, kernel_size=3, stride=1)
    print(f"kernel map: Σ|M_δ| = {int(jnp.sum(kmap.ws_count))} pairs "
          f"(avg {float(jnp.sum(kmap.ws_count)) / int(kmap.n_out):.1f} neighbors/point)")

    # 3. one sparse conv under each dataflow — identical math
    w = jax.random.normal(jax.random.PRNGKey(1), (27, 16, 32)) * 0.1
    outs = {}
    for name in df.DATAFLOWS:
        cfg = df.DataflowConfig(name)
        fn = jax.jit(lambda x: df.sparse_conv_forward(x, w, kmap, cfg))
        us = timeit_fn(lambda: jax.block_until_ready(fn(st.feats))) * 1e6
        outs[name] = fn(st.feats)
        print(f"  {name:18s}: {us:9.1f} us/call")
    a, b, c = outs.values()
    print(f"max |Δ| across dataflows: {float(jnp.abs(a - b).max()):.2e}, "
          f"{float(jnp.abs(a - c).max()):.2e}")

    # 4. sorting reduces MXU-tile redundancy (the paper's Fig. 6 on TPU terms)
    for splits, sort in ((1, False), (1, True), (2, True), (4, True)):
        plan = km.make_split_plan(kmap, splits, sort=sort)
        stats = km.redundancy_stats(kmap, plan, tile_m=128)
        tag = "unsorted" if not sort else f"sorted s={splits}"
        print(f"  {tag:14s}: compute overhead {float(stats['overhead']):.2f}x")


if __name__ == "__main__":
    main()
