"""Persisted autotuner plans: tune once, serve forever.

The Sparse Autotuner's output is a per-group ``TrainDataflowConfig``
assignment keyed by map-sharing signature ``(stride, kernel_size, kind)``.
Tuning measures end-to-end latency (minutes of wall clock); a serving
process must not pay that on every start.  ``PlanRegistry`` persists
assignments to a small JSON file and loads them at engine startup — the
serving analogue of the paper's offline tuning step.

Schema (version 1)::

    {"version": 1,
     "plans": {"minkunet_kitti": {
         "1:3:sub": {"fwd": {...DataflowConfig...}, "dgrad": …, "wgrad": …},
         …}}}
"""
from __future__ import annotations

import json
import os
from typing import Dict, Optional

from repro.core.sparse_conv import TrainDataflowConfig

_VERSION = 1

Assignment = Dict[tuple, TrainDataflowConfig]


def _sig_to_str(sig: tuple) -> str:
    stride, k, kind = sig
    return f"{int(stride)}:{int(k)}:{kind}"


def _sig_from_str(s: str) -> tuple:
    stride, k, kind = s.split(":")
    return (int(stride), int(k), kind)


class PlanRegistry:
    """arch name → {group signature → TrainDataflowConfig}, JSON-persisted."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._plans: Dict[str, Assignment] = {}

    def set(self, arch: str, assignment: Assignment) -> None:
        self._plans[arch] = dict(assignment)

    def get(self, arch: str) -> Assignment:
        """The stored assignment for ``arch`` ({} when never tuned)."""
        return dict(self._plans.get(arch, {}))

    def archs(self):
        return sorted(self._plans)

    def to_dict(self) -> dict:
        return {"version": _VERSION,
                "plans": {arch: {_sig_to_str(sig): cfg.to_dict()
                                 for sig, cfg in assignment.items()}
                          for arch, assignment in sorted(self._plans.items())}}

    def save(self, path: Optional[str] = None) -> str:
        path = path or self.path
        assert path, "PlanRegistry.save needs a path"
        tmp = f"{path}.tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_dict(), f, indent=2, sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)  # atomic: a crashed save never corrupts plans
        self.path = path
        return path

    @classmethod
    def load(cls, path: str, missing_ok: bool = True) -> "PlanRegistry":
        reg = cls(path=path)
        if not os.path.exists(path):
            if missing_ok:
                return reg
            raise FileNotFoundError(path)
        with open(path) as f:
            doc = json.load(f)
        if doc.get("version") != _VERSION:
            raise ValueError(f"unsupported plan version {doc.get('version')!r} "
                             f"in {path} (expected {_VERSION})")
        for arch, groups in doc.get("plans", {}).items():
            reg._plans[arch] = {
                _sig_from_str(s): TrainDataflowConfig.from_dict(d)
                for s, d in groups.items()}
        return reg
