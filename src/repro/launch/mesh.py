"""Production mesh definitions.

A function, not a module-level constant — importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before any jax init).
"""
from __future__ import annotations

import jax

from repro.models.lm_common import ShardCtx


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    """Arbitrary mesh (tests, elastic restore)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_ctx(mesh, fsdp: bool = False) -> ShardCtx:
    axes = mesh.axis_names
    batch = tuple(a for a in axes if a in ("pod", "data"))
    return ShardCtx(mesh=mesh, batch=batch, model="model",
                    model_size=mesh.shape["model"], fsdp=fsdp)


# TPU v5e hardware constants for the roofline (per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link
