"""Paper Fig. 14 — end-to-end inference latency of MinkUNet (segmentation)
and CenterPoint (detection) under each system's dataflow, plus the
TorchSparse++ autotuned hybrid.  ``derived`` column = speedup vs the
slowest baseline."""
from __future__ import annotations

import jax

from benchmarks import common
from repro.core import dataflows as df
from repro.core.autotuner import Autotuner, partition_groups, timeit_fn
from repro.core.sparse_conv import TrainDataflowConfig
from repro.models import centerpoint, minkunet


def _bench_model(tag, apply_fn, params, stx, maps, sigs):
    groups = partition_groups(sigs)
    sig_of = {g.name: sigs[g.layer_names[0]] for g in groups}
    lats = {}
    for name, cfg in common.SYSTEMS.items():
        amap = {s: TrainDataflowConfig.bind_all(cfg) for s in set(sigs.values())}
        fn = jax.jit(lambda p: apply_fn(p, stx, maps=maps, assignment=amap))
        lats[name] = common.time_fn(lambda: fn(params))

    # TorchSparse++ = group-tuned hybrid over the full design space
    space = [df.DataflowConfig("gather_scatter"),
             df.DataflowConfig("fetch_on_demand"),
             df.DataflowConfig("implicit_gemm", n_splits=0),
             df.DataflowConfig("implicit_gemm", n_splits=1),
             df.DataflowConfig("implicit_gemm", n_splits=2)]

    def measure(assign):
        amap = {sig_of[k]: TrainDataflowConfig.bind_all(v) for k, v in assign.items()}
        fn = jax.jit(lambda p: apply_fn(p, stx, maps=maps, assignment=amap))
        return timeit_fn(lambda: jax.block_until_ready(fn(params)), warmup=1, iters=2)

    best = Autotuner(groups, space, measure).tune()
    amap = {sig_of[k]: TrainDataflowConfig.bind_all(v) for k, v in best.items()}
    fn = jax.jit(lambda p: apply_fn(p, stx, maps=maps, assignment=amap))
    lats["torchsparse++(autotuned)"] = common.time_fn(lambda: fn(params))

    worst = max(lats.values())
    for name, us in lats.items():
        common.emit(f"fig14/{tag}/{name}", us, f"speedup_vs_worst={worst / us:.2f}x")
    return lats


def run():
    key = jax.random.PRNGKey(0)
    mcfg = minkunet.MinkUNetConfig(width=0.25, blocks_per_stage=1)
    stx = common.seg_scene()
    params = minkunet.init_params(mcfg, key)
    maps = minkunet.build_maps(stx)
    _bench_model("SK-M", lambda p, s, maps, assignment: minkunet.apply(p, s, mcfg, maps, assignment),
                 params, stx, maps, minkunet.layer_signatures(mcfg))

    ccfg = centerpoint.CenterPointConfig(width=0.5)
    std = common.det_scene()
    cparams = centerpoint.init_params(ccfg, key)
    cmaps = centerpoint.build_maps(std)
    _bench_model("WM-C", lambda p, s, maps, assignment: centerpoint.apply(p, s, ccfg, maps, assignment),
                 cparams, std, cmaps, centerpoint.layer_signatures(ccfg))


if __name__ == "__main__":
    run()
