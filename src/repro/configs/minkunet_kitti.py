"""The paper's own segmentation workload: MinkUNet on SemanticKITTI-like
synthetic scenes (SK-M in Fig. 14/15).  Width 0.5 / 1.0 variants."""
from repro.models.minkunet import MinkUNetConfig

CONFIG_1X = MinkUNetConfig(in_channels=4, num_classes=19, width=1.0)
CONFIG_05X = MinkUNetConfig(in_channels=4, num_classes=19, width=0.5)
# benchmark-scale (CPU container) variant
CONFIG_BENCH = MinkUNetConfig(in_channels=4, num_classes=19, width=0.25,
                              blocks_per_stage=1)
