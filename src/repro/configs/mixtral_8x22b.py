"""Mixtral 8x22B — 8 experts top-2, SWA [arXiv:2401.04088; hf]."""
from repro.models.lm_common import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="mixtral-8x22b", family="moe", n_layers=56, d_model=6144,
    n_heads=48, kv_heads=8, d_ff=16384, vocab=32768, norm="rms", mlp="swiglu",
    sliding_window=4096,
    # 8 experts don't divide the 16-way model axis: shard d_ff inside each
    # expert (TP) instead of EP over experts.
    moe=MoECfg(n_experts=8, top_k=2, d_ff_expert=16384, shard_experts=False),
)
