"""Pure-jnp oracle for the sparse-conv weight-gradient kernel.

dW_δ = Σ_{(p,q) ∈ M_δ} x_pᵀ dy_q — per offset, a GEMM whose *both* operands
go through sparse iterators (paper §6.1: why wgrad prefers different
dataflow parameters than fwd/dgrad).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wgrad_ref(x: jax.Array, dy: jax.Array, ws_in: jax.Array,
              ws_out: jax.Array) -> jax.Array:
    """x: (N_in, Cin); dy: (N_out, Cout); ws_in/ws_out: (KD, cap) int32
    (-1 padded) → (KD, Cin, Cout) in f32."""
    def per_offset(i_in, i_out):
        xs = jnp.where((i_in >= 0)[:, None], x[jnp.clip(i_in, 0)], 0)
        ys = jnp.where((i_out >= 0)[:, None], dy[jnp.clip(i_out, 0)], 0)
        return jnp.dot(xs.astype(jnp.float32).T, ys.astype(jnp.float32))

    return jax.vmap(per_offset)(ws_in, ws_out)
