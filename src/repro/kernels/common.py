"""Shared helpers for the Pallas kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def pad_rows(x: jax.Array, multiple: int, value=0) -> jax.Array:
    """Pad the leading dim of ``x`` to a multiple (paper §3.2 padding trick)."""
    n = x.shape[0]
    pad = (-n) % multiple
    if pad == 0:
        return x
    cfg = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, cfg, constant_values=value)


def default_interpret() -> bool:
    """Pallas kernels run in interpret mode unless a real TPU is attached."""
    return jax.default_backend() != "tpu"


def pallas_supported() -> bool:
    """True when the installed jax can launch this repo's Pallas kernels —
    they pass ``pltpu.CompilerParams``, absent on older jax (the same probe
    tests/conftest.py gates the kernel suites behind).  The serving tuner
    uses this to decide whether the pallas backend axis is searchable."""
    try:
        from jax.experimental.pallas import tpu as pltpu  # noqa: F401
    except Exception:
        return False
    return hasattr(pltpu, "CompilerParams")
