"""Relational graph convolution (R-GCN) on the sparse-conv engine.

Paper §5.2 (Fig. 16): graph convolutions exhibit the same computation pattern
as sparse convolution — each *relation* plays the role of a kernel offset δ,
and the per-relation edge list is exactly a weight-stationary kernel map
(gather by source, GEMM with W_r, scatter-add to destination).

Because a node can have many neighbors under one relation, the
output-stationary (implicit GEMM) representation does not apply; the engine
runs the weight-stationary dataflows (gather-GEMM-scatter / fetch-on-demand),
which is how the paper's graph mode works too.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import dataflows as df
from repro.core.kmap import KernelMap


def edges_to_kmap(src: jax.Array, dst: jax.Array, edge_type: jax.Array,
                  num_relations: int, num_nodes_cap: int, cap_per_rel: int) -> KernelMap:
    """Build the weight-stationary map from a typed edge list.

    src/dst/edge_type: (E_cap,) int32 with -1 padding.
    Returns a KernelMap whose ws_* lists drive the shared dataflow engine
    (m_out/bitmask are degenerate placeholders — implicit GEMM is N/A).

    Note on declared bounds: graph workloads index nodes by integer id
    directly — no coordinate table is ever built, so the packed-key engine's
    ``batch_bound``/``spatial_bound`` declarations don't apply here (there
    is nothing to sort or binary-search; the edge list IS the map)."""
    rel = jnp.arange(num_relations)

    def per_rel(r):
        in_rel = (edge_type == r) & (src >= 0)
        order = jnp.argsort(~in_rel)  # valid first, stable
        take = order[:cap_per_rel]
        ok = in_rel[take]
        return (jnp.where(ok, src[take], -1).astype(jnp.int32),
                jnp.where(ok, dst[take], -1).astype(jnp.int32),
                jnp.sum(in_rel).astype(jnp.int32))

    ws_in, ws_out, count = jax.vmap(per_rel)(rel)
    dummy = jnp.zeros((num_nodes_cap, num_relations), jnp.int32) - 1
    return KernelMap(m_out=dummy, out_coords=jnp.zeros((num_nodes_cap, 1), jnp.int32),
                     n_out=jnp.asarray(num_nodes_cap, jnp.int32), ws_in=ws_in,
                     ws_out=ws_out, ws_count=count,
                     bitmask=jnp.zeros((num_nodes_cap,), jnp.int32),
                     out_stride=1, kernel_size=1)


GRAPH_DEFAULT = df.DataflowConfig("gather_scatter")


def rgcn_layer(feats: jax.Array, w_rel: jax.Array, w_self: jax.Array,
               kmap: KernelMap, cfg: df.DataflowConfig = GRAPH_DEFAULT,
               normalize: bool = True) -> jax.Array:
    """One R-GCN layer: h'_i = W_self h_i + Σ_r Σ_{j∈N_r(i)} (1/c_{i,r}) W_r h_j.

    feats: (N_cap, Cin); w_rel: (R, Cin, Cout); w_self: (Cin, Cout)."""
    assert cfg.dataflow in ("gather_scatter", "fetch_on_demand"), \
        "implicit GEMM is output-stationary with ≤1 neighbor per offset; N/A for graphs"
    if normalize:
        # per-(node, relation) in-degree normalization folded into the gathered rows
        deg = _per_rel_indegree(kmap, feats.shape[0])  # (R, N_cap)
        agg = _weighted_gather_scatter(feats, w_rel, kmap, deg)
    else:
        agg = df.sparse_conv_forward(feats, w_rel, kmap, dataclasses.replace(cfg, backend="xla"))
    return agg + feats @ w_self


def _per_rel_indegree(kmap: KernelMap, n_cap: int) -> jax.Array:
    def per_rel(i_out):
        ones = (i_out >= 0).astype(jnp.float32)
        deg = jnp.zeros((n_cap,), jnp.float32).at[i_out].add(ones, mode="drop")
        return jnp.maximum(deg, 1.0)

    return jax.vmap(per_rel)(kmap.ws_out)


def _weighted_gather_scatter(x, w, kmap, deg):
    def body(acc, inputs):
        wk, i_in, i_out, dk = inputs
        rows = jnp.where((i_in >= 0)[:, None], x[jnp.clip(i_in, 0)], 0)
        y = jnp.dot(rows.astype(jnp.float32), wk.astype(jnp.float32))
        y = y / dk[jnp.clip(i_out, 0)][:, None]
        return acc.at[i_out].add(y, mode="drop"), None

    acc0 = jnp.zeros((deg.shape[1], w.shape[-1]), jnp.float32)
    acc, _ = jax.lax.scan(body, acc0, (w, kmap.ws_in, kmap.ws_out, deg))
    return acc.astype(x.dtype)
