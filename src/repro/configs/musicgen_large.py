"""MusicGen-large — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

Modality frontend is a STUB per assignment: input_specs() provides
precomputed frame embeddings (B, S, d_model); the backbone is the standard
decoder with the 2048-entry codebook head.
"""
from repro.models.lm_common import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large", family="audio", n_layers=48, d_model=2048,
    n_heads=32, kv_heads=32, d_ff=8192, vocab=2048, norm="ln", mlp="gelu",
    embed_input=False,
)
