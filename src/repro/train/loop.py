"""Fault-tolerant training loop.

Production posture (1000+ nodes):
* resume-from-latest on entry — a restarted job continues where the fleet
  left off (the data-iterator offset rides in the checkpoint ``extra``);
* periodic **async** checkpointing (snapshot-to-host is synchronous and
  cheap; serialization happens off-thread);
* a step watchdog flags stragglers: steps slower than
  ``straggler_factor ×`` the rolling median are logged and counted — on a
  real fleet this signal feeds the controller that evicts the slow host and
  triggers an **elastic restart** (checkpoint.restore with the new mesh's
  shardings; see tests/test_distributed.py::test_elastic_reshard);
* on any exception the loop writes a final synchronous checkpoint before
  re-raising, so no more than ``ckpt_every`` steps are ever lost.
"""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Callable, Iterator, Optional

import jax

from repro import obs
from repro.train import checkpoint as ckpt


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    log_every: int = 10
    straggler_factor: float = 3.0
    keep_ckpts: int = 2


@dataclasses.dataclass
class LoopReport:
    steps_run: int = 0
    resumed_from: Optional[int] = None
    straggler_steps: int = 0
    last_metrics: Optional[dict] = None
    step_times: list = dataclasses.field(default_factory=list)


def train_loop(step_fn: Callable, params, opt_state, data_iter: Iterator,
               cfg: LoopConfig, state_of=lambda p, o: {"params": p, "opt": o}) -> tuple:
    """Run ``step_fn(params, opt_state, batch) → (params, opt_state, metrics)``.

    Returns (params, opt_state, LoopReport)."""
    report = LoopReport()
    start_step = 0

    if cfg.ckpt_dir and ckpt.latest_step(cfg.ckpt_dir) is not None:
        tree = state_of(params, opt_state)
        tree, step, extra = ckpt.restore(cfg.ckpt_dir, None, tree)
        params, opt_state = tree["params"], tree["opt"]
        start_step = step
        report.resumed_from = step
        # fast-forward the data iterator (its offset is part of the state)
        for _ in range(int(extra.get("data_offset", step))):
            next(data_iter)

    median = None
    try:
        for step in range(start_step, cfg.total_steps):
            batch = next(data_iter)
            t0 = time.perf_counter()
            with obs.span("train_step", step=step):
                params, opt_state, metrics = step_fn(params, opt_state, batch)
                jax.block_until_ready(metrics)
            dt = time.perf_counter() - t0
            report.step_times.append(dt)
            report.steps_run += 1
            report.last_metrics = jax.device_get(metrics)

            # straggler watchdog
            if median is None:
                median = dt
            else:
                median = 0.9 * median + 0.1 * dt
                if dt > cfg.straggler_factor * median:
                    report.straggler_steps += 1

            if cfg.ckpt_dir and (step + 1) % cfg.ckpt_every == 0:
                with obs.span("checkpoint", step=step + 1):
                    ckpt.save_async(cfg.ckpt_dir, step + 1,
                                    state_of(params, opt_state),
                                    extra={"data_offset": step + 1})
            if (step + 1) % cfg.log_every == 0:
                m = report.last_metrics
                print(f"step {step + 1}: {m}", flush=True)
    except KeyboardInterrupt:
        # preemption signal: final synchronous checkpoint, then bail
        if cfg.ckpt_dir:
            ckpt.save(cfg.ckpt_dir, report.steps_run + start_step,
                      state_of(params, opt_state),
                      extra={"data_offset": report.steps_run + start_step})
        raise
    finally:
        ckpt.wait_pending()
    return params, opt_state, report
