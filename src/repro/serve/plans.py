"""Persisted execution plans: tune once, serve forever.

The Sparse Autotuner's output is a tuned ``core.plan.NetworkPlan`` — per
layer group, a ``TrainDataflowConfig`` bound into the plan's ``LayerPlan``s.
Tuning measures end-to-end latency (minutes of wall clock); a serving
process must not pay that on every start.  ``PlanRegistry`` persists plans
to a small JSON file and loads them at engine startup — the serving
analogue of the paper's offline tuning step.

Schema (version 2)::

    {"version": 2,
     "plans": {"minkunet_kitti": {
         "assignment": {"1:3:sub": {"fwd": {...DataflowConfig...},
                                    "dgrad": ..., "wgrad": ...}, ...},
         "network": {...serialized core.plan.NetworkPlan...} | null,
         "service": {...ServiceConfig.to_dict()...} | null}}}

The per-signature ``assignment`` block is the schema-v1 payload (kept both
for humans diffing plan files and so a v2 file degrades gracefully);
``network`` is the full serialized ``NetworkPlan`` (layers + execution ops
+ kernel-map program + precision policies).  Version-1 files from PR 2
(``{"version": 1, "plans": {arch: {sig: cfg3}}}``) still load through the
shim: their assignments are read and the network plan is recompiled from
the model declaration at engine startup.
"""
from __future__ import annotations

import json
import os
from typing import Dict, Optional

from repro.core.plan import NetworkPlan
from repro.core.sparse_conv import TrainDataflowConfig

_VERSION = 2

Assignment = Dict[tuple, TrainDataflowConfig]

#: Per-device plan entries are ordinary v2 plan names (``arch@dev3``): a
#: sharded serving tier can tune each device separately (heterogeneous
#: fleets) and the file stays loadable by every schema-v2 reader.
DEVICE_KEY_SEP = "@dev"


def device_key(arch: str, device_index: int) -> str:
    """The registry name of ``arch``'s plan for worker ``device_index``."""
    assert device_index >= 0
    return f"{arch}{DEVICE_KEY_SEP}{device_index}"


def _sig_to_str(sig: tuple) -> str:
    stride, k, kind = sig
    return f"{int(stride)}:{int(k)}:{kind}"


def _sig_from_str(s: str) -> tuple:
    stride, k, kind = s.split(":")
    return (int(stride), int(k), kind)


def _assignment_to_json(assignment: Assignment) -> dict:
    return {_sig_to_str(sig): cfg.to_dict() for sig, cfg in assignment.items()}


def _assignment_from_json(d: dict) -> Assignment:
    return {_sig_from_str(s): TrainDataflowConfig.from_dict(c)
            for s, c in d.items()}


class PlanRegistry:
    """arch name → tuned plan (assignment + optional NetworkPlan), JSON-persisted."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._plans: Dict[str, Assignment] = {}
        self._networks: Dict[str, NetworkPlan] = {}
        # the ServiceConfig each plan was tuned/served under ("service" key
        # per entry; absent in older files) — "the config that served this
        # plan" persists next to the plan instead of living in folklore
        self._services: Dict[str, dict] = {}

    def set(self, arch: str, assignment: Assignment,
            network: Optional[NetworkPlan] = None) -> None:
        self._plans[arch] = dict(assignment)
        if network is not None:
            self._networks[arch] = network
        else:
            self._networks.pop(arch, None)

    def get(self, arch: str) -> Assignment:
        """The stored assignment for ``arch`` ({} when never tuned)."""
        return dict(self._plans.get(arch, {}))

    def network(self, arch: str) -> Optional[NetworkPlan]:
        """The stored NetworkPlan for ``arch`` (None when never stored —
        v1 files and assignment-only writes; callers recompile from the
        model declaration)."""
        return self._networks.get(arch)

    def set_service(self, arch: str, config) -> None:
        """Record the ``ServiceConfig`` ``arch``'s plan was tuned under."""
        self._services[arch] = config.to_dict()

    def service(self, arch: str):
        """The persisted ``ServiceConfig`` for ``arch`` (None when the entry
        predates service persistence or was never recorded)."""
        d = self._services.get(arch)
        if d is None:
            return None
        from repro.serve.service import ServiceConfig
        return ServiceConfig.from_dict(d)

    def archs(self):
        return sorted(self._plans)

    def resolve_key(self, arch: str, device_index: Optional[int] = None) -> str:
        """The plan name an engine should read: the per-device entry when one
        was persisted for ``device_index``, else the shared ``arch`` entry.

        Per-device entries are written by ``DeviceRouter.tune`` under
        ``device_key(arch, i)``; a registry without them routes every device
        to the shared plan (homogeneous fleet — the common case)."""
        if device_index is not None:
            key = device_key(arch, device_index)
            if key in self._plans or key in self._networks:
                return key
        return arch

    def to_dict(self) -> dict:
        names = sorted(set(self._plans) | set(self._services))
        return {"version": _VERSION,
                "plans": {arch: {
                    "assignment": _assignment_to_json(
                        self._plans.get(arch, {})),
                    "network": (self._networks[arch].to_dict()
                                if arch in self._networks else None),
                    "service": self._services.get(arch)}
                    for arch in names}}

    def save(self, path: Optional[str] = None) -> str:
        path = path or self.path
        assert path, "PlanRegistry.save needs a path"
        tmp = f"{path}.tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_dict(), f, indent=2, sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)  # atomic: a crashed save never corrupts plans
        self.path = path
        return path

    @classmethod
    def load(cls, path: str, missing_ok: bool = True) -> "PlanRegistry":
        reg = cls(path=path)
        if not os.path.exists(path):
            if missing_ok:
                return reg
            raise FileNotFoundError(path)
        with open(path) as f:
            doc = json.load(f)
        version = doc.get("version")
        if version == 1:
            # v1 shim (PR 2 files): {arch: {sig: cfg3}} — assignment only.
            for arch, groups in doc.get("plans", {}).items():
                reg._plans[arch] = _assignment_from_json(groups)
            return reg
        if version != _VERSION:
            raise ValueError(f"unsupported plan version {version!r} "
                             f"in {path} (expected {_VERSION} or 1)")
        for arch, entry in doc.get("plans", {}).items():
            reg._plans[arch] = _assignment_from_json(entry.get("assignment", {}))
            net = entry.get("network")
            if net is not None:
                reg._networks[arch] = NetworkPlan.from_dict(net)
            svc = entry.get("service")
            if svc is not None:
                reg._services[arch] = svc
        return reg
