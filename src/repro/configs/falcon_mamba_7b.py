"""Falcon-Mamba-7B — mamba1 arch [arXiv:2410.05355; unverified]."""
from repro.models.lm_common import ArchConfig, SSMCfg

CONFIG = ArchConfig(
    name="falcon-mamba-7b", family="ssm", n_layers=64, d_model=4096,
    n_heads=1, kv_heads=1, d_ff=0, vocab=65024, norm="rms",
    ssm=SSMCfg(d_state=16, expand=2, conv_kernel=4, version=1, chunk=128),
    sub_quadratic=True,
)
