"""Sharded, async, *elastic* checkpointing (no orbax in this environment).

Layout:  <dir>/step_<n>/
            manifest.json          tree structure, shapes, dtypes
            arr_<i>.npy            one file per leaf (host-gathered)
         <dir>/LATEST              atomic pointer file

Fault-tolerance posture:
* writes go to ``step_<n>.tmp`` and are renamed only when complete, so a
  preempted save can never be mistaken for a valid checkpoint;
* ``save_async`` snapshots arrays to host memory synchronously (cheap) and
  does the serialization on a background thread — the train loop continues;
* ``restore`` takes an optional sharding tree and ``jax.device_put``s each
  leaf accordingly: restoring to a *different mesh shape* (elastic scaling
  after losing a pod) is just a different sharding tree;
* save/restore are **dtype-aware**: extension dtypes (bfloat16, fp8 — numpy
  kind ``V``) are serialized through a same-width unsigned-int view (a bare
  ``np.save`` silently degrades them to raw void bytes) and restored at
  their *saved* dtype from the manifest, never silently cast to the target
  tree's dtype — so the AdamW fp32 master-weight tree of a bf16
  mixed-precision run round-trips bit-exactly even when the restore
  template was rebuilt from freshly-cast params.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


#: same-width unsigned carrier for extension dtypes (numpy kind 'V'):
#: np.save would silently write them as opaque void records otherwise.
_UINT_OF_WIDTH = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}


def _np_dtype(name: str) -> np.dtype:
    """Resolve a manifest dtype string, including ml_dtypes extension types
    (registered by jax's import) like "bfloat16"."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _encode_leaf(leaf: np.ndarray) -> np.ndarray:
    if leaf.dtype.kind == "V" and leaf.dtype.names is None:
        return leaf.view(_UINT_OF_WIDTH[leaf.dtype.itemsize])
    return leaf


def _decode_leaf(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    want = _np_dtype(dtype_name)
    if arr.dtype != want and arr.dtype.itemsize == want.itemsize and \
            arr.dtype.kind in ("u", "V"):
        return arr.view(want)
    return arr


def save(ckpt_dir: str | Path, step: int, tree: Any, *, extra: Optional[dict] = None):
    """Synchronous checkpoint write."""
    leaves = [np.asarray(jax.device_get(x)) for x in jax.tree.leaves(tree)]
    _write(Path(ckpt_dir), step, tree, leaves, extra or {})


_PENDING: list[threading.Thread] = []


def save_async(ckpt_dir: str | Path, step: int, tree: Any, *, extra: Optional[dict] = None):
    """Snapshot to host now; write files on a background thread."""
    leaves = [np.asarray(jax.device_get(x)) for x in jax.tree.leaves(tree)]
    t = threading.Thread(target=_write, args=(Path(ckpt_dir), step, tree, leaves, extra or {}),
                         daemon=True)
    t.start()
    _PENDING.append(t)
    return t


def wait_pending():
    for t in _PENDING:
        t.join()
    _PENDING.clear()


def _write(ckpt_dir: Path, step: int, tree, leaves, extra):
    tmp = ckpt_dir / f"step_{step}.tmp"
    final = ckpt_dir / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    treedef = jax.tree_util.tree_structure(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "shapes": [list(l.shape) for l in leaves],
        "dtypes": [str(l.dtype) for l in leaves],
        "extra": extra,
    }
    for i, leaf in enumerate(leaves):
        np.save(tmp / f"arr_{i}.npy", _encode_leaf(leaf))
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    (ckpt_dir / "LATEST.tmp").write_text(str(step))
    os.replace(ckpt_dir / "LATEST.tmp", ckpt_dir / "LATEST")


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    p = Path(ckpt_dir) / "LATEST"
    if not p.exists():
        return None
    return int(p.read_text().strip())


def restore(ckpt_dir: str | Path, step: Optional[int], target_tree: Any,
            shardings: Any = None):
    """Restore into the structure of ``target_tree``.

    Leaves come back at their **saved** dtype (from the manifest) — the
    checkpoint is the source of truth: a template whose dtype disagrees
    (e.g. a bf16 working copy standing in for the saved fp32 master tree)
    must not silently crush the restored values.  Shapes are still
    validated against the template.

    shardings: optional matching tree of jax.sharding.Sharding — pass the
    *new* mesh's shardings to reshard elastically."""
    if step is None:
        step = latest_step(ckpt_dir)
        assert step is not None, f"no checkpoint under {ckpt_dir}"
    d = Path(ckpt_dir) / f"step_{step}"
    manifest = json.loads((d / "manifest.json").read_text())
    leaves, treedef = jax.tree_util.tree_flatten(target_tree)
    assert manifest["n_leaves"] == len(leaves), "checkpoint/tree structure mismatch"
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(leaves))
    out = []
    for i, (ref, shd) in enumerate(zip(leaves, shard_leaves)):
        arr = _decode_leaf(np.load(d / f"arr_{i}.npy"), manifest["dtypes"][i])
        assert list(arr.shape) == list(ref.shape), f"leaf {i} shape mismatch"
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.numpy.asarray(arr))
    extra = manifest.get("extra", {})
    return jax.tree_util.tree_unflatten(treedef, out), step, extra
