"""OLMo-1B — non-parametric LN [arXiv:2402.00838; hf]."""
from repro.models.lm_common import ArchConfig

CONFIG = ArchConfig(
    name="olmo-1b", family="dense", n_layers=16, d_model=2048,
    n_heads=16, kv_heads=16, d_ff=8192, vocab=50304, norm="nonparam",
    mlp="swiglu", tie_embeddings=True,
)
