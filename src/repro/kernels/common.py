"""Shared helpers for the Pallas kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def pad_rows(x: jax.Array, multiple: int, value=0) -> jax.Array:
    """Pad the leading dim of ``x`` to a multiple (paper §3.2 padding trick)."""
    n = x.shape[0]
    pad = (-n) % multiple
    if pad == 0:
        return x
    cfg = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, cfg, constant_values=value)


def default_interpret() -> bool:
    """Pallas kernels run in interpret mode unless a real TPU is attached."""
    return jax.default_backend() != "tpu"


def pallas_supported() -> bool:
    """True when the installed jax can launch this repo's Pallas kernels.

    The kernels need a TPU compiler-params class for ``pl.pallas_call``;
    current jax spells it ``pltpu.CompilerParams``, 0.4.x spells it
    ``pltpu.TPUCompilerParams``.  :func:`tpu_compiler_params` papers over
    the rename, so either spelling makes the tier launchable (interpret
    mode off-TPU).  tests/conftest.py gates the kernel suites behind the
    same probe and the serving tuner uses it to decide whether the pallas
    backend axis is searchable."""
    try:
        from jax.experimental.pallas import tpu as pltpu  # noqa: F401
    except Exception:
        return False
    return hasattr(pltpu, "CompilerParams") or hasattr(pltpu, "TPUCompilerParams")


def tpu_compiler_params(*, dimension_semantics=None, interpret: bool = False):
    """Build TPU compiler params across the CompilerParams rename.

    Returns an instance of whichever class this jax provides, or ``None``
    when the kernel runs in interpret mode (the interpreter rejects /
    ignores Mosaic compiler params) or when neither spelling exists.
    Pass the result straight to ``pl.pallas_call(compiler_params=...)`` —
    ``None`` is the documented default there.
    """
    if interpret:
        return None
    try:
        from jax.experimental.pallas import tpu as pltpu
    except Exception:
        return None
    cls = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams", None)
    if cls is None:
        return None
    kwargs = {}
    if dimension_semantics is not None:
        kwargs["dimension_semantics"] = tuple(dimension_semantics)
    return cls(**kwargs)
