"""Render EXPERIMENTS.md §Dry-run/§Roofline tables from the dry-run JSONs.

MODEL_FLOPS convention:
  train   : 6 · N · D       (N = params [active for MoE], D = tokens)
  prefill : 2 · N · D
  decode  : 2 · N · B       (one token per sequence)
Ratio MODEL_FLOPS / HLO_FLOPS measures how much compiled compute is
"useful" (remat and padding waste show up here).
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs import base as cfgbase

RESULTS = Path(__file__).resolve().parent / "results" / "dryrun"


def model_flops(arch: str, shape_name: str) -> float:
    cfg = cfgbase.get_arch(arch)
    shape = cfgbase.SHAPES[shape_name]
    n = cfg.active_params_count()
    if shape.kind == "train":
        return 6.0 * n * shape.batch * shape.seq
    if shape.kind == "prefill":
        return 2.0 * n * shape.batch * shape.seq
    return 2.0 * n * shape.batch


def improvement_hint(rec: dict) -> str:
    r = rec["roofline"]
    b = r["bottleneck"]
    if b == "collective":
        return "cut wire bytes: better sharding of the dominant all-gather/all-reduce"
    if b == "memory":
        return "cut HBM traffic: less remat / fuse elementwise chains / bf16 intermediates"
    return "already compute-bound: raise MXU utilization (padding, layouts)"


def load(mesh_dir: str):
    out = []
    for f in sorted((RESULTS / mesh_dir).glob("*.json")):
        out.append(json.loads(f.read_text()))
    return out


def table(mesh_dir: str, full: bool = True) -> str:
    rows = []
    header = ("| arch | shape | T_comp (s) | T_mem (s) | T_coll (s) | bottleneck | "
              "roofline frac | model/HLO FLOPs | hint |\n"
              "|---|---|---|---|---|---|---|---|---|")
    for rec in load(mesh_dir):
        if rec["status"] == "skipped":
            rows.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | SKIP | — | — | "
                        f"{rec['reason'][:60]} |")
            continue
        if rec["status"] != "ok":
            rows.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | ERROR | — | — | see json |")
            continue
        r = rec["roofline"]
        mf = model_flops(rec["arch"], rec["shape"])
        hlo_global = rec["global_flops"]
        ratio = mf / hlo_global if hlo_global else float("nan")
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | {r['bottleneck']} | {r['roofline_fraction']:.3f} "
            f"| {ratio:.2f} | {improvement_hint(rec)[:58]} |")
    return header + "\n" + "\n".join(rows)


def memory_table(mesh_dir: str) -> str:
    header = ("| arch | shape | args GB/dev | temp GB/dev | fits 16G? |\n|---|---|---|---|---|")
    rows = []
    for rec in load(mesh_dir):
        if rec["status"] != "ok":
            continue
        pd = rec["per_device"]
        if pd["argument_bytes"] is None:
            continue
        args = (pd["argument_bytes"] - (pd["alias_bytes"] or 0)) / 1e9 + (pd["alias_bytes"] or 0) / 1e9
        temp = (pd["temp_bytes"] or 0) / 1e9
        total = pd["argument_bytes"] / 1e9 + temp
        rows.append(f"| {rec['arch']} | {rec['shape']} | {pd['argument_bytes']/1e9:.2f} "
                    f"| {temp:.2f} | {'yes' if total < 16 else 'NO (' + f'{total:.0f}G' + ')'} |")
    return header + "\n" + "\n".join(rows)


def main():
    for mesh in ("single_pod", "multi_pod"):
        if (RESULTS / mesh).exists():
            print(f"\n### Roofline — {mesh}\n")
            print(table(mesh))
    print("\n### Memory fit — single_pod\n")
    print(memory_table("single_pod"))


if __name__ == "__main__":
    main()
