"""Map-construction latency: packed single-sort engine vs the seed's
multi-word sort/search path.

The paper's Tables 3 vs 4 show mapping-operator overhead (bitmask building,
sorting, reordering) can flip end-to-end rankings; Minuet (PAPERS.md) makes
sort/merge mapping the central optimization target.  This suite times the
mapping path in isolation:

* single-layer kernel-map construction (submanifold K=3 and strided K=2)
  on the deterministic CenterPoint detection scene, jitted, best-of-n;
* the full CenterPoint map stack (5 submanifold + 4 strided maps) with the
  cross-layer ``MapCache`` vs the legacy per-layer rebuild;
* split-plan construction with and without the fused tile-occupancy pass.

``--tiny`` runs a reduced scene for CI smoke coverage.  The ``legacy``
engine rows exist only for this A/B and disappear when the legacy path is
deleted (ROADMAP).
"""
from __future__ import annotations

import argparse

import jax

from benchmarks import common
from repro.core import kmap as km
from repro.models import centerpoint


def run(tiny: bool = False):
    if tiny:
        stx = common.det_scene(n=300, cap=512)
        iters = 2
    else:
        stx = common.det_scene()
        iters = 5
    results = {}
    for engine in ("legacy", "packed"):
        fn_sub = jax.jit(lambda e=engine: km.build_kmap(stx, 3, 1, engine=e))
        us = common.time_fn(lambda: fn_sub(), iters=iters)
        results[f"sub/{engine}"] = us
        common.emit(f"kmap/sub_k3/{engine}", us, "")

        fn_down = jax.jit(lambda e=engine: km.build_kmap(stx, 2, 2, engine=e))
        us = common.time_fn(lambda: fn_down(), iters=iters)
        results[f"down/{engine}"] = us
        common.emit(f"kmap/down_k2s2/{engine}", us, "")

        fn_stack = jax.jit(lambda e=engine: centerpoint.build_maps(stx, engine=e))
        us = common.time_fn(lambda: fn_stack(), iters=iters)
        results[f"stack/{engine}"] = us
        common.emit(f"kmap/centerpoint_stack/{engine}", us, "")

    for name in ("sub", "down", "stack"):
        ratio = results[f"{name}/legacy"] / max(results[f"{name}/packed"], 1e-9)
        common.emit(f"kmap/speedup/{name}", 0.0, f"packed_vs_legacy={ratio:.2f}x")

    # split-plan construction: fused occupancy vs separate pass
    kmap = km.build_kmap(stx, 3, 1)
    fn_sep = jax.jit(lambda: km.tile_occupancy(kmap, km.make_split_plan(kmap, 2), 128))
    fn_fused = jax.jit(lambda: km.make_split_plan(kmap, 2, tile_m=128).occupancy)
    common.emit("kmap/plan_occupancy/separate", common.time_fn(lambda: fn_sep(), iters=iters), "")
    common.emit("kmap/plan_occupancy/fused", common.time_fn(lambda: fn_fused(), iters=iters), "")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="reduced scene for CI smoke runs")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(tiny=args.tiny)
