# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness entrypoint: ``PYTHONPATH=src python -m benchmarks.run``.

One module per paper table/figure (see DESIGN.md §6):
  Fig. 14 inference, Fig. 15/22 training, Tab. 3/4 + Fig. 17 sorted-vs-
  unsorted, Tab. 5 mask splits, Fig. 18 hybrid dataflow, Fig. 16 R-GCN,
  Fig. 8 generator-vs-dense-GEMM.

CPU-container caveat: wall-clock numbers here validate *ranking logic*
(mapping overhead vs kernel time trade-offs) at reduced scale; the TPU
performance story lives in the dry-run roofline (EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (bench_generator, bench_graph, bench_hybrid,
                            bench_inference, bench_kmap, bench_serving,
                            bench_sorted, bench_splits, bench_streaming,
                            bench_training, common)

    suites = [
        ("kmap_engine", bench_kmap.run),
        ("serving_engine", bench_serving.run),
        ("streaming_serving", bench_streaming.run),
        ("fig14_inference", bench_inference.run),
        ("fig15_training", bench_training.run),
        ("tab34_sorted", bench_sorted.run),
        ("tab5_splits", bench_splits.run),
        ("fig18_hybrid", bench_hybrid.run),
        ("fig16_graph", bench_graph.run),
        ("fig8_generator", bench_generator.run),
    ]
    print("name,us_per_call,derived")
    failures = []
    for name, fn in suites:
        try:
            fn()
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"FAILED suites: {failures}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == '__main__':
    main()
