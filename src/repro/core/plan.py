"""Unified execution-plan compiler: the `LayerPlan`/`NetworkPlan` IR.

TorchSparse++'s central claim is that a small kernel generator plus a
*whole-network* autotuner beats hand-engineered kernels: the tuner assigns a
dataflow configuration **per layer group for the entire network** (paper §4),
and mixed-precision training is where it wins biggest (§5).  Before this
module, that network-level view existed only implicitly — models hand-plumbed
``apply_conv`` calls, ``DataflowConfig`` dicts, ``MapCache`` handles and
``SplitPlan`` policies, and nothing in the conv stack knew about precision.

The IR makes the network the unit of compilation:

* ``LayerPlan`` — one conv layer: its ``ConvSpec``, which kernel map it runs
  on (``map_ref``), its map-sharing signature and tuner group, its
  ``TrainDataflowConfig`` (fwd/dgrad/wgrad dataflows), and its
  ``PrecisionPolicy``.
* ``KmapSpec`` — one kernel-map build step with the *explicit* dependency
  edges that used to be implicit in ``build_maps`` call order: which tensor
  stride it reads, whether its output table is adopted into the ``MapCache``
  (strided maps seed the next pyramid level's table for free), and which
  forward map a transposed map reuses.
* ``NetworkPlan`` — the compiled artifact every consumer shares: models
  execute through ``NetworkPlan.apply``, the autotuner rebinds per-group
  configs with ``with_assignment``, the serving engine persists/loads it as
  JSON (``serve/plans.PlanRegistry`` schema v2), and the training stack
  threads each layer's precision through the ``sparse_conv_apply``
  custom_vjp.

Lifecycle: **declare → compile → tune → persist → serve/train.**  Models
declare their layer list (a ``ModelDecl``); ``compile_plan`` partitions
tuner groups, binds dataflow assignments and precision policies;
``resolve_tiles`` applies the generator's adaptive tiling (paper §6.2) once
real kernel maps exist; ``PlanTuner``/``TrainingPlanTuner`` (see
``core/autotuner.py`` for the underlying greedy search) produce *tuned
plans* rather than bare config dicts.

A plan compiled with the default FP32 policy executes bit-identically to
the pre-plan per-call path (regression-tested in tests/test_plan.py).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import dataflows as df
from repro.core import generator
from repro.core import precision as prec
from repro.core.autotuner import (Autotuner, TrainingAutotuner,
                                  partition_groups)
from repro.core.hashing import CoordTable
from repro.core.kmap import (MapCache, SceneEntry, build_kmap,
                             make_split_plan, transpose_kmap)
from repro.core.precision import FP32, PrecisionPolicy
from repro.core.sparse_conv import (ConvSpec, TrainDataflowConfig, apply_conv)
from repro.core.sparse_tensor import SparseTensor

PLAN_VERSION = 2


# ---------------------------------------------------------------------------
# Shared layers: masked batch norm (+ ReLU)
# ---------------------------------------------------------------------------

def bn_relu_init(c: int) -> dict:
    return {"scale": jnp.ones((c,)), "bias": jnp.zeros((c,))}


def bn_relu(p, st: SparseTensor, relu: bool = True,
            mode: str = "batch") -> SparseTensor:
    """Masked batch norm (stats over valid rows) + ReLU.

    ``mode="batch"`` (training/eval parity with the seed) normalizes with
    statistics over all valid rows — which couples every row in a *batched*
    tensor.  ``mode="affine"`` is the serving/inference mode: a per-channel
    scale+bias only, so each row's output depends on that row alone and a
    capacity-bucketed batched forward is bit-identical to the per-scene
    forward (the serving engine's correctness contract).  It implements the
    standard deploy-time convention of *folding* BN into an affine op: a
    checkpoint exported for serving is expected to carry running statistics
    pre-folded into ``scale``/``bias`` (this repo trains with batch stats
    and keeps no running stats, so affine-mode outputs are not numerically
    comparable to a ``mode="batch"`` forward of the same raw params).

    Statistics are always computed in fp32; the result is cast back to the
    feature dtype, so bf16 activations stay bf16 across the layer.
    """
    mask = st.valid_mask[:, None]
    x = st.feats.astype(jnp.float32)
    if mode == "affine":
        y = x * p["scale"] + p["bias"]
    else:
        assert mode == "batch", mode
        n = jnp.maximum(st.num_valid, 1).astype(jnp.float32)
        mean = jnp.sum(jnp.where(mask, x, 0), axis=0) / n
        var = jnp.sum(jnp.where(mask, jnp.square(x - mean), 0), axis=0) / n
        y = (x - mean) * jax.lax.rsqrt(var + 1e-5) * p["scale"] + p["bias"]
    if relu:
        y = jax.nn.relu(y)
    return st.replace_feats(jnp.where(mask, y, 0).astype(st.feats.dtype))


# ---------------------------------------------------------------------------
# The IR
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerPlan:
    """One conv layer's slice of the compiled network plan.

    map_ref:  key into the map dict built by the plan's ``KmapSpec`` program
              (e.g. ``("sub", 4)``) — layers sharing a ref share the map.
    sig:      map-sharing signature ``(stride, kernel, kind)`` — the tuner
              groups layers by this (paper Fig. 12).
    group:    tuner group name, filled by ``compile_plan``.
    dataflow: decoupled fwd/dgrad/wgrad configs (paper Fig. 13).
    precision: numeric policy threaded through all three dataflow kernels.
    bn/relu:  whether the layer is followed by masked BN / ReLU.
    """

    name: str
    spec: ConvSpec
    map_ref: Tuple
    sig: Tuple
    group: str = ""
    dataflow: TrainDataflowConfig = TrainDataflowConfig()
    precision: PrecisionPolicy = FP32
    bn: bool = True
    relu: bool = True

    def to_dict(self) -> dict:
        return {"name": self.name, "spec": dataclasses.asdict(self.spec),
                "map_ref": list(self.map_ref), "sig": list(self.sig),
                "group": self.group, "dataflow": self.dataflow.to_dict(),
                "precision": self.precision.to_dict(),
                "bn": self.bn, "relu": self.relu}

    @staticmethod
    def from_dict(d: dict) -> "LayerPlan":
        known = {f.name for f in dataclasses.fields(LayerPlan)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown LayerPlan fields: {sorted(unknown)}")
        return LayerPlan(
            name=d["name"], spec=ConvSpec(**d["spec"]),
            map_ref=tuple(d["map_ref"]), sig=tuple(d["sig"]),
            group=d.get("group", ""),
            dataflow=TrainDataflowConfig.from_dict(d["dataflow"]),
            precision=PrecisionPolicy.from_dict(d["precision"]),
            bn=d.get("bn", True), relu=d.get("relu", True))


@dataclasses.dataclass(frozen=True)
class KmapSpec:
    """One kernel-map build step, with explicit dependency edges.

    kind:          "sub" (submanifold), "down" (strided), "up" (transposed).
    tensor_stride: stride of the tensor the map is built on ("up": the fine
                   tensor whose coordinates the inverse conv restores).
    adopts_output_table: a "down" map's strided-unique pass emits the child
                   level's sorted ``CoordTable`` for free; this edge makes
                   the ``MapCache`` adoption — implicit call-order magic
                   before this IR — part of the plan.
    transpose_of:  for "up" maps, the forward map whose pair lists are
                   swapped (decoder layers reuse encoder maps — same group).
    """

    ref: Tuple
    kind: str
    kernel_size: int
    stride: int
    tensor_stride: int
    adopts_output_table: bool = False
    transpose_of: Optional[Tuple] = None
    table: str = "sort"

    #: coordinate-table strategies: "sort" rebuilds every table with a fresh
    #: argsort; "composed" allows scene-granular merge-composition of cached
    #: per-scene tables/maps (serving); "incremental" additionally allows
    #: streaming frames to delta-merge their scene table.  A declared,
    #: serializable, tunable axis like dataflow — builders without composed
    #: inputs simply fall back to "sort" semantics.
    TABLE_STRATEGIES = ("sort", "composed", "incremental")

    def __post_init__(self):
        assert self.kind in ("sub", "down", "up"), self.kind
        assert self.table in self.TABLE_STRATEGIES, self.table
        if self.kind == "up":
            assert self.transpose_of is not None

    def to_dict(self) -> dict:
        return {"ref": list(self.ref), "kind": self.kind,
                "kernel_size": self.kernel_size, "stride": self.stride,
                "tensor_stride": self.tensor_stride,
                "adopts_output_table": self.adopts_output_table,
                "transpose_of": (None if self.transpose_of is None
                                 else list(self.transpose_of)),
                "table": self.table}

    @staticmethod
    def from_dict(d: dict) -> "KmapSpec":
        known = {f.name for f in dataclasses.fields(KmapSpec)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown KmapSpec fields: {sorted(unknown)}")
        t = d.get("transpose_of")
        return KmapSpec(ref=tuple(d["ref"]), kind=d["kind"],
                        kernel_size=d["kernel_size"], stride=d["stride"],
                        tensor_stride=d["tensor_stride"],
                        adopts_output_table=d.get("adopts_output_table", False),
                        transpose_of=None if t is None else tuple(t),
                        table=d.get("table", "sort"))


#: Structural ops of the execution program.  ("conv", name) runs a LayerPlan;
#: the rest wire skips/residuals/head exactly as the models' hand-written
#: forwards did: push/concat implement U-Net skip connections as a stack,
#: res_begin/res_end bracket a residual block, ("head", pname) is a final
#: dense projection.  A program with no head op returns the last features.
OPS = ("conv", "push", "concat", "res_begin", "res_end", "head")


@dataclasses.dataclass(frozen=True)
class ModelDecl:
    """What a model module declares: its layers, execution program, and
    kernel-map program.  ``compile_plan`` turns this into a NetworkPlan."""

    arch: str
    layers: Tuple[LayerPlan, ...]
    ops: Tuple[Tuple, ...]
    map_specs: Tuple[KmapSpec, ...]


def pyramid_map_specs(levels: int, with_up: bool,
                      sub_kernel: int = 3, down_kernel: int = 2,
                      table: str = "sort") -> Tuple[KmapSpec, ...]:
    """The standard encoder(/decoder) map program: a submanifold map per
    stride level, a strided map per downsample (adopting its output table),
    and — for U-Nets — transposed maps reusing the forward strided maps.
    ``table`` declares the coordinate-table strategy for the whole program
    (see ``KmapSpec.TABLE_STRATEGIES``)."""
    specs = [KmapSpec(("sub", 1), "sub", sub_kernel, 1, 1, table=table)]
    stride = 1
    for _ in range(levels):
        specs.append(KmapSpec(("down", stride), "down", down_kernel, 2, stride,
                              adopts_output_table=True, table=table))
        stride *= 2
        specs.append(KmapSpec(("sub", stride), "sub", sub_kernel, 1, stride,
                              table=table))
    if with_up:
        for lvl in range(levels - 1, -1, -1):
            s = 2 ** lvl
            specs.append(KmapSpec(("up", s), "up", down_kernel, 2, s,
                                  transpose_of=("down", s), table=table))
    return tuple(specs)


def build_maps_from_specs(specs: Sequence[KmapSpec], st: SparseTensor,
                          cache: Optional[MapCache] = None,
                          tables: Optional[dict] = None) -> dict:
    """Execute a kernel-map program.  One ``MapCache`` spans the pyramid:
    submanifold and strided maps at a stride share one sorted table, and
    each ``adopts_output_table`` edge seeds the next level's table for free.
    A caller-supplied warm ``cache`` (the serving engine) is used as-is;
    never reuse one across ``jit`` traces.

    ``tables``: optional pre-composed coordinate tables, as produced by
    ``kmap.compose_batch_tables`` — {tensor_stride: (sorted_keys, order,
    n_valid)}.  The entry at ``st.stride`` (its row order is required)
    replaces the root argsort; deeper entries (identity order, ``order``
    None) are adopted per out-stride so the strided maps skip their
    floor-grid unique argsorts too.  Levels absent from ``tables`` build
    normally — composition degrades gracefully, never changes results.
    """
    if cache is None:   # NOT `or`: an empty caller cache is falsy but wanted
        cache = MapCache.for_tensor(st)
    if tables:
        for s, (keys, order, n) in sorted(tables.items()):
            if s == st.stride:
                assert order is not None, "the root table needs its row order"
                cache.adopt(st.coords, CoordTable(cache.spec, keys, order))
            else:
                cache.adopt_for_stride(s, CoordTable.from_sorted_keys(
                    cache.spec, keys), n)
    maps: dict = {}
    tensors = {st.stride: st}
    for ms in specs:
        cur = tensors[ms.tensor_stride]
        if ms.kind == "sub":
            maps[ms.ref] = build_kmap(cur, ms.kernel_size, 1, cache=cache)
        elif ms.kind == "down":
            kd = build_kmap(cur, ms.kernel_size, ms.stride, cache=cache)
            maps[ms.ref] = kd
            tensors[kd.out_stride] = SparseTensor(
                coords=kd.out_coords,
                feats=jnp.zeros((kd.capacity, 1), st.feats.dtype),
                num_valid=kd.n_out, stride=kd.out_stride,
                batch_bound=st.batch_bound, spatial_bound=st.spatial_bound)
        else:  # "up"
            maps[ms.ref] = transpose_kmap(maps[ms.transpose_of], cur)
    return maps


def scene_entry_arrays(map_specs: Sequence[KmapSpec], st: SparseTensor,
                       root_table: Optional[CoordTable] = None,
                       tables: Optional[dict] = None):
    """The traceable core of a per-scene mapping build: the kernel-map
    stack plus the scene's sorted root table arrays.  ``st`` is a
    single-scene tensor (batch column 0, padding allowed — the serving
    engine buckets scene capacities so this jits once per rung).

    root_table: an already-merged ``CoordTable`` for ``st`` (streaming
    delta path) — adopted so the build skips the scene's root argsort.
    tables: optional pre-composed deeper-level tables (the incremental
    cell-ladder path) — see ``build_maps_from_specs``.
    """
    cache = MapCache.for_tensor(st)
    if root_table is not None:
        cache.adopt(st.coords, root_table)
    maps = build_maps_from_specs(map_specs, st, cache, tables=tables)
    root = cache.table(st)   # cache hit: the table the build sorted/adopted
    return maps, root.sorted_keys, root.order


def scene_entry_from_arrays(map_specs: Sequence[KmapSpec], maps: dict,
                            n: int, root_keys, root_order,
                            root_stride: int = 1) -> SceneEntry:
    """Extract the host-side ``SceneEntry`` from a (possibly padded) scene
    build: numpy kernel-map fields, per-level valid row counts, and the
    root table trimmed to its valid prefix (PAD keys sort last, so the
    first ``n`` entries ARE the exact-size table delta-merge expects)."""
    sizes = {root_stride: n}
    entry_maps: dict = {}
    for ms in map_specs:
        km = maps[ms.ref]
        if ms.kind == "down":
            sizes[km.out_stride] = int(km.n_out)
        entry_maps[ms.ref] = {
            "m_out": np.asarray(km.m_out),
            "out_coords": np.asarray(km.out_coords),
            "ws_in": np.asarray(km.ws_in), "ws_out": np.asarray(km.ws_out),
            "ws_count": np.asarray(km.ws_count),
            "bitmask": np.asarray(km.bitmask),
            "in_stride": ms.tensor_stride * (ms.stride if ms.kind == "up"
                                             else 1),
            "out_stride": km.out_stride, "kernel_size": km.kernel_size,
            "transpose_of": ms.transpose_of}
    return SceneEntry(n=n, sizes=sizes, maps=entry_maps,
                      root_keys=np.asarray(root_keys)[:n],
                      root_order=np.asarray(root_order)[:n])


def build_scene_entry(map_specs: Sequence[KmapSpec], st: SparseTensor,
                      root_table: Optional[CoordTable] = None) -> SceneEntry:
    """Build one scene's cached mapping work for scene-granular composition
    (eager convenience wrapper; the serving engine jits
    ``scene_entry_arrays`` per scene-capacity rung instead)."""
    maps, keys, order = scene_entry_arrays(map_specs, st, root_table)
    return scene_entry_from_arrays(map_specs, maps, int(st.num_valid),
                                   keys, order, root_stride=st.stride)


@dataclasses.dataclass(frozen=True)
class NetworkPlan:
    """The compiled, serializable execution plan of one sparse network."""

    arch: str
    layers: Tuple[LayerPlan, ...]
    ops: Tuple[Tuple, ...]
    map_specs: Tuple[KmapSpec, ...]
    version: int = PLAN_VERSION

    # ------------------------------------------------------------ structure
    def layer(self, name: str) -> LayerPlan:
        for lp in self.layers:
            if lp.name == name:
                return lp
        raise KeyError(name)

    def signatures(self) -> Dict[str, tuple]:
        return {lp.name: lp.sig for lp in self.layers}

    def groups(self) -> list:
        """Tuner groups (``GroupInfo``) over this plan's layers."""
        return partition_groups(self.signatures())

    def assignment(self) -> Dict[tuple, TrainDataflowConfig]:
        """Per-signature dataflow assignment (layers in a group share one)."""
        out: Dict[tuple, TrainDataflowConfig] = {}
        for lp in self.layers:
            out.setdefault(lp.sig, lp.dataflow)
        return out

    # ----------------------------------------------------------- rebinding
    def with_assignment(self, assignment: Dict[tuple, TrainDataflowConfig]
                        ) -> "NetworkPlan":
        """Rebind per-group dataflow configs (tuner output → plan)."""
        layers = tuple(dataclasses.replace(lp, dataflow=assignment[lp.sig])
                       if lp.sig in assignment else lp for lp in self.layers)
        return dataclasses.replace(self, layers=layers)

    @property
    def table_strategy(self) -> str:
        """The map program's declared coordinate-table strategy ("sort" /
        "composed" / "incremental") — read off the root spec."""
        return self.map_specs[0].table if self.map_specs else "sort"

    def with_table_strategy(self, strategy: str) -> "NetworkPlan":
        """Rebind the coordinate-table strategy (a tunable axis like
        dataflow) on every map spec of the program."""
        assert strategy in KmapSpec.TABLE_STRATEGIES, strategy
        specs = tuple(dataclasses.replace(ms, table=strategy)
                      for ms in self.map_specs)
        return dataclasses.replace(self, map_specs=specs)

    def with_precision(self, policy) -> "NetworkPlan":
        """Rebind the numeric policy: one policy for the whole network, or a
        ``{sig: policy}`` dict for per-group mixes."""
        if isinstance(policy, dict):
            layers = tuple(dataclasses.replace(lp, precision=prec.resolve(policy[lp.sig]))
                           if lp.sig in policy else lp for lp in self.layers)
        else:
            pol = prec.resolve(policy)
            layers = tuple(dataclasses.replace(lp, precision=pol)
                           for lp in self.layers)
        return dataclasses.replace(self, layers=layers)

    def resolve_tiles(self, maps: dict, threshold_macs: float = 5e8,
                      measure: Optional[Callable[["NetworkPlan"], float]] = None,
                      candidates: Optional[Sequence[tuple]] = None
                      ) -> "NetworkPlan":
        """Adaptive tiling (paper §6.2): once real kernel maps exist, pick
        each implicit-GEMM layer's (tile_m, tile_n).  Tile sizes only matter
        to the Pallas backend's launch geometry — the math is unchanged.

        With ``measure=None`` (default) tiles come from the MAC heuristic
        (``generator.adaptive_tiles``).  With a ``measure(candidate_plan) →
        seconds`` callable, the Pallas implicit-GEMM *groups* are instead
        retiled by greedy measurement — each group tries every ``candidates``
        pair (default: the generator's tile menu) under end-to-end latency,
        mirroring the dataflow tuner's loop — so the kernel tier is a
        searched axis, not a guessed one."""
        def retile(cfg: df.DataflowConfig, kmap, cin, cout):
            if cfg.dataflow != "implicit_gemm":
                return cfg
            tm, tn = generator.adaptive_tiles(kmap, cin, cout,
                                              threshold_macs=threshold_macs)
            return dataclasses.replace(cfg, tile_m=tm, tile_n=tn)

        layers = []
        for lp in self.layers:
            kmap = maps[lp.map_ref]
            cin, cout = lp.spec.in_channels, lp.spec.out_channels
            cfg3 = TrainDataflowConfig(
                fwd=retile(lp.dataflow.fwd, kmap, cin, cout),
                dgrad=retile(lp.dataflow.dgrad, kmap, cout, cin),
                wgrad=retile(lp.dataflow.wgrad, kmap, cin, cout))
            layers.append(dataclasses.replace(lp, dataflow=cfg3))
        plan = dataclasses.replace(self, layers=tuple(layers))
        if measure is None:
            return plan

        # -------- measured mode: greedy per-group tile search (pallas only)
        cands = tuple(candidates if candidates is not None
                      else dict.fromkeys((generator.SMALL_TILES,
                                          generator.LARGE_TILES, (128, 128))))

        def group_tiles(p: "NetworkPlan", sig: tuple, tm: int,
                        tn: int) -> "NetworkPlan":
            def retile3(cfg: df.DataflowConfig) -> df.DataflowConfig:
                if cfg.dataflow != "implicit_gemm":
                    return cfg
                return dataclasses.replace(cfg, tile_m=tm, tile_n=tn)
            new = tuple(
                dataclasses.replace(lp, dataflow=TrainDataflowConfig(
                    fwd=retile3(lp.dataflow.fwd),
                    dgrad=retile3(lp.dataflow.dgrad),
                    wgrad=retile3(lp.dataflow.wgrad)))
                if lp.sig == sig else lp for lp in p.layers)
            return dataclasses.replace(p, layers=new)

        for g in plan.groups():
            rep = plan.layer(g.layer_names[0])
            fwd = rep.dataflow.fwd
            if not (fwd.backend == "pallas" and fwd.dataflow == "implicit_gemm"):
                continue
            results = []
            for tm, tn in cands:
                trial = group_tiles(plan, rep.sig, tm, tn)
                with obs.span("resolve_tiles_candidate", group=g.name,
                              tiles=f"{tm}x{tn}") as sp:
                    lat = measure(trial)
                    sp.set(latency_ms=lat * 1e3)
                results.append((lat, (tm, tn)))
            _, (tm, tn) = min(results, key=lambda r: r[0])
            plan = group_tiles(plan, rep.sig, tm, tn)
        return plan

    # ----------------------------------------------------------- execution
    def cast_params(self, params: dict) -> dict:
        """Cast each conv layer's parameter leaves to its LayerPlan's
        declared storage dtype (``PrecisionPolicy.params``); BN/head params
        are left untouched (normalization statistics and the final
        projection stay fp32 under the mixed policies).  The single home
        for the bench/example/test param-casting rule."""
        out = dict(params)
        for lp in self.layers:
            out[lp.name] = {k: lp.precision.cast_param(v)
                            for k, v in params[lp.name].items()}
        return out

    def build_maps(self, st: SparseTensor, cache: Optional[MapCache] = None,
                   tables: Optional[dict] = None) -> dict:
        return build_maps_from_specs(self.map_specs, st, cache, tables=tables)

    def split_plan_specs(self) -> Tuple[Tuple[tuple, int, bool], ...]:
        """Deduped (map_ref, n_splits, sorted) triples of every layer whose
        forward dataflow consumes a ``SplitPlan`` (pallas implicit GEMM) —
        the executor inputs the serving engine pre-builds/composes so the
        per-batch bitmask argsorts leave the dispatch hot path."""
        out = []
        for lp in self.layers:
            fwd = lp.dataflow.fwd
            if fwd.backend == "pallas" and fwd.dataflow == "implicit_gemm":
                key = (lp.map_ref, fwd.effective_splits, fwd.sorted)
                if key not in out:
                    out.append(key)
        return tuple(out)

    def build_split_plans(self, maps: dict) -> dict:
        """Fresh (traceable) split plans for every ``split_plan_specs()``
        triple — the cold-batch fallback when no per-scene cached orders
        exist to compose."""
        return {(ref, ns, srt): make_split_plan(maps[ref], ns, sort=srt)
                for ref, ns, srt in self.split_plan_specs()}

    def apply(self, params: dict, st: SparseTensor,
              maps: Optional[dict] = None, bn_mode: str = "batch",
              plans: Optional[dict] = None) -> jax.Array:
        """Run the compiled program.  Bit-identical to the models'
        pre-plan hand-written forwards under the FP32 policy.

        plans: optional pre-built split plans keyed ``(map_ref, n_splits,
        sorted)`` (see ``split_plan_specs``); layers without an entry build
        their plan in-trace as before.
        """
        if maps is None:
            maps = self.build_maps(st)
        by_name = {lp.name: lp for lp in self.layers}
        x = st
        skips: list = []
        resid: list = []
        for op in self.ops:
            kind = op[0]
            if kind == "conv":
                lp = by_name[op[1]]
                fwd = lp.dataflow.fwd
                plan = (plans or {}).get(
                    (lp.map_ref, fwd.effective_splits, fwd.sorted))
                x = apply_conv(params[lp.name], x, maps[lp.map_ref],
                               lp.dataflow, precision=lp.precision, plan=plan)
                if lp.bn:
                    x = bn_relu(params[f"{lp.name}_bn"], x, relu=lp.relu,
                                mode=bn_mode)
            elif kind == "push":
                skips.append(x)
            elif kind == "concat":
                skip = skips.pop()
                x = x.replace_feats(jnp.concatenate([x.feats, skip.feats],
                                                    axis=1))
            elif kind == "res_begin":
                resid.append(x.feats)
            elif kind == "res_end":
                idn = resid.pop()
                y = jax.nn.relu(x.feats +
                                (idn if idn.shape == x.feats.shape else 0))
                x = x.replace_feats(jnp.where(x.valid_mask[:, None], y, 0))
            elif kind == "head":
                return x.feats @ params[op[1]]["w"]
            else:
                raise ValueError(f"unknown plan op {op!r}")
        return x.feats

    # -------------------------------------------------------- serialization
    def to_dict(self) -> dict:
        return {"version": self.version, "arch": self.arch,
                "layers": [lp.to_dict() for lp in self.layers],
                "ops": [list(op) for op in self.ops],
                "map_specs": [ms.to_dict() for ms in self.map_specs]}

    @staticmethod
    def from_dict(d: dict) -> "NetworkPlan":
        version = d.get("version", PLAN_VERSION)
        if version != PLAN_VERSION:
            raise ValueError(f"unsupported NetworkPlan version {version!r} "
                             f"(expected {PLAN_VERSION})")
        known = {"version", "arch", "layers", "ops", "map_specs"}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown NetworkPlan fields: {sorted(unknown)}")
        return NetworkPlan(
            arch=d["arch"],
            layers=tuple(LayerPlan.from_dict(x) for x in d["layers"]),
            ops=tuple(tuple(op) for op in d["ops"]),
            map_specs=tuple(KmapSpec.from_dict(x) for x in d["map_specs"]))


# ---------------------------------------------------------------------------
# The compiler
# ---------------------------------------------------------------------------

def compile_plan(decl: ModelDecl,
                 assignment: Optional[Dict[tuple, TrainDataflowConfig]] = None,
                 precision=None) -> NetworkPlan:
    """Compile a model declaration into a NetworkPlan.

    Partitions the tuner groups from the layers' map-sharing signatures
    (paper Fig. 12), binds the per-group dataflow ``assignment`` (missing
    groups keep the declaration's default), and binds the numeric policy
    (one policy, a ``{sig: policy}`` dict, or None to keep per-layer
    declarations).  Tile resolution (``resolve_tiles``) is a separate step
    because it needs real kernel maps.
    """
    sigs = {lp.name: lp.sig for lp in decl.layers}
    groups = partition_groups(sigs)
    group_of = {name: g.name for g in groups for name in g.layer_names}
    assignment = assignment or {}
    layers = []
    for lp in decl.layers:
        lp = dataclasses.replace(lp, group=group_of[lp.name])
        if lp.sig in assignment:
            lp = dataclasses.replace(lp, dataflow=assignment[lp.sig])
        layers.append(lp)
    nplan = NetworkPlan(arch=decl.arch, layers=tuple(layers), ops=decl.ops,
                        map_specs=decl.map_specs)
    if precision is not None:
        nplan = nplan.with_precision(precision)
    return nplan


# ---------------------------------------------------------------------------
# Plan-producing tuners (paper §4 on top of the IR)
# ---------------------------------------------------------------------------

class PlanTuner:
    """Greedy group tuner that produces a *tuned NetworkPlan*.

    ``measure(candidate_plan)`` must return end-to-end latency (seconds) of
    the workload executed under the candidate plan — never per-kernel time
    (paper Tables 3 vs 4).  Inference binding: all three kernels share the
    group's config (``bind_all``).

    With ``maps`` given, the dataflow search is followed by a *measured*
    tile resolution pass (``NetworkPlan.resolve_tiles(measure=...)``) over
    the Pallas implicit-GEMM groups of the winning assignment — the kernel
    generator's tile axis joins the search instead of staying a heuristic.
    """

    def __init__(self, nplan: NetworkPlan, space: Sequence[df.DataflowConfig],
                 measure: Callable[[NetworkPlan], float],
                 maps: Optional[dict] = None,
                 tile_candidates: Optional[Sequence[tuple]] = None):
        self.nplan = nplan
        self.space = list(space)
        self.measure = measure
        self.maps = maps
        self.tile_candidates = tile_candidates
        self.groups = nplan.groups()
        self.sig_of = {g.name: nplan.layer(g.layer_names[0]).sig
                       for g in self.groups}
        self.log: list = []

    def _plan_for(self, assign: Dict[str, df.DataflowConfig]) -> NetworkPlan:
        amap = {self.sig_of[k]: TrainDataflowConfig.bind_all(v)
                for k, v in assign.items()}
        return self.nplan.with_assignment(amap)

    def tune(self) -> NetworkPlan:
        tuner = Autotuner(self.groups, self.space,
                          lambda assign: self.measure(self._plan_for(assign)))
        best = tuner.tune()
        self.log = tuner.log
        tuned = self._plan_for(best)
        if self.maps is not None:
            tuned = tuned.resolve_tiles(self.maps, measure=self.measure,
                                        candidates=self.tile_candidates)
        return tuned


class TrainingPlanTuner:
    """Two-pass training tuner (partial binding, paper Fig. 13) over plans.

    ``measure(candidate_plan)`` returns end-to-end train-step latency of the
    candidate.  Returns a plan whose layers carry decoupled fwd/dgrad/wgrad
    configs per group.
    """

    def __init__(self, nplan: NetworkPlan, space: Sequence[df.DataflowConfig],
                 measure: Callable[[NetworkPlan], float],
                 scheme: str = "bind_dgrad_wgrad"):
        self.nplan = nplan
        self.space = list(space)
        self.measure = measure
        self.scheme = scheme
        groups = nplan.groups()
        self.sig_of = {g.name: nplan.layer(g.layer_names[0]).sig
                       for g in groups}
        self._tuner = TrainingAutotuner(groups, self.space, self._measure,
                                        scheme=scheme)

    def _measure(self, assign3: Dict[str, TrainDataflowConfig]) -> float:
        amap = {self.sig_of[k]: v for k, v in assign3.items()}
        return self.measure(self.nplan.with_assignment(amap))

    def tune(self) -> NetworkPlan:
        best = self._tuner.tune()
        amap = {self.sig_of[k]: v for k, v in best.items()}
        return self.nplan.with_assignment(amap)
