"""Kernel-map construction: the "mapping operators" of the paper.

A kernel map relates output points to input points for every kernel offset
δ ∈ Δ^D(K).  Two representations exist (paper §4.2) and each dataflow needs
its own:

* **output-stationary** ``m_out[n, k]`` — index of the input neighbor of
  output ``n`` at offset ``k`` (or -1).  Required by implicit GEMM.
* **weight-stationary** ``(ws_in[k, i], ws_out[k, i])`` for ``i < ws_count[k]``
  — the per-offset gather/scatter lists.  Required by gather-GEMM-scatter and
  fetch-on-demand.

On top of the raw map we build the paper's redundancy-reduction machinery:
per-output neighbor **bitmasks**, bitmask **sorting** (Fig. 6), arbitrary
**mask splits** (Fig. 10) and per-(tile, δ) occupancy masks — the TPU analogue
of warp-level skipping (DESIGN.md §2).

Everything is static-shape: maps are built at the capacity of the output
tensor and padded with -1 rows, which is precisely the paper's §3.2 padding
trick (no bounds check in the kernel inner loop).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing
from repro.core.sparse_tensor import INVALID_COORD, SparseTensor


def kernel_offsets(kernel_size: int, ndim: int) -> np.ndarray:
    """Δ^D(K) as an (K^D, D) int array.

    Odd K: centered window {-(K//2)..K//2}^D (submanifold convention).
    Even K: forward window {0..K-1}^D (downsampling convention, e.g. K=2,s=2).
    The *center-first* ordering puts δ=0 (or the lowest corner for even K)
    first: the center offset is always dense for submanifold convs, and
    leading with it makes split 0 the "dense" split.
    """
    if kernel_size % 2 == 1:
        r = range(-(kernel_size // 2), kernel_size // 2 + 1)
    else:
        r = range(kernel_size)
    offs = np.array(list(itertools.product(r, repeat=ndim)), dtype=np.int32)
    # center-first ordering
    norm = np.abs(offs).sum(axis=1)
    order = np.argsort(norm, kind="stable")
    return offs[order]


def _bitmask(hit: jax.Array) -> jax.Array:
    """Neighbor bitmask (paper Fig. 6) in int32.  Kernel volumes ≤ 31 pack
    exactly; larger volumes use a (popcount << 24 | low-24-bits) composite — a
    rank-preserving proxy that keeps rows with similar occupancy adjacent
    after sorting (x64 stays disabled framework-wide)."""
    kd = hit.shape[-1]
    if kd <= 31:
        return jnp.sum(jnp.where(hit, jnp.int32(1) << jnp.arange(kd, dtype=jnp.int32), 0), axis=-1)
    pop = jnp.sum(hit, axis=-1).astype(jnp.int32)
    low = jnp.sum(jnp.where(hit[..., :24], jnp.int32(1) << jnp.arange(24, dtype=jnp.int32), 0), axis=-1)
    return (pop << 24) | low


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class KernelMap:
    """All map representations for one (layer-group) convolution."""

    m_out: jax.Array          # (N_out_cap, KD) int32, -1 = missing
    out_coords: jax.Array     # (N_out_cap, 1+D) int32
    n_out: jax.Array          # () int32
    ws_in: jax.Array          # (KD, cap) int32 gather indices (-1 pad)
    ws_out: jax.Array         # (KD, cap) int32 scatter indices (-1 pad)
    ws_count: jax.Array       # (KD,) int32
    bitmask: jax.Array        # (N_out_cap,) int64 neighbor bitmask (0 pad)
    out_stride: int = dataclasses.field(metadata=dict(static=True), default=1)
    kernel_size: int = dataclasses.field(metadata=dict(static=True), default=3)

    @property
    def volume(self) -> int:
        return self.m_out.shape[1]

    @property
    def capacity(self) -> int:
        return self.m_out.shape[0]


def _unique_coords(coords: jax.Array, valid: jax.Array, capacity: int):
    """Sort-unique of coordinate rows; returns (coords[capacity], count)."""
    big = jnp.int32(jnp.iinfo(jnp.int32).max)
    words = jnp.where(valid[:, None], coords.astype(jnp.int32), big)
    order = hashing.lex_argsort(words)
    coords_s = coords[order]
    valid_s = valid[order]
    same_as_prev = hashing.rows_equal(coords_s[1:], coords_s[:-1])
    is_first = jnp.concatenate([jnp.ones((1,), bool), ~same_as_prev]) & valid_s
    dest = jnp.where(is_first, jnp.cumsum(is_first) - 1, capacity)
    out = jnp.full((capacity + 1, coords.shape[1]), INVALID_COORD, jnp.int32)
    out = out.at[dest].set(coords_s, mode="drop")
    return out[:capacity], jnp.minimum(jnp.sum(is_first), capacity).astype(jnp.int32)


def build_kmap(x: SparseTensor, kernel_size: int, stride: int = 1,
               transposed: bool = False, out_coords: Optional[jax.Array] = None,
               n_out: Optional[jax.Array] = None, out_capacity: Optional[int] = None) -> KernelMap:
    """Build the kernel map for a sparse convolution over ``x``.

    stride == 1                 : submanifold conv, outputs = inputs.
    stride > 1, not transposed  : downsample; outputs = unique(floor-grid).
    transposed                  : upsample (inverse conv); ``out_coords`` (the
        cached finer coordinates) and ``n_out`` must be given.
    """
    d = x.ndim_space
    t = x.stride
    offs = kernel_offsets(kernel_size, d)
    kd = offs.shape[0]
    cap_in = x.capacity
    table = hashing.SortedCoords(x.coords, x.valid_mask)

    if transposed:
        assert out_coords is not None and n_out is not None
        out_stride = t // stride
        assert out_stride >= 1
        n_out_cap = out_capacity or out_coords.shape[0]
        out_coords = out_coords[:n_out_cap]
        # neighbor input coord = out + δ * out_stride mirrored (q = p - δ·t_f)
        delta_scale = -out_stride
    elif stride == 1:
        out_coords, n_out = x.coords, x.num_valid
        out_stride = t
        n_out_cap = out_capacity or cap_in
        out_coords = out_coords[:n_out_cap]
        delta_scale = t
    else:
        out_stride = t * stride
        n_out_cap = out_capacity or cap_in
        grid = jnp.concatenate(
            [x.coords[:, :1],
             (x.coords[:, 1:] // out_stride) * out_stride], axis=1)
        grid = jnp.where(x.valid_mask[:, None], grid, INVALID_COORD)
        out_coords, n_out = _unique_coords(grid, x.valid_mask, n_out_cap)
        delta_scale = t

    out_valid = jnp.arange(n_out_cap) < n_out

    # Output-stationary map: one hash query per offset (vectorized over rows).
    def query(off):
        shift = jnp.concatenate([jnp.zeros((1,), jnp.int32), off * delta_scale])
        q = out_coords + shift[None, :]
        q = jnp.where(out_valid[:, None], q, INVALID_COORD)
        return table.lookup(q)

    m_out = jax.vmap(query, in_axes=0, out_axes=1)(jnp.asarray(offs))  # (N_out_cap, KD)
    m_out = jnp.where(out_valid[:, None], m_out, -1)

    # Weight-stationary lists: stable-compact valid rows of each column.
    hit = m_out >= 0  # (N_out_cap, KD)
    ws_count = jnp.sum(hit, axis=0).astype(jnp.int32)

    def compact(col_hit, col_idx):
        order = jnp.argsort(~col_hit)  # valid rows first, stable
        in_idx = jnp.where(col_hit[order], col_idx[order], -1)
        out_idx = jnp.where(col_hit[order], order, -1)
        return in_idx.astype(jnp.int32), out_idx.astype(jnp.int32)

    ws_in, ws_out = jax.vmap(compact, in_axes=(1, 1), out_axes=0)(hit, m_out)

    bm = jnp.where(out_valid, _bitmask(hit), 0)

    return KernelMap(m_out=m_out, out_coords=out_coords, n_out=jnp.asarray(n_out, jnp.int32),
                     ws_in=ws_in, ws_out=ws_out, ws_count=ws_count, bitmask=bm,
                     out_stride=out_stride, kernel_size=kernel_size)


def transpose_kmap(fwd: KernelMap, x_fine: SparseTensor) -> KernelMap:
    """Kernel map of the inverse (transposed) conv from a cached forward map.

    UNet decoders reuse the encoder's maps (paper: layers in the same *group*
    share maps).  We rebuild output-stationary structure for the fine outputs
    by swapping the weight-stationary pair lists.
    """
    kd = fwd.volume
    cap = x_fine.capacity
    # m_out for the fine side: column k of the transposed conv pairs
    # (in=coarse=fwd ws_out rows, out=fine=fwd ws_in rows).
    def col(k):
        m = jnp.full((cap,), -1, jnp.int32)
        src = fwd.ws_out[k]   # coarse index (input of transposed conv)
        dst = fwd.ws_in[k]    # fine index (output of transposed conv)
        ok = dst >= 0
        return m.at[jnp.where(ok, dst, cap)].set(jnp.where(ok, src, -1), mode="drop")

    m_out = jax.vmap(col, out_axes=1)(jnp.arange(kd))
    bm = _bitmask(m_out >= 0)
    return KernelMap(m_out=m_out, out_coords=x_fine.coords, n_out=x_fine.num_valid,
                     ws_in=fwd.ws_out, ws_out=fwd.ws_in, ws_count=fwd.ws_count,
                     bitmask=bm, out_stride=x_fine.stride, kernel_size=fwd.kernel_size)


# ---------------------------------------------------------------------------
# Sorting + mask splits (Sparse Autotuner design-space, paper §4.1)
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SplitPlan:
    """Row orders and offset ranges for s-split (un)sorted implicit GEMM.

    order[s]   : (N_out_cap,) permutation of output rows for split s.
    inv_order[s]: inverse permutations (to undo the reordering on write-back).
    ranges     : static ((start, end), ...) partition of the KD offsets.
    sorted_    : False ⇒ identity order (paper's "unsorted", split=0 case).
    """

    order: jax.Array       # (S, N_out_cap) int32
    inv_order: jax.Array   # (S, N_out_cap) int32
    ranges: Tuple[Tuple[int, int], ...] = dataclasses.field(metadata=dict(static=True))
    sorted_: bool = dataclasses.field(metadata=dict(static=True), default=True)

    @property
    def num_splits(self) -> int:
        return len(self.ranges)


def split_ranges(volume: int, n_splits: int) -> Tuple[Tuple[int, int], ...]:
    """Partition KD offsets into ~equal contiguous ranges."""
    n_splits = max(1, min(n_splits, volume))
    bounds = np.linspace(0, volume, n_splits + 1).round().astype(int)
    return tuple((int(bounds[i]), int(bounds[i + 1])) for i in range(n_splits))


def make_split_plan(kmap: KernelMap, n_splits: int, sort: bool = True) -> SplitPlan:
    """Paper Fig. 10: split the δ loop into s parts, argsort each split's
    bitmask independently and reorder rows per split.  ``n_splits=1, sort``
    reproduces SpConv v2 (Fig. 6); ``sort=False`` is the unsorted dataflow
    (Fig. 5) the paper re-adds to the design space."""
    ranges = split_ranges(kmap.volume, n_splits)
    cap = kmap.capacity
    hit = kmap.m_out >= 0
    valid = jnp.arange(cap) < kmap.n_out

    orders = []
    for (a, b) in ranges:
        if not sort:
            orders.append(jnp.arange(cap, dtype=jnp.int32))
            continue
        bm = _bitmask(hit[:, a:b])
        # valid rows first (sorted by bitmask), padding last
        key = jnp.where(valid, bm, jnp.iinfo(jnp.int32).max)
        orders.append(jnp.argsort(key).astype(jnp.int32))
    order = jnp.stack(orders)
    inv = jax.vmap(lambda o: jnp.argsort(o).astype(jnp.int32))(order)
    return SplitPlan(order=order, inv_order=inv, ranges=ranges, sorted_=sort)


def tile_occupancy(kmap: KernelMap, plan: SplitPlan, tile_m: int) -> jax.Array:
    """Per-(split, tile, δ) occupancy: 1 iff any row of the tile has a
    neighbor at δ within the split's range (else the whole MXU tile matmul is
    skipped — the TPU analogue of warp-level zero skipping).

    Returns (S, n_tiles, KD) int32 (columns outside the split's range are 0).
    """
    cap = kmap.capacity
    assert cap % tile_m == 0, "capacity must be padded to tile_m (paper §3.2)"
    n_tiles = cap // tile_m
    hit = (kmap.m_out >= 0).astype(jnp.int32)

    def per_split(order, rng):
        a, b = rng
        h = hit[order].reshape(n_tiles, tile_m, kmap.volume)
        occ = jnp.max(h, axis=1)
        col_in_range = (jnp.arange(kmap.volume) >= a) & (jnp.arange(kmap.volume) < b)
        return occ * col_in_range[None, :].astype(jnp.int32)

    return jnp.stack([per_split(plan.order[i], r) for i, r in enumerate(plan.ranges)])


def redundancy_stats(kmap: KernelMap, plan: SplitPlan, tile_m: int) -> dict:
    """Effective vs issued MACs (paper Fig. 11): issued = Σ occupied tiles ×
    tile_m; effective = Σ hits.  The autotuner's analytic cost model reads
    these."""
    occ = tile_occupancy(kmap, plan, tile_m)
    issued_rows = jnp.sum(occ) * tile_m
    effective = jnp.sum(kmap.m_out >= 0)
    return dict(issued_rows=issued_rows, effective_rows=effective,
                overhead=issued_rows / jnp.maximum(effective, 1))
