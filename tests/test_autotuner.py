"""Sparse Autotuner: group partition, greedy search, training binding."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dataflows as df
from repro.core import generator
from repro.core.autotuner import Autotuner, GroupInfo, TrainingAutotuner, partition_groups
from repro.core.sparse_conv import TrainDataflowConfig


def test_partition_groups_by_signature():
    sigs = {"conv_a": (1, 3, "sub"), "conv_b": (1, 3, "sub"),
            "down": (1, 2, "down"), "conv_c": (2, 3, "sub")}
    groups = partition_groups(sigs)
    assert len(groups) == 3
    sizes = sorted(len(g.layer_names) for g in groups)
    assert sizes == [1, 1, 2]


def _synthetic_measure(latency_table):
    """End-to-end latency = Σ_g table[g][cfg] (+ fixed overhead)."""
    def measure(assign):
        return 1.0 + sum(latency_table[g][c] for g, c in assign.items())

    return measure


def test_greedy_finds_per_group_optimum():
    space = generator.design_space()
    groups = [GroupInfo("g0", ["a"]), GroupInfo("g1", ["b"])]
    rng = np.random.default_rng(0)
    table = {g.name: {c: float(rng.uniform(1, 10)) for c in space} for g in groups}
    tuner = Autotuner(groups, space, _synthetic_measure(table))
    best = tuner.tune()
    for g in groups:
        assert table[g.name][best[g.name]] == min(table[g.name].values())
    # tuner complexity is linear: |groups| × |space| measurements
    assert len(tuner.log) == len(groups) * len(space)


def test_design_space_is_superset_of_spconv2():
    full = generator.design_space()
    sub = generator.spconv_v2_space()
    assert set(sub) <= set(full)
    # the paper's additions: unsorted (splits=0), splits > 2, fetch-on-demand
    assert any(c.dataflow == "implicit_gemm" and c.n_splits == 0 for c in full)
    assert any(c.dataflow == "implicit_gemm" and c.n_splits > 2 for c in full)
    assert any(c.dataflow == "fetch_on_demand" for c in full)


def test_training_tuner_binding_schemes():
    space = [df.DataflowConfig("gather_scatter"),
             df.DataflowConfig("implicit_gemm", n_splits=1)]
    groups = [GroupInfo("g0", ["a"])]

    # build a measure where fwd prefers implicit, wgrad prefers gather
    def measure(assign):
        t = 0.0
        for g, c3 in assign.items():
            t += 1.0 if c3.fwd.dataflow == "implicit_gemm" else 2.0
            t += 1.0 if c3.dgrad.dataflow == "implicit_gemm" else 2.0
            t += 1.0 if c3.wgrad.dataflow == "gather_scatter" else 3.0
        return t

    for scheme in ("bind_fwd_dgrad", "bind_dgrad_wgrad", "bind_all"):
        out = TrainingAutotuner(groups, space, measure, scheme).tune()["g0"]
        assert isinstance(out, TrainDataflowConfig)

    # bind_fwd_dgrad can reach the true optimum here
    out = TrainingAutotuner(groups, space, measure, "bind_fwd_dgrad").tune()["g0"]
    assert out.fwd.dataflow == "implicit_gemm"
    assert out.dgrad.dataflow == "implicit_gemm"
    assert out.wgrad.dataflow == "gather_scatter"


def test_scheme_choice_by_device():
    assert TrainingAutotuner.choose_scheme(high_parallelism=True) == "bind_dgrad_wgrad"
    assert TrainingAutotuner.choose_scheme(high_parallelism=False) == "bind_fwd_dgrad"


def test_adaptive_tiles_switch_on_macs():
    from repro.core.kmap import build_kmap
    from tests.test_kmap import random_tensor

    stx = random_tensor(0, n=60, cap=64, channels=4)
    kmap = build_kmap(stx, 3, 1)
    small = generator.adaptive_tiles(kmap, 4, 8, threshold_macs=1e12)
    large = generator.adaptive_tiles(kmap, 4, 8, threshold_macs=1.0)
    assert small == generator.SMALL_TILES
    assert large == generator.LARGE_TILES
