"""Paper Fig. 16 — R-GCN on heterogeneous graphs: the sparse-conv dataflows
vs a dense one-hot baseline (the DGL/PyG-style segment formulation without
relation batching)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import dataflows as df
from repro.core.graph_conv import edges_to_kmap, rgcn_layer
from repro.data.synthetic import typed_graph


def _dense_onehot_rgcn(feats, w_rel, w_self, src, dst, etype, n_nodes):
    """Baseline: per-edge gather → per-edge relation one-hot weighting →
    scatter (≈ unbatched message passing, the slow path in DGL/PyG)."""
    msgs = jnp.einsum("ec,rcf->erf", feats[src], w_rel)           # (E,R,F)
    oh = jax.nn.one_hot(etype, w_rel.shape[0], dtype=feats.dtype)
    m = jnp.einsum("erf,er->ef", msgs, oh)
    out = jnp.zeros((n_nodes, w_rel.shape[-1]), feats.dtype).at[dst].add(m)
    return out + feats @ w_self


def run():
    datasets = {  # name: (nodes, edges, relations) — AIFB/MUTAG-like scales
        "aifb-like": (1024, 8192, 8),
        "mutag-like": (2048, 16384, 4),
        "bgs-like": (4096, 24576, 12),
    }
    for name, (n, e, r) in datasets.items():
        src, dst, etype = typed_graph(jax.random.PRNGKey(0), n, e, r)
        c = 16
        feats = jax.random.normal(jax.random.PRNGKey(1), (n, c))
        w_rel = jax.random.normal(jax.random.PRNGKey(2), (r, c, c)) * 0.2
        w_self = jax.random.normal(jax.random.PRNGKey(3), (c, c)) * 0.2
        kmap = edges_to_kmap(src, dst, etype, r, n, cap_per_rel=e)

        lats = {}
        fn_d = jax.jit(lambda f: _dense_onehot_rgcn(f, w_rel, w_self, src, dst, etype, n))
        lats["dense_onehot(DGL-like)"] = common.time_fn(lambda: fn_d(feats))
        for dn, cfg in (("gather_scatter", df.DataflowConfig("gather_scatter")),
                        ("fetch_on_demand", df.DataflowConfig("fetch_on_demand"))):
            fn = jax.jit(lambda f: rgcn_layer(f, w_rel, w_self, kmap, cfg=cfg,
                                              normalize=False))
            lats[f"torchsparse++/{dn}"] = common.time_fn(lambda: fn(feats))
        worst = max(lats.values())
        for k, us in lats.items():
            common.emit(f"fig16/{name}/{k}", us, f"speedup_vs_worst={worst / us:.2f}x")


if __name__ == "__main__":
    run()
