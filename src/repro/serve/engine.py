"""The sparse serving engine: submit()/flush() over bucketed batched scenes.

Ties the subsystem together (DESIGN: ISSUE 2 tentpole):

* requests (variable-size scenes) queue in a ``SceneBatcher`` and pack FIFO
  into capacity-bucketed batched ``SparseTensor``s with declared bounds —
  every served batch takes the single-argsort packed-key mapping path;
* each bucket capacity owns two pre-jitted stages: a **map builder**
  (``build_maps`` under one trace, so the per-trace ``MapCache`` shares
  sorted tables across the layer pyramid) and an **executor** (the model
  forward in inference-mode normalization).  Static bucket shapes bound jit
  recompiles to one per (bucket, stage) for the engine's lifetime;
* built kernel maps are reused **across requests**: batches are keyed by a
  content digest of their packed coordinates, and a small LRU maps digest →
  device-resident map stack (Minuet's observation, lifted from layers to
  requests — repeated frames/scenes skip mapping entirely);
* the engine executes a compiled ``core.plan.NetworkPlan`` — the same
  artifact the models and the training stack run — loaded from a
  ``PlanRegistry`` at startup when one was persisted (tune once, serve
  forever; v1 assignment-only files recompile the plan from the model
  declaration) and re-tuned in place by ``tune()``;
* latency/throughput stats: per-scene p50/p95, scenes/s, recompile and
  map-cache counters.

The correctness contract — asserted in tests/test_serving.py — is that the
batched engine output is bit-identical to the per-scene forward at the same
bucket capacity: batching only ever adds rows whose keys can't collide with
another scene's (batch index is packed into every voxel key) and
inference-mode normalization keeps every output row a function of its own
scene's rows.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.core import dataflows as df
from repro.core.autotuner import timeit_fn
from repro.core.plan import NetworkPlan, PlanTuner
from repro.core.sparse_conv import TrainDataflowConfig
from repro.core.sparse_tensor import SparseTensor
from repro.models import centerpoint, minkunet
from repro.serve.batcher import PackedBatch, Scene, SceneBatcher, SceneResult
from repro.serve.bucketing import BucketLadder
from repro.serve.plans import PlanRegistry


@dataclasses.dataclass(frozen=True)
class ArchBinding:
    """Everything the engine needs to serve one sparse architecture."""

    name: str
    model: object                       # module: init_params/build_maps/apply/layer_signatures
    default_config: object
    out_stride_of: Callable[[object], int]
    outputs_of: Callable[[object, SparseTensor, dict, jax.Array], tuple]
    in_channels_of: Callable[[object], int]


def _minkunet_outputs(cfg, st, maps, feats):
    # logits are per input voxel: rows align with the stride-1 input coords
    return st.coords, feats, st.num_valid


def _centerpoint_outputs(cfg, st, maps, feats):
    s = 2 ** len(cfg.channels)
    km = maps[("sub", s)]
    return km.out_coords, feats, km.n_out


def _arch_bindings() -> Dict[str, ArchBinding]:
    from repro.configs import centerpoint_waymo, minkunet_kitti

    return {
        "minkunet_kitti": ArchBinding(
            name="minkunet_kitti", model=minkunet,
            default_config=minkunet_kitti.CONFIG_BENCH,
            out_stride_of=lambda cfg: 1,
            outputs_of=_minkunet_outputs,
            in_channels_of=lambda cfg: cfg.in_channels),
        "centerpoint_waymo": ArchBinding(
            name="centerpoint_waymo", model=centerpoint,
            default_config=centerpoint_waymo.CONFIG_BENCH,
            out_stride_of=lambda cfg: 2 ** len(cfg.channels),
            outputs_of=_centerpoint_outputs,
            in_channels_of=lambda cfg: cfg.in_channels),
    }


ARCHS = _arch_bindings()

DEFAULT_LADDER = BucketLadder.geometric(base=512, steps=3, max_batch=4)
DEFAULT_SPATIAL_BOUND = 256


#: per-scene latencies kept for percentile stats; bounded so a
#: tune-once-serve-forever process doesn't grow memory with uptime
LATENCY_WINDOW = 8192


@dataclasses.dataclass
class EngineStats:
    submitted: int = 0
    completed: int = 0
    batches: int = 0
    flushes: int = 0
    busy_s: float = 0.0
    latencies_ms: "collections.deque" = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=LATENCY_WINDOW))
    recompiles: Dict[int, int] = dataclasses.field(default_factory=dict)
    map_compiles: Dict[int, int] = dataclasses.field(default_factory=dict)
    map_hits: int = 0
    map_misses: int = 0

    def summary(self) -> dict:
        lat = np.asarray(self.latencies_ms) if self.latencies_ms else np.zeros(1)
        return {
            "scenes": self.completed,
            "batches": self.batches,
            "p50_ms": float(np.percentile(lat, 50)),
            "p95_ms": float(np.percentile(lat, 95)),
            "scenes_per_s": self.completed / self.busy_s if self.busy_s else 0.0,
            "recompiles": dict(self.recompiles),
            "map_compiles": dict(self.map_compiles),
            "map_cache": {"hits": self.map_hits, "misses": self.map_misses},
        }


class Engine:
    """Front end: ``submit()`` scenes, ``flush()`` to run queued work.

    arch: "minkunet_kitti" | "centerpoint_waymo" (see ``ARCHS``).
    plans: a PlanRegistry (or path to one) holding tuned per-group dataflow
        assignments; missing entries fall back to the default config.
    """

    def __init__(self, arch: str, ladder: BucketLadder = DEFAULT_LADDER,
                 spatial_bound: int = DEFAULT_SPATIAL_BOUND,
                 model_config=None, params=None,
                 plans: Optional[PlanRegistry] = None,
                 maps_cache_size: int = 32, seed: int = 0,
                 precision=None):
        if arch not in ARCHS:
            raise ValueError(f"unknown arch {arch!r}; have {sorted(ARCHS)}")
        self.binding = ARCHS[arch]
        self.arch = arch
        self.cfg = model_config if model_config is not None else self.binding.default_config
        self.params = params if params is not None else self.binding.model.init_params(
            self.cfg, jax.random.PRNGKey(seed))
        self.ladder = ladder
        self.batcher = SceneBatcher(ladder, spatial_bound)
        if isinstance(plans, str):
            plans = PlanRegistry.load(plans)
        self.plans = plans or PlanRegistry()
        self.assignment = self.plans.get(arch)
        # The compiled artifact every stage shares: a persisted NetworkPlan
        # is used as-is when it still matches this engine's model config
        # (same layer names + ConvSpecs); otherwise — v1 files, or a plan
        # tuned under a different width/depth — one is recompiled from the
        # model declaration with the registry's assignment.
        nplan = self.plans.network(arch)
        compiled = self.binding.model.network_plan(self.cfg,
                                                   assignment=self.assignment)
        if nplan is None or [(lp.name, lp.spec) for lp in nplan.layers] != \
                [(lp.name, lp.spec) for lp in compiled.layers]:
            nplan = compiled
        if precision is not None:
            nplan = nplan.with_precision(precision)
        self.nplan: NetworkPlan = nplan
        self.out_stride = self.binding.out_stride_of(self.cfg)
        self.stats = EngineStats()
        self.maps_cache_size = maps_cache_size
        self._queue: List[tuple] = []       # (ticket, Scene, t_submit)
        self._next_ticket = 0
        self._map_store: "collections.OrderedDict[str, dict]" = collections.OrderedDict()
        self._builders: Dict[int, Callable] = {}
        self._executors: Dict[int, Callable] = {}

    # ------------------------------------------------------------------ jit
    def _builder_for(self, cap: int) -> Callable:
        fn = self._builders.get(cap)
        if fn is None:
            nplan = self.nplan

            def build(st):
                # trace-time side effect: counts actual recompiles, not calls
                self.stats.map_compiles[cap] = self.stats.map_compiles.get(cap, 0) + 1
                return nplan.build_maps(st)

            fn = jax.jit(build)
            self._builders[cap] = fn
        return fn

    def _executor_for(self, cap: int) -> Callable:
        fn = self._executors.get(cap)
        if fn is None:
            binding, cfg, nplan = self.binding, self.cfg, self.nplan

            def run(params, st, maps):
                self.stats.recompiles[cap] = self.stats.recompiles.get(cap, 0) + 1
                feats = nplan.apply(params, st, maps, bn_mode="affine")
                return binding.outputs_of(cfg, st, maps, feats)

            fn = jax.jit(run)
            self._executors[cap] = fn
        return fn

    def _maps_for(self, batch: PackedBatch) -> dict:
        maps = self._map_store.get(batch.digest)
        if maps is not None:
            self.stats.map_hits += 1
            self._map_store.move_to_end(batch.digest)
            return maps
        self.stats.map_misses += 1
        maps = self._builder_for(batch.bucket)(batch.st)
        self._map_store[batch.digest] = maps
        while len(self._map_store) > self.maps_cache_size:
            self._map_store.popitem(last=False)
        return maps

    # ------------------------------------------------------------------ api
    def submit(self, scene: Scene) -> int:
        """Enqueue one scene; returns a ticket resolved by the next flush."""
        if scene.num_points > self.ladder.max_capacity:
            raise ValueError(f"scene of {scene.num_points} rows exceeds the "
                             f"largest bucket ({self.ladder.max_capacity})")
        t = self._next_ticket
        self._next_ticket += 1
        self._queue.append((t, scene, time.perf_counter()))
        self.stats.submitted += 1
        return t

    def flush(self) -> Dict[int, SceneResult]:
        """Pack and run everything queued; returns {ticket: SceneResult}."""
        if not self._queue:
            return {}
        queue, self._queue = self._queue, []
        t0 = time.perf_counter()
        results: Dict[int, SceneResult] = {}
        groups = self.batcher.plan([s.num_points for _, s, _ in queue])
        for group in groups:
            batch = self.batcher.pack([queue[i][1] for i in group])
            maps = self._maps_for(batch)
            out_coords, out_feats, n_out = jax.block_until_ready(
                self._executor_for(batch.bucket)(self.params, batch.st, maps))
            per_scene = self.batcher.unpack(batch, out_coords, out_feats,
                                            int(n_out), self.out_stride)
            t_done = time.perf_counter()
            for slot, i in enumerate(group):
                ticket, _, t_sub = queue[i]
                results[ticket] = per_scene[slot]
                self.stats.latencies_ms.append((t_done - t_sub) * 1e3)
            self.stats.batches += 1
            self.stats.completed += len(group)
        self.stats.busy_s += time.perf_counter() - t0
        self.stats.flushes += 1
        return results

    def serve(self, scenes: Sequence[Scene],
              flush_every: int = 0) -> List[SceneResult]:
        """Convenience driver: submit all, flush (in chunks), return in order."""
        out: Dict[int, SceneResult] = {}
        tickets = []
        for i, s in enumerate(scenes):
            tickets.append(self.submit(s))
            if flush_every and (i + 1) % flush_every == 0:
                out.update(self.flush())
        out.update(self.flush())
        return [out[t] for t in tickets]

    def warmup(self, channels: Optional[int] = None) -> None:
        """Compile every bucket once on synthetic single-scene batches so the
        request stream never pays a trace."""
        c = channels or self.binding.in_channels_of(self.cfg)
        for cap in self.ladder.capacities:
            n = cap   # fill the bucket exactly so every rung compiles
            rng = np.random.default_rng(cap)
            coords = rng.integers(-self.batcher.spatial_bound,
                                  self.batcher.spatial_bound, size=(n, 3),
                                  dtype=np.int32)
            scene = Scene(coords=coords, feats=rng.normal(size=(n, c)).astype(np.float32))
            batch = self.batcher.pack([scene])
            assert batch.bucket == cap, (batch.bucket, cap)
            maps = self._maps_for(batch)
            jax.block_until_ready(
                self._executor_for(batch.bucket)(self.params, batch.st, maps))

    # ------------------------------------------------------------- autotune
    def tune(self, sample_scenes: Sequence[Scene],
             space: Optional[Sequence[df.DataflowConfig]] = None,
             iters: int = 2, save: bool = True) -> Dict[tuple, TrainDataflowConfig]:
        """Run the group-based Sparse Autotuner on a representative packed
        batch and persist the winning *NetworkPlan* to the PlanRegistry.

        Measurement is end-to-end engine-forward latency of each candidate
        plan (paper §4: never per-kernel time).  Existing executors are
        dropped so the tuned plan takes effect on the next flush.  Returns
        the per-group assignment for inspection; the serialized plan (and
        its v1-compatible assignment block) lands in the registry.
        """
        space = list(space or [df.DataflowConfig("gather_scatter"),
                               df.DataflowConfig("implicit_gemm", n_splits=1)])
        sample_scenes = list(sample_scenes)
        # measure on the first bucket-fitting FIFO group of the sample
        group = self.batcher.plan([s.num_points for s in sample_scenes])[0]
        batch = self.batcher.pack([sample_scenes[i] for i in group])
        maps = self._maps_for(batch)

        def measure(candidate: NetworkPlan) -> float:
            fn = jax.jit(lambda p, st, m: candidate.apply(p, st, m,
                                                          bn_mode="affine"))
            return timeit_fn(lambda: jax.block_until_ready(
                fn(self.params, batch.st, maps)), warmup=1, iters=iters)

        tuned = PlanTuner(self.nplan, space, measure).tune()
        self.nplan = tuned
        self.assignment = tuned.assignment()
        self.plans.set(self.arch, self.assignment, network=tuned)
        if save and self.plans.path:
            self.plans.save()
        self._executors.clear()   # recompile with the tuned plan
        return dict(self.assignment)
