"""Step builders shared by dryrun / train / serve launchers.

Everything here returns (step_fn, example_args_as_ShapeDtypeStructs,
in_shardings, donate_argnums) so the launcher can ``jit(...).lower(...)``
without allocating a single parameter.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import base as cfgbase
from repro.models import api
from repro.models.lm_common import ArchConfig, ShardCtx
from repro.train import optimizer as opt


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def batch_pspecs(cfg: ArchConfig, shape: cfgbase.ShapeCfg, ctx: ShardCtx):
    b = ctx.b
    specs = {}
    if shape.kind in ("train", "prefill"):
        if cfg.embed_input:
            specs["tokens"] = P(b, None)
        else:
            specs["embeds"] = P(b, None, None)
        if shape.kind == "train":
            specs["labels"] = P(b, None)
        if cfg.cross_every:
            specs["img_emb"] = P(b, None, None)
        return specs
    raise ValueError(shape.kind)


def cache_pspecs(cfg: ArchConfig, cache_sds, ctx: ShardCtx):
    """Partition the KV/SSM caches: batch over data axes when divisible,
    else sequence (context parallelism for the B=1 long_500k cell); heads /
    d_inner over the model axis."""
    from repro.models.lm_common import _axes_size

    dp = _axes_size(ctx)

    def spec(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        shp = leaf.shape
        if name == "pos":
            return P()
        if name in ("k", "v", "img_k", "img_v"):
            # (L, B, T, Hkv, hd)
            bspec = ctx.b if shp[1] % dp == 0 else None
            tspec = None if bspec is not None else ("data" if shp[2] % ctx.mesh.shape["data"] == 0 else None)
            return P(None, bspec, tspec, ctx.heads(shp[3]), None)
        if name == "conv":
            # (L, B, K-1, d_in)
            bspec = ctx.b if shp[1] % dp == 0 else None
            return P(None, bspec, None, ctx.heads(shp[3]))
        if name == "ssm":
            # mamba1 (L,B,d_in,N) / mamba2 (L,B,H,N,P)
            bspec = ctx.b if shp[1] % dp == 0 else None
            return P(None, bspec, ctx.heads(shp[2]), *([None] * (len(shp) - 3)))
        return P()

    return jax.tree_util.tree_map_with_path(spec, cache_sds)


def make_train_step(cfg: ArchConfig, ctx: ShardCtx, opt_cfg: Optional[opt.AdamWConfig] = None):
    opt_cfg = opt_cfg or opt.AdamWConfig(factored=cfg.params_count() > 2e11)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(partial(api.loss_fn, cfg, ctx=ctx))(params, batch)
        new_params, new_state, gnorm = opt.adamw_update(params, grads, opt_state, opt_cfg)
        return new_params, new_state, {"loss": loss, "grad_norm": gnorm}

    return train_step, opt_cfg


def lowerable_train(cfg: ArchConfig, shape: cfgbase.ShapeCfg, mesh, ctx: ShardCtx,
                    opt_cfg: Optional[opt.AdamWConfig] = None):
    params_sds = jax.eval_shape(lambda: api.init_params(cfg, jax.random.PRNGKey(0)))
    pspecs = api.param_pspecs(cfg, params_sds, ctx)
    train_step, opt_cfg = make_train_step(cfg, ctx, opt_cfg)
    opt_sds = jax.eval_shape(lambda: opt.init_opt_state(params_sds, opt_cfg))
    opt_specs = _opt_pspecs(pspecs, opt_sds)
    batch_sds = cfgbase.input_specs(cfg, shape)
    bspecs = batch_pspecs(cfg, shape, ctx)
    jitted = jax.jit(train_step,
                     in_shardings=(_named(mesh, pspecs), _named(mesh, opt_specs),
                                   _named(mesh, bspecs)),
                     out_shardings=(_named(mesh, pspecs), _named(mesh, opt_specs), None),
                     donate_argnums=(0, 1))
    return jitted, (params_sds, opt_sds, batch_sds)


def _opt_pspecs(param_pspecs, opt_sds):
    """Adam state specs mirror the params; factored leaves drop reduced dims."""
    def per_leaf(spec, state_leaf):
        def pad(s, rank):
            e = list(s) + [None] * (rank - len(s))
            return e

        m_rank = state_leaf["m"].ndim
        e = pad(spec, m_rank)
        out = {"m": P(*e)}
        if "v" in state_leaf:
            out["v"] = P(*e)
        else:
            out["vr"] = P(*e[:-1])
            out["vc"] = P(*(e[:-2] + [e[-1]]))
        return out

    return {"mu": jax.tree.map(per_leaf, param_pspecs, opt_sds["mu"],
                               is_leaf=lambda x: isinstance(x, P)),
            "step": P()}


def lowerable_prefill(cfg: ArchConfig, shape: cfgbase.ShapeCfg, mesh, ctx: ShardCtx):
    params_sds = jax.eval_shape(lambda: api.init_params(cfg, jax.random.PRNGKey(0)))
    pspecs = api.param_pspecs(cfg, params_sds, ctx)
    batch_sds = cfgbase.input_specs(cfg, shape)
    bspecs = batch_pspecs(cfg, shape, ctx)

    def prefill_step(params, batch):
        cache = api.init_cache(cfg, shape.batch, shape.seq)
        inp = batch.get("tokens", batch.get("embeds"))
        return api.prefill(cfg, params, inp, cache, ctx,
                           img_emb=batch.get("img_emb"))

    jitted = jax.jit(prefill_step,
                     in_shardings=(_named(mesh, pspecs), _named(mesh, bspecs)))
    return jitted, (params_sds, batch_sds)


def lowerable_decode(cfg: ArchConfig, shape: cfgbase.ShapeCfg, mesh, ctx: ShardCtx):
    params_sds = jax.eval_shape(lambda: api.init_params(cfg, jax.random.PRNGKey(0)))
    pspecs = api.param_pspecs(cfg, params_sds, ctx)
    specs = cfgbase.input_specs(cfg, shape)
    cache_sds, token_sds = specs["cache"], specs["token"]
    cspecs = cache_pspecs(cfg, cache_sds, ctx)
    from repro.models.lm_common import _axes_size

    tok_spec = P(ctx.b) if token_sds.shape[0] % _axes_size(ctx) == 0 else P(None)
    if token_sds.ndim == 2:
        tok_spec = P(*tok_spec, None)

    def serve_step(params, cache, token):
        return api.decode_step(cfg, params, cache, token, ctx)

    jitted = jax.jit(serve_step,
                     in_shardings=(_named(mesh, pspecs), _named(mesh, cspecs),
                                   NamedSharding(mesh, tok_spec)),
                     out_shardings=(None, _named(mesh, cspecs)),
                     donate_argnums=(1,))
    return jitted, (params_sds, cache_sds, token_sds)


def lowerable(cfg, shape, mesh, ctx, opt_cfg=None):
    if shape.kind == "train":
        jitted, args = lowerable_train(cfg, shape, mesh, ctx, opt_cfg)
    elif shape.kind == "prefill":
        jitted, args = lowerable_prefill(cfg, shape, mesh, ctx)
    else:
        jitted, args = lowerable_decode(cfg, shape, mesh, ctx)
    return jitted, args
