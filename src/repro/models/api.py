"""Unified model API: family → (init, loss, prefill, decode, cache, specs)."""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.models import mamba, mamba2, transformer
from repro.models.lm_common import ArchConfig, NO_SHARD, ShardCtx, make_pspecs

_FAMILY_MOD = {
    "dense": transformer, "moe": transformer, "vlm": transformer,
    "audio": transformer, "ssm": mamba, "hybrid": mamba2,
}


def model_module(cfg: ArchConfig):
    return _FAMILY_MOD[cfg.family]


def init_params(cfg: ArchConfig, key):
    return model_module(cfg).init_params(cfg, key)


def param_pspecs(cfg: ArchConfig, params, ctx: ShardCtx):
    expert_sharded = cfg.moe.shard_experts if cfg.moe else True
    return make_pspecs(params, ctx, expert_sharded=expert_sharded)


def loss_fn(cfg: ArchConfig, params, batch, ctx: ShardCtx = NO_SHARD):
    return model_module(cfg).loss_fn(cfg, params, batch, ctx)


def init_cache(cfg: ArchConfig, batch: int, max_len: int):
    return model_module(cfg).init_cache(cfg, batch, max_len)


def decode_step(cfg: ArchConfig, params, cache, token, ctx: ShardCtx = NO_SHARD):
    return model_module(cfg).decode_step(cfg, params, cache, token, ctx)


def prefill(cfg: ArchConfig, params, tokens, cache, ctx: ShardCtx = NO_SHARD, **kw):
    return model_module(cfg).prefill(cfg, params, tokens, cache, ctx, **kw)
