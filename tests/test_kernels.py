"""Per-kernel shape/dtype sweeps against the ref.py pure-jnp oracles
(interpret mode on CPU; these kernels target TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dataflows as df
from repro.core import kmap as km
from repro.kernels.fetch_on_demand.ops import fetch_on_demand
from repro.kernels.fetch_on_demand.ref import fetch_on_demand_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import mha_ref
from repro.kernels.implicit_gemm.ops import implicit_gemm
from repro.kernels.implicit_gemm.ref import implicit_gemm_ref
from tests.test_kmap import random_tensor


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("tile_m,tile_n", [(8, 8), (16, 16)])
@pytest.mark.parametrize("splits,sort", [(1, True), (2, True), (3, True), (1, False)])
def test_implicit_gemm_sweep(dtype, tile_m, tile_n, splits, sort):
    stx = random_tensor(11, n=90, cap=128, channels=8, extent=7)
    kmap = km.build_kmap(stx, 3, 1)
    w = (jax.random.normal(jax.random.PRNGKey(1), (27, 8, 16)) * 0.3).astype(dtype)
    x = stx.feats.astype(dtype)
    plan = km.make_split_plan(kmap, splits, sort=sort)
    got = implicit_gemm(x, w, kmap, plan, tile_m=tile_m, tile_n=tile_n, interpret=True)
    ref = implicit_gemm_ref(x, w, kmap.m_out)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(ref, np.float32), **tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("cin,cout", [(8, 16), (16, 8)])
def test_implicit_gemm_channel_shapes(dtype, cin, cout):
    stx = random_tensor(12, n=60, cap=64, channels=cin, extent=6)
    kmap = km.build_kmap(stx, 3, 1)
    w = (jax.random.normal(jax.random.PRNGKey(2), (27, cin, cout)) * 0.3).astype(dtype)
    x = stx.feats.astype(dtype)
    plan = km.make_split_plan(kmap, 2)
    got = implicit_gemm(x, w, kmap, plan, tile_m=16, tile_n=8, interpret=True)
    ref = implicit_gemm_ref(x, w, kmap.m_out)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(ref, np.float32), **tol(dtype))


def test_implicit_gemm_strided():
    stx = random_tensor(13, n=80, cap=128, channels=8, extent=10)
    kmap = km.build_kmap(stx, 2, 2)
    w = jax.random.normal(jax.random.PRNGKey(3), (8, 8, 16)) * 0.3
    plan = km.make_split_plan(kmap, 1)
    got = implicit_gemm(stx.feats, w, kmap, plan, tile_m=16, tile_n=16, interpret=True)
    ref = implicit_gemm_ref(stx.feats, w, kmap.m_out)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("tile_r", [8, 32])
def test_fetch_on_demand_sweep(dtype, tile_r):
    stx = random_tensor(14, n=70, cap=96, channels=8, extent=7)
    kmap = km.build_kmap(stx, 3, 1)
    w = (jax.random.normal(jax.random.PRNGKey(4), (27, 8, 16)) * 0.3).astype(dtype)
    x = stx.feats.astype(dtype)
    got = fetch_on_demand(x, w, kmap, tile_r=tile_r, interpret=True)
    ref = fetch_on_demand_ref(x, w, kmap.ws_in, kmap.ws_out, kmap.capacity)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(ref, np.float32), **tol(dtype))


def test_pallas_kernels_agree_with_each_other():
    stx = random_tensor(15, n=90, cap=128, channels=8, extent=8)
    kmap = km.build_kmap(stx, 3, 1)
    w = jax.random.normal(jax.random.PRNGKey(5), (27, 8, 8)) * 0.3
    plan = km.make_split_plan(kmap, 2)
    a = implicit_gemm(stx.feats, w, kmap, plan, tile_m=16, tile_n=8, interpret=True)
    b = fetch_on_demand(stx.feats, w, kmap, tile_r=16, interpret=True)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("splits,sort", [(1, True), (2, True), (3, True),
                                         (1, False)])
def test_implicit_gemm_worklist_bit_identical_to_dense(splits, sort):
    """Tile skipping changes the launch geometry, not the math: the
    worklist kernel visits the occupied (tile, δ) pairs in the same order
    the dense grid's gated steps run them, so the two are *bit*-identical
    (same float add sequence) — with ad-hoc occupancy, with the occupancy
    fused into the split plan, and through the traced-occupancy fallback."""
    stx = random_tensor(11, n=90, cap=128, channels=8, extent=7)
    kmap = km.build_kmap(stx, 3, 1)
    w = jax.random.normal(jax.random.PRNGKey(1), (27, 8, 16)) * 0.3
    plan = km.make_split_plan(kmap, splits, sort=sort)
    fused = km.make_split_plan(kmap, splits, sort=sort, tile_m=16)
    dense = implicit_gemm(stx.feats, w, kmap, plan, tile_m=16, tile_n=8,
                          interpret=True)
    for p in (plan, fused):
        wl = implicit_gemm(stx.feats, w, kmap, p, tile_m=16, tile_n=8,
                           worklist=True, interpret=True)
        assert jnp.array_equal(dense, wl)
    # under jit the occupancy is a tracer: no concrete worklist to compact,
    # so the wrapper falls back to the dense grid — still identical
    jitted = jax.jit(lambda x, w_: implicit_gemm(
        x, w_, kmap, plan, tile_m=16, tile_n=8, worklist=True,
        interpret=True))
    assert jnp.array_equal(dense, jitted(stx.feats, w))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("s,hkv,g", [(128, 2, 1), (256, 2, 2)])
def test_flash_attention_sweep(dtype, causal, s, hkv, g):
    b, d = 2, 16
    h = hkv * g
    key = jax.random.PRNGKey(0)
    q = (jax.random.normal(key, (b, h, s, d)) * 0.5).astype(dtype)
    k = (jax.random.normal(jax.random.fold_in(key, 1), (b, hkv, s, d)) * 0.5).astype(dtype)
    v = (jax.random.normal(jax.random.fold_in(key, 2), (b, hkv, s, d)) * 0.5).astype(dtype)
    got = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64, interpret=True)
    ref = mha_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(ref, np.float32),
                               rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=3e-2 if dtype == jnp.bfloat16 else 1e-5)


def test_flash_attention_rectangular_blocks():
    b, h, s, d = 1, 2, 128, 32
    key = jax.random.PRNGKey(7)
    q = jax.random.normal(key, (b, h, s, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, h, s, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, h, s, d))
    got = flash_attention(q, k, v, causal=True, block_q=32, block_k=64, interpret=True)
    ref = mha_ref(q, k, v, causal=True)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-5)
