"""Paper Fig. 8 — can the generated sparse kernel match the vendor library
on the equivalent-size dense GEMM?  On this CPU container the "vendor
library" is XLA's dense dot; the sparse side is the implicit-GEMM XLA path
on the same effective-MAC workload.  ``derived`` = utilization relative to
the dense GEMM (>1 means the sparse path beats the equivalent dense one,
as Fig. 8 reports for several layers)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import dataflows as df
from repro.core import kmap as km


def run():
    # channel ladder from MinkUNet on SemanticKITTI (Fig. 8 workloads)
    stx = common.seg_scene(n=1800)
    kmap = km.build_kmap(stx, 3, 1)
    n_eff = int(jnp.sum(kmap.ws_count))          # Σ_δ |M_δ|
    for cin, cout in ((16, 16), (32, 32), (64, 64), (96, 96)):
        x = jax.random.normal(jax.random.PRNGKey(0), (stx.capacity, cin))
        w = jax.random.normal(jax.random.PRNGKey(1), (27, cin, cout)) * 0.1
        fn_sparse = jax.jit(lambda x: df.sparse_conv_forward(
            x, w, kmap, df.DataflowConfig("implicit_gemm")))
        us_sparse = common.time_fn(lambda: fn_sparse(x))

        # equivalent-size dense GEMM: (n_eff × cin) @ (cin × cout)
        a = jax.random.normal(jax.random.PRNGKey(2), (n_eff, cin))
        b = jax.random.normal(jax.random.PRNGKey(3), (cin, cout))
        fn_dense = jax.jit(lambda a: a @ b)
        us_dense = common.time_fn(lambda: fn_dense(a))

        util = us_dense / us_sparse
        # structural MXU utilization of the generated TPU kernel: effective
        # rows / issued rows under sorted tiling (what Fig. 8 measures on
        # device; the XLA-path wall-clock ratio above is CPU-only context)
        plan = km.make_split_plan(kmap, 1, sort=True)
        stats = km.redundancy_stats(kmap, plan, tile_m=128)
        mxu_util = float(stats["effective_rows"]) / float(stats["issued_rows"])
        common.emit(f"fig8/minkunet/c{cin}-{cout}", us_sparse,
                    f"dense_equiv_us={us_dense:.1f},cpu_xla_ratio={util:.2f},"
                    f"kernel_mxu_utilization={mxu_util:.2f}")


if __name__ == "__main__":
    run()
