"""Zamba2-7B — Mamba2 + shared attention blocks [arXiv:2411.15242; unverified]."""
from repro.models.lm_common import ArchConfig, SSMCfg

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid", n_layers=81, d_model=3584,
    n_heads=32, kv_heads=32, d_ff=14336, vocab=32000, norm="rms", mlp="swiglu",
    ssm=SSMCfg(d_state=64, expand=2, conv_kernel=4, head_dim=64, version=2, chunk=128),
    attn_every=6, sub_quadratic=True,
)
