"""Serving launcher: batched prefill + decode with a KV cache.

``python -m repro.launch.serve --arch qwen1_5_0_5b --batch 4 --gen 16``
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import base as cfgbase
from repro.models import api


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    args = ap.parse_args()

    cfg = cfgbase.get_arch(args.arch)
    if args.reduced:
        cfg = cfgbase.reduced(cfg)
    assert cfg.embed_input, "serving driver expects token-input archs"

    params = api.init_params(cfg, jax.random.PRNGKey(0))
    b, s = args.batch, args.prompt_len
    prompts = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    cache = api.init_cache(cfg, b, s + args.gen)

    t0 = time.perf_counter()
    logits, cache = jax.block_until_ready(
        jax.jit(lambda p, t, c: api.prefill(cfg, p, t, c))(params, prompts, cache))
    t_prefill = time.perf_counter() - t0
    dstep = jax.jit(lambda p, c, t: api.decode_step(cfg, p, c, t))

    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [tok]
    t0 = time.perf_counter()
    for _ in range(args.gen - 1):
        logits, cache = dstep(params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0
    seqs = jnp.stack(out, 1)
    print(f"prefill: {b}×{s} tokens in {t_prefill * 1e3:.1f} ms "
          f"({b * s / t_prefill:.0f} tok/s)")
    print(f"decode:  {b}×{args.gen - 1} tokens in {t_decode * 1e3:.1f} ms "
          f"({b * (args.gen - 1) / max(t_decode, 1e-9):.0f} tok/s)")
    print("sample:", seqs[0, :10].tolist())


if __name__ == "__main__":
    main()
