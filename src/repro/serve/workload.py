"""Synthetic request streams for the serving CLI, benchmark and tests.

Scenes are drawn from the same LiDAR-statistics generator the rest of the
repo benchmarks with (``data.synthetic.lidar_scene``), at per-request point
counts sampled from a declared range — the mixed-size traffic a deployed
perception service sees frame to frame.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import numpy as np

from repro.data.synthetic import lidar_scene
from repro.serve.batcher import Scene, SceneDelta, apply_delta, scene_from_tensor


def lidar_stream(seed: int, count: int, channels: int,
                 n_range: Tuple[int, int] = (200, 1200),
                 extent: float = 50.0, voxel: float = 0.4) -> Tuple[List[Scene], int]:
    """``count`` mixed-size scenes + the spatial bound they all respect.

    Replaying the same stream through a warm engine (as the CLI and
    benchmark do) models repeated-frame traffic: identical packed batches
    hit the engine's cross-request map cache.
    """
    rng = np.random.default_rng(seed)
    lo, hi = n_range
    margin = 8.0
    bound = int(np.ceil((extent + margin) / voxel)) + 2
    scenes: List[Scene] = []
    for i in range(count):
        n = int(rng.integers(lo, hi + 1))
        st = lidar_scene(jax.random.PRNGKey(seed * 100003 + i), n, n, channels,
                         extent=extent, voxel=voxel)
        scenes.append(scene_from_tensor(st))
    return scenes, bound


def _scene_delta(rng, scene: Scene, churn_points: float, bound: int,
                 channels: int) -> SceneDelta:
    """A point-level frame update: evict ~churn_points of the voxels, insert
    as many fresh ones (unique, in-bounds, absent from the kept set)."""
    n = scene.num_points
    r = max(1, int(round(churn_points * n)))
    rm_idx = rng.choice(n, size=r, replace=False)
    removed = scene.coords[rm_idx]
    taken = set(map(tuple, scene.coords))
    for c in removed:
        taken.discard(tuple(c))
    added: List[np.ndarray] = []
    while len(added) < r:
        cand = rng.integers(-bound, bound, size=(3,), dtype=np.int32)
        if tuple(cand) not in taken:
            taken.add(tuple(cand))
            added.append(cand)
    return SceneDelta(removed=removed, added_coords=np.asarray(added, np.int32),
                      added_feats=rng.normal(size=(r, channels)).astype(np.float32))


def churned_stream(seed: int, streams: int, frames: int, channels: int,
                   n_range: Tuple[int, int] = (200, 600),
                   churn_streams: float = 0.34, churn_points: float = 0.1,
                   extent: float = 50.0, voxel: float = 0.4,
                   ) -> Tuple[List[List[Tuple[str, Scene, Optional[SceneDelta]]]], int]:
    """Streaming-scene traffic: ``streams`` concurrent sensors, each frame
    re-submitting every stream's scene, with ~``churn_streams`` of the
    streams receiving a point-level delta (``churn_points`` of their voxels
    evicted and replaced) and the rest repeating unchanged.

    This is the traffic shape where PR-2's whole-batch digest always misses
    (every frame's packed batch differs) but scene-granular reuse keeps
    hitting: unchanged streams compose straight from the scene store, and
    changed streams carry an explicit ``SceneDelta`` for the incremental
    path.  Returns ``(frames, bound)`` where ``frames[t]`` lists
    ``(stream_id, scene, delta_or_None)`` per stream — ``delta`` is None on
    frame 0 and on unchanged frames.  Deterministic in ``seed``.
    """
    base, bound = lidar_stream(seed, streams, channels, n_range=n_range,
                               extent=extent, voxel=voxel)
    rng = np.random.default_rng(seed + 1)
    churned_per_frame = max(1, int(round(churn_streams * streams)))
    ids = [f"s{i}" for i in range(streams)]
    cur = list(base)
    out: List[List[Tuple[str, Scene, Optional[SceneDelta]]]] = [
        [(ids[i], cur[i], None) for i in range(streams)]]
    for t in range(1, frames):
        # rotate deterministically through the streams so churn is spread
        churned = {(t * churned_per_frame + j) % streams
                   for j in range(churned_per_frame)}
        frame: List[Tuple[str, Scene, Optional[SceneDelta]]] = []
        for i in range(streams):
            if i in churned:
                delta = _scene_delta(rng, cur[i], churn_points, bound, channels)
                cur[i] = apply_delta(cur[i], delta)
                frame.append((ids[i], cur[i], delta))
            else:
                frame.append((ids[i], cur[i], None))
        out.append(frame)
    return out, bound
