"""End-to-end behaviour: autotuned MinkUNet training, the full tuner loop on
a real model, and the serving path."""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dataflows as df
from repro.core import generator
from repro.core.autotuner import Autotuner, GroupInfo, partition_groups, timeit_fn
from repro.core.sparse_conv import TrainDataflowConfig
from repro.data.synthetic import lidar_scene, token_batches
from repro.models import api, minkunet
from repro.configs import base
from repro.train import optimizer as opt
from repro.train.loop import LoopConfig, train_loop


def test_minkunet_train_descends():
    cfg = minkunet.MinkUNetConfig(in_channels=4, num_classes=4, width=0.25,
                                  blocks_per_stage=1)
    stx = lidar_scene(jax.random.PRNGKey(0), 300, 256, 4, extent=20.0, voxel=0.5)
    params = minkunet.init_params(cfg, jax.random.PRNGKey(1))
    maps = minkunet.build_maps(stx)
    labels = jax.random.randint(jax.random.PRNGKey(2), (256,), 0, 4)
    ocfg = opt.AdamWConfig(lr=3e-3, weight_decay=0.0)
    state = opt.init_opt_state(params, ocfg)

    @jax.jit
    def step(params, state):
        def loss(p):
            lg = minkunet.apply(p, stx, cfg, maps)
            ls = jax.nn.log_softmax(lg)[jnp.arange(256), labels]
            return -jnp.sum(jnp.where(stx.valid_mask, ls, 0)) / jnp.maximum(stx.num_valid, 1)

        l, g = jax.value_and_grad(loss)(params)
        p2, s2, _ = opt.adamw_update(params, g, state, ocfg)
        return p2, s2, l

    losses = []
    for _ in range(8):
        params, state, l = step(params, state)
        losses.append(float(l))
    assert losses[-1] < losses[0] * 0.9, losses


def test_autotuner_end_to_end_on_minkunet():
    """The real group-based tuner over the real design space on the real
    model — picks a valid assignment whose choices are consistent with its
    own measurements.

    Deliberately load-tolerant: asserting relative wall-clock of two fresh
    measurements flakes under CPU contention (CI neighbors), so nothing
    here thresholds a duration — timing is printed for information only.
    What is asserted is structure: the tuner measured every (group,
    candidate) pair exactly once, every group got a config from the space,
    and per group the tuner chose exactly the argmin of the latencies *it
    measured* (monotone non-worsening objective by construction)."""
    cfg = minkunet.MinkUNetConfig(width=0.25, blocks_per_stage=1)
    stx = lidar_scene(jax.random.PRNGKey(0), 250, 256, 4, extent=20.0, voxel=0.5)
    params = minkunet.init_params(cfg, jax.random.PRNGKey(1))
    maps = minkunet.build_maps(stx)
    sigs = minkunet.layer_signatures(cfg)
    groups = partition_groups(sigs)
    # small space to keep CPU time sane
    space = [df.DataflowConfig("gather_scatter"),
             df.DataflowConfig("implicit_gemm", n_splits=1)]

    sig_of_group = {g.name: sigs[g.layer_names[0]] for g in groups}

    n_calls = 0

    def measure(assign):
        nonlocal n_calls
        n_calls += 1
        amap = {sig_of_group[k]: TrainDataflowConfig.bind_all(v) for k, v in assign.items()}
        fn = jax.jit(lambda p: minkunet.apply(p, stx, cfg, maps, assignment=amap))
        return timeit_fn(lambda: jax.block_until_ready(fn(params)), warmup=1, iters=2)

    t_tune = time.perf_counter()
    tuner = Autotuner(groups, space, measure)
    best = tuner.tune()
    print(f"[autotuner] {n_calls} measurements, "
          f"{time.perf_counter() - t_tune:.1f}s wall (informational)")
    # exhaustive sweep, no re-measurement: one call per (group, candidate)
    assert n_calls == len(groups) * len(space)
    # valid assignment: every group assigned, every choice from the space
    assert set(best) == {g.name for g in groups}
    assert all(c in space for c in best.values())
    # choices consistent with the tuner's own measured objective: per group,
    # the winner is the argmin of that group's logged (candidate, latency)
    # sweep, and all measured latencies are sane
    by_group = {}
    for gname, cand, lat in tuner.log:
        assert lat > 0 and np.isfinite(lat)
        by_group.setdefault(gname, []).append((lat, cand))
    for g in groups:
        results = by_group[g.name]
        assert len(results) == len(space)
        assert best[g.name] == min(results, key=lambda r: r[0])[1]


def test_lm_train_loop_with_checkpoint(tmp_path):
    cfg = base.reduced(base.get_arch("olmo_1b"))
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    ocfg = opt.AdamWConfig(lr=1e-3, weight_decay=0.0)
    state = opt.init_opt_state(params, ocfg)

    @jax.jit
    def step(params, state, batch):
        l, g = jax.value_and_grad(lambda p: api.loss_fn(cfg, p, batch))(params)
        p2, s2, gn = opt.adamw_update(params, g, state, ocfg)
        return p2, s2, {"loss": l, "gnorm": gn}

    data = token_batches(0, batch=2, seq=32, vocab=cfg.vocab)
    lcfg = LoopConfig(total_steps=6, ckpt_every=3, ckpt_dir=str(tmp_path), log_every=100)
    params, state, report = train_loop(step, params, state, data, lcfg)
    assert report.steps_run == 6
    assert np.isfinite(report.last_metrics["loss"])


def test_generate_then_serve_batched():
    """Prefill a batch of prompts, decode 8 tokens greedily."""
    cfg = dataclasses.replace(base.reduced(base.get_arch("qwen1_5_0_5b")), dtype="float32")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    b, s = 4, 16
    prompts = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    cache = api.init_cache(cfg, b, s + 8)
    logits, cache = api.prefill(cfg, params, prompts, cache)
    toks = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    dstep = jax.jit(lambda p, c, t: api.decode_step(cfg, p, c, t))
    for _ in range(8):
        toks.append(tok)
        logits, cache = dstep(params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = jnp.stack(toks, 1)
    assert out.shape == (b, 8)
    assert int(cache["pos"]) == s + 8
