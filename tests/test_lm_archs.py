"""Per-assigned-architecture smoke tests: a REDUCED config of the same
family runs one forward/train step on CPU — shapes + finiteness.  The FULL
configs are exercised only via launch/dryrun.py (ShapeDtypeStruct only)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base
from repro.models import api
from repro.train import optimizer as opt


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=32):
    batch = {"labels": jnp.zeros((b, s), jnp.int32)}
    if cfg.embed_input:
        batch["tokens"] = jnp.ones((b, s), jnp.int32)
    else:
        batch["embeds"] = jnp.ones((b, s, cfg.d_model), cfg.jdtype)
    if cfg.cross_every:
        batch["img_emb"] = jnp.ones((b, cfg.n_img_tokens, cfg.d_model), cfg.jdtype)
    return batch


@pytest.mark.parametrize("arch", base.ARCH_IDS)
def test_reduced_train_step(arch, key):
    cfg = base.reduced(base.get_arch(arch))
    params = api.init_params(cfg, key)
    batch = _batch(cfg)
    ocfg = opt.AdamWConfig(lr=1e-3)
    state = opt.init_opt_state(params, ocfg)
    loss, grads = jax.value_and_grad(lambda p: api.loss_fn(cfg, p, batch))(params)
    new_params, state, gnorm = opt.adamw_update(params, grads, state, ocfg)
    assert np.isfinite(float(loss))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0
    # one more step must change the loss (weights actually updated)
    loss2 = api.loss_fn(cfg, new_params, batch)
    assert float(loss2) != float(loss)


@pytest.mark.parametrize("arch", base.ARCH_IDS)
def test_reduced_decode_step(arch, key):
    cfg = base.reduced(base.get_arch(arch))
    params = api.init_params(cfg, key)
    b = 2
    cache = api.init_cache(cfg, b, 64)
    tok = (jnp.zeros((b,), jnp.int32) if cfg.embed_input
           else jnp.ones((b, cfg.d_model), cfg.jdtype))
    logits, cache = api.decode_step(cfg, params, cache, tok)
    assert logits.shape == (b, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    assert int(cache["pos"]) == 1


@pytest.mark.parametrize("arch", ["qwen1_5_0_5b", "falcon_mamba_7b", "zamba2_7b",
                                  "musicgen_large", "llama_3_2_vision_90b"])
def test_prefill_then_decode_consistency(arch, key):
    """prefill(t₀..t_{n-1}) + decode(t_n) == prefill(t₀..t_n) last logits."""
    cfg = dataclasses.replace(base.reduced(base.get_arch(arch)), dtype="float32")
    params = api.init_params(cfg, key)
    b, s = 2, 16
    kw = {}
    if cfg.cross_every:
        kw["img_emb"] = jnp.ones((b, cfg.n_img_tokens, cfg.d_model), cfg.jdtype)
    if cfg.embed_input:
        toks = jax.random.randint(jax.random.PRNGKey(1), (b, s + 1), 0, cfg.vocab)
        first, last = toks[:, :s], toks[:, s]
        full = toks
    else:
        toks = jax.random.normal(jax.random.PRNGKey(1), (b, s + 1, cfg.d_model))
        first, last = toks[:, :s], toks[:, s]
        full = toks
    cache = api.init_cache(cfg, b, 64)
    _, cache = api.prefill(cfg, params, first, cache, **kw)
    lg_dec, _ = api.decode_step(cfg, params, cache, last)
    cache2 = api.init_cache(cfg, b, 64)
    lg_full, _ = api.prefill(cfg, params, full, cache2, **kw)
    np.testing.assert_allclose(lg_dec, lg_full, rtol=2e-3, atol=2e-3)


def test_full_configs_match_assignment_table():
    """Exact hyperparameters from the assignment (guards against drift)."""
    t = {  # n_layers, d_model, n_heads, kv, d_ff, vocab
        "kimi_k2_1t_a32b": (61, 7168, 64, 8, 2048, 163840),
        "mixtral_8x22b": (56, 6144, 48, 8, 16384, 32768),
        "olmo_1b": (16, 2048, 16, 16, 8192, 50304),
        "starcoder2_3b": (30, 3072, 24, 2, 12288, 49152),
        "qwen1_5_0_5b": (24, 1024, 16, 16, 2816, 151936),
        "codeqwen1_5_7b": (32, 4096, 32, 32, 13440, 92416),
        "musicgen_large": (48, 2048, 32, 32, 8192, 2048),
        "falcon_mamba_7b": (64, 4096, 1, 1, 0, 65024),
        "zamba2_7b": (81, 3584, 32, 32, 14336, 32000),
        "llama_3_2_vision_90b": (100, 8192, 64, 8, 28672, 128256),
    }
    for arch, (L, d, h, kv, ff, v) in t.items():
        cfg = base.get_arch(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.kv_heads,
                cfg.d_ff, cfg.vocab) == (L, d, h, kv, ff, v), arch
    kimi = base.get_arch("kimi_k2_1t_a32b")
    assert kimi.moe.n_experts == 384 and kimi.moe.top_k == 8
    mix = base.get_arch("mixtral_8x22b")
    assert mix.moe.n_experts == 8 and mix.moe.top_k == 2
    fm = base.get_arch("falcon_mamba_7b")
    assert fm.ssm.d_state == 16
    z = base.get_arch("zamba2_7b")
    assert z.ssm.d_state == 64 and z.attn_every == 6


def test_param_counts_in_expected_range():
    """Analytic N for the roofline: sanity-check magnitudes."""
    expect = {  # rough public sizes, ±40%
        "kimi_k2_1t_a32b": 1.0e12, "mixtral_8x22b": 1.4e11, "olmo_1b": 1.2e9,
        "starcoder2_3b": 3e9, "qwen1_5_0_5b": 5e8, "codeqwen1_5_7b": 7e9,
        "musicgen_large": 3.3e9, "falcon_mamba_7b": 7e9, "zamba2_7b": 7e9,
        "llama_3_2_vision_90b": 8.5e10,
    }
    for arch, n in expect.items():
        cfg = base.get_arch(arch)
        got = cfg.params_count()
        assert 0.5 * n < got < 1.7 * n, (arch, got, n)
    kimi = base.get_arch("kimi_k2_1t_a32b")
    assert kimi.active_params_count() < 0.1 * kimi.params_count()


def test_long_500k_eligibility():
    for arch in base.ARCH_IDS:
        cfg = base.get_arch(arch)
        ok, why = base.cell_supported(cfg, base.SHAPES["long_500k"])
        if arch in ("falcon_mamba_7b", "zamba2_7b"):
            assert ok
        else:
            assert not ok and "full-attention" in why
