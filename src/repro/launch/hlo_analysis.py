"""Parse collective traffic and roofline terms out of a compiled dry-run.

collective_bytes is not in cost_analysis(); we parse the optimized (SPMD
partitioned, per-device) HLO text and sum the result-shape bytes of every
collective op, scaled by the standard ring-algorithm wire factors:

    all-reduce          2·(n-1)/n  ≈ 2   (reduce-scatter + all-gather)
    all-gather          (n-1)/n    ≈ 1
    reduce-scatter      (n-1)/n    ≈ 1
    all-to-all          (n-1)/n    ≈ 1
    collective-permute  1

The HLO is already the per-device program, so summed bytes are per-device
wire traffic; dividing by the per-link ICI bandwidth gives the collective
roofline term directly.
"""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_FACTORS = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
    "collective-broadcast": 1.0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# e.g.:  %x = (f32[8,16]{1,0}, f32[8,16]{1,0}) all-reduce(...)  or
#        %y = bf16[128,7168]{1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+\[[^\]]*\]\S*)\s+"
    r"(all-reduce-start|all-reduce|all-gather-start|all-gather|reduce-scatter|"
    r"all-to-all|collective-permute-start|collective-permute|collective-broadcast)"
    r"(?!-done)\b(?!-done)")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> Dict[str, float]:
    stats: Dict[str, float] = {k: 0.0 for k in _COLL_FACTORS}
    counts: Dict[str, int] = {k: 0 for k in _COLL_FACTORS}
    for m in _OP_RE.finditer(hlo_text):
        shape_str, op = m.group(1), m.group(2)
        op = op.replace("-start", "")
        b = _shape_bytes(shape_str)
        stats[op] += b * _COLL_FACTORS[op]
        counts[op] += 1
    out = {f"bytes_{k}": v for k, v in stats.items() if counts[k]}
    out.update({f"count_{k}": counts[k] for k in counts if counts[k]})
    out["collective_bytes"] = sum(stats.values())
    return out


def roofline_terms(per_dev_flops: float, per_dev_bytes: float,
                   per_dev_coll_bytes: float, *, peak_flops: float = 197e12,
                   hbm_bw: float = 819e9, link_bw: float = 50e9) -> Dict[str, float]:
    t_c = per_dev_flops / peak_flops
    t_m = per_dev_bytes / hbm_bw
    t_x = per_dev_coll_bytes / link_bw
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x), key=lambda kv: kv[1])
    return {
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "bottleneck": dom[0],
        "bound_s": dom[1],
        # fraction of roofline actually achievable if perfectly overlapped:
        "roofline_fraction": t_c / max(dom[1], 1e-30),
    }
