"""O(N) radix argsort — Pallas kernel for bounded packed keys.

Minuet's observation, lifted to the kernel tier: packed coordinate keys
carry a *declared* bit budget (``KeySpec``), so the table-build sort never
needs a comparison argsort — ``nbits`` stable binary partitions reproduce
``jnp.argsort(stable=True)`` exactly, in O(N·nbits) work with O(N) memory
traffic per pass.

One ``pallas_call``, no grid: the key column lives in VMEM and a
``fori_loop`` runs one stable bit partition per iteration (prefix-sum the
zero/one flags, scatter rows to their partition rank).  The value-level
scatter (`.at[pos].set`) is the interpret-mode contract this repo asserts
in tier-1; on real TPUs the partition would become an SMEM-offset DMA
shuffle — noted as a follow-up in ROADMAP.md.  The XLA twin is
``repro.core.hashing.radix_argsort_bits`` (bit-identical, same pass
structure); the numpy twin serves the engine's host-side scene tables.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import common


def _kernel(vals_ref, perm_ref, *, nbits: int):
    r = vals_ref[...]                      # (N, 1) int32, non-negative
    o = jax.lax.broadcasted_iota(jnp.int32, r.shape, 0)

    def body(b, carry):
        r, o = carry
        bit = (r >> b) & 1
        zeros = jnp.cumsum(1 - bit, axis=0)
        n0 = zeros[-1, 0]
        pos = jnp.where(bit == 0, zeros - 1, n0 + jnp.cumsum(bit, axis=0) - 1)
        idx = pos[:, 0]
        return (jnp.zeros_like(r).at[idx].set(r),
                jnp.zeros_like(o).at[idx].set(o))

    _, o = jax.lax.fori_loop(0, nbits, body, (r, o))
    perm_ref[...] = o


@functools.partial(jax.jit, static_argnames=("nbits", "interpret"))
def radix_argsort_bits_pallas(vals: jax.Array, *, nbits: int,
                              interpret: bool = True) -> jax.Array:
    """Stable argsort permutation of non-negative int32 ``vals < 2**nbits``.

    vals: (N,) int32.  Returns (N,) int32 — bit-identical to
    ``jnp.argsort(vals, stable=True)``.
    """
    n = vals.shape[0]
    if n == 0 or nbits <= 0:
        return jnp.arange(n, dtype=jnp.int32)
    perm = pl.pallas_call(
        functools.partial(_kernel, nbits=nbits),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.int32),
        interpret=interpret,
        compiler_params=common.tpu_compiler_params(interpret=interpret),
    )(vals[:, None])
    return perm[:, 0]
