"""SparseConv layer: the paper's operator as a composable JAX module.

Training support (paper §4.2/Fig. 13): forward, dgrad and wgrad are *three
different kernels* with independently tunable dataflow parameters.  We express
that with a ``custom_vjp`` whose backward pass dispatches on the layer's
``TrainDataflowConfig`` — the exact mechanism the Sparse Autotuner's binding
schemes tune.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import dataflows as df
from repro.core.kmap import KernelMap, MapCache, build_kmap, transpose_kmap
from repro.core.precision import FP32, PrecisionPolicy
from repro.core.sparse_tensor import SparseTensor


@dataclasses.dataclass(frozen=True)
class TrainDataflowConfig:
    """Per-layer(-group) dataflow parameters for fwd / dgrad / wgrad."""

    fwd: df.DataflowConfig = df.DEFAULT_CONFIG
    dgrad: df.DataflowConfig = df.DEFAULT_CONFIG
    wgrad: df.DataflowConfig = df.DEFAULT_CONFIG

    # Binding schemes (paper Fig. 13): construct coupled configs.
    @staticmethod
    def bind_all(cfg: df.DataflowConfig) -> "TrainDataflowConfig":
        return TrainDataflowConfig(cfg, cfg, cfg)

    @staticmethod
    def bind_fwd_dgrad(cfg: df.DataflowConfig, wgrad: df.DataflowConfig) -> "TrainDataflowConfig":
        """Workload-pattern oriented (low-parallelism devices)."""
        return TrainDataflowConfig(cfg, cfg, wgrad)

    @staticmethod
    def bind_dgrad_wgrad(fwd: df.DataflowConfig, cfg: df.DataflowConfig) -> "TrainDataflowConfig":
        """Sparse-mapping oriented (high-parallelism devices)."""
        return TrainDataflowConfig(fwd, cfg, cfg)

    def to_dict(self) -> dict:
        return {"fwd": self.fwd.to_dict(), "dgrad": self.dgrad.to_dict(),
                "wgrad": self.wgrad.to_dict()}

    @staticmethod
    def from_dict(d: dict) -> "TrainDataflowConfig":
        unknown = set(d) - {"fwd", "dgrad", "wgrad"}
        if unknown:
            raise ValueError(
                f"unknown TrainDataflowConfig fields: {sorted(unknown)}")
        return TrainDataflowConfig(fwd=df.DataflowConfig.from_dict(d["fwd"]),
                                   dgrad=df.DataflowConfig.from_dict(d["dgrad"]),
                                   wgrad=df.DataflowConfig.from_dict(d["wgrad"]))


DEFAULT_TRAIN_CONFIG = TrainDataflowConfig()


def sparse_conv_apply(feats: jax.Array, w: jax.Array, kmap: KernelMap,
                      cfg: TrainDataflowConfig = DEFAULT_TRAIN_CONFIG,
                      precision: PrecisionPolicy = FP32,
                      plan=None) -> jax.Array:
    """Differentiable sparse conv with decoupled fwd/dgrad/wgrad dataflows.

    ``precision`` applies to all three kernels: bf16 compute / fp32
    accumulate under the mixed policy.  Cotangents are re-cast to the primal
    dtypes as the last step (custom_vjp contract), so the weight gradient
    rounds at most once — after full-precision accumulation — on its way to
    the optimizer's fp32 master copy.

    ``plan``: optional pre-built ``SplitPlan`` for the forward dataflow
    (serving composes these per batch); None keeps the build-in-trace path.
    """

    @jax.custom_vjp
    def f(feats, w):
        return df.sparse_conv_forward(feats, w, kmap, cfg.fwd,
                                      precision=precision, plan=plan)

    def f_fwd(feats, w):
        return f(feats, w), (feats, w)

    def f_bwd(res, dy):
        feats_, w_ = res
        dx = df.sparse_conv_dgrad(dy, w_, kmap, cfg.dgrad,
                                  in_capacity=feats_.shape[0],
                                  precision=precision)
        dw = df.sparse_conv_wgrad(feats_, dy, kmap, cfg.wgrad,
                                  precision=precision)
        return dx.astype(feats_.dtype), dw.astype(w_.dtype)

    f.defvjp(f_fwd, f_bwd)
    return f(feats, w)


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    in_channels: int
    out_channels: int
    kernel_size: int = 3
    stride: int = 1
    transposed: bool = False
    bias: bool = False

    @property
    def volume(self) -> int:
        return self.kernel_size ** 3  # models here are 3D


def init_conv(key: jax.Array, spec: ConvSpec, ndim: int = 3, dtype=jnp.float32) -> dict:
    kd = spec.kernel_size ** ndim
    fan_in = spec.in_channels * kd
    w = jax.random.normal(key, (kd, spec.in_channels, spec.out_channels), dtype) * (fan_in ** -0.5)
    params = {"w": w}
    if spec.bias:
        params["b"] = jnp.zeros((spec.out_channels,), dtype)
    return params


def apply_conv(params: dict, x: SparseTensor, kmap: KernelMap,
               cfg: TrainDataflowConfig = DEFAULT_TRAIN_CONFIG,
               precision: PrecisionPolicy = FP32,
               plan=None) -> SparseTensor:
    """Apply a sparse conv given a prebuilt kernel map; returns the output
    SparseTensor on the map's coordinates."""
    y = sparse_conv_apply(x.feats, params["w"], kmap, cfg, precision=precision,
                          plan=plan)
    if "b" in params:
        y = y + params["b"][None, :].astype(y.dtype)
    valid = jnp.arange(kmap.capacity) < kmap.n_out
    y = jnp.where(valid[:, None], y, 0)
    # Output coordinates live in the same declared (batch, spatial) region as
    # the input's: propagate the bounds so downstream build_kmap calls stay on
    # the single-word packed-key path instead of falling back to raw keys.
    return SparseTensor(coords=kmap.out_coords, feats=y, num_valid=kmap.n_out,
                        stride=kmap.out_stride, batch_bound=x.batch_bound,
                        spatial_bound=x.spatial_bound)


def conv_kmap(x: SparseTensor, spec: ConvSpec,
              cached_fine: Optional[SparseTensor] = None,
              cached_fwd: Optional[KernelMap] = None,
              cache: Optional[MapCache] = None) -> KernelMap:
    """Build (or derive) the kernel map for ``spec`` applied to ``x``.

    Decoder (transposed) convs reuse the encoder's map (paper: same group).
    ``cache`` (a ``kmap.MapCache``) lets layers at the same stride share the
    sorted coordinate table instead of rebuilding it per layer group."""
    if spec.transposed:
        assert cached_fwd is not None and cached_fine is not None
        return transpose_kmap(cached_fwd, cached_fine)
    return build_kmap(x, spec.kernel_size, spec.stride, cache=cache)
