"""Gradient compression for the cross-pod data-parallel all-reduce.

At 1000+ nodes the inter-pod links (DCN) are the scarcest bandwidth; the
standard trick is a two-phase compressed all-reduce with **error feedback**:

    1. reduce-scatter the int8-quantized gradient chunks (all_to_all + local sum)
    2. all-gather the int8-quantized reduced chunks
    3. feed the quantization residual back into the next step's gradient

Wire bytes drop 4× vs f32 (2× vs bf16); error feedback makes the scheme
convergent (Karimireddy et al., 2019).  The collectives are expressed with
``jax.lax`` primitives inside ``shard_map`` so the HLO shows real
all-to-all / all-gather ops on the pod axis.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def _axis_size(axis_name: str) -> int:
    """Static size of a named mapped axis.  ``jax.lax.axis_size`` only
    exists in newer jax; older runtimes (0.4.37 CI) read the axis frame."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    frame = jax.core.axis_frame(axis_name)   # int on 0.4.x, frame later
    return frame if isinstance(frame, int) else frame.size


def compressed_all_reduce_mean(x: jax.Array, axis_name: str) -> jax.Array:
    """Int8 two-phase all-reduce along ``axis_name`` (call inside shard_map).

    x: any shape; flattened internally; returns mean over the axis."""
    n = _axis_size(axis_name)
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.shape[0]) % n
    flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(n, -1)

    # phase 1: quantize my n chunks, all_to_all so peer i gets chunk i from
    # everyone, dequantize + sum → I own the reduced chunk i.
    q, scale = _quantize(chunks)
    q_t = jax.lax.all_to_all(q, axis_name, split_axis=0, concat_axis=0, tiled=False)
    scales = jax.lax.all_gather(scale, axis_name)
    mine = jnp.sum(q_t.astype(jnp.float32) * scales[:, None], axis=0)

    # phase 2: quantize the reduced chunk, all-gather.
    q2, scale2 = _quantize(mine)
    qs = jax.lax.all_gather(q2, axis_name)
    s2 = jax.lax.all_gather(scale2, axis_name)
    out = (qs.astype(jnp.float32) * s2[:, None]).reshape(-1)
    out = out[: x.size] / n
    return out.reshape(x.shape).astype(x.dtype)


def ef_compressed_all_reduce_mean(x: jax.Array, err: jax.Array, axis_name: str):
    """Error-feedback wrapper: returns (reduced, new_error)."""
    corrected = x.astype(jnp.float32) + err.astype(jnp.float32)
    reduced = compressed_all_reduce_mean(corrected, axis_name)
    # residual of *this device's* contribution
    q, scale = _quantize(corrected.reshape(-1))
    approx = _dequantize(q, scale).reshape(x.shape)
    new_err = corrected - approx
    return reduced.astype(x.dtype), new_err.astype(err.dtype)


def make_compressed_grad_reducer(mesh, axis_name: str = "pod"):
    """Tree-level reducer over the pod axis via shard_map.

    grads must be pod-local (i.e. produced inside an outer shard_map over the
    pod axis, or with batch sharded only over 'data').  Returns
    (reduce_fn, init_err_fn)."""
    from jax.sharding import PartitionSpec as P
    from jax import shard_map

    def reduce_tree(grads, errs):
        def per_leaf(g, e):
            fn = shard_map(partial(ef_compressed_all_reduce_mean, axis_name=axis_name),
                           mesh=mesh,
                           in_specs=(P(), P()), out_specs=(P(), P()),
                           check_vma=False)
            return fn(g, e)

        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        flat_e = tdef.flatten_up_to(errs)
        out = [per_leaf(g, e) for g, e in zip(flat_g, flat_e)]
        return (tdef.unflatten([o[0] for o in out]),
                tdef.unflatten([o[1] for o in out]))

    def init_err(grads):
        return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    return reduce_tree, init_err
