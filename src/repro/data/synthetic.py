"""Synthetic data: point clouds with LiDAR-like statistics, token streams,
and typed graphs.  Deterministic per (seed, index) so a restarted job's
fast-forwarded iterator reproduces the exact stream (fault tolerance)."""
from __future__ import annotations

from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparse_tensor import SparseTensor, voxelize


def lidar_scene(key, n_points: int, capacity: int, channels: int,
                extent: float = 100.0, voxel: float = 0.2,
                batch_size: int = 1) -> SparseTensor:
    """Point cloud with ground-plane + cluster structure (≈LiDAR sparsity:
    points concentrate on a 2D manifold, ~99.99% of the voxel grid empty)."""
    k1, k2, k3, k4, kb = jax.random.split(key, 5)
    n_ground = n_points // 2
    ground = jnp.stack([
        jax.random.uniform(k1, (n_ground,)) * extent,
        jax.random.uniform(k2, (n_ground,)) * extent,
        jax.random.normal(k3, (n_ground,)) * 0.2 + 1.0,
    ], axis=1)
    n_obj = n_points - n_ground
    centers = jax.random.uniform(k4, (32, 3)) * jnp.array([extent, extent, 4.0])
    assign = jax.random.randint(k1, (n_obj,), 0, 32)
    objs = centers[assign] + jax.random.normal(k2, (n_obj, 3)) * jnp.array([1.5, 1.5, 0.8])
    pts = jnp.concatenate([ground, objs], axis=0)
    # Clip to a declared region (real LiDAR pipelines crop to a range cap
    # anyway): the declared bound lets the mapping engine pack voxel keys
    # into one int32 word, making kernel-map construction a single argsort.
    margin = 8.0
    pts = jnp.clip(pts, -margin, extent + margin)
    bound = int(np.ceil((extent + margin) / voxel)) + 2
    feats = jax.random.normal(k3, (n_points, channels))
    bidx = jax.random.randint(kb, (n_points,), 0, batch_size)
    return voxelize(pts, feats, voxel, capacity, batch_idx=bidx,
                    batch_size=batch_size, spatial_bound=bound)


def token_batches(seed: int, batch: int, seq: int, vocab: int) -> Iterator[dict]:
    """Infinite iterator of (tokens, labels) with skewed unigram stats."""
    i = 0
    while True:
        rng = np.random.default_rng((seed, i))
        # zipf-ish distribution so embedding-gather patterns are realistic
        z = rng.zipf(1.3, size=(batch, seq + 1))
        toks = np.minimum(z - 1, vocab - 1).astype(np.int32)
        yield {"tokens": jnp.asarray(toks[:, :-1]), "labels": jnp.asarray(toks[:, 1:])}
        i += 1


def typed_graph(key, n_nodes: int, n_edges: int, n_relations: int,
                power: float = 1.2):
    """Random typed multigraph with power-law-ish degree distribution."""
    k1, k2, k3 = jax.random.split(key, 3)
    # preferential-attachment-flavored endpoints
    u = jax.random.uniform(k1, (n_edges,))
    src = jnp.clip((u ** power * n_nodes).astype(jnp.int32), 0, n_nodes - 1)
    dst = jax.random.randint(k2, (n_edges,), 0, n_nodes)
    etype = jax.random.randint(k3, (n_edges,), 0, n_relations)
    return src.astype(jnp.int32), dst.astype(jnp.int32), etype.astype(jnp.int32)
