"""Cross-host serving fleet (serve/fleet.py, serve/wire.py, serve/service.py).

The contracts under test:

* **wire codec**: every value that crosses the fleet boundary round-trips
  bit-identically — scalars, nested containers, ndarrays of every serving
  dtype including ``bfloat16`` — and malformed frames (bad magic, newer
  version, trailing bytes, overflowing ints) raise ``WireError`` instead
  of mis-parsing;
* **ServiceConfig**: dict round-trip rejects unknown keys, persists
  alongside plans in ``PlanRegistry``, and the legacy per-kwarg
  constructor path folds into it with exactly one DeprecationWarning per
  process;
* **SparseService conformance**: Engine, DeviceRouter and FleetFrontend
  all satisfy the protocol and produce **bit-identical** results on the
  same stream;
* **failover loses zero requests**: an injected worker exception
  (router) or a killed worker process mid-stream (fleet) re-routes every
  un-acked batch to the survivors, outputs stay bit-identical to the
  single-device engine, and — with ``respawn`` — a replacement host comes
  back re-warmed.

The fleet cases spawn real localhost worker subprocesses (each with its
own jax runtime), so they are the slowest in the tier-1 suite; scene
counts and the bucket ladder are kept minimal.
"""
import threading
import warnings

import jax
import numpy as np
import pytest

from repro.serve import (BucketLadder, DeviceRouter, Engine, PlanRegistry,
                         Scene)
from repro.serve.batcher import SceneBatcher, SceneDelta, apply_delta
from repro.serve.fleet import (FleetFrontend, FleetStats, FleetWorker,
                               HostHandle)
from repro.serve import service as service_mod
from repro.serve import wire
from repro.serve.service import (STATS_SCHEMA_VERSION, ServiceConfig,
                                 SparseService, resolve_config)
from repro.serve.workload import lidar_stream

from conftest import property_test

ARCH = "minkunet_kitti"
SCENES, BOUND = lidar_stream(0, 6, 4, n_range=(40, 100))
CFG = ServiceConfig(buckets=(128, 256), max_batch=2, spatial_bound=BOUND)

try:
    import ml_dtypes
    HAS_BF16 = True
except ImportError:             # pragma: no cover - jax ships ml_dtypes
    HAS_BF16 = False


def _assert_results_equal(got, want):
    assert len(got) == len(want)
    for a, b in zip(got, want):
        np.testing.assert_array_equal(a.coords, b.coords)
        np.testing.assert_array_equal(a.feats, b.feats)
        assert a.stride == b.stride


# ------------------------------------------------------------------ wire codec

@property_test(
    "value",
    [None, True, False, 0, -1, 2**62, 1.5, -0.0,
     "", "héllo", b"\x00\xff", [1, [2, "x"], None],
     {"a": 1, 2: [True, b"z"], "n": {"d": 3.5}}],
    lambda st: {"value": st.recursive(
        st.none() | st.booleans() |
        st.integers(min_value=-2**63, max_value=2**63 - 1) |
        st.floats(allow_nan=False) | st.text(max_size=20) |
        st.binary(max_size=20),
        lambda leaf: st.lists(leaf, max_size=4) |
        st.dictionaries(st.text(max_size=5), leaf, max_size=4),
        max_leaves=10)})
def test_wire_scalar_tree_roundtrip(value):
    assert wire.decode(wire.encode(value)) == value


@pytest.mark.parametrize("dtype", ["int32", "int64", "uint8", "float32",
                                   "float64", "bool"])
@pytest.mark.parametrize("shape", [(0, 3), (5,), (4, 4), ()])
def test_wire_ndarray_roundtrip(dtype, shape):
    rng = np.random.default_rng(0)
    a = np.asarray(rng.random(shape) * 100).astype(dtype)
    b = wire.decode(wire.encode(a))
    assert b.dtype == a.dtype and b.shape == a.shape
    np.testing.assert_array_equal(a, b)


@pytest.mark.skipif(not HAS_BF16, reason="ml_dtypes unavailable")
def test_wire_bfloat16_bit_identical():
    a = np.linspace(-3.0, 3.0, 16).astype(ml_dtypes.bfloat16).reshape(4, 4)
    b = wire.decode(wire.encode(a))
    assert b.dtype == a.dtype
    np.testing.assert_array_equal(a.view(np.uint16), b.view(np.uint16))


def test_wire_rejects_malformed():
    frame = wire.pack_frame(wire.encode({"op": "ping"}))
    with pytest.raises(wire.WireError, match="magic"):
        wire.unpack_header(b"XX" + frame[2:wire.HEADER_SIZE])
    with pytest.raises(wire.WireError, match="version"):
        wire.unpack_header(bytes([frame[0], frame[1], 99])
                           + frame[3:wire.HEADER_SIZE])
    with pytest.raises(wire.WireError, match="trailing"):
        wire.decode(wire.encode(1) + b"\x00")
    with pytest.raises(wire.WireError, match="truncated"):
        wire.decode(wire.encode("hello")[:-2])
    with pytest.raises(wire.WireError, match="overflow"):
        wire.encode(2**70)
    with pytest.raises(wire.WireError, match="unencodable"):
        wire.encode(object())


def test_wire_socket_roundtrip():
    import socket
    a, b = socket.socketpair()
    try:
        msg = {"op": "execute", "scenes": [wire.scene_to_wire(SCENES[0])]}
        wire.send_msg(a, msg)
        got = wire.recv_msg(b)
        assert got["op"] == "execute"
        s = wire.scene_from_wire(got["scenes"][0])
        np.testing.assert_array_equal(s.coords, SCENES[0].coords)
        assert s.digest == SCENES[0].digest
    finally:
        a.close()
        b.close()


def test_wire_serving_object_roundtrips():
    s = SCENES[0]
    s2 = wire.scene_from_wire(wire.decode(wire.encode(wire.scene_to_wire(s))))
    assert s2.digest == s.digest
    np.testing.assert_array_equal(s2.feats, s.feats)

    D = s.coords.shape[1]
    d = SceneDelta(removed=s.coords[:3], added_coords=np.zeros((0, D), np.int32),
                   added_feats=np.zeros((0, s.feats.shape[1]), s.feats.dtype))
    d2 = wire.delta_from_wire(
        wire.decode(wire.encode(wire.delta_to_wire(d))))
    np.testing.assert_array_equal(d2.removed, d.removed)
    np.testing.assert_array_equal(apply_delta(s, d2).coords,
                                  apply_delta(s, d).coords)

    # PackedBatch: declared bounds survive the trip (the key-bit budget)
    batcher = SceneBatcher(CFG.ladder(), CFG.spatial_bound)
    batch = batcher.pack(SCENES[:2])
    b2 = wire.packed_batch_from_wire(
        wire.decode(wire.encode(wire.packed_batch_to_wire(batch))))
    assert b2.st.batch_bound == batch.st.batch_bound
    assert b2.st.spatial_bound == batch.st.spatial_bound
    assert b2.st.stride == batch.st.stride
    assert int(b2.st.num_valid) == int(batch.st.num_valid)
    assert b2.scene_sizes == batch.scene_sizes
    assert b2.bucket == batch.bucket and b2.digest == batch.digest
    np.testing.assert_array_equal(np.asarray(b2.st.coords),
                                  np.asarray(batch.st.coords))


# -------------------------------------------------------------- ServiceConfig

def test_service_config_dict_roundtrip_rejects_unknown():
    d = CFG.to_dict()
    assert ServiceConfig.from_dict(d) == CFG
    import json
    assert ServiceConfig.from_dict(json.loads(json.dumps(d))) == CFG
    with pytest.raises(ValueError, match="unknown ServiceConfig keys"):
        ServiceConfig.from_dict({**d, "warp_factor": 9})


def test_service_config_persists_in_plan_registry(tmp_path):
    reg = PlanRegistry()
    reg.set(ARCH, {})
    reg.set_service(ARCH, CFG)
    path = reg.save(str(tmp_path / "plans.json"))
    loaded = PlanRegistry.load(path)
    assert loaded.service(ARCH) == CFG
    assert loaded.service("never_tuned") is None


def test_legacy_kwargs_warn_once_and_typo_raises():
    old = service_mod._LEGACY_WARNED[0]
    service_mod._LEGACY_WARNED[0] = False
    try:
        with pytest.warns(DeprecationWarning, match="ServiceConfig"):
            cfg = resolve_config(None, {"ladder": BucketLadder((128, 256),
                                                               max_batch=2),
                                        "spatial_bound": BOUND})
        assert cfg == CFG
        with warnings.catch_warnings():     # second use: silent
            warnings.simplefilter("error")
            resolve_config(None, {"max_wait_ms": 5.0})
    finally:
        service_mod._LEGACY_WARNED[0] = old
    with pytest.raises(TypeError, match="unexpected serving kwargs"):
        resolve_config(None, {"ladderr": None})


def test_engine_legacy_and_config_paths_identical():
    eng = Engine(ARCH, config=CFG)
    legacy = Engine(ARCH, ladder=CFG.ladder(), spatial_bound=BOUND)
    assert eng.config == legacy.config == CFG
    _assert_results_equal(legacy.serve(SCENES[:2]), eng.serve(SCENES[:2]))


# --------------------------------------------------- SparseService conformance

@pytest.fixture(scope="module")
def engine_ref():
    return Engine(ARCH, config=CFG).serve(SCENES, flush_every=3)


@pytest.fixture(scope="module")
def fleet():
    fl = FleetFrontend(ARCH, hosts=2, config=CFG)
    yield fl
    fl.close()


@pytest.fixture
def service(request, fleet):
    if request.param == "engine":
        return Engine(ARCH, config=CFG)
    if request.param == "router":
        dev = jax.devices()[0]
        return DeviceRouter(ARCH, devices=[dev] * 2, config=CFG)
    return fleet


@pytest.mark.parametrize("service", ["engine", "router", "fleet"],
                         indirect=True)
def test_sparse_service_conformance(service, engine_ref):
    assert isinstance(service, SparseService)
    assert service.config == CFG
    got = service.serve(SCENES, flush_every=3)
    _assert_results_equal(got, engine_ref)
    # submit/flush ticketing: monotone tickets, flush resolves exactly them
    t0 = service.submit(SCENES[0])
    t1 = service.submit(SCENES[1])
    assert t1 == t0 + 1
    out = service.flush()
    assert set(out) >= {t0, t1}
    _assert_results_equal([out[t0], out[t1]], engine_ref[:2])
    # streaming: a delta resolves like the full scene it denotes
    service.submit(SCENES[2], stream="s0")
    service.flush()
    D = SCENES[2].coords.shape[1]
    delta = SceneDelta(removed=SCENES[2].coords[:4],
                       added_coords=np.zeros((0, D), np.int32),
                       added_feats=np.zeros((0, SCENES[2].feats.shape[1]),
                                            SCENES[2].feats.dtype))
    td = service.submit_delta("s0", delta)
    got_d = service.flush()[td]
    want_d = Engine(ARCH, config=CFG).serve([apply_delta(SCENES[2], delta)])[0]
    _assert_results_equal([got_d], [want_d])
    s = service.stats.summary()
    assert s["schema_version"] == STATS_SCHEMA_VERSION
    assert s["scenes"] >= len(SCENES)
    assert s["p50_ms"] is None or s["p50_ms"] > 0


# ---------------------------------------------------------------- fleet stats

def test_fleet_stats_blocks(fleet, engine_ref):
    fleet.serve(SCENES, flush_every=3)
    s = fleet.stats.summary()
    assert s["schema_version"] == STATS_SCHEMA_VERSION
    assert set(s["hosts"]) == {"h0", "h1"}
    for h in s["hosts"].values():
        assert h["alive"] and h["weight"] >= 1.0
        assert ":" in h["addr"]
    f = s["fleet"]
    assert f["hosts"] == 2 and f["live"] == 2
    assert f["replication"] == "lazy"
    assert f["failovers"] == 0
    assert sum(h["routed_batches"] for h in s["hosts"].values()) \
        == s["routed_batches"] > 0
    # both hosts actually took traffic (round-robin over uniform groups)
    assert all(h["routed_batches"] >= 1 for h in s["hosts"].values())


def test_fleet_gossip_replication(fleet):
    scenes, _ = lidar_stream(7, 2, 4, n_range=(40, 80))
    fleet.set_replication("gs", "gossip")
    before = fleet.stats.gossip_scenes
    fleet.submit(scenes[0], stream="gs")
    fleet.flush()
    live = fleet.live_hosts
    assert fleet.stats.gossip_scenes == before + len(live)
    for h in live:
        assert scenes[0].digest in h.warmed
    # lazy stream: no admit-time fan-out
    before = fleet.stats.gossip_scenes
    fleet.submit(scenes[1], stream="other")
    fleet.flush()
    assert fleet.stats.gossip_scenes == before


# ------------------------------------------------------- routing (unit level)

def _bare_frontend(weights):
    """A FleetFrontend with fake host handles — exercises ``_route``
    without any worker processes."""
    fl = FleetFrontend.__new__(FleetFrontend)
    fl.hosts = []
    fl.outstanding_score = []
    fl._rr = 0
    fl._lock = threading.Lock()
    fl.stats = FleetStats(fl)
    for i, w in enumerate(weights):
        h = HostHandle(i, ("127.0.0.1", 0), None)
        h.alive = True
        h.weight = w
        fl.hosts.append(h)
        fl.outstanding_score.append(0.0)
    return fl


def test_fleet_route_uniform_round_robin():
    fl = _bare_frontend([1.0, 1.0, 1.0])
    counts = [0, 0, 0]
    for _ in range(9):
        counts[fl._route(128)] += 1
    assert counts == [3, 3, 3]


def test_fleet_route_weighted_prefers_fast_host():
    # host 1 calibrated 2x slower: its score grows twice as fast, so the
    # fast host absorbs ~2/3 of a uniform stream
    fl = _bare_frontend([1.0, 2.0])
    counts = [0, 0]
    for _ in range(9):
        counts[fl._route(128)] += 1
    assert counts[0] > counts[1] >= 1, counts
    log = [i for i, _ in fl.stats.route_log]
    fl2 = _bare_frontend([1.0, 2.0])
    for _ in range(9):
        fl2._route(128)
    assert [i for i, _ in fl2.stats.route_log] == log   # deterministic


def test_fleet_route_skips_dead_hosts():
    fl = _bare_frontend([1.0, 1.0])
    fl.hosts[0].alive = False
    assert all(fl._route(64) == 1 for _ in range(3))
    fl.hosts[1].alive = False
    with pytest.raises(RuntimeError, match="no live fleet hosts"):
        fl._route(64)


# --------------------------------------------------- worker ops (in-process)

def test_fleet_worker_handle_ops(engine_ref):
    w = FleetWorker(ARCH, CFG.replace(max_wait_ms=3.0, flush_count=2))
    # admission knobs are stripped: the front end owns flushing
    assert w.config.max_wait_ms is None and w.config.flush_count is None
    assert w.handle({"op": "nope"}) == {"ok": False,
                                        "error": "unknown op 'nope'"}
    assert w.handle({"op": "ping"})["ok"]
    r = w.handle({"op": "hello"})
    assert r["ok"] and r["arch"] == ARCH
    # execute one front-end-formed group: bit-identical to the engine
    group = [wire.scene_to_wire(s) for s in SCENES[:2]]
    r = w.handle({"op": "execute", "scenes": group})
    assert r["ok"]
    got = [wire.result_from_wire(d) for d in r["results"]]
    _assert_results_equal(got, engine_ref[:2])
    # a raising op reports, never kills the loop
    r = w.handle({"op": "execute", "scenes": [{"bad": "payload"}]})
    assert not r["ok"] and "error" in r


# ------------------------------------------------------- per-host swimlanes

def test_chrome_trace_per_host_swimlanes():
    from repro.obs import Tracer, chrome_trace
    tr = Tracer()
    with tr.span("host_rpc", host="h0", rows=128):
        pass
    with tr.span("host_rpc", host="h1", rows=128):
        pass
    tr.event("host_down", host="h1", why="execute")
    doc = chrome_trace(tr)
    events = doc["traceEvents"]
    lanes = {e["args"]["name"] for e in events
             if e["ph"] == "M" and e["name"] == "process_name"}
    assert {"host h0", "host h1"} <= lanes
    pids = {e["args"]["host"]: e["pid"] for e in events if e["ph"] == "X"}
    assert pids["h0"] != pids["h1"]       # one synthetic process per host
    (down,) = [e for e in events if e["ph"] == "i"]
    assert down["pid"] == pids["h1"]      # events land in their host's lane


# ----------------------------------------------------------- router failover

def test_router_injected_failure_zero_loss(engine_ref):
    dev = jax.devices()[0]
    r = DeviceRouter(ARCH, devices=[dev] * 2, config=CFG)

    boom = {"armed": False}
    orig = r.workers[1]._run_pipeline

    def failing(groups, on_done, urgent=None):
        if boom["armed"]:
            raise RuntimeError("injected device loss")
        return orig(groups, on_done, urgent)

    r.workers[1]._run_pipeline = failing
    got = r.serve(SCENES[:3], flush_every=0)
    _assert_results_equal(got, engine_ref[:3])
    boom["armed"] = True                      # dies mid-stream
    got = r.serve(SCENES[3:], flush_every=0)
    _assert_results_equal(got, engine_ref[3:])
    s = r.stats.summary()
    assert s["failover"]["dead"] == ["d1"]
    assert s["failover"]["worker_failures"] == 1
    assert s["failover"]["rerouted_batches"] >= 1
    assert not s["devices"]["d1"]["alive"] and s["devices"]["d0"]["alive"]
    # the survivor carries on alone
    got = r.serve(SCENES[:2], flush_every=0)
    _assert_results_equal(got, engine_ref[:2])


def test_router_all_workers_dead_raises():
    dev = jax.devices()[0]
    r = DeviceRouter(ARCH, devices=[dev], config=CFG)

    def failing(groups, on_done, urgent=None):
        raise RuntimeError("injected")

    r.workers[0]._run_pipeline = failing
    with pytest.raises(RuntimeError, match="dead"):
        r.serve(SCENES[:2])


# ------------------------------------------------------------ fleet failover

def test_fleet_kill_worker_mid_stream_zero_loss(engine_ref):
    """The acceptance contract: kill a worker process mid-stream, lose
    zero requests, outputs bit-identical to the single-device engine, and
    (respawn=True) a re-warmed replacement rejoins the fleet."""
    fl = FleetFrontend(ARCH, hosts=2, config=CFG, respawn=True,
                       heartbeat_s=0.2)
    try:
        out = {}
        tickets = [fl.submit(s) for s in SCENES[:3]]
        out.update(fl.flush())

        victim = fl.hosts[0]
        victim.proc.kill()
        victim.proc.wait(timeout=10)

        tickets += [fl.submit(s) for s in SCENES[3:]]
        out.update(fl.flush())            # detects the death, re-routes

        assert sorted(out) == tickets     # zero lost requests
        got = [out[t] for t in tickets]
        _assert_results_equal(got, engine_ref)

        s = fl.stats.summary()
        assert s["fleet"]["failovers"] >= 1
        assert s["fleet"]["respawns"] >= 1
        assert s["fleet"]["live"] == 2    # replacement joined
        assert all(h.alive for h in fl.hosts)
        # the respawned host was re-warmed from the front end's digest store
        assert fl.hosts[0].warmed >= set(fl._digest_store)
        # and the fleet still serves bit-identically after recovery
        _assert_results_equal(fl.serve(SCENES, flush_every=3), engine_ref)
    finally:
        fl.close()
