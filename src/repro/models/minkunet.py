"""MinkUNet (3D semantic segmentation U-Net) on the sparse-conv engine.

The paper's primary segmentation workload (SemanticKITTI-MinkUNet, Fig. 14).
Structure (MinkUNet18-ish, width-scalable): stem → 4 encoder stages
(stride-2 conv + residual submanifold blocks) → 4 decoder stages
(transposed conv reusing the encoder's kernel map + skip concat + blocks).

Layer *groups* (paper Fig. 12) fall out naturally: every submanifold conv at
one stride shares a kernel map; each down/up-sample pair shares the strided
map.  The per-group DataflowConfig dict is what the Sparse Autotuner tunes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import dataflows as df
from repro.core.kmap import KernelMap, MapCache, build_kmap, transpose_kmap
from repro.core.sparse_conv import (ConvSpec, TrainDataflowConfig, apply_conv,
                                    init_conv)
from repro.core.sparse_tensor import SparseTensor


@dataclasses.dataclass(frozen=True)
class MinkUNetConfig:
    in_channels: int = 4
    num_classes: int = 19
    width: float = 1.0
    enc_channels: tuple = (32, 64, 128, 256)
    dec_channels: tuple = (256, 128, 96, 96)
    blocks_per_stage: int = 2

    def ch(self, c: float) -> int:
        return max(8, int(c * self.width))


def _bn_relu_init(c: int):
    return {"scale": jnp.ones((c,)), "bias": jnp.zeros((c,))}


def _bn_relu(p, st: SparseTensor, relu: bool = True,
             mode: str = "batch") -> SparseTensor:
    """Masked batch norm (stats over valid rows) + ReLU.

    ``mode="batch"`` (training/eval parity with the seed) normalizes with
    statistics over all valid rows — which couples every row in a *batched*
    tensor.  ``mode="affine"`` is the serving/inference mode: a per-channel
    scale+bias only, so each row's output depends on that row alone and a
    capacity-bucketed batched forward is bit-identical to the per-scene
    forward (the serving engine's correctness contract).  It implements the
    standard deploy-time convention of *folding* BN into an affine op: a
    checkpoint exported for serving is expected to carry running statistics
    pre-folded into ``scale``/``bias`` (this repo trains with batch stats
    and keeps no running stats, so affine-mode outputs are not numerically
    comparable to a ``mode="batch"`` forward of the same raw params).
    """
    mask = st.valid_mask[:, None]
    x = st.feats.astype(jnp.float32)
    if mode == "affine":
        y = x * p["scale"] + p["bias"]
    else:
        assert mode == "batch", mode
        n = jnp.maximum(st.num_valid, 1).astype(jnp.float32)
        mean = jnp.sum(jnp.where(mask, x, 0), axis=0) / n
        var = jnp.sum(jnp.where(mask, jnp.square(x - mean), 0), axis=0) / n
        y = (x - mean) * jax.lax.rsqrt(var + 1e-5) * p["scale"] + p["bias"]
    if relu:
        y = jax.nn.relu(y)
    return st.replace_feats(jnp.where(mask, y, 0).astype(st.feats.dtype))


def init_params(cfg: MinkUNetConfig, key) -> dict:
    keys = iter(jax.random.split(key, 128))
    p: dict = {}
    w = cfg.ch
    c0 = w(cfg.enc_channels[0])
    p["stem1"] = init_conv(next(keys), ConvSpec(cfg.in_channels, c0, 3))
    p["stem1_bn"] = _bn_relu_init(c0)
    p["stem2"] = init_conv(next(keys), ConvSpec(c0, c0, 3))
    p["stem2_bn"] = _bn_relu_init(c0)

    cin = c0
    for i, ce in enumerate(cfg.enc_channels):
        ce = w(ce)
        p[f"down{i}"] = init_conv(next(keys), ConvSpec(cin, ce, 2, stride=2))
        p[f"down{i}_bn"] = _bn_relu_init(ce)
        for b in range(cfg.blocks_per_stage):
            p[f"enc{i}b{b}_1"] = init_conv(next(keys), ConvSpec(ce, ce, 3))
            p[f"enc{i}b{b}_1_bn"] = _bn_relu_init(ce)
            p[f"enc{i}b{b}_2"] = init_conv(next(keys), ConvSpec(ce, ce, 3))
            p[f"enc{i}b{b}_2_bn"] = _bn_relu_init(ce)
        cin = ce

    skips = [c0] + [w(c) for c in cfg.enc_channels[:-1]]
    for i, cd in enumerate(cfg.dec_channels):
        cd = w(cd)
        p[f"up{i}"] = init_conv(next(keys), ConvSpec(cin, cd, 2, stride=2, transposed=True))
        p[f"up{i}_bn"] = _bn_relu_init(cd)
        cskip = skips[-(i + 1)]
        for b in range(cfg.blocks_per_stage):
            cin_b = cd + cskip if b == 0 else cd
            p[f"dec{i}b{b}_1"] = init_conv(next(keys), ConvSpec(cin_b, cd, 3))
            p[f"dec{i}b{b}_1_bn"] = _bn_relu_init(cd)
            p[f"dec{i}b{b}_2"] = init_conv(next(keys), ConvSpec(cd, cd, 3))
            p[f"dec{i}b{b}_2_bn"] = _bn_relu_init(cd)
        cin = cd
    p["head"] = {"w": jax.random.normal(next(keys), (cin, cfg.num_classes)) * cin ** -0.5}
    return p


def layer_signatures(cfg: MinkUNetConfig) -> Dict[str, tuple]:
    """layer name → map-sharing signature (stride_in, K, kind) for grouping."""
    sigs: Dict[str, tuple] = {"stem1": (1, 3, "sub"), "stem2": (1, 3, "sub")}
    for i in range(len(cfg.enc_channels)):
        sigs[f"down{i}"] = (2 ** i, 2, "down")
        for b in range(cfg.blocks_per_stage):
            sigs[f"enc{i}b{b}_1"] = (2 ** (i + 1), 3, "sub")
            sigs[f"enc{i}b{b}_2"] = (2 ** (i + 1), 3, "sub")
    n = len(cfg.dec_channels)
    for i in range(n):
        lvl = n - i - 1            # decoder level i undoes down{lvl}
        sigs[f"up{i}"] = (2 ** lvl, 2, "up")
        for b in range(cfg.blocks_per_stage):
            sigs[f"dec{i}b{b}_1"] = (2 ** lvl, 3, "sub")
            sigs[f"dec{i}b{b}_2"] = (2 ** lvl, 3, "sub")
    return sigs


def build_maps(st: SparseTensor, cache: Optional[MapCache] = None) -> dict:
    """Build every kernel map once (maps are shared within groups).

    A single ``MapCache`` spans the whole pyramid: the submanifold and
    strided convs at each level share one sorted coordinate table, and each
    downsample's unique pass emits the next level's table for free.  Callers
    that already hold a warm cache for these coordinates (the serving
    engine) pass it in; by default a fresh one is created per call, which is
    also the only safe choice under ``jit`` (a cache must not outlive its
    trace)."""
    if cache is None:   # NOT `or`: an empty caller cache is falsy but wanted
        cache = MapCache.for_tensor(st)
    maps = {}
    cur = st
    maps[("sub", 1)] = build_kmap(cur, 3, 1, cache=cache)
    tensors = {1: cur}
    stride = 1
    for i in range(4):
        kd = build_kmap(cur, 2, 2, cache=cache)
        maps[("down", stride)] = kd
        cur = SparseTensor(coords=kd.out_coords, feats=jnp.zeros(
            (kd.capacity, 1), st.feats.dtype), num_valid=kd.n_out, stride=kd.out_stride,
            batch_bound=st.batch_bound, spatial_bound=st.spatial_bound)
        stride *= 2
        tensors[stride] = cur
        maps[("sub", stride)] = build_kmap(cur, 3, 1, cache=cache)
    for lvl in range(3, -1, -1):
        s = 2 ** lvl
        maps[("up", s)] = transpose_kmap(maps[("down", s)], tensors[s])
    return maps


def _conv_bn(p, name, st, kmap, cfgs, relu=True, bn_mode="batch"):
    st = apply_conv(p[name], st, kmap, cfgs)
    return _bn_relu(p[f"{name}_bn"], st, relu, mode=bn_mode)


def apply(params, st: SparseTensor, cfg: MinkUNetConfig,
          maps: Optional[dict] = None,
          assignment: Optional[Dict[tuple, TrainDataflowConfig]] = None,
          bn_mode: str = "batch") -> jax.Array:
    """Returns per-point class logits (capacity, num_classes).

    ``bn_mode="affine"`` runs inference-mode normalization (see ``_bn_relu``)
    — required by the serving engine so batched and per-scene forwards agree
    bit-for-bit."""
    maps = maps or build_maps(st)
    assignment = assignment or {}

    def cfg_for(sig) -> TrainDataflowConfig:
        return assignment.get(sig, TrainDataflowConfig())

    def res_block(st, prefix, sig, kmap):
        idn = st.feats
        st = _conv_bn(params, f"{prefix}_1", st, kmap, cfg_for(sig), bn_mode=bn_mode)
        st = apply_conv(params[f"{prefix}_2"], st, kmap, cfg_for(sig))
        st = _bn_relu(params[f"{prefix}_2_bn"], st, relu=False, mode=bn_mode)
        y = jax.nn.relu(st.feats + (idn if idn.shape == st.feats.shape else 0))
        return st.replace_feats(jnp.where(st.valid_mask[:, None], y, 0))

    x = _conv_bn(params, "stem1", st, maps[("sub", 1)], cfg_for((1, 3, "sub")), bn_mode=bn_mode)
    x = _conv_bn(params, "stem2", x, maps[("sub", 1)], cfg_for((1, 3, "sub")), bn_mode=bn_mode)
    skips = [x]
    stride = 1
    for i in range(len(cfg.enc_channels)):
        x = _conv_bn(params, f"down{i}", x, maps[("down", stride)],
                     cfg_for((stride, 2, "down")), bn_mode=bn_mode)
        stride *= 2
        for b in range(cfg.blocks_per_stage):
            x = res_block(x, f"enc{i}b{b}", (stride, 3, "sub"), maps[("sub", stride)])
        if i < len(cfg.enc_channels) - 1:
            skips.append(x)

    n = len(cfg.dec_channels)
    for i in range(n):
        stride //= 2
        x = _conv_bn(params, f"up{i}", x, maps[("up", stride)],
                     cfg_for((stride, 2, "up")), bn_mode=bn_mode)
        skip = skips[-(i + 1)]
        x = x.replace_feats(jnp.concatenate([x.feats, skip.feats], axis=1))
        for b in range(cfg.blocks_per_stage):
            x = res_block(x, f"dec{i}b{b}", (stride, 3, "sub"), maps[("sub", stride)])

    return x.feats @ params["head"]["w"]
