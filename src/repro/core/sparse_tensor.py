"""Capacity-padded sparse tensors.

The paper's workloads are point clouds with *dynamic* point counts.  JAX traces
static shapes, so every sparse tensor in this framework carries a static
capacity ``Nmax`` plus the number of valid rows.  Invalid rows hold the
sentinel coordinate ``INVALID_COORD`` which never matches a hash query, so all
kernel-map machinery is oblivious to padding.  This is the static-shape
analogue of the paper's dynamic-shape kernels (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

# Sentinel for padded coordinate rows.  Chosen so that shifted/strided variants
# of a padded coordinate also never collide with a real voxel key.
INVALID_COORD = jnp.int32(0x3FFFFFF)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SparseTensor:
    """A batched, quantized point cloud (or any D-dim sparse feature map).

    coords: (Nmax, 1 + D) int32 — [batch, x, y, z, ...]; padded rows are
        INVALID_COORD in every spatial column.
    feats:  (Nmax, C) — feature rows; padded rows are zero.
    num_valid: () int32 — number of real rows.
    stride: static int — the tensor stride (grows by conv stride).
    batch_bound: static int — declared number of batches (0 = unknown).
    spatial_bound: static int — declared max |spatial coordinate| (0 =
        unknown).  The packed-key mapping engine (core/hashing.py) derives
        its key bit budget from these; declaring them lets every voxel key
        fit one int32 word so kernel-map construction is a single argsort.
    """

    coords: jax.Array
    feats: jax.Array
    num_valid: jax.Array
    stride: int = dataclasses.field(metadata=dict(static=True), default=1)
    batch_bound: int = dataclasses.field(metadata=dict(static=True), default=0)
    spatial_bound: int = dataclasses.field(metadata=dict(static=True), default=0)

    @property
    def capacity(self) -> int:
        return self.coords.shape[0]

    @property
    def ndim_space(self) -> int:
        return self.coords.shape[1] - 1

    @property
    def num_channels(self) -> int:
        return self.feats.shape[1]

    @property
    def valid_mask(self) -> jax.Array:
        return jnp.arange(self.capacity) < self.num_valid

    def replace_feats(self, feats: jax.Array) -> "SparseTensor":
        return dataclasses.replace(self, feats=feats)


def make_sparse_tensor(coords: jax.Array, feats: jax.Array, num_valid, stride: int = 1,
                       batch_bound: int = 0, spatial_bound: int = 0) -> SparseTensor:
    """Build a SparseTensor, forcing padded rows to sentinel/zero.

    Declared bounds are a caller promise (|spatial coord| ≤ spatial_bound,
    0 ≤ batch < batch_bound); coordinates violating them pack to the PAD key
    and drop out of kernel maps.  ``voxelize`` enforces the promise by
    clamping; here the coords are taken as-is.
    """
    n = coords.shape[0]
    mask = jnp.arange(n) < num_valid
    coords = jnp.where(mask[:, None], coords.astype(jnp.int32), INVALID_COORD)
    feats = jnp.where(mask[:, None], feats, 0)
    return SparseTensor(coords=coords, feats=feats, num_valid=jnp.asarray(num_valid, jnp.int32),
                        stride=stride, batch_bound=batch_bound, spatial_bound=spatial_bound)


@partial(jax.jit, static_argnames=("capacity", "batch_size", "spatial_bound"))
def voxelize(points: jax.Array, feats: jax.Array, voxel_size: float, capacity: int,
             batch_idx: Optional[jax.Array] = None, batch_size: int = 1,
             spatial_bound: int = 0) -> SparseTensor:
    """Quantize raw points to voxel coordinates and deduplicate.

    points: (N, D) float — raw coordinates.
    feats:  (N, C) — per-point features (first point in each voxel wins; the
        paper keeps one point per voxel, matching CenterPoint preprocessing).
    Returns a SparseTensor with static ``capacity`` rows.
    """
    n, d = points.shape
    if batch_idx is None:
        batch_idx = jnp.zeros((n,), jnp.int32)
    q = jnp.floor(points / voxel_size).astype(jnp.int32)
    if spatial_bound > 0:
        # A declared bound is a promise the mapping engine packs keys by;
        # enforce it here (range cap, as real LiDAR pipelines do) so stray
        # points clamp to the boundary voxel instead of silently vanishing
        # from every kernel map.
        q = jnp.clip(q, -spatial_bound, spatial_bound)
    coords = jnp.concatenate([batch_idx[:, None].astype(jnp.int32), q], axis=1)
    #

    # Deduplicate via lexicographic sort; first occurrence wins.
    from repro.core import hashing

    order = hashing.lex_argsort(coords)
    coords_sorted = coords[order]
    same_as_prev = hashing.rows_equal(coords_sorted[1:], coords_sorted[:-1])
    is_first = jnp.concatenate([jnp.ones((1,), bool), ~same_as_prev])
    # Stable compaction of the first-occurrence rows.
    dest = jnp.cumsum(is_first) - 1
    dest = jnp.where(is_first, dest, capacity)  # drop dups past the end
    out_coords = jnp.full((capacity + 1, d + 1), INVALID_COORD, jnp.int32)
    out_feats = jnp.zeros((capacity + 1, feats.shape[1]), feats.dtype)
    out_coords = out_coords.at[dest].set(coords[order], mode="drop")
    out_feats = out_feats.at[dest].set(feats[order], mode="drop")
    num = jnp.minimum(jnp.sum(is_first), capacity)
    return SparseTensor(coords=out_coords[:capacity], feats=out_feats[:capacity],
                        num_valid=num.astype(jnp.int32), stride=1,
                        batch_bound=batch_size, spatial_bound=spatial_bound)


def to_dense(st: SparseTensor, grid: tuple, batch_size: int) -> jax.Array:
    """Scatter a SparseTensor to a dense (B, *grid, C) array (test oracle)."""
    d = st.ndim_space
    assert len(grid) == d
    mask = st.valid_mask
    idx = [jnp.where(mask, st.coords[:, 0], batch_size)]  # OOB batch drops row
    for i in range(d):
        c = st.coords[:, 1 + i] // st.stride
        idx.append(jnp.where(mask & (c >= 0) & (c < grid[i]), c, grid[i]))
    dense = jnp.zeros((batch_size + 1,) + tuple(g + 1 for g in grid) + (st.num_channels,), st.feats.dtype)
    dense = dense.at[tuple(idx)].add(st.feats, mode="drop")
    slicer = (slice(0, batch_size),) + tuple(slice(0, g) for g in grid) + (slice(None),)
    return dense[slicer]
