"""Map-construction latency: the packed single-sort engine, uncached vs
cross-layer cached (``MapCache`` table reuse + strided-output adoption).

The paper's Tables 3 vs 4 show mapping-operator overhead (bitmask building,
sorting, reordering) can flip end-to-end rankings; Minuet (PAPERS.md) makes
sort/merge mapping the central optimization target.  This suite times the
mapping path in isolation:

* single-layer kernel-map construction (submanifold K=3 and strided K=2)
  on the deterministic CenterPoint detection scene, jitted, best-of-n;
* the full CenterPoint map stack (5 submanifold + 4 strided maps) built
  through the execution plan's ``KmapSpec`` program (cross-layer
  ``MapCache``: shared tables + adoption edges) vs the same stack with
  every map built cold — the cached-vs-uncached A/B that replaced the
  deleted legacy-engine A/B;
* split-plan construction with and without the fused tile-occupancy pass.

``--tiny`` runs a reduced scene for CI smoke coverage.
"""
from __future__ import annotations

import argparse

import jax

from benchmarks import common
from repro.core import kmap as km
from repro.models import centerpoint


def _stack_uncached(stx):
    """The CenterPoint map ladder with every table built from scratch —
    what the per-layer world pays without the plan's adoption edges."""
    import jax.numpy as jnp

    from repro.core.sparse_tensor import SparseTensor

    maps = {("sub", 1): km.build_kmap(stx, 3, 1)}
    cur, stride = stx, 1
    for _ in range(4):
        kd = km.build_kmap(cur, 2, 2)
        maps[("down", stride)] = kd
        cur = SparseTensor(coords=kd.out_coords,
                           feats=jnp.zeros((kd.capacity, 1), stx.feats.dtype),
                           num_valid=kd.n_out, stride=kd.out_stride,
                           batch_bound=stx.batch_bound,
                           spatial_bound=stx.spatial_bound)
        stride *= 2
        maps[("sub", stride)] = km.build_kmap(cur, 3, 1)
    return maps


def run(tiny: bool = False):
    if tiny:
        stx = common.det_scene(n=300, cap=512)
        iters = 2
    else:
        stx = common.det_scene()
        iters = 5

    fn_sub = jax.jit(lambda: km.build_kmap(stx, 3, 1))
    common.emit("kmap/sub_k3", common.time_fn(lambda: fn_sub(), iters=iters), "")

    fn_down = jax.jit(lambda: km.build_kmap(stx, 2, 2))
    common.emit("kmap/down_k2s2", common.time_fn(lambda: fn_down(), iters=iters), "")

    results = {}
    for name, fn in (("uncached", _stack_uncached),
                     ("cached", centerpoint.build_maps)):
        f = jax.jit(lambda fn=fn: fn(stx))
        us = common.time_fn(lambda: f(), iters=iters)
        results[name] = us
        common.emit(f"kmap/centerpoint_stack/{name}", us, "")
    ratio = results["uncached"] / max(results["cached"], 1e-9)
    common.emit("kmap/speedup/stack", 0.0, f"cached_vs_uncached={ratio:.2f}x")

    # split-plan construction: fused occupancy vs separate pass
    kmap = km.build_kmap(stx, 3, 1)
    fn_sep = jax.jit(lambda: km.tile_occupancy(kmap, km.make_split_plan(kmap, 2), 128))
    fn_fused = jax.jit(lambda: km.make_split_plan(kmap, 2, tile_m=128).occupancy)
    common.emit("kmap/plan_occupancy/separate", common.time_fn(lambda: fn_sep(), iters=iters), "")
    common.emit("kmap/plan_occupancy/fused", common.time_fn(lambda: fn_fused(), iters=iters), "")

    # the table-build sort itself: O(N·bits) radix (what CoordTable.build
    # now runs for bounded keys) vs the stable comparison argsort it
    # replaced — same permutation, different asymptotics
    from repro.core import hashing
    spec = hashing.key_spec_for(3, stx.batch_bound, stx.spatial_bound)
    keys = hashing.pack_keys(stx.coords, spec, valid=stx.valid_mask)
    assert hashing.radix_word_bits(spec) is not None, "scene spec unbounded?"
    fn_radix = jax.jit(lambda: hashing.radix_argsort_keys(keys, spec))
    if keys.ndim == 1:
        fn_cmp = jax.jit(lambda: jax.numpy.argsort(keys, stable=True))
    else:
        fn_cmp = jax.jit(lambda: hashing.lex_argsort(keys))
    us_r = common.time_fn(lambda: fn_radix(), iters=iters)
    us_c = common.time_fn(lambda: fn_cmp(), iters=iters)
    common.emit("kmap/key_sort/radix", us_r, "")
    common.emit("kmap/key_sort/argsort", us_c, "")
    common.emit("kmap/speedup/key_sort", 0.0,
                f"radix_vs_argsort={us_c / max(us_r, 1e-9):.2f}x")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="reduced scene for CI smoke runs")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(tiny=args.tiny)
