"""MinkUNet (3D semantic segmentation U-Net) on the sparse-conv engine.

The paper's primary segmentation workload (SemanticKITTI-MinkUNet, Fig. 14).
Structure (MinkUNet18-ish, width-scalable): stem → 4 encoder stages
(stride-2 conv + residual submanifold blocks) → 4 decoder stages
(transposed conv reusing the encoder's kernel map + skip concat + blocks).

The model *declares* its layers (``declare`` → ``core.plan.ModelDecl``) and
executes through a compiled ``NetworkPlan``: layer *groups* (paper Fig. 12)
fall out of the declared map-sharing signatures — every submanifold conv at
one stride shares a kernel map; each down/up-sample pair shares the strided
map — and the Sparse Autotuner rebinds the plan's per-group
``TrainDataflowConfig``s.  ``apply``/``build_maps`` keep the pre-plan
call signatures (and bit-exact outputs) for existing callers.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax

from repro.core import plan as planlib
from repro.core.kmap import MapCache
from repro.core.plan import (KmapSpec, LayerPlan, ModelDecl, NetworkPlan,
                             compile_plan, pyramid_map_specs)
from repro.core.sparse_conv import ConvSpec, TrainDataflowConfig, init_conv
from repro.core.sparse_tensor import SparseTensor

# Shared masked-BN(+ReLU) now lives with the plan executor; these aliases
# keep the historical names importable (centerpoint, tests).
_bn_relu = planlib.bn_relu
_bn_relu_init = planlib.bn_relu_init


@dataclasses.dataclass(frozen=True)
class MinkUNetConfig:
    in_channels: int = 4
    num_classes: int = 19
    width: float = 1.0
    enc_channels: tuple = (32, 64, 128, 256)
    dec_channels: tuple = (256, 128, 96, 96)
    blocks_per_stage: int = 2

    def ch(self, c: float) -> int:
        return max(8, int(c * self.width))


def init_params(cfg: MinkUNetConfig, key) -> dict:
    keys = iter(jax.random.split(key, 128))
    p: dict = {}
    for lp in declare(cfg).layers:
        p[lp.name] = init_conv(next(keys), lp.spec)
        p[f"{lp.name}_bn"] = _bn_relu_init(lp.spec.out_channels)
    cin = cfg.ch(cfg.dec_channels[-1])
    p["head"] = {"w": jax.random.normal(next(keys), (cin, cfg.num_classes)) * cin ** -0.5}
    return p


def declare(cfg: MinkUNetConfig) -> ModelDecl:
    """Declare the layer list, execution program and kernel-map program.

    ``compile_plan(declare(cfg))`` is the compiled artifact every consumer
    shares (models, tuner, serving engine, training loop)."""
    w = cfg.ch
    c0 = w(cfg.enc_channels[0])
    layers = [
        LayerPlan("stem1", ConvSpec(cfg.in_channels, c0, 3), ("sub", 1), (1, 3, "sub")),
        LayerPlan("stem2", ConvSpec(c0, c0, 3), ("sub", 1), (1, 3, "sub")),
    ]
    ops = [("conv", "stem1"), ("conv", "stem2"), ("push",)]

    def res_block(prefix: str, cin_b: int, c: int, sig, ref):
        layers.append(LayerPlan(f"{prefix}_1", ConvSpec(cin_b, c, 3), ref, sig))
        layers.append(LayerPlan(f"{prefix}_2", ConvSpec(c, c, 3), ref, sig,
                                relu=False))
        ops.extend([("res_begin",), ("conv", f"{prefix}_1"),
                    ("conv", f"{prefix}_2"), ("res_end",)])

    cin = c0
    stride = 1
    for i, ce in enumerate(cfg.enc_channels):
        ce = w(ce)
        layers.append(LayerPlan(f"down{i}", ConvSpec(cin, ce, 2, stride=2),
                                ("down", stride), (stride, 2, "down")))
        ops.append(("conv", f"down{i}"))
        stride *= 2
        for b in range(cfg.blocks_per_stage):
            res_block(f"enc{i}b{b}", ce, ce, (stride, 3, "sub"), ("sub", stride))
        if i < len(cfg.enc_channels) - 1:
            ops.append(("push",))
        cin = ce

    skips = [c0] + [w(c) for c in cfg.enc_channels[:-1]]
    n = len(cfg.dec_channels)
    for i, cd in enumerate(cfg.dec_channels):
        cd = w(cd)
        lvl = n - i - 1            # decoder level i undoes down{lvl}
        s = 2 ** lvl
        layers.append(LayerPlan(f"up{i}", ConvSpec(cin, cd, 2, stride=2, transposed=True),
                                ("up", s), (s, 2, "up")))
        ops.extend([("conv", f"up{i}"), ("concat",)])
        cskip = skips[-(i + 1)]
        for b in range(cfg.blocks_per_stage):
            cin_b = cd + cskip if b == 0 else cd
            res_block(f"dec{i}b{b}", cin_b, cd, (s, 3, "sub"), ("sub", s))
        cin = cd
    ops.append(("head", "head"))

    return ModelDecl(arch="minkunet", layers=tuple(layers), ops=tuple(ops),
                     map_specs=pyramid_map_specs(len(cfg.enc_channels),
                                                 with_up=True,
                                                 table="composed"))


def network_plan(cfg: MinkUNetConfig,
                 assignment: Optional[Dict[tuple, TrainDataflowConfig]] = None,
                 precision=None) -> NetworkPlan:
    """Compile the execution plan: declare → compile (→ tune → persist)."""
    return compile_plan(declare(cfg), assignment=assignment, precision=precision)


def layer_signatures(cfg: MinkUNetConfig) -> Dict[str, tuple]:
    """layer name → map-sharing signature (stride_in, K, kind) for grouping."""
    return {lp.name: lp.sig for lp in declare(cfg).layers}


def build_maps(st: SparseTensor, cache: Optional[MapCache] = None,
               tables: Optional[dict] = None) -> dict:
    """Build every kernel map once (maps are shared within groups) — the
    standard 4-level U-Net map program (``plan.pyramid_map_specs``), with
    the table-adoption edges declared explicitly per ``KmapSpec``.
    ``tables``: pre-composed coordinate tables (scene-granular serving
    reuse; see ``plan.build_maps_from_specs``) — the strided maps then skip
    their unique argsorts and adopt the composed child tables instead."""
    return planlib.build_maps_from_specs(pyramid_map_specs(4, with_up=True),
                                         st, cache, tables=tables)


def apply(params, st: SparseTensor, cfg: MinkUNetConfig,
          maps: Optional[dict] = None,
          assignment: Optional[Dict[tuple, TrainDataflowConfig]] = None,
          bn_mode: str = "batch",
          nplan: Optional[NetworkPlan] = None,
          precision=None) -> jax.Array:
    """Returns per-point class logits (capacity, num_classes).

    Compiles a ``NetworkPlan`` from the declaration (or executes a caller's
    pre-compiled ``nplan``, in which case ``assignment``/``precision`` are
    already baked in) — bit-identical to the historical hand-written
    forward.  ``bn_mode="affine"`` runs inference-mode normalization (see
    ``core.plan.bn_relu``) — required by the serving engine so batched and
    per-scene forwards agree bit-for-bit."""
    if nplan is None:
        nplan = network_plan(cfg, assignment=assignment, precision=precision)
    return nplan.apply(params, st, maps, bn_mode=bn_mode)
