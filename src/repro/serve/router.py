"""Multi-device sharded serving: a device-routed tier above the engine.

The single-device ``Engine`` bounds compile churn with a bucket ladder and
amortizes mapping work across requests; the remaining scaling lever for the
ROADMAP's heavy-traffic north star is putting more devices behind one front
end.  ``DeviceRouter`` owns one bucket-ladder **worker per device** — a
plain ``Engine`` pinned to that device (params and every packed batch land
there via ``jax.device_put``, so each compiled rung's executor is a
per-device artifact: ≤1 compile per (rung, device) after warmup) — and
routes flushed batches between them:

* **load score**: each planned FIFO group is charged at its *padded* row
  count (the bucket capacity it will occupy — what a batch actually costs a
  device) and routed to the worker with the fewest outstanding padded rows;
* **deterministic tie-break**: exact ties fall to a round-robin cursor, so
  a uniform stream degenerates to round-robin and the same stream always
  produces the same device assignment (asserted in tests/test_router.py);
* workers run their assigned batches **concurrently** (one thread per
  worker — XLA execution releases the GIL, so one worker's host-side
  packing/unpacking overlaps another's device compute);
* the host-side **scene store is shared** across workers (``SceneEntry``
  composition is device-agnostic numpy): a scene warmed by any device
  composes into batches on every device;
* each worker resolves its own ``NetworkPlan`` through the
  ``PlanRegistry`` (``arch@devI`` entries when per-device plans were tuned,
  the shared ``arch`` entry otherwise — schema-v2 compatible either way).

Correctness contract (tests/test_router.py): the sharded router's outputs
are **bit-identical** to the single-device engine on the same scene stream
— routing only decides *where* a packed batch executes, never how it is
packed, mapped, or unpacked — and a router with one device degenerates to
the plain engine.

Devices are real accelerators in production; CPU CI shards across
host-platform virtual devices (``XLA_FLAGS=--xla_force_host_platform_
device_count=N`` — see ``launch.mesh.serving_devices``).
"""
from __future__ import annotations

import collections
import concurrent.futures
import os
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro import obs
from repro.launch.mesh import serving_devices
from repro.serve.batcher import Scene, SceneBatcher, SceneDelta, SceneResult
from repro.serve.bucketing import BucketLadder
from repro.serve.engine import (DEFAULT_LADDER, DEFAULT_SPATIAL_BOUND, ARCHS,
                                Engine, EngineStats, PHASE_WINDOW,
                                percentiles_ms, summarize_phases)
from repro.serve.plans import PlanRegistry, device_key
from repro.serve.service import (STATS_SCHEMA_VERSION, ServiceConfig,
                                 resolve_config)


class RouterStats:
    """Merged view over the per-worker ``EngineStats``.

    ``summary()`` keeps the single-engine schema (``scenes``, ``batches``,
    ``p50_ms``…, so CLI/bench code reads either) and adds a ``devices``
    block: per device, ``routed_batches``, ``queue_depth`` (outstanding
    padded rows right now), and that device's own p50/p95.
    """

    def __init__(self, router: "DeviceRouter"):
        self._router = router
        self.submitted = 0
        self.busy_s = 0.0
        self.flushes = 0
        self.deadline_flushes = 0
        self.count_flushes = 0
        #: (device_index, padded_rows) per routed batch, in routing order —
        #: the determinism contract is over this log
        self.route_log: List[Tuple[int, int]] = []
        # failover accounting: a worker whose shard raises is declared dead
        # and its unfinished groups re-route to the survivors
        self.worker_failures = 0
        self.rerouted_batches = 0
        # router-level phase windows (queue_wait happens before routing, so
        # it belongs to the tier, not to any worker) + SLO accounting
        self.phases: Dict[str, collections.deque] = {}
        self.slo_deadline_ms: Optional[float] = None
        self.slo_measured = 0
        self.slo_miss_count = 0

    def observe(self, phase: str, ms: float) -> None:
        win = self.phases.get(phase)
        if win is None:
            win = self.phases[phase] = collections.deque(maxlen=PHASE_WINDOW)
        win.append(ms)

    def slo_observe(self, latency_ms: float, deadline_ms: float) -> None:
        self.slo_deadline_ms = deadline_ms
        self.slo_measured += 1
        if latency_ms > deadline_ms:
            self.slo_miss_count += 1

    def _merge_counter(self, field: str) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for i, w in enumerate(self._router.workers):
            for cap, n in getattr(w.stats, field).items():
                out[f"d{i}:{cap}"] = n
        return out

    @staticmethod
    def _pctl(lat_deques) -> Tuple[Optional[float], Optional[float]]:
        rows = [np.asarray(d) for d in lat_deques if len(d)]
        if not rows:
            return (None, None)   # idle: report nothing, not a made-up 0.0
        return percentiles_ms(np.concatenate(rows))

    def summary(self) -> dict:
        workers = self._router.workers
        stats: List[EngineStats] = [w.stats for w in workers]
        completed = sum(s.completed for s in stats)
        p50, p95 = self._pctl([s.latencies_ms for s in stats])
        scene_tables = {
            "hits": sum(s.scene_hits for s in stats),
            "misses": sum(s.scene_misses for s in stats),
            "composed_batches": sum(s.composed_batches for s in stats),
            "delta_merges": sum(s.delta_merges for s in stats),
            "compiles": self._merge_counter("scene_compiles"),
        }
        devices = {}
        for i, w in enumerate(workers):
            dp50, dp95 = self._pctl([w.stats.latencies_ms])
            devices[f"d{i}"] = {
                "device": str(w.device),
                "alive": i not in self._router.dead,
                "routed_batches": w.stats.routed_batches,
                "queue_depth": self._router.outstanding_rows[i],
                "scenes": w.stats.completed,
                "p50_ms": dp50,
                "p95_ms": dp95,
            }
        # per-phase windows merged across the tier: router-level phases
        # (queue_wait) + every worker's (pack/map/execute/unpack/…)
        windows: Dict[str, list] = {}
        for holder in [self] + stats:
            for name, win in holder.phases.items():
                windows.setdefault(name, []).extend(win)
        slo_measured = self.slo_measured + sum(s.slo_measured for s in stats)
        slo_misses = (self.slo_miss_count
                      + sum(s.slo_miss_count for s in stats))
        device_busy = sum(s.device_busy_s for s in stats)
        overlap = sum(s.overlap_s for s in stats)
        return {
            "schema_version": STATS_SCHEMA_VERSION,
            "scenes": completed,
            "batches": sum(s.batches for s in stats),
            "routed_batches": sum(s.routed_batches for s in stats),
            "p50_ms": p50,
            "p95_ms": p95,
            "scenes_per_s": completed / self.busy_s if self.busy_s else 0.0,
            "recompiles": self._merge_counter("recompiles"),
            "map_compiles": self._merge_counter("map_compiles"),
            "plan_compiles": self._merge_counter("plan_compiles"),
            "map_cache": {"hits": sum(s.map_hits for s in stats),
                          "misses": sum(s.map_misses for s in stats)},
            "scene_tables": scene_tables,
            "deadline_flushes": self.deadline_flushes,
            "count_flushes": self.count_flushes,
            "deadline_cuts": sum(s.deadline_cuts for s in stats),
            "pipeline": {
                "inflight_peak": max((s.inflight_peak for s in stats),
                                     default=0),
                "host_busy_s": sum(s.host_busy_s for s in stats),
                "device_busy_s": device_busy,
                "overlap_s": overlap,
                "overlap_frac": overlap / device_busy if device_busy else 0.0},
            "phases": summarize_phases(windows),
            "slo": {
                "deadline_ms": self.slo_deadline_ms,
                "measured": slo_measured,
                "misses": slo_misses,
                "miss_rate": (slo_misses / slo_measured
                              if slo_measured else None),
            },
            "devices": devices,
            "failover": {
                "dead": sorted(f"d{i}" for i in self._router.dead),
                "worker_failures": self.worker_failures,
                "rerouted_batches": self.rerouted_batches,
            },
        }


class DeviceRouter:
    """Engine-compatible front end sharding one request stream over devices.

    devices: an int (take the first N jax devices; raises with the
        ``XLA_FLAGS`` hint when fewer are attached), an explicit device
        sequence, or None for every visible device.
    parallel: run workers' assigned batches in one thread per worker
        (default).  False serializes workers on the caller thread — same
        results, useful for debugging; routing is identical either way.
    max_inflight / deadline_margin / scene_cache_bytes are forwarded to
        every worker: each device runs its assigned shard through the
        engine's double-buffered pipeline, so one worker overlaps its *own*
        host mapping with its own device compute on top of the cross-worker
        thread overlap.
    Remaining behavioral knobs come from ``config=ServiceConfig(...)``
        (legacy per-kwarg spelling still works — see ``Engine``); the
        config is forwarded to every worker with its per-device plan key.
    """

    def __init__(self, arch: str, devices=None,
                 config: Optional[ServiceConfig] = None,
                 model_config=None, params=None,
                 plans: Optional[PlanRegistry] = None,
                 precision=None, parallel: bool = True, **legacy):
        if arch not in ARCHS:
            raise ValueError(f"unknown arch {arch!r}; have {sorted(ARCHS)}")
        if isinstance(config, BucketLadder):   # (arch, devices, ladder) callers
            legacy.setdefault("ladder", config)
            config = None
        self.config = resolve_config(config, legacy)
        cfg_s = self.config
        if isinstance(devices, int) or devices is None:
            devices = serving_devices(devices)
        self.devices = list(devices)
        assert self.devices, "DeviceRouter needs at least one device"
        self.arch = arch
        self.ladder = cfg_s.ladder()
        self.parallel = parallel
        self.max_wait_ms = cfg_s.max_wait_ms
        self.flush_count = cfg_s.flush_count
        self.max_inflight = cfg_s.max_inflight
        self.deadline_margin = cfg_s.deadline_margin
        if isinstance(plans, str):
            plans = PlanRegistry.load(plans)
        self.plans = plans or PlanRegistry()
        binding = ARCHS[arch]
        cfg = model_config if model_config is not None else binding.default_config
        if params is None:
            params = binding.model.init_params(cfg,
                                               jax.random.PRNGKey(cfg_s.seed))
        self.workers: List[Engine] = [
            Engine(arch,
                   config=cfg_s.replace(
                       plan_key=self.plans.resolve_key(arch, i)),
                   model_config=cfg, params=params, plans=self.plans,
                   precision=precision, device=dev)
            for i, dev in enumerate(self.devices)]
        # one host-side scene store (and guard) for the whole tier: entries
        # are device-agnostic numpy, so any worker's build serves every device
        for w in self.workers[1:]:
            w._scene_store = self.workers[0]._scene_store
            w._scene_lock = self.workers[0]._scene_lock
            w._streams = self.workers[0]._streams
        self._streams = self.workers[0]._streams
        self.batcher: SceneBatcher = self.workers[0].batcher
        self.stats = RouterStats(self)
        self.outstanding_rows = [0] * len(self.workers)
        #: worker indices declared dead by a raising shard — excluded from
        #: routing; their unfinished groups re-route to the survivors
        self.dead: set = set()
        self._rr = 0                       # round-robin cursor for tie-breaks
        self._queue: List[tuple] = []      # (ticket, Scene, t_submit)
        self._next_ticket = 0
        self._ready: Dict[int, SceneResult] = {}
        # Persistent pool, capped at the host's core count: more worker
        # threads than cores just thrash the intra-op pools (measured ~10%
        # slower on a 2-core host), and results don't depend on pool size —
        # routing is fixed before execution starts.
        self._pool: Optional[concurrent.futures.ThreadPoolExecutor] = None
        if self.parallel and len(self.workers) > 1:
            self._pool = concurrent.futures.ThreadPoolExecutor(
                max_workers=min(len(self.workers), os.cpu_count() or 1),
                thread_name_prefix="router-worker")

    @property
    def num_devices(self) -> int:
        return len(self.workers)

    # ---------------------------------------------------------------- route
    def _route(self, padded_rows: int) -> int:
        """Worker index for a batch costing ``padded_rows``: least
        outstanding padded rows over *live* workers; exact ties fall to the
        round-robin cursor.  Deterministic in the sequence of routed row
        counts and the liveness state."""
        loads = self.outstanding_rows
        n = len(loads)
        live = [i for i in range(n) if i not in self.dead]
        if not live:
            raise RuntimeError("all router workers are dead")
        lo = min(loads[i] for i in live)
        pick = min((i for i in live if loads[i] == lo),
                   key=lambda i: (i - self._rr) % n)
        obs.event("route", device=f"d{pick}",
                  device_name=str(self.devices[pick]), rows=padded_rows,
                  loads=list(loads))
        self._rr = (pick + 1) % n
        loads[pick] += padded_rows
        self.stats.route_log.append((pick, padded_rows))
        return pick

    # ------------------------------------------------------------------ api
    def submit(self, scene: Scene, stream: Optional[str] = None) -> int:
        """Enqueue one scene (ticket resolved by the next flush); identical
        semantics to ``Engine.submit`` including the auto-flush triggers."""
        if scene.num_points > self.ladder.max_capacity:
            raise ValueError(f"scene of {scene.num_points} rows exceeds the "
                             f"largest bucket ({self.ladder.max_capacity})")
        t = self._next_ticket
        self._next_ticket += 1
        self._queue.append((t, scene, time.perf_counter()))
        self.stats.submitted += 1
        if stream is not None:
            w0 = self.workers[0]
            self._streams[stream] = scene
            self._streams.move_to_end(stream)
            while len(self._streams) > w0.stream_cache_size:
                self._streams.popitem(last=False)
        self._autoflush()
        return t

    def submit_delta(self, stream: str, delta: SceneDelta) -> int:
        """Streaming frame as a delta of the stream's last scene.  The
        delta-merge itself is host-side work on the *shared* scene store, so
        it runs on worker 0's machinery and the refreshed entry composes on
        whichever device the batch is later routed to."""
        scene = self.workers[0]._merge_delta(stream, delta)
        return self.submit(scene, stream=stream)

    def _deadline_due(self) -> bool:
        # worker 0 holds the tier's deadline budget: plain ``max_wait_ms``
        # by default, shrunk by the predicted service time under
        # ``deadline_margin`` (its phase windows are as warm as any worker's)
        budget = self.workers[0]._deadline_budget_ms()
        return (budget is not None and bool(self._queue) and
                (time.perf_counter() - self._queue[0][2]) * 1e3 >= budget)

    def _autoflush(self) -> None:
        if self.flush_count is not None and len(self._queue) >= self.flush_count:
            self.stats.count_flushes += 1
            self._ready.update(self._run_queue())
        elif self._deadline_due():
            self.stats.deadline_flushes += 1
            self._ready.update(self._run_queue())

    def poll(self) -> Dict[int, SceneResult]:
        if self._deadline_due():
            self.stats.deadline_flushes += 1
            self._ready.update(self._run_queue())
        out, self._ready = self._ready, {}
        return out

    def flush(self) -> Dict[int, SceneResult]:
        out, self._ready = self._ready, {}
        out.update(self._run_queue())
        return out

    def _run_queue(self) -> Dict[int, SceneResult]:
        if not self._queue:
            return {}
        queue, self._queue = self._queue, []
        t0 = time.perf_counter()
        with obs.span("flush", scenes=len(queue),
                      devices=len(self.workers)):
            results = self._flush_queue(queue, t0)
        self.stats.busy_s += time.perf_counter() - t0
        self.stats.flushes += 1
        return results

    def _flush_queue(self, queue: List[tuple],
                     t0: float) -> Dict[int, SceneResult]:
        t0_ns = time.perf_counter_ns()
        for ticket, _, t_sub in queue:
            self.stats.observe("queue_wait", (t0 - t_sub) * 1e3)
            obs.record_span("queue_wait", int(t_sub * 1e9), t0_ns,
                            ticket=ticket)
        sizes = [s.num_points for _, s, _ in queue]
        # identical FIFO grouping to the single-device engine (bit-identity
        # contract), then each whole group is routed to one device; worker
        # 0's deadline-cut (margin-aware) caps the head group exactly as the
        # single engine would
        groups = self.batcher.plan(sizes,
                                   cut_first=self.workers[0]._deadline_cut(queue))
        pending = [(group, self.ladder.group_capacity([sizes[i] for i in group]))
                   for group in groups]
        completed: List[tuple] = []     # (group, per_scene, t_done)

        def run_shard(wi: int, items):
            """Run one worker's assigned groups; a raising batch doesn't
            propagate — it declares the worker failed and hands its
            unfinished groups back for re-routing."""
            w = self.workers[wi]
            done = []
            n_done = 0

            def on_done(k, batch, per_scene):
                # fires at each pipeline drain, in shard order: settle the
                # load score the moment the batch's results exist
                nonlocal n_done
                group, rows = items[k]
                self.outstanding_rows[wi] -= rows
                n_done += 1
                w.stats.routed_batches += 1
                done.append((wi, group, per_scene, time.perf_counter()))

            urgent = None
            if self.deadline_margin is not None and self.max_wait_ms is not None:
                def urgent(k):
                    oldest = min(queue[i][2] for i in items[k][0])
                    budget = w._deadline_budget_ms()
                    return (budget is not None and
                            (time.perf_counter() - oldest) * 1e3 >= budget)

            err = None
            try:
                with obs.span("shard", device=f"d{wi}",
                              device_name=str(w.device),
                              batches=len(items)):
                    w._run_pipeline(
                        [[queue[i][1] for i in group] for group, _ in items],
                        on_done, urgent)
            except Exception as e:        # device loss / injected failure
                err = e
            finally:
                # an aborted shard: un-charge every unprocessed group, or
                # the leaked load score would bias routing away from a
                # healthy worker forever
                for _, rows in items[n_done:]:
                    self.outstanding_rows[wi] -= rows
            return done, items[n_done:], err

        while pending:
            shards: List[list] = [[] for _ in self.workers]
            for item in pending:
                shards[self._route(item[1])].append(item)
            pending = []
            active = [wi for wi in range(len(self.workers)) if shards[wi]]
            if self._pool is not None and len(active) > 1:
                finished = list(self._pool.map(
                    lambda wi: run_shard(wi, shards[wi]), active))
            else:
                finished = [run_shard(wi, shards[wi]) for wi in active]
            for wi, (done, failed, err) in zip(active, finished):
                completed.extend(done)
                if err is None:
                    continue
                # failover: declare the worker dead, re-route what it did
                # not finish to the survivors (groups are idempotent —
                # re-execution yields bit-identical rows)
                self.dead.add(wi)
                self.stats.worker_failures += 1
                self.stats.rerouted_batches += len(failed)
                pending.extend(failed)
                obs.event("worker_down", device=f"d{wi}",
                          rerouted=len(failed), error=repr(err))
                if not any(i not in self.dead
                           for i in range(len(self.workers))):
                    raise RuntimeError(
                        f"all router workers dead with {len(pending)} "
                        f"batches outstanding") from err

        results: Dict[int, SceneResult] = {}
        for wi, group, per_scene, t_done in completed:
            for slot, i in enumerate(group):
                ticket, _, t_sub = queue[i]
                results[ticket] = per_scene[slot]
                lat_ms = (t_done - t_sub) * 1e3
                self.workers[wi].stats.latencies_ms.append(lat_ms)
                obs.record_span("request", int(t_sub * 1e9),
                                int(t_done * 1e9), ticket=ticket,
                                device=f"d{wi}")
                if self.max_wait_ms is not None:
                    # max_wait_ms doubles as the per-request latency SLO
                    self.stats.slo_observe(lat_ms, self.max_wait_ms)
        return results

    def serve(self, scenes: Sequence[Scene],
              flush_every: int = 0) -> List[SceneResult]:
        """Submit all, flush (in chunks), return in submission order."""
        out: Dict[int, SceneResult] = {}
        tickets = []
        for i, s in enumerate(scenes):
            tickets.append(self.submit(s))
            if flush_every and (i + 1) % flush_every == 0:
                out.update(self.flush())
        out.update(self.flush())
        return [out[t] for t in tickets]

    def warmup(self, channels: Optional[int] = None) -> None:
        """Compile every (rung, device) once so the request stream never
        pays a trace.  Workers warm concurrently when ``parallel`` — XLA
        compilation releases the GIL too."""
        if self._pool is not None:
            list(self._pool.map(lambda w: w.warmup(channels), self.workers))
        else:
            for w in self.workers:
                w.warmup(channels)

    def tune(self, sample_scenes: Sequence[Scene], space=None, iters: int = 2,
             save: bool = True, per_device: bool = True) -> Dict[int, dict]:
        """Tune each worker on its own device and persist per-device plans.

        per_device: write each worker's tuned ``NetworkPlan`` under its
        ``arch@devI`` registry name (heterogeneous fleets tune apart);
        False re-tunes the shared ``arch`` entry instead (last one wins —
        homogeneous fleets).  Returns {device_index: assignment}.
        """
        out: Dict[int, dict] = {}
        for i, w in enumerate(self.workers):
            w.plan_key = device_key(self.arch, i) if per_device else self.arch
            out[i] = w.tune(sample_scenes, space=space, iters=iters,
                            save=False)
        if save and self.plans.path:
            self.plans.save()
        return out
