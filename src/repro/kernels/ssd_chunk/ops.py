"""Jit'd wrapper: (B, S, H, P) model layout → per-(b,h) kernel layout."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.ssd_chunk.ssd_chunk import ssd_chunk_pallas


def ssd_scan(xh: jax.Array, dt: jax.Array, a_log: jax.Array, b_ssm: jax.Array,
             c_ssm: jax.Array, *, chunk: int = 128,
             interpret: bool | None = None):
    """Drop-in for models.mamba2._ssd_chunked on TPU.

    xh: (B, S, H, P); dt: (B, S, H); a_log: (H,); b/c: (B, S, N).
    Returns (y (B, S, H, P) f32, h_final (B, H, N, P) f32)."""
    if interpret is None:
        interpret = default_interpret()
    bsz, s, h, p = xh.shape
    n = b_ssm.shape[-1]
    a = (-jnp.exp(a_log.astype(jnp.float32)) * dt)           # (B, S, H)
    xdt = xh.astype(jnp.float32) * dt[..., None]
    # (B, S, H, ·) → (B·H, S, ·); B/C shared across heads → broadcast
    a_bh = a.transpose(0, 2, 1).reshape(bsz * h, s)
    x_bh = xdt.transpose(0, 2, 1, 3).reshape(bsz * h, s, p)
    b_bh = jnp.broadcast_to(b_ssm[:, None], (bsz, h, s, n)).reshape(bsz * h, s, n)
    c_bh = jnp.broadcast_to(c_ssm[:, None], (bsz, h, s, n)).reshape(bsz * h, s, n)
    y, hf = ssd_chunk_pallas(a_bh, x_bh, b_bh, c_bh, chunk=min(chunk, s),
                             interpret=interpret)
    y = y.reshape(bsz, h, s, p).transpose(0, 2, 1, 3)
    return y, hf.reshape(bsz, h, n, p)
