"""SSD chunk Pallas kernel vs the sequential-recurrence oracle (interpret
mode) across shapes, dtypes and chunk sizes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd_chunk.ops import ssd_scan
from repro.kernels.ssd_chunk.ref import ssd_ref
from repro.models.mamba2 import _ssd_chunked


def _inputs(key, b, s, h, p, n, dtype):
    xh = (jax.random.normal(key, (b, s, h, p)) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1), (b, s, h))).astype(dtype)
    a_log = jax.random.normal(jax.random.fold_in(key, 2), (h,)) * 0.3
    bs = (jax.random.normal(jax.random.fold_in(key, 3), (b, s, n)) * 0.5).astype(dtype)
    cs = (jax.random.normal(jax.random.fold_in(key, 4), (b, s, n)) * 0.5).astype(dtype)
    return xh, dt, a_log, bs, cs


def _oracle(xh, dt, a_log, bs, cs):
    b, s, h, p = xh.shape
    n = bs.shape[-1]
    a = (-jnp.exp(a_log.astype(jnp.float32)) * dt.astype(jnp.float32))
    a = a.transpose(0, 2, 1).reshape(b * h, s)
    xdt = (xh.astype(jnp.float32) * dt.astype(jnp.float32)[..., None])
    xdt = xdt.transpose(0, 2, 1, 3).reshape(b * h, s, p)
    bb = jnp.broadcast_to(bs[:, None], (b, h, s, n)).reshape(b * h, s, n)
    cc = jnp.broadcast_to(cs[:, None], (b, h, s, n)).reshape(b * h, s, n)
    y, hf = ssd_ref(a, xdt, bb, cc)
    return (y.reshape(b, h, s, p).transpose(0, 2, 1, 3), hf.reshape(b, h, n, p))


@pytest.mark.parametrize("chunk", [8, 16, 32])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel_matches_sequential(chunk, dtype):
    xh, dt, a_log, bs, cs = _inputs(jax.random.PRNGKey(0), 2, 64, 3, 16, 8, dtype)
    y_k, hf_k = ssd_scan(xh, dt, a_log, bs, cs, chunk=chunk, interpret=True)
    y_r, hf_r = _oracle(xh, dt, a_log, bs, cs)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(y_k, y_r, rtol=tol, atol=tol)
    np.testing.assert_allclose(hf_k, hf_r, rtol=tol, atol=tol)


@pytest.mark.parametrize("s,p,n", [(32, 8, 8), (64, 32, 16)])
def test_ssd_kernel_shape_sweep(s, p, n):
    xh, dt, a_log, bs, cs = _inputs(jax.random.PRNGKey(1), 1, s, 2, p, n, jnp.float32)
    y_k, hf_k = ssd_scan(xh, dt, a_log, bs, cs, chunk=16, interpret=True)
    y_r, hf_r = _oracle(xh, dt, a_log, bs, cs)
    np.testing.assert_allclose(y_k, y_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(hf_k, hf_r, rtol=1e-4, atol=1e-4)


def test_ssd_kernel_matches_model_path():
    """The XLA chunked path used in the dry-run and the Pallas kernel agree."""
    xh, dt, a_log, bs, cs = _inputs(jax.random.PRNGKey(2), 2, 64, 3, 16, 8, jnp.float32)
    y_k, hf_k = ssd_scan(xh, dt, a_log, bs, cs, chunk=16, interpret=True)
    y_m, hf_m = _ssd_chunked(xh, dt, a_log, bs, cs, chunk=16)
    np.testing.assert_allclose(y_k, y_m, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(hf_k, hf_m, rtol=1e-5, atol=1e-5)
