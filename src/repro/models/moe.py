"""Mixture-of-Experts layer on the sparse dataflow engine.

DESIGN.md §4: MoE token dispatch *is* the paper's gather-GEMM-scatter — the
router produces a (token → expert) kernel map instead of coordinate hashing.
Two dataflows are offered behind the same config switch the Sparse Autotuner
tunes:

* ``dataflow='gather_scatter'``   — sort-based ragged dispatch: argsort tokens
  by expert, gather into a capacity-padded (E, C, d) buffer (the "gather
  buffer"), dense per-expert GEMMs, scatter-add combine.  Capacity padding is
  the MoE analogue of padding kernel maps to ``tile_m`` (§3.2).
* ``dataflow='dense_onehot'``     — the "implicit" formulation: einsum with
  the one-hot dispatch tensor, zero gather/scatter ops but top-k/E redundant
  compute — the same compute-vs-traffic trade the paper's autotuner navigates.

Experts shard over the model axis (EP); activations arrive replicated across
the model axis (post-TP-psum), so per-shard dispatch is a local gather and
the combine rides the existing TP all-reduce.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.lm_common import ArchConfig, ShardCtx, _rand


def moe_init(cfg: ArchConfig, key, dtype):
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_ff_expert, m.n_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "router": _rand(k1, (d, e), dtype),
        "w_gate": _rand(k2, (e, d, f), dtype),
        "w_up": _rand(k3, (e, d, f), dtype),
        "w_down": _rand(k4, (e, f, d), dtype, scale=f ** -0.5),
    }


def _capacity(cfg: ArchConfig, n_tokens: int) -> int:
    m = cfg.moe
    c = int(n_tokens * m.top_k / m.n_experts * m.capacity_factor)
    return max(8, -(-c // 8) * 8)


def moe_apply(cfg: ArchConfig, p, x, ctx: ShardCtx, dataflow: str = "gather_scatter"):
    """x: (B, S, d) → (B, S, d).  Dropped tokens (over capacity) pass through
    the residual only, as in standard capacity-factor MoE."""
    if (cfg.moe.dispatch == "local_shardmap" and ctx.mesh is not None
            and cfg.moe.shard_experts):
        return moe_apply_local(cfg, p, x, ctx)
    b, s, d = x.shape
    m = cfg.moe
    t = b * s
    xf = x.reshape(t, d)
    logits = (xf @ p["router"]).astype(jnp.float32)            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, m.top_k)                  # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    if dataflow == "dense_onehot":
        # implicit formulation: every expert sees every token's slot weight
        oh = jax.nn.one_hot(eidx, m.n_experts, dtype=xf.dtype)          # (T, k, E)
        w = (oh * gate[..., None].astype(xf.dtype)).sum(1)              # (T, E)
        h = jnp.einsum("td,edf->tef", xf, p["w_gate"])
        h = jax.nn.silu(h) * jnp.einsum("td,edf->tef", xf, p["w_up"])
        y = jnp.einsum("tef,efd->ted", h, p["w_down"])
        out = jnp.einsum("ted,te->td", y, w)
        return out.reshape(b, s, d)

    # ---- sort-based ragged dispatch (gather-GEMM-scatter) ----
    cap = _capacity(cfg, t)
    a_exp = eidx.reshape(-1)                                    # (T*k,) assignments
    order = jnp.argsort(a_exp, stable=True)                     # group by expert
    e_sorted = a_exp[order]
    tok_sorted = order // m.top_k                               # source token
    # rank of each assignment within its expert
    seg_start = jnp.searchsorted(e_sorted, jnp.arange(m.n_experts))
    rank = jnp.arange(t * m.top_k) - seg_start[e_sorted]
    keep = rank < cap

    # gather buffer (E, C, d): experts on the model axis, capacity on batch axes
    buf = jnp.zeros((m.n_experts, cap, d), x.dtype)
    buf = buf.at[jnp.where(keep, e_sorted, m.n_experts),
                 jnp.where(keep, rank, 0)].set(xf[tok_sorted], mode="drop")
    if m.shard_experts:
        buf = ctx.cons(buf, ctx.m, ctx.b, None)
        espec = (ctx.m, None, None)
    else:
        buf = ctx.cons(buf, None, ctx.b, ctx.m)
        espec = (None, None, ctx.m)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    h = ctx.cons(h, *espec) if m.shard_experts else ctx.cons(h, None, ctx.b, ctx.m)
    y = jnp.einsum("ecf,efd->ecd", h, p["w_down"])              # (E, C, d)
    y = ctx.cons(y, ctx.m if m.shard_experts else None, ctx.b, None)

    # combine: scatter expert outputs back to assignment slots, weight, sum k
    out_sorted = y[jnp.where(keep, e_sorted, 0), jnp.where(keep, rank, 0)]
    out_sorted = jnp.where(keep[:, None], out_sorted, 0)
    flat = jnp.zeros((t * m.top_k, d), x.dtype).at[order].set(out_sorted)
    out = jnp.sum(flat.reshape(t, m.top_k, d) * gate[..., None].astype(x.dtype), axis=1)
    return out.reshape(b, s, d)


def moe_apply_local(cfg: ArchConfig, p, x, ctx: ShardCtx):
    """Beyond-paper dispatch (EXPERIMENTS.md §Perf): shard_map-local MoE.

    The GSPMD formulation above scatters into a globally-sharded (E, C, d)
    buffer with data-dependent indices; the SPMD partitioner can only resolve
    that with full-buffer all-reduces (measured: 5.8 TB/device/step on
    kimi-k2 train_4k — 100× the rest of the program's traffic).

    Observation: after the attention TP all-reduce, activations are already
    *replicated* across the model axis, and experts are *sharded* across it.
    So dispatch is purely local: every model shard routes its token slice,
    keeps only assignments owned by its expert slice, computes, and the
    combine rides a single (T_local, d) psum over the model axis — the same
    wire class as one TP layer.  No all-to-all, no scatter all-reduce.

    This is the paper's dataflow-selection insight applied at datacenter
    scale: the token→expert kernel map is consumed weight-stationarily
    (per-expert gather lists), with capacity padding playing the role of
    §3.2 map padding.
    """
    from jax import shard_map
    from jax.sharding import PartitionSpec as P

    m = cfg.moe
    ms = max(ctx.model_size, 1)
    assert m.n_experts % ms == 0, "local dispatch needs experts % model_size == 0"
    e_loc = m.n_experts // ms
    b, s, d = x.shape

    def local(xs, router, wg, wu, wd):
        bl, sl, _ = xs.shape
        t = bl * sl
        xf = xs.reshape(t, d)
        my = jax.lax.axis_index(ctx.model)
        logits = (xf @ router).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate, eidx = jax.lax.top_k(probs, m.top_k)
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

        e_flat = eidx.reshape(-1)
        local_e = e_flat - my * e_loc
        mine = (local_e >= 0) & (local_e < e_loc)
        key = jnp.where(mine, local_e, e_loc)          # foreign experts last
        order = jnp.argsort(key, stable=True)
        e_sorted = key[order]
        tok = order // m.top_k
        cap = _capacity(cfg, t)
        seg_start = jnp.searchsorted(e_sorted, jnp.arange(e_loc))
        rank = jnp.arange(t * m.top_k) - seg_start[jnp.clip(e_sorted, 0, e_loc - 1)]
        keep = (e_sorted < e_loc) & (rank < cap)

        buf = jnp.zeros((e_loc, cap, d), xs.dtype)
        buf = buf.at[jnp.where(keep, e_sorted, e_loc),
                     jnp.where(keep, rank, 0)].set(xf[tok], mode="drop")
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg))
        h = h * jnp.einsum("ecd,edf->ecf", buf, wu)
        y = jnp.einsum("ecf,efd->ecd", h, wd)

        rows = y[jnp.where(keep, e_sorted, 0), jnp.where(keep, rank, 0)]
        rows = jnp.where(keep[:, None], rows, 0)
        flat = jnp.zeros((t * m.top_k, d), xs.dtype).at[order].set(rows)
        out = jnp.sum(flat.reshape(t, m.top_k, d) * gate[..., None].astype(xs.dtype), axis=1)
        out = jax.lax.psum(out, ctx.model)             # combine = one TP psum
        return out.reshape(bl, sl, d)

    fn = shard_map(local, mesh=ctx.mesh,
                   in_specs=(P(ctx.b, None, None), P(), P(ctx.m, None, None),
                             P(ctx.m, None, None), P(ctx.m, None, None)),
                   out_specs=P(ctx.b, None, None), check_vma=False)
    return fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])


def aux_load_balance_loss(logits: jax.Array, eidx: jax.Array, n_experts: int) -> jax.Array:
    """Switch-style auxiliary loss (fraction·probability per expert)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    frac = jnp.mean(jax.nn.one_hot(eidx[..., 0], n_experts), axis=0)
    pmean = jnp.mean(probs, axis=0)
    return n_experts * jnp.sum(frac * pmean)
