"""Fused SSD chunk scan (Mamba-2) as a Pallas TPU kernel.

EXPERIMENTS.md §Perf cycles 3/4 showed that dtype tweaks to the XLA SSD path
don't move the memory roofline because the O(Q²) intra-chunk tensors and the
elementwise chains are *materialized to HBM* between XLA ops.  This kernel is
the structural fix: per (sequence, chunk) grid step it keeps

    cum-decay (Q,)  ·  decay kernel (Q, Q)  ·  CBᵀ (Q, Q)  ·  state (N, P)

entirely in VMEM — HBM sees only the streamed inputs (x·dt, B, C, a) and the
(Q, P) output tile.  The carried state lives in a VMEM scratch accumulator
across the *sequential* chunk grid dimension (same pattern as the matmul
k-loop accumulator), zeroed at chunk 0.

MXU shapes: CBᵀ is (Q, N)×(N, Q), the intra product (Q, Q)×(Q, P), the state
update (N, Q)×(Q, P) — all 128-aligned for Q, P, N multiples of 128/8.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common


def _kernel(a_ref, x_ref, b_ref, c_ref, y_ref, hfin_ref, state, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    a = a_ref[0].astype(jnp.float32)                     # (Q,)
    x = x_ref[0].astype(jnp.float32)                     # (Q, P)
    b = b_ref[0].astype(jnp.float32)                     # (Q, N)
    c = c_ref[0].astype(jnp.float32)                     # (Q, N)

    cum = jnp.cumsum(a)                                  # (Q,)
    # intra-chunk: y_t += Σ_{s≤t} exp(cum_t - cum_s) (c_t·b_s) xdt_s
    l_ts = cum[:, None] - cum[None, :]
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(cols <= rows, jnp.exp(l_ts), 0.0)
    cb = jnp.dot(c, b.T, preferred_element_type=jnp.float32)
    y = jnp.dot(cb * decay, x, preferred_element_type=jnp.float32)
    # inter-chunk: y_t += (c_t ⊙ exp(cum_t)) · h_in
    y = y + jnp.dot(c * jnp.exp(cum)[:, None], state[...],
                    preferred_element_type=jnp.float32)
    # state update: h_out = exp(cum_Q) h_in + Σ_s exp(cum_Q - cum_s) b_s ⊗ x_s
    seg = jnp.exp(cum[-1] - cum)                         # (Q,)
    state[...] = (jnp.exp(cum[-1]) * state[...]
                  + jnp.dot((b * seg[:, None]).T, x,
                            preferred_element_type=jnp.float32))
    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ci == pl.num_programs(1) - 1)
    def _flush():
        hfin_ref[0] = state[...].astype(hfin_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunk_pallas(a: jax.Array, xdt: jax.Array, b: jax.Array, c: jax.Array,
                     *, chunk: int = 128, interpret: bool = True):
    """a: (BH, S) log-decays; xdt: (BH, S, P); b/c: (BH, S, N), S % chunk == 0.

    Returns (y (BH, S, P) f32, h_final (BH, N, P) f32)."""
    bh, s = a.shape
    n, p = b.shape[-1], xdt.shape[-1]
    assert s % chunk == 0
    nc = s // chunk
    kernel = functools.partial(_kernel, chunk=chunk)
    grid = (bh, nc)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk), lambda i, j: (i, j)),
            pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, n, p), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, p), jnp.float32),
            jax.ShapeDtypeStruct((bh, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
        compiler_params=common.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
            interpret=interpret),
    )(a, xdt, b, c)
