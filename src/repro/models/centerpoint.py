"""CenterPoint sparse-conv backbone (SECOND-style 3D detection encoder).

The paper's detection workload (Waymo/nuScenes-CenterPoint).  Only the
SparseConv layers are timed in the paper's detection benchmarks, so this is
the backbone alone: 4 stages of [stride-2 conv + submanifold convs],
channel ladder 16→32→64→128.

Like MinkUNet, the backbone declares its layers (``declare``) and executes
through a compiled ``core.plan.NetworkPlan``; ``apply``/``build_maps``
keep the historical signatures and bit-exact outputs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax

from repro.core import plan as planlib
from repro.core.kmap import MapCache
from repro.core.plan import (LayerPlan, ModelDecl, NetworkPlan, compile_plan,
                             pyramid_map_specs)
from repro.core.sparse_conv import ConvSpec, TrainDataflowConfig, init_conv
from repro.core.sparse_tensor import SparseTensor
from repro.models.minkunet import _bn_relu, _bn_relu_init  # noqa: F401 (re-export)


@dataclasses.dataclass(frozen=True)
class CenterPointConfig:
    in_channels: int = 5
    channels: tuple = (16, 32, 64, 128)
    sub_convs_per_stage: int = 2
    width: float = 1.0

    def ch(self, c):
        return max(8, int(c * self.width))


def init_params(cfg: CenterPointConfig, key) -> dict:
    keys = iter(jax.random.split(key, 64))
    p = {}
    for lp in declare(cfg).layers:
        p[lp.name] = init_conv(next(keys), lp.spec)
        p[f"{lp.name}_bn"] = _bn_relu_init(lp.spec.out_channels)
    return p


def declare(cfg: CenterPointConfig) -> ModelDecl:
    """Layer list + execution program + kernel-map program (see core.plan)."""
    c0 = cfg.ch(cfg.channels[0])
    layers = [LayerPlan("stem", ConvSpec(cfg.in_channels, c0, 3),
                        ("sub", 1), (1, 3, "sub"))]
    ops = [("conv", "stem")]
    cin, stride = c0, 1
    for i, c in enumerate(cfg.channels):
        c = cfg.ch(c)
        layers.append(LayerPlan(f"down{i}", ConvSpec(cin, c, 2, stride=2),
                                ("down", stride), (stride, 2, "down")))
        ops.append(("conv", f"down{i}"))
        stride *= 2
        for b in range(cfg.sub_convs_per_stage):
            layers.append(LayerPlan(f"sub{i}_{b}", ConvSpec(c, c, 3),
                                    ("sub", stride), (stride, 3, "sub")))
            ops.append(("conv", f"sub{i}_{b}"))
        cin = c
    return ModelDecl(arch="centerpoint", layers=tuple(layers), ops=tuple(ops),
                     map_specs=pyramid_map_specs(len(cfg.channels),
                                                 with_up=False,
                                                 table="composed"))


def network_plan(cfg: CenterPointConfig,
                 assignment: Optional[Dict[tuple, TrainDataflowConfig]] = None,
                 precision=None) -> NetworkPlan:
    """Compile the execution plan: declare → compile (→ tune → persist)."""
    return compile_plan(declare(cfg), assignment=assignment, precision=precision)


def layer_signatures(cfg: CenterPointConfig) -> Dict[str, tuple]:
    return {lp.name: lp.sig for lp in declare(cfg).layers}


def build_maps(st: SparseTensor, cache: Optional[MapCache] = None,
               tables: Optional[dict] = None) -> dict:
    """One ``MapCache`` across the stage ladder: the stem/submanifold and
    strided convs at each stride share a sorted coordinate table, and each
    downsample's declared ``adopts_output_table`` edge seeds the next
    stage's table for free.  A prebuilt warm ``cache`` may be passed
    (serving engine); never reuse one across ``jit`` traces.  ``tables``:
    pre-composed coordinate tables (scene-granular serving reuse; see
    ``plan.build_maps_from_specs``)."""
    return planlib.build_maps_from_specs(pyramid_map_specs(4, with_up=False),
                                         st, cache, tables=tables)


def apply(params, st: SparseTensor, cfg: CenterPointConfig,
          maps: Optional[dict] = None,
          assignment: Optional[Dict[tuple, TrainDataflowConfig]] = None,
          bn_mode: str = "batch",
          nplan: Optional[NetworkPlan] = None,
          precision=None) -> jax.Array:
    if nplan is None:
        nplan = network_plan(cfg, assignment=assignment, precision=precision)
    return nplan.apply(params, st, maps, bn_mode=bn_mode)
