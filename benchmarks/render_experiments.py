"""Inject the generated roofline / memory / perf tables into EXPERIMENTS.md
placeholders (<!-- ROOFLINE_TABLE --> etc.).

    PYTHONPATH=src python -m benchmarks.render_experiments
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks import roofline_report

ROOT = Path(__file__).resolve().parents[1]
PERF = Path(__file__).resolve().parent / "results" / "perf"


def perf_table() -> str:
    rows = ["| cell | variant | term | baseline | optimized | Δ | confirmed? |",
            "|---|---|---|---|---|---|---|"]
    for f in sorted(PERF.glob("*.json")):
        r = json.loads(f.read_text())
        b, o = r["baseline_roofline"], r["roofline"]
        dom = b["bottleneck"]
        key = {"collective": "collective_s", "memory": "memory_s",
               "compute": "compute_s"}[dom]
        bb, oo = b[key], o[key]
        delta = bb / max(oo, 1e-30)
        rows.append(
            f"| {r['arch']}×{r['shape']} | {r['variant']} | T_{dom} "
            f"| {bb:.3e} s | {oo:.3e} s | **{delta:.1f}×** "
            f"| {'yes' if delta > 1.05 else 'NO (refuted)'} |")
        rows.append(
            f"| | | roofline frac | {b['roofline_fraction']:.3f} "
            f"| {o['roofline_fraction']:.3f} "
            f"| {o['roofline_fraction'] / max(b['roofline_fraction'], 1e-9):.1f}× | |")
    return "\n".join(rows)


def main():
    md = (ROOT / "EXPERIMENTS.md").read_text()
    md = md.replace("<!-- ROOFLINE_TABLE -->",
                    roofline_report.table("single_pod"))
    md = md.replace("<!-- MEMORY_TABLE -->",
                    roofline_report.memory_table("single_pod"))
    if PERF.exists() and list(PERF.glob("*.json")):
        md = md.replace("<!-- PERF_TABLE -->", perf_table())
    (ROOT / "EXPERIMENTS.md").write_text(md)
    print("EXPERIMENTS.md rendered")


if __name__ == "__main__":
    main()
