"""wgrad Pallas kernel vs the pure-jnp oracle and vs autodiff."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dataflows as df
from repro.core import kmap as km
from repro.kernels.wgrad.ops import wgrad
from repro.kernels.wgrad.ref import wgrad_ref
from tests.test_kmap import random_tensor


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("tile_r", [8, 32])
def test_wgrad_matches_ref(dtype, tile_r):
    stx = random_tensor(21, n=70, cap=96, channels=8, extent=7)
    kmap = km.build_kmap(stx, 3, 1)
    x = stx.feats.astype(dtype)
    dy = (jax.random.normal(jax.random.PRNGKey(5), (kmap.capacity, 16)) * 0.5).astype(dtype)
    got = wgrad(x, dy, kmap, tile_r=tile_r, interpret=True)
    ref = wgrad_ref(x, dy, kmap.ws_in, kmap.ws_out)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


def test_wgrad_matches_autodiff():
    stx = random_tensor(22, n=60, cap=64, channels=4, extent=7)
    kmap = km.build_kmap(stx, 3, 1)
    w = jax.random.normal(jax.random.PRNGKey(6), (27, 4, 8)) * 0.2
    dy = jax.random.normal(jax.random.PRNGKey(7), (kmap.capacity, 8))

    def f(w):
        y = df.sparse_conv_forward(stx.feats, w, kmap, df.DataflowConfig("gather_scatter"))
        return jnp.sum(y * dy)

    gw = jax.grad(f)(w)
    got = wgrad(stx.feats, dy, kmap, tile_r=16, interpret=True)
    np.testing.assert_allclose(got, gw, rtol=1e-4, atol=1e-5)


def test_wgrad_strided_map():
    stx = random_tensor(23, n=80, cap=128, channels=8, extent=10)
    kmap = km.build_kmap(stx, 2, 2)
    dy = jax.random.normal(jax.random.PRNGKey(8), (kmap.capacity, 8))
    got = wgrad(stx.feats, dy, kmap, tile_r=16, interpret=True)
    ref = wgrad_ref(stx.feats, dy, kmap.ws_in, kmap.ws_out)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-5)
