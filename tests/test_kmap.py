"""Kernel-map construction invariants (unit + hypothesis property tests).

``hypothesis`` is optional (see requirements-dev.txt): without it the
property tests fall back to a small deterministic sample so the suite still
collects and runs (``conftest.property_test``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import property_test

from repro.core import dataflows as df
from repro.core import kmap as km
from repro.core.sparse_tensor import INVALID_COORD, make_sparse_tensor, voxelize


def random_tensor(seed, n=100, cap=128, channels=8, extent=8, batch=1, d=3):
    rng = np.random.default_rng(seed)
    coords = rng.integers(0, extent, size=(n, d))
    b = rng.integers(0, batch, size=(n, 1))
    coords = np.concatenate([b, coords], axis=1)
    coords = np.unique(coords, axis=0)
    n = coords.shape[0]
    feats = rng.standard_normal((cap, channels)).astype(np.float32)
    pad = np.zeros((cap - n, d + 1), np.int32)
    return make_sparse_tensor(jnp.asarray(np.concatenate([coords, pad])),
                              jnp.asarray(feats), n)


def brute_force_map(coords, n_valid, offsets, stride=1):
    """O(N²) reference for the output-stationary map (stride-1 submanifold)."""
    coords = np.asarray(coords)[:n_valid]
    lut = {tuple(c): i for i, c in enumerate(coords)}
    m = -np.ones((len(coords), len(offsets)), np.int32)
    for i, c in enumerate(coords):
        for k, off in enumerate(offsets):
            q = (c[0],) + tuple(c[1:] + off)
            if q in lut:
                m[i, k] = lut[q]
    return m


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_submanifold_map_matches_bruteforce(seed):
    stx = random_tensor(seed)
    kmap = km.build_kmap(stx, 3, 1)
    offs = km.kernel_offsets(3, 3)
    ref = brute_force_map(stx.coords, int(stx.num_valid), np.asarray(offs))
    got = np.asarray(kmap.m_out)[: int(stx.num_valid)]
    np.testing.assert_array_equal(got, ref)


def test_center_offset_is_identity():
    stx = random_tensor(3)
    kmap = km.build_kmap(stx, 3, 1)
    n = int(stx.num_valid)
    # center-first ordering: column 0 is δ=0 → identity map
    np.testing.assert_array_equal(np.asarray(kmap.m_out)[:n, 0], np.arange(n))


def test_ws_consistent_with_mout():
    stx = random_tensor(4)
    kmap = km.build_kmap(stx, 3, 1)
    m = np.asarray(kmap.m_out)
    ws_in, ws_out, cnt = (np.asarray(kmap.ws_in), np.asarray(kmap.ws_out),
                          np.asarray(kmap.ws_count))
    for k in range(kmap.volume):
        pairs_m = {(m[n, k], n) for n in range(m.shape[0]) if m[n, k] >= 0}
        pairs_w = {(ws_in[k, i], ws_out[k, i]) for i in range(cnt[k])}
        assert pairs_m == pairs_w
        assert (ws_in[k, cnt[k]:] == -1).all()


def test_bitmask_matches_hits():
    stx = random_tensor(5)
    kmap = km.build_kmap(stx, 3, 1)
    m = np.asarray(kmap.m_out)
    bm = np.asarray(kmap.bitmask)
    n = int(stx.num_valid)
    for i in range(n):
        expect = sum(1 << k for k in range(27) if m[i, k] >= 0)
        assert bm[i] == expect


def test_strided_output_coords_are_unique_and_on_grid():
    stx = random_tensor(6, extent=16)
    kmap = km.build_kmap(stx, 2, 2)
    n = int(kmap.n_out)
    oc = np.asarray(kmap.out_coords)[:n]
    assert (oc[:, 1:] % 2 == 0).all()
    assert len({tuple(c) for c in oc}) == n
    assert kmap.out_stride == 2


def test_transpose_kmap_is_transpose_relation():
    stx = random_tensor(7, extent=16)
    fwd = km.build_kmap(stx, 2, 2)
    inv = km.transpose_kmap(fwd, stx)
    fi, fo = np.asarray(fwd.ws_in), np.asarray(fwd.ws_out)
    ii, io = np.asarray(inv.ws_in), np.asarray(inv.ws_out)
    for k in range(fwd.volume):
        fwd_pairs = {(a, b) for a, b in zip(fi[k], fo[k]) if a >= 0}
        inv_pairs = {(b, a) for a, b in zip(ii[k], io[k]) if a >= 0}
        assert fwd_pairs == inv_pairs
    # and the output-stationary form agrees with the pair lists
    m = np.asarray(inv.m_out)
    for k in range(inv.volume):
        pairs_m = {(m[n, k], n) for n in range(m.shape[0]) if m[n, k] >= 0}
        pairs_w = {(a, b) for a, b in zip(ii[k], io[k]) if a >= 0}
        assert pairs_m == pairs_w


def test_split_plan_partitions_and_permutes():
    stx = random_tensor(8)
    kmap = km.build_kmap(stx, 3, 1)
    for s in (1, 2, 3, 5):
        plan = km.make_split_plan(kmap, s)
        assert plan.num_splits == s
        # ranges partition [0, 27)
        flat = [i for a, b in plan.ranges for i in range(a, b)]
        assert flat == list(range(27))
        for i in range(s):
            order = np.asarray(plan.order[i])
            assert sorted(order) == list(range(kmap.capacity))
            inv = np.asarray(plan.inv_order[i])
            np.testing.assert_array_equal(order[inv], np.arange(kmap.capacity))


def test_sorting_reduces_tile_occupancy():
    stx = random_tensor(9, n=400, cap=512, extent=10)
    kmap = km.build_kmap(stx, 3, 1)
    unsorted = km.redundancy_stats(kmap, km.make_split_plan(kmap, 1, sort=False), 16)
    sorted_ = km.redundancy_stats(kmap, km.make_split_plan(kmap, 1, sort=True), 16)
    assert float(sorted_["issued_rows"]) <= float(unsorted["issued_rows"])
    assert float(sorted_["overhead"]) >= 1.0 - 1e-6


@property_test(
    "seed,extent,kernel",
    cases=[(0, 3, 2), (1, 7, 3), (2, 12, 3), (3, 5, 2),
           (4, 9, 3), (5, 4, 2), (6, 11, 2), (7, 6, 3)],
    strategies=lambda st: dict(seed=st.integers(0, 10_000),
                               extent=st.integers(3, 12),
                               kernel=st.sampled_from([2, 3])))
def test_property_dataflows_agree(seed, extent, kernel):
    """All three dataflows compute identical results on random clouds."""
    stx = random_tensor(seed, n=60, cap=64, channels=4, extent=extent)
    stride = 2 if kernel == 2 else 1
    kmap = km.build_kmap(stx, kernel, stride)
    kd = kernel ** 3
    w = jax.random.normal(jax.random.PRNGKey(seed), (kd, 4, 8)) * 0.3
    y1 = df.sparse_conv_forward(stx.feats, w, kmap, df.DataflowConfig("gather_scatter"))
    y2 = df.sparse_conv_forward(stx.feats, w, kmap, df.DataflowConfig("fetch_on_demand"))
    y3 = df.sparse_conv_forward(stx.feats, w, kmap, df.DataflowConfig("implicit_gemm"))
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(y1, y3, rtol=1e-4, atol=1e-5)


def test_voxelize_dedups_and_keeps_extent():
    pts = jnp.asarray(np.random.default_rng(0).uniform(0, 5, (200, 3)))
    feats = jnp.ones((200, 2))
    stx = voxelize(pts, feats, 1.0, capacity=256)
    n = int(stx.num_valid)
    coords = np.asarray(stx.coords[:n])
    assert len({tuple(c) for c in coords}) == n
    assert (np.asarray(stx.coords[n:]) == int(INVALID_COORD)).all()
    assert coords[:, 1:].max() <= 5
