import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_DRYRUN_XLA_FLAGS")
                           or "--xla_force_host_platform_device_count=512")
# ^ MUST run before any other import: jax locks the device count on first init.

"""Multi-pod dry-run: .lower().compile() every (arch × shape × mesh) cell.

For each cell we AOT-compile the real step function (train / prefill /
serve) against ShapeDtypeStruct inputs on the production mesh — no host
memory is allocated for parameters.  The compiled artifact yields:

* memory_analysis()  — per-device bytes (does the cell fit a 16 GB v5e?),
* cost_analysis()    — per-device HLO FLOPs / bytes for the roofline,
* as_text()          — the collective schedule, parsed into wire bytes.

Results append to benchmarks/results/dryrun/<mesh>/<arch>__<shape>.json and
are summarized into EXPERIMENTS.md §Dry-run/§Roofline by
benchmarks/roofline_report.py.
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import base as cfgbase
from repro.launch import hlo_analysis, mesh as meshlib, steps

RESULTS = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"


def run_cell(arch: str, shape_name: str, multi_pod: bool, fsdp: bool = True) -> dict:
    cfg = cfgbase.get_arch(arch)
    shape = cfgbase.SHAPES[shape_name]
    ok, why = cfgbase.cell_supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped", "reason": why}
    debug = os.environ.get("REPRO_DRYRUN_MESH")  # e.g. "4,2" or "2,2,2"
    if debug:
        dims = tuple(int(x) for x in debug.split(","))
        axes = ("pod", "data", "model")[-len(dims):]
        mesh = meshlib.make_mesh(dims, axes)
    else:
        mesh = meshlib.make_production_mesh(multi_pod=multi_pod)
    ctx = meshlib.make_ctx(mesh, fsdp=fsdp)
    t0 = time.time()
    jitted, args = steps.lowerable(cfg, shape, mesh, ctx)
    lowered = jitted.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    print(ma)                       # proves it fits (or reports it doesn't)
    ca = compiled.cost_analysis()
    print({k: v for k, v in ca.items() if k in ("flops", "bytes accessed")})
    txt = compiled.as_text()
    coll = hlo_analysis.collective_stats(txt)
    roof = hlo_analysis.roofline_terms(ca.get("flops", 0.0),
                                       ca.get("bytes accessed", 0.0),
                                       coll["collective_bytes"])
    n_chips = mesh.size
    rec = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "status": "ok", "n_chips": n_chips, "fsdp": fsdp,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "per_device": {
            "flops": ca.get("flops", 0.0),
            "bytes_accessed": ca.get("bytes accessed", 0.0),
            "argument_bytes": ma.argument_size_in_bytes if ma else None,
            "output_bytes": ma.output_size_in_bytes if ma else None,
            "temp_bytes": ma.temp_size_in_bytes if ma else None,
            "alias_bytes": ma.alias_size_in_bytes if ma else None,
        },
        "collectives": coll,
        "roofline": roof,
        "global_flops": ca.get("flops", 0.0) * n_chips,
    }
    return rec


_ACCT_KEYS = ("flops", "bytes_accessed", "collective_bytes",
              "bytes_all-reduce", "bytes_all-gather", "bytes_reduce-scatter",
              "bytes_all-to-all", "bytes_collective-permute")


def _measure_quantities(cfg, shape, mesh, ctx, opt_cfg) -> dict:
    jitted, args = steps.lowerable(cfg, shape, mesh, ctx, opt_cfg)
    compiled = jitted.lower(*args).compile()
    ca = compiled.cost_analysis()
    coll = hlo_analysis.collective_stats(compiled.as_text())
    q = {"flops": ca.get("flops", 0.0), "bytes_accessed": ca.get("bytes accessed", 0.0)}
    for k in _ACCT_KEYS[2:]:
        q[k] = coll.get(k, 0.0)
    return q


def _perf_variants():
    """Beyond-paper optimizations measured by the §Perf hillclimb."""
    import dataclasses as dc

    return {
        "moe_local_dispatch": lambda c: dc.replace(
            c, moe=dc.replace(c.moe, dispatch="local_shardmap")),
        "exact_causal": lambda c: dc.replace(c, attn_exact_causal=True),
        "ssd_bf16": lambda c: dc.replace(
            c, ssm=dc.replace(c.ssm, bf16_scores=True)),
    }


def accounting_pass(arch: str, shape_name: str, multi_pod: bool, fsdp: bool = True,
                    variant: str | None = None) -> dict:
    """Exact FLOP/byte accounting: fully-unrolled reduced-depth compiles +
    linear extrapolation in depth (see configs.base.depth_basis)."""
    import numpy as np

    cfg = cfgbase.get_arch(arch)
    if variant:
        cfg = _perf_variants()[variant](cfg)
    shape = cfgbase.SHAPES[shape_name]
    mesh = meshlib.make_production_mesh(multi_pod=multi_pod)
    ctx = meshlib.make_ctx(mesh, fsdp=fsdp)
    depths, row, full_row = cfgbase.depth_basis(cfg)
    from repro.train import optimizer as optlib

    opt_cfg = optlib.AdamWConfig(factored=cfg.params_count() > 2e11)
    old = os.environ.get("REPRO_SCAN_UNROLL")
    os.environ["REPRO_SCAN_UNROLL"] = "full"
    try:
        samples = []
        for d in depths:
            dcfg = cfgbase.accounting_variant(cfg, shape, d)
            t0 = time.time()
            samples.append(_measure_quantities(dcfg, shape, mesh, ctx, opt_cfg))
            print(f"  accounting depth={d}: {time.time() - t0:.1f}s", flush=True)
    finally:
        if old is None:
            os.environ.pop("REPRO_SCAN_UNROLL", None)
        else:
            os.environ["REPRO_SCAN_UNROLL"] = old
    a_mat = np.array([row(d) for d in depths])
    est = {}
    for k in _ACCT_KEYS:
        b = np.array([s.get(k, 0.0) for s in samples])
        coef, *_ = np.linalg.lstsq(a_mat, b, rcond=None)
        est[k] = max(float(np.dot(full_row, coef)), 0.0)
    return est


def apply_accounting(rec: dict, est: dict) -> dict:
    """Merge extrapolated quantities; recompute the roofline terms."""
    rec["per_device_extrapolated"] = est
    rec["roofline_raw_scan_counts"] = rec["roofline"]
    rec["roofline"] = hlo_analysis.roofline_terms(
        est["flops"], est["bytes_accessed"], est["collective_bytes"])
    rec["global_flops"] = est["flops"] * rec["n_chips"]
    return rec


def save(rec: dict):
    sub = RESULTS / ("multi_pod" if rec["multi_pod"] else "single_pod")
    sub.mkdir(parents=True, exist_ok=True)
    path = sub / f"{rec['arch']}__{rec['shape']}.json"
    path.write_text(json.dumps(rec, indent=1))
    print("saved", path)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--roofline", action="store_true",
                    help="run the exact-accounting pass and merge into the "
                         "existing per-cell JSONs")
    args = ap.parse_args()

    archs = cfgbase.ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(cfgbase.SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                sub = RESULTS / ("multi_pod" if mp else "single_pod")
                path = sub / f"{arch}__{shape}.json"
                if args.skip_existing and path.exists() and not args.roofline:
                    print("skip existing", path.name)
                    continue
                tag = f"[{arch} × {shape} × {'2x16x16' if mp else '16x16'}]"
                print(f"=== {tag} ===", flush=True)
                try:
                    if args.roofline:
                        if not path.exists():
                            print(f"{tag} no base record; run compile pass first")
                            continue
                        rec = json.loads(path.read_text())
                        if rec["status"] != "ok":
                            print(f"{tag} {rec['status']}; skip accounting")
                            continue
                        if "per_device_extrapolated" in rec and args.skip_existing:
                            continue
                        est = accounting_pass(arch, shape, mp, fsdp=not args.no_fsdp)
                        rec = apply_accounting(rec, est)
                        save(rec)
                        r = rec["roofline"]
                        print(f"{tag} ACCOUNTED bottleneck={r['bottleneck']} "
                              f"frac={r['roofline_fraction']:.3f}", flush=True)
                        continue
                    rec = run_cell(arch, shape, mp, fsdp=not args.no_fsdp)
                    save(rec)
                    if rec["status"] == "ok":
                        r = rec["roofline"]
                        print(f"{tag} OK compile={rec['compile_s']}s "
                              f"bottleneck={r['bottleneck']} "
                              f"frac={r['roofline_fraction']:.3f}", flush=True)
                    else:
                        print(f"{tag} SKIPPED: {rec['reason']}", flush=True)
                except Exception as e:  # record, continue sweep
                    failures.append((tag, repr(e)))
                    sub.mkdir(parents=True, exist_ok=True)
                    path.write_text(json.dumps({
                        "arch": arch, "shape": shape, "multi_pod": mp,
                        "status": "error", "error": traceback.format_exc()}, indent=1))
                    print(f"{tag} FAILED: {e}", flush=True)
    if failures:
        print("\nFAILURES:")
        for tag, e in failures:
            print(" ", tag, e)
        raise SystemExit(1)
    print("\nAll requested cells passed.")


if __name__ == "__main__":
    main()
