"""Sparse-conv weight gradient (wgrad) as a Pallas TPU kernel.

The paper's third training kernel (§4.2/§6.1): a GEMM with *two* sparse
iterators — both operands are gathered through the kernel map, and the K
loop runs over output points (large), which is why the paper tunes wgrad's
dataflow separately and prefers offline-reordered maps for it.

Structure mirrors the fwd kernels: pair lists in SMEM, per-row async DMA
gathers of BOTH operands into VMEM (double scratch), MXU outer-product
accumulation into a VMEM (Cin, Cout) accumulator across the *sequential*
row-tile grid dimension, one write-back per offset.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common


def _kernel(wsin_ref, wsout_ref, x_ref, dy_ref, o_ref, xs, ys, acc,
            sems_x, sems_y, *, tile_r: int, cin: int, cout: int):
    r = pl.program_id(1)

    @pl.when(r == 0)
    def _zero():
        acc[...] = jnp.zeros_like(acc)

    # gather both operands' rows (all DMAs in flight before any wait)
    for i in range(tile_r):
        idx = wsin_ref[0, i]

        @pl.when(idx >= 0)
        def _sx():
            pltpu.make_async_copy(x_ref.at[idx], xs.at[i], sems_x.at[i]).start()

        @pl.when(idx < 0)
        def _zx():
            xs[i, :] = jnp.zeros((cin,), xs.dtype)

        odx = wsout_ref[0, i]

        @pl.when(odx >= 0)
        def _sy():
            pltpu.make_async_copy(dy_ref.at[odx], ys.at[i], sems_y.at[i]).start()

        @pl.when(odx < 0)
        def _zy():
            ys[i, :] = jnp.zeros((cout,), ys.dtype)

    for i in range(tile_r):
        idx = wsin_ref[0, i]

        @pl.when(idx >= 0)
        def _wx():
            pltpu.make_async_copy(x_ref.at[idx], xs.at[i], sems_x.at[i]).wait()

        odx = wsout_ref[0, i]

        @pl.when(odx >= 0)
        def _wy():
            pltpu.make_async_copy(dy_ref.at[odx], ys.at[i], sems_y.at[i]).wait()

    acc[...] += jnp.dot(xs[...].T, ys[...], preferred_element_type=jnp.float32)

    @pl.when(r == pl.num_programs(1) - 1)
    def _flush():
        o_ref[0] = acc[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_r", "interpret"))
def wgrad_pallas(ws_in: jax.Array, ws_out: jax.Array, x: jax.Array,
                 dy: jax.Array, *, tile_r: int = 128,
                 interpret: bool = True) -> jax.Array:
    """ws_in/ws_out: (KD, cap) int32 pair lists; x: (N_in, Cin);
    dy: (N_out, Cout) → dW (KD, Cin, Cout) f32."""
    kd, cap = ws_in.shape
    cin, cout = x.shape[1], dy.shape[1]
    assert cap % tile_r == 0
    grid = (kd, cap // tile_r)
    kernel = functools.partial(_kernel, tile_r=tile_r, cin=cin, cout=cout)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile_r), lambda k, r: (k, r), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, tile_r), lambda k, r: (k, r), memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((1, cin, cout), lambda k, r: (k, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((kd, cin, cout), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((tile_r, cin), x.dtype),
            pltpu.VMEM((tile_r, cout), dy.dtype),
            pltpu.VMEM((cin, cout), jnp.float32),
            pltpu.SemaphoreType.DMA((tile_r,)),
            pltpu.SemaphoreType.DMA((tile_r,)),
        ],
        interpret=interpret,
        compiler_params=common.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
            interpret=interpret),
    )(ws_in, ws_out, x, dy)
