"""Paper Fig. 18 — single best dataflow vs the autotuned hybrid (different
dataflows per layer group)."""
from __future__ import annotations

import jax

from benchmarks import common
from repro.core import dataflows as df
from repro.core.autotuner import Autotuner, partition_groups, timeit_fn
from repro.core.sparse_conv import TrainDataflowConfig
from repro.models import minkunet


def run():
    cfg = minkunet.MinkUNetConfig(width=0.25, blocks_per_stage=1)
    stx = common.seg_scene(n=1200)   # NS-M-like smaller segmentation workload
    params = minkunet.init_params(cfg, jax.random.PRNGKey(0))
    maps = minkunet.build_maps(stx)
    sigs = minkunet.layer_signatures(cfg)
    groups = partition_groups(sigs)
    sig_of = {g.name: sigs[g.layer_names[0]] for g in groups}

    def lat_for(amap):
        fn = jax.jit(lambda p: minkunet.apply(p, stx, cfg, maps, assignment=amap))
        return common.time_fn(lambda: fn(params), iters=2)

    singles = {}
    for name, c in (("implicit_gemm", df.DataflowConfig("implicit_gemm", n_splits=1)),
                    ("fetch_on_demand", df.DataflowConfig("fetch_on_demand")),
                    ("gather_scatter", df.DataflowConfig("gather_scatter"))):
        singles[name] = lat_for({s: TrainDataflowConfig.bind_all(c) for s in set(sigs.values())})

    space = [df.DataflowConfig("implicit_gemm", n_splits=1),
             df.DataflowConfig("fetch_on_demand"),
             df.DataflowConfig("gather_scatter")]

    def measure(assign):
        amap = {sig_of[k]: TrainDataflowConfig.bind_all(v) for k, v in assign.items()}
        fn = jax.jit(lambda p: minkunet.apply(p, stx, cfg, maps, assignment=amap))
        return timeit_fn(lambda: jax.block_until_ready(fn(params)), warmup=1, iters=2)

    best = Autotuner(groups, space, measure).tune()
    hybrid = lat_for({sig_of[k]: TrainDataflowConfig.bind_all(v) for k, v in best.items()})

    best_single = min(singles.values())
    for name, us in singles.items():
        common.emit(f"fig18/NS-M/single/{name}", us, "")
    n_dataflows = len({v.dataflow for v in best.values()})
    common.emit("fig18/NS-M/hybrid(torchsparse++)", hybrid,
                f"speedup_vs_best_single={best_single / hybrid:.3f}x,dataflows_used={n_dataflows}")


if __name__ == "__main__":
    run()
