"""Synthetic request streams for the serving CLI, benchmark and tests.

Scenes are drawn from the same LiDAR-statistics generator the rest of the
repo benchmarks with (``data.synthetic.lidar_scene``), at per-request point
counts sampled from a declared range — the mixed-size traffic a deployed
perception service sees frame to frame.
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import numpy as np

from repro.data.synthetic import lidar_scene
from repro.serve.batcher import Scene, scene_from_tensor


def lidar_stream(seed: int, count: int, channels: int,
                 n_range: Tuple[int, int] = (200, 1200),
                 extent: float = 50.0, voxel: float = 0.4) -> Tuple[List[Scene], int]:
    """``count`` mixed-size scenes + the spatial bound they all respect.

    Replaying the same stream through a warm engine (as the CLI and
    benchmark do) models repeated-frame traffic: identical packed batches
    hit the engine's cross-request map cache.
    """
    rng = np.random.default_rng(seed)
    lo, hi = n_range
    margin = 8.0
    bound = int(np.ceil((extent + margin) / voxel)) + 2
    scenes: List[Scene] = []
    for i in range(count):
        n = int(rng.integers(lo, hi + 1))
        st = lidar_scene(jax.random.PRNGKey(seed * 100003 + i), n, n, channels,
                         extent=extent, voxel=voxel)
        scenes.append(scene_from_tensor(st))
    return scenes, bound
