"""Optimizer, checkpointing (incl. elastic restore), fault-tolerant loop."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt
from repro.train import compression
from repro.train import optimizer as opt
from repro.train.loop import LoopConfig, train_loop


def test_adamw_matches_numpy_reference():
    cfg = opt.AdamWConfig(lr=0.1, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
                          clip_norm=1e9)
    p = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    g = {"w": jnp.asarray([0.1, 0.2, -0.3])}
    state = opt.init_opt_state(p, cfg)
    new_p, state, _ = opt.adamw_update(p, g, state, cfg)
    # numpy reference
    m = 0.1 * np.asarray(g["w"])
    v = 0.001 * np.asarray(g["w"]) ** 2
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.999)
    ref = np.asarray(p["w"]) - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(new_p["w"], ref, rtol=1e-6)


def test_clip_by_global_norm():
    g = {"a": jnp.ones((4,)) * 3.0, "b": jnp.ones((4,)) * 4.0}
    clipped, norm = opt.clip_by_global_norm(g, 1.0)
    assert np.isclose(float(norm), 10.0)
    assert np.isclose(float(opt.global_norm(clipped)), 1.0, rtol=1e-5)


def test_factored_second_moment_reduces_state():
    cfg = opt.AdamWConfig(factored=True)
    p = {"w": jnp.zeros((32, 16)), "b": jnp.zeros((8,))}
    st = opt.init_opt_state(p, cfg)
    assert "vr" in st["mu"]["w"] and st["mu"]["w"]["vr"].shape == (32,)
    assert st["mu"]["w"]["vc"].shape == (16,)
    assert "v" in st["mu"]["b"]            # 1-D params stay unfactored
    g = {"w": jnp.ones((32, 16)), "b": jnp.ones((8,))}
    new_p, _, _ = opt.adamw_update(p, g, st, cfg)
    assert bool(jnp.isfinite(new_p["w"]).all())


def test_optimizer_descends_quadratic():
    cfg = opt.AdamWConfig(lr=0.05, weight_decay=0.0)
    p = {"w": jnp.asarray([3.0, -4.0])}
    state = opt.init_opt_state(p, cfg)
    for _ in range(200):
        g = {"w": 2 * p["w"]}
        p, state, _ = opt.adamw_update(p, g, state, cfg)
    assert float(jnp.abs(p["w"]).max()) < 0.2


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.asarray([1, 2], jnp.int32)}}
    ckpt.save(tmp_path, 7, tree, extra={"data_offset": 7})
    assert ckpt.latest_step(tmp_path) == 7
    restored, step, extra = ckpt.restore(tmp_path, None, tree)
    assert step == 7 and extra["data_offset"] == 7
    np.testing.assert_array_equal(restored["a"], tree["a"])
    np.testing.assert_array_equal(restored["b"]["c"], tree["b"]["c"])


def test_checkpoint_async_and_latest(tmp_path):
    tree = {"w": jnp.ones((4,))}
    ckpt.save_async(tmp_path, 1, tree)
    ckpt.save_async(tmp_path, 2, jax.tree.map(lambda x: x * 2, tree))
    ckpt.wait_pending()
    assert ckpt.latest_step(tmp_path) == 2
    restored, _, _ = ckpt.restore(tmp_path, None, tree)
    np.testing.assert_array_equal(restored["w"], 2 * np.ones((4,)))


def test_checkpoint_atomicity(tmp_path):
    """A .tmp directory must never be treated as a checkpoint."""
    tree = {"w": jnp.ones((2,))}
    ckpt.save(tmp_path, 1, tree)
    (tmp_path / "step_2.tmp").mkdir()
    assert ckpt.latest_step(tmp_path) == 1


def test_checkpoint_dtype_roundtrip_master_weights(tmp_path):
    """The AdamW fp32 master-weight tree of a bf16 run round-trips
    bit-exactly: bf16 leaves survive np.save (which degrades extension
    dtypes to raw void bytes without the uint carrier), and restore honors
    the SAVED dtype from the manifest — a bf16 template standing in for the
    fp32 master tree must not silently crush it."""
    key = jax.random.PRNGKey(0)
    params32 = {"w": jax.random.normal(key, (4, 3)),
                "b": jax.random.normal(key, (3,))}
    params = jax.tree.map(lambda x: x.astype(jnp.bfloat16), params32)
    cfg = opt.AdamWConfig(master_weights=True)
    state = opt.init_opt_state(params, cfg)
    # give the master copy mantissa bits a bf16 cast would destroy
    state["master"] = jax.tree.map(lambda m: m + 1.1920929e-4, params32)
    tree = {"params": params, "opt": state}
    ckpt.save(tmp_path, 5, tree)

    # restore into a template rebuilt from scratch, with the master tree
    # (wrongly) templated at the working bf16 dtype
    template = {"params": jax.tree.map(jnp.zeros_like, params),
                "opt": opt.init_opt_state(
                    jax.tree.map(jnp.zeros_like, params), cfg)}
    template["opt"]["master"] = jax.tree.map(
        lambda m: m.astype(jnp.bfloat16), template["opt"]["master"])
    restored, step, _ = ckpt.restore(tmp_path, None, template)
    assert step == 5
    for name in ("w", "b"):
        r = restored["params"][name]
        assert r.dtype == jnp.bfloat16
        np.testing.assert_array_equal(
            np.asarray(r).view(np.uint16),
            np.asarray(params[name]).view(np.uint16))  # bit-exact bf16
        rm = restored["opt"]["master"][name]
        assert rm.dtype == jnp.float32            # saved dtype wins
        np.testing.assert_array_equal(np.asarray(rm),
                                      np.asarray(state["master"][name]))
        # and the fp32 master really carries bits its bf16 cast loses
        assert not np.array_equal(
            np.asarray(rm), np.asarray(rm.astype(jnp.bfloat16)
                                       .astype(jnp.float32)))
    assert restored["opt"]["step"].dtype == jnp.int32


def _toy_problem():
    target = jnp.asarray([1.0, -2.0])

    def step_fn(params, state, batch):
        def loss(p):
            return jnp.sum((p["w"] - target) ** 2) + 0.0 * jnp.sum(batch)

        l, g = jax.value_and_grad(loss)(params)
        new_p, new_s, _ = opt.adamw_update(params, g, state,
                                           opt.AdamWConfig(lr=0.1, weight_decay=0.0))
        return new_p, new_s, {"loss": l}

    params = {"w": jnp.zeros((2,))}
    state = opt.init_opt_state(params, opt.AdamWConfig())
    return step_fn, params, state


def _data():
    i = 0
    while True:
        yield jnp.asarray([float(i)])
        i += 1


def test_train_loop_runs_and_checkpoints(tmp_path):
    step_fn, params, state = _toy_problem()
    cfg = LoopConfig(total_steps=10, ckpt_every=5, ckpt_dir=str(tmp_path), log_every=100)
    params, state, report = train_loop(step_fn, params, state, _data(), cfg)
    assert report.steps_run == 10
    assert ckpt.latest_step(tmp_path) == 10
    assert report.last_metrics["loss"] < 5.0


def test_train_loop_resumes_from_checkpoint(tmp_path):
    step_fn, params, state = _toy_problem()
    cfg = LoopConfig(total_steps=6, ckpt_every=3, ckpt_dir=str(tmp_path), log_every=100)
    train_loop(step_fn, params, state, _data(), cfg)
    # "restart the job" with more steps: must resume from step 6, not step 0
    step_fn2, params0, state0 = _toy_problem()
    cfg2 = LoopConfig(total_steps=9, ckpt_every=3, ckpt_dir=str(tmp_path), log_every=100)
    _, _, report = train_loop(step_fn2, params0, state0, _data(), cfg2)
    assert report.resumed_from == 6
    assert report.steps_run == 3


def test_quantize_roundtrip_error_bound():
    x = jnp.asarray(np.random.default_rng(0).standard_normal(1000) * 5)
    q, scale = compression._quantize(x)
    err = jnp.abs(compression._dequantize(q, scale) - x)
    assert float(err.max()) <= float(scale) * 0.5 + 1e-6
