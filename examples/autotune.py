"""The Sparse Autotuner end to end: group partition → greedy end-to-end
search → per-group dataflow assignment, on MinkUNet (inference) and the
training tuner with both binding schemes.

    PYTHONPATH=src python examples/autotune.py
"""
import jax
import jax.numpy as jnp

from repro.core import dataflows as df
from repro.core.autotuner import (Autotuner, TrainingAutotuner,
                                  partition_groups, timeit_fn)
from repro.core.sparse_conv import TrainDataflowConfig
from repro.data.synthetic import lidar_scene
from repro.models import minkunet


def main():
    cfg = minkunet.MinkUNetConfig(width=0.25, blocks_per_stage=1)
    st = lidar_scene(jax.random.PRNGKey(0), 1500, 2048, 4, extent=40.0, voxel=0.5)
    params = minkunet.init_params(cfg, jax.random.PRNGKey(1))
    maps = minkunet.build_maps(st)
    sigs = minkunet.layer_signatures(cfg)
    groups = partition_groups(sigs)
    sig_of = {g.name: sigs[g.layer_names[0]] for g in groups}
    print(f"{len(sigs)} conv layers → {len(groups)} map-sharing groups")

    space = [df.DataflowConfig("gather_scatter"),
             df.DataflowConfig("fetch_on_demand"),
             df.DataflowConfig("implicit_gemm", n_splits=0),
             df.DataflowConfig("implicit_gemm", n_splits=1),
             df.DataflowConfig("implicit_gemm", n_splits=2)]

    def measure(assign):
        amap = {sig_of[k]: TrainDataflowConfig.bind_all(v) for k, v in assign.items()}
        fn = jax.jit(lambda p: minkunet.apply(p, st, cfg, maps, assignment=amap))
        return timeit_fn(lambda: jax.block_until_ready(fn(params)), warmup=1, iters=2)

    tuner = Autotuner(groups, space, measure)
    best = tuner.tune()
    print("\nper-group inference assignment:")
    for g in groups:
        c = best[g.name]
        print(f"  {sig_of[g.name]}: {c.dataflow} splits={c.n_splits} "
              f"({len(g.layer_names)} layers)")
    base = measure({g.name: df.DEFAULT_CONFIG for g in groups})
    tuned = measure(best)
    print(f"default {base * 1e3:.1f} ms → tuned {tuned * 1e3:.1f} ms "
          f"({base / tuned:.2f}x)")

    # training tuner: both binding schemes (paper Fig. 13)
    labels = jnp.zeros((st.capacity,), jnp.int32)

    def measure_train(assign3):
        amap = {sig_of[k]: v for k, v in assign3.items()}

        def loss(p):
            lg = minkunet.apply(p, st, cfg, maps, assignment=amap)
            return -jnp.sum(jax.nn.log_softmax(lg)[jnp.arange(st.capacity), labels])

        fn = jax.jit(lambda p: jax.grad(loss)(p))
        return timeit_fn(lambda: jax.block_until_ready(fn(params)), warmup=1, iters=2)

    small = space[:3]
    for scheme in ("bind_fwd_dgrad", "bind_dgrad_wgrad"):
        t = TrainingAutotuner(groups, small, measure_train, scheme)
        out = t.tune()
        lat = measure_train(out)
        print(f"training scheme {scheme}: {lat * 1e3:.1f} ms/step")


if __name__ == "__main__":
    main()
