"""StarCoder2-3B — GQA (kv=2), RoPE, sliding window [arXiv:2402.19173; hf]."""
from repro.models.lm_common import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b", family="dense", n_layers=30, d_model=3072,
    n_heads=24, kv_heads=2, d_ff=12288, vocab=49152, norm="ln", mlp="gelu",
    qkv_bias=True, mlp_bias=True, sliding_window=4096,
)
