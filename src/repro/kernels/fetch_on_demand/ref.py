"""Pure-jnp oracle for the fetch-on-demand dataflow.

Weight-stationary: for each offset δ, gather the paired input rows, multiply
by W_δ and scatter-add into the output.  Zero redundant computation, maximal
write-back traffic (paper §2.2.2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fetch_on_demand_ref(x: jax.Array, w: jax.Array, ws_in: jax.Array,
                        ws_out: jax.Array, n_out: int,
                        acc_dtype=jnp.float32, compute_dtype=None,
                        out_dtype=None) -> jax.Array:
    """x: (N_in, Cin); w: (KD, Cin, Cout); ws_in/ws_out: (KD, cap) int32
    compacted pair lists (-1 padded) → (n_out, Cout).

    ``compute_dtype`` (default ``acc_dtype``) is the GEMM operand dtype;
    scatter-adds accumulate in ``acc_dtype``; ``out_dtype`` defaults to
    ``x.dtype``."""
    from repro.core.precision import gemm_operand

    kd = w.shape[0]
    ct = acc_dtype if compute_dtype is None else compute_dtype
    # round/cast the loop-invariant operands once, not per δ iteration
    xq, wq = gemm_operand(x, ct, acc_dtype), gemm_operand(w, ct, acc_dtype)

    def body(acc, k):
        i_in, i_out = ws_in[k], ws_out[k]
        rows = jnp.where((i_in >= 0)[:, None], xq[jnp.clip(i_in, 0)], 0)
        y = jnp.dot(rows, wq[k], preferred_element_type=acc_dtype)
        return acc.at[i_out].add(y, mode="drop"), None

    acc0 = jnp.zeros((n_out, w.shape[-1]), acc_dtype)
    acc, _ = jax.lax.scan(body, acc0, jnp.arange(kd))
    return acc.astype(x.dtype if out_dtype is None else out_dtype)
