"""Kernel-map construction: the "mapping operators" of the paper.

A kernel map relates output points to input points for every kernel offset
δ ∈ Δ^D(K).  Two representations exist (paper §4.2) and each dataflow needs
its own:

* **output-stationary** ``m_out[n, k]`` — index of the input neighbor of
  output ``n`` at offset ``k`` (or -1).  Required by implicit GEMM.
* **weight-stationary** ``(ws_in[k, i], ws_out[k, i])`` for ``i < ws_count[k]``
  — the per-offset gather/scatter lists.  Required by gather-GEMM-scatter and
  fetch-on-demand.

Packed-key mapping engine
-------------------------
The paper is explicit that mapping overhead (bitmask building, sorting,
reordering) can dominate end-to-end rankings (Tables 3 vs 4).  The mapping
path therefore minimizes sort work:

* the coordinate table is a ``hashing.CoordTable`` — coordinates packed into
  scalar int32 keys, **one** argsort, scalar binary-search compares;
* all K^D shifted queries are answered as one flattened ``(K^D·N,)`` batched
  lookup instead of K^D independent searches;
* the weight-stationary pair lists are compacted **sort-free** in one fused
  segmented pass (per-offset cumsum + rank-select binary search) instead of
  one argsort per offset;
* strided downsampling dedupes grid cells by masking the low stride bits of
  the *already-packed* sorted key array (power-of-two strides; one argsort),
  and the resulting unique key array doubles as the next level's
  ``CoordTable`` — adopted for free through the sidecar ``MapCache`` so
  submanifold layers at the same stride never rebuild the table.

(The seed's multi-word ``engine="legacy"`` A/B path was deleted after a
release cycle of bit-identical cross-checks — see ROADMAP PR-1; the tests
in tests/test_mapping_engine.py now verify against brute-force numpy
references instead.)

On top of the raw map we build the paper's redundancy-reduction machinery:
per-output neighbor **bitmasks**, bitmask **sorting** (Fig. 6), arbitrary
**mask splits** (Fig. 10) and per-(tile, δ) occupancy masks — the TPU analogue
of warp-level skipping (DESIGN.md §2).  ``make_split_plan`` slices per-split
bitmasks out of the stored per-row bitmask with shift/mask bit ops (no
re-scan of ``m_out``) and can emit the tile-occupancy tensor in the same
pass (``tile_m=...``).

Everything is static-shape: maps are built at the capacity of the output
tensor and padded with -1 rows, which is precisely the paper's §3.2 padding
trick (no bounds check in the kernel inner loop).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashing
from repro.core.hashing import CoordTable, KeySpec
from repro.core.sparse_tensor import INVALID_COORD, SparseTensor

_I32_MAX = int(jnp.iinfo(jnp.int32).max)


def kernel_offsets(kernel_size: int, ndim: int) -> np.ndarray:
    """Δ^D(K) as an (K^D, D) int array.

    Odd K: centered window {-(K//2)..K//2}^D (submanifold convention).
    Even K: forward window {0..K-1}^D (downsampling convention, e.g. K=2,s=2).
    The *center-first* ordering puts δ=0 (or the lowest corner for even K)
    first: the center offset is always dense for submanifold convs, and
    leading with it makes split 0 the "dense" split.
    """
    if kernel_size % 2 == 1:
        r = range(-(kernel_size // 2), kernel_size // 2 + 1)
    else:
        r = range(kernel_size)
    offs = np.array(list(itertools.product(r, repeat=ndim)), dtype=np.int32)
    # center-first ordering
    norm = np.abs(offs).sum(axis=1)
    order = np.argsort(norm, kind="stable")
    return offs[order]


def _bitmask(hit: jax.Array) -> jax.Array:
    """Neighbor bitmask (paper Fig. 6) in int32.  Kernel volumes ≤ 31 pack
    exactly; larger volumes use a (popcount << 24 | low-24-bits) composite — a
    rank-preserving proxy that keeps rows with similar occupancy adjacent
    after sorting (x64 stays disabled framework-wide)."""
    kd = hit.shape[-1]
    if kd <= 31:
        return jnp.sum(jnp.where(hit, jnp.int32(1) << jnp.arange(kd, dtype=jnp.int32), 0), axis=-1)
    pop = jnp.sum(hit, axis=-1).astype(jnp.int32)
    low = jnp.sum(jnp.where(hit[..., :24], jnp.int32(1) << jnp.arange(24, dtype=jnp.int32), 0), axis=-1)
    return (pop << 24) | low


def _np_bitmask(hit: np.ndarray) -> np.ndarray:
    """Numpy twin of ``_bitmask`` (identical exact/composite rules) for the
    host-side split-plan composition path."""
    kd = hit.shape[-1]
    h = hit.astype(np.int32)
    if kd <= 31:
        w = np.int32(1) << np.arange(kd, dtype=np.int32)
        return (h * w).sum(axis=-1).astype(np.int32)
    pop = h.sum(axis=-1).astype(np.int32)
    w24 = np.int32(1) << np.arange(24, dtype=np.int32)
    low = (h[..., :24] * w24).sum(axis=-1).astype(np.int32)
    return (pop << np.int32(24)) | low


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class KernelMap:
    """All map representations for one (layer-group) convolution."""

    m_out: jax.Array          # (N_out_cap, KD) int32, -1 = missing
    out_coords: jax.Array     # (N_out_cap, 1+D) int32
    n_out: jax.Array          # () int32
    ws_in: jax.Array          # (KD, cap) int32 gather indices (-1 pad)
    ws_out: jax.Array         # (KD, cap) int32 scatter indices (-1 pad)
    ws_count: jax.Array       # (KD,) int32
    bitmask: jax.Array        # (N_out_cap,) int32 neighbor bitmask (0 pad;
                              # composite popcount proxy when KD > 31)
    out_stride: int = dataclasses.field(metadata=dict(static=True), default=1)
    kernel_size: int = dataclasses.field(metadata=dict(static=True), default=3)

    @property
    def volume(self) -> int:
        return self.m_out.shape[1]

    @property
    def capacity(self) -> int:
        return self.m_out.shape[0]


class MapCache:
    """Sidecar cache of sorted ``CoordTable``s, keyed by coordinate-array
    identity (or a caller-supplied content key), sharing one ``KeySpec``
    across an entire model.

    Model map builders create one per input cloud; every ``build_kmap`` call
    at the same stride then reuses the sorted table (submanifold + strided
    convs over the same coordinates), and strided maps *adopt* their output
    table into the cache so the next pyramid level's table costs zero sorts.

    Serving hook: ``key=`` lets a caller that knows two coordinate arrays
    hold identical *content* (e.g. the serving engine, which digests packed
    request batches) share tables across distinct array objects — the
    cross-request analogue of the cross-layer reuse above.  ``hits``/
    ``misses`` counters and ``clear()`` expose cache behaviour to engine
    stats and tests.  A MapCache must not be reused across separate ``jit``
    traces (cached tables would leak tracers): create one per trace, or use
    it only eagerly.
    """

    def __init__(self, spec: KeySpec):
        self.spec = spec
        self._tables: dict = {}
        self._stride_tables: dict = {}
        self.hits = 0
        self.misses = 0

    @classmethod
    def for_tensor(cls, st: SparseTensor) -> "MapCache":
        return cls(hashing.key_spec_for(st.ndim_space, st.batch_bound,
                                        st.spatial_bound))

    def table(self, st: SparseTensor, key=None) -> CoordTable:
        key = id(st.coords) if key is None else key
        ent = self._tables.get(key)
        if ent is None:
            self.misses += 1
            t = CoordTable.build(st.coords, st.valid_mask, self.spec)
            # hold the coords array so its id stays unique for the cache's life
            self._tables[key] = (st.coords, t)
            return t
        self.hits += 1
        return ent[1]

    def adopt(self, coords: jax.Array, table: CoordTable, key=None) -> None:
        self._tables.setdefault(id(coords) if key is None else key,
                                (coords, table))

    def adopt_for_stride(self, out_stride: int, table: CoordTable,
                         n_out) -> None:
        """Pre-adopt a *composed* output table for the strided map at
        ``out_stride`` (before its output coordinates exist): ``build_kmap``
        then skips the floor-grid unique argsort entirely and derives the
        output coords from the table.  ``n_out`` may be a host int or a
        traced scalar (the composed valid-row count)."""
        self._stride_tables[out_stride] = (table, n_out)

    def table_for_stride(self, out_stride: int):
        return self._stride_tables.get(out_stride)

    def clear(self) -> None:
        self._tables.clear()
        self._stride_tables.clear()

    def __len__(self) -> int:
        return len(self._tables) + len(self._stride_tables)


def _unique_coords(coords: jax.Array, valid: jax.Array, capacity: int):
    """Sort-unique of coordinate rows; returns (coords[capacity], count).
    (Multi-word fallback for non-power-of-two strides — the happy path is
    ``_unique_from_keys``.)"""
    big = jnp.int32(jnp.iinfo(jnp.int32).max)
    words = jnp.where(valid[:, None], coords.astype(jnp.int32), big)
    order = hashing.lex_argsort(words)
    coords_s = coords[order]
    valid_s = valid[order]
    same_as_prev = hashing.rows_equal(coords_s[1:], coords_s[:-1])
    is_first = jnp.concatenate([jnp.ones((1,), bool), ~same_as_prev]) & valid_s
    dest = jnp.where(is_first, jnp.cumsum(is_first) - 1, capacity)
    out = jnp.full((capacity + 1, coords.shape[1]), INVALID_COORD, jnp.int32)
    out = out.at[dest].set(coords_s, mode="drop")
    return out[:capacity], jnp.minimum(jnp.sum(is_first), capacity).astype(jnp.int32)


def _grid_mask_ints(spec: KeySpec, out_stride: int):
    """Per-key-column AND masks (MSB-first, plain python ints) clearing the
    low ``log2(out_stride)`` bits of every spatial field — turning a
    coordinate key into its floor-grid key in one bit op.  For ``raw`` specs
    the columns ARE the coordinates, and two's-complement masking floors
    negatives correctly.  Returns None when the stride is not a power of two
    or a packed field is too narrow (callers fall back to the multi-word
    grid dedup)."""
    if out_stride & (out_stride - 1):
        return None
    log2s = out_stride.bit_length() - 1
    if log2s == 0:
        return None
    if spec.raw:
        return (-1,) + (~(out_stride - 1),) * spec.ndim_space
    masks = [np.int64(2 ** 31 - 1), np.int64(2 ** 31 - 1)]
    for f, (word, shift, width) in enumerate(spec.layout()):
        if f == 0:
            continue  # batch never strides
        if log2s > width - 1:
            return None  # bias 2^(width-1) must stay divisible by the stride
        masks[word] &= ~(((1 << log2s) - 1) << shift) & (2 ** 32 - 1)
    cols = [int(np.int32(m)) for m in masks]
    # MSB-first column order: single word → (lo,), pair → (hi, lo)
    return (cols[0],) if spec.words == 1 else (cols[1], cols[0])


def _grid_key_mask(spec: KeySpec, out_stride: int):
    """jnp-scalar view of ``_grid_mask_ints`` for the traced unique pass."""
    ints = _grid_mask_ints(spec, out_stride)
    if ints is None:
        return None
    return tuple(jnp.int32(m) for m in ints)


def _unique_from_keys(table: CoordTable, out_stride: int, capacity: int):
    """Floor-grid unique pass that *reuses the already-packed sorted key
    array* of the input table.

    Masks the low stride bits of ``table.sorted_keys`` (exactly the packed
    key of each row's grid cell), argsorts the masked keys once, and
    compacts first occurrences.  Returns ``(out_coords, n_out, child_table)``
    where ``child_table`` is the output tensor's CoordTable for free (the
    unique keys come out sorted).  Returns None when masking doesn't apply.
    """
    spec = table.spec
    w = spec.words
    masks = _grid_key_mask(spec, out_stride)
    if masks is None:
        return None
    # PAD rows (invalid/out-of-range) are exactly the int32-max keys; keep
    # them PAD through the masking so they still sort last.  (A raw-spec
    # table row whose leading word legitimately equals int32 max is
    # indistinguishable from padding — the same ambiguity the seed's
    # multi-word table had.)
    if w == 1:
        row_valid = table.sorted_keys != _I32_MAX
        masked = jnp.where(row_valid, table.sorted_keys & masks[0], _I32_MAX)
        same = lambda ks: ks[1:] == ks[:-1]
        pad_shape = (capacity + 1,)
    else:
        row_valid = table.sorted_keys[:, 0] != _I32_MAX
        masked = jnp.where(row_valid[:, None], table.sorted_keys &
                           jnp.stack(list(masks)), _I32_MAX)
        same = lambda ks: hashing.keys_equal(ks[1:], ks[:-1], w)
        pad_shape = (capacity + 1, w)
    order, ks = hashing.sort_keys(masked, spec)
    first_valid = row_valid[order]
    same_as_prev = same(ks)
    is_first = jnp.concatenate([jnp.ones((1,), bool), ~same_as_prev]) & first_valid
    dest = jnp.where(is_first, jnp.cumsum(is_first) - 1, capacity)
    out_keys = jnp.full(pad_shape, _I32_MAX, jnp.int32)
    out_keys = out_keys.at[dest].set(ks, mode="drop")[:capacity]
    n_out = jnp.minimum(jnp.sum(is_first), capacity).astype(jnp.int32)
    key_valid = jnp.arange(capacity) < n_out
    out_coords = jnp.where(key_valid[:, None],
                           hashing.unpack_keys(out_keys, spec), INVALID_COORD)
    child = CoordTable.from_sorted_keys(spec, out_keys)
    return out_coords, n_out, child


def _compact_ws(m_out: jax.Array):
    """Weight-stationary pair lists via one fused segmented pass — NO sorts.

    A stable compaction is a rank-select over the per-column hit cumsum: the
    source row of output slot ``i`` in offset column ``k`` is the first row
    whose inclusive hit-count reaches ``i+1`` (a batched binary search over
    a monotone array — all gathers, no scatters).  One 2-D cumsum plus one
    vectorized searchsorted replaces the seed's K^D per-offset argsorts,
    with identical output: hits first in row order, -1 padding after.
    """
    cap, kd = m_out.shape
    hit = m_out >= 0
    cs = jnp.cumsum(hit, axis=0, dtype=jnp.int32)  # monotone per column
    ws_count = cs[-1]
    slot = jnp.arange(cap, dtype=jnp.int32)

    def col(c, mk, ck):
        # rank-select: source row of output slot i = first row with cumsum i+1
        src = jnp.searchsorted(c, slot + 1, side="left").astype(jnp.int32)
        src = jnp.clip(src, 0, cap - 1)
        ok = slot < ck
        return jnp.where(ok, mk[src], -1), jnp.where(ok, src, -1)

    ws_in, ws_out = jax.vmap(col, in_axes=(1, 1, 0))(cs, m_out, ws_count)
    return ws_in, ws_out, ws_count


def build_kmap(x: SparseTensor, kernel_size: int, stride: int = 1,
               transposed: bool = False, out_coords: Optional[jax.Array] = None,
               n_out: Optional[jax.Array] = None, out_capacity: Optional[int] = None,
               cache: Optional[MapCache] = None) -> KernelMap:
    """Build the kernel map for a sparse convolution over ``x``.

    stride == 1                 : submanifold conv, outputs = inputs.
    stride > 1, not transposed  : downsample; outputs = unique(floor-grid).
    transposed                  : upsample (inverse conv); ``out_coords`` (the
        cached finer coordinates) and ``n_out`` must be given.

    ``cache``: optional ``MapCache`` — reuses the sorted coordinate table
    across calls at the same stride and adopts strided outputs' tables.
    """
    d = x.ndim_space
    t = x.stride
    offs = kernel_offsets(kernel_size, d)
    kd = offs.shape[0]
    cap_in = x.capacity
    spec = cache.spec if cache is not None else hashing.key_spec_for(
        d, x.batch_bound, x.spatial_bound)
    if cache is not None:
        table = cache.table(x)
    else:
        table = CoordTable.build(x.coords, x.valid_mask, spec)

    child_table = None
    if transposed:
        assert out_coords is not None and n_out is not None
        out_stride = t // stride
        assert out_stride >= 1
        n_out_cap = out_capacity or out_coords.shape[0]
        out_coords = out_coords[:n_out_cap]
        # neighbor input coord = out + δ * out_stride mirrored (q = p - δ·t_f)
        delta_scale = -out_stride
    elif stride == 1:
        out_coords, n_out = x.coords, x.num_valid
        out_stride = t
        n_out_cap = out_capacity or cap_in
        out_coords = out_coords[:n_out_cap]
        delta_scale = t
    else:
        out_stride = t * stride
        n_out_cap = out_capacity or cap_in
        pre = cache.table_for_stride(out_stride) if cache is not None else None
        use_pre = pre is not None and pre[0].n == n_out_cap
        uniq = None if use_pre else \
            _unique_from_keys(table, out_stride, n_out_cap)
        if use_pre:
            # composed child table (scene-granular serving reuse): the
            # output coords ARE the unpacked table keys — no unique argsort
            child_table, n_out = pre
            n_out = jnp.asarray(n_out, jnp.int32)
            key_valid = jnp.arange(n_out_cap) < n_out
            out_coords = jnp.where(key_valid[:, None],
                                   hashing.unpack_keys(child_table.sorted_keys,
                                                       spec), INVALID_COORD)
        elif uniq is not None:
            out_coords, n_out, child_table = uniq
        else:
            # non-power-of-two stride (or too-narrow fields): fall back to
            # the multi-word grid dedup — correctness over speed off the
            # happy path
            grid = jnp.concatenate(
                [x.coords[:, :1],
                 (x.coords[:, 1:] // out_stride) * out_stride], axis=1)
            grid = jnp.where(x.valid_mask[:, None], grid, INVALID_COORD)
            out_coords, n_out = _unique_coords(grid, x.valid_mask, n_out_cap)
        delta_scale = t

    out_valid = jnp.arange(n_out_cap) < n_out

    # Output-stationary map: ONE flattened batched lookup over all K^D·N
    # shifted queries.  Padded/out-of-range rows pack to the MISS key.
    shifts = np.concatenate([np.zeros((kd, 1), np.int32),
                             offs * np.int32(delta_scale)], axis=1)
    q = out_coords[None, :, :] + jnp.asarray(shifts)[:, None, :]  # (KD, N, 1+D)
    qkeys = hashing.pack_keys(q.reshape(kd * n_out_cap, d + 1), spec, query=True)
    m_out = table.lookup_keys(qkeys).reshape(kd, n_out_cap).T
    m_out = jnp.where(out_valid[:, None], m_out, -1)

    # Weight-stationary lists: one fused sort-free pass for all K^D offsets.
    ws_in, ws_out, ws_count = _compact_ws(m_out)

    bm = jnp.where(out_valid, _bitmask(m_out >= 0), 0)

    kmap = KernelMap(m_out=m_out, out_coords=out_coords, n_out=jnp.asarray(n_out, jnp.int32),
                     ws_in=ws_in, ws_out=ws_out, ws_count=ws_count, bitmask=bm,
                     out_stride=out_stride, kernel_size=kernel_size)
    if cache is not None and child_table is not None:
        cache.adopt(kmap.out_coords, child_table)
    return kmap


def transpose_kmap(fwd: KernelMap, x_fine: SparseTensor) -> KernelMap:
    """Kernel map of the inverse (transposed) conv from a cached forward map.

    UNet decoders reuse the encoder's maps (paper: layers in the same *group*
    share maps).  We rebuild output-stationary structure for the fine outputs
    by swapping the weight-stationary pair lists.
    """
    kd = fwd.volume
    cap = x_fine.capacity
    # m_out for the fine side: column k of the transposed conv pairs
    # (in=coarse=fwd ws_out rows, out=fine=fwd ws_in rows).
    def col(k):
        m = jnp.full((cap,), -1, jnp.int32)
        src = fwd.ws_out[k]   # coarse index (input of transposed conv)
        dst = fwd.ws_in[k]    # fine index (output of transposed conv)
        ok = dst >= 0
        return m.at[jnp.where(ok, dst, cap)].set(jnp.where(ok, src, -1), mode="drop")

    m_out = jax.vmap(col, out_axes=1)(jnp.arange(kd))
    bm = _bitmask(m_out >= 0)
    return KernelMap(m_out=m_out, out_coords=x_fine.coords, n_out=x_fine.num_valid,
                     ws_in=fwd.ws_out, ws_out=fwd.ws_in, ws_count=fwd.ws_count,
                     bitmask=bm, out_stride=x_fine.stride, kernel_size=fwd.kernel_size)


# ---------------------------------------------------------------------------
# Scene-granular composition (Minuet §4 proper: compose per-scene cached
# mapping work into batch-level structures instead of digesting whole batches)
# ---------------------------------------------------------------------------
#
# Batch bits are the most significant key field, so every sorted structure of
# a packed batch — the coordinate table at every pyramid level, and therefore
# every kernel map built on those tables — is the batch-major concatenation
# of the corresponding per-scene (batch-0) structure with index offsets added
# in.  The helpers below exploit that at two granularities:
#
# * ``scene_table_ladder`` + ``compose_batch_tables`` — per-scene sorted
#   table ladders merge-composed into batch tables (adopted into a MapCache
#   via ``build_maps_from_specs(..., tables=...)``, killing every argsort of
#   a batch map build);
# * ``compose_kmaps`` — per-scene *kernel map* stacks concatenated into the
#   batch map stack (host-side numpy, no device compute at all): warm scenes
#   skip mapping entirely; only cold scenes ever build maps, at their own
#   size.  Bit-identical to a fresh batch build (tests/test_streaming.py).


@dataclasses.dataclass
class SceneEntry:
    """Cached per-scene mapping work, keyed by the scene's content digest.

    n:          scene row count (level-1 size).
    sizes:      tensor-stride -> per-scene row count at that pyramid level.
    maps:       map ref -> numpy kernel-map fields plus the static metadata
                composition needs (``in_stride``/``out_stride``/``kernel``).
    root_keys/root_order: the scene's sorted batch-0 CoordTable — the object
                ``CoordTable.delta_merge`` updates on streaming frames.
    splits:     lazily-filled (map ref, ranges) -> per-split (sorted bitmask
                values, local stable order) numpy pairs — the per-scene half
                of ``compose_split_plans``.
    ladder:     streaming down-ladder state: down out-stride -> (folded cell
                keys, root-row counts) — see ``cell_ladder``.
    """

    n: int
    sizes: Dict[int, int]
    maps: Dict[tuple, dict]
    root_keys: np.ndarray
    root_order: np.ndarray
    splits: Dict[tuple, list] = dataclasses.field(default_factory=dict)
    ladder: Dict[int, tuple] = dataclasses.field(default_factory=dict)

    @property
    def nbytes(self) -> int:
        """Host-memory footprint — the byte-aware scene-store LRU's unit.
        Sums ``.nbytes`` of every numpy array the entry pins (maps, root
        table, lazily-added split orders and ladder state); O(#arrays),
        never touches array data."""
        total = self.root_keys.nbytes + self.root_order.nbytes
        for sm in self.maps.values():
            for v in sm.values():
                if isinstance(v, np.ndarray):
                    total += v.nbytes
        for runs in self.splits.values():
            for vals, loc in runs:
                total += vals.nbytes + loc.nbytes
        for cells, counts in self.ladder.values():
            total += cells.nbytes + counts.nbytes
        return total


def scene_table_ladder(coords: np.ndarray, spec: KeySpec,
                       down_strides: Sequence[int]) -> Dict[int, tuple]:
    """Per-scene sorted table ladder for batch composition.

    coords: (n, 1+D) batch-0 rows, all valid (exact size, no padding).
    down_strides: ascending out-strides of the plan's "down" maps.
    Returns {tensor_stride: (sorted_keys, order_or_None, n)} as numpy — the
    root level keeps its row order; deeper levels are identity-order unique
    key arrays (exactly what a strided map's adopted child table holds).
    Stops early when a stride's floor-grid masking doesn't apply (non-pow2
    stride / too-narrow fields) — composition then covers the upper levels.
    """
    n = coords.shape[0]
    table = CoordTable.build(jnp.asarray(coords), jnp.ones((n,), bool), spec)
    ladder = {1: (np.asarray(table.sorted_keys), np.asarray(table.order), n)}
    cur, cur_n = table, n
    for s in sorted(down_strides):
        res = _unique_from_keys(cur, s, cur_n)
        if res is None:
            break
        _, n_out, child = res
        m = int(n_out)
        keys = np.asarray(child.sorted_keys)[:m]
        ladder[s] = (keys, None, m)
        cur = CoordTable.from_sorted_keys(spec, jnp.asarray(keys))
        cur_n = m
    return ladder


def compose_batch_tables(spec: KeySpec, ladders: Sequence[Dict[int, tuple]],
                         capacity: int) -> Dict[int, tuple]:
    """Compose per-scene table ladders (batch order) into batch tables.

    Returns {tensor_stride: (keys, order_or_None, n)} as device arrays — the
    ``tables=`` argument of ``plan.build_maps_from_specs``, covering every
    level present in *all* ladders.  O(N) concatenation per level.
    """
    strides = set(ladders[0])
    for lad in ladders[1:]:
        strides &= set(lad)
    out: Dict[int, tuple] = {}
    for s in sorted(strides):
        off = 0
        parts = []
        for b, lad in enumerate(ladders):
            keys, order, n = lad[s]
            parts.append((keys, order, b, off))
            off += n
        keys, order = hashing.compose_tables(spec, parts, capacity)
        out[s] = (jnp.asarray(keys),
                  None if order is None else jnp.asarray(order),
                  jnp.asarray(off, jnp.int32))
    return out


def compose_kmaps(entries: Sequence[SceneEntry],
                  capacity: int) -> Optional[Dict[tuple, KernelMap]]:
    """Concatenate per-scene kernel-map stacks into the batch map stack.

    entries: cached SceneEntry per scene, in batch (= packing) order.
    capacity: the batch bucket capacity every composed map is padded to.

    Pure host-side numpy — scene blocks are copied with their input/output
    row offsets added (misses stay -1), weight-stationary lists concatenate
    valid prefixes per offset (scene blocks are already hits-first in row
    order), bitmasks/coords concatenate with the batch column rewritten.
    Returns None when composition does not apply (an empty scene, or a level
    size exceeding the capacity).
    """
    if not entries or any(e.n == 0 for e in entries):
        return None
    strides = set(entries[0].sizes)
    for e in entries[1:]:
        strides &= set(e.sizes)
    offs = {s: np.cumsum([0] + [e.sizes[s] for e in entries]) for s in strides}
    if any(offs[s][-1] > capacity for s in strides):
        return None
    maps: Dict[tuple, KernelMap] = {}
    for ref in entries[0].maps:
        m0 = entries[0].maps[ref]
        in_s, out_s = m0["in_stride"], m0["out_stride"]
        if in_s not in strides or out_s not in strides:
            return None
        kd = m0["m_out"].shape[1]
        d1 = m0["out_coords"].shape[1]
        m_out = np.full((capacity, kd), -1, np.int32)
        oc = np.full((capacity, d1), int(INVALID_COORD), np.int32)
        bm = np.zeros((capacity,), np.int32)
        for b, e in enumerate(entries):
            sm = e.maps[ref]
            n_o = e.sizes[out_s]
            off_in, off_out = int(offs[in_s][b]), int(offs[out_s][b])
            blk = sm["m_out"][:n_o]
            m_out[off_out:off_out + n_o] = np.where(blk >= 0, blk + off_in, -1)
            c = sm["out_coords"][:n_o].copy()
            c[:, 0] = b
            oc[off_out:off_out + n_o] = c
            bm[off_out:off_out + n_o] = sm["bitmask"][:n_o]
        transpose_of = m0.get("transpose_of")
        if transpose_of is not None and transpose_of in maps:
            # a fresh batch build derives an up map's pair lists by swapping
            # the forward strided map's (transpose_kmap) — mirror that
            # exactly, from the already-composed down map (map-spec order
            # puts downs before ups), so slot layout matches bit-for-bit
            # even when scene rows are not lexicographically sorted
            fwd = maps[transpose_of]
            ws_in_j, ws_out_j, wc_j = fwd.ws_out, fwd.ws_in, fwd.ws_count
        else:
            # weight-stationary lists re-derived from the composed m_out in
            # one vectorized pass — hits first in row order per offset
            # column, the exact ``_compact_ws`` layout (scene blocks are
            # row-ordered, so this equals concatenating the per-scene valid
            # prefixes)
            ws_in = np.full((kd, capacity), -1, np.int32)
            ws_out = np.full((kd, capacity), -1, np.int32)
            hit = m_out >= 0
            k_idx, row_idx = np.nonzero(hit.T)  # sorted by offset, then row
            counts = hit.sum(axis=0)
            slot = np.arange(k_idx.size) - np.concatenate(
                [[0], np.cumsum(counts)[:-1]])[k_idx]
            ws_in[k_idx, slot] = m_out[row_idx, k_idx]
            ws_out[k_idx, slot] = row_idx
            ws_in_j, ws_out_j = jnp.asarray(ws_in), jnp.asarray(ws_out)
            wc_j = jnp.asarray(counts.astype(np.int32))
        maps[ref] = KernelMap(
            m_out=jnp.asarray(m_out), out_coords=jnp.asarray(oc),
            n_out=jnp.asarray(int(offs[out_s][-1]), jnp.int32),
            ws_in=ws_in_j, ws_out=ws_out_j, ws_count=wc_j,
            bitmask=jnp.asarray(bm), out_stride=int(out_s),
            kernel_size=int(m0["kernel_size"]))
    return maps


# ---------------------------------------------------------------------------
# Incremental down-ladder (cross-level delta maps): streaming deltas propagate
# through the pyramid as exact per-cell occupancy counts, so a delta-merged
# scene rebuilds its map stack from adopted tables at EVERY level — no
# per-level masked-key argsort on the merged root.  All host-side numpy.
# ---------------------------------------------------------------------------
#
# State per down level s: the sorted unique floor-grid cell keys (folded to
# int64 scalars for two-word specs) plus, per cell, the number of root rows
# inside it.  Counts make removal exact: a cell leaves the level exactly when
# its last root row leaves the scene.  Note masking a sorted key array does
# NOT keep it sorted (flooring two packed fields can swap neighbors), so the
# initial derivation argsorts per level — but chained level-from-previous-
# level (masks nest across pow2 strides), on strictly shrinking arrays, and
# the per-frame delta path (``cell_ladder_delta``) only ever sorts the delta.


def _fold_keys(keys: np.ndarray, words: int) -> np.ndarray:
    """Order-isomorphic int64 scalar fold of packed key rows
    (``hashing._np_cmp_keys``), always int64 so masks compose."""
    return np.asarray(hashing._np_cmp_keys(np.asarray(keys), words),
                      dtype=np.int64).reshape(-1)


def _fold_grid_mask(spec: KeySpec, out_stride: int) -> Optional[int]:
    """AND-mask on *folded* keys equivalent to per-word grid masking.  Valid
    packed low words are non-negative (fields live in bits 0..29), so the
    fold's ``lo - int32_min`` bias only sets bit 31 — kept in the mask."""
    if spec.raw:
        return None
    ints = _grid_mask_ints(spec, out_stride)
    if ints is None:
        return None
    if spec.words == 1:
        return ints[0]
    hi, lo = ints
    return (hi << 32) | (1 << 31) | lo


def _unique_counts(vals: np.ndarray, cnts: np.ndarray):
    """(unique sorted vals, summed counts) of an unsorted (vals, cnts) pair."""
    o = np.argsort(vals, kind="stable")
    v, c = vals[o], cnts[o]
    if not v.size:
        return v, c
    first = np.empty(v.shape, bool)
    first[0] = True
    np.not_equal(v[1:], v[:-1], out=first[1:])
    starts = np.flatnonzero(first)
    return v[starts], np.add.reduceat(c, starts)


def cell_ladder(spec: KeySpec, root_keys: np.ndarray,
                down_strides: Sequence[int]) -> Dict[int, tuple]:
    """Initial down-ladder occupancy state of a scene.

    root_keys: the scene's packed sorted keys, exact size (no PAD rows).
    Returns {down out-stride: (folded cell keys — sorted unique int64,
    int64 per-cell root-row counts)}.  Level s's cells are exactly
    ``unique(mask_s(root))``; since pow2 grid masks nest, each level is
    derived from the previous (smaller) level's cells with counts summed
    through.  Stops at the first stride whose masking doesn't apply; raw
    specs return {} (callers fall back to root-table-only adoption).
    """
    if spec.raw:
        return {}
    vals = _fold_keys(root_keys, spec.words)
    cnts = np.ones(vals.shape, np.int64)
    out: Dict[int, tuple] = {}
    for s in sorted(down_strides):
        fm = _fold_grid_mask(spec, s)
        if fm is None:
            break
        vals, cnts = _unique_counts(vals & fm, cnts)
        out[s] = (vals, cnts)
    return out


def cell_ladder_delta(spec: KeySpec, ladder: Dict[int, tuple],
                      removed_keys: np.ndarray,
                      added_keys: np.ndarray) -> Dict[int, tuple]:
    """Propagate a root delta through the cell ladder: per level an O(r+a)
    sort of the delta plus an O(cells) splice — never a sort of the full
    cloud.  ``removed_keys``/``added_keys`` are packed root key rows (exact
    sets: removed rows were present, added rows were absent).  Returns fresh
    {out-stride: (cells, counts)}; the input ladder is not mutated.
    """
    w = spec.words
    rem = _fold_keys(removed_keys, w)
    add = _fold_keys(added_keys, w)
    out: Dict[int, tuple] = {}
    for s, (cells, cnts) in ladder.items():
        fm = _fold_grid_mask(spec, s)
        dv = np.concatenate([rem & fm, add & fm])
        dc = np.concatenate([np.full(rem.shape, -1, np.int64),
                             np.ones(add.shape, np.int64)])
        dv, dc = _unique_counts(dv, dc)
        live = dc != 0
        dv, dc = dv[live], dc[live]
        pos = np.searchsorted(cells, dv)
        hit = np.zeros(dv.shape, bool)
        in_r = pos < cells.size
        hit[in_r] = cells[pos[in_r]] == dv[in_r]
        new_cnts = cnts.copy()
        new_cnts[pos[hit]] += dc[hit]
        keep = new_cnts > 0
        base_v, base_c = cells[keep], new_cnts[keep]
        ins_v, ins_c = dv[~hit], dc[~hit]  # unseen cells can only gain rows
        if ins_v.size:
            ip = np.searchsorted(base_v, ins_v)
            base_v = np.insert(base_v, ip, ins_v)
            base_c = np.insert(base_c, ip, ins_c)
        out[s] = (base_v, base_c)
    return out


def ladder_tables(spec: KeySpec, ladder: Dict[int, tuple],
                  capacity: int) -> Dict[int, tuple]:
    """Unfold ladder cells into the padded sorted-key arrays that
    ``build_maps_from_specs(tables=...)`` adopts: {down out-stride: (keys
    padded to ``capacity`` with PAD rows, None, n)} as numpy — every down
    level of a delta-merged scene build then takes the table-adoption path
    instead of re-argsorting masked keys."""
    out: Dict[int, tuple] = {}
    i32min = int(np.iinfo(np.int32).min)
    for s, (cells, _) in ladder.items():
        m = int(cells.shape[0])
        if m > capacity:
            return {}
        if spec.words == 1:
            keys = np.full((capacity,), _I32_MAX, np.int32)
            keys[:m] = cells.astype(np.int32)
        else:
            keys = np.full((capacity, 2), _I32_MAX, np.int32)
            keys[:m, 0] = (cells >> np.int64(32)).astype(np.int32)
            keys[:m, 1] = ((cells & np.int64(0xFFFFFFFF)) + i32min).astype(np.int32)
        out[s] = (keys, None, m)
    return out


# ---------------------------------------------------------------------------
# Sorting + mask splits (Sparse Autotuner design-space, paper §4.1)
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SplitPlan:
    """Row orders and offset ranges for s-split (un)sorted implicit GEMM.

    order[s]   : (N_out_cap,) permutation of output rows for split s.
    inv_order[s]: inverse permutations (to undo the reordering on write-back).
    ranges     : static ((start, end), ...) partition of the KD offsets.
    sorted_    : False ⇒ identity order (paper's "unsorted", split=0 case).
    occupancy  : optional (S, n_tiles, KD) per-(split, tile, δ) occupancy,
                 fused into the plan pass when ``make_split_plan(tile_m=...)``.
    tile_m     : static tile height the occupancy was computed for (0 = none).
    """

    order: jax.Array       # (S, N_out_cap) int32
    inv_order: jax.Array   # (S, N_out_cap) int32
    ranges: Tuple[Tuple[int, int], ...] = dataclasses.field(metadata=dict(static=True))
    sorted_: bool = dataclasses.field(metadata=dict(static=True), default=True)
    occupancy: Optional[jax.Array] = None
    tile_m: int = dataclasses.field(metadata=dict(static=True), default=0)

    @property
    def num_splits(self) -> int:
        return len(self.ranges)


def split_ranges(volume: int, n_splits: int) -> Tuple[Tuple[int, int], ...]:
    """Partition KD offsets into ~equal contiguous ranges."""
    n_splits = max(1, min(n_splits, volume))
    bounds = np.linspace(0, volume, n_splits + 1).round().astype(int)
    return tuple((int(bounds[i]), int(bounds[i + 1])) for i in range(n_splits))


def make_split_plan(kmap: KernelMap, n_splits: int, sort: bool = True,
                    tile_m: Optional[int] = None) -> SplitPlan:
    """Paper Fig. 10: split the δ loop into s parts, argsort each split's
    bitmask independently and reorder rows per split.  ``n_splits=1, sort``
    reproduces SpConv v2 (Fig. 6); ``sort=False`` is the unsorted dataflow
    (Fig. 5) the paper re-adds to the design space.

    One pass over ``m_out``: per-split bitmasks are bit-sliced out of the
    stored ``kmap.bitmask`` (exact for KD ≤ 31), and passing ``tile_m``
    additionally emits the per-(split, tile, δ) occupancy on the already-
    permuted hit matrix instead of a separate ``tile_occupancy`` pass.
    """
    ranges = split_ranges(kmap.volume, n_splits)
    cap = kmap.capacity
    kd = kmap.volume
    hit = kmap.m_out >= 0
    valid = jnp.arange(cap) < kmap.n_out

    orders = []
    for (a, b) in ranges:
        if not sort:
            orders.append(jnp.arange(cap, dtype=jnp.int32))
            continue
        if kd <= 31:
            bm = (kmap.bitmask >> a) & jnp.int32((1 << (b - a)) - 1)
        else:
            bm = _bitmask(hit[:, a:b])
        # valid rows first (sorted by bitmask), padding last
        key = jnp.where(valid, bm, jnp.iinfo(jnp.int32).max)
        if kd <= 31 and (b - a) <= 29 and hashing.radix_enabled():
            orders.append(hashing.radix_argsort_padded(key, b - a))
        else:
            orders.append(jnp.argsort(key).astype(jnp.int32))
    order = jnp.stack(orders)
    inv = jax.vmap(lambda o: jnp.argsort(o).astype(jnp.int32))(order)

    occ = None
    if tile_m is not None:
        hit_i = hit.astype(jnp.int32)
        occ = jnp.stack([_split_occupancy(hit_i, order[s], r, tile_m)
                         for s, r in enumerate(ranges)])

    return SplitPlan(order=order, inv_order=inv, ranges=ranges, sorted_=sort,
                     occupancy=occ, tile_m=tile_m or 0)


def _scene_split_keys(entry: SceneEntry, ref: tuple,
                      ranges: Tuple[Tuple[int, int], ...]) -> list:
    """Per-split (sorted bitmask values, local stable order) of one scene's
    cached map — the per-scene half of a composed ``SplitPlan``.  Computed
    once per (ref, ranges) with numpy stable argsorts and cached on the
    entry; every subsequent batch containing the scene merge-composes the
    cached runs instead of re-sorting."""
    ck = (ref, ranges)
    cached = entry.splits.get(ck)
    if cached is not None:
        return cached
    sm = entry.maps[ref]
    n_o = entry.sizes[sm["out_stride"]]
    kd = sm["m_out"].shape[1]
    runs = []
    for a, b in ranges:
        if kd <= 31:
            bm = ((sm["bitmask"][:n_o].astype(np.int32) >> np.int32(a))
                  & np.int32((1 << (b - a)) - 1))
        else:
            bm = _np_bitmask(sm["m_out"][:n_o, a:b] >= 0)
        if kd <= 31 and (b - a) <= 29 and hashing.radix_enabled():
            loc = hashing.np_radix_argsort_bits(bm, b - a)
        else:
            loc = np.argsort(bm, kind="stable").astype(np.int32)
        runs.append((bm[loc], loc))
    entry.splits[ck] = runs
    return runs


def _merge_sorted_runs(vals_a, ord_a, vals_b, ord_b):
    """Stable two-way merge of two sorted runs whose A row indices all
    precede B's — ties land A-first (the ``np_delta_merge`` searchsorted
    pattern), matching a stable sort of the concatenation."""
    pos_a = np.arange(vals_a.size) + np.searchsorted(vals_b, vals_a, side="left")
    pos_b = np.arange(vals_b.size) + np.searchsorted(vals_a, vals_b, side="right")
    vals = np.empty(vals_a.size + vals_b.size, vals_a.dtype)
    order = np.empty(vals.size, np.int32)
    vals[pos_a] = vals_a
    vals[pos_b] = vals_b
    order[pos_a] = ord_a
    order[pos_b] = ord_b
    return vals, order


def compose_split_plans(entries: Sequence[SceneEntry], ref: tuple,
                        n_splits: int, sort: bool, capacity: int) -> SplitPlan:
    """Merge-compose per-scene sorted split orders into the batch
    ``SplitPlan`` — host-side numpy, no device argsort on the batch path.

    Bit-identical to ``make_split_plan(compose_kmaps(entries, capacity)[ref],
    n_splits, sort)``: jnp's argsort is stable, so sorting the concatenated
    per-scene bitmask blocks (pad tail at int32 max) IS the stable k-way
    merge of the per-scene stable-sorted runs — ties break toward the lower
    global row, i.e. the earlier scene — followed by the pad rows in slot
    order.  Callers must pass the same entries/capacity that composed the
    kernel maps.
    """
    m0 = entries[0].maps[ref]
    kd = m0["m_out"].shape[1]
    ranges = split_ranges(kd, n_splits)
    cap = capacity
    if not sort:
        eye = np.ascontiguousarray(np.broadcast_to(
            np.arange(cap, dtype=np.int32), (len(ranges), cap)))
        order = jnp.asarray(eye)
        return SplitPlan(order=order, inv_order=order, ranges=ranges,
                         sorted_=False)
    out_s = m0["out_stride"]
    offs = np.cumsum([0] + [e.sizes[out_s] for e in entries])
    total = int(offs[-1])
    per_scene = [_scene_split_keys(e, ref, ranges) for e in entries]
    order = np.empty((len(ranges), cap), np.int32)
    for s in range(len(ranges)):
        vals, merged = per_scene[0][s]
        for b in range(1, len(entries)):
            sv, so = per_scene[b][s]
            vals, merged = _merge_sorted_runs(vals, merged,
                                              sv, so + np.int32(offs[b]))
        order[s, :total] = merged
        order[s, total:] = np.arange(total, cap, dtype=np.int32)
    inv = np.empty_like(order)
    rows = np.arange(cap, dtype=np.int32)
    for s in range(len(ranges)):
        inv[s, order[s]] = rows
    # one batched transfer: two separate jnp.asarray dispatches would double
    # the per-batch host->device overhead that dominates at small capacities
    order_d, inv_d = jax.device_put((order, inv))
    return SplitPlan(order=order_d, inv_order=inv_d,
                     ranges=ranges, sorted_=True)


def _split_occupancy(hit: jax.Array, order: jax.Array, rng: Tuple[int, int],
                     tile_m: int) -> jax.Array:
    """(n_tiles, KD) occupancy of one split: 1 iff any row of the permuted
    tile has a neighbor at δ, zeroed outside the split's offset range."""
    cap, kd = hit.shape
    assert cap % tile_m == 0, "capacity must be padded to tile_m (paper §3.2)"
    a, b = rng
    h = hit[order].reshape(cap // tile_m, tile_m, kd)
    col = jnp.arange(kd)
    in_range = ((col >= a) & (col < b)).astype(jnp.int32)
    return jnp.max(h, axis=1) * in_range[None, :]


def tile_occupancy(kmap: KernelMap, plan: SplitPlan, tile_m: int) -> jax.Array:
    """Per-(split, tile, δ) occupancy: 1 iff any row of the tile has a
    neighbor at δ within the split's range (else the whole MXU tile matmul is
    skipped — the TPU analogue of warp-level zero skipping).

    Returns (S, n_tiles, KD) int32 (columns outside the split's range are 0).
    Reuses the plan's fused occupancy when it was built with the same
    ``tile_m``; otherwise recomputes.
    """
    if plan.occupancy is not None and plan.tile_m == tile_m:
        return plan.occupancy
    hit = (kmap.m_out >= 0).astype(jnp.int32)
    return jnp.stack([_split_occupancy(hit, plan.order[i], r, tile_m)
                      for i, r in enumerate(plan.ranges)])


def redundancy_stats(kmap: KernelMap, plan: SplitPlan, tile_m: int) -> dict:
    """Effective vs issued MACs (paper Fig. 11): issued = Σ occupied tiles ×
    tile_m; effective = Σ hits.  The autotuner's analytic cost model reads
    these."""
    occ = tile_occupancy(kmap, plan, tile_m)
    issued_rows = jnp.sum(occ) * tile_m
    effective = jnp.sum(kmap.m_out >= 0)
    return dict(issued_rows=issued_rows, effective_rows=effective,
                overhead=issued_rows / jnp.maximum(effective, 1))
