"""Mamba-1 (falcon-mamba-7b) — selective SSM with chunked scan.

TPU adaptation of the CUDA "hardware-aware" selective scan: the per-timestep
recurrence is re-expressed as a chunked associative scan — within a chunk the
(B, Q, d_inner, d_state) tensors are materialized once (VMEM-sized transient
under remat), across chunks a `lax.scan` carries only the (B, d_inner,
d_state) boundary state.  This keeps peak memory at ~1/nc of the naive
associative scan while staying fully vectorized (no 4096-step scalar scan).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models.lm_common import (ArchConfig, NO_SHARD, ShardCtx, _rand, xscan,
                                    apply_norm, chunked_xent, embed_init,
                                    init_norm, rms_norm, unembed_matrix)


def _causal_conv1d(x, w, b):
    """Depthwise causal conv. x: (B, S, D); w: (D, K); b: (D,)."""
    k = w.shape[1]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    y = sum(xp[:, j:j + x.shape[1]] * w[:, j] for j in range(k))
    return y + b


def mamba_init(cfg: ArchConfig, key, dtype):
    d = cfg.d_model
    s = cfg.ssm
    d_in = s.expand * d
    dt_rank = s.dt_rank or d // 16
    ks = jax.random.split(key, 6)
    return {
        "norm": init_norm(cfg, d, dtype),
        "in_proj": _rand(ks[0], (d, 2 * d_in), dtype),
        "conv_w": _rand(ks[1], (d_in, s.conv_kernel), dtype, scale=s.conv_kernel ** -0.5),
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": _rand(ks[2], (d_in, dt_rank + 2 * s.d_state), dtype),
        "dt_w": _rand(ks[3], (dt_rank, d_in), dtype),
        "dt_b": jnp.full((d_in,), -4.6, dtype),   # softplus⁻¹(0.01)
        "A_log": jnp.log(jnp.tile(jnp.arange(1, s.d_state + 1, dtype=jnp.float32),
                                  (d_in, 1))).astype(dtype),
        "D": jnp.ones((d_in,), dtype),
        "out_proj": _rand(ks[4], (d_in, d), dtype),
    }


def _ssm_scan_chunked(decay, bx, chunk: int, bf16: bool = False):
    """h_t = decay_t ⊙ h_{t-1} + bx_t over axis 1.

    decay/bx: (B, S, D, N) → y-states (B, S, D, N).  Chunked: associative scan
    inside a chunk, sequential scan over chunk boundaries.

    bf16 (§Perf): the (B, S, d_inner, N) decay/input/state tensors are by far
    the block's largest HBM traffic (16× the activations at N=16); keeping
    them bf16 halves it.  A Pallas selective-scan kernel would avoid
    materializing them at all — bf16 is the XLA-measurable stand-in."""
    b, s_len, d, n = decay.shape
    if bf16:
        decay, bx = decay.astype(jnp.bfloat16), bx.astype(jnp.bfloat16)
    pad = (-s_len) % chunk
    if pad:
        # identity steps: decay 1, input 0 — states pass through unchanged
        decay = jnp.pad(decay, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
        bx = jnp.pad(bx, ((0, 0), (0, pad), (0, 0), (0, 0)))
        out = _ssm_scan_chunked(decay, bx, chunk)
        return out[:, :s_len]
    nc = s_len // chunk
    dc = decay.reshape(b, nc, chunk, d, n)
    bc = bx.reshape(b, nc, chunk, d, n)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    def chunk_body(h0, xs):
        a_c, b_c = xs                                   # (B, Q, D, N)
        aa, hh = jax.lax.associative_scan(combine, (a_c, b_c), axis=1)
        h = hh + aa * h0[:, None]                       # add boundary state
        return h[:, -1], h

    h0 = jnp.zeros((b, d, n), decay.dtype)
    _, hs = xscan(jax.checkpoint(chunk_body),
                         h0, (dc.transpose(1, 0, 2, 3, 4), bc.transpose(1, 0, 2, 3, 4)))
    return hs.transpose(1, 0, 2, 3, 4).reshape(b, s_len, d, n)


def mamba_block(cfg: ArchConfig, p, x, ctx: ShardCtx = NO_SHARD):
    """x: (B, S, d) → (B, S, d) (pre-norm residual block)."""
    s_cfg = cfg.ssm
    b, s_len, d = x.shape
    dt_rank = s_cfg.dt_rank or d // 16
    n = s_cfg.d_state

    h = apply_norm(cfg, x, p["norm"])
    xz = h @ p["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)                # (B, S, d_in)
    x_in = ctx.cons(x_in, ctx.b, None, ctx.m)
    x_c = jax.nn.silu(_causal_conv1d(x_in, p["conv_w"], p["conv_b"]))

    proj = x_c @ p["x_proj"]
    dt_in, b_ssm, c_ssm = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
    dt = jax.nn.softplus(dt_in @ p["dt_w"] + p["dt_b"]).astype(jnp.float32)  # (B,S,d_in)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))                             # (d_in, N)

    sdt = jnp.bfloat16 if s_cfg.bf16_scores else jnp.float32
    dt_s, a_s = dt.astype(sdt), a.astype(sdt)
    decay = jnp.exp(dt_s[..., None] * a_s)                                   # (B,S,d_in,N)
    bx = (dt_s * x_c.astype(sdt))[..., None] * b_ssm.astype(sdt)[:, :, None, :]
    hs = _ssm_scan_chunked(decay, bx, min(s_cfg.chunk, s_len), bf16=s_cfg.bf16_scores)
    y = jnp.einsum("bsdn,bsn->bsd", hs, c_ssm.astype(hs.dtype),
                   preferred_element_type=jnp.float32)
    y = y + p["D"].astype(jnp.float32) * x_c.astype(jnp.float32)
    y = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["out_proj"]
    return x + ctx.cons(y, ctx.b, None, None)


# ---------------------------------------------------------------------------
# Model: embeddings + stacked mamba blocks
# ---------------------------------------------------------------------------

def init_params(cfg: ArchConfig, key) -> dict:
    dtype = cfg.jdtype
    ke, kl = jax.random.split(key)
    params = dict(embed_init(cfg, ke, dtype))
    params["final_norm"] = init_norm(cfg, cfg.d_model, dtype)
    keys = jax.random.split(kl, cfg.n_layers)
    params["layers"] = jax.vmap(lambda k: mamba_init(cfg, k, dtype))(keys)
    return params


def forward_hidden(cfg: ArchConfig, params, tokens, ctx: ShardCtx = NO_SHARD):
    x = params["embed"][tokens]
    x = ctx.cons(x, ctx.b, None, None)

    def body(x, lp):
        return jax.checkpoint(partial(mamba_block, cfg, ctx=ctx))(lp, x), None

    x, _ = xscan(body, x, params["layers"])
    return apply_norm(cfg, x, params["final_norm"])


def loss_fn(cfg: ArchConfig, params, batch, ctx: ShardCtx = NO_SHARD):
    h = forward_hidden(cfg, params, batch["tokens"], ctx)
    return chunked_xent(cfg, params, h, batch["labels"], ctx)


# ---------------------------------------------------------------------------
# Serving: recurrent state decode (O(1) per token — the sub-quadratic path
# that makes long_500k viable for this family)
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_len: int = 0, dtype=None):
    dtype = dtype or cfg.jdtype
    d_in = cfg.ssm.expand * cfg.d_model
    return {"conv": jnp.zeros((cfg.n_layers, batch, cfg.ssm.conv_kernel - 1, d_in), dtype),
            "ssm": jnp.zeros((cfg.n_layers, batch, d_in, cfg.ssm.d_state), jnp.float32),
            "pos": jnp.zeros((), jnp.int32)}


def prefill(cfg: ArchConfig, params, tokens, cache, ctx: ShardCtx = NO_SHARD, **kw):
    """Process the prompt, return (last-token logits, decode-ready cache)."""
    x = params["embed"][tokens]
    x = ctx.cons(x, ctx.b, None, None)
    s_cfg = cfg.ssm
    k = s_cfg.conv_kernel

    def body(x, lp):
        d = cfg.d_model
        dt_rank = s_cfg.dt_rank or d // 16
        n = s_cfg.d_state
        h = apply_norm(cfg, x, lp["norm"])
        xz = h @ lp["in_proj"]
        x_in, z = jnp.split(xz, 2, axis=-1)
        x_in = ctx.cons(x_in, ctx.b, None, ctx.m)
        x_c = jax.nn.silu(_causal_conv1d(x_in, lp["conv_w"], lp["conv_b"]))
        proj = x_c @ lp["x_proj"]
        dt_in, b_ssm, c_ssm = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
        dt = jax.nn.softplus(dt_in @ lp["dt_w"] + lp["dt_b"]).astype(jnp.float32)
        a = -jnp.exp(lp["A_log"].astype(jnp.float32))
        sdt = jnp.bfloat16 if s_cfg.bf16_scores else jnp.float32
        dt_s, a_s = dt.astype(sdt), a.astype(sdt)
        decay = jnp.exp(dt_s[..., None] * a_s)
        bx = (dt_s * x_c.astype(sdt))[..., None] * b_ssm.astype(sdt)[:, :, None, :]
        hs = _ssm_scan_chunked(decay, bx, min(s_cfg.chunk, x.shape[1]), bf16=s_cfg.bf16_scores)
        y = jnp.einsum("bsdn,bsn->bsd", hs, c_ssm.astype(hs.dtype),
                   preferred_element_type=jnp.float32)
        y = y + lp["D"].astype(jnp.float32) * x_c.astype(jnp.float32)
        y = (y.astype(x.dtype) * jax.nn.silu(z)) @ lp["out_proj"]
        return x + ctx.cons(y, ctx.b, None, None), (x_in[:, -(k - 1):], hs[:, -1])

    def scanned(x, lp):
        return jax.checkpoint(body)(x, lp)

    x, (conv_st, ssm_st) = xscan(scanned, x, params["layers"])
    h = apply_norm(cfg, x[:, -1], params["final_norm"])
    logits = (h @ unembed_matrix(cfg, params)).astype(jnp.float32)
    cache = dict(cache, conv=conv_st, ssm=ssm_st,
                 pos=jnp.asarray(tokens.shape[1], jnp.int32))
    return logits, cache


def decode_step(cfg: ArchConfig, params, cache, token, ctx: ShardCtx = NO_SHARD):
    x = params["embed"][token]                          # (B, d)
    s_cfg = cfg.ssm
    d = cfg.d_model
    dt_rank = s_cfg.dt_rank or d // 16
    n = s_cfg.d_state

    def body(x, xs):
        lp, conv_st, ssm_st = xs
        h = apply_norm(cfg, x, lp["norm"])
        xz = h @ lp["in_proj"]
        x_in, z = jnp.split(xz, 2, axis=-1)             # (B, d_in)
        window = jnp.concatenate([conv_st, x_in[:, None]], axis=1)  # (B, K, d_in)
        x_c = jax.nn.silu(jnp.einsum("bkd,dk->bd", window, lp["conv_w"]) + lp["conv_b"])
        proj = x_c @ lp["x_proj"]
        dt_in, b_ssm, c_ssm = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
        dt = jax.nn.softplus(dt_in @ lp["dt_w"] + lp["dt_b"]).astype(jnp.float32)
        a = -jnp.exp(lp["A_log"].astype(jnp.float32))
        decay = jnp.exp(dt[..., None] * a)              # (B, d_in, N)
        bx = (dt * x_c.astype(jnp.float32))[..., None] * b_ssm.astype(jnp.float32)[:, None, :]
        ssm_new = decay * ssm_st + bx
        y = jnp.einsum("bdn,bn->bd", ssm_new, c_ssm.astype(jnp.float32))
        y = y + lp["D"].astype(jnp.float32) * x_c.astype(jnp.float32)
        out = (y.astype(x.dtype) * jax.nn.silu(z)) @ lp["out_proj"]
        return x + out, (window[:, 1:], ssm_new)

    x, (conv_new, ssm_new) = xscan(body, x, (params["layers"], cache["conv"], cache["ssm"]))
    h = apply_norm(cfg, x, params["final_norm"])
    logits = (h @ unembed_matrix(cfg, params)).astype(jnp.float32)
    return logits, dict(cache, conv=conv_new, ssm=ssm_new, pos=cache["pos"] + 1)
