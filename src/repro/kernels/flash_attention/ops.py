"""Jit'd wrapper: GQA head broadcast + shape glue for flash attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.flash_attention.flash_attention import flash_attention_pallas


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """q: (B, H, S, D); k/v: (B, Hkv, T, D). Returns (B, H, S, D)."""
    if interpret is None:
        interpret = default_interpret()
    b, h, s, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    g = h // hkv
    k = jnp.repeat(k, g, axis=1)
    v = jnp.repeat(v, g, axis=1)
    out = flash_attention_pallas(
        q.reshape(b * h, s, d), k.reshape(b * h, t, d), v.reshape(b * h, t, d),
        causal=causal, block_q=min(block_q, s), block_k=min(block_k, t),
        interpret=interpret)
    return out.reshape(b, h, s, d)
