"""Implicit-GEMM sparse convolution — the flagship Pallas TPU kernel.

Paper §3.1 (Fig. 7): a sparse conv kernel is a dense GEMM whose operand-A
loads go through one level of indirection (the kernel map).  TPU adaptation
(DESIGN.md §2):

* the kernel map tile lives in **SMEM** (BlockSpec memory_space=SMEM) — the
  structural equivalent of the paper's hoisted, register-resident addressing;
* operand A rows are fetched **HBM→VMEM by per-row async DMA**
  (`pltpu.make_async_copy`), all `tile_m` copies in flight before the MXU
  consumes them — this is the "sparse DRAM→L1 iterator" with overlapped
  memory access and compute (paper Fig. 3d);
* per-(tile, δ) **occupancy scalars** gate the whole gather+matmul with
  `@pl.when` — warp-level zero skipping becomes MXU-tile-level skipping;
* `-1` map entries (paper §3.2 padding) zero the scratch row instead of
  issuing a DMA, so the inner loop has no bounds check.

Grid: (m_tiles, n_tiles, KD_split) with δ innermost; the f32 accumulator
lives in VMEM across δ steps and is written once at the last δ.

``implicit_gemm_worklist_pallas`` is the tile-*skipping* variant (Spira's
structure-exploiting scheduling): instead of the dense (m_tiles, KD) product
gated per step by ``@pl.when``, the grid runs over a host-compacted worklist
of the occupied (m_tile, δ) pairs only — empty tiles are never scheduled.
The worklist is sorted by m_tile so all δ entries of one output tile are
consecutive grid steps; Pallas keeps the revisited output block (and the
VMEM accumulator) resident across them, and per-entry flags mark the
first/last entry of each tile (zero / flush points).  Scalar-prefetch
(``pltpu.PrefetchScalarGridSpec``) feeds the worklist to the index maps, so
the weight block and output block are data-dependent on the worklist entry.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common
from repro.kernels.common import cdiv


def _kernel(midx_ref, occ_ref, x_ref, w_ref, o_ref, scratch, acc, sems, *,
            tile_m: int, cin: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc[...] = jnp.zeros_like(acc)

    @pl.when(occ_ref[0, 0] == 1)
    def _compute():
        # Issue all row gathers (double buffering degenerates to "all in
        # flight": one DMA + semaphore per row).
        for r in range(tile_m):
            idx = midx_ref[r, 0]

            @pl.when(idx >= 0)
            def _start():
                pltpu.make_async_copy(x_ref.at[idx], scratch.at[r], sems.at[r]).start()

            @pl.when(idx < 0)
            def _zero_row():
                scratch[r, :] = jnp.zeros((cin,), scratch.dtype)

        for r in range(tile_m):
            idx = midx_ref[r, 0]

            @pl.when(idx >= 0)
            def _wait():
                pltpu.make_async_copy(x_ref.at[idx], scratch.at[r], sems.at[r]).wait()

        acc[...] += jnp.dot(scratch[...], w_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_m", "tile_n", "interpret"))
def implicit_gemm_pallas(midx: jax.Array, occ: jax.Array, x: jax.Array,
                         w: jax.Array, *, tile_m: int = 128, tile_n: int = 128,
                         interpret: bool = True) -> jax.Array:
    """One split of sorted/unsorted implicit GEMM.

    midx: (N_out_pad, KD) int32 — (already row-permuted) kernel map slice.
    occ:  (N_out_pad // tile_m, KD) int32 — per-(tile, δ) occupancy.
    x:    (N_in, Cin) — input features (stays in HBM; gathered by DMA).
    w:    (KD, Cin, Cout) — weights for this split's offsets.
    Returns (N_out_pad, Cout) partial sums in x.dtype.
    """
    n_out, kd = midx.shape
    _, cin = x.shape
    cout = w.shape[-1]
    assert n_out % tile_m == 0, "pad map rows to tile_m (paper §3.2)"
    assert cout % tile_n == 0, f"Cout {cout} must be a multiple of tile_n {tile_n}"
    grid = (n_out // tile_m, cout // tile_n, kd)

    kernel = functools.partial(_kernel, tile_m=tile_m, cin=cin)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, 1), lambda i, j, k: (i, k), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda i, j, k: (i, k), memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec((1, cin, tile_n), lambda i, j, k: (k, 0, j)),
        ],
        out_specs=pl.BlockSpec((tile_m, tile_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_out, cout), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((tile_m, cin), x.dtype),
            pltpu.VMEM((tile_m, tile_n), jnp.float32),
            pltpu.SemaphoreType.DMA((tile_m,)),
        ],
        interpret=interpret,
        compiler_params=common.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
            interpret=interpret),
    )(midx, occ, x, w)


# ------------------------------------------------------- tile skipping
# Worklist entry flags (bit field; 0 = padding entry, never computes)
WL_FIRST = 1   # first entry of its output tile: zero the accumulator
WL_LAST = 2    # last entry of its output tile: flush acc → output block
WL_VALID = 4   # real entry: gather + accumulate (middle entries are
#                VALID-only; pads are 0)


def _wl_kernel(wl_tile_ref, wl_delta_ref, wl_flags_ref, midx_ref, x_ref,
               w_ref, o_ref, scratch, acc, sems, *, tile_m: int, cin: int):
    del wl_tile_ref, wl_delta_ref   # consumed by the index maps
    i = pl.program_id(1)
    fl = wl_flags_ref[i]

    @pl.when((fl & WL_FIRST) != 0)
    def _zero():
        acc[...] = jnp.zeros_like(acc)

    @pl.when((fl & WL_VALID) != 0)
    def _compute():
        for r in range(tile_m):
            idx = midx_ref[0, r]

            @pl.when(idx >= 0)
            def _start():
                pltpu.make_async_copy(x_ref.at[idx], scratch.at[r], sems.at[r]).start()

            @pl.when(idx < 0)
            def _zero_row():
                scratch[r, :] = jnp.zeros((cin,), scratch.dtype)

        for r in range(tile_m):
            idx = midx_ref[0, r]

            @pl.when(idx >= 0)
            def _wait():
                pltpu.make_async_copy(x_ref.at[idx], scratch.at[r], sems.at[r]).wait()

        acc[...] += jnp.dot(scratch[...], w_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when((fl & WL_LAST) != 0)
    def _flush():
        o_ref[...] = acc[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("n_tiles_m", "tile_m", "tile_n",
                                    "interpret"))
def implicit_gemm_worklist_pallas(wl_tile: jax.Array, wl_delta: jax.Array,
                                  wl_flags: jax.Array, wl_midx: jax.Array,
                                  x: jax.Array, w: jax.Array, *,
                                  n_tiles_m: int, tile_m: int = 128,
                                  tile_n: int = 128,
                                  interpret: bool = True) -> jax.Array:
    """One split of tile-skipping implicit GEMM over a compacted worklist.

    wl_tile:  (W,) int32 — output m-tile of each entry, sorted ascending
              (all entries of one tile consecutive); pads repeat the last
              real tile so no fresh output block is visited.
    wl_delta: (W,) int32 — δ offset (into this split's weight slice).
    wl_flags: (W,) int32 — WL_VALID/WL_FIRST/WL_LAST bit field; 0 ⇒ padding
              entry (no compute, no write).
    wl_midx:  (W, tile_m) int32 — pre-gathered kernel-map rows of each
              entry (``midx[tile·tile_m:(tile+1)·tile_m, δ]``).
    x:        (N_in, Cin); w: (KD_split, Cin, Cout).
    Returns (n_tiles_m · tile_m, Cout) partials; tiles with NO worklist
    entry hold uninitialized garbage — callers must mask them to zero
    (the wrapper does).
    """
    wn, cin = wl_midx.shape[0], x.shape[1]
    cout = w.shape[-1]
    assert cout % tile_n == 0, f"Cout {cout} must be a multiple of tile_n {tile_n}"
    grid = (cout // tile_n, wn)   # worklist innermost: same-tile steps stay
    #                               resident in the output block / acc

    kernel = functools.partial(_wl_kernel, tile_m=tile_m, cin=cin)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile_m), lambda j, i, wt, wd, wf: (i, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec((1, cin, tile_n), lambda j, i, wt, wd, wf: (wd[i], 0, j)),
        ],
        out_specs=pl.BlockSpec((tile_m, tile_n),
                               lambda j, i, wt, wd, wf: (wt[i], j)),
        scratch_shapes=[
            pltpu.VMEM((tile_m, cin), x.dtype),
            pltpu.VMEM((tile_m, tile_n), jnp.float32),
            pltpu.SemaphoreType.DMA((tile_m,)),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_tiles_m * tile_m, cout), x.dtype),
        interpret=interpret,
        compiler_params=common.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
            interpret=interpret),
    )(wl_tile, wl_delta, wl_flags, wl_midx, x, w)
