"""Length-prefixed binary RPC frames for the serving fleet — pure numpy.

The fleet boundary (serve/fleet.py) ships ``Scene``s out to worker hosts
and per-scene results back.  Everything that crosses it is already
host-side numpy by PR-5 construction (the batcher packs on the host; the
engine unpacks to numpy), so the wire format needs no third-party codec:
a small self-describing binary encoding of python scalars / str / bytes /
list / dict / ndarray, framed with a magic + version + length prefix.

Frame layout (big-endian)::

    'S' 'W'  version:u8  kind:u8  length:u32  payload[length]

``kind`` is free for the application (the fleet uses KIND_MSG for every
op); ``version`` gates decoding — a reader rejects frames from a newer
protocol instead of mis-parsing them.

Value encoding is one tag byte per node::

    N none | T true | F false | I int:i64 | f float:f64
    S str:u32+utf8 | B bytes:u32 | L list:u32+items
    D dict:u32+(key,value) pairs (keys are arbitrary encoded values —
      stats dicts key recompile counters by int bucket capacity)
    A ndarray: dtype-name str, ndim u8, dims u32*, raw C-order bytes

Arrays preserve dtype, shape and byte content exactly — including
``bfloat16`` (ml_dtypes, jax's own dependency) whose raw 2-byte words
round-trip bit-identically, so a bf16 feature tensor crosses the fleet
boundary without a float32 detour.  Big ints that overflow i64 raise
rather than truncate.

``Scene`` / ``SceneDelta`` / ``SceneResult`` / ``PackedBatch`` get
dedicated to/from-dict helpers so the declared-bounds contract
(``batch_bound`` / ``spatial_bound`` / ``stride``) survives the trip —
a worker that rebuilt a batch with different bounds would pack different
keys and silently break bit-identity.
"""
from __future__ import annotations

import io
import socket
import struct
from typing import Any, Tuple

import numpy as np

from repro.core.sparse_tensor import SparseTensor

MAGIC = b"SW"
WIRE_VERSION = 1

#: the one frame kind the fleet protocol uses (frames carry dict messages)
KIND_MSG = 1

_HEADER = struct.Struct(">2sBBI")

#: dtypes reconstructible by name through plain numpy
_EXTRA_DTYPES = {}
try:                                    # jax depends on ml_dtypes, but keep
    import ml_dtypes                    # the codec importable without it
    for _name in ("bfloat16", "float8_e4m3fn", "float8_e5m2"):
        if hasattr(ml_dtypes, _name):
            _EXTRA_DTYPES[_name] = np.dtype(getattr(ml_dtypes, _name))
except ImportError:                     # pragma: no cover - minimal envs
    pass


class WireError(ValueError):
    """Malformed frame or unsupported value/protocol version."""


def _dtype_by_name(name: str) -> np.dtype:
    if name in _EXTRA_DTYPES:
        return _EXTRA_DTYPES[name]
    try:
        return np.dtype(name)
    except TypeError as e:
        raise WireError(f"undecodable dtype {name!r}") from e


# --------------------------------------------------------------- value codec

def _encode_value(out: io.BytesIO, v: Any) -> None:
    if isinstance(v, np.ndarray):
        name = v.dtype.name
        if _dtype_by_name(name) != v.dtype:
            raise WireError(f"dtype {v.dtype} has no stable wire name")
        # ascontiguousarray promotes 0-d to 1-d; restore the true shape
        a = np.ascontiguousarray(v).reshape(v.shape)
        nb = name.encode("ascii")
        out.write(b"A" + struct.pack(">I", len(nb)) + nb)
        out.write(struct.pack(">B", a.ndim))
        if a.ndim:
            out.write(struct.pack(f">{a.ndim}I", *a.shape))
        raw = a.tobytes()
        out.write(struct.pack(">I", len(raw)) + raw)
    elif v is None:
        out.write(b"N")
    elif isinstance(v, (bool, np.bool_)):   # before int: bool ⊂ int
        out.write(b"T" if v else b"F")
    elif isinstance(v, (int, np.integer)):
        i = int(v)
        try:
            out.write(b"I" + struct.pack(">q", i))
        except struct.error as e:
            raise WireError(f"int {i} overflows the i64 wire word") from e
    elif isinstance(v, (float, np.floating)):
        out.write(b"f" + struct.pack(">d", float(v)))
    elif isinstance(v, str):
        b = v.encode("utf-8")
        out.write(b"S" + struct.pack(">I", len(b)) + b)
    elif isinstance(v, bytes):
        out.write(b"B" + struct.pack(">I", len(v)) + v)
    elif isinstance(v, (list, tuple)):
        out.write(b"L" + struct.pack(">I", len(v)))
        for item in v:
            _encode_value(out, item)
    elif isinstance(v, dict):
        out.write(b"D" + struct.pack(">I", len(v)))
        for k, val in v.items():
            _encode_value(out, k)
            _encode_value(out, val)
    else:
        raise WireError(f"unencodable value of type {type(v).__name__}")


def _read(buf: io.BytesIO, n: int) -> bytes:
    b = buf.read(n)
    if len(b) != n:
        raise WireError(f"truncated payload: wanted {n} bytes, got {len(b)}")
    return b


def _decode_value(buf: io.BytesIO) -> Any:
    tag = _read(buf, 1)
    if tag == b"N":
        return None
    if tag == b"T":
        return True
    if tag == b"F":
        return False
    if tag == b"I":
        return struct.unpack(">q", _read(buf, 8))[0]
    if tag == b"f":
        return struct.unpack(">d", _read(buf, 8))[0]
    if tag == b"S":
        (n,) = struct.unpack(">I", _read(buf, 4))
        return _read(buf, n).decode("utf-8")
    if tag == b"B":
        (n,) = struct.unpack(">I", _read(buf, 4))
        return _read(buf, n)
    if tag == b"L":
        (n,) = struct.unpack(">I", _read(buf, 4))
        return [_decode_value(buf) for _ in range(n)]
    if tag == b"D":
        (n,) = struct.unpack(">I", _read(buf, 4))
        out = {}
        for _ in range(n):
            k = _decode_value(buf)
            out[k] = _decode_value(buf)
        return out
    if tag == b"A":
        (n,) = struct.unpack(">I", _read(buf, 4))
        dtype = _dtype_by_name(_read(buf, n).decode("ascii"))
        (ndim,) = struct.unpack(">B", _read(buf, 1))
        shape = struct.unpack(f">{ndim}I", _read(buf, 4 * ndim)) if ndim else ()
        (nbytes,) = struct.unpack(">I", _read(buf, 4))
        raw = _read(buf, nbytes)
        expect = int(np.prod(shape, dtype=np.int64)) * dtype.itemsize
        if nbytes != expect:
            raise WireError(f"array byte count {nbytes} != shape/dtype "
                            f"promise {expect}")
        return np.frombuffer(raw, dtype=dtype).reshape(shape).copy()
    raise WireError(f"unknown wire tag {tag!r}")


def encode(value: Any) -> bytes:
    """Serialize one value tree to payload bytes (no frame header)."""
    out = io.BytesIO()
    _encode_value(out, value)
    return out.getvalue()


def decode(payload: bytes) -> Any:
    """Inverse of ``encode``; raises WireError on malformed/trailing bytes."""
    buf = io.BytesIO(payload)
    v = _decode_value(buf)
    rest = buf.read()
    if rest:
        raise WireError(f"{len(rest)} trailing bytes after value")
    return v


# -------------------------------------------------------------------- frames

def pack_frame(payload: bytes, kind: int = KIND_MSG) -> bytes:
    return _HEADER.pack(MAGIC, WIRE_VERSION, kind, len(payload)) + payload


def unpack_header(header: bytes) -> Tuple[int, int]:
    """(kind, payload_length) of a frame header; validates magic+version."""
    magic, version, kind, length = _HEADER.unpack(header)
    if magic != MAGIC:
        raise WireError(f"bad frame magic {magic!r}")
    if version != WIRE_VERSION:
        raise WireError(f"unsupported wire version {version} "
                        f"(this reader speaks {WIRE_VERSION})")
    return kind, length


HEADER_SIZE = _HEADER.size


# ------------------------------------------------------------------- sockets

def _recv_exact(sock: socket.socket, n: int) -> bytes:
    chunks = []
    got = 0
    while got < n:
        b = sock.recv(min(n - got, 1 << 20))
        if not b:
            raise ConnectionError("peer closed mid-frame")
        chunks.append(b)
        got += len(b)
    return b"".join(chunks)


def send_msg(sock: socket.socket, msg: Any) -> None:
    """Encode + frame + send one message (blocking, whole frame)."""
    sock.sendall(pack_frame(encode(msg)))


def recv_msg(sock: socket.socket) -> Any:
    """Receive + decode one framed message (blocking)."""
    kind, length = unpack_header(_recv_exact(sock, HEADER_SIZE))
    payload = _recv_exact(sock, length) if length else b""
    return decode(payload)


# ------------------------------------------------- serving object round-trips

def scene_to_wire(scene) -> dict:
    return {"coords": scene.coords, "feats": scene.feats}


def scene_from_wire(d: dict):
    from repro.serve.batcher import Scene
    return Scene(coords=d["coords"], feats=d["feats"])


def delta_to_wire(delta) -> dict:
    return {"removed": delta.removed, "added_coords": delta.added_coords,
            "added_feats": delta.added_feats}


def delta_from_wire(d: dict):
    from repro.serve.batcher import SceneDelta
    return SceneDelta(removed=d["removed"], added_coords=d["added_coords"],
                      added_feats=d["added_feats"])


def result_to_wire(res) -> dict:
    return {"coords": res.coords, "feats": res.feats, "stride": res.stride}


def result_from_wire(d: dict):
    from repro.serve.batcher import SceneResult
    return SceneResult(coords=d["coords"], feats=d["feats"],
                       stride=int(d["stride"]))


def packed_batch_to_wire(batch) -> dict:
    """Flatten a PackedBatch (device tensors → host numpy) with every
    declared bound, so the receiver rebuilds a tensor that packs the SAME
    voxel keys (bounds are the key bit budget — see sparse_tensor.py)."""
    st = batch.st
    return {"coords": np.asarray(st.coords), "feats": np.asarray(st.feats),
            "num_valid": int(st.num_valid), "stride": int(st.stride),
            "batch_bound": int(st.batch_bound),
            "spatial_bound": int(st.spatial_bound),
            "scene_sizes": list(batch.scene_sizes),
            "bucket": int(batch.bucket), "digest": batch.digest}


def packed_batch_from_wire(d: dict):
    import jax.numpy as jnp

    from repro.serve.batcher import PackedBatch
    st = SparseTensor(coords=jnp.asarray(d["coords"]),
                      feats=jnp.asarray(d["feats"]),
                      num_valid=jnp.asarray(d["num_valid"], jnp.int32),
                      stride=int(d["stride"]),
                      batch_bound=int(d["batch_bound"]),
                      spatial_bound=int(d["spatial_bound"]))
    return PackedBatch(st=st, scene_sizes=tuple(d["scene_sizes"]),
                       bucket=int(d["bucket"]), digest=d["digest"])
