"""Jit'd wrapper: splits + sorting + padding around the implicit-GEMM kernel.

The Sparse Kernel Generator (core/generator.py) picks ``tile_m/tile_n`` and
the Sparse Autotuner picks ``n_splits``/``sorted``; this wrapper is the glue
that turns a (KernelMap, SplitPlan) pair into pallas_call invocations plus the
split-sum reduction of paper Fig. 10.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.kmap import KernelMap, SplitPlan
from repro.kernels.common import default_interpret
from repro.kernels.implicit_gemm.implicit_gemm import implicit_gemm_pallas


def implicit_gemm(x: jax.Array, w: jax.Array, kmap: KernelMap, plan: SplitPlan,
                  *, tile_m: int = 128, tile_n: int = 128,
                  interpret: bool | None = None) -> jax.Array:
    """Full sparse conv via (split, sorted) implicit GEMM. Returns (N_out_cap, Cout)."""
    if interpret is None:
        interpret = default_interpret()
    cap = kmap.capacity
    cout = w.shape[-1]
    assert cap % tile_m == 0, "choose capacities as multiples of tile_m"
    out = jnp.zeros((cap, cout), x.dtype)
    for s, (a, b) in enumerate(plan.ranges):
        order = plan.order[s]
        midx = kmap.m_out[order][:, a:b]
        occ = (midx.reshape(cap // tile_m, tile_m, b - a) >= 0).any(axis=1).astype(jnp.int32)
        partial = implicit_gemm_pallas(midx, occ, x, w[a:b], tile_m=tile_m,
                                       tile_n=tile_n, interpret=interpret)
        out = out + partial[plan.inv_order[s]]
    return out
