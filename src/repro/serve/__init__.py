"""Sparse serving engine: bucketed dynamic batching, scene-granular and
streaming map reuse, persisted tuned plans, the multi-device routed tier,
and the cross-host fleet tier — all behind one ``SparseService`` protocol
(see engine.py, router.py, fleet.py and service.py for the architecture)."""
from repro.serve.batcher import (PackedBatch, Scene, SceneBatcher, SceneDelta,
                                 SceneResult, apply_delta, scene_from_tensor)
from repro.serve.bucketing import BucketLadder
from repro.serve.engine import ARCHS, Engine, EngineStats
from repro.serve.fleet import FleetFrontend, FleetStats, FleetWorker
from repro.serve.plans import PlanRegistry, device_key
from repro.serve.router import DeviceRouter, RouterStats
from repro.serve.service import (STATS_SCHEMA_VERSION, ServiceConfig,
                                 SparseService)

__all__ = ["ARCHS", "BucketLadder", "DeviceRouter", "Engine", "EngineStats",
           "FleetFrontend", "FleetStats", "FleetWorker", "PackedBatch",
           "PlanRegistry", "RouterStats", "STATS_SCHEMA_VERSION", "Scene",
           "SceneBatcher", "SceneDelta", "SceneResult", "ServiceConfig",
           "SparseService", "apply_delta", "device_key", "scene_from_tensor"]
