"""Sparse serving launcher: bucketed batched point-cloud inference.

    python -m repro.launch.serve_sparse --arch minkunet_kitti
    python -m repro.launch.serve_sparse --arch centerpoint_waymo \
        --tune --plans plans.json     # tune once…
    python -m repro.launch.serve_sparse --arch centerpoint_waymo \
        --plans plans.json            # …serve forever
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    python -m repro.launch.serve_sparse --arch minkunet_kitti --devices 4
    python -m repro.launch.serve_sparse --arch minkunet_kitti --hosts 2

Drives a mixed-size synthetic request stream through one of the three
``SparseService`` tiers — the single-device ``Engine``, the sharded
``DeviceRouter`` (``--devices N``), or the cross-host ``FleetFrontend``
(``--hosts N`` spawns N localhost worker processes) — and prints
latency/throughput stats (p50/p95 per scene, scenes/s, jit recompile and
map-cache counters; per-device / per-host routing counters when sharded).
"""
from __future__ import annotations

import argparse
import contextlib

from repro import obs
from repro.serve.engine import ARCHS, Engine
from repro.serve.fleet import FleetFrontend
from repro.serve.plans import PlanRegistry
from repro.serve.router import DeviceRouter
from repro.serve.service import ServiceConfig
from repro.serve.workload import lidar_stream


def build_service(arch: str, buckets, max_batch: int, spatial_bound: int,
                  plans_path=None, seed: int = 0, map_strategy=None,
                  devices: int = 1, hosts: int = 1, max_wait_ms=None,
                  replication: str = "lazy"):
    """One ``SparseService`` front end, picked from deployment shape alone:
    a plain ``Engine`` for a single device, a ``DeviceRouter`` sharding the
    same ladder across ``devices`` workers, or — with ``hosts > 1`` — a
    ``FleetFrontend`` spawning that many localhost worker processes
    (identical submit/flush/serve API, bit-identical outputs)."""
    config = ServiceConfig(buckets=tuple(buckets), max_batch=max_batch,
                           spatial_bound=spatial_bound, seed=seed,
                           map_strategy=map_strategy,
                           max_wait_ms=max_wait_ms)
    if hosts > 1:
        # the fleet forwards the plans *path* — worker processes load it
        return FleetFrontend(arch, hosts=hosts, config=config,
                             plans=plans_path, replication=replication,
                             respawn=True, devices_per_host=devices)
    plans = PlanRegistry.load(plans_path) if plans_path else None
    if devices > 1:
        return DeviceRouter(arch, devices=devices, config=config, plans=plans)
    return Engine(arch, config=config, plans=plans)


def fmt_ms(v) -> str:
    """Format a maybe-None millisecond value (idle stats report None)."""
    return "-" if v is None else f"{v:.1f} ms"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--scenes", type=int, default=24)
    ap.add_argument("--buckets", default="512,1024,2048",
                    help="comma-separated capacity ladder")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--min-points", type=int, default=200)
    ap.add_argument("--max-points", type=int, default=1200)
    ap.add_argument("--epochs", type=int, default=2,
                    help="replay the stream N times; epochs > 1 exercise "
                         "cross-request map reuse on repeated batches")
    ap.add_argument("--flush-every", type=int, default=8,
                    help="scenes per flush (0 = one flush at the end)")
    ap.add_argument("--devices", type=int, default=1,
                    help="shard serving across the first N jax devices "
                         "(CPU smoke: set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N); with "
                         "--hosts, devices per spawned worker")
    ap.add_argument("--hosts", type=int, default=1,
                    help="fleet tier: spawn N localhost worker processes "
                         "behind a FleetFrontend (RPC boundary + failover "
                         "+ weighted routing)")
    ap.add_argument("--replication", default="lazy",
                    choices=["lazy", "gossip"],
                    help="fleet scene-store replication policy: push every "
                         "admitted scene to all hosts (gossip) or let hosts "
                         "warm from routed traffic (lazy)")
    ap.add_argument("--plans", default=None,
                    help="PlanRegistry JSON (loaded at startup; --tune writes it)")
    ap.add_argument("--tune", action="store_true",
                    help="run the Sparse Autotuner on a sample batch and "
                         "persist the assignment before serving (per-device "
                         "plan entries when --devices > 1)")
    ap.add_argument("--map-strategy", default=None,
                    choices=["sort", "composed", "incremental"],
                    help="coordinate-table strategy override (default: the "
                         "plan's declared KmapSpec.table axis)")
    ap.add_argument("--tiny", action="store_true",
                    help="reduced stream/ladder for smoke runs")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None, metavar="OUT",
                    help="write a trace of the serving run: Chrome "
                         "trace-event JSON (open in Perfetto) or a flat "
                         "event log when OUT ends in .jsonl; also captures "
                         "an XLA-level profile to OUT.xprof/ when the jax "
                         "profiler is available")
    ap.add_argument("--max-wait-ms", type=float, default=None,
                    help="latency deadline: flush when the oldest queued "
                         "scene exceeds this age; doubles as the per-request "
                         "SLO reported in summary()['slo']")
    args = ap.parse_args(argv)

    if args.tiny:
        args.scenes, args.buckets = 6, "256,512"
        args.min_points, args.max_points, args.flush_every = 80, 400, 3
    buckets = [int(b) for b in args.buckets.split(",")]

    binding = ARCHS[args.arch]
    channels = binding.in_channels_of(binding.default_config)
    scenes, bound = lidar_stream(args.seed, args.scenes, channels,
                                 n_range=(args.min_points, args.max_points))
    engine = build_service(args.arch, buckets, args.max_batch, bound,
                           plans_path=args.plans, seed=args.seed,
                           map_strategy=args.map_strategy,
                           devices=args.devices, hosts=args.hosts,
                           max_wait_ms=args.max_wait_ms,
                           replication=args.replication)
    sharded = isinstance(engine, DeviceRouter)
    fleet = isinstance(engine, FleetFrontend)
    if args.trace:
        obs.enable()

    if args.tune:
        sample = scenes[:min(2, len(scenes))]
        assignment = engine.tune(sample)   # persists when --plans was given
        n_groups = (sum(len(a) for a in assignment.values())
                    if (sharded or fleet) else len(assignment))
        print(f"tuned {n_groups} groups"
              + (f" across {engine.num_devices} devices" if sharded else "")
              + (f" across {engine.num_hosts} hosts" if fleet else "")
              + (f" -> {args.plans}" if args.plans else " (not persisted)"))
    elif not (sharded or fleet) and engine.assignment:
        print(f"loaded {len(engine.assignment)} tuned groups from {args.plans}")

    engine.warmup()
    warm = engine.stats.summary()
    # --trace also brackets the serve epochs with the XLA-level profiler
    # (TensorBoard/XProf artifact next to our own Chrome trace) when the
    # running jax exposes one
    profiler = (obs.jax_profile(args.trace + ".xprof")
                if args.trace else contextlib.nullcontext(False))
    with profiler as profiling:
        for _ in range(max(1, args.epochs)):
            results = engine.serve(scenes, flush_every=args.flush_every)

    s = engine.stats.summary()
    print(f"arch={args.arch} buckets={buckets} max_batch={args.max_batch}"
          + (f" devices={engine.num_devices}" if sharded else "")
          + (f" hosts={engine.num_hosts}" if fleet else ""))
    print(f"scenes: {s['scenes']} in {s['batches']} batches "
          f"({s['scenes_per_s']:.1f} scenes/s)")
    print(f"latency: p50 {fmt_ms(s['p50_ms'])}  p95 {fmt_ms(s['p95_ms'])}")
    print(f"jit: {sum(s['recompiles'].values())} executor + "
          f"{sum(s['map_compiles'].values())} map-builder compiles "
          f"across {len(buckets)} buckets "
          f"({sum(warm['recompiles'].values())} during warmup)")
    print(f"map cache: {s['map_cache']['hits']} hits / "
          f"{s['map_cache']['misses']} misses")
    sc = s["scene_tables"]
    strategy = (engine.config.map_strategy or "plan-default" if fleet
                else engine.workers[0].map_strategy if sharded
                else engine.map_strategy)
    print(f"scene store [{strategy}]: "
          f"{sc['hits']} hits / "
          f"{sc['misses']} misses, {sc['composed_batches']} composed batches, "
          f"{sc['delta_merges']} delta merges")
    if sharded:
        for name, d in s["devices"].items():
            print(f"  {name} [{d['device']}]: {d['routed_batches']} batches, "
                  f"{d['scenes']} scenes, p50 {fmt_ms(d['p50_ms'])} "
                  f"p95 {fmt_ms(d['p95_ms'])}, queue_depth {d['queue_depth']}")
    if fleet:
        fl = s["fleet"]
        print(f"fleet: {fl['live']}/{fl['hosts']} hosts live, "
              f"replication={fl['replication']}, "
              f"{fl['failovers']} failovers, "
              f"{fl['rerouted_batches']} rerouted batches, "
              f"{fl['respawns']} respawns")
        for name, h in s["hosts"].items():
            print(f"  {name} [{h['addr']}]"
                  f"{'' if h['alive'] else ' (dead)'}: "
                  f"{h['routed_batches']} batches, {h['scenes']} scenes, "
                  f"weight {h['weight']:.2f}, p50 {fmt_ms(h['p50_ms'])} "
                  f"p95 {fmt_ms(h['p95_ms'])}")
    if s["phases"]:
        print("phases: " + "  ".join(
            f"{name} p50 {fmt_ms(ph['p50_ms'])}"
            for name, ph in s["phases"].items()))
    if s["slo"]["measured"]:
        slo = s["slo"]
        print(f"slo: deadline {slo['deadline_ms']:.1f} ms, "
              f"{slo['misses']}/{slo['measured']} misses "
              f"({100 * slo['miss_rate']:.1f}%), "
              f"{s['deadline_flushes']} deadline flushes")
    out = results[0]
    print(f"sample result: {out.feats.shape[0]} rows x {out.feats.shape[1]} ch "
          f"@ stride {out.stride}")
    if args.trace:
        path = obs.export(obs.get_tracer(), args.trace)
        tr = obs.get_tracer().snapshot()
        print(f"trace: {tr['spans']} spans + {tr['events']} events -> {path}"
              + (f" (+ XLA profile in {args.trace}.xprof/)"
                 if profiling else ""))
    if fleet:
        engine.close()


if __name__ == "__main__":
    main()
