"""Blockwise-attention (XLA path) correctness: causal, window, decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ref import mha_ref
from repro.models.lm_common import chunked_attention, decode_attention


def _rand_qkv(key, b, h, hkv, s, t, d):
    q = jax.random.normal(key, (b, s, h, d)) * 0.5
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, t, hkv, d)) * 0.5
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, t, hkv, d)) * 0.5
    return q, k, v


@pytest.mark.parametrize("chunk", [32, 64, 128])
@pytest.mark.parametrize("g", [1, 4])
def test_chunked_causal_matches_ref(chunk, g):
    b, hkv, s, d = 2, 2, 128, 16
    q, k, v = _rand_qkv(jax.random.PRNGKey(0), b, hkv * g, hkv, s, s, d)
    got = chunked_attention(q, k, v, causal=True, chunk_q=chunk, chunk_k=chunk)
    ref = mha_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                  v.transpose(0, 2, 1, 3), causal=True).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-5)


def test_window_attention_matches_masked_ref():
    b, h, s, d, w = 1, 2, 128, 16, 24
    q, k, v = _rand_qkv(jax.random.PRNGKey(1), b, h, h, s, s, d)
    got = chunked_attention(q, k, v, causal=True, window=w, chunk_q=32, chunk_k=32)
    # reference: full attention with a band mask
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) * d ** -0.5
    pos = jnp.arange(s)
    mask = (pos[None, :] <= pos[:, None]) & (pos[None, :] > pos[:, None] - w)
    logits = jnp.where(mask[None, None], logits, -1e30)
    ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(logits, -1), v)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-5)


def test_decode_matches_prefix_of_full_attention():
    b, h, s, d = 2, 4, 64, 16
    q, k, v = _rand_qkv(jax.random.PRNGKey(2), b, h, h, s, s, d)
    full = chunked_attention(q, k, v, causal=True, chunk_q=64, chunk_k=64)
    # decode the token at position p given cache of length p+1
    for p in (0, 13, 63):
        cache_len = jnp.asarray(p + 1, jnp.int32)
        got = decode_attention(q[:, p], k, v, cache_len)
        np.testing.assert_allclose(got, full[:, p], rtol=1e-4, atol=1e-5)


def test_decode_window_limits_context():
    b, h, s, d, w = 1, 2, 64, 8, 8
    q, k, v = _rand_qkv(jax.random.PRNGKey(3), b, h, h, s, s, d)
    got = decode_attention(q[:, -1], k, v, jnp.asarray(s), window=w)
    # only the last w entries should matter
    k2 = k.at[:, : s - w].set(999.0)
    v2 = v.at[:, : s - w].set(999.0)
    got2 = decode_attention(q[:, -1], k2, v2, jnp.asarray(s), window=w)
    np.testing.assert_allclose(got, got2, rtol=1e-5, atol=1e-6)


def test_prefill_offset_semantics():
    """q_offset shifts causal alignment (chunked prefill continuation)."""
    b, h, s, d = 1, 1, 64, 8
    q, k, v = _rand_qkv(jax.random.PRNGKey(4), b, h, h, s, s, d)
    # full pass in one go
    full = chunked_attention(q, k, v, causal=True, chunk_q=32, chunk_k=32)
    # second half processed separately against the whole kv with offset
    half = chunked_attention(q[:, 32:], k, v, causal=True, chunk_q=32,
                             chunk_k=32, q_offset=32)
    np.testing.assert_allclose(half, full[:, 32:], rtol=1e-4, atol=1e-5)


def test_exact_causal_matches_masked_scan():
    b, h, s, d = 2, 2, 128, 16
    q, k, v = _rand_qkv(jax.random.PRNGKey(5), b, h, h, s, s, d)
    base = chunked_attention(q, k, v, causal=True, chunk_q=32, chunk_k=32)
    fast = chunked_attention(q, k, v, causal=True, chunk_q=32, chunk_k=32,
                             exact_causal=True)
    np.testing.assert_allclose(fast, base, rtol=1e-4, atol=1e-5)
