"""Decoder-only transformer covering the dense / MoE / VLM / audio families.

One implementation parameterized by ArchConfig:
* layer stack = `lax.scan` over stacked params (+ jax.checkpoint remat);
* GQA attention with RoPE, optional QKV bias, optional sliding window;
* SwiGLU or GELU MLP, or MoE (models/moe.py — the paper-technique carryover);
* VLM: groups of `cross_every` self layers followed by one gated cross-attn
  layer over precomputed image-patch embeddings (frontend stub);
* audio (musicgen): frontend stub feeds frame embeddings directly
  (`embed_input=False`), backbone is the standard decoder.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import moe as moe_mod
from repro.models.lm_common import (ArchConfig, NO_SHARD, ShardCtx, _rand, xscan,
                                    apply_norm, attn_apply, attn_init,
                                    attn_qkv, chunked_attention, chunked_xent,
                                    decode_attention, embed_init, init_norm,
                                    mlp_apply, mlp_init, rope, unembed_matrix)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _layer_init(cfg: ArchConfig, key, dtype):
    k1, k2 = jax.random.split(key)
    p = {"norm1": init_norm(cfg, cfg.d_model, dtype),
         "attn": attn_init(cfg, k1, dtype),
         "norm2": init_norm(cfg, cfg.d_model, dtype)}
    if cfg.moe is not None:
        p["moe"] = moe_mod.moe_init(cfg, k2, dtype)
    else:
        p["mlp"] = mlp_init(cfg, k2, dtype)
    return p


def _cross_layer_init(cfg: ArchConfig, key, dtype):
    k1, k2 = jax.random.split(key)
    return {"norm1": init_norm(cfg, cfg.d_model, dtype),
            "attn": attn_init(cfg, k1, dtype),
            "norm2": init_norm(cfg, cfg.d_model, dtype),
            "mlp": mlp_init(cfg, k2, dtype),
            "gate_attn": jnp.zeros((), dtype),
            "gate_mlp": jnp.zeros((), dtype)}


def init_params(cfg: ArchConfig, key) -> dict:
    dtype = cfg.jdtype
    ke, kl, kf = jax.random.split(key, 3)
    params = {}
    if cfg.embed_input:
        params.update(embed_init(cfg, ke, dtype))
    else:
        params["unembed"] = _rand(ke, (cfg.d_model, cfg.vocab), dtype)
    params["final_norm"] = init_norm(cfg, cfg.d_model, dtype)

    if cfg.cross_every:
        g = cfg.n_layers // (cfg.cross_every + 1)
        n_self = g * cfg.cross_every
        self_keys = jax.random.split(kl, n_self)
        cross_keys = jax.random.split(kf, g)
        self_p = jax.vmap(lambda k: _layer_init(cfg, k, dtype))(self_keys)
        # regroup (n_self, ...) → (g, cross_every, ...)
        self_p = jax.tree.map(lambda x: x.reshape((g, cfg.cross_every) + x.shape[1:]), self_p)
        cross_p = jax.vmap(lambda k: _cross_layer_init(cfg, k, dtype))(cross_keys)
        params["self_layers"] = self_p
        params["cross_layers"] = cross_p
    else:
        keys = jax.random.split(kl, cfg.n_layers)
        params["layers"] = jax.vmap(lambda k: _layer_init(cfg, k, dtype))(keys)
    return params


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _self_block(cfg: ArchConfig, lp, x, positions, ctx: ShardCtx):
    h = apply_norm(cfg, x, lp["norm1"])
    x = x + attn_apply(cfg, lp["attn"], h, positions, ctx)
    h2 = apply_norm(cfg, x, lp["norm2"])
    if cfg.moe is not None:
        x = x + moe_mod.moe_apply(cfg, lp["moe"], h2, ctx)
    else:
        x = x + mlp_apply(cfg, lp["mlp"], h2, ctx)
    return ctx.cons(x, ctx.b, None, None)


def _cross_block(cfg: ArchConfig, lp, x, img_kv, ctx: ShardCtx):
    """Gated cross-attention layer (llama-3.2-vision style)."""
    b, s, _ = x.shape
    h = apply_norm(cfg, x, lp["norm1"])
    q, _, _ = attn_qkv(cfg, lp["attn"], h, jnp.arange(s), ctx, use_rope=False)
    k, v = img_kv
    o = chunked_attention(q, k, v, causal=False, chunk_q=min(cfg.attn_chunk, s),
                          chunk_k=k.shape[1])
    o = o.reshape(b, s, -1) @ lp["attn"]["wo"]
    x = x + jnp.tanh(lp["gate_attn"]).astype(x.dtype) * o
    h2 = apply_norm(cfg, x, lp["norm2"])
    x = x + jnp.tanh(lp["gate_mlp"]).astype(x.dtype) * mlp_apply(cfg, lp["mlp"], h2, ctx)
    return ctx.cons(x, ctx.b, None, None)


def _img_kv(cfg: ArchConfig, lp, img_emb, ctx: ShardCtx):
    """Precompute cross-attn K/V from the (stubbed) image embeddings."""
    b, n, _ = img_emb.shape
    hkv, hd = cfg.kv_heads, cfg.hd
    k = (img_emb @ lp["attn"]["wk"]).reshape(b, n, hkv, hd)
    v = (img_emb @ lp["attn"]["wv"]).reshape(b, n, hkv, hd)
    if cfg.qkv_bias:
        k = k + lp["attn"]["bk"].reshape(hkv, hd)
        v = v + lp["attn"]["bv"].reshape(hkv, hd)
    return k, v


def forward_hidden(cfg: ArchConfig, params, tokens_or_embeds, ctx: ShardCtx = NO_SHARD,
                   img_emb: Optional[jax.Array] = None) -> jax.Array:
    if cfg.embed_input:
        x = params["embed"][tokens_or_embeds]
    else:
        x = tokens_or_embeds.astype(cfg.jdtype)
    x = ctx.cons(x, ctx.b, None, None)
    b, s, _ = x.shape
    positions = jnp.arange(s)

    if cfg.cross_every:
        def group_body(x, gp):
            sp, cp = gp

            def self_body(x, lp):
                return jax.checkpoint(partial(_self_block, cfg, ctx=ctx))(lp, x, positions), None

            x, _ = xscan(self_body, x, sp)
            kv = _img_kv(cfg, cp, img_emb, ctx)
            x = jax.checkpoint(partial(_cross_block, cfg, ctx=ctx))(cp, x, kv)
            return x, None

        x, _ = xscan(group_body, x, (params["self_layers"], params["cross_layers"]))
    else:
        def body(x, lp):
            return jax.checkpoint(partial(_self_block, cfg, ctx=ctx))(lp, x, positions), None

        x, _ = xscan(body, x, params["layers"])
    return apply_norm(cfg, x, params["final_norm"])


def loss_fn(cfg: ArchConfig, params, batch, ctx: ShardCtx = NO_SHARD) -> jax.Array:
    inputs = batch["embeds"] if not cfg.embed_input else batch["tokens"]
    h = forward_hidden(cfg, params, inputs, ctx, img_emb=batch.get("img_emb"))
    return chunked_xent(cfg, params, h, batch["labels"], ctx)


# ---------------------------------------------------------------------------
# Serving: prefill + single-token decode with a KV cache
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or cfg.jdtype
    hkv, hd = cfg.kv_heads, cfg.hd
    if cfg.cross_every:
        g = cfg.n_layers // (cfg.cross_every + 1)
        n_self = g * cfg.cross_every
        return {"k": jnp.zeros((n_self, batch, max_len, hkv, hd), dtype),
                "v": jnp.zeros((n_self, batch, max_len, hkv, hd), dtype),
                "img_k": jnp.zeros((g, batch, cfg.n_img_tokens, hkv, hd), dtype),
                "img_v": jnp.zeros((g, batch, cfg.n_img_tokens, hkv, hd), dtype),
                "pos": jnp.zeros((), jnp.int32)}
    return {"k": jnp.zeros((cfg.n_layers, batch, max_len, hkv, hd), dtype),
            "v": jnp.zeros((cfg.n_layers, batch, max_len, hkv, hd), dtype),
            "pos": jnp.zeros((), jnp.int32)}


def prefill(cfg: ArchConfig, params, tokens_or_embeds, cache,
            ctx: ShardCtx = NO_SHARD, img_emb=None):
    """Run the full prompt, fill the cache, return last-token logits."""
    if cfg.embed_input:
        x = params["embed"][tokens_or_embeds]
    else:
        x = tokens_or_embeds.astype(cfg.jdtype)
    x = ctx.cons(x, ctx.b, None, None)
    b, s, _ = x.shape
    positions = jnp.arange(s)
    max_len = cache["k"].shape[2]

    def attn_and_cache(lp, x):
        h = apply_norm(cfg, x, lp["norm1"])
        q, k, v = attn_qkv(cfg, lp["attn"], h, positions, ctx)
        o = chunked_attention(q, k, v, causal=True, window=cfg.sliding_window,
                              chunk_q=min(cfg.attn_chunk, s), chunk_k=min(cfg.attn_chunk, s),
                              exact_causal=cfg.attn_exact_causal)
        x = x + o.reshape(b, s, -1) @ lp["attn"]["wo"]
        kc = jnp.zeros((b, max_len) + k.shape[2:], k.dtype)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k, 0, 1)
        vc = jnp.zeros((b, max_len) + v.shape[2:], v.dtype)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v, 0, 1)
        return x, kc, vc

    if cfg.cross_every:
        def group_body(x, gp):
            sp, cp = gp

            def self_body(x, lp):
                x, kc, vc = attn_and_cache(lp, x)
                h2 = apply_norm(cfg, x, lp["norm2"])
                x = x + mlp_apply(cfg, lp["mlp"], h2, ctx)
                return ctx.cons(x, ctx.b, None, None), (kc, vc)

            x, (kcs, vcs) = xscan(self_body, x, sp)
            ik, iv = _img_kv(cfg, cp, img_emb, ctx)
            x = _cross_block(cfg, cp, x, (ik, iv), ctx)
            return x, (kcs, vcs, ik, iv)

        x, (kc, vc, ik, iv) = xscan(group_body, x, (params["self_layers"], params["cross_layers"]))
        cache = dict(cache, k=kc.reshape((-1,) + kc.shape[2:]),
                     v=vc.reshape((-1,) + vc.shape[2:]),
                     img_k=ik, img_v=iv, pos=jnp.asarray(s, jnp.int32))
    else:
        def body(x, lp):
            x, kc, vc = attn_and_cache(lp, x)
            h2 = apply_norm(cfg, x, lp["norm2"])
            if cfg.moe is not None:
                x = x + moe_mod.moe_apply(cfg, lp["moe"], h2, ctx)
            else:
                x = x + mlp_apply(cfg, lp["mlp"], h2, ctx)
            return ctx.cons(x, ctx.b, None, None), (kc, vc)

        x, (kc, vc) = xscan(body, x, params["layers"])
        cache = dict(cache, k=kc, v=vc, pos=jnp.asarray(s, jnp.int32))

    h = apply_norm(cfg, x[:, -1], params["final_norm"])
    logits = (h @ unembed_matrix(cfg, params)).astype(jnp.float32)
    return logits, cache


def decode_step(cfg: ArchConfig, params, cache, token, ctx: ShardCtx = NO_SHARD):
    """One decode step. token: (B,) int32 (or (B, d) embeds for audio)."""
    if cfg.embed_input:
        x = params["embed"][token]                      # (B, d)
    else:
        x = token.astype(cfg.jdtype)
    pos = cache["pos"]
    b = x.shape[0]
    x = x[:, None, :]                                   # (B, 1, d)
    hkv, hd = cfg.kv_heads, cfg.hd

    def attn_one(lp, x, kc, vc):
        h = apply_norm(cfg, x, lp["norm1"])
        q, k, v = attn_qkv(cfg, lp["attn"], h, pos[None], ctx)
        kc = jax.lax.dynamic_update_slice(kc, k, (0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, pos, 0, 0))
        o = decode_attention(q[:, 0], kc, vc, pos + 1, window=cfg.sliding_window)
        x = x + (o.reshape(b, -1) @ lp["attn"]["wo"])[:, None]
        return x, kc, vc

    if cfg.cross_every:
        def group_body(x, gp):
            sp, cp, kcs, vcs, ik, iv = gp

            def self_body(x, xs):
                lp, kc, vc = xs
                x, kc, vc = attn_one(lp, x, kc, vc)
                h2 = apply_norm(cfg, x, lp["norm2"])
                x = x + mlp_apply(cfg, lp["mlp"], h2, ctx)
                return x, (kc, vc)

            x, (kcs, vcs) = xscan(self_body, x, (sp, kcs, vcs))
            x = _cross_block(cfg, cp, x, (ik, iv), ctx)
            return x, (kcs, vcs)

        g = params["cross_layers"]["gate_attn"].shape[0]
        kc = cache["k"].reshape((g, cfg.cross_every) + cache["k"].shape[1:])
        vc = cache["v"].reshape((g, cfg.cross_every) + cache["v"].shape[1:])
        x, (kc, vc) = xscan(group_body, x,
                                   (params["self_layers"], params["cross_layers"],
                                    kc, vc, cache["img_k"], cache["img_v"]))
        cache = dict(cache, k=kc.reshape((-1,) + kc.shape[2:]),
                     v=vc.reshape((-1,) + vc.shape[2:]), pos=pos + 1)
    else:
        def body(x, xs):
            lp, kc, vc = xs
            x, kc, vc = attn_one(lp, x, kc, vc)
            h2 = apply_norm(cfg, x, lp["norm2"])
            if cfg.moe is not None:
                x = x + moe_mod.moe_apply(cfg, lp["moe"], h2, ctx)
            else:
                x = x + mlp_apply(cfg, lp["mlp"], h2, ctx)
            return ctx.cons(x, ctx.b, None, None), (kc, vc)

        x, (kc, vc) = xscan(body, x, (params["layers"], cache["k"], cache["v"]))
        cache = dict(cache, k=kc, v=vc, pos=pos + 1)

    h = apply_norm(cfg, x[:, 0], params["final_norm"])
    logits = (h @ unembed_matrix(cfg, params)).astype(jnp.float32)
    return logits, cache
