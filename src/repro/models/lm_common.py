"""Shared LM building blocks: configs, norms, RoPE, chunked attention,
MLPs, embeddings, chunked loss, and sharding-constraint helpers.

All modules are pure functions over explicit param pytrees (no flax).  Layer
stacks are `lax.scan`s over stacked params so the HLO (and compile time) is
O(1) in depth — essential for the 100-layer dry-run cells on one CPU core.
"""
from __future__ import annotations

import dataclasses
import os
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def xscan(body, init, xs, length=None):
    """lax.scan wrapper honoring REPRO_SCAN_UNROLL.

    XLA's cost_analysis counts a while-loop body ONCE regardless of trip
    count, which silently under-reports FLOPs/bytes of layer-scanned models
    by ~L×.  The dry-run's roofline accounting pass sets
    REPRO_SCAN_UNROLL=full on reduced-depth configs so every scan unrolls and
    the counts are exact (launch/dryrun.py --roofline)."""
    mode = os.environ.get("REPRO_SCAN_UNROLL", "")
    kw = {}
    if mode == "full":
        kw["unroll"] = True
    elif mode:
        kw["unroll"] = int(mode)
    return jax.lax.scan(body, init, xs, length=length, **kw)


# ---------------------------------------------------------------------------
# Sharding helper: constraints are no-ops without a mesh (CPU unit tests).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Axis names of the active mesh; None mesh disables all constraints."""

    mesh: Optional[object] = None          # jax.sharding.Mesh
    batch: Tuple[str, ...] = ("data",)     # ('pod','data') when multi-pod
    model: Optional[str] = "model"
    model_size: int = 1                    # devices along the model axis
    fsdp: bool = False                     # additionally shard params on batch axes

    def cons(self, x, *spec):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, P(*spec)))

    @property
    def b(self):   # batch partition entry
        return self.batch if len(self.batch) > 1 else self.batch[0]

    @property
    def m(self):
        return self.model

    def heads(self, n: int):
        """Model-axis entry for a head-count dim (only if evenly divisible)."""
        return self.model if (self.model and n % max(self.model_size, 1) == 0) else None


NO_SHARD = ShardCtx(mesh=None)


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    shard_experts: bool = True   # EP over model axis (False ⇒ TP inside expert)
    # token→expert dispatch dataflow (the Sparse Autotuner choice at scale):
    #   gspmd_sort      — global sort-based gather-GEMM-scatter (paper-faithful)
    #   local_shardmap  — shard_map-local masked dispatch (beyond-paper, §Perf)
    dispatch: str = "gspmd_sort"


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int = 16
    expand: int = 2
    conv_kernel: int = 4
    dt_rank: int = 0          # 0 ⇒ d_model // 16 (mamba1 only)
    head_dim: int = 64        # mamba2 only
    version: int = 1          # 1 = mamba1 (falcon-mamba), 2 = mamba2/SSD
    chunk: int = 128
    # §Perf beyond-paper switch: keep the O(Q²) intra-chunk SSD tensors in
    # bf16 (cumsums/state flow stay f32) — halves the dominant HBM traffic.
    bf16_scores: bool = False
    # Use the fused Pallas SSD kernel (kernels/ssd_chunk) instead of the XLA
    # chunked path — the TPU deployment hot-swap (interpret-mode on CPU).
    use_pallas_kernel: bool = False


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0               # 0 ⇒ d_model // n_heads
    norm: str = "rms"               # rms | ln | nonparam
    mlp: str = "swiglu"             # swiglu | gelu
    qkv_bias: bool = False
    mlp_bias: bool = False
    rope_theta: float = 10000.0
    sliding_window: int = 0         # 0 = full attention
    tie_embeddings: bool = False
    moe: Optional[MoECfg] = None
    ssm: Optional[SSMCfg] = None
    # hybrid (zamba2): shared attention block every `attn_every` ssm blocks
    attn_every: int = 0
    # vlm (llama-3.2-vision): one cross-attn layer after every `cross_every`
    # self-attn layers; n_img_tokens precomputed patch embeddings per sample
    cross_every: int = 0
    n_img_tokens: int = 0
    # audio (musicgen): frontend stub feeds embeddings directly
    embed_input: bool = True        # False ⇒ input_specs provide (B, S, d) embeddings
    sub_quadratic: bool = False     # long_500k eligibility
    dtype: str = "bfloat16"
    attn_chunk: int = 1024          # kv chunk for blockwise attention
    loss_chunk: int = 512           # seq chunk for big-vocab loss
    # §Perf beyond-paper switches (False = paper-faithful baseline):
    # exact-causal chunking drops fully-masked KV blocks (≈2× attention
    # FLOPs/bytes at long S) and runs the P·V matmul in the activation dtype.
    attn_exact_causal: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def params_count(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS = 6·N·D in the roofline)."""
        d, v, L = self.d_model, self.vocab, self.n_layers
        n = v * d * (1 if self.tie_embeddings else 2)
        if self.family in ("ssm",):
            d_in = self.ssm.expand * d
            per = (d * 2 * d_in                  # in_proj (x, z)
                   + d_in * self.ssm.conv_kernel
                   + d_in * ((self.ssm.dt_rank or d // 16) + 2 * self.ssm.d_state)
                   + (self.ssm.dt_rank or d // 16) * d_in
                   + d_in * self.ssm.d_state + d_in   # A_log, D
                   + d_in * d)                   # out_proj
            return n + L * per
        att = d * (self.n_heads * self.hd) + 2 * d * (self.kv_heads * self.hd) \
            + (self.n_heads * self.hd) * d
        if self.moe is not None:
            ff = self.moe.n_experts * 3 * d * self.moe.d_ff_expert + d * self.moe.n_experts
            ff_active = self.moe.top_k * 3 * d * self.moe.d_ff_expert + d * self.moe.n_experts
        elif self.mlp == "swiglu":
            ff = ff_active = 3 * d * self.d_ff
        else:
            ff = ff_active = 2 * d * self.d_ff
        if self.family == "hybrid":
            # zamba2: L mamba2 blocks + one shared attention block
            d_in = self.ssm.expand * d
            nh = d_in // self.ssm.head_dim
            per = (d * 2 * d_in + d_in * self.ssm.conv_kernel
                   + d_in * 2 * self.ssm.d_state + nh * 2 + d_in * d)
            return n + L * per + (att + ff)
        total = n + L * (att + ff)
        if self.cross_every:
            n_cross = self.n_layers // (self.cross_every + 1)
            n_self = self.n_layers - n_cross
            total = n + n_self * (att + ff) + n_cross * (att + ff)
        return total

    def active_params_count(self) -> int:
        if self.moe is None:
            return self.params_count()
        d, L = self.d_model, self.n_layers
        att = d * (self.n_heads * self.hd) + 2 * d * (self.kv_heads * self.hd) \
            + (self.n_heads * self.hd) * d
        ff_active = self.moe.top_k * 3 * d * self.moe.d_ff_expert + d * self.moe.n_experts
        return self.vocab * d * 2 + L * (att + ff_active)


# ---------------------------------------------------------------------------
# Primitives
# ---------------------------------------------------------------------------

def rms_norm(x, scale=None, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    if scale is not None:
        y = y * scale.astype(jnp.float32)
    return y.astype(x.dtype)


def layer_norm(x, scale=None, bias=None, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    if scale is not None:
        y = y * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(x.dtype)


def apply_norm(cfg: ArchConfig, x, p):
    if cfg.norm == "rms":
        return rms_norm(x, p["scale"])
    if cfg.norm == "ln":
        return layer_norm(x, p["scale"], p["bias"])
    return layer_norm(x, None, None)     # olmo non-parametric LN


def init_norm(cfg: ArchConfig, d: int, dtype):
    if cfg.norm == "rms":
        return {"scale": jnp.ones((d,), dtype)}
    if cfg.norm == "ln":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    return {}


def rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs          # (..., S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Blockwise attention (XLA path; Pallas flash kernel is the TPU hot swap)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def chunked_attention(q, k, v, *, causal=True, window: int = 0,
                      chunk_q: int = 1024, chunk_k: int = 1024,
                      q_offset=0, exact_causal: bool = False) -> jax.Array:
    """Online-softmax blockwise attention.

    q: (B, S, H, hd); k/v: (B, T, Hkv, hd).  GQA via head folding.
    q_offset: absolute position of q[0] relative to k[0] (prefill: T - S).
    window > 0: sliding-window; only the needed kv slab is gathered per q
    chunk, so compute is O(S·window) not O(S·T).
    """
    b, s, h, hd = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    chunk_q = min(chunk_q, s)
    assert s % chunk_q == 0
    scale = hd ** -0.5
    qg = q.reshape(b, s, hkv, g, hd)

    if window:
        # pad kv on the left so every q chunk sees a fixed-size slab
        slab = ((window + chunk_q - 1) // chunk_k + 1) * chunk_k
        kp = jnp.pad(k, ((0, 0), (slab, 0), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (slab, 0), (0, 0), (0, 0)))

        def one_chunk(i):
            q_blk = jax.lax.dynamic_slice_in_dim(qg, i * chunk_q, chunk_q, 1)
            q_pos = q_offset + i * chunk_q + jnp.arange(chunk_q)
            start = i * chunk_q + q_offset + chunk_q - slab + slab  # in padded coords
            k_blk = jax.lax.dynamic_slice_in_dim(kp, start, slab, 1)
            v_blk = jax.lax.dynamic_slice_in_dim(vp, start, slab, 1)
            k_pos = i * chunk_q + q_offset + chunk_q - slab + jnp.arange(slab)
            logits = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk.astype(jnp.float32),
                                k_blk.astype(jnp.float32)) * scale
            mask = (k_pos[None, :] <= q_pos[:, None]) & \
                   (k_pos[None, :] > q_pos[:, None] - window) & (k_pos[None, :] >= 0)
            logits = jnp.where(mask[None, None, None], logits, NEG_INF)
            p = jax.nn.softmax(logits, axis=-1)
            o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v_blk.astype(jnp.float32))
            return o.astype(q.dtype)

        outs = [one_chunk(i) for i in range(s // chunk_q)]
        return jnp.concatenate(outs, axis=1).reshape(b, s, h, hd)

    if exact_causal and causal and q_offset == 0 and s == t:
        # §Perf: python-unrolled q chunks with *static* kv prefixes — no
        # compute or traffic on fully-masked blocks, and the P·V matmul runs
        # in the activation dtype (softmax stats stay f32).
        nq = s // chunk_q
        outs = []
        for i in range(nq):
            hi = (i + 1) * chunk_q
            q_blk = qg[:, i * chunk_q:hi].astype(jnp.float32)
            k_blk = k[:, :hi].astype(jnp.float32)
            logits = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk, k_blk) * scale
            rows = i * chunk_q + jnp.arange(chunk_q)
            cols = jnp.arange(hi)
            logits = jnp.where((cols[None, :] <= rows[:, None])[None, None, None],
                               logits, NEG_INF)
            p = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
            outs.append(jnp.einsum("bhgqk,bkhd->bqhgd", p, v[:, :hi]).astype(q.dtype))
        return jnp.concatenate(outs, axis=1).reshape(b, s, h, hd)

    chunk_k = min(chunk_k, t)
    assert t % chunk_k == 0
    nq, nk = s // chunk_q, t // chunk_k

    def q_body(_, iq):
        q_blk = jax.lax.dynamic_slice_in_dim(qg, iq * chunk_q, chunk_q, 1).astype(jnp.float32)
        q_pos = q_offset + iq * chunk_q + jnp.arange(chunk_q)

        def kv_body(carry, ik):
            m_prev, l_prev, acc = carry
            k_blk = jax.lax.dynamic_slice_in_dim(k, ik * chunk_k, chunk_k, 1).astype(jnp.float32)
            v_blk = jax.lax.dynamic_slice_in_dim(v, ik * chunk_k, chunk_k, 1).astype(jnp.float32)
            logits = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk, k_blk) * scale
            if causal:
                k_pos = ik * chunk_k + jnp.arange(chunk_k)
                mask = k_pos[None, :] <= q_pos[:, None]
                logits = jnp.where(mask[None, None, None], logits, NEG_INF)
            m_new = jnp.maximum(m_prev, logits.max(axis=-1))
            alpha = jnp.exp(m_prev - m_new)
            pl_ = jnp.exp(logits - m_new[..., None])
            l_new = l_prev * alpha + pl_.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum("bhgqk,bkhd->bhgqd", pl_, v_blk)
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, hkv, g, chunk_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, chunk_q), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, chunk_q, hd), jnp.float32)
        (m, l, acc), _ = xscan(kv_body, (m0, l0, a0), jnp.arange(nk))
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, o.astype(q.dtype)

    _, outs = xscan(q_body, None, jnp.arange(nq))
    # outs: (nq, b, hkv, g, chunk_q, hd) → (b, s, h, hd)
    outs = jnp.moveaxis(outs, 0, 3)                    # b,hkv,g,nq,cq,hd
    return outs.reshape(b, hkv, g, s, hd).transpose(0, 3, 1, 2, 4).reshape(b, s, h, hd)


def decode_attention(q, k_cache, v_cache, cache_len, *, window: int = 0):
    """Single-token attention over a cache.

    q: (B, H, hd); caches: (B, T, Hkv, hd); cache_len: () int32 — number of
    valid cache entries *including* the token just written."""
    b, h, hd = q.shape
    t, hkv = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    qg = q.reshape(b, hkv, g, hd).astype(jnp.float32)
    logits = jnp.einsum("bhgd,bthd->bhgt", qg, k_cache.astype(jnp.float32)) * hd ** -0.5
    pos = jnp.arange(t)
    mask = pos[None] < cache_len
    if window:
        mask = mask & (pos[None] >= cache_len - window)
    logits = jnp.where(mask[:, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhgt,bthd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(b, h, hd).astype(k_cache.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_apply(cfg: ArchConfig, p, x, ctx: ShardCtx):
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
        h = ctx.cons(h, *( [ctx.b] + [None]*(x.ndim-2) + [ctx.m] ))
        return h @ p["w_down"]
    h = x @ p["w_up"]
    if "b_up" in p:
        h = h + p["b_up"]
    h = jax.nn.gelu(h)
    h = ctx.cons(h, *( [ctx.b] + [None]*(x.ndim-2) + [ctx.m] ))
    y = h @ p["w_down"]
    if "b_down" in p:
        y = y + p["b_down"]
    return y


def mlp_init(cfg: ArchConfig, key, dtype):
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.mlp == "swiglu":
        return {"w_gate": _rand(k1, (d, f), dtype),
                "w_up": _rand(k2, (d, f), dtype),
                "w_down": _rand(k3, (f, d), dtype)}
    p = {"w_up": _rand(k1, (d, f), dtype), "w_down": _rand(k2, (f, d), dtype)}
    if cfg.mlp_bias:
        p["b_up"] = jnp.zeros((f,), dtype)
        p["b_down"] = jnp.zeros((d,), dtype)
    return p


def _rand(key, shape, dtype, scale=None):
    scale = scale if scale is not None else shape[0] ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Attention block (projections + rope + blockwise attention)
# ---------------------------------------------------------------------------

def attn_init(cfg: ArchConfig, key, dtype, d_model: int = 0):
    d = d_model or cfg.d_model
    h, hkv, hd = cfg.n_heads, cfg.kv_heads, cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {"wq": _rand(k1, (d, h * hd), dtype),
         "wk": _rand(k2, (d, hkv * hd), dtype),
         "wv": _rand(k3, (d, hkv * hd), dtype),
         "wo": _rand(k4, (h * hd, d), dtype)}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    return p


def attn_qkv(cfg: ArchConfig, p, x, positions, ctx: ShardCtx, use_rope=True):
    b, s, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.kv_heads, cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, hkv, hd)
    v = v.reshape(b, s, hkv, hd)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = ctx.cons(q, ctx.b, None, ctx.heads(h), None)
    k = ctx.cons(k, ctx.b, None, ctx.heads(hkv), None)
    v = ctx.cons(v, ctx.b, None, ctx.heads(hkv), None)
    return q, k, v


def attn_apply(cfg: ArchConfig, p, x, positions, ctx: ShardCtx,
               chunk: int = 0) -> jax.Array:
    b, s, _ = x.shape
    q, k, v = attn_qkv(cfg, p, x, positions, ctx)
    chunk = chunk or cfg.attn_chunk
    o = chunked_attention(q, k, v, causal=True, window=cfg.sliding_window,
                          chunk_q=min(chunk, s), chunk_k=min(chunk, s),
                          exact_causal=cfg.attn_exact_causal)
    o = o.reshape(b, s, -1)
    return o @ p["wo"]


# ---------------------------------------------------------------------------
# Embedding + chunked loss
# ---------------------------------------------------------------------------

def embed_init(cfg: ArchConfig, key, dtype):
    p = {"embed": _rand(key, (cfg.vocab, cfg.d_model), dtype, scale=1.0)}
    if not cfg.tie_embeddings:
        p["unembed"] = _rand(jax.random.fold_in(key, 1), (cfg.d_model, cfg.vocab), dtype)
    return p


def unembed_matrix(cfg: ArchConfig, p):
    return p["embed"].T if cfg.tie_embeddings else p["unembed"]


_PSPEC_RULES = {
    # name: trailing-dim roles; 'm' = model axis, 'f' = fsdp (batch axes), '.' = replicated
    "embed": "mf", "unembed": "fm",
    "wq": "fm", "wk": "fm", "wv": "fm", "wo": "mf",
    "w_gate": "fm", "w_up": "fm", "w_down": "mf",
    "router": "f.",
    "in_proj": "fm", "out_proj": "mf", "x_proj": "m.", "dt_w": ".m",
    "conv_w": "m.", "conv_b": "m", "A_log": "m.", "A_log2": "m", "D": "m",
    "dt_b": "m", "dt_b2": "m",
}
_EXPERT_RULES = {  # (E, d, f) tensors under a 'moe' subtree
    True: {"w_gate": "mf.", "w_up": "mf.", "w_down": "m.f"},   # EP on experts
    False: {"w_gate": ".fm", "w_up": ".fm", "w_down": ".mf"},  # TP inside expert
}


def make_pspecs(params, ctx: ShardCtx, expert_sharded: bool = True):
    """Partition specs for a param tree by leaf-name rules.  Leading stack
    dims (layers/groups) are replicated; model-axis entries are dropped when
    the dim is not divisible by the mesh's model-axis size."""
    def spec_for(path, leaf):
        keys = [k.key for k in path if hasattr(k, "key")]
        name = keys[-1]
        roles = _PSPEC_RULES.get(name)
        if any(k == "moe" for k in keys) and name in _EXPERT_RULES[True]:
            roles = _EXPERT_RULES[expert_sharded][name]
        if roles is None:
            return P()
        shape = leaf.shape
        trailing = len(roles)
        entries = [None] * (len(shape) - trailing)
        for i, r in enumerate(roles):
            dim = shape[len(shape) - trailing + i]
            if r == "m" and ctx.model and dim % max(ctx.model_size, 1) == 0:
                entries.append(ctx.model)
            elif r == "f" and ctx.fsdp and dim % _axes_size(ctx) == 0:
                entries.append(ctx.b)
            else:
                entries.append(None)
        return P(*entries)

    return jax.tree_util.tree_map_with_path(spec_for, params)


def _axes_size(ctx: ShardCtx) -> int:
    if ctx.mesh is None:
        return 1
    n = 1
    for a in ctx.batch:
        n *= ctx.mesh.shape[a]
    return n


def chunked_xent(cfg: ArchConfig, p, h, labels, ctx: ShardCtx) -> jax.Array:
    """Cross-entropy with the (B, chunk, V) logits materialized one sequence
    chunk at a time (vocab 164k × 1M tokens never exists at once)."""
    b, s, d = h.shape
    w = unembed_matrix(cfg, p)
    c = min(cfg.loss_chunk, s)
    assert s % c == 0

    def body(acc, i):
        hc = jax.lax.dynamic_slice_in_dim(h, i * c, c, 1)
        yc = jax.lax.dynamic_slice_in_dim(labels, i * c, c, 1)
        logits = (hc @ w).astype(jnp.float32)
        logits = ctx.cons(logits, ctx.b, None, ctx.m)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(lse - gold), None

    tot, _ = xscan(body, jnp.zeros((), jnp.float32), jnp.arange(s // c))
    return tot / (b * s)
