"""R-GCN on the sparse-conv dataflow engine vs a dense reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dataflows as df
from repro.core.graph_conv import edges_to_kmap, rgcn_layer
from repro.data.synthetic import typed_graph


def _dense_rgcn(feats, w_rel, w_self, src, dst, etype, n_nodes, normalize=True):
    out = feats @ w_self
    r = w_rel.shape[0]
    deg = np.ones((r, n_nodes))
    srcn, dstn, etn = map(np.asarray, (src, dst, etype))
    if normalize:
        for s, d, e in zip(srcn, dstn, etn):
            deg[e, d] += 1
        deg = np.maximum(deg - 1, 1)
    acc = np.zeros((n_nodes, w_rel.shape[-1]))
    msgs = np.asarray(feats) @ np.asarray(w_rel)     # (R, N, C)
    for s, d, e in zip(srcn, dstn, etn):
        acc[d] += msgs[e, s] / (deg[e, d] if normalize else 1.0)
    return np.asarray(out) + acc


@pytest.mark.parametrize("normalize", [True, False])
def test_rgcn_matches_dense(normalize):
    n_nodes, n_edges, r, c = 32, 100, 3, 8
    src, dst, etype = typed_graph(jax.random.PRNGKey(0), n_nodes, n_edges, r)
    feats = jax.random.normal(jax.random.PRNGKey(1), (n_nodes, c))
    w_rel = jax.random.normal(jax.random.PRNGKey(2), (r, c, 16)) * 0.3
    w_self = jax.random.normal(jax.random.PRNGKey(3), (c, 16)) * 0.3
    kmap = edges_to_kmap(src, dst, etype, r, n_nodes, cap_per_rel=n_edges)
    got = rgcn_layer(feats, w_rel, w_self, kmap, normalize=normalize)
    ref = _dense_rgcn(feats, w_rel, w_self, src, dst, etype, n_nodes, normalize)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)


def test_relation_capacity_truncation_is_safe():
    """cap_per_rel smaller than a relation's edge count drops edges, never corrupts."""
    n_nodes = 16
    src = jnp.arange(10, dtype=jnp.int32)
    dst = jnp.zeros(10, jnp.int32)
    etype = jnp.zeros(10, jnp.int32)
    kmap = edges_to_kmap(src, dst, etype, 1, n_nodes, cap_per_rel=4)
    assert int(kmap.ws_count[0]) == 10          # true count reported
    assert int((kmap.ws_in[0] >= 0).sum()) == 4  # but only cap edges kept
    feats = jnp.ones((n_nodes, 2))
    w = jnp.ones((1, 2, 2))
    out = rgcn_layer(feats, w, jnp.zeros((2, 2)), kmap, normalize=False)
    assert bool(jnp.isfinite(out).all())


def test_implicit_gemm_rejected_for_graphs():
    src, dst, etype = typed_graph(jax.random.PRNGKey(0), 8, 16, 2)
    kmap = edges_to_kmap(src, dst, etype, 2, 8, cap_per_rel=16)
    feats = jnp.ones((8, 4))
    w = jnp.ones((2, 4, 4))
    with pytest.raises(AssertionError):
        rgcn_layer(feats, w, jnp.zeros((4, 4)), kmap,
                   cfg=df.DataflowConfig("implicit_gemm"))
