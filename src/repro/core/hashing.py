"""Coordinate hashing and sort-based lookup — int32-only, collision-free.

The paper builds kernel maps with a GPU hash table.  The TPU-idiomatic (and
JAX-native) equivalent is a *sorted binary search*: sort the coordinate table
once per map group and answer all K^D shifted queries with a vectorized
binary search (O(log N) gathers, fully static shapes).  PointAcc (the ASIC
the paper compares against) and Minuet make the same observation —
point-cloud mapping operators reduce to sort/merge primitives.

Packed-key engine (the fast path)
---------------------------------
``CoordTable`` packs each ``(batch, x, y, z)`` row into a single int32 key
(or an ``(hi, lo)`` int32 key pair when the bit budget exceeds one word), so

* table construction is **one** ``argsort`` over scalar keys (two chained
  stable argsorts for the pair case), not one stable argsort per column;
* every binary-search step is a **scalar** compare (pair compare at worst),
  not a 4-word lexicographic compare;
* all K^D shifted queries of a kernel map are answered as one flattened
  batched lookup of shape ``(K^D · N,)``.

Bit budgets are derived from the tensor's *declared* bounds by
``key_spec_for``: ``batch_bits = ceil(log2(batch_bound))`` and, per spatial
axis, ``ceil(log2(spatial_bound + 65)) + 1`` bits — one sign bit plus ≥64
voxels of headroom so strided floor-grids and shifted queries stay
representable.  Spatial fields are biased by ``2^(bits-1)`` (offset binary),
which keeps negative coordinates sort-correct.  Tensors that declare no
bounds (or whose bounds exceed the two-word budget) get the ``raw`` spec:
the key words are the coordinate columns themselves — no range limits, the
seed's multi-word contract — still driven through the batched-lookup,
sort-free-compaction and MapCache machinery.  Packing is order-isomorphic
to the lexicographic order on rows, so packed tables sort and deduplicate
exactly like the multi-word path.

Out-of-range *queries* (e.g. a kernel shift off the edge of the declared
bounds, or the ``INVALID_COORD`` padding sentinel) pack to the ``MISS`` key
(-1), which can never equal a table key; out-of-range or padded *table* rows
pack to ``PAD`` (int32 max), which sorts last.  Everything is int32 (x64
stays disabled framework-wide).

Composable tables (scene-granular and streaming reuse)
------------------------------------------------------
Because the batch index is the *most significant* key field, the sorted key
array of a packed batch is exactly the batch-major concatenation of each
scene's own sorted (batch-0) table with the batch bits added in.  Two O(N)
merge primitives exploit that (Minuet's observation, lifted to first-class
table operations):

* ``compose_tables`` — build a batch table by merge-composing per-scene
  sorted tables (one key-delta add + concatenation per scene; no argsort),
  bit-identical to ``CoordTable.build`` on the packed batch;
* ``CoordTable.delta_merge`` — update a streaming scene's table by merging
  a small sorted insertion/eviction delta instead of re-sorting the full
  cloud, bit-identical to a fresh build of the updated scene.

(``SortedCoords``, the seed's multi-word reference table, and the
``engine="legacy"`` A/B flag in ``kmap.build_kmap`` were deleted after a
release cycle of bit-identical cross-checks; the property tests now verify
against brute-force numpy oracles.  The word-wise helpers below remain —
they serve multi-word packed keys, ``raw`` specs and ``voxelize``.)
"""
from __future__ import annotations

import dataclasses
import math
import os
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_I32_MAX = int(jnp.iinfo(jnp.int32).max)

# Usable bits per key word.  Both words are capped at 30 bits so that no
# valid key word can ever equal the PAD sentinel (int32 max) — with 31
# usable bits a maximal in-field value would pack to exactly int32 max and
# be silently treated as padding.
_LO_BITS = 30
_HI_BITS = 30


@dataclasses.dataclass(frozen=True)
class KeySpec:
    """Static bit budget for packing (batch, *spatial) rows into int32 keys.

    Field layout is MSB→LSB ``batch | x | y | z`` so integer order on keys is
    lexicographic order on rows.  Fields never straddle the word boundary:
    the layout pads a field up to the next word instead (wasting a few bits
    but keeping pack/unpack to one shift+mask per field).

    ``raw=True`` is the no-range-limit fallback: the key "words" are simply
    the coordinate columns themselves (MSB-first: batch, x, y, z), valid for
    the full int32 range — exactly the seed's multi-word table, but still
    driven through the batched-lookup / sort-free-compaction / MapCache
    machinery.  Used when no bounds are declared or the declared bounds
    exceed the two-word bit budget.
    """

    batch_bits: int
    spatial_bits: Tuple[int, ...]
    raw: bool = False

    @property
    def ndim_space(self) -> int:
        return len(self.spatial_bits)

    def _place_fields(self):
        """(placements LSB-first, in_budget) without raising — the budget
        check must hold even under ``python -O`` (no assert reliance)."""
        widths = list(self.spatial_bits)[::-1] + [self.batch_bits]  # LSB first
        placed = []
        cur = 0
        ok = True
        for w in widths:
            ok = ok and 0 < w <= _HI_BITS
            if cur < _LO_BITS and cur + w > _LO_BITS:
                cur = _LO_BITS  # don't straddle the word boundary
            word = 0 if cur < _LO_BITS else 1
            shift = cur if word == 0 else cur - _LO_BITS
            placed.append((word, shift, w))
            cur += w
            ok = ok and (word == 0 or shift + w <= _HI_BITS)
        return placed, ok

    def layout(self) -> Tuple[Tuple[int, int, int], ...]:
        """Per field (MSB-first: batch, x, y, …): (word, shift, width).

        word 0 is the low word (bit offsets 0..29), word 1 the high word
        (offsets 30..59).  Single-word specs place everything in word 0.
        """
        if self.raw:
            raise ValueError("raw specs have no packed layout")
        placed, ok = self._place_fields()
        if not ok:
            raise ValueError(f"KeySpec {self} exceeds the 60-bit two-word budget")
        # back to MSB-first (batch, x, y, z)
        return tuple(placed[::-1])

    def fits(self) -> bool:
        """True iff the budget packs into at most two 30-bit words."""
        return not self.raw and self._place_fields()[1]

    @property
    def words(self) -> int:
        if self.raw:
            return 1 + self.ndim_space
        return 1 + max(w for w, _, _ in self.layout())

    @property
    def total_bits(self) -> int:
        return self.batch_bits + sum(self.spatial_bits)


def key_spec_for(ndim_space: int, batch_bound: int = 0,
                 spatial_bound: int = 0) -> KeySpec:
    """Derive the bit budget from a tensor's declared bounds.

    ``batch_bound``: number of batches (coords in [0, batch_bound)); 0 = unknown.
    ``spatial_bound``: max |spatial coordinate|; 0 = unknown.  Unknown or
    too-large bounds fall back to the ``raw`` coordinate-column spec, which
    has no range limits (and a correspondingly wider sort/compare).
    """
    if batch_bound <= 0 or spatial_bound <= 0:
        return KeySpec(batch_bits=32, spatial_bits=(32,) * ndim_space, raw=True)
    bb = max(1, math.ceil(math.log2(max(batch_bound, 2))))
    sb = math.ceil(math.log2(spatial_bound + 65)) + 1
    spec = KeySpec(batch_bits=bb, spatial_bits=(sb,) * ndim_space)
    if not spec.fits():
        return KeySpec(batch_bits=32, spatial_bits=(32,) * ndim_space, raw=True)
    return spec


def pack_keys(coords: jax.Array, spec: KeySpec, valid=None,
              query: bool = False) -> jax.Array:
    """Pack coordinate rows ``(..., 1+D)`` into int32 keys.

    Returns ``(...,)`` for single-word specs, ``(..., W)`` MSB-first
    otherwise (``[hi, lo]`` for two-word packed specs; the coordinate
    columns themselves for ``raw`` specs).  Rows that are masked out by
    ``valid`` or fall outside the declared per-field range become ``PAD``
    (int32 max in every word, sorts last) — or ``MISS`` (-1 in every word,
    matches nothing) when ``query=True``.
    """
    c = coords.astype(jnp.int32)
    if spec.raw:
        if valid is None:
            return c
        sentinel = jnp.int32(-1 if query else _I32_MAX)
        return jnp.where(valid[..., None], c, sentinel)
    layout = spec.layout()
    words = spec.words
    lo = jnp.zeros(c.shape[:-1], jnp.int32)
    hi = jnp.zeros(c.shape[:-1], jnp.int32)
    b = c[..., 0]
    ok = (b >= 0) & (b < (1 << spec.batch_bits))
    for f, (word, shift, width) in enumerate(layout):
        if f == 0:
            val = b
        else:
            half = 1 << (width - 1)
            v = c[..., f]
            ok = ok & (v >= -half) & (v < half)
            val = v + half
        contrib = val << shift
        if word == 0:
            lo = lo + contrib
        else:
            hi = hi + contrib
    if valid is not None:
        ok = ok & valid
    sentinel = jnp.int32(-1 if query else _I32_MAX)
    lo = jnp.where(ok, lo, sentinel)
    if words == 1:
        return lo
    hi = jnp.where(ok, hi, sentinel)
    return jnp.stack([hi, lo], axis=-1)


def unpack_keys(keys: jax.Array, spec: KeySpec) -> jax.Array:
    """Inverse of ``pack_keys`` for in-range keys → ``(..., 1+D)`` int32.

    Sentinel keys produce garbage rows; callers mask them via validity.
    """
    if spec.raw:
        return keys
    if spec.words == 1:
        hi, lo = jnp.zeros_like(keys), keys
    else:
        hi, lo = keys[..., 0], keys[..., 1]
    cols = []
    for f, (word, shift, width) in enumerate(spec.layout()):
        src = lo if word == 0 else hi
        val = (src >> shift) & ((1 << width) - 1)
        cols.append(val if f == 0 else val - (1 << (width - 1)))
    return jnp.stack(cols, axis=-1)


def keys_less(a: jax.Array, b: jax.Array, words: int = 1) -> jax.Array:
    """a < b for packed keys (scalar when words==1, MSB-first rows else)."""
    if words == 1:
        return a < b
    return _lex_less(a, b)


def keys_equal(a: jax.Array, b: jax.Array, words: int = 1) -> jax.Array:
    if words == 1:
        return a == b
    return jnp.all(a == b, axis=-1)


def searchsorted_keys(sorted_keys: jax.Array, q: jax.Array, words: int = 1,
                      side: str = "left") -> jax.Array:
    """Insertion positions of ``q`` in packed sorted keys — the multi-word
    generalization of ``jnp.searchsorted``.  Returns int32 positions in
    ``[0, n]``."""
    if words == 1:
        return jnp.searchsorted(sorted_keys, q, side=side).astype(jnp.int32)
    n = sorted_keys.shape[0]
    m = q.shape[0]
    if n == 0:
        return jnp.zeros((m,), jnp.int32)
    lo = jnp.zeros((m,), jnp.int32)
    hi = jnp.full((m,), n, jnp.int32)
    for _ in range(max(1, math.ceil(math.log2(max(n, 2))) + 1)):
        active = lo < hi
        mid = (lo + hi) // 2
        row = sorted_keys[jnp.clip(mid, 0, n - 1)]
        adv = _lex_less(row, q) if side == "left" else ~_lex_less(q, row)
        lo = jnp.where(active & adv, mid + 1, lo)
        hi = jnp.where(active & ~adv, mid, hi)
    return lo


# ---------------------------------------------------------------------------
# O(N) radix sort for bounded packed keys (ROADMAP item 1; Minuet-style —
# the declared bit budget caps key entropy, so a bit-serial stable partition
# replaces XLA's O(N log N) comparison argsort on the table-build hot path)
# ---------------------------------------------------------------------------

def radix_enabled() -> bool:
    """Policy switch for the O(N·bits) radix sort tier.

    Default: on for compiled TPU execution (comparison sorts lower to the
    O(N·log²N) bitonic network there; the bit-partition passes beat it at
    table scale) and OFF for CPU/interpret containers, where XLA runs the
    ~30 sequential cumsum+scatter passes serially and a single comparison
    argsort wins outright (bench: ``kmap/speedup/key_sort``).  Both paths
    produce bit-identical permutations, so this flips cost, never layout.
    ``REPRO_RADIX_SORT=1/0`` overrides for A/B runs.
    """
    env = os.environ.get("REPRO_RADIX_SORT")
    if env is not None:
        return env not in ("0", "false", "")
    from repro.kernels.common import default_interpret
    return not default_interpret()


def radix_word_bits(spec: KeySpec) -> Optional[Tuple[int, ...]]:
    """Per-word used bit counts, indexed by word number (0 = low word), for
    a bounded packed spec — or ``None`` when the spec is raw / over budget
    (no bit bound ⇒ no radix; comparison sort stays)."""
    if spec.raw or not spec.fits():
        return None
    used = [0, 0]
    for word, shift, width in spec.layout():
        used[word] = max(used[word], shift + width)
    return tuple(used[:spec.words])


def _remap_radix_word(vals, nbits: int):
    """Map one key word onto the dense radix domain ``[0, 2**(nbits+1))``:
    ``MISS`` (-1) → 0, valid ``v ∈ [0, 2**nbits)`` → ``v+1``, ``PAD``
    (int32 max) → ``2**nbits + 1``.  Order-preserving (MISS first, PAD
    last — the signed-compare layout), so a radix sort of the remapped
    word is bit-identical to a stable argsort of the original."""
    return jnp.where(vals == _I32_MAX, jnp.int32((1 << nbits) + 1),
                     vals + jnp.int32(1))


def radix_argsort_bits(vals: jax.Array, nbits: int) -> jax.Array:
    """Stable argsort of non-negative int32 ``vals < 2**nbits`` in
    O(N·nbits): one stable binary partition (cumsum + scatter) per bit,
    LSB first.  Bit-identical to ``jnp.argsort(vals, stable=True)``."""
    n = vals.shape[0]
    order = jnp.arange(n, dtype=jnp.int32)
    if n == 0 or nbits <= 0:
        return order

    def body(b, carry):
        r, o = carry
        bit = (r >> b) & 1
        zeros = jnp.cumsum(1 - bit)
        pos = jnp.where(bit == 0, zeros - 1, zeros[-1] + jnp.cumsum(bit) - 1)
        return (jnp.zeros_like(r).at[pos].set(r),
                jnp.zeros_like(o).at[pos].set(o))

    _, order = jax.lax.fori_loop(0, nbits, body, (vals, order))
    return order


def radix_argsort_padded(vals: jax.Array, nbits: int) -> jax.Array:
    """Stable radix argsort of ``vals ∈ [0, 2**nbits) ∪ {MISS, PAD}`` —
    remaps the sentinels onto the dense domain then bit-partitions.
    Needs ``nbits ≤ 29`` so the remapped domain stays inside int32."""
    return radix_argsort_bits(_remap_radix_word(vals, nbits), nbits + 1)


def radix_argsort_keys(keys: jax.Array, spec: KeySpec) -> jax.Array:
    """O(N·bits) stable radix argsort of packed keys (XLA twin of the
    Pallas kernel in ``repro.kernels.radix_sort``).  Requires a bounded
    spec; two-word keys chain lo-word then hi-word passes (stable LSD).
    The permutation is bit-identical to ``sort_keys``'s argsort, pads and
    MISS sentinels included."""
    wb = radix_word_bits(spec)
    if wb is None:
        raise ValueError(f"radix sort needs a bounded spec, got {spec}")
    if spec.words == 1:
        return radix_argsort_bits(_remap_radix_word(keys, wb[0]), wb[0] + 1)
    lo = _remap_radix_word(keys[:, 1], wb[0])
    hi = _remap_radix_word(keys[:, 0], wb[1])
    order = radix_argsort_bits(lo, wb[0] + 1)
    return order[radix_argsort_bits(hi[order], wb[1] + 1)]


def sort_keys(keys: jax.Array, spec: Optional[KeySpec] = None):
    """Argsort packed keys.  With a bounded ``spec``, an O(N·bits) stable
    radix sort keyed off the declared bit budget; otherwise one comparison
    argsort for scalar keys / one chained stable argsort per word
    (least-significant first) for multi-word keys.  The permutation is
    identical either way.  Returns (order, sorted_keys)."""
    if spec is not None and radix_word_bits(spec) is not None \
            and radix_enabled():
        order = radix_argsort_keys(keys, spec)
        return order, keys[order]
    if keys.ndim == 1:
        order = jnp.argsort(keys, stable=True).astype(jnp.int32)
    else:
        order = lex_argsort(keys)
    return order, keys[order]


class CoordTable:
    """Sorted packed-key coordinate table answering batched exact-match
    queries.  Construction: pack (elementwise) + one argsort."""

    def __init__(self, spec: KeySpec, sorted_keys: jax.Array, order: jax.Array):
        self.spec = spec
        self.sorted_keys = sorted_keys
        self.order = order
        self.n = sorted_keys.shape[0]

    @classmethod
    def build(cls, coords: jax.Array, valid_mask: jax.Array,
              spec: KeySpec) -> "CoordTable":
        keys = pack_keys(coords, spec, valid=valid_mask)
        order, sorted_keys = sort_keys(keys, spec)
        return cls(spec, sorted_keys, order)

    @classmethod
    def from_sorted_keys(cls, spec: KeySpec, sorted_keys: jax.Array) -> "CoordTable":
        """Adopt an already-sorted key array (identity order) — used when a
        strided map's unique pass emits the next level's table for free."""
        n = sorted_keys.shape[0]
        return cls(spec, sorted_keys, jnp.arange(n, dtype=jnp.int32))

    def lookup_keys(self, q: jax.Array) -> jax.Array:
        """Original row index of each query key, or -1 if absent. q: (M,)
        int32 or (M, 2) — any query count, e.g. the K^D·N flattened batch."""
        sk = self.sorted_keys
        w = self.spec.words
        if w == 1:
            pos = jnp.searchsorted(sk, q, side="left").astype(jnp.int32)
            pos = jnp.clip(pos, 0, self.n - 1)
            hit = sk[pos] == q
        else:
            m = q.shape[0]
            lo = jnp.zeros((m,), jnp.int32)
            hi = jnp.full((m,), self.n, jnp.int32)
            for _ in range(max(1, math.ceil(math.log2(max(self.n, 2))) + 1)):
                mid = (lo + hi) // 2
                less = keys_less(sk[jnp.clip(mid, 0, self.n - 1)], q, w)
                lo = jnp.where(less, mid + 1, lo)
                hi = jnp.where(less, hi, mid)
            pos = jnp.clip(lo, 0, self.n - 1)
            hit = keys_equal(sk[pos], q, w)
        return jnp.where(hit, self.order[pos], -1).astype(jnp.int32)

    def lookup(self, query_coords: jax.Array, valid=None) -> jax.Array:
        """Coordinate-row lookup: pack the query rows, search the table."""
        return self.lookup_keys(pack_keys(query_coords, self.spec,
                                          valid=valid, query=True))

    def delta_merge(self, removed_coords: jax.Array,
                    added_coords: jax.Array) -> "CoordTable":
        """Streaming-frame table update: merge a small sorted delta instead
        of re-sorting the full cloud.

        Requires an *exact-size* table (every row valid, all keys unique —
        the per-scene tables the serving engine caches).  ``removed_coords``
        must all be present (each exactly once) and ``added_coords`` absent.
        The result is bit-identical to ``CoordTable.build`` on the updated
        scene whose row layout is ``[kept rows in original order, then
        added rows]`` — exactly what ``serve.batcher.apply_delta`` produces.

        Cost: two O(r+a) binary-search passes plus O(N) compaction/scatter —
        no O(N log N) argsort of the full cloud.
        """
        spec = self.spec
        w = spec.words
        n = self.n
        r = int(removed_coords.shape[0])
        a = int(added_coords.shape[0])
        n_keep = n - r
        assert n_keep >= 0, (n, r)
        sk, order = self.sorted_keys, self.order
        if r:
            rk = pack_keys(jnp.asarray(removed_coords, jnp.int32), spec,
                           query=True)
            pos = jnp.clip(searchsorted_keys(sk, rk, w, side="left"), 0, n - 1)
            keep = jnp.ones((n,), bool).at[pos].set(False)
            # removal shifts every later row index down by the number of
            # removed rows before it (the fresh build's compacted layout)
            ind = jnp.zeros((n,), jnp.int32).at[order[pos]].set(1)
            shift = jnp.cumsum(ind)
            order = (order - shift[order]).astype(jnp.int32)
        else:
            keep = jnp.ones((n,), bool)
        dest = jnp.where(keep, jnp.cumsum(keep).astype(jnp.int32) - 1, n_keep)
        kept_keys = jnp.full((n_keep + 1,) + sk.shape[1:], _I32_MAX,
                             jnp.int32).at[dest].set(sk, mode="drop")[:n_keep]
        kept_order = jnp.zeros((n_keep + 1,), jnp.int32).at[dest].set(
            order, mode="drop")[:n_keep]
        if not a:
            return CoordTable(spec, kept_keys, kept_order)
        ak = pack_keys(jnp.asarray(added_coords, jnp.int32), spec)
        add_perm, add_sorted = sort_keys(ak, spec)
        add_order = (n_keep + add_perm).astype(jnp.int32)
        # stable two-way merge: scatter both sorted runs at their final ranks
        pos_k = jnp.arange(n_keep, dtype=jnp.int32) + \
            searchsorted_keys(add_sorted, kept_keys, w, side="left")
        pos_a = jnp.arange(a, dtype=jnp.int32) + \
            searchsorted_keys(kept_keys, add_sorted, w, side="right")
        out_keys = (jnp.zeros((n_keep + a,) + sk.shape[1:], jnp.int32)
                    .at[pos_k].set(kept_keys).at[pos_a].set(add_sorted))
        out_order = (jnp.zeros((n_keep + a,), jnp.int32)
                     .at[pos_k].set(kept_order).at[pos_a].set(add_order))
        return CoordTable(spec, out_keys, out_order)


def np_pack_keys(coords: np.ndarray, spec: KeySpec) -> np.ndarray:
    """Numpy twin of ``pack_keys`` for in-range, all-valid rows (the
    host-side streaming path packs delta rows; bounds are the caller's
    declared promise)."""
    c = np.asarray(coords, np.int32)
    if spec.raw:
        return c
    lo = np.zeros(c.shape[:-1], np.int64)
    hi = np.zeros(c.shape[:-1], np.int64)
    for f, (word, shift, width) in enumerate(spec.layout()):
        val = c[..., f].astype(np.int64)
        if f > 0:
            val = val + (1 << (width - 1))
        if word == 0:
            lo += val << shift
        else:
            hi += val << shift
    if spec.words == 1:
        return lo.astype(np.int32)
    return np.stack([hi, lo], axis=-1).astype(np.int32)


def np_radix_argsort_bits(vals: np.ndarray, nbits: int) -> np.ndarray:
    """Numpy twin of ``radix_argsort_bits`` — stable O(N·nbits) bit-serial
    partition, bit-identical to ``np.argsort(vals, kind="stable")`` for
    non-negative ``vals < 2**nbits``."""
    r = np.asarray(vals).astype(np.int64, copy=True)
    n = r.shape[0]
    order = np.arange(n, dtype=np.int32)
    if n == 0 or nbits <= 0:
        return order
    for b in range(nbits):
        bit = (r >> b) & 1
        zeros = np.cumsum(bit == 0)
        pos = np.where(bit == 0, zeros - 1, zeros[-1] + np.cumsum(bit) - 1)
        nr = np.empty_like(r)
        nr[pos] = r
        no = np.empty_like(order)
        no[pos] = order
        r, order = nr, no
    return order


def np_radix_argsort_keys(keys: np.ndarray, spec: KeySpec) -> np.ndarray:
    """Numpy twin of ``radix_argsort_keys`` (host-side scene tables)."""
    wb = radix_word_bits(spec)
    if wb is None:
        raise ValueError(f"radix sort needs a bounded spec, got {spec}")
    keys = np.asarray(keys)

    def remap(v, ub):
        v = v.astype(np.int64)
        return np.where(v == _I32_MAX, (1 << ub) + 1, v + 1)

    if spec.words == 1:
        return np_radix_argsort_bits(remap(keys, wb[0]), wb[0] + 1)
    order = np_radix_argsort_bits(remap(keys[:, 1], wb[0]), wb[0] + 1)
    hi = remap(keys[:, 0], wb[1])
    return order[np_radix_argsort_bits(hi[order], wb[1] + 1)]


def _np_cmp_keys(keys: np.ndarray, words: int) -> Optional[np.ndarray]:
    """Collapse packed keys into one order-isomorphic comparable numpy
    array: identity for scalar keys, a signed-int64 fold for [hi, lo]
    pairs, None for wider (raw) keys."""
    if words == 1:
        return keys
    if words == 2:
        return (keys[..., 0].astype(np.int64) * (1 << 32)
                + (keys[..., 1].astype(np.int64) - np.iinfo(np.int32).min))
    return None


def np_delta_merge(spec: KeySpec, keys: np.ndarray, order: np.ndarray,
                   removed_coords: np.ndarray, added_coords: np.ndarray):
    """Host-side twin of ``CoordTable.delta_merge`` on numpy arrays — the
    serving engine's streaming hot path (scene tables live on the host, and
    numpy has no per-shape compile cost).  Same contract: exact-size sorted
    table, removed rows present, added rows absent; returns ``(keys,
    order)`` bit-identical to a fresh build of ``[kept rows in original
    order, then added rows]``.  Raw (>2-word) specs fall back to one stable
    lexsort of the merged key set — still host-only, still exact."""
    keys = np.asarray(keys)
    order = np.asarray(order, np.int32)
    n = keys.shape[0]
    r = removed_coords.shape[0]
    a = added_coords.shape[0]
    cmp_keys = _np_cmp_keys(keys, spec.words)
    if r:
        rm = np_pack_keys(removed_coords, spec)
        if cmp_keys is None:
            keep = np.ones((n,), bool)
            view = {tuple(k): i for i, k in enumerate(keys)}
            pos = np.asarray([view[tuple(k)] for k in rm], np.int64)
        else:
            pos = np.searchsorted(cmp_keys, _np_cmp_keys(rm, spec.words))
            keep = np.ones((n,), bool)
        keep[pos] = False
        ind = np.zeros((n,), np.int32)
        ind[order[pos]] = 1
        shift = np.cumsum(ind).astype(np.int32)
        order = order - shift[order]
    else:
        keep = np.ones((n,), bool)
    kept_keys, kept_order = keys[keep], order[keep]
    n_keep = n - r
    if not a:
        return kept_keys, kept_order
    ak = np_pack_keys(added_coords, spec)
    ak_cmp = _np_cmp_keys(ak, spec.words)
    if ak_cmp is None:   # raw fallback: one stable host lexsort, no device
        merged = np.concatenate([kept_keys, ak])
        morder = np.concatenate([kept_order,
                                 n_keep + np.arange(a, dtype=np.int32)])
        perm = lex_argsort_np(merged)
        return merged[perm], morder[perm]
    if radix_word_bits(spec) is not None and radix_enabled():
        perm = np_radix_argsort_keys(ak, spec)   # bounded keys: O(N) radix
    else:
        perm = np.argsort(ak_cmp, kind="stable").astype(np.int32)
    ak, ak_cmp = ak[perm], ak_cmp[perm]
    add_order = (n_keep + perm).astype(np.int32)
    kept_cmp = _np_cmp_keys(kept_keys, spec.words)
    pos_k = np.arange(n_keep) + np.searchsorted(ak_cmp, kept_cmp, side="left")
    pos_a = np.arange(a) + np.searchsorted(kept_cmp, ak_cmp, side="right")
    out_keys = np.empty((n_keep + a,) + keys.shape[1:], np.int32)
    out_order = np.empty((n_keep + a,), np.int32)
    out_keys[pos_k], out_keys[pos_a] = kept_keys, ak
    out_order[pos_k], out_order[pos_a] = kept_order, add_order
    return out_keys, out_order


def lex_argsort_np(words: np.ndarray) -> np.ndarray:
    """Stable lexicographic argsort of (N, W) int32 rows, MSB-first — the
    numpy twin of ``lex_argsort``."""
    return np.lexsort(words.T[::-1]).astype(np.int32)


def batch_key_delta(spec: KeySpec, batch_id: int) -> np.ndarray:
    """Additive key delta rebasing a batch-0 key row to ``batch_id``.

    Returns an ``(spec.words,)`` int32 vector in the same MSB-first column
    order as the packed keys (scalar layouts use the single entry).  Valid
    because the batch field of a batch-0 key is all zeros, so adding the
    shifted batch value equals packing with ``batch_id`` directly.
    """
    b = int(batch_id)
    d = np.zeros((spec.words,), np.int32)
    if spec.raw:
        d[0] = b          # raw keys ARE the coordinate columns, batch first
        return d
    word, shift, width = spec.layout()[0]
    assert 0 <= b < (1 << width), (b, width)
    # MSB-first column order: the batch field always lands in the highest
    # word (it is placed last / most significant), i.e. column 0.
    assert word == spec.words - 1, (word, spec.words)
    d[0] = np.int32(b << shift)
    return d


def rebase_batch_keys(keys, spec: KeySpec, batch_id: int):
    """Rebase batch-0 keys (numpy or jax, ``(n,)`` or ``(n, W)``) to
    ``batch_id`` by adding the batch-field delta."""
    d = batch_key_delta(spec, batch_id)
    if keys.ndim == 1:
        return keys + d[0]
    return keys + d[None, :]


def compose_tables(spec: KeySpec,
                   parts: Sequence[Tuple[np.ndarray, Optional[np.ndarray],
                                         int, int]],
                   capacity: int) -> Tuple[np.ndarray, Optional[np.ndarray]]:
    """Merge-compose per-scene sorted batch-0 tables into one batch table.

    ``parts``: per scene, in batch order: ``(sorted_keys, order_or_None,
    batch_id, row_offset)`` where the arrays are the scene's *exact-size*
    sorted table (no padding) and ``row_offset`` is the scene's first row in
    the packed batch.  Because the batch index is the most significant key
    field and scenes are packed batch-major, the k-way merge degenerates to
    a concatenation: O(N) total, no argsort.  Padding rows (``PAD`` keys;
    order ``arange(total, capacity)``) reproduce a fresh build's stable-sort
    layout exactly, so the result is bit-identical to ``CoordTable.build``
    on the packed batch.  Host-side numpy (the serving engine composes on
    the host); wrap in ``CoordTable`` after ``jnp.asarray``.
    """
    key_parts, order_parts = [], []
    with_order = bool(parts) and parts[0][1] is not None
    total = 0
    for keys, order, batch_id, row_offset in parts:
        keys = np.asarray(keys)
        key_parts.append(rebase_batch_keys(keys, spec, batch_id)
                         .astype(np.int32, copy=False))
        if with_order:
            order_parts.append(np.asarray(order, np.int32) + np.int32(row_offset))
        total += keys.shape[0]
    assert total <= capacity, (total, capacity)
    tail_shape = (capacity - total,) + key_parts[0].shape[1:] if key_parts \
        else (capacity,) + ((spec.words,) if spec.words > 1 else ())
    key_parts.append(np.full(tail_shape, _I32_MAX, np.int32))
    keys = np.concatenate(key_parts)
    if not with_order:
        return keys, None
    order_parts.append(np.arange(total, capacity, dtype=np.int32))
    return keys, np.concatenate(order_parts)


# ---------------------------------------------------------------------------
# Multi-word helpers (raw/two-word specs, voxelize, non-pow2-stride dedup)
# ---------------------------------------------------------------------------

def lex_argsort(words: jax.Array) -> jax.Array:
    """Stable lexicographic argsort of rows. words: (N, W) int32 → (N,) int32."""
    n, w = words.shape
    order = jnp.arange(n, dtype=jnp.int32)
    # least-significant word first; stable sorts compose lexicographically
    for col in range(w - 1, -1, -1):
        order = order[jnp.argsort(words[order, col], stable=True)]
    return order


def rows_equal(a: jax.Array, b: jax.Array) -> jax.Array:
    """Elementwise row equality for (N, W) word matrices → (N,) bool."""
    return jnp.all(a == b, axis=-1)


def _lex_less(row_a, row_b):
    """row_a < row_b lexicographically; rows are (..., W)."""
    w = row_a.shape[-1]
    lt = row_a[..., 0] < row_b[..., 0]
    eq = row_a[..., 0] == row_b[..., 0]
    for c in range(1, w):
        lt = lt | (eq & (row_a[..., c] < row_b[..., c]))
        eq = eq & (row_a[..., c] == row_b[..., c])
    return lt
