"""Shared benchmark utilities: synthetic workloads with the paper's sparsity
statistics, timing, and CSV emission (``name,us_per_call,derived``)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import dataflows as df
from repro.core.sparse_conv import TrainDataflowConfig
from repro.data.synthetic import lidar_scene

ROWS: list[str] = []
#: structured twin of ROWS — (name, us, derived) — for consumers like
#: benchmarks/run.py's BENCH_CI.json: names may legally contain commas
#: (e.g. "tab5/SK-M/splits={1,2}"), so re-parsing the CSV line is ambiguous
RECORDS: list[tuple] = []


def emit(name: str, us: float, derived: str = ""):
    row = f"{name},{us:.1f},{derived}"
    ROWS.append(row)
    RECORDS.append((name, float(us), derived))
    print(row, flush=True)


def time_fn(fn, warmup=1, iters=3) -> float:
    """Best-of-n microseconds."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


# CPU-container benchmark scale (the paper's scenes have 10⁵-10⁶ points; we
# keep the same *structure* at reduced point counts so end-to-end ranking
# logic — mapping overhead vs kernel time — is preserved).
def seg_scene(seed=0, n=2000, cap=2048, channels=4):
    """SemanticKITTI-like (64-beam, segmentation: denser, bigger extent)."""
    return lidar_scene(jax.random.PRNGKey(seed), n, cap, channels,
                       extent=50.0, voxel=0.4)


def det_scene(seed=0, n=1200, cap=2048, channels=5):
    """Waymo-like (detection: sparser voxelization)."""
    return lidar_scene(jax.random.PRNGKey(seed), n, cap, channels,
                       extent=75.0, voxel=0.8)


# Named dataflow configs ≈ the systems compared in the paper.
SYSTEMS = {
    "gather_gemm_scatter(SpConv1-like)": df.DataflowConfig("gather_scatter"),
    "fetch_on_demand(MinkEngine-like)": df.DataflowConfig("fetch_on_demand"),
    "implicit_gemm_s1(SpConv2-like)": df.DataflowConfig("implicit_gemm", n_splits=1),
    "implicit_gemm_unsorted": df.DataflowConfig("implicit_gemm", n_splits=0),
}


def bind(cfg: df.DataflowConfig) -> TrainDataflowConfig:
    return TrainDataflowConfig.bind_all(cfg)
