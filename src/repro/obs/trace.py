"""Phase-level tracing + metrics: the repo's zero-dependency observability
core.

The paper's Sparse Autotuner picks dataflows purely from measurements
(PAPER.md §4), and TorchSparse's own gather/GEMM/scatter cost breakdowns
are per-phase visibility — this module gives the *system* that same
visibility at request granularity.  One ``Tracer`` holds:

* **spans** — nestable ``span("phase", **attrs)`` context managers on
  monotonic clocks (``time.perf_counter_ns``), with a per-thread span
  stack so router worker threads interleave correctly: every record
  carries its thread id/name and its nesting depth *within that thread*.
  ``record_span`` retroactively records an interval measured elsewhere
  (queue waits: the submit timestamp predates the flush that observes it);
* **instant events** — ``event("compile", rung=..., device=...)`` for
  point-in-time facts like jit recompiles, routing decisions, checkpoint
  writes;
* **counters / gauges** — monotonically accumulated / last-value metrics,
  readable as one ``snapshot()`` dict;
* **phase histograms** — ``phase_summary()`` folds recorded spans into
  per-name count/p50/p95/total.

A process-global default tracer starts **disabled** and compiles to
no-ops: the disabled ``span()`` fast path returns one preallocated
singleton, so instrumented hot paths pay a truthiness check and retain
zero allocations (asserted in tests/test_obs.py).  Enable it with
``enable()`` (or install your own via ``set_tracer``), export with
``repro.obs.export`` (Chrome trace-event JSON for Perfetto /
``chrome://tracing``, or a flat JSONL event log).

Storage is bounded: past ``max_records`` spans/events the tracer keeps
the earliest records (a trace's interesting part is usually its start —
compiles, warmup) and counts the rest in ``dropped``.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class SpanRecord:
    """One finished span: a named interval on the monotonic clock."""

    name: str
    t0_ns: int
    t1_ns: int
    tid: int
    thread: str
    depth: int      # nesting depth within this thread's span stack
    attrs: dict

    @property
    def dur_ms(self) -> float:
        return (self.t1_ns - self.t0_ns) / 1e6


@dataclasses.dataclass(frozen=True)
class EventRecord:
    """One instant event (a point, not an interval)."""

    name: str
    t_ns: int
    tid: int
    thread: str
    attrs: dict


class _NoopSpan:
    """The disabled fast path: one preallocated singleton, no state."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self


NOOP_SPAN = _NoopSpan()


class _Span:
    """A live span context manager (enabled tracer only)."""

    __slots__ = ("_tracer", "name", "attrs", "_t0", "_depth")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs

    def set(self, **attrs) -> "_Span":
        """Attach attributes discovered mid-span (e.g. a measured latency)
        — must be called before the span closes."""
        self.attrs.update(attrs)
        return self

    def __enter__(self):
        stack = self._tracer._stack()
        self._depth = len(stack)
        stack.append(self)
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter_ns()
        stack = self._tracer._stack()
        if stack and stack[-1] is self:
            stack.pop()
        elif self in stack:     # out-of-order exit: drop through to self
            del stack[stack.index(self):]
        th = threading.current_thread()
        self._tracer._add_span(SpanRecord(
            name=self.name, t0_ns=self._t0, t1_ns=t1, tid=th.ident or 0,
            thread=th.name, depth=self._depth, attrs=self.attrs))
        return False


class Tracer:
    """Thread-safe span/event/metric collector (see module docstring).

    enabled:     a disabled tracer records nothing; its ``span()`` returns
                 the no-op singleton (counters/gauges stay live — they are
                 cheap and callers rely on them for stats).
    max_records: bound on stored spans and on stored events (separately);
                 excess records are counted in ``dropped``, never stored.
    """

    def __init__(self, enabled: bool = True, max_records: int = 200_000):
        self.enabled = enabled
        self.max_records = int(max_records)
        self.dropped = 0
        self._lock = threading.Lock()
        self._spans: List[SpanRecord] = []
        self._events: List[EventRecord] = []
        self._counters: "collections.Counter" = collections.Counter()
        self._gauges: Dict[str, float] = {}
        self._tls = threading.local()

    # ------------------------------------------------------------- plumbing
    def _stack(self) -> list:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def _add_span(self, rec: SpanRecord) -> None:
        with self._lock:
            if len(self._spans) < self.max_records:
                self._spans.append(rec)
            else:
                self.dropped += 1

    # ------------------------------------------------------------ recording
    def span(self, name: str, **attrs):
        """Context manager timing a named phase; no-op when disabled."""
        if not self.enabled:
            return NOOP_SPAN
        return _Span(self, name, attrs)

    def record_span(self, name: str, t0_ns: int, t1_ns: int, **attrs) -> None:
        """Record an interval measured elsewhere (both ends in
        ``time.perf_counter_ns`` time) — e.g. a queue wait whose start
        predates the flush that observes it."""
        if not self.enabled:
            return
        th = threading.current_thread()
        self._add_span(SpanRecord(
            name=name, t0_ns=int(t0_ns), t1_ns=int(t1_ns),
            tid=th.ident or 0, thread=th.name,
            depth=len(self._stack()), attrs=attrs))

    def event(self, name: str, **attrs) -> None:
        """Record an instant event; no-op when disabled."""
        if not self.enabled:
            return
        th = threading.current_thread()
        rec = EventRecord(name=name, t_ns=time.perf_counter_ns(),
                          tid=th.ident or 0, thread=th.name, attrs=attrs)
        with self._lock:
            if len(self._events) < self.max_records:
                self._events.append(rec)
            else:
                self.dropped += 1

    def count(self, name: str, n: int = 1) -> None:
        """Bump a monotonic counter (live even when tracing is disabled)."""
        with self._lock:
            self._counters[name] += n

    def gauge(self, name: str, value: float) -> None:
        """Set a last-value gauge (live even when tracing is disabled)."""
        with self._lock:
            self._gauges[name] = value

    # -------------------------------------------------------------- reading
    def spans(self) -> List[SpanRecord]:
        with self._lock:
            return list(self._spans)

    def events(self, name: Optional[str] = None) -> List[EventRecord]:
        with self._lock:
            evs = list(self._events)
        return evs if name is None else [e for e in evs if e.name == name]

    def snapshot(self) -> dict:
        """Counters + gauges + record bookkeeping, one JSON-able dict."""
        with self._lock:
            return {"counters": dict(self._counters),
                    "gauges": dict(self._gauges),
                    "spans": len(self._spans), "events": len(self._events),
                    "dropped": self.dropped}

    def phase_summary(self) -> Dict[str, dict]:
        """Per span name: count, p50/p95/total milliseconds (pure python —
        percentiles by sorted index, no numpy dependency here)."""
        by_name: Dict[str, List[float]] = {}
        for rec in self.spans():
            by_name.setdefault(rec.name, []).append(rec.dur_ms)
        out = {}
        for name, durs in sorted(by_name.items()):
            durs.sort()
            n = len(durs)
            out[name] = {"count": n,
                         "p50_ms": durs[min(n - 1, int(0.50 * n))],
                         "p95_ms": durs[min(n - 1, int(0.95 * n))],
                         "total_ms": sum(durs)}
        return out

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()
            self._events.clear()
            self._counters.clear()
            self._gauges.clear()
            self.dropped = 0


# ---------------------------------------------------------------------------
# The process-global default tracer
# ---------------------------------------------------------------------------

_default = Tracer(enabled=False)


def get_tracer() -> Tracer:
    return _default


def set_tracer(tracer: Tracer) -> Tracer:
    """Install ``tracer`` as the process default; returns it."""
    global _default
    _default = tracer
    return tracer


def enable(max_records: int = 200_000) -> Tracer:
    """Install and return a fresh enabled default tracer."""
    return set_tracer(Tracer(enabled=True, max_records=max_records))


def disable() -> Tracer:
    """Install and return a fresh disabled default tracer."""
    return set_tracer(Tracer(enabled=False))


def span(name: str, **attrs):
    """Module-level span on the default tracer — THE instrumentation entry
    point for hot paths: when disabled it returns the preallocated no-op
    singleton (no tracer state touched, nothing retained)."""
    t = _default
    if not t.enabled:
        return NOOP_SPAN
    return _Span(t, name, attrs)


def event(name: str, **attrs) -> None:
    t = _default
    if t.enabled:
        t.event(name, **attrs)


def record_span(name: str, t0_ns: int, t1_ns: int, **attrs) -> None:
    t = _default
    if t.enabled:
        t.record_span(name, t0_ns, t1_ns, **attrs)


def count(name: str, n: int = 1) -> None:
    _default.count(name, n)


def gauge(name: str, value: float) -> None:
    _default.gauge(name, value)
