"""Kimi K2 — trillion-parameter MoE (paper-table) [arXiv:2501.kimi2; unverified].

61L d_model=7168 64H (GQA kv=8) expert d_ff=2048 vocab=163840, MoE 384e top-8.
"""
from repro.models.lm_common import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b", family="moe", n_layers=61, d_model=7168,
    n_heads=64, kv_heads=8, d_ff=2048, vocab=163840, norm="rms", mlp="swiglu",
    # dispatch="gspmd_sort" is the paper-faithful gather-GEMM-scatter
    # baseline recorded in EXPERIMENTS.md §Roofline.  For deployment switch
    # to dispatch="local_shardmap": 118x less collective traffic
    # (EXPERIMENTS.md §Perf cycle 1; `python -m benchmarks.perf_hillclimb`).
    moe=MoECfg(n_experts=384, top_k=8, d_ff_expert=2048, shard_experts=True),
)
