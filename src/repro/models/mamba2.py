"""Mamba-2 (SSD) blocks and the Zamba2-7B hybrid model.

SSD chunked algorithm (Dao & Gu 2024): within a chunk the recurrence is the
attention-like quadratic form  Y = (L ⊙ C Bᵀ) X, across chunks only the
(B, H, N, P) boundary states flow through a `lax.scan`.  The (Q × Q)
intra-chunk scores are the only quadratic object and exist one chunk at a
time — on TPU this is an MXU-friendly batch of small matmuls.

Zamba2: 81 Mamba-2 blocks with a single *shared* attention+MLP block invoked
after every 6th Mamba block (13 invocations for 78 layers, then 3 trailing
Mamba blocks).  The shared block's weights are reused at every invocation —
a parameter-efficiency trick from the paper [arXiv:2411.15242]; each
invocation keeps its own KV cache at decode time.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models.lm_common import (ArchConfig, NO_SHARD, ShardCtx, _rand, xscan,
                                    apply_norm, attn_init, attn_qkv,
                                    chunked_attention, chunked_xent,
                                    decode_attention, embed_init, init_norm,
                                    mlp_apply, mlp_init, rms_norm,
                                    unembed_matrix)


def mamba2_init(cfg: ArchConfig, key, dtype):
    d = cfg.d_model
    s = cfg.ssm
    d_in = s.expand * d
    nh = d_in // s.head_dim
    ks = jax.random.split(key, 6)
    return {
        "norm": init_norm(cfg, d, dtype),
        "in_proj": _rand(ks[0], (d, 2 * d_in), dtype),
        "bc_proj": _rand(ks[1], (d, 2 * s.d_state), dtype),
        "dt_proj": _rand(ks[2], (d, nh), dtype),
        "dt_b2": jnp.full((nh,), -4.6, dtype),
        "conv_w": _rand(ks[3], (d_in, s.conv_kernel), dtype, scale=s.conv_kernel ** -0.5),
        "conv_b": jnp.zeros((d_in,), dtype),
        "A_log2": jnp.zeros((nh,), dtype),
        "D": jnp.ones((nh,), dtype),
        "gate_norm": jnp.ones((d_in,), dtype),
        "out_proj": _rand(ks[4], (d_in, d), dtype),
    }


def _causal_conv1d(x, w, b):
    k = w.shape[1]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    y = sum(xp[:, j:j + x.shape[1]] * w[:, j] for j in range(k))
    return y + b


def _ssd_chunked(xh, dt, a_log, b_ssm, c_ssm, chunk: int, h0=None,
                 bf16_scores: bool = False):
    """SSD scan.  xh: (B,S,H,P); dt: (B,S,H); b/c: (B,S,N).

    Returns (y (B,S,H,P), h_final (B,H,N,P)).

    bf16_scores (§Perf): the O(Q²) intra-chunk tensors (decay kernel, CBᵀ,
    masked scores) are the dominant HBM traffic of the whole block; keeping
    them bf16 halves it.  Cumulative log-decays, softplus outputs and the
    carried state stay f32 — the same split a Pallas SSD kernel would use
    (f32 VREG accumulators, bf16 MXU operands)."""
    b, s_len, h, p_dim = xh.shape
    n = b_ssm.shape[-1]
    pad = (-s_len) % chunk
    if pad:
        # identity steps: dt=0 ⇒ decay 1 and zero input
        y, hf = _ssd_chunked(jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0))),
                             jnp.pad(dt, ((0, 0), (0, pad), (0, 0))), a_log,
                             jnp.pad(b_ssm, ((0, 0), (0, pad), (0, 0))),
                             jnp.pad(c_ssm, ((0, 0), (0, pad), (0, 0))), chunk, h0,
                             bf16_scores)
        return y[:, :s_len], hf
    nc = s_len // chunk
    sdt = jnp.bfloat16 if bf16_scores else jnp.float32
    a = (-jnp.exp(a_log.astype(jnp.float32)) * dt)            # (B,S,H) log-decay
    xdt = xh.astype(sdt) * dt[..., None].astype(sdt)

    ac = a.reshape(b, nc, chunk, h)
    xc = xdt.reshape(b, nc, chunk, h, p_dim)
    bc = b_ssm.astype(sdt).reshape(b, nc, chunk, n)
    cc = c_ssm.astype(sdt).reshape(b, nc, chunk, n)

    def chunk_body(hprev, xs):
        a_c, x_c, b_c, c_c = xs                                # (B,Q,H), (B,Q,H,P), (B,Q,N)
        cum = jnp.cumsum(a_c.astype(jnp.float32), axis=1)      # (B,Q,H) f32
        # intra-chunk attention-like term: the O(Q²) tensors are built
        # directly in sdt so no f32 copy ever materializes
        cum_s = cum.astype(sdt)
        l_ts = cum_s[:, :, None, :] - cum_s[:, None, :, :]     # (B,Qt,Qs,H)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        decay = jnp.where(tri[None, :, :, None], jnp.exp(l_ts), jnp.zeros((), sdt))
        cb = jnp.einsum("btn,bsn->bts", c_c, b_c,
                        preferred_element_type=sdt)            # (B,Qt,Qs)
        att = cb[..., None] * decay                            # (B,Qt,Qs,H)
        y = jnp.einsum("btsh,bshp->bthp", att, x_c,
                       preferred_element_type=jnp.float32)
        # inter-chunk contribution from carried state
        y = y + jnp.einsum("btn,bth,bhnp->bthp", c_c, jnp.exp(cum), hprev)
        # next boundary state
        seg = jnp.exp(cum[:, -1:, :] - cum)                    # decay from s to end
        hnew = jnp.einsum("bsn,bsh,bshp->bhnp", b_c, seg, x_c)
        hnew = hnew + jnp.exp(cum[:, -1])[:, :, None, None] * hprev
        return hnew, y

    if h0 is None:
        h0 = jnp.zeros((b, h, n, p_dim), jnp.float32)
    hf, ys = xscan(jax.checkpoint(chunk_body), h0,
                          (ac.transpose(1, 0, 2, 3), xc.transpose(1, 0, 2, 3, 4),
                           bc.transpose(1, 0, 2, 3), cc.transpose(1, 0, 2, 3)))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s_len, h, p_dim)
    return y, hf


def mamba2_block(cfg: ArchConfig, p, x, ctx: ShardCtx = NO_SHARD):
    s_cfg = cfg.ssm
    b, s_len, d = x.shape
    d_in = s_cfg.expand * d
    nh = d_in // s_cfg.head_dim

    h = apply_norm(cfg, x, p["norm"])
    xz = h @ p["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_in = ctx.cons(x_in, ctx.b, None, ctx.m)
    x_c = jax.nn.silu(_causal_conv1d(x_in, p["conv_w"], p["conv_b"]))
    bc = h @ p["bc_proj"]
    b_ssm, c_ssm = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus(h @ p["dt_proj"] + p["dt_b2"]).astype(jnp.float32)  # (B,S,H)

    xh = x_c.reshape(b, s_len, nh, s_cfg.head_dim)
    if s_cfg.use_pallas_kernel:
        from repro.kernels.ssd_chunk.ops import ssd_scan

        y, _ = ssd_scan(xh, dt, p["A_log2"], b_ssm, c_ssm, chunk=s_cfg.chunk)
    else:
        y, _ = _ssd_chunked(xh, dt, p["A_log2"], b_ssm, c_ssm, min(s_cfg.chunk, s_len),
                            bf16_scores=s_cfg.bf16_scores)
    y = y + p["D"].astype(jnp.float32)[:, None] * xh.astype(jnp.float32)
    y = y.reshape(b, s_len, d_in)
    y = rms_norm(y.astype(x.dtype) * jax.nn.silu(z), p["gate_norm"])
    out = y @ p["out_proj"]
    return x + ctx.cons(out, ctx.b, None, None)


# ---------------------------------------------------------------------------
# Zamba2 hybrid model
# ---------------------------------------------------------------------------

def _shared_block_init(cfg: ArchConfig, key, dtype):
    k1, k2 = jax.random.split(key)
    return {"norm1": init_norm(cfg, cfg.d_model, dtype),
            "attn": attn_init(cfg, k1, dtype),
            "norm2": init_norm(cfg, cfg.d_model, dtype),
            "mlp": mlp_init(cfg, k2, dtype)}


def _shared_block(cfg: ArchConfig, p, x, ctx: ShardCtx):
    b, s, _ = x.shape
    positions = jnp.arange(s)
    h = apply_norm(cfg, x, p["norm1"])
    q, k, v = attn_qkv(cfg, p["attn"], h, positions, ctx)
    o = chunked_attention(q, k, v, causal=True, chunk_q=min(cfg.attn_chunk, s),
                          chunk_k=min(cfg.attn_chunk, s))
    x = x + o.reshape(b, s, -1) @ p["attn"]["wo"]
    h2 = apply_norm(cfg, x, p["norm2"])
    return ctx.cons(x + mlp_apply(cfg, p["mlp"], h2, ctx), ctx.b, None, None)


def _split_layers(cfg: ArchConfig):
    """81 layers → 13 groups of `attn_every` + trailing remainder."""
    g = cfg.n_layers // cfg.attn_every
    trailing = cfg.n_layers - g * cfg.attn_every
    return g, trailing


def init_params(cfg: ArchConfig, key) -> dict:
    dtype = cfg.jdtype
    ke, kl, ka = jax.random.split(key, 3)
    params = dict(embed_init(cfg, ke, dtype))
    params["final_norm"] = init_norm(cfg, cfg.d_model, dtype)
    keys = jax.random.split(kl, cfg.n_layers)
    layers = jax.vmap(lambda k: mamba2_init(cfg, k, dtype))(keys)
    if cfg.attn_every:
        g, trailing = _split_layers(cfg)
        grouped = jax.tree.map(lambda a: a[: g * cfg.attn_every].reshape(
            (g, cfg.attn_every) + a.shape[1:]), layers)
        tail = jax.tree.map(lambda a: a[g * cfg.attn_every:], layers)
        params["groups"] = grouped
        params["tail"] = tail
        params["shared"] = _shared_block_init(cfg, ka, dtype)
    else:
        params["layers"] = layers
    return params


def forward_hidden(cfg: ArchConfig, params, tokens, ctx: ShardCtx = NO_SHARD):
    x = params["embed"][tokens]
    x = ctx.cons(x, ctx.b, None, None)
    block = jax.checkpoint(partial(mamba2_block, cfg, ctx=ctx))

    if cfg.attn_every:
        shared = params["shared"]

        def group_body(x, gp):
            def inner(x, lp):
                return block(lp, x), None

            x, _ = xscan(inner, x, gp)
            x = jax.checkpoint(partial(_shared_block, cfg, ctx=ctx))(shared, x)
            return x, None

        x, _ = xscan(group_body, x, params["groups"])

        def tail_body(x, lp):
            return block(lp, x), None

        x, _ = xscan(tail_body, x, params["tail"])
    else:
        def body(x, lp):
            return block(lp, x), None

        x, _ = xscan(body, x, params["layers"])
    return apply_norm(cfg, x, params["final_norm"])


def loss_fn(cfg: ArchConfig, params, batch, ctx: ShardCtx = NO_SHARD):
    h = forward_hidden(cfg, params, batch["tokens"], ctx)
    return chunked_xent(cfg, params, h, batch["labels"], ctx)


# ---------------------------------------------------------------------------
# Serving (long_500k runs here: O(1) SSM state + 13 shared-attn KV caches)
# ---------------------------------------------------------------------------

def _mamba2_block_with_state(cfg: ArchConfig, lp, x, ctx: ShardCtx):
    """mamba2_block that also returns (conv_tail, final ssm state)."""
    s_cfg = cfg.ssm
    b, s_len, d = x.shape
    d_in = s_cfg.expand * d
    nh = d_in // s_cfg.head_dim
    k = s_cfg.conv_kernel
    h = apply_norm(cfg, x, lp["norm"])
    xz = h @ lp["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_in = ctx.cons(x_in, ctx.b, None, ctx.m)
    x_c = jax.nn.silu(_causal_conv1d(x_in, lp["conv_w"], lp["conv_b"]))
    bc = h @ lp["bc_proj"]
    b_ssm, c_ssm = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus(h @ lp["dt_proj"] + lp["dt_b2"]).astype(jnp.float32)
    xh = x_c.reshape(b, s_len, nh, s_cfg.head_dim)
    y, hf = _ssd_chunked(xh, dt, lp["A_log2"], b_ssm, c_ssm, min(s_cfg.chunk, s_len),
                         bf16_scores=s_cfg.bf16_scores)
    y = y + lp["D"].astype(jnp.float32)[:, None] * xh.astype(jnp.float32)
    y = y.reshape(b, s_len, d_in)
    y = rms_norm(y.astype(x.dtype) * jax.nn.silu(z), lp["gate_norm"])
    out = y @ lp["out_proj"]
    # hf: (B,H,N,P) → cache layout (B,H,N,P); conv tail: last K-1 inputs
    return x + ctx.cons(out, ctx.b, None, None), x_in[:, -(k - 1):], hf


def prefill(cfg: ArchConfig, params, tokens, cache, ctx: ShardCtx = NO_SHARD, **kw):
    """Prompt pass: final SSM/conv states per layer + per-invocation KV caches."""
    x = params["embed"][tokens]
    x = ctx.cons(x, ctx.b, None, None)
    b, s = x.shape[0], x.shape[1]
    max_len = cache["k"].shape[2] if "k" in cache else s
    positions = jnp.arange(s)

    def mamba_body(x, lp):
        return jax.checkpoint(partial(_mamba2_block_with_state, cfg, ctx=ctx))(lp, x)

    if cfg.attn_every:
        shared = params["shared"]

        def shared_with_cache(x):
            h = apply_norm(cfg, x, shared["norm1"])
            q, k, v = attn_qkv(cfg, shared["attn"], h, positions, ctx)
            o = chunked_attention(q, k, v, causal=True, chunk_q=min(cfg.attn_chunk, s),
                                  chunk_k=min(cfg.attn_chunk, s),
                                  exact_causal=cfg.attn_exact_causal)
            x = x + o.reshape(b, s, -1) @ shared["attn"]["wo"]
            h2 = apply_norm(cfg, x, shared["norm2"])
            x = ctx.cons(x + mlp_apply(cfg, shared["mlp"], h2, ctx), ctx.b, None, None)
            kc = jnp.zeros((b, max_len) + k.shape[2:], k.dtype)
            kc = jax.lax.dynamic_update_slice_in_dim(kc, k, 0, 1)
            vc = jnp.zeros((b, max_len) + v.shape[2:], v.dtype)
            vc = jax.lax.dynamic_update_slice_in_dim(vc, v, 0, 1)
            return x, kc, vc

        def group_body(x, gp):
            def inner(x, lp):
                x, ct, sf = mamba_body(x, lp)
                return x, (ct, sf)

            x, (cts, sfs) = xscan(inner, x, gp)
            x, kc, vc = shared_with_cache(x)
            return x, (cts, sfs, kc, vc)

        x, (ct_g, sf_g, kc, vc) = xscan(group_body, x, params["groups"])

        def tail_body(x, lp):
            x, ct, sf = mamba_body(x, lp)
            return x, (ct, sf)

        x, (ct_t, sf_t) = xscan(tail_body, x, params["tail"])
        conv_st = jnp.concatenate([ct_g.reshape((-1,) + ct_g.shape[2:]), ct_t])
        ssm_st = jnp.concatenate([sf_g.reshape((-1,) + sf_g.shape[2:]), sf_t])
        cache = dict(cache, conv=conv_st, ssm=ssm_st, k=kc, v=vc,
                     pos=jnp.asarray(s, jnp.int32))
    else:
        def body(x, lp):
            x, ct, sf = mamba_body(x, lp)
            return x, (ct, sf)

        x, (conv_st, ssm_st) = xscan(body, x, params["layers"])
        cache = dict(cache, conv=conv_st, ssm=ssm_st, pos=jnp.asarray(s, jnp.int32))

    h = apply_norm(cfg, x[:, -1], params["final_norm"])
    logits = (h @ unembed_matrix(cfg, params)).astype(jnp.float32)
    return logits, cache


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or cfg.jdtype
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim
    cache = {"conv": jnp.zeros((cfg.n_layers, batch, s.conv_kernel - 1, d_in), dtype),
             "ssm": jnp.zeros((cfg.n_layers, batch, nh, s.d_state, s.head_dim), jnp.float32),
             "pos": jnp.zeros((), jnp.int32)}
    if cfg.attn_every:
        g, _ = _split_layers(cfg)
        cache["k"] = jnp.zeros((g, batch, max_len, cfg.kv_heads, cfg.hd), dtype)
        cache["v"] = jnp.zeros((g, batch, max_len, cfg.kv_heads, cfg.hd), dtype)
    return cache


def _mamba2_decode(cfg: ArchConfig, lp, x, conv_st, ssm_st, ctx: ShardCtx):
    """One-token mamba2 step. x: (B, d)."""
    s_cfg = cfg.ssm
    d_in = s_cfg.expand * cfg.d_model
    nh = d_in // s_cfg.head_dim
    h = apply_norm(cfg, x, lp["norm"])
    xz = h @ lp["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)
    window = jnp.concatenate([conv_st, x_in[:, None]], axis=1)
    x_c = jax.nn.silu(jnp.einsum("bkd,dk->bd", window, lp["conv_w"]) + lp["conv_b"])
    bc = h @ lp["bc_proj"]
    b_ssm, c_ssm = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus(h @ lp["dt_proj"] + lp["dt_b2"]).astype(jnp.float32)   # (B,H)
    a = jnp.exp(-jnp.exp(lp["A_log2"].astype(jnp.float32)) * dt)                # (B,H)
    xh = (x_c.reshape(-1, nh, s_cfg.head_dim).astype(jnp.float32) * dt[..., None])
    upd = jnp.einsum("bn,bhp->bhnp", b_ssm.astype(jnp.float32), xh)
    ssm_new = a[:, :, None, None] * ssm_st + upd
    y = jnp.einsum("bn,bhnp->bhp", c_ssm.astype(jnp.float32), ssm_new)
    y = y + lp["D"].astype(jnp.float32)[:, None] * x_c.reshape(-1, nh, s_cfg.head_dim).astype(jnp.float32)
    y = y.reshape(-1, d_in)
    y = rms_norm(y.astype(x.dtype) * jax.nn.silu(z), lp["gate_norm"])
    return x + y @ lp["out_proj"], window[:, 1:], ssm_new


def _shared_decode(cfg: ArchConfig, p, x, kc, vc, pos, ctx: ShardCtx):
    b = x.shape[0]
    h = apply_norm(cfg, x[:, None], p["norm1"])
    q, k, v = attn_qkv(cfg, p["attn"], h, pos[None], ctx)
    kc = jax.lax.dynamic_update_slice(kc, k, (0, pos, 0, 0))
    vc = jax.lax.dynamic_update_slice(vc, v, (0, pos, 0, 0))
    o = decode_attention(q[:, 0], kc, vc, pos + 1)
    x = x + o.reshape(b, -1) @ p["attn"]["wo"]
    h2 = apply_norm(cfg, x, p["norm2"])
    return x + mlp_apply(cfg, p["mlp"], h2, ctx), kc, vc


def decode_step(cfg: ArchConfig, params, cache, token, ctx: ShardCtx = NO_SHARD):
    x = params["embed"][token]
    pos = cache["pos"]
    g, trailing = _split_layers(cfg) if cfg.attn_every else (0, cfg.n_layers)

    if cfg.attn_every:
        shared = params["shared"]
        n_grouped = g * cfg.attn_every
        conv_g = jax.tree.map(lambda a: a[:n_grouped].reshape((g, cfg.attn_every) + a.shape[1:]),
                              cache["conv"])
        ssm_g = cache["ssm"][:n_grouped].reshape((g, cfg.attn_every) + cache["ssm"].shape[1:])

        def group_body(x, xs):
            gp, conv_st, ssm_st, kc, vc = xs

            def inner(x, ys):
                lp, cs, ss = ys
                x, cs, ss = _mamba2_decode(cfg, lp, x, cs, ss, ctx)
                return x, (cs, ss)

            x, (conv_st, ssm_st) = xscan(inner, x, (gp, conv_st, ssm_st))
            x, kc, vc = _shared_decode(cfg, shared, x, kc, vc, pos, ctx)
            return x, (conv_st, ssm_st, kc, vc)

        x, (conv_g, ssm_g, kc, vc) = xscan(
            group_body, x, (params["groups"], conv_g, ssm_g, cache["k"], cache["v"]))

        def tail_body(x, ys):
            lp, cs, ss = ys
            x, cs, ss = _mamba2_decode(cfg, lp, x, cs, ss, ctx)
            return x, (cs, ss)

        x, (conv_t, ssm_t) = xscan(
            tail_body, x, (params["tail"], cache["conv"][n_grouped:], cache["ssm"][n_grouped:]))
        conv_new = jnp.concatenate([conv_g.reshape((-1,) + conv_g.shape[2:]), conv_t])
        ssm_new = jnp.concatenate([ssm_g.reshape((-1,) + ssm_g.shape[2:]), ssm_t])
        cache = dict(cache, conv=conv_new, ssm=ssm_new, k=kc, v=vc, pos=pos + 1)
    else:
        def body(x, ys):
            lp, cs, ss = ys
            x, cs, ss = _mamba2_decode(cfg, lp, x, cs, ss, ctx)
            return x, (cs, ss)

        x, (conv_new, ssm_new) = xscan(body, x, (params["layers"], cache["conv"], cache["ssm"]))
        cache = dict(cache, conv=conv_new, ssm=ssm_new, pos=pos + 1)

    h = apply_norm(cfg, x, params["final_norm"])
    logits = (h @ unembed_matrix(cfg, params)).astype(jnp.float32)
    return logits, cache
