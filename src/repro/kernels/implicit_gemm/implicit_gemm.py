"""Implicit-GEMM sparse convolution — the flagship Pallas TPU kernel.

Paper §3.1 (Fig. 7): a sparse conv kernel is a dense GEMM whose operand-A
loads go through one level of indirection (the kernel map).  TPU adaptation
(DESIGN.md §2):

* the kernel map tile lives in **SMEM** (BlockSpec memory_space=SMEM) — the
  structural equivalent of the paper's hoisted, register-resident addressing;
* operand A rows are fetched **HBM→VMEM by per-row async DMA**
  (`pltpu.make_async_copy`), all `tile_m` copies in flight before the MXU
  consumes them — this is the "sparse DRAM→L1 iterator" with overlapped
  memory access and compute (paper Fig. 3d);
* per-(tile, δ) **occupancy scalars** gate the whole gather+matmul with
  `@pl.when` — warp-level zero skipping becomes MXU-tile-level skipping;
* `-1` map entries (paper §3.2 padding) zero the scratch row instead of
  issuing a DMA, so the inner loop has no bounds check.

Grid: (m_tiles, n_tiles, KD_split) with δ innermost; the f32 accumulator
lives in VMEM across δ steps and is written once at the last δ.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import cdiv


def _kernel(midx_ref, occ_ref, x_ref, w_ref, o_ref, scratch, acc, sems, *,
            tile_m: int, cin: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc[...] = jnp.zeros_like(acc)

    @pl.when(occ_ref[0, 0] == 1)
    def _compute():
        # Issue all row gathers (double buffering degenerates to "all in
        # flight": one DMA + semaphore per row).
        for r in range(tile_m):
            idx = midx_ref[r, 0]

            @pl.when(idx >= 0)
            def _start():
                pltpu.make_async_copy(x_ref.at[idx], scratch.at[r], sems.at[r]).start()

            @pl.when(idx < 0)
            def _zero_row():
                scratch[r, :] = jnp.zeros((cin,), scratch.dtype)

        for r in range(tile_m):
            idx = midx_ref[r, 0]

            @pl.when(idx >= 0)
            def _wait():
                pltpu.make_async_copy(x_ref.at[idx], scratch.at[r], sems.at[r]).wait()

        acc[...] += jnp.dot(scratch[...], w_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_m", "tile_n", "interpret"))
def implicit_gemm_pallas(midx: jax.Array, occ: jax.Array, x: jax.Array,
                         w: jax.Array, *, tile_m: int = 128, tile_n: int = 128,
                         interpret: bool = True) -> jax.Array:
    """One split of sorted/unsorted implicit GEMM.

    midx: (N_out_pad, KD) int32 — (already row-permuted) kernel map slice.
    occ:  (N_out_pad // tile_m, KD) int32 — per-(tile, δ) occupancy.
    x:    (N_in, Cin) — input features (stays in HBM; gathered by DMA).
    w:    (KD, Cin, Cout) — weights for this split's offsets.
    Returns (N_out_pad, Cout) partial sums in x.dtype.
    """
    n_out, kd = midx.shape
    _, cin = x.shape
    cout = w.shape[-1]
    assert n_out % tile_m == 0, "pad map rows to tile_m (paper §3.2)"
    assert cout % tile_n == 0, f"Cout {cout} must be a multiple of tile_n {tile_n}"
    grid = (n_out // tile_m, cout // tile_n, kd)

    kernel = functools.partial(_kernel, tile_m=tile_m, cin=cin)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, 1), lambda i, j, k: (i, k), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda i, j, k: (i, k), memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec((1, cin, tile_n), lambda i, j, k: (k, 0, j)),
        ],
        out_specs=pl.BlockSpec((tile_m, tile_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_out, cout), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((tile_m, cin), x.dtype),
            pltpu.VMEM((tile_m, tile_n), jnp.float32),
            pltpu.SemaphoreType.DMA((tile_m,)),
        ],
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(midx, occ, x, w)
