"""Churned-stream serving: scene-granular and incremental map reuse.

The traffic PR-2's whole-batch digest cannot help with: a pool of concurrent
scene streams where every frame re-submits all streams but only a fraction
churn (point-level deltas), so no packed batch ever repeats exactly.  Four
engine configurations replay the same deterministic stream
(``workload.churned_stream``):

* ``cold``     — "sort" strategy with the batch-map LRU disabled: every
  flush pays a full jitted batch map build (the mapping-cost floor);
* ``digest``   — "sort" strategy, PR-2 behavior: the whole-batch digest LRU
  is live but scores only misses on a churned stream;
* ``composed`` — "composed" strategy: per-scene kernel-map stacks cached by
  scene digest and merge-composed into batch maps, so only churned scenes
  ever build maps (at their own size);
* ``delta``    — "incremental" strategy driven through ``submit_delta``:
  churned frames additionally delta-merge their scene's sorted table
  instead of re-sorting the cloud.

Emits wall-clock scenes/s and p50/p95 per-scene latency per variant, the
scene-store hit rates and delta-merge counts, and the composed-vs-digest
throughput and mapping-phase ratios (the scene-granular win the ROADMAP
queues).  ``--tiny`` shrinks the stream and ladder for CI smoke coverage —
at toy scale the jitted batch build costs ~10 ms and composition shows
parity; the full config (2048-cap buckets) is where the measured win lives
(mapping 5.1x/2.9x, end-to-end 1.14x/1.05x — see ROADMAP PR-4).
"""
from __future__ import annotations

import argparse
import time

import jax

from benchmarks import common
from repro.serve.bucketing import BucketLadder
from repro.serve.engine import ARCHS, Engine, EngineStats
from repro.serve.workload import churned_stream


#: leading frames excluded from timing: they fill the scene store (and any
#: first-touch caches), which is exactly the steady state the comparison is
#: about — every variant gets the same treatment.
WARM_FRAMES = 2

#: (tag, table strategy, drive deltas through submit_delta, maps LRU size)
VARIANTS = (("cold", "sort", False, 0),
            ("digest", "sort", False, 32),
            ("composed", "composed", False, 32),
            ("delta", "incremental", True, 32))


def _drive(arch: str, frames, bound: int, ladder: BucketLadder) -> dict:
    """Run all variants **interleaved frame-by-frame** on co-resident warm
    engines, so machine noise at any instant hits every variant equally
    (the same reason bench_training interleaves its fp32/bf16 pairs)."""
    engines = {}
    for tag, strategy, use_delta, maps_cache in VARIANTS:
        eng = Engine(arch, ladder=ladder, spatial_bound=bound,
                     map_strategy=strategy, maps_cache_size=maps_cache)
        eng.warmup()
        eng.stats = EngineStats()
        engines[tag] = (eng, use_delta)
    frame_s = {tag: [] for tag in engines}
    for frame in frames:
        for tag, (eng, use_delta) in engines.items():
            t0 = time.perf_counter()
            for sid, scene, delta in frame:
                if use_delta and delta is not None:
                    eng.submit_delta(sid, delta)
                else:
                    eng.submit(scene, stream=sid)
            eng.flush()
            frame_s[tag].append(time.perf_counter() - t0)
    streams = len(frames[0])
    last_scenes = [scene for _, scene, _ in frames[-1]]
    out = {}
    for tag, (eng, _) in engines.items():
        times = frame_s[tag]
        measured = times[WARM_FRAMES:] if len(times) > WARM_FRAMES else times
        med = sorted(measured)[len(measured) // 2]
        s = eng.stats.summary()
        sc = s["scene_tables"]
        scene_total = max(sc["hits"] + sc["misses"], 1)
        s["wall_scenes_per_s"] = streams / med   # median steady-state frame
        # mapping-phase isolation: the batch map path alone (warm scene
        # store, whole-batch LRU cleared each round) — the executor cost is
        # common to every variant and would otherwise dilute the comparison.
        # Group via plan() exactly as flushes do (a direct pack of all
        # streams could overflow the largest bucket).
        group_idx = eng.batcher.plan([s.num_points for s in last_scenes])[0]
        group = [last_scenes[i] for i in group_idx]
        batch = eng.batcher.pack(group)
        m_times = []
        for _ in range(5):
            eng._map_store.clear()
            t0 = time.perf_counter()
            maps, _ = eng._maps_for(batch, group)
            jax.block_until_ready(jax.tree.leaves(maps))
            m_times.append(time.perf_counter() - t0)
        s["mapping_ms"] = sorted(m_times)[len(m_times) // 2] * 1e3
        derived = (f"scenes_per_s={s['wall_scenes_per_s']:.2f};"
                   f"median_frame_ms={med * 1e3:.1f};"
                   f"mapping_ms={s['mapping_ms']:.1f};"
                   f"p95_ms={s['p95_ms']:.1f};"
                   f"map_hits={s['map_cache']['hits']};"
                   f"map_misses={s['map_cache']['misses']};"
                   f"scene_hit_rate={sc['hits'] / scene_total:.2f};"
                   f"delta_merges={sc['delta_merges']}")
        common.emit(f"streaming/{arch}/{tag}/p50", s["p50_ms"] * 1e3, derived)
        out[tag] = s
    return out


def run(tiny: bool = False):
    if tiny:
        archs = ["centerpoint_waymo"]
        streams, n_frames, n_range = 4, 8, (60, 150)
        ladder = BucketLadder((256, 512), max_batch=4)
        extent, voxel = 16.0, 0.4
    else:
        archs = sorted(ARCHS)
        streams, n_frames, n_range = 6, 16, (150, 400)
        ladder = BucketLadder((512, 1024, 2048), max_batch=6)
        extent, voxel = 50.0, 0.4

    for arch in archs:
        channels = ARCHS[arch].in_channels_of(ARCHS[arch].default_config)
        frames, bound = churned_stream(0, streams, n_frames, channels,
                                       n_range=n_range, extent=extent,
                                       voxel=voxel)
        res = _drive(arch, frames, bound, ladder)
        digest = max(res["digest"]["wall_scenes_per_s"], 1e-9)
        for tag in ("composed", "delta"):
            ratio = res[tag]["wall_scenes_per_s"] / digest
            map_ratio = (res["digest"]["mapping_ms"]
                         / max(res[tag]["mapping_ms"], 1e-9))
            common.emit(f"streaming/{arch}/{tag}_vs_digest", 0.0,
                        f"throughput_ratio={ratio:.2f}x;"
                        f"mapping_speedup={map_ratio:.2f}x")
        common.emit(f"streaming/{arch}/digest_vs_cold", 0.0,
                    f"throughput_ratio="
                    f"{digest / max(res['cold']['wall_scenes_per_s'], 1e-9):.2f}x")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="reduced stream for CI smoke runs")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(tiny=args.tiny)
