"""Paper Tables 3/4 + Fig. 17 — unsorted vs sorted implicit GEMM, measured
BOTH as kernel-only time (maps prebuilt, Table 4) and end-to-end including
the mapping/sorting overhead (Table 3).  The paper's point: the ranking can
FLIP between the two views."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import dataflows as df
from repro.core import kmap as km
from repro.core.sparse_conv import TrainDataflowConfig
from repro.models import centerpoint


def run():
    cfg = centerpoint.CenterPointConfig(width=0.5)
    stx = common.det_scene()
    params = centerpoint.init_params(cfg, jax.random.PRNGKey(0))
    sigs = centerpoint.layer_signatures(cfg)

    variants = {
        "unsorted": df.DataflowConfig("implicit_gemm", n_splits=0),
        "split=1": df.DataflowConfig("implicit_gemm", n_splits=1),
        "split=2": df.DataflowConfig("implicit_gemm", n_splits=2),
    }

    # Table 4: kernel-only (maps + split plans prebuilt outside the timer)
    maps = centerpoint.build_maps(stx)
    for name, c in variants.items():
        amap = {s: TrainDataflowConfig.bind_all(c) for s in set(sigs.values())}
        fn = jax.jit(lambda p: centerpoint.apply(p, stx, cfg, maps, assignment=amap))
        us = common.time_fn(lambda: fn(params))
        common.emit(f"tab4/WM-C/kernel_only/{name}", us, "")

    # Table 3: end-to-end — map building + sorting inside the timed region
    for name, c in variants.items():
        amap = {s: TrainDataflowConfig.bind_all(c) for s in set(sigs.values())}

        def e2e(p):
            m = centerpoint.build_maps(stx)
            # sorting/split-plan cost happens inside the dataflow when the
            # kernel map is fresh; charge it explicitly per offsets group
            for kmp in m.values():
                km.make_split_plan(kmp, max(c.n_splits, 1), sort=c.sorted)
            return centerpoint.apply(p, stx, cfg, m, assignment=amap)

        fn = jax.jit(e2e)
        us = common.time_fn(lambda: fn(params))
        common.emit(f"tab3/WM-C/end_to_end/{name}", us, "")

    # Fig. 17 analogue: redundant-computation stats per variant
    kmp = maps[("sub", 2)]
    for name, c in variants.items():
        plan = km.make_split_plan(kmp, max(c.n_splits, 1), sort=c.sorted)
        stats = km.redundancy_stats(kmp, plan, tile_m=128)
        common.emit(f"fig17/WM-C/overhead/{name}", 0.0,
                    f"compute_overhead={float(stats['overhead']):.2f}x")


if __name__ == "__main__":
    run()
