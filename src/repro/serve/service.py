"""The serving tiers' shared contract: ``SparseService`` + ``ServiceConfig``.

Three front ends serve the same sparse workloads at three scales — the
single-device ``Engine``, the multi-device ``DeviceRouter``, and the
cross-host ``FleetFrontend`` — and the promise of the whole serving stack
is that they are interchangeable: same ``submit``/``flush`` semantics,
bit-identical outputs on the same stream (asserted by the conformance
suite in tests/test_fleet.py).  This module pins that promise down:

* ``SparseService`` — the structural protocol every tier implements.
  Callers (the CLI, benchmarks, tests) program against it, never against a
  concrete tier; ``build_service`` in launch/serve_sparse.py picks the tier
  from deployment shape alone.
* ``ServiceConfig`` — one serializable dataclass holding every behavioral
  knob the tiers share (the bucket ladder, admission deadlines, cache
  bounds, pipeline depth, …).  It crosses process boundaries (the fleet
  ships it to workers as JSON) and persists alongside tuned plans in
  ``PlanRegistry``, so "the config that served this plan" stops being
  folklore.  Legacy per-kwarg construction still works through a
  deprecation shim that warns once per process.
* ``STATS_SCHEMA_VERSION`` — the version stamped into every tier's
  ``stats.summary()`` dict, so the stats schema is an explicit contract
  (benchmarks/check_regression.py tolerates version-suffixed rows instead
  of silently drifting).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import (Dict, List, Optional, Protocol, Sequence, Tuple,
                    runtime_checkable)

from repro.serve.batcher import Scene, SceneDelta, SceneResult
from repro.serve.bucketing import BucketLadder

#: Version of the ``stats.summary()`` dict shape shared by EngineStats /
#: RouterStats / FleetStats.  History: 1 = PR-2 engine stats, 2 = PR-5
#: router ``devices`` merge, 3 = this tier (``hosts``/``fleet`` blocks +
#: the stamp itself).  Bump when a key is renamed or removed — additions
#: are compatible and don't require one.
STATS_SCHEMA_VERSION = 3


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Every shared serving knob, one serializable value.

    The fields mirror the historical ``Engine``/``DeviceRouter`` kwargs —
    see engine.py for per-knob semantics.  ``buckets``/``max_batch`` are
    the ``BucketLadder`` flattened to plain data (``ladder()`` rebuilds
    it); everything here must stay JSON-able because the fleet ships this
    exact dict to worker processes and ``PlanRegistry`` persists it next
    to tuned plans.
    """

    buckets: Tuple[int, ...] = (512, 1024, 2048)
    max_batch: int = 4
    spatial_bound: int = 256
    seed: int = 0
    map_strategy: Optional[str] = None
    maps_cache_size: int = 32
    scene_cache_size: int = 64
    scene_cache_bytes: Optional[int] = None
    max_wait_ms: Optional[float] = None
    flush_count: Optional[int] = None
    max_inflight: int = 2
    deadline_margin: Optional[float] = None
    plan_key: Optional[str] = None

    def __post_init__(self):
        object.__setattr__(self, "buckets", tuple(int(b) for b in self.buckets))
        self.ladder()   # validate: ascending, positive, max_batch >= 1

    def ladder(self) -> BucketLadder:
        return BucketLadder(self.buckets, max_batch=self.max_batch)

    def replace(self, **changes) -> "ServiceConfig":
        return dataclasses.replace(self, **changes)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["buckets"] = list(self.buckets)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ServiceConfig":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown ServiceConfig keys {sorted(unknown)}; "
                             f"known: {sorted(known)}")
        return cls(**d)

    @classmethod
    def from_ladder(cls, ladder: BucketLadder, **kw) -> "ServiceConfig":
        return cls(buckets=ladder.capacities, max_batch=ladder.max_batch, **kw)


#: ServiceConfig fields a tier constructor accepts as direct (legacy)
#: kwargs, plus ``ladder`` which flattens into buckets/max_batch.
_LEGACY_FIELDS = frozenset(f.name for f in dataclasses.fields(ServiceConfig)
                           if f.name not in ("buckets", "max_batch"))

#: one-shot flag for the legacy-kwarg deprecation warning (a serving test
#: suite constructs hundreds of engines; one nudge per process is enough)
_LEGACY_WARNED = [False]


def resolve_config(config: Optional[ServiceConfig],
                   legacy: dict) -> ServiceConfig:
    """Fold legacy per-kwarg construction into one ``ServiceConfig``.

    config: an explicit ServiceConfig (the modern path) or None.
    legacy: constructor ``**kwargs`` — ``ladder`` plus any ServiceConfig
        field name.  Unknown names raise TypeError (typo protection —
        exactly what ``**kwargs`` would otherwise silently eat); known
        names override ``config``'s fields and warn once per process.
    """
    changes = {}
    ladder = legacy.pop("ladder", None)
    if ladder is not None:
        changes["buckets"] = ladder.capacities
        changes["max_batch"] = ladder.max_batch
    unknown = set(legacy) - _LEGACY_FIELDS
    if unknown:
        raise TypeError(f"unexpected serving kwargs {sorted(unknown)}; "
                        f"pass a ServiceConfig or one of "
                        f"{sorted(_LEGACY_FIELDS | {'ladder'})}")
    changes.update(legacy)
    if changes and not _LEGACY_WARNED[0]:
        _LEGACY_WARNED[0] = True
        warnings.warn(
            "per-kwarg serving construction (ladder=…, max_wait_ms=…, …) is "
            "deprecated: pass config=ServiceConfig(...) — legacy kwargs keep "
            "working but this warning fires once per process",
            DeprecationWarning, stacklevel=3)
    base = config if config is not None else ServiceConfig()
    return base.replace(**changes) if changes else base


@runtime_checkable
class SparseService(Protocol):
    """What every serving tier exposes — program against this, not a tier.

    ``stats`` is an attribute whose ``summary()`` returns the shared
    stats dict (stamped with ``STATS_SCHEMA_VERSION``); the methods mirror
    ``Engine``'s request API exactly.  ``isinstance(x, SparseService)``
    works (structurally) on all three tiers.
    """

    config: ServiceConfig
    stats: object        # EngineStats | RouterStats | FleetStats

    def submit(self, scene: Scene, stream: Optional[str] = None) -> int: ...

    def submit_delta(self, stream: str, delta: SceneDelta) -> int: ...

    def poll(self) -> Dict[int, SceneResult]: ...

    def flush(self) -> Dict[int, SceneResult]: ...

    def serve(self, scenes: Sequence[Scene],
              flush_every: int = 0) -> List[SceneResult]: ...

    def warmup(self, channels: Optional[int] = None) -> None: ...

    def tune(self, sample_scenes: Sequence[Scene], **kw) -> dict: ...
