"""Execution-plan IR: compile/serialize round-trips, plan-vs-per-call
bit-identity, mixed-precision policies through all three dataflows, the
plan-producing tuners, and the v2 PlanRegistry with its v1 shim."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dataflows as df
from repro.core import generator
from repro.core import kmap as km
from repro.core import plan as planlib
from repro.core import precision as prec
from repro.core.plan import (NetworkPlan, PlanTuner, TrainingPlanTuner,
                             compile_plan)
from repro.core.sparse_conv import TrainDataflowConfig, apply_conv
from repro.data.synthetic import lidar_scene
from repro.models import centerpoint, minkunet
from repro.serve import Engine, PlanRegistry
from repro.serve.bucketing import BucketLadder
from tests.test_kmap import random_tensor


def det_scene(n=300, cap=512):
    """The deterministic CenterPoint detection scene (benchmarks.common
    parameters at CI scale)."""
    return lidar_scene(jax.random.PRNGKey(0), n, cap, 5, extent=75.0, voxel=0.8)


MU_CFG = minkunet.MinkUNetConfig(in_channels=4, num_classes=5, width=0.25,
                                 blocks_per_stage=1)
CP_CFG = centerpoint.CenterPointConfig(width=0.5)


# ---------------------------------------------------------------------------
# Compile: structure
# ---------------------------------------------------------------------------

def test_compile_partitions_groups_and_binds_assignment():
    amap = {(1, 3, "sub"): TrainDataflowConfig.bind_all(
        df.DataflowConfig("gather_scatter"))}
    nplan = minkunet.network_plan(MU_CFG, assignment=amap)
    assert all(lp.group for lp in nplan.layers)
    # layers in one group share a signature and a config
    for g in nplan.groups():
        sigs = {nplan.layer(n).sig for n in g.layer_names}
        cfgs = {nplan.layer(n).dataflow for n in g.layer_names}
        assert len(sigs) == 1 and len(cfgs) == 1
    assert nplan.layer("stem1").dataflow.fwd.dataflow == "gather_scatter"
    assert nplan.layer("down0").dataflow == TrainDataflowConfig()
    # the signature view matches the historical layer_signatures
    assert nplan.signatures() == minkunet.layer_signatures(MU_CFG)
    # map program declares the adoption edges explicitly
    down_specs = [ms for ms in nplan.map_specs if ms.kind == "down"]
    assert down_specs and all(ms.adopts_output_table for ms in down_specs)
    up_specs = [ms for ms in nplan.map_specs if ms.kind == "up"]
    assert up_specs and all(ms.transpose_of == ("down", ms.ref[1])
                            for ms in up_specs)


def test_resolve_tiles_uses_generator_adaptive_tiling():
    stx = random_tensor(0, n=150, cap=256, channels=5, extent=16)
    nplan = centerpoint.network_plan(CP_CFG)
    maps = nplan.build_maps(stx)
    small = nplan.resolve_tiles(maps, threshold_macs=1e18)
    large = nplan.resolve_tiles(maps, threshold_macs=1.0)
    for lp in small.layers:
        assert (lp.dataflow.fwd.tile_m, lp.dataflow.fwd.tile_n) == generator.SMALL_TILES
    for lp in large.layers:
        assert (lp.dataflow.fwd.tile_m, lp.dataflow.fwd.tile_n) == generator.LARGE_TILES
    # non-implicit-gemm configs are left alone
    gs = nplan.with_assignment({lp.sig: TrainDataflowConfig.bind_all(
        df.DataflowConfig("gather_scatter")) for lp in nplan.layers})
    assert gs.resolve_tiles(maps, threshold_macs=1.0).layers == gs.layers


# ---------------------------------------------------------------------------
# Serialize → load → bit-identical forward
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("model,cfg,scene", [
    (minkunet, MU_CFG, dict(n=200, cap=256, channels=4, extent=16)),
    (centerpoint, CP_CFG, dict(n=200, cap=256, channels=5, extent=20)),
])
def test_network_plan_json_roundtrip_bit_identical(model, cfg, scene):
    amap = {(1, 3, "sub"): TrainDataflowConfig.bind_fwd_dgrad(
        df.DataflowConfig("implicit_gemm", n_splits=2, tile_m=64),
        df.DataflowConfig("fetch_on_demand"))}
    nplan = model.network_plan(cfg, assignment=amap, precision="bf16")
    loaded = NetworkPlan.from_dict(json.loads(json.dumps(nplan.to_dict())))
    assert loaded == nplan  # full structural equality incl. precision
    # and the fp32 variant executes bit-identically after the round trip
    nplan32 = nplan.with_precision("fp32")
    loaded32 = NetworkPlan.from_dict(json.loads(json.dumps(nplan32.to_dict())))
    stx = random_tensor(4, **scene)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    maps = loaded32.build_maps(stx)
    np.testing.assert_array_equal(
        np.asarray(nplan32.apply(params, stx, maps)),
        np.asarray(loaded32.apply(params, stx, maps)))


def test_plan_rejects_unknown_fields_and_versions():
    nplan = centerpoint.network_plan(CP_CFG)
    d = nplan.to_dict()
    with pytest.raises(ValueError):
        NetworkPlan.from_dict({**d, "bogus": 1})
    with pytest.raises(ValueError):
        NetworkPlan.from_dict({**d, "version": 99})
    with pytest.raises(ValueError):
        TrainDataflowConfig.from_dict({**TrainDataflowConfig().to_dict(),
                                       "bogus": {}})
    with pytest.raises(ValueError):
        prec.PrecisionPolicy.from_dict({"compute": "bfloat16", "bogus": 1})
    assert prec.PrecisionPolicy.from_dict(prec.BF16.to_dict()) == prec.BF16
    # autocast-style policy: bf16 compute numerics, fp32 storage, no master
    assert prec.BF16_AMP.compute == "bfloat16"
    assert not prec.BF16_AMP.master_weights
    p32 = jnp.ones((4,), jnp.float32)
    assert prec.BF16_AMP.cast_param(p32) is p32       # params left fp32
    assert prec.BF16.cast_param(p32).dtype == jnp.bfloat16
    assert prec.bf16_training_policy("cpu") == prec.BF16_AMP
    assert prec.bf16_training_policy("tpu") == prec.BF16


# ---------------------------------------------------------------------------
# Plan-driven execution ≡ pre-refactor per-call path
# ---------------------------------------------------------------------------

def _precall_centerpoint(params, st, cfg, maps, bn_mode="batch"):
    """The pre-plan hand-written CenterPoint forward, verbatim."""
    x = apply_conv(params["stem"], st, maps[("sub", 1)])
    x = planlib.bn_relu(params["stem_bn"], x, mode=bn_mode)
    stride = 1
    for i in range(len(cfg.channels)):
        x = apply_conv(params[f"down{i}"], x, maps[("down", stride)])
        x = planlib.bn_relu(params[f"down{i}_bn"], x, mode=bn_mode)
        stride *= 2
        for b in range(cfg.sub_convs_per_stage):
            x = apply_conv(params[f"sub{i}_{b}"], x, maps[("sub", stride)])
            x = planlib.bn_relu(params[f"sub{i}_{b}_bn"], x, mode=bn_mode)
    return x.feats


def test_plan_equals_precall_path_on_deterministic_scene():
    stx = det_scene()
    params = centerpoint.init_params(CP_CFG, jax.random.PRNGKey(0))
    nplan = centerpoint.network_plan(CP_CFG)
    maps = nplan.build_maps(stx)
    for bn_mode in ("batch", "affine"):
        ref = _precall_centerpoint(params, stx, CP_CFG, maps, bn_mode=bn_mode)
        got = nplan.apply(params, stx, maps, bn_mode=bn_mode)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    # module-level apply (the historical entry point) matches too
    np.testing.assert_array_equal(
        np.asarray(centerpoint.apply(params, stx, CP_CFG, maps)),
        np.asarray(_precall_centerpoint(params, stx, CP_CFG, maps)))


# ---------------------------------------------------------------------------
# Mixed precision: bf16 fwd/dgrad/wgrad vs fp32 on all three dataflows
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("flow", df.DATAFLOWS)
def test_bf16_policy_close_to_fp32_all_kernels(flow):
    stx = random_tensor(1, n=80, cap=96, channels=8, extent=8)
    kmap = km.build_kmap(stx, 3, 1)
    cfg = df.DataflowConfig(flow)
    w = jax.random.normal(jax.random.PRNGKey(2), (27, 8, 16)) * 0.2
    dy = jax.random.normal(jax.random.PRNGKey(3), (kmap.capacity, 16))
    xb, wb, dyb = (stx.feats.astype(jnp.bfloat16), w.astype(jnp.bfloat16),
                   dy.astype(jnp.bfloat16))

    y32 = df.sparse_conv_forward(stx.feats, w, kmap, cfg)
    ybf = df.sparse_conv_forward(xb, wb, kmap, cfg, precision=prec.BF16)
    assert ybf.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(ybf, np.float32), np.asarray(y32),
                               rtol=5e-2, atol=5e-2)

    dx32 = df.sparse_conv_dgrad(dy, w, kmap, cfg, in_capacity=stx.capacity)
    dxbf = df.sparse_conv_dgrad(dyb, wb, kmap, cfg, in_capacity=stx.capacity,
                                precision=prec.BF16)
    assert dxbf.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(dxbf, np.float32), np.asarray(dx32),
                               rtol=5e-2, atol=5e-2)

    dw32 = df.sparse_conv_wgrad(stx.feats, dy, kmap, cfg)
    dwbf = df.sparse_conv_wgrad(xb, dyb, kmap, cfg, precision=prec.BF16)
    assert dwbf.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(dwbf, np.float32), np.asarray(dw32),
                               rtol=5e-2, atol=0.3)


def test_fp32_policy_is_bit_identical_to_default():
    stx = random_tensor(2, n=60, cap=64, channels=4, extent=8)
    kmap = km.build_kmap(stx, 3, 1)
    w = jax.random.normal(jax.random.PRNGKey(4), (27, 4, 8)) * 0.2
    dy = jax.random.normal(jax.random.PRNGKey(5), (kmap.capacity, 8))
    for flow in df.DATAFLOWS:
        cfg = df.DataflowConfig(flow)
        np.testing.assert_array_equal(
            np.asarray(df.sparse_conv_forward(stx.feats, w, kmap, cfg)),
            np.asarray(df.sparse_conv_forward(stx.feats, w, kmap, cfg,
                                              precision=prec.FP32)))
        np.testing.assert_array_equal(
            np.asarray(df.sparse_conv_dgrad(dy, w, kmap, cfg)),
            np.asarray(df.sparse_conv_dgrad(dy, w, kmap, cfg,
                                            precision=prec.FP32)))
        np.testing.assert_array_equal(
            np.asarray(df.sparse_conv_wgrad(stx.feats, dy, kmap, cfg)),
            np.asarray(df.sparse_conv_wgrad(stx.feats, dy, kmap, cfg,
                                            precision=prec.FP32)))


def test_bf16_plan_trains_with_master_weights():
    """End-to-end mixed-precision training: bf16 conv params + fp32 master
    weights descend on the segmentation toy problem."""
    from repro.train import optimizer as opt

    stx = lidar_scene(jax.random.PRNGKey(0), 300, 256, 4, extent=20.0, voxel=0.5)
    nplan = minkunet.network_plan(MU_CFG, precision="bf16")
    params = nplan.cast_params(minkunet.init_params(MU_CFG, jax.random.PRNGKey(1)))
    maps = nplan.build_maps(stx)
    labels = jax.random.randint(jax.random.PRNGKey(2), (256,), 0, 5)
    ocfg = opt.AdamWConfig(lr=3e-3, weight_decay=0.0, master_weights=True)
    state = opt.init_opt_state(params, ocfg)
    assert "master" in state
    assert all(x.dtype == jnp.float32
               for x in jax.tree.leaves(state["master"]))

    @jax.jit
    def step(params, state):
        def loss(p):
            lg = nplan.apply(p, stx, maps).astype(jnp.float32)
            ls = jax.nn.log_softmax(lg)[jnp.arange(256), labels]
            return -jnp.sum(jnp.where(stx.valid_mask, ls, 0)) / jnp.maximum(stx.num_valid, 1)

        l, g = jax.value_and_grad(loss)(params)
        p2, s2, _ = opt.adamw_update(params, g, state, ocfg)
        return p2, s2, l

    losses = []
    for _ in range(8):
        params, state, l = step(params, state)
        losses.append(float(l))
    assert params["stem1"]["w"].dtype == jnp.bfloat16
    assert losses[-1] < losses[0] * 0.9, losses


def test_master_weights_accumulate_sub_ulp_updates():
    """Updates smaller than one bf16 ulp vanish without the fp32 master."""
    from repro.train import optimizer as opt

    p = {"w": jnp.ones((4,), jnp.bfloat16)}
    g = {"w": jnp.full((4,), 1e-3, jnp.bfloat16)}
    for master, moved in ((False, False), (True, True)):
        cfg = opt.AdamWConfig(lr=1e-4, weight_decay=0.0, master_weights=master)
        params, state = p, opt.init_opt_state(p, cfg)
        for _ in range(20):
            params, state, _ = opt.adamw_update(params, g, state, cfg)
        changed = bool(jnp.any(params["w"] != p["w"]))
        assert changed == moved, (master, np.asarray(params["w"], np.float32))


# ---------------------------------------------------------------------------
# Plan-producing tuners
# ---------------------------------------------------------------------------

def _cost_measure(table):
    """Synthetic end-to-end cost: Σ per-group cost of the assigned fwd
    dataflow (reads the candidate plan, no jit)."""
    def measure(nplan: NetworkPlan) -> float:
        seen = {}
        for lp in nplan.layers:
            seen.setdefault(lp.sig, lp.dataflow.fwd.dataflow)
        return 1.0 + sum(table[sig][flow] for sig, flow in seen.items())

    return measure


def test_plan_tuner_returns_tuned_network_plan():
    nplan = centerpoint.network_plan(CP_CFG)
    space = [df.DataflowConfig("gather_scatter"),
             df.DataflowConfig("implicit_gemm", n_splits=1)]
    rng = np.random.default_rng(0)
    sigs = sorted({lp.sig for lp in nplan.layers}, key=str)
    table = {sig: {c.dataflow: float(rng.uniform(1, 10)) for c in space}
             for sig in sigs}
    tuned = PlanTuner(nplan, space, _cost_measure(table)).tune()
    assert isinstance(tuned, NetworkPlan)
    for sig in sigs:
        best_flow = min(table[sig], key=table[sig].get)
        got = tuned.assignment()[sig]
        assert got.fwd.dataflow == best_flow
        assert got == TrainDataflowConfig.bind_all(got.fwd)  # inference binding
    # the input plan is immutable — tuning returns a new artifact
    assert nplan.assignment() != tuned.assignment()


def test_training_plan_tuner_binds_decoupled_configs():
    nplan = centerpoint.network_plan(CP_CFG)
    space = [df.DataflowConfig("gather_scatter"),
             df.DataflowConfig("implicit_gemm", n_splits=1)]

    # fwd/dgrad prefer implicit, wgrad prefers gather (paper Fig. 13 shape)
    def measure(candidate: NetworkPlan) -> float:
        t = 0.0
        for sig, c3 in candidate.assignment().items():
            t += 1.0 if c3.fwd.dataflow == "implicit_gemm" else 2.0
            t += 1.0 if c3.dgrad.dataflow == "implicit_gemm" else 2.0
            t += 1.0 if c3.wgrad.dataflow == "gather_scatter" else 3.0
        return t

    tuned = TrainingPlanTuner(nplan, space, measure, "bind_fwd_dgrad").tune()
    for c3 in tuned.assignment().values():
        assert c3.fwd.dataflow == "implicit_gemm"
        assert c3.dgrad.dataflow == "implicit_gemm"
        assert c3.wgrad.dataflow == "gather_scatter"


# ---------------------------------------------------------------------------
# PlanRegistry v2 + v1 shim + engine integration
# ---------------------------------------------------------------------------

def test_plan_registry_v2_persists_network_plans(tmp_path):
    nplan = centerpoint.network_plan(
        CP_CFG, assignment={(1, 3, "sub"): TrainDataflowConfig.bind_all(
            df.DataflowConfig("gather_scatter"))})
    reg = PlanRegistry()
    reg.set("centerpoint_waymo", nplan.assignment(), network=nplan)
    path = reg.save(str(tmp_path / "plans.json"))
    doc = json.loads(open(path).read())
    assert doc["version"] == 2
    loaded = PlanRegistry.load(path)
    assert loaded.get("centerpoint_waymo") == nplan.assignment()
    assert loaded.network("centerpoint_waymo") == nplan
    assert loaded.network("never_tuned") is None


def test_plan_registry_v1_shim_loads_pr2_files(tmp_path):
    """A persisted v1 plans JSON (PR 2 schema) still loads: assignments are
    read and the engine recompiles its NetworkPlan from the declaration."""
    cfg3 = TrainDataflowConfig.bind_all(df.DataflowConfig("gather_scatter"))
    v1 = {"version": 1,
          "plans": {"minkunet_kitti": {"1:3:sub": cfg3.to_dict()}}}
    path = tmp_path / "plans_v1.json"
    path.write_text(json.dumps(v1))
    reg = PlanRegistry.load(str(path))
    assert reg.get("minkunet_kitti") == {(1, 3, "sub"): cfg3}
    assert reg.network("minkunet_kitti") is None
    # engine startup on the v1 file: assignment lands in the compiled plan
    eng = Engine("minkunet_kitti", ladder=BucketLadder((256,), max_batch=2),
                 spatial_bound=64, plans=str(path))
    assert eng.assignment == {(1, 3, "sub"): cfg3}
    assert eng.nplan.layer("stem1").dataflow == cfg3
    assert eng.nplan.layer("down0").dataflow == TrainDataflowConfig()


def test_engine_prefers_persisted_network_plan(tmp_path):
    binding_cfg = None
    from repro.serve.engine import ARCHS

    cfg = ARCHS["centerpoint_waymo"].default_config
    nplan = centerpoint.network_plan(cfg).with_assignment(
        {(1, 3, "sub"): TrainDataflowConfig.bind_all(
            df.DataflowConfig("fetch_on_demand"))})
    reg = PlanRegistry()
    reg.set("centerpoint_waymo", nplan.assignment(), network=nplan)
    path = reg.save(str(tmp_path / "plans.json"))
    eng = Engine("centerpoint_waymo", ladder=BucketLadder((256,), max_batch=2),
                 spatial_bound=64, plans=path)
    assert eng.nplan == nplan


def test_measured_resolve_tiles_searches_pallas_groups():
    """With a measure callable, resolve_tiles runs a greedy per-group tile
    search over the Pallas implicit-GEMM groups (end-to-end latency, like
    the dataflow tuner) instead of trusting the MAC heuristic; XLA groups
    keep the heuristic tiles (tile choice can't matter to them)."""
    stx = random_tensor(0, n=150, cap=256, channels=5, extent=16)
    nplan = centerpoint.network_plan(CP_CFG)
    maps = nplan.build_maps(stx)
    sigs = sorted({lp.sig for lp in nplan.layers}, key=str)
    pallas_sig, xla_sig = sigs[0], sigs[1]
    nplan = nplan.with_assignment({
        pallas_sig: TrainDataflowConfig.bind_all(
            df.DataflowConfig("implicit_gemm", n_splits=1, backend="pallas")),
        xla_sig: TrainDataflowConfig.bind_all(
            df.DataflowConfig("implicit_gemm", n_splits=1))})

    calls = []

    def measure(p: NetworkPlan) -> float:
        fwd = p.assignment()[pallas_sig].fwd
        calls.append((fwd.tile_m, fwd.tile_n))
        return 1.0 + 0.01 * abs(fwd.tile_m - 64) + 0.01 * abs(fwd.tile_n - 128)

    resolved = nplan.resolve_tiles(maps, measure=measure)
    got = resolved.assignment()[pallas_sig].fwd
    assert (got.tile_m, got.tile_n) == (64, 128)
    # the search actually tried the generator's tile menu
    assert set(calls) >= {generator.SMALL_TILES, generator.LARGE_TILES}
    # the xla group took the MAC heuristic, not a measured pick
    heur = nplan.resolve_tiles(maps).assignment()[xla_sig].fwd
    assert resolved.assignment()[xla_sig].fwd == heur
    # no measure → pure heuristic, unchanged behavior
    assert nplan.resolve_tiles(maps).assignment()[pallas_sig].fwd.tile_m in (
        generator.SMALL_TILES[0], generator.LARGE_TILES[0])


def test_plan_tuner_measures_pallas_axis_and_resolves_tiles():
    """End-to-end: PlanTuner with maps searches the dataflow×backend space
    (including the worklist variant) and follows with measured tile
    resolution on the winning Pallas groups."""
    stx = random_tensor(0, n=150, cap=256, channels=5, extent=16)
    nplan = centerpoint.network_plan(CP_CFG)
    maps = nplan.build_maps(stx)
    space = [df.DataflowConfig("gather_scatter"),
             df.DataflowConfig("implicit_gemm", n_splits=1, backend="pallas",
                               worklist=True)]

    def measure(p: NetworkPlan) -> float:
        t = 1.0
        for _, c3 in p.assignment().items():
            fwd = c3.fwd
            t += 1.0 if fwd.effective_backend("fwd") == "pallas" else 5.0
            if fwd.dataflow == "implicit_gemm":
                t += 0.01 * abs(fwd.tile_m - 64)
        return t

    tuned = PlanTuner(nplan, space, measure, maps=maps).tune()
    for _, c3 in tuned.assignment().items():
        assert c3.fwd.backend == "pallas" and c3.fwd.worklist
        assert c3.fwd.effective_backend("fwd") == "pallas"
        assert c3.fwd.tile_m == 64
    # worklist configs demand pre-built split plans on the executor side
    assert tuned.split_plan_specs()


def test_tuned_pallas_plan_roundtrips_registry(tmp_path):
    """A tuned plan carrying pallas assignments (worklist variant included)
    and measured tiles survives PlanRegistry JSON round-trip bit-exactly —
    including the derived effective_backend stamp in the serialized form."""
    stx = random_tensor(0, n=150, cap=256, channels=5, extent=16)
    nplan = centerpoint.network_plan(CP_CFG)
    maps = nplan.build_maps(stx)
    space = [df.DataflowConfig("implicit_gemm", n_splits=2, backend="pallas",
                               worklist=True),
             df.DataflowConfig("gather_scatter", backend="pallas")]

    def measure(p: NetworkPlan) -> float:
        return sum(1.0 if c3.fwd.dataflow == "implicit_gemm" else 2.0
                   for c3 in p.assignment().values())

    tuned = PlanTuner(nplan, space, measure, maps=maps).tune()
    reg = PlanRegistry()
    reg.set("centerpoint_waymo", tuned.assignment(), network=tuned)
    path = reg.save(str(tmp_path / "plans.json"))
    doc = json.loads(open(path).read())
    blob = json.dumps(doc)
    assert '"worklist": true' in blob
    assert '"effective_backend": "pallas"' in blob
    loaded = PlanRegistry.load(path)
    assert loaded.network("centerpoint_waymo") == tuned
    assert loaded.get("centerpoint_waymo") == tuned.assignment()
