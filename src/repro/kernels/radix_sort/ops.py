"""Spec-aware wrapper: packed keys → radix argsort via the Pallas kernel.

Mirrors ``repro.core.hashing.radix_argsort_keys`` (the XLA twin) exactly:
sentinel remap onto the dense domain, lo-word passes then hi-word passes
for two-word keys (stable LSD).  The permutation is bit-identical to the
stable comparison argsort the table build historically used — pads
(``PAD`` → int32 max, sorts last) and ``MISS`` (-1, sorts first)
included.
"""
from __future__ import annotations

import jax

from repro.core.hashing import KeySpec, _remap_radix_word, radix_word_bits
from repro.kernels.common import default_interpret
from repro.kernels.radix_sort.radix_sort import radix_argsort_bits_pallas


def radix_argsort(keys: jax.Array, spec: KeySpec,
                  *, interpret: bool | None = None) -> jax.Array:
    """Argsort permutation of packed keys ((N,) or (N, 2) int32) under a
    bounded spec.  Returns (N,) int32."""
    if interpret is None:
        interpret = default_interpret()
    wb = radix_word_bits(spec)
    if wb is None:
        raise ValueError(f"radix sort needs a bounded spec, got {spec}")
    if spec.words == 1:
        return radix_argsort_bits_pallas(
            _remap_radix_word(keys, wb[0]), nbits=wb[0] + 1,
            interpret=interpret)
    lo = _remap_radix_word(keys[:, 1], wb[0])
    hi = _remap_radix_word(keys[:, 0], wb[1])
    order = radix_argsort_bits_pallas(lo, nbits=wb[0] + 1, interpret=interpret)
    return order[radix_argsort_bits_pallas(hi[order], nbits=wb[1] + 1,
                                           interpret=interpret)]
