"""Oracle for the radix argsort: the stable comparison argsort whose
permutation (layout incl. the PAD tail) defines the table contract."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.hashing import lex_argsort


def radix_argsort_ref(keys: jax.Array) -> jax.Array:
    """Stable argsort permutation of packed keys ((N,) or (N, W) MSB-first)."""
    if keys.ndim == 1:
        return jnp.argsort(keys, stable=True).astype(jnp.int32)
    return lex_argsort(keys)
