"""The observability layer (repro.obs): span nesting and thread
attribution, the disabled-tracer no-op fast path (bounded overhead, zero
retained allocations), exporter schema round-trips, SLO accounting, and
the serving integration contract — exactly one ``compile`` event per
(rung, stage) on a cold stream and none on the warm replay, with
``summary()`` phases reconciling against the recorded spans."""
import gc
import json
import sys
import threading
import time

import numpy as np
import pytest

from conftest import property_test
from repro import obs
from repro.obs.trace import Tracer


@pytest.fixture(autouse=True)
def _fresh_default_tracer():
    """Every test starts and ends with the process default DISABLED — an
    enabled global leaking across tests would slow the whole suite."""
    obs.disable()
    yield
    obs.disable()


# ------------------------------------------------------------------- tracer

def test_span_nesting_depth_and_containment():
    tr = Tracer()
    with tr.span("outer", kind="a"):
        with tr.span("inner"):
            with tr.span("leaf"):
                pass
    spans = {s.name: s for s in tr.spans()}
    assert [spans[n].depth for n in ("outer", "inner", "leaf")] == [0, 1, 2]
    # exit order: leaf records first
    assert [s.name for s in tr.spans()] == ["leaf", "inner", "outer"]
    # time containment: children lie inside the parent interval
    assert spans["outer"].t0_ns <= spans["inner"].t0_ns
    assert spans["inner"].t1_ns <= spans["outer"].t1_ns
    assert spans["outer"].attrs == {"kind": "a"}
    assert spans["leaf"].dur_ms >= 0.0


@property_test(
    "depths",
    cases=[[1, 3, 2], [5], [2, 2, 2, 2]],
    strategies=lambda st: {"depths": st.lists(
        st.integers(min_value=1, max_value=6), min_size=1, max_size=5)})
def test_span_depths_reset_between_roots(depths):
    """Each root-level nest starts back at depth 0, however deep the
    previous one went (per-thread stack pops what it pushes)."""
    tr = Tracer()
    for d in depths:
        ctxs = [tr.span(f"level{i}") for i in range(d)]
        for c in ctxs:
            c.__enter__()
        for c in reversed(ctxs):
            c.__exit__(None, None, None)
    recorded = [s.depth for s in tr.spans()]
    expected = [d for want in depths for d in reversed(range(want))]
    assert recorded == expected


def test_spans_attribute_to_their_thread():
    tr = Tracer()

    def worker():
        with tr.span("work"):
            with tr.span("inner"):
                pass

    threads = [threading.Thread(target=worker, name=f"w{i}")
               for i in range(3)]
    with tr.span("main"):
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    by_thread = {}
    for s in tr.spans():
        by_thread.setdefault(s.thread, []).append(s)
    assert set(by_thread) == {"w0", "w1", "w2", "MainThread"}
    for name in ("w0", "w1", "w2"):
        # each worker's stack is independent: its root span is depth 0
        # even while the main thread holds an open span
        assert sorted(s.depth for s in by_thread[name]) == [0, 1]
    # the main thread's tid is distinct from every worker's (worker idents
    # may be reused between workers once a thread exits, so no exact count)
    main_tid = threading.main_thread().ident
    assert {s.tid for s in by_thread["MainThread"]} == {main_tid}
    assert main_tid not in {s.tid for name in ("w0", "w1", "w2")
                            for s in by_thread[name]}


def test_record_span_retroactive_interval():
    tr = Tracer()
    t0 = time.perf_counter_ns()
    t1 = t0 + 5_000_000   # 5 ms measured elsewhere
    tr.record_span("queue_wait", t0, t1, ticket=7)
    (s,) = tr.spans()
    assert (s.t0_ns, s.t1_ns, s.attrs) == (t0, t1, {"ticket": 7})
    assert s.dur_ms == pytest.approx(5.0)


def test_set_attaches_mid_span_attrs():
    tr = Tracer()
    with tr.span("tune", group="g0") as sp:
        sp.set(latency_ms=12.5)
    (s,) = tr.spans()
    assert s.attrs == {"group": "g0", "latency_ms": 12.5}


def test_bounded_storage_counts_drops():
    tr = Tracer(max_records=3)
    for i in range(5):
        with tr.span(f"s{i}"):
            pass
        tr.event(f"e{i}")
    assert len(tr.spans()) == 3 and len(tr.events()) == 3
    # keep-earliest: the interesting part of a trace is its start
    assert [s.name for s in tr.spans()] == ["s0", "s1", "s2"]
    assert tr.dropped == 4
    assert tr.snapshot()["dropped"] == 4


def test_counters_gauges_snapshot_and_clear():
    tr = Tracer(enabled=False)     # counters/gauges stay live when disabled
    tr.count("requests")
    tr.count("requests", 2)
    tr.gauge("queue_depth", 7.0)
    snap = tr.snapshot()
    assert snap["counters"] == {"requests": 3}
    assert snap["gauges"] == {"queue_depth": 7.0}
    assert snap["spans"] == 0 and snap["events"] == 0
    tr.clear()
    assert tr.snapshot()["counters"] == {}


def test_phase_summary_percentiles():
    tr = Tracer()
    base = time.perf_counter_ns()
    for i in range(10):
        tr.record_span("phase", base, base + (i + 1) * 1_000_000)
    s = tr.phase_summary()["phase"]
    assert s["count"] == 10
    assert s["p50_ms"] == pytest.approx(6.0)    # sorted-index percentile
    assert s["p95_ms"] == pytest.approx(10.0)
    assert s["total_ms"] == pytest.approx(55.0)


# ------------------------------------------------- disabled-tracer fast path

def test_disabled_span_is_the_noop_singleton():
    assert obs.span("anything", a=1) is obs.NOOP_SPAN
    assert obs.get_tracer().span("x") is obs.NOOP_SPAN
    with obs.span("x") as sp:
        assert sp.set(k=2) is obs.NOOP_SPAN
    obs.event("x", a=1)            # all no-ops, nothing recorded
    obs.record_span("x", 0, 1)
    assert obs.get_tracer().spans() == []
    assert obs.get_tracer().events() == []


def test_disabled_span_retains_zero_allocations():
    def burst(n):
        for _ in range(n):
            with obs.span("hot", bucket=512):
                pass
    burst(100)                      # warm any lazy interpreter state
    gc.collect()
    before = sys.getallocatedblocks()
    burst(1000)
    gc.collect()
    after = sys.getallocatedblocks()
    # transient kwargs dicts are freed; nothing is retained per call
    assert after - before <= 5, f"leaked {after - before} blocks"


def test_disabled_span_overhead_is_negligible():
    n = 20_000

    def noop_pass():
        for _ in range(n):
            pass

    def instrumented():
        for _ in range(n):
            with obs.span("hot"):
                pass

    noop_pass(); instrumented()     # warmup
    t0 = time.perf_counter(); instrumented(); dt = time.perf_counter() - t0
    per_call_us = dt / n * 1e6
    # a truthiness check + context-manager protocol on a preallocated
    # singleton: single-digit µs even on a loaded shared CPU runner
    assert per_call_us < 20.0, f"{per_call_us:.2f}µs per disabled span"


# ---------------------------------------------------------------- exporters

def _sample_tracer() -> Tracer:
    tr = Tracer()
    with tr.span("flush", scenes=2):
        with tr.span("pack", bucket=512):
            pass
    tr.event("compile", kind="executor", rung=512, device="cpu:0")
    tr.count("flushes")
    tr.gauge("depth", 1.0)
    return tr


def test_chrome_trace_schema(tmp_path):
    tr = _sample_tracer()
    path = obs.export_chrome(tr, str(tmp_path / "trace.json"))
    doc = json.load(open(path))
    assert doc["displayTimeUnit"] == "ms"
    events = doc["traceEvents"]
    meta = [e for e in events if e["ph"] == "M"]
    assert {m["name"] for m in meta} >= {"process_name", "thread_name"}
    complete = {e["name"]: e for e in events if e["ph"] == "X"}
    assert set(complete) == {"flush", "pack"}
    for e in complete.values():
        assert e["dur"] >= 0 and e["cat"] == "phase"
        assert isinstance(e["ts"], float)
    # nesting renders by time containment within one tid
    assert complete["flush"]["ts"] <= complete["pack"]["ts"]
    assert complete["flush"]["tid"] == complete["pack"]["tid"]
    (inst,) = [e for e in events if e["ph"] == "i"]
    assert inst["name"] == "compile" and inst["args"]["rung"] == 512
    assert doc["otherData"]["counters"] == {"flushes": 1}


def test_jsonl_round_trip(tmp_path):
    tr = _sample_tracer()
    path = obs.export_jsonl(tr, str(tmp_path / "trace.jsonl"))
    lines = [json.loads(l) for l in open(path)]
    assert [l["type"] for l in lines] == ["span", "span", "event", "snapshot"]
    spans = {l["name"]: l for l in lines if l["type"] == "span"}
    originals = {s.name: s for s in tr.spans()}
    for name, s in originals.items():
        assert spans[name]["t0_ns"] == s.t0_ns
        assert spans[name]["t1_ns"] == s.t1_ns
        assert spans[name]["depth"] == s.depth
        assert spans[name]["attrs"] == s.attrs
    assert lines[-1]["counters"] == {"flushes": 1}


def test_export_dispatches_on_extension(tmp_path):
    tr = _sample_tracer()
    chrome = obs.export(tr, str(tmp_path / "t.json"))
    jsonl = obs.export(tr, str(tmp_path / "t.jsonl"))
    assert "traceEvents" in json.load(open(chrome))
    assert json.loads(open(jsonl).readline())["type"] == "span"


def test_jax_profile_noop_path(tmp_path):
    # capability-probed: yields a bool either way and never raises
    with obs.jax_profile(str(tmp_path / "prof")) as active:
        assert isinstance(active, bool)
        assert active == obs.has_jax_profiler()


# --------------------------------------------------------- stats & SLO math

def test_idle_summary_reports_none_not_zero():
    from repro.serve.engine import EngineStats
    s = EngineStats().summary()
    assert s["p50_ms"] is None and s["p95_ms"] is None
    assert s["slo"] == {"deadline_ms": None, "measured": 0, "misses": 0,
                        "miss_rate": None}
    assert s["phases"] == {}


def test_slo_observe_counts_misses():
    from repro.serve.engine import EngineStats
    st = EngineStats()
    for lat in (5.0, 15.0, 25.0):
        st.slo_observe(lat, 10.0)
    s = st.summary()["slo"]
    assert s == {"deadline_ms": 10.0, "measured": 3, "misses": 2,
                 "miss_rate": pytest.approx(2 / 3)}


def test_phase_windows_are_bounded():
    from repro.serve.engine import PHASE_WINDOW, EngineStats
    st = EngineStats()
    for i in range(PHASE_WINDOW + 10):
        st.observe("pack", float(i))
    ph = st.summary()["phases"]["pack"]
    assert ph["count"] == PHASE_WINDOW
    assert ph["p50_ms"] is not None


def test_router_pctl_idle_is_none():
    from repro.serve.router import RouterStats
    assert RouterStats._pctl([]) == (None, None)
    import collections
    assert RouterStats._pctl([collections.deque()]) == (None, None)
    p50, p95 = RouterStats._pctl([collections.deque([1.0, 2.0, 3.0])])
    assert p50 == pytest.approx(2.0)


# --------------------------------------------------- serving integration

@pytest.fixture(scope="module")
def traced_serving():
    """One tiny cold-then-warm serving run under an enabled tracer; the
    assertions below all read this single (expensive) run."""
    from repro.serve.batcher import Scene
    from repro.serve.bucketing import BucketLadder
    from repro.serve.engine import Engine

    tracer = obs.enable()
    try:
        ladder = BucketLadder((256, 512), max_batch=2)
        eng = Engine("minkunet_kitti", ladder=ladder, spatial_bound=64,
                     max_wait_ms=50.0)
        rng = np.random.default_rng(0)

        def scene(n):
            coords = np.unique(rng.integers(-60, 60, size=(2 * n, 3),
                                            dtype=np.int32), axis=0)[:n]
            feats = rng.normal(size=(coords.shape[0], 4)).astype(np.float32)
            return Scene(coords=coords, feats=feats)

        scenes = [scene(100), scene(200), scene(150)]
        eng.serve(scenes)                       # cold epoch: compiles
        cold_compiles = list(tracer.events("compile"))
        eng.serve(scenes)                       # warm replay
        yield {"engine": eng, "tracer": tracer,
               "cold_compiles": cold_compiles}
    finally:
        obs.disable()


def test_exactly_one_compile_event_per_rung_and_stage(traced_serving):
    tracer = traced_serving["tracer"]
    keys = [(e.attrs["kind"], e.attrs["rung"], e.attrs["device"])
            for e in traced_serving["cold_compiles"]]
    assert len(keys) == len(set(keys)), f"duplicate compiles: {keys}"
    for e in tracer.events("compile"):
        assert e.attrs["wall_ms"] > 0
    # the warm replay re-traced NOTHING
    assert len(tracer.events("compile")) == len(keys)


def test_request_phases_are_spanned_and_nested(traced_serving):
    tracer = traced_serving["tracer"]
    by_name = {}
    for s in tracer.spans():
        by_name.setdefault(s.name, []).append(s)
    for phase in ("flush", "queue_wait", "request", "batch_plan", "pack",
                  "batch_pack", "map", "dispatch", "execute", "unpack"):
        assert phase in by_name, f"no {phase!r} spans recorded"
    # per-request phases nest under their flush (time containment, one tid)
    flushes = by_name["flush"]
    for phase in ("pack", "map", "execute", "unpack"):
        for s in by_name[phase]:
            assert s.depth >= 1
            assert any(f.t0_ns <= s.t0_ns and s.t1_ns <= f.t1_ns
                       for f in flushes), f"{phase} span outside any flush"
    # batch_pack nests inside the engine's pack phase
    assert all(s.depth >= 2 for s in by_name["batch_pack"])


def test_summary_reconciles_with_trace(traced_serving):
    eng, tracer = traced_serving["engine"], traced_serving["tracer"]
    s = eng.stats.summary()
    phase_counts = {}
    for rec in tracer.spans():
        phase_counts[rec.name] = phase_counts.get(rec.name, 0) + 1
    # every stats phase window was fed by the same code path as its spans
    for name in ("pack", "map", "execute", "unpack", "queue_wait"):
        assert s["phases"][name]["count"] == phase_counts[name], name
        assert s["phases"][name]["p50_ms"] is not None
        assert s["phases"][name]["p95_ms"] >= s["phases"][name]["p50_ms"]
    # every completed request was scored against the max_wait_ms SLO
    assert s["slo"]["deadline_ms"] == 50.0
    assert s["slo"]["measured"] == s["scenes"] == 6
    assert phase_counts["request"] == 6


def test_tuner_spans_carry_measured_latency():
    from repro.core import dataflows as df
    from repro.core.autotuner import Autotuner, GroupInfo

    tracer = obs.enable()
    try:
        groups = [GroupInfo("g0", ["a"]), GroupInfo("g1", ["b"])]
        space = [df.DataflowConfig("gather_scatter"),
                 df.DataflowConfig("implicit_gemm", n_splits=1)]
        Autotuner(groups, space, measure=lambda a: 0.001 * len(a)).tune()
        spans = [s for s in tracer.spans() if s.name == "tune_candidate"]
        assert len(spans) == len(groups) * len(space)
        for s in spans:
            assert s.attrs["group"] in ("g0", "g1")
            assert s.attrs["latency_ms"] == pytest.approx(2.0)
    finally:
        obs.disable()


def test_train_loop_emits_step_spans():
    import jax.numpy as jnp

    from repro.train.loop import LoopConfig, train_loop

    tracer = obs.enable()
    try:
        def step(params, opt, batch):
            return params + batch, opt, {"loss": jnp.float32(0.0)}

        data = iter([jnp.float32(1.0)] * 3)
        train_loop(step, jnp.float32(0.0), None, data,
                   LoopConfig(total_steps=3, ckpt_dir=None))
        steps = [s for s in tracer.spans() if s.name == "train_step"]
        assert [s.attrs["step"] for s in steps] == [0, 1, 2]
    finally:
        obs.disable()


# ----------------------------------------------------------- CI perf gate

def test_check_regression_classification():
    from benchmarks.check_regression import compare
    baseline = {"a": 1000.0, "b": 1000.0, "c": 1000.0, "tiny": 50.0,
                "gone": 400.0}
    current = {"a": 1100.0, "b": 2500.0, "c": 9000.0, "tiny": 500.0,
               "new": 300.0}
    r = compare(current, baseline, min_us=200.0, warn_ratio=2.0,
                fail_ratio=3.0)
    assert [e[0] for e in r["ok"]] == ["a"]
    assert [e[0] for e in r["warn"]] == ["b"]
    assert [e[0] for e in r["fail"]] == ["c"]
    assert r["skipped"] == 1                    # 'tiny' is under the floor
    assert r["only_current"] == ["new"]
    assert r["only_baseline"] == ["gone"]


def test_check_regression_refresh_and_gate(tmp_path):
    from benchmarks.check_regression import main
    artifact = {"meta": {"tiny": True}, "suites": {"s": {"rows": [
        {"name": "serving/x/p50", "us_per_call": 5000.0, "derived": ""},
        {"name": "ratio_row", "us_per_call": 0.0, "derived": "r=2x"},
    ]}}}
    cur = tmp_path / "BENCH_CI.json"
    base = tmp_path / "baseline.json"
    cur.write_text(json.dumps(artifact))
    assert main(["--current", str(cur), "--baseline", str(base),
                 "--refresh"]) == 0
    saved = json.loads(base.read_text())
    assert saved["rows"] == {"serving/x/p50": 5000.0}   # ratio rows excluded
    # identical re-run passes the gate
    assert main(["--current", str(cur), "--baseline", str(base)]) == 0
    # a >3x cliff hard-fails
    artifact["suites"]["s"]["rows"][0]["us_per_call"] = 20000.0
    cur.write_text(json.dumps(artifact))
    assert main(["--current", str(cur), "--baseline", str(base)]) == 1
    # missing baseline: warn-only, never red
    assert main(["--current", str(cur),
                 "--baseline", str(tmp_path / "absent.json")]) == 0
