import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest

jax.config.update("jax_platforms", "cpu")

try:
    import hypothesis
    import hypothesis.strategies as _hst
except ModuleNotFoundError:  # pragma: no cover - exercised in minimal envs
    hypothesis = None
    _hst = None


# --------------------------------------------------------- capability probes
#
# The repo targets current jax APIs; CI pins jax 0.4.37 (see ci.yml), where
# some of them don't exist yet.  Each probe names ONE api gap; tests that
# need it are skip-marked with the probe's reason so the suite is green on
# the pinned runtime and a *new* failure is never hidden inside known-red.

def _probe_pltpu_compiler_params() -> bool:
    """jax.experimental.pallas.tpu.CompilerParams — the Pallas-TPU kernels
    pass it to pl.pallas_call; jax 0.4.37 only has the old TPUCompilerParams
    spelling."""
    try:
        from jax.experimental.pallas import tpu as pltpu
    except Exception:  # pragma: no cover - pallas missing entirely
        return False
    return hasattr(pltpu, "CompilerParams")


HAS_PLTPU_COMPILER_PARAMS = _probe_pltpu_compiler_params()
# The other 0.4.37 gaps this PR met — jax.sharding.AxisType and
# jax.lax.axis_size — need no skip probes: launch/mesh.py and
# train/compression.py carry runtime fallbacks, so those tests really pass.

#: test files whose every case drives a Pallas-TPU kernel through
#: pltpu.CompilerParams (50 known env failures on jax 0.4.37)
_PALLAS_KERNEL_FILES = frozenset(
    ["test_kernels.py", "test_ssd_kernel.py", "test_wgrad_kernel.py"])

_PALLAS_SKIP = pytest.mark.skip(
    reason="pallas kernels use pltpu.CompilerParams, absent in this jax "
           "(CI pins 0.4.37; kernels target the current pallas API)")


def pytest_collection_modifyitems(config, items):
    if HAS_PLTPU_COMPILER_PARAMS:
        return
    for item in items:
        if os.path.basename(str(item.fspath)) in _PALLAS_KERNEL_FILES:
            item.add_marker(_PALLAS_SKIP)


def property_test(argnames, cases, strategies, max_examples=15):
    """Property-test decorator that degrades gracefully without hypothesis.

    With ``hypothesis`` installed (requirements-dev.txt) the test runs under
    ``@given(**strategies(st))``; without it, it runs as a plain parametrize
    over the deterministic ``cases`` so the suite still collects and covers
    the path.

    argnames:   "a,b,c" — pytest parametrize signature (fallback mode).
    cases:      deterministic fallback tuples matching ``argnames``.
    strategies: callable ``st_module -> dict`` of hypothesis strategies
                (lazy so the module is only touched when present).
    """
    def deco(f):
        if hypothesis is None:
            return pytest.mark.parametrize(argnames, cases)(f)
        return hypothesis.settings(max_examples=max_examples, deadline=None)(
            hypothesis.given(**strategies(_hst))(f))
    return deco
