"""Assigned architectures, input shapes and (arch × shape) cell definitions.

Shapes are the assignment's four LM shapes; ``decode_*``/``long_*`` lower
``serve_step`` (one token + KV cache), not ``train_step``.  ``long_500k``
requires sub-quadratic attention and is skipped (recorded, not silently) for
pure full-attention archs.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.lm_common import ArchConfig

ARCH_IDS = (
    "kimi_k2_1t_a32b", "mixtral_8x22b", "olmo_1b", "starcoder2_3b",
    "qwen1_5_0_5b", "codeqwen1_5_7b", "musicgen_large", "falcon_mamba_7b",
    "zamba2_7b", "llama_3_2_vision_90b",
)

# public ids (hyphenated) → module names
ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    batch: int


SHAPES: Dict[str, ShapeCfg] = {
    "train_4k": ShapeCfg("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCfg("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCfg("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCfg("long_500k", "decode", 524288, 1),
}


def get_arch(name: str) -> ArchConfig:
    mod_name = ALIASES.get(name, name).replace("-", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def cell_supported(cfg: ArchConfig, shape: ShapeCfg) -> Tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: 500k decode skipped per assignment"
    return True, ""


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests."""
    small = dict(
        n_layers=max(2, (cfg.attn_every or 1) + 1) if cfg.family == "hybrid" else 2,
        d_model=64, n_heads=4, kv_heads=max(1, min(cfg.kv_heads, 2)),
        d_ff=128, vocab=128, head_dim=16, n_img_tokens=8 if cfg.cross_every else 0,
        attn_chunk=32, loss_chunk=16, sliding_window=min(cfg.sliding_window, 32),
    )
    if cfg.moe is not None:
        small["moe"] = dataclasses.replace(cfg.moe, n_experts=4, top_k=2, d_ff_expert=32)
    if cfg.ssm is not None:
        small["ssm"] = dataclasses.replace(cfg.ssm, d_state=8, head_dim=16, chunk=8)
    if cfg.cross_every:
        small["n_layers"] = (cfg.cross_every + 1) * 2  # two groups
    if cfg.family == "hybrid":
        small["n_layers"] = cfg.attn_every + 2         # one group + tail
    small.update(overrides)
    return dataclasses.replace(cfg, **small)


def accounting_variant(cfg: ArchConfig, shape: ShapeCfg, depth: int) -> ArchConfig:
    """Reduced-depth, scan-light config for the roofline accounting pass.

    XLA cost_analysis counts while-loop bodies once, so the accounting pass
    compiles fully-unrolled reduced-depth variants (REPRO_SCAN_UNROLL=full)
    and extrapolates linearly in depth.  Inner chunk scans get trip counts
    ≤ 4-8 so the unroll stays compilable; chunk sizes only re-tile the same
    math, so FLOPs are unchanged and HBM bytes are ~chunk-invariant (the
    O(S²) score traffic dominates regardless of tile)."""
    over = dict(n_layers=depth,
                attn_chunk=max(512, shape.seq // 4),
                loss_chunk=max(512, shape.seq // 4))
    if cfg.ssm is not None:
        over["ssm"] = dataclasses.replace(cfg.ssm, chunk=max(128, shape.seq // 8))
    return dataclasses.replace(cfg, **over)


def depth_basis(cfg: ArchConfig):
    """(depths, row(L), full_row) describing quantity(L) = basis · coeffs.

    dense/moe/ssm/audio : q = c + L·per_layer            → depths (6, 10)
    vlm                 : q = c + g·per_group (L = 5g)    → depths (10, 15)
    hybrid (zamba2)     : q = c + n_mamba·m + n_shared·s  → depths (13, 19, 20)

    Depths are deliberately NOT tiny: XLA's buffer assignment makes
    bytes-per-layer mildly superlinear at shallow depth; validation against a
    full-depth unrolled olmo_1b compile shows (6,10) keeps FLOPs within ~2%,
    bytes within ~12% (under-estimate), collectives exact (EXPERIMENTS.md).
    """
    if cfg.family == "vlm":
        u = cfg.cross_every + 1
        return [2 * u, 3 * u], (lambda L: [1.0, L // u]), [1.0, cfg.n_layers // u]
    if cfg.family == "hybrid":
        e = cfg.attn_every

        def row(L):
            g = L // e
            return [1.0, float(L), float(g)]

        return [2 * e + 1, 3 * e + 1, 3 * e + 2], row, row(cfg.n_layers)
    return [6, 10], (lambda L: [1.0, float(L)]), [1.0, float(cfg.n_layers)]


def input_specs(cfg: ArchConfig, shape: ShapeCfg, dtype=jnp.int32) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    from repro.models import api

    b, s = shape.batch, shape.seq
    f = jnp.dtype(cfg.dtype)
    if shape.kind == "train":
        batch = {"labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if cfg.embed_input:
            batch["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        else:
            batch["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), f)
        if cfg.cross_every:
            batch["img_emb"] = jax.ShapeDtypeStruct((b, cfg.n_img_tokens, cfg.d_model), f)
        return batch
    if shape.kind == "prefill":
        batch = {}
        if cfg.embed_input:
            batch["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        else:
            batch["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), f)
        if cfg.cross_every:
            batch["img_emb"] = jax.ShapeDtypeStruct((b, cfg.n_img_tokens, cfg.d_model), f)
        return batch
    # decode: one token + cache
    cache = jax.eval_shape(lambda: api.init_cache(cfg, b, s))
    token = (jax.ShapeDtypeStruct((b,), jnp.int32) if cfg.embed_input
             else jax.ShapeDtypeStruct((b, cfg.d_model), f))
    batch = {"token": token, "cache": cache}
    return batch
