"""Cross-host serving fleet: an RPC front end over per-host serving workers.

The third serving tier (ROADMAP item 2).  ``Engine`` serves one device,
``DeviceRouter`` the devices of one process; ``FleetFrontend`` puts whole
*hosts* behind one ``SparseService`` front end, speaking the length-prefixed
binary protocol in serve/wire.py over plain sockets:

* **workers** are separate processes (``python -m repro.serve.fleet
  --worker``), each running its own engine (so its own jax runtime,
  devices, compile cache).  A worker listens on localhost and answers
  framed ops: ``execute`` (a FIFO scene group → per-scene results),
  ``warm`` (admit scenes into the worker's scene-digest store), ``warmup``
  (compile every rung, return a calibration timing), ``stats``, ``ping``,
  ``tune``, ``shutdown``.  ``--hosts N`` in launch/serve_sparse.py spawns
  N of them on localhost; production would point the front end at real
  host:port addresses instead — the protocol is the same;
* **routing** happens at batch granularity and in two levels, host then
  device: the front end runs the SAME deterministic FIFO grouping as the
  single engine (`SceneBatcher.plan`), charges each group at its padded
  row count **× the host's calibrated weight** (warmup timings of a slow
  host scale its scores up, so heterogeneous fleets balance by actual
  capacity, not batch count), and sends it to the host with the least
  outstanding weighted rows (round-robin tie-break).  Inside the worker,
  the engine (or a DeviceRouter, when the worker has several devices)
  routes to a device as before;
* **failover**: a worker death is detected three ways — a socket
  error/EOF on its data connection, an in-flight timeout on an un-acked
  batch, or a missed heartbeat on the control connection.  Its un-acked
  and still-queued batches are re-routed to the surviving hosts and
  re-executed (groups are self-contained and idempotent: re-running one
  yields bit-identical rows), so a mid-stream kill loses zero requests.
  With ``respawn=True`` the front end then spawns a replacement process
  and **re-warms** it from the front end's scene-digest store before it
  takes traffic;
* **replication policy** per stream: ``"gossip"`` pushes every admitted
  scene's digest+payload to all live hosts at submit time (any host can
  then merge-compose batches containing it from its local scene store —
  the right call for streams that will be served repeatedly), while
  ``"lazy"`` (default) lets each host warm up from the traffic it is
  actually routed (no admit-time fan-out cost).

Correctness contract (tests/test_fleet.py): fleet outputs are
**bit-identical** to the single-device ``Engine`` on the same stream —
grouping and packing decisions all happen in the front end exactly as the
engine makes them, workers only execute — and killing a worker mid-stream
loses zero requests.
"""
from __future__ import annotations

import argparse
import collections
import json
import os
import socket
import subprocess
import sys
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.serve import wire
from repro.serve.batcher import (Scene, SceneBatcher, SceneDelta, SceneResult,
                                 apply_delta)
from repro.serve.engine import LATENCY_WINDOW, PHASE_WINDOW, percentiles_ms, \
    summarize_phases
from repro.serve.plans import (PlanRegistry, _assignment_from_json,
                               _assignment_to_json)
from repro.serve.service import (STATS_SCHEMA_VERSION, ServiceConfig,
                                 resolve_config)

REPLICATION_POLICIES = ("lazy", "gossip")

#: scenes the front end remembers (digest → Scene) for gossip and re-warm
DIGEST_STORE_SIZE = 1024


class HostFailure(Exception):
    """One host's connection died mid-operation; carries the host index."""

    def __init__(self, index: int, cause: BaseException):
        super().__init__(f"fleet host h{index} failed: {cause!r}")
        self.index = index
        self.cause = cause


# ---------------------------------------------------------------------------
# Worker side (runs in its own process)
# ---------------------------------------------------------------------------

class FleetWorker:
    """One host's serving loop: an engine/router behind a socket.

    Accepts any number of connections (the front end opens two: data for
    the heavy ops, control for ping/stats so liveness checks never queue
    behind a batch) and answers one framed request per received frame.
    Engine-touching ops serialize on one lock; ``ping``/``stats`` don't,
    so a heartbeat gets answered while a batch executes.
    """

    def __init__(self, arch: str, config: ServiceConfig,
                 plans: Optional[str] = None, devices: int = 1):
        # the front end owns admission; a worker must never auto-flush
        # or cut batches on its own or bit-identity breaks
        cfg = config.replace(max_wait_ms=None, flush_count=None,
                             deadline_margin=None)
        self.config = cfg
        if devices > 1:
            from repro.serve.router import DeviceRouter
            self.engine = DeviceRouter(arch, devices=devices, config=cfg,
                                       plans=plans)
        else:
            from repro.serve.engine import Engine
            self.engine = Engine(arch, config=cfg, plans=plans)
        self._elock = threading.Lock()

    # ------------------------------------------------------------------- ops
    def handle(self, msg: dict) -> dict:
        op = msg.get("op")
        fn = getattr(self, f"_op_{op}", None)
        if fn is None:
            return {"ok": False, "error": f"unknown op {op!r}"}
        try:
            return {"ok": True, **fn(msg)}
        except Exception as e:     # report, don't kill the worker loop
            return {"ok": False, "error": f"{type(e).__name__}: {e}"}

    def _op_hello(self, msg) -> dict:
        import jax
        return {"pid": os.getpid(), "device_count": jax.device_count(),
                "arch": self.engine.arch}

    def _op_ping(self, msg) -> dict:
        return {"t_ns": time.perf_counter_ns()}

    def _op_warmup(self, msg) -> dict:
        """Compile every rung; returns the warmup wall time and the median
        warm execute phase — the calibration number weighted routing uses."""
        with self._elock:
            t0 = time.perf_counter()
            self.engine.warmup(msg.get("channels"))
            wall_ms = (time.perf_counter() - t0) * 1e3
        phases = self.engine.stats.summary().get("phases", {})
        execute = phases.get("execute", {})
        return {"warmup_ms": wall_ms, "calib_ms": execute.get("p50_ms")}

    def _op_execute(self, msg) -> dict:
        """Run one front-end-formed FIFO group; returns per-scene results
        in group order.  The group fits one batch by construction, so the
        worker's own plan() re-derives exactly that single group and the
        result rows are bit-identical to any other host running it."""
        scenes = [wire.scene_from_wire(d) for d in msg["scenes"]]
        with self._elock:
            results = self.engine.serve(scenes, flush_every=0)
        return {"results": [wire.result_to_wire(r) for r in results]}

    def _op_warm(self, msg) -> dict:
        """Admit scenes into the scene-digest store ahead of traffic (the
        gossip replication path, and the re-warm of a respawned worker)."""
        scenes = [wire.scene_from_wire(d) for d in msg["scenes"]]
        eng = self.engine
        if hasattr(eng, "workers"):           # DeviceRouter: shared store
            eng = eng.workers[0]
        stored = 0
        with self._elock:
            for s in scenes:
                if eng.map_strategy in ("composed", "incremental"):
                    eng._scene_entry(s)
                    stored += 1
        return {"stored": stored}

    def _op_stats(self, msg) -> dict:
        return {"summary": self.engine.stats.summary()}

    def _op_tune(self, msg) -> dict:
        from repro.core import dataflows as df
        scenes = [wire.scene_from_wire(d) for d in msg["scenes"]]
        space = msg.get("space")
        if space is not None:
            space = [df.DataflowConfig.from_dict(d) for d in space]
        with self._elock:
            assignment = self.engine.tune(scenes, space=space,
                                          iters=int(msg.get("iters", 2)),
                                          save=False)
        return {"assignment": _assignment_to_json(assignment)}

    def _op_shutdown(self, msg) -> dict:
        return {"bye": True}

    # ------------------------------------------------------------- serve loop
    def serve_forever(self, port: int = 0, announce=print) -> None:
        """Bind localhost, announce ``FLEET_WORKER_PORT=<port>`` (the spawn
        handshake), then answer frames until a ``shutdown`` op."""
        srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        srv.bind(("127.0.0.1", port))
        srv.listen(8)
        announce(f"FLEET_WORKER_PORT={srv.getsockname()[1]}", flush=True)
        done = threading.Event()

        def conn_loop(conn: socket.socket) -> None:
            try:
                while not done.is_set():
                    msg = wire.recv_msg(conn)
                    reply = self.handle(msg)
                    wire.send_msg(conn, reply)
                    if msg.get("op") == "shutdown":
                        done.set()
            except (ConnectionError, OSError, wire.WireError):
                pass               # front end went away; keep serving others
            finally:
                conn.close()

        srv.settimeout(0.25)
        try:
            while not done.is_set():
                try:
                    conn, _ = srv.accept()
                except socket.timeout:
                    continue
                threading.Thread(target=conn_loop, args=(conn,),
                                 daemon=True).start()
        finally:
            srv.close()


def worker_main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="fleet worker process (spawned by FleetFrontend / "
                    "serve_sparse --hosts)")
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--config", required=True,
                    help="ServiceConfig as JSON (ServiceConfig.to_dict)")
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--plans", default=None)
    ap.add_argument("--devices", type=int, default=1)
    args = ap.parse_args(argv)
    cfg = ServiceConfig.from_dict(json.loads(args.config))
    FleetWorker(args.arch, cfg, plans=args.plans,
                devices=args.devices).serve_forever(args.port)


# ---------------------------------------------------------------------------
# Front end side
# ---------------------------------------------------------------------------

class HostHandle:
    """Front-end state for one worker host: process + two connections."""

    def __init__(self, index: int, addr: Tuple[str, int],
                 proc: Optional[subprocess.Popen]):
        self.index = index
        self.label = f"h{index}"
        self.addr = addr
        self.proc = proc
        self.data: Optional[socket.socket] = None
        self.ctrl: Optional[socket.socket] = None
        self.data_lock = threading.Lock()
        self.ctrl_lock = threading.Lock()
        self.alive = False
        self.weight = 1.0
        self.calib_ms: Optional[float] = None
        self.warmed: set = set()            # scene digests pushed via gossip
        self.last_summary: Optional[dict] = None

    def close(self) -> None:
        for s in (self.data, self.ctrl):
            if s is not None:
                try:
                    s.close()
                except OSError:
                    pass
        if self.proc is not None and self.proc.poll() is None:
            self.proc.terminate()
            try:
                self.proc.wait(timeout=5)
            except subprocess.TimeoutExpired:
                self.proc.kill()


class FleetStats:
    """Fleet-level stats: the RouterStats schema with ``hosts`` in place of
    ``devices`` plus a ``fleet`` robustness block, aggregated from the
    front end's own windows and each live worker's reported summary."""

    def __init__(self, frontend: "FleetFrontend"):
        self._frontend = frontend
        self.submitted = 0
        self.completed = 0
        self.busy_s = 0.0
        self.flushes = 0
        self.deadline_flushes = 0
        self.count_flushes = 0
        self.failovers = 0           # hosts declared dead
        self.rerouted_batches = 0    # un-acked/queued batches re-routed
        self.respawns = 0
        self.heartbeat_misses = 0
        self.gossip_scenes = 0
        self.latencies_ms = collections.deque(maxlen=LATENCY_WINDOW)
        self.route_log: List[Tuple[int, int]] = []
        self.phases: Dict[str, collections.deque] = {}
        self.slo_deadline_ms: Optional[float] = None
        self.slo_measured = 0
        self.slo_miss_count = 0

    def observe(self, phase: str, ms: float) -> None:
        win = self.phases.get(phase)
        if win is None:
            win = self.phases[phase] = collections.deque(maxlen=PHASE_WINDOW)
        win.append(ms)

    def slo_observe(self, latency_ms: float, deadline_ms: float) -> None:
        self.slo_deadline_ms = deadline_ms
        self.slo_measured += 1
        if latency_ms > deadline_ms:
            self.slo_miss_count += 1

    def summary(self) -> dict:
        fr = self._frontend
        host_sums = fr._host_summaries()
        live = [h for h in fr.hosts if h.alive]

        def total(*path, default=0):
            out = 0
            for s in host_sums.values():
                v = s
                for p in path:
                    v = v.get(p, {}) if isinstance(v, dict) else {}
                out += v if isinstance(v, (int, float)) else default
            return out

        merged_compiles: Dict[str, Dict[str, int]] = {
            k: {} for k in ("recompiles", "map_compiles", "plan_compiles")}
        for h in fr.hosts:
            s = host_sums.get(h.label)
            if not s:
                continue
            for field, sink in merged_compiles.items():
                for cap, n in s.get(field, {}).items():
                    sink[f"{h.label}:{cap}"] = n
        p50, p95 = percentiles_ms(self.latencies_ms)
        hosts = {}
        for h in fr.hosts:
            s = host_sums.get(h.label) or {}
            hosts[h.label] = {
                "addr": f"{h.addr[0]}:{h.addr[1]}",
                "alive": h.alive,
                "weight": h.weight,
                "calib_ms": h.calib_ms,
                "routed_batches": sum(1 for i, _ in self.route_log
                                      if i == h.index),
                "queue_depth": fr.outstanding_score[h.index],
                "scenes": s.get("scenes", 0),
                "batches": s.get("batches", 0),
                "p50_ms": s.get("p50_ms"),
                "p95_ms": s.get("p95_ms"),
            }
        return {
            "schema_version": STATS_SCHEMA_VERSION,
            "scenes": self.completed,
            "batches": len(self.route_log),
            "routed_batches": len(self.route_log),
            "p50_ms": p50,
            "p95_ms": p95,
            "scenes_per_s": self.completed / self.busy_s if self.busy_s else 0.0,
            "recompiles": merged_compiles["recompiles"],
            "map_compiles": merged_compiles["map_compiles"],
            "plan_compiles": merged_compiles["plan_compiles"],
            "map_cache": {"hits": total("map_cache", "hits"),
                          "misses": total("map_cache", "misses")},
            "scene_tables": {
                "hits": total("scene_tables", "hits"),
                "misses": total("scene_tables", "misses"),
                "composed_batches": total("scene_tables", "composed_batches"),
                "delta_merges": total("scene_tables", "delta_merges")},
            "deadline_flushes": self.deadline_flushes,
            "count_flushes": self.count_flushes,
            "phases": summarize_phases(self.phases),
            "slo": {"deadline_ms": self.slo_deadline_ms,
                    "measured": self.slo_measured,
                    "misses": self.slo_miss_count,
                    "miss_rate": (self.slo_miss_count / self.slo_measured
                                  if self.slo_measured else None)},
            "hosts": hosts,
            "fleet": {
                "schema_version": STATS_SCHEMA_VERSION,
                "hosts": len(fr.hosts),
                "live": len(live),
                "replication": fr.replication,
                "weights": {h.label: h.weight for h in fr.hosts},
                "failovers": self.failovers,
                "rerouted_batches": self.rerouted_batches,
                "respawns": self.respawns,
                "heartbeat_misses": self.heartbeat_misses,
                "gossip_scenes": self.gossip_scenes,
            },
        }


def _src_pythonpath() -> str:
    """PYTHONPATH for spawned workers: this repro's src root first.
    ``repro`` is a namespace package (no __init__), so the root comes from
    its ``__path__`` rather than ``__file__``."""
    import repro
    src = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    current = os.environ.get("PYTHONPATH", "")
    return src + (os.pathsep + current if current else "")


class FleetFrontend:
    """Host-level ``SparseService``: route scene groups to worker hosts.

    arch: model architecture, as for ``Engine``.
    hosts: an int N — spawn N localhost worker processes — or a list of
        ``(host, port)`` addresses of already-running workers.
    config: the ``ServiceConfig`` every worker serves with (shipped to
        spawned workers as JSON; remote workers must be started with the
        same config or bit-identity is forfeit).
    plans: optional PlanRegistry JSON *path*, forwarded to workers.
    replication: default scene replication policy ("lazy" | "gossip");
        per-stream overrides via ``set_replication(stream, policy)``.
    respawn: spawn + re-warm a replacement when a spawned host dies
        (address-only hosts are never respawned — we didn't start them).
    heartbeat_s: control-connection ping interval (None disables).
    inflight_timeout_s: per-operation data-socket timeout — the in-flight
        detector for a host that accepted a batch and hung.
    devices_per_host: devices each spawned worker routes over (>1 runs a
        DeviceRouter inside the worker: host-level then device-level
        routing).
    """

    def __init__(self, arch: str, hosts=2, config: Optional[ServiceConfig] = None,
                 plans: Optional[str] = None, replication: str = "lazy",
                 respawn: bool = False, heartbeat_s: Optional[float] = None,
                 inflight_timeout_s: float = 300.0, devices_per_host: int = 1,
                 seed: Optional[int] = None, **legacy):
        if seed is not None:
            legacy["seed"] = seed
        self.config = resolve_config(config, legacy)
        assert replication in REPLICATION_POLICIES, replication
        self.arch = arch
        self.plans_path = plans
        self.replication = replication
        self.respawn = respawn
        self.heartbeat_s = heartbeat_s
        self.inflight_timeout_s = inflight_timeout_s
        self.devices_per_host = devices_per_host
        self.ladder = self.config.ladder()
        self.batcher = SceneBatcher(self.ladder, self.config.spatial_bound)
        self.max_wait_ms = self.config.max_wait_ms
        self.flush_count = self.config.flush_count
        self.stats = FleetStats(self)
        self.hosts: List[HostHandle] = []
        self.outstanding_score: List[float] = []
        self._rr = 0
        self._queue: List[tuple] = []
        self._next_ticket = 0
        self._ready: Dict[int, SceneResult] = {}
        self._streams: "collections.OrderedDict[str, Scene]" = collections.OrderedDict()
        self.stream_cache_size = 1024
        self._replication_overrides: Dict[str, str] = {}
        self._digest_store: "collections.OrderedDict[str, Scene]" = collections.OrderedDict()
        self._lock = threading.Lock()       # host liveness + score mutation
        self._closed = False
        if isinstance(hosts, int):
            assert hosts >= 1, hosts
            procs = [self._spawn_worker() for _ in range(hosts)]
            for proc in procs:
                self._attach(self._handshake(proc))
        else:
            for addr in hosts:
                h, p = (addr.rsplit(":", 1) if isinstance(addr, str)
                        else addr)
                handle = HostHandle(len(self.hosts), (h, int(p)), proc=None)
                self._connect(handle)
                self._attach(handle)
        self._hb_stop = threading.Event()
        self._hb_thread = None
        if heartbeat_s:
            self._hb_thread = threading.Thread(
                target=self._heartbeat_loop, daemon=True,
                name="fleet-heartbeat")
            self._hb_thread.start()

    # -------------------------------------------------------------- lifecycle
    def _spawn_worker(self) -> subprocess.Popen:
        # -c instead of -m: runpy re-executing an already-imported
        # submodule of repro.serve would warn on every worker start
        cmd = [sys.executable, "-c",
               "from repro.serve.fleet import worker_main; worker_main()",
               "--worker",
               "--arch", self.arch, "--port", "0",
               "--config", json.dumps(self.config.to_dict())]
        if self.plans_path:
            cmd += ["--plans", self.plans_path]
        if self.devices_per_host > 1:
            cmd += ["--devices", str(self.devices_per_host)]
        env = os.environ.copy()
        env["PYTHONPATH"] = _src_pythonpath()
        return subprocess.Popen(cmd, stdout=subprocess.PIPE, text=True,
                                env=env)

    def _handshake(self, proc: subprocess.Popen,
                   timeout_s: float = 120.0) -> HostHandle:
        """Read the worker's announced port off its stdout and connect."""
        deadline = time.monotonic() + timeout_s
        port = None
        while time.monotonic() < deadline:
            line = proc.stdout.readline()
            if not line:
                raise RuntimeError(
                    f"fleet worker exited during startup "
                    f"(rc={proc.poll()})")
            if line.startswith("FLEET_WORKER_PORT="):
                port = int(line.strip().split("=", 1)[1])
                break
        if port is None:
            raise RuntimeError("fleet worker never announced its port")
        handle = HostHandle(len(self.hosts), ("127.0.0.1", port), proc)
        self._connect(handle)
        return handle

    def _connect(self, handle: HostHandle) -> None:
        handle.data = socket.create_connection(handle.addr, timeout=120.0)
        handle.data.settimeout(self.inflight_timeout_s)
        handle.ctrl = socket.create_connection(handle.addr, timeout=120.0)
        handle.ctrl.settimeout(30.0)
        hello = self._request(handle, {"op": "hello"})
        handle.alive = True
        obs.event("host_up", host=handle.label, pid=hello.get("pid"),
                  devices=hello.get("device_count"))

    def _attach(self, handle: HostHandle) -> None:
        handle.index = len(self.hosts)
        handle.label = f"h{handle.index}"
        self.hosts.append(handle)
        self.outstanding_score.append(0.0)

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._hb_stop.set()
        for h in self.hosts:
            if h.alive:
                try:
                    with h.data_lock:
                        wire.send_msg(h.data, {"op": "shutdown"})
                        wire.recv_msg(h.data)
                except (OSError, wire.WireError):
                    pass
            h.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass

    @property
    def num_hosts(self) -> int:
        return len(self.hosts)

    @property
    def live_hosts(self) -> List[HostHandle]:
        return [h for h in self.hosts if h.alive]

    # --------------------------------------------------------------- plumbing
    def _request(self, handle: HostHandle, msg: dict, ctrl: bool = False) -> dict:
        """One framed request/response on a host connection; socket failures
        and worker-reported errors surface as ``HostFailure``."""
        sock = handle.ctrl if ctrl else handle.data
        lock = handle.ctrl_lock if ctrl else handle.data_lock
        try:
            with lock:
                wire.send_msg(sock, msg)
                reply = wire.recv_msg(sock)
        except (OSError, ConnectionError, socket.timeout,
                wire.WireError) as e:
            raise HostFailure(handle.index, e) from e
        if not reply.get("ok"):
            raise HostFailure(handle.index,
                              RuntimeError(reply.get("error", "worker error")))
        return reply

    def _mark_dead(self, handle: HostHandle, why: str) -> None:
        with self._lock:
            if not handle.alive:
                return
            handle.alive = False
            self.stats.failovers += 1
        obs.event("host_down", host=handle.label, why=why)

    def _heartbeat_loop(self) -> None:
        while not self._hb_stop.wait(self.heartbeat_s):
            for h in list(self.hosts):
                if not h.alive:
                    continue
                try:
                    self._request(h, {"op": "ping"}, ctrl=True)
                except HostFailure:
                    self.stats.heartbeat_misses += 1
                    self._mark_dead(h, "heartbeat")

    def _host_summaries(self) -> Dict[str, dict]:
        out = {}
        for h in self.hosts:
            if h.alive:
                try:
                    h.last_summary = self._request(
                        h, {"op": "stats"}, ctrl=True)["summary"]
                except HostFailure:
                    self._mark_dead(h, "stats")
            if h.last_summary is not None:
                out[h.label] = h.last_summary
        return out

    # ---------------------------------------------------------------- routing
    def _route(self, rows: int) -> int:
        """Host index for a batch of ``rows`` padded rows: least outstanding
        *weighted* rows over live hosts; exact ties fall to a round-robin
        cursor.  Deterministic in the routed sequence and liveness state."""
        live = [h.index for h in self.hosts if h.alive]
        if not live:
            raise RuntimeError("no live fleet hosts")
        lo = min(self.outstanding_score[i] for i in live)
        n = len(self.hosts)
        pick = min((i for i in live if self.outstanding_score[i] == lo),
                   key=lambda i: (i - self._rr) % n)
        self._rr = (pick + 1) % n
        self.outstanding_score[pick] += rows * self.hosts[pick].weight
        self.stats.route_log.append((pick, rows))
        obs.event("route", host=self.hosts[pick].label, rows=rows,
                  weight=self.hosts[pick].weight)
        return pick

    def _uncharge(self, host_index: int, rows: int) -> None:
        with self._lock:
            self.outstanding_score[host_index] = max(
                0.0, self.outstanding_score[host_index]
                - rows * self.hosts[host_index].weight)

    # -------------------------------------------------------------------- api
    def set_replication(self, stream: str, policy: str) -> None:
        assert policy in REPLICATION_POLICIES, policy
        self._replication_overrides[stream] = policy

    def _admit(self, scene: Scene, stream: Optional[str]) -> None:
        self._digest_store[scene.digest] = scene
        self._digest_store.move_to_end(scene.digest)
        while len(self._digest_store) > DIGEST_STORE_SIZE:
            self._digest_store.popitem(last=False)
        policy = (self._replication_overrides.get(stream, self.replication)
                  if stream is not None else self.replication)
        if policy != "gossip":
            return
        payload = wire.scene_to_wire(scene)
        for h in self.live_hosts:
            if scene.digest in h.warmed:
                continue
            try:
                self._request(h, {"op": "warm", "scenes": [payload]})
                h.warmed.add(scene.digest)
                self.stats.gossip_scenes += 1
            except HostFailure:
                self._mark_dead(h, "gossip")

    def submit(self, scene: Scene, stream: Optional[str] = None) -> int:
        """Enqueue one scene; ticket resolved by the next flush — identical
        semantics to ``Engine.submit`` including the auto-flush triggers."""
        if scene.num_points > self.ladder.max_capacity:
            raise ValueError(f"scene of {scene.num_points} rows exceeds the "
                             f"largest bucket ({self.ladder.max_capacity})")
        t = self._next_ticket
        self._next_ticket += 1
        self._queue.append((t, scene, time.perf_counter()))
        self.stats.submitted += 1
        if stream is not None:
            self._streams[stream] = scene
            self._streams.move_to_end(stream)
            while len(self._streams) > self.stream_cache_size:
                self._streams.popitem(last=False)
        self._admit(scene, stream)
        self._autoflush()
        return t

    def submit_delta(self, stream: str, delta: SceneDelta) -> int:
        """Streaming frame as a delta of the stream's last scene.  The
        front end applies the delta host-side (it holds the stream's last
        full scene) and ships the full scene; workers on the incremental
        strategy still delta-merge locally from their own stores."""
        prev = self._streams.get(stream)
        if prev is None:
            raise KeyError(f"unknown stream {stream!r}; seed it with "
                           f"submit(scene, stream=...) first")
        return self.submit(apply_delta(prev, delta), stream=stream)

    def _deadline_due(self) -> bool:
        return (self.max_wait_ms is not None and bool(self._queue) and
                (time.perf_counter() - self._queue[0][2]) * 1e3
                >= self.max_wait_ms)

    def _autoflush(self) -> None:
        if self.flush_count is not None and len(self._queue) >= self.flush_count:
            self.stats.count_flushes += 1
            self._ready.update(self._run_queue())
        elif self._deadline_due():
            self.stats.deadline_flushes += 1
            self._ready.update(self._run_queue())

    def poll(self) -> Dict[int, SceneResult]:
        if self._deadline_due():
            self.stats.deadline_flushes += 1
            self._ready.update(self._run_queue())
        out, self._ready = self._ready, {}
        return out

    def flush(self) -> Dict[int, SceneResult]:
        out, self._ready = self._ready, {}
        out.update(self._run_queue())
        return out

    def serve(self, scenes: Sequence[Scene],
              flush_every: int = 0) -> List[SceneResult]:
        """Submit all, flush (in chunks), return in submission order."""
        out: Dict[int, SceneResult] = {}
        tickets = []
        for i, s in enumerate(scenes):
            tickets.append(self.submit(s))
            if flush_every and (i + 1) % flush_every == 0:
                out.update(self.flush())
        out.update(self.flush())
        return [out[t] for t in tickets]

    # ------------------------------------------------------------------ flush
    def _run_queue(self) -> Dict[int, SceneResult]:
        if not self._queue:
            return {}
        queue, self._queue = self._queue, []
        t0 = time.perf_counter()
        with obs.span("flush", scenes=len(queue), hosts=len(self.hosts)):
            results = self._flush_queue(queue, t0)
        self.stats.busy_s += time.perf_counter() - t0
        self.stats.flushes += 1
        return results

    def _flush_queue(self, queue: List[tuple],
                     t0: float) -> Dict[int, SceneResult]:
        t0_ns = time.perf_counter_ns()
        for ticket, _, t_sub in queue:
            self.stats.observe("queue_wait", (t0 - t_sub) * 1e3)
            obs.record_span("queue_wait", int(t_sub * 1e9), t0_ns,
                            ticket=ticket)
        sizes = [s.num_points for _, s, _ in queue]
        # identical FIFO grouping to the single-device engine: the
        # bit-identity contract — a worker only ever sees whole groups
        groups = self.batcher.plan(sizes)
        pending = [(gi, group, self.ladder.group_capacity(
            [sizes[i] for i in group])) for gi, group in enumerate(groups)]
        done: Dict[int, Tuple[List[SceneResult], float]] = {}

        while pending:
            shards: Dict[int, list] = {}
            with self._lock:
                for item in pending:
                    shards.setdefault(self._route(item[2]), []).append(item)
            pending = []
            failures: List[Tuple[HostHandle, list]] = []
            lock = threading.Lock()

            def run_host(hi: int, items: list) -> None:
                handle = self.hosts[hi]
                for k, (gi, group, rows) in enumerate(items):
                    payload = {"op": "execute",
                               "scenes": [wire.scene_to_wire(queue[i][1])
                                          for i in group]}
                    t_rpc = time.perf_counter()
                    try:
                        with obs.span("host_rpc", host=handle.label,
                                      rows=rows, scenes=len(group)):
                            reply = self._request(handle, payload)
                    except HostFailure:
                        self._mark_dead(handle, "execute")
                        with lock:
                            failures.append((handle, items[k:]))
                        return
                    self.stats.observe("rpc", (time.perf_counter() - t_rpc) * 1e3)
                    self._uncharge(hi, rows)
                    res = [wire.result_from_wire(d)
                           for d in reply["results"]]
                    with lock:
                        done[gi] = (res, time.perf_counter())

            threads = [threading.Thread(target=run_host, args=(hi, items),
                                        name=f"fleet-{self.hosts[hi].label}")
                       for hi, items in shards.items()]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            for handle, lost in failures:
                for _, _, rows in lost:
                    self._uncharge(handle.index, rows)
                self.stats.rerouted_batches += len(lost)
                obs.event("reroute", host=handle.label, batches=len(lost))
                pending.extend(lost)
            if pending and not self.live_hosts:
                raise RuntimeError(
                    f"all fleet hosts died with {len(pending)} batches "
                    f"outstanding")

        results: Dict[int, SceneResult] = {}
        for gi, group in enumerate(groups):
            per_scene, t_done = done[gi]
            for slot, i in enumerate(group):
                ticket, _, t_sub = queue[i]
                results[ticket] = per_scene[slot]
                lat_ms = (t_done - t_sub) * 1e3
                self.stats.latencies_ms.append(lat_ms)
                obs.record_span("request", int(t_sub * 1e9),
                                int(t_done * 1e9), ticket=ticket)
                if self.max_wait_ms is not None:
                    self.stats.slo_observe(lat_ms, self.max_wait_ms)
        self.stats.completed += len(queue)
        if self.respawn:
            self._respawn_dead()
        return results

    # --------------------------------------------------------------- recovery
    def _respawn_dead(self) -> None:
        for h in list(self.hosts):
            if not h.alive and h.proc is not None:
                self.respawn_host(h.index)

    def respawn_host(self, index: int) -> HostHandle:
        """Replace a dead spawned host with a fresh worker process and
        re-warm its scene store from the front end's digest store."""
        old = self.hosts[index]
        assert old.proc is not None, \
            "cannot respawn a host this front end did not spawn"
        old.close()
        proc = self._spawn_worker()
        handle = self._handshake(proc)
        handle.index = index
        handle.label = f"h{index}"
        handle.weight = old.weight
        handle.calib_ms = old.calib_ms
        with self._lock:
            self.hosts[index] = handle
            self.outstanding_score[index] = 0.0
        scenes = [wire.scene_to_wire(s) for s in self._digest_store.values()]
        if scenes:
            try:
                stored = self._request(
                    handle, {"op": "warm", "scenes": scenes})["stored"]
                handle.warmed.update(self._digest_store.keys())
                obs.event("rewarm", host=handle.label, scenes=stored)
            except HostFailure:
                self._mark_dead(handle, "rewarm")
        self.stats.respawns += 1
        return handle

    # ------------------------------------------------------------ maintenance
    def warmup(self, channels: Optional[int] = None) -> None:
        """Warm every host (compile all rungs) and calibrate routing
        weights from the reported warm timings: a host 2× slower than the
        fastest carries weight 2.0, so its outstanding-rows score grows
        2× per routed row and it receives proportionally less work."""
        calib: Dict[int, float] = {}

        def warm_one(h: HostHandle) -> None:
            try:
                r = self._request(h, {"op": "warmup", "channels": channels})
            except HostFailure:
                self._mark_dead(h, "warmup")
                return
            ms = r.get("calib_ms") or r.get("warmup_ms")
            if ms:
                calib[h.index] = float(ms)

        threads = [threading.Thread(target=warm_one, args=(h,))
                   for h in self.live_hosts]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if calib:
            fastest = min(calib.values())
            for i, ms in calib.items():
                self.hosts[i].calib_ms = ms
                self.hosts[i].weight = ms / fastest if fastest > 0 else 1.0

    def tune(self, sample_scenes: Sequence[Scene], space=None, iters: int = 2,
             save: bool = True) -> Dict[str, dict]:
        """Tune every live host's engine on the sample and return
        {host_label: assignment}.  With ``save`` and a plans path, host 0's
        winning assignment is persisted under the shared arch entry (a
        homogeneous fleet serves one plan; heterogeneous fleets should
        tune per host out of band and pass per-host plan files)."""
        payload = {"op": "tune", "iters": iters,
                   "scenes": [wire.scene_to_wire(s) for s in sample_scenes],
                   "space": ([c.to_dict() for c in space]
                             if space is not None else None)}
        out: Dict[str, dict] = {}
        for h in self.live_hosts:
            try:
                r = self._request(h, payload)
            except HostFailure:
                self._mark_dead(h, "tune")
                continue
            out[h.label] = _assignment_from_json(r["assignment"])
        if save and self.plans_path and out:
            reg = PlanRegistry.load(self.plans_path)
            first = next(iter(out))
            reg.set(self.arch, out[first])
            reg.set_service(self.arch, self.config)
            reg.save(self.plans_path)
        return out


if __name__ == "__main__":
    worker_main()
