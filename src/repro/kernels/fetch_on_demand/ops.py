"""Jit'd wrapper for the fetch-on-demand kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.kmap import KernelMap
from repro.kernels.common import default_interpret, pad_rows
from repro.kernels.fetch_on_demand.fetch_on_demand import fetch_on_demand_pallas


def fetch_on_demand(x: jax.Array, w: jax.Array, kmap: KernelMap, *,
                    tile_r: int = 128, interpret: bool | None = None) -> jax.Array:
    """Full sparse conv via the fused fetch-on-demand dataflow."""
    if interpret is None:
        interpret = default_interpret()
    kd, cap = kmap.ws_in.shape
    pad = (-cap) % tile_r
    ws_in = jnp.pad(kmap.ws_in, ((0, 0), (0, pad)), constant_values=-1)
    ws_out = jnp.pad(kmap.ws_out, ((0, 0), (0, pad)), constant_values=-1)
    out0 = jnp.zeros((kmap.capacity, w.shape[-1]), x.dtype)
    return fetch_on_demand_pallas(ws_in, ws_out, x, w, out0, tile_r=tile_r,
                                  interpret=interpret)
