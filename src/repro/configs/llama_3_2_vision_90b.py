"""Llama-3.2-Vision-90B — cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision; unverified].

100 layers = 20 groups of (4 self-attn + 1 gated cross-attn); the vision
frontend is a STUB per assignment: input_specs() provides precomputed patch
embeddings (B, n_img_tokens, d_model).
"""
from repro.models.lm_common import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b", family="vlm", n_layers=100, d_model=8192,
    n_heads=64, kv_heads=8, d_ff=28672, vocab=128256, norm="rms", mlp="swiglu",
    cross_every=4, n_img_tokens=1600,
)
