import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver: measure the three beyond-paper variants against
their paper-faithful baselines with the exact-accounting pass, and persist
the results to benchmarks/results/perf/<cell>__<variant>.json.

    PYTHONPATH=src python -m benchmarks.perf_hillclimb
"""
import json
from pathlib import Path

from repro.launch import dryrun, hlo_analysis

CELLS = [
    ("kimi_k2_1t_a32b", "train_4k", "moe_local_dispatch"),
    ("llama_3_2_vision_90b", "train_4k", "exact_causal"),
    ("zamba2_7b", "train_4k", "ssd_bf16"),
    # bonus cycle: worst non-MoE train cell after exact accounting
    ("falcon_mamba_7b", "train_4k", "ssd_bf16"),
    # cycle-2 hypothesis refinement: attention share scales with S — retry
    # exact-causal where S is 8x larger
    ("llama_3_2_vision_90b", "prefill_32k", "exact_causal"),
]

OUT = Path(__file__).resolve().parent / "results" / "perf"


def main():
    OUT.mkdir(parents=True, exist_ok=True)
    for arch, shape, variant in CELLS:
        base_path = (Path(__file__).resolve().parent / "results" / "dryrun" /
                     "single_pod" / f"{arch}__{shape}.json")
        base = json.loads(base_path.read_text())
        print(f"=== {arch} × {shape} → {variant} ===", flush=True)
        est = dryrun.accounting_pass(arch, shape, multi_pod=False, variant=variant)
        roof = hlo_analysis.roofline_terms(est["flops"], est["bytes_accessed"],
                                           est["collective_bytes"])
        rec = {"arch": arch, "shape": shape, "variant": variant,
               "per_device_extrapolated": est, "roofline": roof,
               "baseline_roofline": base.get("roofline"),
               "baseline_per_device": base.get("per_device_extrapolated")}
        (OUT / f"{arch}__{shape}__{variant}.json").write_text(json.dumps(rec, indent=1))
        b = base.get("roofline", {})
        print(f"  baseline : bott={b.get('bottleneck')} frac={b.get('roofline_fraction', 0):.3f} "
              f"T=({b.get('compute_s', 0):.3e},{b.get('memory_s', 0):.3e},{b.get('collective_s', 0):.3e})")
        print(f"  optimized: bott={roof['bottleneck']} frac={roof['roofline_fraction']:.3f} "
              f"T=({roof['compute_s']:.3e},{roof['memory_s']:.3e},{roof['collective_s']:.3e})",
              flush=True)


if __name__ == "__main__":
    main()
