"""Optional XLA-level profiling behind the same ``--trace`` flag.

``jax_profile(logdir)`` brackets a region with
``jax.profiler.start_trace``/``stop_trace`` when the running jax has them
(capability-probed like the pallas skips in tests/conftest.py), writing a
TensorBoard/XProf trace next to the repo's own Chrome trace — on TPU that
is the free XLA-level view of the same run.  On runtimes without the API,
or when the profiler itself fails (some CPU builds), the context manager
degrades to a no-op rather than taking down serving.

jax is imported lazily so ``repro.obs`` itself stays dependency-free.
"""
from __future__ import annotations

import contextlib
import warnings


def has_jax_profiler() -> bool:
    """True iff the running jax exposes start_trace/stop_trace."""
    try:
        import jax.profiler
    except Exception:  # pragma: no cover - jax missing entirely
        return False
    return (hasattr(jax.profiler, "start_trace")
            and hasattr(jax.profiler, "stop_trace"))


@contextlib.contextmanager
def jax_profile(logdir: str):
    """Bracket a region with the jax profiler when available; yields True
    when a trace is actually being captured, False on the no-op path."""
    if not has_jax_profiler():
        yield False
        return
    import jax.profiler
    try:
        jax.profiler.start_trace(logdir)
    except Exception as e:  # pragma: no cover - backend-dependent
        warnings.warn(f"jax profiler unavailable ({e}); continuing untraced")
        yield False
        return
    try:
        yield True
    finally:
        try:
            jax.profiler.stop_trace()
        except Exception as e:  # pragma: no cover - backend-dependent
            warnings.warn(f"jax profiler stop failed ({e})")
