"""CI perf gate: compare a fresh ``BENCH_CI.json`` against the committed
baseline and annotate regressions.

    PYTHONPATH=src python -m benchmarks.check_regression \
        --current BENCH_CI.json
    PYTHONPATH=src python -m benchmarks.check_regression \
        --current BENCH_CI.json --refresh     # rewrite the baseline

The baseline (``benchmarks/baselines/ci_baseline.json``) maps row name →
median-µs as measured by ``benchmarks.run --tiny`` on a CI-class runner.
Shared runners are noisy — a 2-core box swings 1.5-2× run to run — so the
gate is deliberately generous:

* rows faster than ``--min-us`` in the baseline are skipped outright
  (µs-scale rows are pure scheduling noise at CI scale);
* ratios past ``--warn-ratio`` (default 2×) emit GitHub ``::warning::``
  annotations but do NOT fail the job;
* only ratios past ``--fail-ratio`` (default 3×) — a real cliff, not
  noise — emit ``::error::`` and exit non-zero;
* rows present on one side only are reported informationally (new
  benchmarks appear, old ones retire; neither is a regression).

``--refresh`` regenerates the baseline from the current artifact (run it
on a quiet machine after an intentional perf change and commit the file).
"""
from __future__ import annotations

import argparse
import json
import os
import re
import sys

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "baselines",
                                "ci_baseline.json")

#: stats-schema version suffix some emitters stamp into row names
#: (``serving/arch/leg@v3``) — stripped before baseline matching, so a
#: schema bump renames nothing from the gate's point of view
_VERSION_SUFFIX = re.compile(r"@v\d+$")


def canonical_name(name: str) -> str:
    """Row name with any ``@vN`` stats-schema suffix stripped."""
    return _VERSION_SUFFIX.sub("", name)


def rows_of(artifact: dict) -> dict:
    """{row name: us_per_call} over every suite in a BENCH_CI artifact,
    timed rows only (us > 0; ratio rows carry their payload in derived).
    Tolerates schema-versioned rows: names are canonicalized (``@vN``
    stripped) and rows without a ``us_per_call`` field are skipped instead
    of crashing the gate on an artifact from a newer emitter."""
    out = {}
    for suite in artifact.get("suites", {}).values():
        for row in suite.get("rows", []):
            us = row.get("us_per_call")
            if us is not None and us > 0:
                out[canonical_name(row["name"])] = us
    return out


def compare(current: dict, baseline: dict, min_us: float,
            warn_ratio: float, fail_ratio: float) -> dict:
    """Classify shared rows: {'ok': [...], 'warn': [...], 'fail': [...],
    'skipped': n, 'only_current': [...], 'only_baseline': [...]} where each
    listed entry is (name, baseline_us, current_us, ratio)."""
    out = {"ok": [], "warn": [], "fail": [], "skipped": 0,
           "only_current": sorted(set(current) - set(baseline)),
           "only_baseline": sorted(set(baseline) - set(current))}
    for name in sorted(set(current) & set(baseline)):
        base, cur = baseline[name], current[name]
        if base < min_us:
            out["skipped"] += 1
            continue
        ratio = cur / base
        entry = (name, base, cur, ratio)
        if ratio >= fail_ratio:
            out["fail"].append(entry)
        elif ratio >= warn_ratio:
            out["warn"].append(entry)
        else:
            out["ok"].append(entry)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--current", required=True,
                    help="BENCH_CI.json from this run")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--refresh", action="store_true",
                    help="rewrite the baseline from --current and exit")
    ap.add_argument("--min-us", type=float, default=200.0,
                    help="skip rows whose baseline is below this (noise)")
    ap.add_argument("--warn-ratio", type=float, default=2.0)
    ap.add_argument("--fail-ratio", type=float, default=3.0)
    args = ap.parse_args(argv)

    with open(args.current) as f:
        artifact = json.load(f)
    current = rows_of(artifact)

    if args.refresh:
        os.makedirs(os.path.dirname(args.baseline), exist_ok=True)
        with open(args.baseline, "w") as f:
            json.dump({"meta": artifact.get("meta", {}), "rows": current},
                      f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"baseline refreshed: {len(current)} rows -> {args.baseline}")
        return 0

    if not os.path.exists(args.baseline):
        print(f"::warning::no perf baseline at {args.baseline}; run "
              f"check_regression --refresh and commit it")
        return 0
    with open(args.baseline) as f:
        baseline = json.load(f)["rows"]

    r = compare(current, baseline, args.min_us, args.warn_ratio,
                args.fail_ratio)
    print(f"perf gate: {len(r['ok'])} ok, {len(r['warn'])} warn, "
          f"{len(r['fail'])} fail, {r['skipped']} skipped (<{args.min_us}µs), "
          f"{len(r['only_current'])} new, {len(r['only_baseline'])} retired")
    for name in r["only_current"]:
        print(f"  new row (no baseline): {name}")
    for name in r["only_baseline"]:
        print(f"  baseline row missing from this run: {name}")
    for name, base, cur, ratio in r["warn"]:
        print(f"::warning::perf: {name} {base:.0f}µs -> {cur:.0f}µs "
              f"({ratio:.2f}x baseline; warn threshold "
              f"{args.warn_ratio:.1f}x — shared-runner noise is common, "
              f"investigate if persistent)")
    for name, base, cur, ratio in r["fail"]:
        print(f"::error::perf regression: {name} {base:.0f}µs -> "
              f"{cur:.0f}µs ({ratio:.2f}x baseline, threshold "
              f"{args.fail_ratio:.1f}x)")
    return 1 if r["fail"] else 0


if __name__ == "__main__":
    sys.exit(main())
