"""CodeQwen1.5-7B — qwen1.5 arch [hf:Qwen/CodeQwen1.5-7B; hf]."""
from repro.models.lm_common import ArchConfig

CONFIG = ArchConfig(
    name="codeqwen1.5-7b", family="dense", n_layers=32, d_model=4096,
    n_heads=32, kv_heads=32, d_ff=13440, vocab=92416, norm="rms",
    mlp="swiglu", qkv_bias=True,
)
