"""Packed-key mapping engine: equivalence with brute-force numpy references
(the ``engine="legacy"`` multi-word oracle was deleted after its A/B window
closed — see ROADMAP), cross-layer table caching, dgrad capacity, and
bitmask dtype invariants.

Property tests use ``hypothesis`` when installed (requirements-dev.txt) and
fall back to a deterministic sample otherwise (``conftest.property_test``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import property_test

from repro.core import dataflows as df
from repro.core import hashing
from repro.core import kmap as km
from repro.core.sparse_conv import sparse_conv_apply
from repro.core.sparse_tensor import INVALID_COORD, SparseTensor, make_sparse_tensor

KMAP_FIELDS = ("m_out", "out_coords", "n_out", "ws_in", "ws_out", "ws_count",
               "bitmask")


def random_tensor(seed, n=100, cap=128, channels=8, extent=8, batch=1, d=3,
                  lo=0, bounds=False):
    """Random unique voxel cloud; ``lo < 0`` exercises negative coordinates,
    ``batch > 1`` duplicate spatial coords across batches."""
    rng = np.random.default_rng(seed)
    coords = rng.integers(lo, extent, size=(n, d))
    b = rng.integers(0, batch, size=(n, 1))
    coords = np.unique(np.concatenate([b, coords], axis=1), axis=0)
    n = coords.shape[0]
    feats = rng.standard_normal((cap, channels)).astype(np.float32)
    pad = np.zeros((cap - n, d + 1), np.int32)
    kw = dict(batch_bound=batch, spatial_bound=max(abs(lo), extent)) if bounds else {}
    return make_sparse_tensor(jnp.asarray(np.concatenate([coords, pad])),
                              jnp.asarray(feats), n, **kw)


def assert_kmaps_equal(a: km.KernelMap, b: km.KernelMap):
    for f in KMAP_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)), err_msg=f)


# ---------------------------------------------------------------------------
# Brute-force numpy references (the oracles the engine is tested against)
# ---------------------------------------------------------------------------

def np_bitmask(hits: np.ndarray) -> np.ndarray:
    """Reference for km._bitmask: exact for KD ≤ 31, composite above."""
    kd = hits.shape[-1]
    if kd <= 31:
        return (hits * (1 << np.arange(kd))).sum(axis=-1).astype(np.int32)
    pop = hits.sum(axis=-1).astype(np.int64)
    low = (hits[..., :24] * (1 << np.arange(24))).sum(axis=-1).astype(np.int64)
    return ((pop << 24) | low).astype(np.int32)


def np_build_kmap(stx, kernel: int, stride: int = 1, out_capacity=None) -> dict:
    """O(N·K^D) dict-based reference for build_kmap's full contract:
    output-stationary map, lex-sorted strided unique coords, hits-first
    pair lists, bitmasks, and all the padding conventions."""
    coords = np.asarray(stx.coords)
    n_valid = int(stx.num_valid)
    t = stx.stride
    cap_in = coords.shape[0]
    offs = np.asarray(km.kernel_offsets(kernel, stx.ndim_space))
    kd = offs.shape[0]
    lut = {tuple(c): i for i, c in enumerate(coords[:n_valid])}

    if stride == 1:
        out_coords = coords.copy()
        n_out = n_valid
        cap_out = out_capacity or cap_in
        out_coords = out_coords[:cap_out]
        out_stride = t
    else:
        out_stride = t * stride
        grid = coords[:n_valid].copy()
        grid[:, 1:] = (grid[:, 1:] // out_stride) * out_stride
        uniq = np.unique(grid, axis=0)        # lexicographic ascending
        n_out = uniq.shape[0]
        cap_out = out_capacity or cap_in
        out_coords = np.full((cap_out, coords.shape[1]), int(INVALID_COORD),
                             np.int32)
        out_coords[:min(n_out, cap_out)] = uniq[:cap_out]
        n_out = min(n_out, cap_out)

    m_out = -np.ones((cap_out, kd), np.int32)
    for i in range(n_out):
        c = out_coords[i]
        for k, off in enumerate(offs):
            q = (c[0],) + tuple(c[1:] + off * t)
            m_out[i, k] = lut.get(q, -1)

    ws_in = -np.ones((kd, cap_out), np.int32)
    ws_out = -np.ones((kd, cap_out), np.int32)
    ws_count = np.zeros((kd,), np.int32)
    for k in range(kd):
        rows = np.nonzero(m_out[:, k] >= 0)[0]
        ws_count[k] = len(rows)
        ws_in[k, :len(rows)] = m_out[rows, k]
        ws_out[k, :len(rows)] = rows

    bm = np.zeros((cap_out,), np.int32)
    bm[:n_out] = np_bitmask(m_out[:n_out] >= 0)
    return dict(m_out=m_out, out_coords=out_coords.astype(np.int32),
                n_out=np.int32(n_out), ws_in=ws_in, ws_out=ws_out,
                ws_count=ws_count, bitmask=bm)


def assert_kmap_matches_ref(kmap: km.KernelMap, ref: dict):
    for f in KMAP_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(kmap, f)), ref[f],
                                      err_msg=f)


# ---------------------------------------------------------------------------
# Packed lookup ≡ brute-force dict lookup (all three key-spec modes)
# ---------------------------------------------------------------------------

def _spec_of_kind(kind, batch, lo, extent):
    """One spec per packing mode: single int32 word, packed [hi, lo] pair,
    and the raw no-range-limit fallback (default when bounds are unknown)."""
    if kind == "one":
        spec = hashing.key_spec_for(3, batch_bound=batch,
                                    spatial_bound=max(abs(lo), extent))
        assert spec.words == 1 and not spec.raw
    elif kind == "two":
        spec = hashing.key_spec_for(3, batch_bound=500, spatial_bound=12000)
        assert spec.words == 2 and not spec.raw
    else:
        spec = hashing.key_spec_for(3)  # unknown bounds → raw columns
        assert spec.raw and spec.words == 4
    return spec


@property_test(
    "seed,extent,lo,batch,spec_kind",
    cases=[(0, 8, 0, 1, "one"), (1, 16, -8, 1, "one"), (2, 6, -5, 3, "one"),
           (3, 20, 0, 2, "two"), (4, 10, -12, 4, "two"), (5, 3, -2, 1, "two"),
           (6, 18, -9, 3, "raw"), (7, 5, 0, 1, "raw"), (8, 12, -12, 4, "raw")],
    strategies=lambda st: dict(seed=st.integers(0, 10_000),
                               extent=st.integers(3, 20),
                               lo=st.integers(-12, 0),
                               batch=st.integers(1, 4),
                               spec_kind=st.sampled_from(["one", "two", "raw"])),
    max_examples=24)
def test_property_packed_lookup_matches_bruteforce(seed, extent, lo, batch,
                                                   spec_kind):
    stx = random_tensor(seed, n=80, cap=96, extent=extent, lo=lo, batch=batch)
    spec = _spec_of_kind(spec_kind, batch, lo, extent)
    packed = hashing.CoordTable.build(stx.coords, stx.valid_mask, spec)
    rng = np.random.default_rng(seed + 1)
    # half perturbed copies of table rows (some present), half random
    q1 = np.asarray(stx.coords)[rng.integers(0, stx.capacity, 64)]
    q1 = q1 + rng.integers(-1, 2, size=q1.shape)
    q2 = np.concatenate([rng.integers(0, batch, (64, 1)),
                         rng.integers(lo - 2, extent + 2, (64, 3))], axis=1)
    q = np.concatenate([q1, q2]).astype(np.int32)
    lut = {tuple(c): i for i, c in
           enumerate(np.asarray(stx.coords)[: int(stx.num_valid)])}
    ref = np.asarray([lut.get(tuple(row), -1) for row in q], np.int32)
    np.testing.assert_array_equal(np.asarray(packed.lookup(jnp.asarray(q))), ref)


def test_pack_unpack_roundtrip_with_negatives():
    spec = hashing.key_spec_for(3, batch_bound=4, spatial_bound=30)
    rng = np.random.default_rng(0)
    coords = np.concatenate([rng.integers(0, 4, (200, 1)),
                             rng.integers(-30, 31, (200, 3))], axis=1)
    keys = hashing.pack_keys(jnp.asarray(coords, jnp.int32), spec)
    back = hashing.unpack_keys(keys, spec)
    np.testing.assert_array_equal(np.asarray(back), coords)
    # packing is order-isomorphic to lexicographic row order
    order_packed = np.asarray(hashing.sort_keys(keys)[0])
    order_lex = np.asarray(hashing.lex_argsort(jnp.asarray(coords, jnp.int32)))
    np.testing.assert_array_equal(np.lexsort(coords.T[::-1]), order_lex)
    np.testing.assert_array_equal(coords[order_packed], coords[order_lex])


def test_undeclared_bounds_have_no_range_limit():
    """Regression: a coordinate far outside any packed bit budget, on a
    tensor with NO declared bounds, must still appear in the kernel map
    (the raw-spec fallback keeps the seed's no-range-limit contract)."""
    coords = np.zeros((8, 4), np.int32)
    coords[:, 1] = np.arange(8) * 20000          # |x| up to 140000
    coords[:, 2] = -70000 + np.arange(8) * 100
    stx = make_sparse_tensor(jnp.asarray(coords), jnp.ones((8, 4)), 8)
    assert stx.spatial_bound == 0  # nothing declared
    for kernel, stride in [(3, 1), (2, 2)]:
        assert_kmap_matches_ref(km.build_kmap(stx, kernel, stride),
                                np_build_kmap(stx, kernel, stride))
    # self-hit at the center offset for every valid row
    m = np.asarray(km.build_kmap(stx, 3, 1).m_out)
    np.testing.assert_array_equal(m[:8, 0], np.arange(8))


def test_huge_declared_bounds_fall_back_instead_of_crashing():
    spec = hashing.key_spec_for(3, batch_bound=2, spatial_bound=20000)
    assert spec.raw  # too wide for two words → raw, not an AssertionError
    stx = make_sparse_tensor(
        jnp.asarray([[0, 20000, -20000, 3], [1, 5, 5, 5]], jnp.int32),
        jnp.ones((2, 4)), 2, batch_bound=2, spatial_bound=20000)
    assert_kmap_matches_ref(km.build_kmap(stx, 2, 2), np_build_kmap(stx, 2, 2))


def test_no_valid_key_aliases_pad_sentinel():
    """Regression: a 31-bit single-word layout would pack the maximal
    in-field row to exactly int32 max (the PAD sentinel), silently dropping
    it from strided dedup.  Word budgets are capped at 30 bits, so this spec
    must spill to two words and the row must survive a downsample."""
    spec = hashing.key_spec_for(3, batch_bound=2, spatial_bound=447)
    assert spec.total_bits == 31 and spec.words == 2
    coords = jnp.asarray([[1, 511, 511, 511], [0, 0, 0, 0]], jnp.int32)
    keys = hashing.pack_keys(coords, spec, valid=jnp.ones((2,), bool))
    assert (np.asarray(keys) != np.iinfo(np.int32).max).any(axis=-1).all()
    table = hashing.CoordTable.build(coords, jnp.ones((2,), bool), spec)
    np.testing.assert_array_equal(np.asarray(table.lookup(coords)), [0, 1])
    uniq = km._unique_from_keys(table, 2, 2)
    assert uniq is not None and int(uniq[1]) == 2


def test_out_of_range_queries_miss():
    spec = hashing.key_spec_for(3, batch_bound=1, spatial_bound=10)
    stx = random_tensor(0, extent=8)
    table = hashing.CoordTable.build(stx.coords, stx.valid_mask, spec)
    q = jnp.asarray([[0, 1000, 0, 0], [0, 0, -1000, 0], [2, 0, 0, 0],
                     [0, 0x3FFFFFF, 0x3FFFFFF, 0x3FFFFFF]], jnp.int32)
    assert (np.asarray(table.lookup(q)) == -1).all()


# ---------------------------------------------------------------------------
# build_kmap ≡ numpy reference, with and without the MapCache
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("kernel,stride", [(3, 1), (2, 2), (3, 2)])
@pytest.mark.parametrize("bounds", [False, True])
def test_build_kmap_matches_bruteforce(seed, kernel, stride, bounds):
    stx = random_tensor(seed, extent=16, lo=-4, batch=2, bounds=bounds)
    assert_kmap_matches_ref(km.build_kmap(stx, kernel, stride),
                            np_build_kmap(stx, kernel, stride))


def test_cached_table_reuse_and_adoption():
    stx = random_tensor(3, extent=16, bounds=True)
    cache = km.MapCache.for_tensor(stx)
    sub = km.build_kmap(stx, 3, 1, cache=cache)
    down = km.build_kmap(stx, 2, 2, cache=cache)
    assert_kmap_matches_ref(sub, np_build_kmap(stx, 3, 1))
    assert_kmap_matches_ref(down, np_build_kmap(stx, 2, 2))
    # the downsample adopted its output table: the child submanifold map
    # must come out identical to a from-scratch build
    cur = SparseTensor(coords=down.out_coords,
                       feats=jnp.zeros((down.capacity, 1)),
                       num_valid=down.n_out, stride=down.out_stride)
    child = km.build_kmap(cur, 3, 1, cache=cache)
    assert_kmap_matches_ref(child, np_build_kmap(cur, 3, 1))
    # exactly two tables live in the cache: stx's and the adopted child's
    assert len(cache._tables) == 2
    assert cache.hits >= 2   # the down reused stx's table; the child hit too


def test_transpose_kmap_equivalent_under_cached_table():
    stx = random_tensor(4, extent=16, bounds=True)
    cache = km.MapCache.for_tensor(stx)
    fwd_cached = km.build_kmap(stx, 2, 2, cache=cache)
    fwd_fresh = km.build_kmap(stx, 2, 2)
    assert_kmaps_equal(km.transpose_kmap(fwd_cached, stx),
                       km.transpose_kmap(fwd_fresh, stx))


def test_build_kmap_inside_jit_with_cache():
    stx = random_tensor(5, extent=16, bounds=True)

    @jax.jit
    def build():
        cache = km.MapCache.for_tensor(stx)
        a = km.build_kmap(stx, 3, 1, cache=cache)
        b = km.build_kmap(stx, 2, 2, cache=cache)
        return a, b

    a, b = build()
    assert_kmap_matches_ref(a, np_build_kmap(stx, 3, 1))
    assert_kmap_matches_ref(b, np_build_kmap(stx, 2, 2))


# ---------------------------------------------------------------------------
# All dataflows bit-identical on cached-table maps vs fresh maps
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kernel,stride", [(3, 1), (2, 2)])
def test_dataflows_bit_identical_on_cached_maps(kernel, stride):
    stx = random_tensor(6, n=60, cap=64, channels=4, extent=10, bounds=True)
    cache = km.MapCache.for_tensor(stx)
    cached = km.build_kmap(stx, kernel, stride, cache=cache)
    fresh = km.build_kmap(stx, kernel, stride)
    assert_kmaps_equal(cached, fresh)
    kd = kernel ** 3
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (kd, 4, 8)) * 0.3
    dy = jax.random.normal(key, (fresh.capacity, 8))
    for flow in df.DATAFLOWS:
        cfg = df.DataflowConfig(flow)
        y_new = df.sparse_conv_forward(stx.feats, w, cached, cfg)
        y_old = df.sparse_conv_forward(stx.feats, w, fresh, cfg)
        np.testing.assert_array_equal(np.asarray(y_new), np.asarray(y_old))
        dx_new = df.sparse_conv_dgrad(dy, w, cached, cfg, in_capacity=stx.capacity)
        dx_old = df.sparse_conv_dgrad(dy, w, fresh, cfg, in_capacity=stx.capacity)
        np.testing.assert_array_equal(np.asarray(dx_new), np.asarray(dx_old))
        dw_new = df.sparse_conv_wgrad(stx.feats, dy, cached, cfg)
        dw_old = df.sparse_conv_wgrad(stx.feats, dy, fresh, cfg)
        np.testing.assert_array_equal(np.asarray(dw_new), np.asarray(dw_old))


# ---------------------------------------------------------------------------
# dgrad accumulator capacity (regression: out_capacity != cap_in)
# ---------------------------------------------------------------------------

def test_dgrad_respects_input_capacity():
    stx = random_tensor(7, n=100, cap=128, channels=4, extent=16)
    out_cap = 64
    kmap = km.build_kmap(stx, 2, 2, out_capacity=out_cap)
    assert kmap.capacity == out_cap != stx.capacity
    w = jax.random.normal(jax.random.PRNGKey(1), (8, 4, 6)) * 0.3
    dy = jax.random.normal(jax.random.PRNGKey(2), (out_cap, 6))
    dx = df.sparse_conv_dgrad(dy, w, kmap, in_capacity=stx.capacity)
    assert dx.shape == (stx.capacity, 4)
    # brute-force pair-list reference
    ws_in, ws_out = np.asarray(kmap.ws_in), np.asarray(kmap.ws_out)
    ref = np.zeros((stx.capacity, 4), np.float32)
    wn, dyn = np.asarray(w), np.asarray(dy)
    for k in range(kmap.volume):
        for i_in, i_out in zip(ws_in[k], ws_out[k]):
            if i_in >= 0:
                ref[i_in] += dyn[i_out] @ wn[k].T
    np.testing.assert_allclose(np.asarray(dx), ref, rtol=1e-5, atol=1e-5)
    # input rows beyond the pair capacity must receive gradient too
    assert (np.abs(ref[out_cap:]).sum() > 0), "regression scene too small"


def test_custom_vjp_dgrad_shape_with_mismatched_capacities():
    stx = random_tensor(8, n=100, cap=128, channels=4, extent=16)
    kmap = km.build_kmap(stx, 2, 2, out_capacity=64)
    w = jax.random.normal(jax.random.PRNGKey(3), (8, 4, 6)) * 0.3

    def loss(feats, w):
        return jnp.sum(sparse_conv_apply(feats, w, kmap) ** 2)

    dx, dw = jax.grad(loss, argnums=(0, 1))(stx.feats, w)
    assert dx.shape == stx.feats.shape
    assert dw.shape == w.shape
    assert float(jnp.abs(dx[64:]).sum()) > 0


# ---------------------------------------------------------------------------
# bitmask dtype + composite path (K^D > 31)
# ---------------------------------------------------------------------------

def test_bitmask_is_int32_exact_below_32():
    stx = random_tensor(9)
    kmap = km.build_kmap(stx, 3, 1)
    assert kmap.bitmask.dtype == jnp.int32
    m = np.asarray(kmap.m_out)
    bm = np.asarray(kmap.bitmask)
    for i in range(int(stx.num_valid)):
        assert bm[i] == sum(1 << k for k in range(27) if m[i, k] >= 0)


def test_bitmask_composite_path_above_31():
    rng = np.random.default_rng(0)
    hit = jnp.asarray(rng.integers(0, 2, size=(50, 64)).astype(bool))
    bm = km._bitmask(hit)
    assert bm.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(bm), np_bitmask(np.asarray(hit)))
    # K=4 (even) in 3D has volume 64 → exercises the composite path end-to-end
    stx = random_tensor(10, extent=16)
    kmap = km.build_kmap(stx, 4, 2)
    assert kmap.volume == 64
    assert kmap.bitmask.dtype == jnp.int32
    assert_kmap_matches_ref(kmap, np_build_kmap(stx, 4, 2))


# ---------------------------------------------------------------------------
# O(N) radix sort for bounded packed keys (vs the stable comparison argsort)
# ---------------------------------------------------------------------------

@property_test(
    "seed,extent,lo,batch,spec_kind",
    cases=[(0, 8, 0, 1, "one"), (1, 16, -8, 2, "one"), (2, 6, -5, 3, "one"),
           (3, 20, 0, 2, "two"), (4, 10, -12, 4, "two"), (5, 3, -2, 1, "two")],
    strategies=lambda st: dict(seed=st.integers(0, 10_000),
                               extent=st.integers(3, 20),
                               lo=st.integers(-12, 0),
                               batch=st.integers(1, 4),
                               spec_kind=st.sampled_from(["one", "two"])),
    max_examples=16)
def test_property_radix_argsort_is_stable_argsort(seed, extent, lo, batch,
                                                  spec_kind):
    """The O(N·bits) radix argsort (XLA twin and numpy twin) is
    *bit*-identical to the stable comparison argsort on bounded packed
    keys: same permutation including tie order, negative coordinates, and
    the PAD tail."""
    stx = random_tensor(seed, n=80, cap=96, extent=extent, lo=lo, batch=batch)
    spec = _spec_of_kind(spec_kind, batch, lo, extent)
    keys = hashing.pack_keys(stx.coords, spec, valid=stx.valid_mask)
    kn = np.array(keys)
    kn[70:80] = kn[0:10]     # duplicates: stability must be exercised
    if kn.ndim == 1:
        ref = np.argsort(kn, kind="stable").astype(np.int32)
    else:
        ref = hashing.lex_argsort_np(kn)
    np.testing.assert_array_equal(
        np.asarray(hashing.radix_argsort_keys(jnp.asarray(kn), spec)), ref)
    np.testing.assert_array_equal(hashing.np_radix_argsort_keys(kn, spec), ref)
    # the sort_keys dispatcher picks radix for bounded specs — identical
    # layout to the comparison path it replaces
    order, sk = hashing.sort_keys(jnp.asarray(kn), spec)
    np.testing.assert_array_equal(np.asarray(order), ref)
    np.testing.assert_array_equal(np.asarray(sk), kn[ref])


def test_radix_argsort_padded_matches_argsort_with_sentinels():
    """Bitmask sort keys carry MISS (-1) and PAD (int32 max) sentinels; the
    padded radix path must keep the signed-compare layout (MISS first, PAD
    last, ties stable)."""
    rng = np.random.default_rng(0)
    vals = rng.integers(0, 1 << 12, 300).astype(np.int32)
    vals[50:80] = np.iinfo(np.int32).max    # PAD
    vals[100:110] = vals[0:10]              # duplicates
    vals[200:205] = -1                      # MISS
    got = np.asarray(hashing.radix_argsort_padded(jnp.asarray(vals), 12))
    np.testing.assert_array_equal(got, np.argsort(vals, kind="stable"))
    # numpy twin of the same padded path
    np.testing.assert_array_equal(
        hashing.np_radix_argsort_bits(
            np.asarray(hashing._remap_radix_word(jnp.asarray(vals), 12)), 13),
        np.argsort(vals, kind="stable"))


def test_sort_keys_raw_spec_falls_back_to_comparison_sort():
    spec = hashing.key_spec_for(3)          # unknown bounds → raw columns
    assert hashing.radix_word_bits(spec) is None
    rng = np.random.default_rng(1)
    keys = jnp.asarray(rng.integers(-50, 50, (64, 4)).astype(np.int32))
    order, _ = hashing.sort_keys(keys, spec)
    np.testing.assert_array_equal(np.asarray(order),
                                  hashing.lex_argsort_np(np.asarray(keys)))
