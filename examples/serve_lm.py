"""Serve a small LM with batched requests: prefill + KV-cache decode.

    PYTHONPATH=src python examples/serve_lm.py --arch qwen1_5_0_5b --batch 8
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import base
from repro.models import api


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1_5_0_5b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = base.reduced(base.get_arch(args.arch))
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    b, s = args.batch, args.prompt_len
    print(f"{cfg.name} (reduced) — batch={b} prompt={s} gen={args.gen}")

    prompts = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    cache = api.init_cache(cfg, b, s + args.gen)

    prefill = jax.jit(lambda p, t, c: api.prefill(cfg, p, t, c))
    decode = jax.jit(lambda p, c, t: api.decode_step(cfg, p, c, t))

    t0 = time.perf_counter()
    logits, cache = jax.block_until_ready(prefill(params, prompts, cache))
    print(f"prefill: {(time.perf_counter() - t0) * 1e3:8.1f} ms "
          f"({b * s / (time.perf_counter() - t0):8.0f} tok/s)")

    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [tok]
    t0 = time.perf_counter()
    for _ in range(args.gen - 1):
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    print(f"decode : {dt * 1e3:8.1f} ms ({b * (args.gen - 1) / dt:8.0f} tok/s, "
          f"{dt / (args.gen - 1) * 1e3:.2f} ms/token)")
    print("first sequence:", jnp.stack(out, 1)[0, :12].tolist())


if __name__ == "__main__":
    main()
