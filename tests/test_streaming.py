"""Composable coordinate tables and streaming kernel maps.

The contract under test (ISSUE 4): composed-batch tables
(``hashing.compose_tables``), delta-merged tables
(``CoordTable.delta_merge``) and every kernel map built from them — through
``build_maps_from_specs(tables=...)`` pre-adoption and through
``kmap.compose_kmaps`` scene-stack concatenation — are **bit-identical** to
fresh full builds, across negative coords, multi-batch packing, strided
table adoption and transposed (up) edges, for all three key-spec modes.
Plus the serving-engine integration: scene-granular hits where the PR-2
whole-batch digest scores misses, streaming delta submits, and the
deadline-/count-triggered flush satellites.

Property tests use ``hypothesis`` when installed and fall back to the
deterministic samples otherwise (``conftest.property_test``).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from conftest import property_test

from repro.core import hashing
from repro.core import kmap as km
from repro.core import plan as planlib
from repro.core.plan import KmapSpec, pyramid_map_specs
from repro.core.sparse_tensor import INVALID_COORD, SparseTensor
from repro.serve import (BucketLadder, Engine, Scene, SceneBatcher,
                         SceneDelta, apply_delta)
from repro.serve.workload import churned_stream

KMAP_FIELDS = ("m_out", "out_coords", "n_out", "ws_in", "ws_out", "ws_count",
               "bitmask")


def _spec_of_kind(kind):
    """One spec per packing mode (cf. test_mapping_engine): single int32
    word, packed [hi, lo] pair, raw no-range-limit fallback."""
    if kind == "one":
        spec = hashing.key_spec_for(3, batch_bound=4, spatial_bound=60)
        assert spec.words == 1 and not spec.raw
    elif kind == "two":
        spec = hashing.key_spec_for(3, batch_bound=500, spatial_bound=12000)
        assert spec.words == 2 and not spec.raw
    else:
        spec = hashing.key_spec_for(3)
        assert spec.raw
    return spec


def _mk_scene_coords(rng, n, lo=-50, hi=50):
    """(n', 4) unique batch-0 voxel rows (exercises negative coords)."""
    c = np.unique(np.concatenate(
        [np.zeros((2 * n, 1), np.int32),
         rng.integers(lo, hi, size=(2 * n, 3), dtype=np.int32)], axis=1),
        axis=0)
    return c[:n]


def _pack_batch(scene_coords, capacity):
    """Batch-major packed coords + tensor, as SceneBatcher lays rows out."""
    batch = np.full((capacity, 4), int(INVALID_COORD), np.int32)
    off = 0
    for b, c in enumerate(scene_coords):
        cb = c.copy()
        cb[:, 0] = b
        batch[off:off + len(c)] = cb
        off += len(c)
    st = SparseTensor(coords=jnp.asarray(batch), feats=jnp.zeros((capacity, 1)),
                      num_valid=jnp.asarray(off, jnp.int32), stride=1,
                      batch_bound=4, spatial_bound=64)
    return batch, st, off


def assert_kmaps_equal(a, b, ctx=""):
    for f in KMAP_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)),
                                      err_msg=f"{ctx}:{f}")


# ---------------------------------------------------------------------------
# compose_tables ≡ fresh batch build (tables bit-identical, incl. pad tail)
# ---------------------------------------------------------------------------

@property_test(
    "seed,sizes,spec_kind",
    cases=[(0, (17, 9, 23), "one"), (1, (40, 1, 12), "two"),
           (2, (8, 30), "raw"), (3, (25,), "one"), (4, (6, 6, 6, 6), "two")],
    strategies=lambda st: dict(
        seed=st.integers(0, 10_000),
        sizes=st.lists(st.integers(1, 40), min_size=1, max_size=4).map(tuple),
        spec_kind=st.sampled_from(["one", "two", "raw"])),
    max_examples=20)
def test_property_composed_table_bit_identical(seed, sizes, spec_kind):
    rng = np.random.default_rng(seed)
    spec = _spec_of_kind(spec_kind)
    scenes = [_mk_scene_coords(rng, n) for n in sizes]
    cap = sum(len(c) for c in scenes) + 11   # uneven pad tail
    batch, bst, total = _pack_batch(scenes, cap)
    fresh = hashing.CoordTable.build(bst.coords, bst.valid_mask, spec)
    off = 0
    parts = []
    for b, c in enumerate(scenes):
        t = hashing.CoordTable.build(jnp.asarray(c), jnp.ones((len(c),), bool),
                                     spec)
        parts.append((np.asarray(t.sorted_keys), np.asarray(t.order), b, off))
        off += len(c)
    keys, order = hashing.compose_tables(spec, parts, cap)
    np.testing.assert_array_equal(keys, np.asarray(fresh.sorted_keys))
    np.testing.assert_array_equal(order, np.asarray(fresh.order))


# ---------------------------------------------------------------------------
# delta_merge ≡ fresh build of the updated scene
# ---------------------------------------------------------------------------

@property_test(
    "seed,n,r,a,spec_kind",
    cases=[(0, 40, 5, 7, "one"), (1, 30, 1, 1, "two"), (2, 25, 4, 0, "raw"),
           (3, 20, 0, 6, "one"), (4, 50, 12, 12, "two"), (5, 15, 15, 3, "raw")],
    strategies=lambda st: dict(
        seed=st.integers(0, 10_000), n=st.integers(2, 50),
        r=st.integers(0, 10), a=st.integers(0, 10),
        spec_kind=st.sampled_from(["one", "two", "raw"])),
    max_examples=20)
def test_property_delta_merged_table_bit_identical(seed, n, r, a, spec_kind):
    rng = np.random.default_rng(seed)
    spec = _spec_of_kind(spec_kind)
    coords = _mk_scene_coords(rng, n)
    n = len(coords)
    r = min(r, n)
    table = hashing.CoordTable.build(jnp.asarray(coords),
                                     jnp.ones((n,), bool), spec)
    rm_idx = rng.choice(n, size=r, replace=False)
    removed = coords[rm_idx]
    kept = np.delete(coords, rm_idx, axis=0)
    taken = set(map(tuple, kept))
    added = []
    while len(added) < a:
        cand = np.concatenate([[0], rng.integers(-50, 50, size=3)]).astype(np.int32)
        if tuple(cand) not in taken:
            taken.add(tuple(cand))
            added.append(cand)
    added = (np.asarray(added, np.int32) if added
             else np.zeros((0, 4), np.int32))
    new_coords = np.concatenate([kept, added])
    fresh = hashing.CoordTable.build(jnp.asarray(new_coords),
                                     jnp.ones((len(new_coords),), bool), spec)
    merged = table.delta_merge(jnp.asarray(removed), jnp.asarray(added))
    np.testing.assert_array_equal(np.asarray(merged.sorted_keys),
                                  np.asarray(fresh.sorted_keys))
    np.testing.assert_array_equal(np.asarray(merged.order),
                                  np.asarray(fresh.order))
    # the host-side numpy twin (the engine's streaming hot path) agrees too
    nk, no = hashing.np_delta_merge(spec, np.asarray(table.sorted_keys),
                                    np.asarray(table.order), removed, added)
    np.testing.assert_array_equal(nk, np.asarray(fresh.sorted_keys))
    np.testing.assert_array_equal(no, np.asarray(fresh.order))


def test_delta_merged_table_builds_identical_kmaps():
    """Maps built on a delta-merged table (pre-adopted through the tables=
    hook, root level) equal maps built from scratch on the updated scene."""
    rng = np.random.default_rng(7)
    spec = _spec_of_kind("one")
    coords = _mk_scene_coords(rng, 60)
    prev = Scene(coords=coords[:, 1:],
                 feats=rng.normal(size=(len(coords), 4)).astype(np.float32))
    delta = SceneDelta(removed=coords[rng.choice(len(coords), 6,
                                                 replace=False), 1:],
                       added_coords=np.asarray([[51, 52, 53], [54, 55, 56]],
                                               np.int32),
                       added_feats=np.zeros((2, 4), np.float32))
    new = apply_delta(prev, delta)
    c01 = np.concatenate([np.zeros((new.num_points, 1), np.int32),
                          new.coords], axis=1)
    st = SparseTensor(coords=jnp.asarray(c01),
                      feats=jnp.asarray(new.feats),
                      num_valid=jnp.asarray(new.num_points, jnp.int32),
                      stride=1, batch_bound=4, spatial_bound=60)
    prev01 = np.concatenate([np.zeros((prev.num_points, 1), np.int32),
                             prev.coords], axis=1)
    table = hashing.CoordTable.build(jnp.asarray(prev01),
                                     jnp.ones((prev.num_points,), bool), spec)
    merged = table.delta_merge(
        np.concatenate([np.zeros((6, 1), np.int32), delta.removed], 1),
        np.concatenate([np.zeros((2, 1), np.int32), delta.added_coords], 1))
    specs = pyramid_map_specs(2, with_up=True)
    fresh = planlib.build_maps_from_specs(specs, st)
    n = jnp.asarray(new.num_points, jnp.int32)
    via_delta = planlib.build_maps_from_specs(
        specs, st, tables={1: (merged.sorted_keys, merged.order, n)})
    for ref in fresh:
        assert_kmaps_equal(fresh[ref], via_delta[ref], ctx=str(ref))


# ---------------------------------------------------------------------------
# Composed tables / composed kernel maps ≡ fresh batch map builds
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec_kind", ["one", "two", "raw"])
@pytest.mark.parametrize("with_up", [False, True])
def test_composed_tables_build_identical_maps(spec_kind, with_up):
    """build_maps over pre-composed table ladders (root order + identity
    child tables through the strided adoption edges, incl. transpose) is
    bit-identical to a fresh batch build, for every key-spec mode."""
    rng = np.random.default_rng(11)
    scenes = [_mk_scene_coords(rng, n) for n in (40, 25, 33)]
    cap = 128
    batch, bst, total = _pack_batch(scenes, cap)
    if spec_kind != "one":   # re-declare bounds to force the other specs
        bb, sb = (500, 12000) if spec_kind == "two" else (0, 0)
        bst = SparseTensor(coords=bst.coords, feats=bst.feats,
                           num_valid=bst.num_valid, stride=1,
                           batch_bound=bb, spatial_bound=sb)
    spec = km.MapCache.for_tensor(bst).spec
    specs = pyramid_map_specs(4, with_up=with_up, table="composed")
    down_strides = sorted({ms.tensor_stride * ms.stride for ms in specs
                           if ms.kind == "down"})
    fresh = planlib.build_maps_from_specs(specs, bst)
    ladders = [km.scene_table_ladder(c, spec, down_strides) for c in scenes]
    tables = km.compose_batch_tables(spec, ladders, cap)
    assert sorted(tables) == [1] + down_strides   # every level composed
    composed = planlib.build_maps_from_specs(specs, bst, tables=tables)
    for ref in fresh:
        assert_kmaps_equal(fresh[ref], composed[ref], ctx=str(ref))


@pytest.mark.parametrize("with_up", [False, True])
def test_composed_scene_kmap_stacks_bit_identical(with_up):
    """compose_kmaps: per-scene cached kernel-map stacks concatenate into
    the exact batch map stack (Minuet §4 proper) — m_out/ws/bitmask and the
    up-map transpose edges included.  Scene rows are shuffled: client scenes
    arrive in arbitrary row order, and the up-map pair lists follow the
    forward map's coarse-row order (transpose_kmap), not fine-row order —
    a regression the sorted rows np.unique produces would mask."""
    rng = np.random.default_rng(13)
    scenes = [_mk_scene_coords(rng, n) for n in (40, 25, 33)]
    for c in scenes:
        rng.shuffle(c)
    cap = 128
    batch, bst, total = _pack_batch(scenes, cap)
    specs = pyramid_map_specs(4, with_up=with_up, table="composed")
    fresh = planlib.build_maps_from_specs(specs, bst)
    entries = []
    for c in scenes:
        st = SparseTensor(coords=jnp.asarray(c),
                          feats=jnp.zeros((len(c), 1)),
                          num_valid=jnp.asarray(len(c), jnp.int32), stride=1,
                          batch_bound=4, spatial_bound=64)
        entries.append(planlib.build_scene_entry(specs, st))
    composed = km.compose_kmaps(entries, cap)
    assert composed is not None and set(composed) == set(fresh)
    for ref in fresh:
        assert_kmaps_equal(fresh[ref], composed[ref], ctx=str(ref))
    # degenerate inputs fall back instead of mis-composing
    assert km.compose_kmaps([], cap) is None
    assert km.compose_kmaps(entries, entries[0].n - 1) is None


# ---------------------------------------------------------------------------
# KmapSpec "table" strategy: a declared, serializable, rebindable axis
# ---------------------------------------------------------------------------

def test_kmap_spec_table_strategy_axis():
    ms = KmapSpec(("sub", 1), "sub", 3, 1, 1, table="incremental")
    assert KmapSpec.from_dict(ms.to_dict()) == ms
    # missing key (pre-PR files) defaults to the sort strategy
    d = ms.to_dict()
    del d["table"]
    assert KmapSpec.from_dict(d).table == "sort"
    with pytest.raises(AssertionError):
        KmapSpec(("sub", 1), "sub", 3, 1, 1, table="bogus")

    from repro.models import centerpoint, minkunet
    from repro.configs import centerpoint_waymo
    nplan = centerpoint.network_plan(centerpoint_waymo.CONFIG_TINY
                                     if hasattr(centerpoint_waymo, "CONFIG_TINY")
                                     else centerpoint_waymo.CONFIG_BENCH)
    assert nplan.table_strategy == "composed"    # models declare composition
    re = nplan.with_table_strategy("incremental")
    assert re.table_strategy == "incremental"
    assert all(ms.table == "incremental" for ms in re.map_specs)
    # round-trips through the serialized plan
    from repro.core.plan import NetworkPlan
    assert NetworkPlan.from_dict(re.to_dict()).table_strategy == "incremental"


# ---------------------------------------------------------------------------
# Engine integration: scene-granular reuse, streaming deltas, deadline flush
# ---------------------------------------------------------------------------

def _mk_scene(rng, n, channels, bound=60):
    coords = np.unique(rng.integers(-bound, bound, size=(n, 3),
                                    dtype=np.int32), axis=0)
    return Scene(coords=coords,
                 feats=rng.normal(size=(coords.shape[0], channels))
                 .astype(np.float32))


def _reference_forward(eng, scene):
    single = eng.batcher.pack([scene])
    maps = eng.binding.model.build_maps(single.st)
    feats = eng.binding.model.apply(eng.params, single.st, eng.cfg, maps,
                                    assignment=eng.assignment,
                                    bn_mode="affine")
    coords, out_feats, n_out = eng.binding.outputs_of(eng.cfg, single.st,
                                                      maps, feats)
    coords, out_feats = np.asarray(coords), np.asarray(out_feats)
    valid = np.arange(coords.shape[0]) < int(n_out)
    return coords[valid][:, 1:], out_feats[valid]


def test_engine_scene_granular_hits_where_digest_misses():
    """Churned batch composition: every flush's packed batch differs (the
    PR-2 whole-batch digest always misses) but the unchanged scenes hit the
    per-scene store, and the composed outputs stay bit-identical to the
    per-scene reference forward."""
    rng = np.random.default_rng(3)
    eng = Engine("centerpoint_waymo", ladder=BucketLadder((512,), max_batch=4),
                 spatial_bound=64)
    assert eng.map_strategy == "composed"
    pool = [_mk_scene(rng, n, 5) for n in (60, 70, 50, 40)]
    # three flushes over rotating scene subsets: batches never repeat
    batches = [pool[:3], [pool[3]] + pool[1:3], pool[:2] + [pool[3]]]
    results = []
    for group in batches:
        tickets = [eng.submit(s) for s in group]
        out = eng.flush()
        results.extend((s, out[t]) for s, t in zip(group, tickets))
    assert eng.stats.map_hits == 0 and eng.stats.map_misses == 3
    assert eng.stats.composed_batches == 3
    assert eng.stats.scene_misses == 4         # each unique scene built once
    assert eng.stats.scene_hits == 5           # every repeat slot composed
    for scene, res in results:
        ref_coords, ref_feats = _reference_forward(eng, scene)
        np.testing.assert_array_equal(res.coords, ref_coords)
        np.testing.assert_array_equal(res.feats, ref_feats)  # bit-identical


def test_engine_streaming_deltas_bit_identical():
    """submit_delta under the incremental strategy: frames delta-merge the
    scene table (counted), compose into batches, and every frame's output
    equals the reference forward of the full updated scene."""
    eng = Engine("centerpoint_waymo", ladder=BucketLadder((512,), max_batch=4),
                 spatial_bound=64, map_strategy="incremental")
    frames, bound = churned_stream(5, streams=3, frames=4, channels=5,
                                   n_range=(40, 80), extent=16.0, voxel=0.4)
    assert bound <= 64
    served = []
    for frame in frames:
        tickets = []
        for sid, scene, delta in frame:
            if delta is not None:
                tickets.append((scene, eng.submit_delta(sid, delta)))
            else:
                tickets.append((scene, eng.submit(scene, stream=sid)))
        out = eng.flush()
        served.extend((s, out[t]) for s, t in tickets)
    assert eng.stats.delta_merges > 0
    assert eng.stats.scene_hits > 0            # unchanged streams composed
    assert eng.stats.composed_batches == eng.stats.map_misses
    for scene, res in served:
        ref_coords, ref_feats = _reference_forward(eng, scene)
        np.testing.assert_array_equal(res.coords, ref_coords)
        np.testing.assert_array_equal(res.feats, ref_feats)


def test_engine_unknown_stream_delta_raises():
    eng = Engine("centerpoint_waymo", ladder=BucketLadder((256,)),
                 spatial_bound=64, map_strategy="incremental")
    with pytest.raises(KeyError):
        eng.submit_delta("nope", SceneDelta(removed=np.zeros((0, 3), np.int32),
                                            added_coords=np.zeros((0, 3), np.int32),
                                            added_feats=np.zeros((0, 5), np.float32)))
    # an added coord outside the declared bound must be rejected loudly —
    # BEFORE it could mis-pack into a cached scene table (np_pack_keys has
    # no PAD sentinel) and alias another scene's voxel
    rng = np.random.default_rng(1)
    eng.submit(_mk_scene(rng, 30, 5), stream="s")
    eng.flush()
    with pytest.raises(ValueError):
        eng.submit_delta("s", SceneDelta(
            removed=np.zeros((0, 3), np.int32),
            added_coords=np.asarray([[200, 0, 0]], np.int32),
            added_feats=np.zeros((1, 5), np.float32)))


def test_apply_delta_layout_and_validation():
    prev = Scene(coords=np.asarray([[0, 0, 0], [1, 1, 1], [2, 2, 2]], np.int32),
                 feats=np.arange(6, dtype=np.float32).reshape(3, 2))
    delta = SceneDelta(removed=np.asarray([[1, 1, 1]], np.int32),
                       added_coords=np.asarray([[3, 3, 3]], np.int32),
                       added_feats=np.asarray([[9.0, 9.0]], np.float32))
    new = apply_delta(prev, delta)
    np.testing.assert_array_equal(new.coords,
                                  [[0, 0, 0], [2, 2, 2], [3, 3, 3]])
    np.testing.assert_array_equal(new.feats, [[0, 1], [4, 5], [9, 9]])
    with pytest.raises(ValueError):
        apply_delta(prev, SceneDelta(removed=np.asarray([[7, 7, 7]], np.int32),
                                     added_coords=np.zeros((0, 3), np.int32),
                                     added_feats=np.zeros((0, 2), np.float32)))


def test_deadline_and_count_triggered_flushes():
    """The async-batching first step: submits flush automatically when the
    queue hits flush_count or the oldest scene ages past max_wait_ms, with
    both triggers counted and results drained by the next flush()/poll()."""
    rng = np.random.default_rng(9)
    scenes = [_mk_scene(rng, 40, 5) for _ in range(4)]

    eng = Engine("centerpoint_waymo", ladder=BucketLadder((256,), max_batch=2),
                 spatial_bound=64, flush_count=2)
    t0 = eng.submit(scenes[0])
    assert eng.stats.count_flushes == 0        # below threshold: queued
    t1 = eng.submit(scenes[1])
    assert eng.stats.count_flushes == 1        # threshold reached: ran
    out = eng.flush()                          # drains the auto-flushed pair
    assert set(out) == {t0, t1}
    assert eng.flush() == {}

    eng2 = Engine("centerpoint_waymo", ladder=BucketLadder((256,), max_batch=2),
                  spatial_bound=64, max_wait_ms=1e6)
    ta = eng2.submit(scenes[2])
    assert eng2.poll() == {}                   # deadline far away
    assert eng2.stats.deadline_flushes == 0
    eng2.max_wait_ms = 0.0                     # expire the oldest instantly
    out2 = eng2.poll()
    assert set(out2) == {ta} and eng2.stats.deadline_flushes == 1
    # a submit can also trip the deadline of an already-queued scene
    eng2.max_wait_ms = 1e6
    tb = eng2.submit(scenes[3])
    eng2.max_wait_ms = 0.0
    tc = eng2.submit(scenes[2])
    assert eng2.stats.deadline_flushes == 2
    assert set(eng2.flush()) == {tb, tc}
    s = eng2.stats.summary()
    assert s["deadline_flushes"] == 2
