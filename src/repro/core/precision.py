"""Mixed-precision policies for the sparse-conv execution stack.

TorchSparse++'s largest training wins over SpConv v2 (1.2-1.3x, paper §5)
come from mixed-precision (fp16/bf16) kernels.  On the TPU/Mosaic stack the
native half type is bfloat16, and the profitable recipe is the standard one:

* **compute** in bf16 — GEMM operands (gathered feature rows and the per-δ
  weight slices) are cast down before the MXU dot;
* **accumulate** in fp32 — every dataflow's output/grad accumulator and the
  ``jnp.dot(..., preferred_element_type=...)`` stay full precision, so Σ_δ
  partial sums don't round at every offset;
* **master weights** in fp32 — the optimizer (``train/optimizer.py``) keeps
  an fp32 copy of bf16 params and re-derives the working copy each step,
  so tiny updates aren't lost to bf16 quantization.

A ``PrecisionPolicy`` is carried per layer by the execution-plan IR
(``core/plan.py``) and threaded through all three dataflows of the
``sparse_conv_apply`` custom_vjp — fwd, dgrad and wgrad each honour it.
The default ``FP32`` policy reproduces the seed behaviour bit for bit
(fp32 compute/accum, output in the input's dtype).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """Per-layer(-group) numeric policy for the sparse-conv kernels.

    compute: dtype GEMM operands are cast to ("float32" | "bfloat16").
    accum:   accumulator / partial-sum dtype (fp32 for both policies — the
             paper's mixed-precision kernels accumulate full precision).
    output:  dtype of the kernel result; "" means "same as the input
             features' dtype" (the seed contract, and what keeps fp32
             plans bit-identical to the pre-plan path).
    params:  storage dtype for conv parameters ("" = leave unchanged).
             ``BF16`` stores a bf16 working copy (halved weight traffic on
             accelerators); ``BF16_AMP`` leaves params fp32 and rounds at
             the GEMM boundary instead (autocast convention).
    master_weights: the optimizer should keep an fp32 master copy of the
             (bf16-stored) parameters; consumed by ``train/optimizer.py``.
             Policies with fp32 param storage don't need one — the params
             are their own master.
    """

    compute: str = "float32"
    accum: str = "float32"
    output: str = ""
    params: str = ""
    master_weights: bool = False

    def __post_init__(self):
        for f in ("compute", "accum"):
            jnp.dtype(getattr(self, f))  # raises on unknown dtype names
        for f in ("output", "params"):
            if getattr(self, f):
                jnp.dtype(getattr(self, f))

    @property
    def compute_dtype(self):
        return jnp.dtype(self.compute)

    @property
    def accum_dtype(self):
        return jnp.dtype(self.accum)

    def output_dtype(self, like):
        """Result dtype for a kernel whose input features are ``like``."""
        return jnp.dtype(self.output) if self.output else jnp.dtype(like)

    def cast_param(self, p):
        """Cast one parameter leaf to the declared storage dtype (bf16
        working copy under the BF16 policy; identity when ``params`` is
        unset — FP32 and the autocast-style BF16_AMP)."""
        if not self.params:
            return p
        t = jnp.dtype(self.params)
        return p.astype(t) if p.dtype != t \
            and jnp.issubdtype(p.dtype, jnp.floating) else p

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "PrecisionPolicy":
        unknown = set(d) - {f.name for f in dataclasses.fields(PrecisionPolicy)}
        if unknown:
            raise ValueError(f"unknown PrecisionPolicy fields: {sorted(unknown)}")
        return PrecisionPolicy(**d)


def gemm_operand(a, compute_dtype, accum_dtype):
    """Round a GEMM operand to the compute dtype, then pick the fastest
    *numerically identical* carrier for the actual dot.

    bf16×bf16→f32 on the MXU multiplies bf16-rounded operands and
    accumulates fp32.  Products of bf16-rounded values are **exact** in
    fp32 (8-bit mantissas square into 16), so rounding the operands to bf16
    and running the dot in fp32 produces bit-identical results to a native
    bf16 GEMM with an fp32 accumulator.  XLA:CPU has no fast bf16 GEMM
    (bf16 dots fall off the Eigen path onto a naive emitter, ~0.6x), so on
    CPU we upcast the already-rounded operands and let Eigen run; on TPU
    the operands stay bf16 and Mosaic drives the MXU natively.
    """
    ct, at = jnp.dtype(compute_dtype), jnp.dtype(accum_dtype)
    a = a.astype(ct)
    if ct != at and jax.default_backend() == "cpu":
        a = a.astype(at)
    return a


#: Seed-identical full-precision policy (the default everywhere).
FP32 = PrecisionPolicy()

#: The paper's mixed-precision training recipe for accelerators: bf16
#: compute AND storage (params/activations — halved HBM traffic, native
#: MXU), fp32 accumulate, fp32 master weights in the optimizer.
BF16 = PrecisionPolicy(compute="bfloat16", output="bfloat16",
                       params="bfloat16", master_weights=True)

#: Autocast-style mixed precision: GEMM operands are rounded to bf16 at the
#: kernel boundary (same bf16-compute / fp32-accumulate numerics as the
#: MXU) but params/activations stay fp32 — the right recipe on backends
#: without bf16 execution units, where bf16 *storage* only buys emulated
#: elementwise ops and conversion traffic.  The fp32 params double as the
#: master copy, so no separate master tree is needed.
BF16_AMP = PrecisionPolicy(compute="bfloat16")

POLICIES = {"fp32": FP32, "bf16": BF16, "bf16_amp": BF16_AMP}


def bf16_training_policy(backend: str = None) -> PrecisionPolicy:
    """The bf16 training recipe best suited to a backend: full bf16 storage
    on accelerators, autocast-style on CPU."""
    backend = backend or jax.default_backend()
    return BF16_AMP if backend == "cpu" else BF16


def resolve(policy) -> PrecisionPolicy:
    """Accept a PrecisionPolicy, a name ("fp32"/"bf16"), or None (FP32)."""
    if policy is None:
        return FP32
    if isinstance(policy, PrecisionPolicy):
        return policy
    if isinstance(policy, str):
        try:
            return POLICIES[policy]
        except KeyError:
            raise ValueError(f"unknown precision policy {policy!r}; "
                             f"have {sorted(POLICIES)}") from None
    raise TypeError(f"cannot resolve precision policy from {type(policy)}")
