"""Scene queueing and (un)packing for the sparse serving engine.

A *scene* is one request: a variable-size quantized point cloud.  The
``SceneBatcher`` groups queued scenes FIFO into batches that fit a bucket,
packs each group into one capacity-padded batched ``SparseTensor`` (batch
index in coordinate column 0, padding rows at ``INVALID_COORD``), and
unpacks per-scene rows back out of a batched model output by batch index.

Packing declares ``batch_bound``/``spatial_bound`` on the batched tensor, so
the mapping engine's single-argsort packed-key path is the norm for every
served batch.  All padding work is host-side numpy: the device only ever
sees the final static-shape tensors.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.sparse_tensor import INVALID_COORD, SparseTensor
from repro.serve.bucketing import BucketLadder


@dataclasses.dataclass(frozen=True)
class Scene:
    """One request: quantized voxel coordinates + per-voxel features."""

    coords: np.ndarray  # (n, D) int32 spatial voxel coords (no batch column)
    feats: np.ndarray   # (n, C)

    def __post_init__(self):
        object.__setattr__(self, "coords", np.asarray(self.coords, np.int32))
        object.__setattr__(self, "feats", np.asarray(self.feats))
        assert self.coords.ndim == 2 and self.feats.ndim == 2
        assert self.coords.shape[0] == self.feats.shape[0]

    @property
    def num_points(self) -> int:
        return self.coords.shape[0]

    @property
    def digest(self) -> str:
        """Content hash of the voxel coordinates — the key of all mapping
        reuse (kernel maps depend on coordinates only, never features)."""
        d = self.__dict__.get("_digest")
        if d is None:
            d = hashlib.blake2b(np.ascontiguousarray(self.coords).tobytes(),
                                digest_size=16).hexdigest()
            object.__setattr__(self, "_digest", d)
        return d


@dataclasses.dataclass(frozen=True)
class SceneDelta:
    """Frame-to-frame update of a streamed scene: evict ``removed`` voxels,
    append ``added_*`` rows.  The streaming analogue of a full Scene — the
    engine's incremental path turns it into a sorted-table delta-merge
    instead of a fresh argsort."""

    removed: np.ndarray       # (r, D) voxel coords present in the prev frame
    added_coords: np.ndarray  # (a, D) voxel coords absent from the prev frame
    added_feats: np.ndarray   # (a, C)

    def __post_init__(self):
        object.__setattr__(self, "removed", np.asarray(self.removed, np.int32))
        object.__setattr__(self, "added_coords",
                           np.asarray(self.added_coords, np.int32))
        object.__setattr__(self, "added_feats", np.asarray(self.added_feats))
        assert self.added_coords.shape[0] == self.added_feats.shape[0]


def apply_delta(prev: Scene, delta: SceneDelta) -> Scene:
    """The new frame's scene: ``prev`` rows minus ``removed`` (original
    order preserved), then the added rows appended — exactly the row layout
    ``hashing.CoordTable.delta_merge`` reproduces, so the delta-merged table
    is bit-identical to a fresh build of this scene.  Streamed scenes must
    hold unique voxel coords (voxelized clouds are)."""
    index = {tuple(c): i for i, c in enumerate(prev.coords)}
    drop = np.zeros((prev.num_points,), bool)
    for c in delta.removed:
        i = index.get(tuple(c))
        if i is None or drop[i]:
            raise ValueError(f"delta removes a coord not in the scene: {c}")
        drop[i] = True
    coords = np.concatenate([prev.coords[~drop], delta.added_coords])
    feats = np.concatenate([prev.feats[~drop],
                            delta.added_feats.astype(prev.feats.dtype, copy=False)])
    return Scene(coords=coords, feats=feats)


def scene_from_tensor(st: SparseTensor) -> Scene:
    """Extract the valid rows of a single-scene SparseTensor as a Scene."""
    n = int(st.num_valid)
    coords = np.asarray(st.coords)[:n]
    assert coords.size == 0 or (coords[:, 0] == coords[0, 0]).all(), \
        "scene_from_tensor expects a single-batch tensor"
    return Scene(coords=coords[:, 1:], feats=np.asarray(st.feats)[:n])


@dataclasses.dataclass(frozen=True)
class SceneResult:
    """Per-scene output rows unpacked from a batched forward."""

    coords: np.ndarray  # (m, D) int32 output voxel coords (stride multiples)
    feats: np.ndarray   # (m, C_out)
    stride: int


@dataclasses.dataclass(frozen=True)
class PackedBatch:
    """One batched request: the padded tensor plus its unpack manifest."""

    st: SparseTensor
    scene_sizes: Tuple[int, ...]   # rows per scene, in batch-index order
    bucket: int                    # capacity the batch was padded to
    digest: str                    # content hash of the packed coords

    @property
    def num_scenes(self) -> int:
        return len(self.scene_sizes)


class SceneBatcher:
    """Queue + deterministic FIFO grouping + pack/unpack.

    spatial_bound: declared |coord| bound every scene must respect — it is
        the packed-key bit-budget promise; violating scenes are rejected at
        pack time rather than silently dropping out of kernel maps.
    """

    def __init__(self, ladder: BucketLadder, spatial_bound: int):
        assert spatial_bound > 0, "serving requires declared spatial bounds"
        self.ladder = ladder
        self.spatial_bound = int(spatial_bound)

    def plan(self, sizes: Sequence[int],
             cut_first: Optional[int] = None) -> List[List[int]]:
        """Greedy FIFO grouping of scene sizes into bucket-fitting batches.

        Deterministic: scenes stay in submission order; a batch closes when
        adding the next scene would overflow the largest bucket or exceed
        ``max_batch`` scenes.  Returns lists of scene indices.

        cut_first: optional scene-count cap on the FIRST group only — the
        engine's deadline-aware admission cuts the head batch so an
        about-to-expire request stops waiting for co-batched work.  None
        (default) is the pure greedy grouping (the bit-identity contract
        path); later groups always use the full ``max_batch``.
        """
        with obs.span("batch_plan", scenes=len(sizes)) as sp:
            groups: List[List[int]] = []
            cur: List[int] = []
            cur_rows = 0
            for i, n in enumerate(sizes):
                if n > self.ladder.max_capacity:
                    raise ValueError(f"scene {i} ({n} rows) exceeds largest "
                                     f"bucket ({self.ladder.max_capacity})")
                limit = (min(cut_first, self.ladder.max_batch)
                         if cut_first is not None and not groups
                         else self.ladder.max_batch)
                if cur and (cur_rows + n > self.ladder.max_capacity
                            or len(cur) >= limit):
                    groups.append(cur)
                    cur, cur_rows = [], 0
                cur.append(i)
                cur_rows += n
            if cur:
                groups.append(cur)
            sp.set(groups=len(groups))
        return groups

    def pack(self, scenes: Sequence[Scene]) -> PackedBatch:
        """Pack ≤ max_batch scenes into one bucket-padded SparseTensor."""
        assert 1 <= len(scenes) <= self.ladder.max_batch, len(scenes)
        sizes = tuple(s.num_points for s in scenes)
        total = sum(sizes)
        cap = self.ladder.select(total)
        with obs.span("batch_pack", scenes=len(scenes), rows=total,
                      bucket=cap):
            return self._pack_body(scenes, sizes, total, cap)

    def _pack_body(self, scenes, sizes, total, cap) -> PackedBatch:
        d = scenes[0].coords.shape[1]
        c = scenes[0].feats.shape[1]

        coords = np.full((cap, 1 + d), int(INVALID_COORD), np.int32)
        feats = np.zeros((cap, c), dtype=scenes[0].feats.dtype)
        off = 0
        for b, s in enumerate(scenes):
            assert s.coords.shape[1] == d and s.feats.shape[1] == c
            if s.num_points and int(np.abs(s.coords).max()) > self.spatial_bound:
                raise ValueError(
                    f"scene {b} violates declared spatial_bound "
                    f"{self.spatial_bound}: max |coord| = {np.abs(s.coords).max()}")
            coords[off:off + s.num_points, 0] = b
            coords[off:off + s.num_points, 1:] = s.coords
            feats[off:off + s.num_points] = s.feats
            off += s.num_points

        digest = hashlib.blake2b(coords.tobytes(), digest_size=16).hexdigest()
        st = SparseTensor(coords=jnp.asarray(coords), feats=jnp.asarray(feats),
                          num_valid=jnp.asarray(total, jnp.int32), stride=1,
                          batch_bound=self.ladder.max_batch,
                          spatial_bound=self.spatial_bound)
        return PackedBatch(st=st, scene_sizes=sizes, bucket=cap, digest=digest)

    @staticmethod
    def unpack(batch: PackedBatch, out_coords, out_feats, n_out,
               out_stride: int = 1) -> List[SceneResult]:
        """Split a batched model output back into per-scene rows.

        Selects rows by the batch column of ``out_coords`` (valid rows
        only), preserving row order — for stride-1 outputs that is exactly
        the packed input order, for strided outputs the sorted-key order the
        unique pass produced (both match the per-scene forward's order).
        """
        out_coords = np.asarray(out_coords)
        out_feats = np.asarray(out_feats)
        valid = np.arange(out_coords.shape[0]) < int(n_out)
        results = []
        for b in range(batch.num_scenes):
            rows = valid & (out_coords[:, 0] == b)
            results.append(SceneResult(coords=out_coords[rows, 1:],
                                       feats=out_feats[rows],
                                       stride=out_stride))
        return results
