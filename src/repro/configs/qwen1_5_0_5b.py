"""Qwen1.5-0.5B — QKV bias [hf:Qwen/Qwen1.5-0.5B; hf]."""
from repro.models.lm_common import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-0.5b", family="dense", n_layers=24, d_model=1024,
    n_heads=16, kv_heads=16, d_ff=2816, vocab=151936, norm="rms",
    mlp="swiglu", qkv_bias=True, tie_embeddings=True,
)
