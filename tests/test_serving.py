"""Serving subsystem: bucket selection, batcher round-trip, plan
persistence, and the engine's end-to-end correctness contract (batched ≡
per-scene, bounded recompiles, cross-request map reuse)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import dataflows as df
from repro.core.kmap import MapCache
from repro.core.sparse_conv import TrainDataflowConfig, apply_conv, init_conv, ConvSpec
from repro.core.kmap import build_kmap
from repro.models import centerpoint, minkunet
from repro.serve import (BucketLadder, Engine, PlanRegistry, Scene,
                         SceneBatcher, scene_from_tensor)
from repro.serve.workload import lidar_stream

RNG = np.random.default_rng(0)


def _mk_scene(n, channels, bound=60, seed=None):
    rng = np.random.default_rng(seed) if seed is not None else RNG
    coords = np.unique(
        rng.integers(-bound, bound, size=(n, 3), dtype=np.int32), axis=0)
    return Scene(coords=coords,
                 feats=rng.normal(size=(coords.shape[0], channels)).astype(np.float32))


# ---------------------------------------------------------------- buckets

def test_bucket_selection_smallest_fit_deterministic():
    ladder = BucketLadder((128, 512, 2048), max_batch=4)
    assert ladder.select(1) == 128
    assert ladder.select(128) == 128
    assert ladder.select(129) == 512
    assert ladder.select(2048) == 2048
    # deterministic: same input, same bucket, every time
    assert all(ladder.select(300) == 512 for _ in range(5))
    with pytest.raises(ValueError):
        ladder.select(2049)


def test_bucket_ladder_validation():
    with pytest.raises(AssertionError):
        BucketLadder((512, 128))          # must ascend
    with pytest.raises(AssertionError):
        BucketLadder(())
    geo = BucketLadder.geometric(256, 3)
    assert geo.capacities == (256, 512, 1024)


def test_batcher_plan_fifo_respects_bucket_and_batch_limits():
    ladder = BucketLadder((256, 512), max_batch=2)
    b = SceneBatcher(ladder, spatial_bound=64)
    groups = b.plan([100, 200, 300, 50, 50, 50])
    # FIFO: scene order preserved; limits: ≤512 rows and ≤2 scenes per group
    assert [i for g in groups for i in g] == list(range(6))
    for g in groups:
        assert len(g) <= 2
        assert sum([100, 200, 300, 50, 50, 50][i] for i in g) <= 512
    assert groups == b.plan([100, 200, 300, 50, 50, 50])  # deterministic
    with pytest.raises(ValueError):
        b.plan([513])


# ---------------------------------------------------------------- batcher

def test_pack_unpack_roundtrip_identity():
    """pack K scenes → 'identity model' → unpack reproduces every scene."""
    ladder = BucketLadder((256,), max_batch=3)
    b = SceneBatcher(ladder, spatial_bound=64)
    scenes = [_mk_scene(n, 4, seed=n) for n in (40, 70, 25)]
    batch = b.pack(scenes)
    assert batch.bucket == 256
    assert int(batch.st.num_valid) == sum(s.num_points for s in scenes)
    assert batch.st.batch_bound == 3 and batch.st.spatial_bound == 64
    out = b.unpack(batch, batch.st.coords, batch.st.feats,
                   int(batch.st.num_valid), out_stride=1)
    assert len(out) == 3
    for scene, res in zip(scenes, out):
        np.testing.assert_array_equal(res.coords, scene.coords)
        np.testing.assert_array_equal(res.feats, scene.feats)


def test_pack_rejects_bound_violation():
    b = SceneBatcher(BucketLadder((256,)), spatial_bound=16)
    bad = Scene(coords=np.array([[0, 0, 40]], np.int32),
                feats=np.zeros((1, 4), np.float32))
    with pytest.raises(ValueError):
        b.pack([bad])


def test_pack_digest_is_content_keyed():
    b = SceneBatcher(BucketLadder((256,), max_batch=2), spatial_bound=64)
    s1, s2 = _mk_scene(30, 4, seed=1), _mk_scene(30, 4, seed=2)
    s1_copy = Scene(coords=s1.coords.copy(), feats=s1.feats.copy())
    assert b.pack([s1]).digest == b.pack([s1_copy]).digest
    assert b.pack([s1]).digest != b.pack([s2]).digest
    assert b.pack([s1, s2]).digest != b.pack([s2, s1]).digest


# ------------------------------------------------------------------ plans

def test_plan_registry_save_load_identical(tmp_path):
    reg = PlanRegistry()
    assignment = {
        (1, 3, "sub"): TrainDataflowConfig.bind_all(
            df.DataflowConfig("gather_scatter")),
        (2, 2, "down"): TrainDataflowConfig.bind_fwd_dgrad(
            df.DataflowConfig("implicit_gemm", n_splits=2, tile_m=64),
            df.DataflowConfig("fetch_on_demand")),
    }
    reg.set("minkunet_kitti", assignment)
    path = reg.save(str(tmp_path / "plans.json"))
    loaded = PlanRegistry.load(path)
    assert loaded.get("minkunet_kitti") == assignment
    assert loaded.archs() == ["minkunet_kitti"]
    # unknown arch → empty assignment, not an error
    assert loaded.get("never_tuned") == {}


def test_plan_registry_rejects_bad_version(tmp_path):
    p = tmp_path / "bad.json"
    p.write_text('{"version": 99, "plans": {}}')
    with pytest.raises(ValueError):
        PlanRegistry.load(str(p))


def test_default_serving_space_spans_dataflows_and_backends():
    """The tuner's default space searches all three dataflows on both
    backends when the installed jax can run Pallas (interpret mode on CPU),
    and degrades to the XLA triple when it can't — never an error."""
    forced = df.default_serving_space(include_pallas=True)
    assert len(forced) == 7
    assert {c.dataflow for c in forced} == set(df.DATAFLOWS)
    assert {c.backend for c in forced} == {"xla", "pallas"}
    # the tile-skipping worklist variant is its own searched point, and
    # only exists on the pallas implicit-GEMM axis
    wl = [c for c in forced if c.worklist]
    assert len(wl) == 1
    assert wl[0].backend == "pallas" and wl[0].dataflow == "implicit_gemm"
    xla_only = df.default_serving_space(include_pallas=False)
    assert len(xla_only) == 3
    assert all(c.backend == "xla" for c in xla_only)
    assert {c.dataflow for c in xla_only} == set(df.DATAFLOWS)
    # the probing default resolves to exactly one of the two shapes
    assert df.default_serving_space() in (xla_only, forced)


def test_pallas_assignment_roundtrips_plan_registry(tmp_path):
    """A tuner pick on the Pallas axis persists through ``PlanRegistry``
    and reloads into an engine intact — including the split-plan demand it
    creates on the executor-input side."""
    reg = PlanRegistry()
    assignment = {(1, 3, "sub"): TrainDataflowConfig.bind_all(
        df.DataflowConfig("implicit_gemm", n_splits=2, backend="pallas"))}
    reg.set("minkunet_kitti", assignment)
    path = reg.save(str(tmp_path / "plans.json"))
    eng = Engine("minkunet_kitti", ladder=BucketLadder((256,), max_batch=2),
                 spatial_bound=64, plans=path)
    assert eng.assignment == assignment
    assert eng.assignment[(1, 3, "sub")].fwd.backend == "pallas"
    # the pallas implicit-GEMM choice declares pre-built executor split
    # plans on the compiled plan (composed per batch by the serving engine)
    specs = eng.nplan.split_plan_specs()
    assert specs and all(ns == 2 and srt for _, ns, srt in specs)


def test_dataflow_config_dict_roundtrip():
    cfg = df.DataflowConfig("fetch_on_demand", n_splits=0, tile_m=32,
                            tile_n=64, backend="pallas")
    assert df.DataflowConfig.from_dict(cfg.to_dict()) == cfg
    with pytest.raises(ValueError):
        df.DataflowConfig.from_dict({"dataflow": "implicit_gemm", "bogus": 1})


def test_serialized_config_stamps_effective_backend():
    """A "pallas" request only *runs* Pallas for dataflows that have a
    kernel; serialized configs (and therefore tuner sweep logs and plan
    registries) carry the derived ``effective_backend`` so sweep records
    say what actually executed."""
    # gather_scatter has no pallas forward kernel: requested != effective
    gs = df.DataflowConfig("gather_scatter", backend="pallas")
    assert gs.to_dict()["effective_backend"] == "xla"
    assert gs.effective_backend("fwd") == "xla"
    ig = df.DataflowConfig("implicit_gemm", backend="pallas")
    assert ig.to_dict()["effective_backend"] == "pallas"
    assert ig.effective_backend("dgrad") == "xla"   # dgrad is always XLA scan
    assert ig.effective_backend("wgrad") == "pallas"
    assert df.DataflowConfig("implicit_gemm").to_dict()["effective_backend"] == "xla"
    # the stamp is derived, not state: it round-trips away cleanly
    assert df.DataflowConfig.from_dict(gs.to_dict()) == gs


# ----------------------------------------------------------------- engine

def _reference_forward(eng, scene):
    """Per-scene forward through the public model API at the same bucket."""
    single = eng.batcher.pack([scene])
    maps = eng.binding.model.build_maps(single.st)
    feats = eng.binding.model.apply(eng.params, single.st, eng.cfg, maps,
                                    assignment=eng.assignment, bn_mode="affine")
    coords, out_feats, n_out = eng.binding.outputs_of(eng.cfg, single.st,
                                                      maps, feats)
    coords, out_feats = np.asarray(coords), np.asarray(out_feats)
    valid = np.arange(coords.shape[0]) < int(n_out)
    return coords[valid][:, 1:], out_feats[valid]


@pytest.mark.parametrize("arch,channels", [("minkunet_kitti", 4),
                                           ("centerpoint_waymo", 5)])
def test_batched_engine_bit_identical_to_per_scene(arch, channels):
    """The acceptance contract: a mixed-size request stream served batched
    produces, per scene, exactly the bits of the per-scene forward."""
    eng = Engine(arch, ladder=BucketLadder((256, 512), max_batch=3),
                 spatial_bound=64)
    scenes = [_mk_scene(n, channels, seed=n) for n in (50, 120, 30, 200, 80)]
    results = eng.serve(scenes, flush_every=3)
    assert len(results) == len(scenes)
    for scene, res in zip(scenes, results):
        ref_coords, ref_feats = _reference_forward(eng, scene)
        np.testing.assert_array_equal(res.coords, ref_coords)
        assert res.feats.dtype == ref_feats.dtype
        np.testing.assert_array_equal(res.feats, ref_feats)  # bit-identical


def test_engine_recompile_bound_and_map_reuse():
    """≤1 jit compile per bucket per stage after warmup, and replayed
    batches skip map construction via the content-keyed cross-request
    cache.  Under the default "composed" strategy batch maps are
    merge-composed on the host, so the map-builder stage is never traced
    at all; the "sort" strategy keeps the PR-2 one-trace-per-bucket bound."""
    eng = Engine("centerpoint_waymo",
                 ladder=BucketLadder((256, 512), max_batch=3), spatial_bound=64)
    assert eng.map_strategy == "composed"    # the plan-declared default
    eng.warmup()
    warm_exec = dict(eng.stats.recompiles)
    assert warm_exec == {256: 1, 512: 1}     # one executor trace per bucket
    assert eng.stats.map_compiles == {}      # composed: no builder traces
    assert eng.stats.composed_batches == 2   # one composed batch per bucket

    scenes = [_mk_scene(n, 5, seed=100 + n) for n in (60, 150, 40, 220)]
    eng.serve(scenes, flush_every=2)
    hits0 = eng.stats.map_hits
    eng.serve(scenes, flush_every=2)         # replay: identical batches
    # no new traces in steady state — the ≤1-per-bucket guarantee
    assert eng.stats.recompiles == warm_exec
    assert eng.stats.map_compiles == {}
    # replayed epoch's batches all hit the whole-batch map cache
    assert eng.stats.map_hits >= hits0 + 2
    s = eng.stats.summary()
    assert s["scenes"] == 8 and s["p95_ms"] >= s["p50_ms"] > 0

    # the "sort" override restores the PR-2 jitted builder path exactly
    eng2 = Engine("centerpoint_waymo",
                  ladder=BucketLadder((256, 512), max_batch=3),
                  spatial_bound=64, map_strategy="sort")
    eng2.warmup()
    assert eng2.stats.map_compiles == {256: 1, 512: 1}
    assert eng2.stats.composed_batches == 0 and eng2.stats.scene_misses == 0


def test_engine_rejects_oversize_scene():
    eng = Engine("minkunet_kitti", ladder=BucketLadder((128,), max_batch=2),
                 spatial_bound=64)
    with pytest.raises(ValueError):
        eng.submit(Scene(coords=np.zeros((129, 3), np.int32),
                         feats=np.zeros((129, 4), np.float32)))


def test_engine_loads_plans_at_startup(tmp_path):
    reg = PlanRegistry()
    assignment = {(1, 3, "sub"): TrainDataflowConfig.bind_all(
        df.DataflowConfig("gather_scatter"))}
    reg.set("minkunet_kitti", assignment)
    path = reg.save(str(tmp_path / "plans.json"))
    eng = Engine("minkunet_kitti", ladder=BucketLadder((256,), max_batch=2),
                 spatial_bound=64, plans=path)
    assert eng.assignment == assignment


def test_scene_from_tensor_and_workload_bounds():
    scenes, bound = lidar_stream(0, 3, 4, n_range=(50, 120))
    assert len(scenes) == 3
    for s in scenes:
        assert s.num_points > 0
        assert int(np.abs(s.coords).max()) <= bound
    # distinct sizes exist in a mixed stream (not all padded equal)
    assert len({s.num_points for s in scenes}) > 1


# ---------------------------------------------------- core serving hooks

def test_mapcache_content_key_hits_across_array_objects():
    st = scene_st = None
    scenes, bound = lidar_stream(1, 1, 4, n_range=(60, 60))
    b = SceneBatcher(BucketLadder((128,)), spatial_bound=bound)
    batch1 = b.pack(scenes)
    batch2 = b.pack([Scene(coords=scenes[0].coords.copy(),
                           feats=scenes[0].feats.copy())])
    cache = MapCache.for_tensor(batch1.st)
    t1 = cache.table(batch1.st, key=batch1.digest)
    t2 = cache.table(batch2.st, key=batch2.digest)   # different arrays, same content
    assert t1 is t2
    assert cache.hits == 1 and cache.misses == 1
    cache.clear()
    assert len(cache) == 0


def test_build_maps_populates_caller_supplied_empty_cache():
    """Regression: an empty MapCache is falsy (__len__), so `cache or ...`
    would silently discard it — the caller's cache must still be warmed."""
    scenes, bound = lidar_stream(3, 1, 4, n_range=(60, 60))
    st = SceneBatcher(BucketLadder((128,)), spatial_bound=bound).pack(scenes).st
    for model in (minkunet, centerpoint):
        cache = MapCache.for_tensor(st)
        assert len(cache) == 0 and not cache   # falsy when empty
        model.build_maps(st, cache=cache)
        assert len(cache) > 0
        assert cache.misses > 0


def test_bounds_propagate_through_apply_conv():
    scenes, bound = lidar_stream(2, 1, 4, n_range=(80, 80))
    b = SceneBatcher(BucketLadder((128,), max_batch=2), spatial_bound=bound)
    st = b.pack(scenes).st
    kmap = build_kmap(st, 2, 2)
    params = init_conv(jax.random.PRNGKey(0), ConvSpec(4, 8, 2, stride=2))
    out = apply_conv(params, st, kmap)
    assert out.batch_bound == st.batch_bound
    assert out.spatial_bound == st.spatial_bound
