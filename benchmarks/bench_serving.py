"""Serving-engine throughput/latency: bucketed batching + map reuse.

The production question behind the ROADMAP north star: what does the sparse
stack sustain under mixed-size request traffic?  For each arch
(MinkUNet-KITTI segmentation, CenterPoint-Waymo detection) this suite
drives the same synthetic stream through:

* ``batched``   — the serving engine with its bucket ladder (warm, jitted);
* ``unbatched`` — the same engine restricted to one scene per batch
  (the "per-request forward" a naive deployment does);
* ``repeat``    — the stream replayed through the warm engine: identical
  packed batches hit the cross-request map cache, so the second epoch skips
  kernel-map construction entirely (hit rate in the derived column);
* ``sharded``   — with ``--devices N`` (or several visible jax devices):
  the replayed stream through a ``DeviceRouter`` sharding the same ladder
  over N devices vs the single-device engine.  CPU CI uses host-platform
  virtual devices (``XLA_FLAGS=--xla_force_host_platform_device_count=N``);
  on one shared CPU the speedup is pipelining (one worker's host packing
  overlapping another's compute), on real accelerators it is parallelism.

Emits scenes/s and p50/p95 per-scene latency.  ``--tiny`` shrinks the
stream and ladder for CI smoke coverage.
"""
from __future__ import annotations

import argparse
import statistics
import time

import jax

from benchmarks import common
from repro import obs
from repro.serve.bucketing import BucketLadder
from repro.serve.engine import ARCHS, Engine, EngineStats
from repro.serve.router import DeviceRouter
from repro.serve.workload import lidar_stream


def _ms(v) -> str:
    """Derived-column formatting for maybe-None millisecond stats."""
    return "none" if v is None else f"{v:.1f}"


def _emit_phases(arch: str, tag: str, s: dict) -> None:
    """One row per recorded phase (median µs) — the per-phase trend lines
    check_regression.py gates on."""
    for name, ph in s.get("phases", {}).items():
        if ph["p50_ms"] is None:
            continue
        common.emit(f"serving/{arch}/{tag}/phase/{name}",
                    ph["p50_ms"] * 1e3,
                    f"count={ph['count']};p95_ms={_ms(ph['p95_ms'])}")


def _drive(arch: str, scenes, bound: int, ladder: BucketLadder,
           flush_every: int, tag: str, epochs: int = 1):
    eng = Engine(arch, ladder=ladder, spatial_bound=bound)
    eng.warmup()
    eng.stats = EngineStats()   # steady state only: warmup compiles excluded,
    for _ in range(epochs):     # so recompiles should stay 0
        eng.serve(scenes, flush_every=flush_every)
    s = eng.stats.summary()
    mc = s["map_cache"]
    hit_rate = mc["hits"] / max(mc["hits"] + mc["misses"], 1)
    derived = (f"scenes_per_s={s['scenes_per_s']:.2f};p95_ms={_ms(s['p95_ms'])};"
               f"recompiles={sum(s['recompiles'].values())};"
               f"map_hit_rate={hit_rate:.2f}")
    common.emit(f"serving/{arch}/{tag}/p50", (s["p50_ms"] or 0.0) * 1e3,
                derived)
    if tag == "batched":
        _emit_phases(arch, tag, s)
    return s


def _saturating_leg(arch: str, scenes, bound: int, ladder: BucketLadder,
                    deadline_ms: float):
    """Drive the engine past capacity: a deadline (``max_wait_ms``) far below
    the per-batch service time, submissions arriving one at a time.  Every
    submit can trip a deadline flush, and per-request latency is scored
    against the deadline as an SLO — the row reports the miss rate and how
    the engine degrades (scenes/s under overload vs the batched leg)."""
    eng = Engine(arch, ladder=ladder, spatial_bound=bound,
                 max_wait_ms=deadline_ms)
    eng.warmup()
    eng.serve(scenes, flush_every=0)            # warm maps/digests
    eng.stats = EngineStats()
    results = {}
    for s in scenes:
        eng.submit(s)
        # an arrival gap longer than the deadline: the next poll/submit sees
        # the oldest queued scene expired and fires a deadline flush (CPU
        # service time >> deadline, so the flushed requests miss the SLO)
        time.sleep(deadline_ms * 1.2 / 1e3)
        results.update(eng.poll())
    results.update(eng.flush())
    assert len(results) == len(scenes)
    s = eng.stats.summary()
    slo = s["slo"]
    common.emit(
        f"serving/{arch}/saturated/p95",
        (s["p95_ms"] or 0.0) * 1e3,
        f"scenes_per_s={s['scenes_per_s']:.2f};"
        f"slo_deadline_ms={_ms(slo['deadline_ms'])};"
        f"slo_miss_rate={slo['miss_rate'] if slo['miss_rate'] is not None else 'none'};"
        f"slo_misses={slo['misses']};slo_measured={slo['measured']};"
        f"deadline_flushes={s['deadline_flushes']}")
    return s


def _sharded_leg(arch: str, scenes, bound: int, ladder: BucketLadder,
                 n_dev: int, reps: int):
    """Replayed-stream throughput, DeviceRouter over ``n_dev`` devices vs
    the single-device engine at the SAME serving config.

    Both variants are co-resident and their replay epochs interleave
    (engine, router, engine, router, …) with the ratio taken over medians —
    the same drift-cancelling protocol bench_streaming uses; sequential
    whole-variant timing on a shared CPU box swung ±2× run to run.  Each
    epoch submits the full stream and flushes once, so every batch in the
    queue is a routable unit.
    """
    eng = Engine(arch, ladder=ladder, spatial_bound=bound)
    rt = DeviceRouter(arch, devices=n_dev, ladder=ladder, spatial_bound=bound)
    eng.warmup()
    rt.warmup()
    eng.serve(scenes, flush_every=0)    # warm-in replay: scene builds,
    rt.serve(scenes, flush_every=0)     # digest caches, routing state
    eng.stats = EngineStats()           # steady state only below: reported
    for w in rt.workers:                # recompiles/routed_batches cover the
        w.stats = EngineStats()         # measured epochs, not warmup
    rt.stats.busy_s, rt.stats.flushes = 0.0, 0
    rt.stats.route_log.clear()
    e_times, r_times = [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        eng.serve(scenes, flush_every=0)
        e_times.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        rt.serve(scenes, flush_every=0)
        r_times.append(time.perf_counter() - t0)
    n = len(scenes)
    e_sps = n / statistics.median(e_times)
    r_sps = n / statistics.median(r_times)
    s = rt.stats.summary()
    routed = ",".join(str(d["routed_batches"]) for d in s["devices"].values())
    common.emit(
        f"serving/{arch}/sharded_d{n_dev}/epoch",
        statistics.median(r_times) * 1e6,
        f"scenes_per_s={r_sps:.2f};single_scenes_per_s={e_sps:.2f};"
        f"recompiles={sum(s['recompiles'].values())};routed_batches={routed}")
    common.emit(f"serving/{arch}/sharded_vs_single", 0.0,
                f"throughput_ratio={r_sps / e_sps:.2f}x;devices={n_dev}")


def run(tiny: bool = False, devices: int = 0):
    if tiny:
        count, n_range, ladder = 6, (80, 400), BucketLadder((256, 512), max_batch=3)
        flush_every = 3
    else:
        count, n_range = 24, (200, 1200)
        ladder = BucketLadder((512, 1024, 2048), max_batch=4)
        flush_every = 8

    for arch in sorted(ARCHS):
        channels = ARCHS[arch].in_channels_of(ARCHS[arch].default_config)
        scenes, bound = lidar_stream(0, count, channels, n_range=n_range)
        batched = _drive(arch, scenes, bound, ladder, flush_every, "batched")
        single = BucketLadder(ladder.capacities, max_batch=1)
        unbatched = _drive(arch, scenes, bound, single, 1, "unbatched")
        speedup = (batched["scenes_per_s"] /
                   max(unbatched["scenes_per_s"], 1e-9))
        common.emit(f"serving/{arch}/batched_vs_unbatched", 0.0,
                    f"throughput_ratio={speedup:.2f}x")

        _drive(arch, scenes, bound, ladder, flush_every, "repeat", epochs=2)

        _saturating_leg(arch, scenes, bound, ladder,
                        deadline_ms=2.0 if tiny else 5.0)

        n_dev = devices if devices else jax.device_count()
        if n_dev > 1:
            if jax.device_count() < n_dev:
                raise RuntimeError(
                    f"--devices {n_dev} needs XLA_FLAGS="
                    f"--xla_force_host_platform_device_count={n_dev}")
            # the sharded leg replays the stream in the warm-traffic regime
            # the router targets (maps cached, executors hot), one scene
            # per batch: the batch is the routing granularity, so this is
            # the request-parallel deployment a device fleet serves
            _sharded_leg(arch, scenes, bound, single, n_dev,
                         reps=5 if tiny else 3)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="reduced stream for CI smoke runs")
    ap.add_argument("--devices", type=int, default=0,
                    help="run the sharded leg across N devices "
                         "(0 = every visible device; sharded leg is skipped "
                         "when only one is attached)")
    ap.add_argument("--trace", default=None, metavar="OUT",
                    help="trace the benchmark run: Chrome trace-event JSON "
                         "(Perfetto) or .jsonl event log")
    args = ap.parse_args()
    if args.trace:
        obs.enable()
    print("name,us_per_call,derived")
    run(tiny=args.tiny, devices=args.devices)
    if args.trace:
        path = obs.export(obs.get_tracer(), args.trace)
        snap = obs.get_tracer().snapshot()
        print(f"# trace: {snap['spans']} spans + {snap['events']} events "
              f"-> {path}")
