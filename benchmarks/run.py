# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness entrypoint: ``PYTHONPATH=src python -m benchmarks.run``.

One module per paper table/figure (see DESIGN.md §6):
  Fig. 14 inference, Fig. 15/22 training, Tab. 3/4 + Fig. 17 sorted-vs-
  unsorted, Tab. 5 mask splits, Fig. 18 hybrid dataflow, Fig. 16 R-GCN,
  Fig. 8 generator-vs-dense-GEMM.

``--tiny`` runs every suite at CI smoke scale (suites without a tiny knob
run at their only scale) and ``--out BENCH_CI.json`` consolidates the
emitted rows into one machine-readable artifact — per-suite rows +
medians + environment metadata — which CI uploads every run, so the perf
trajectory of the repo accumulates instead of scrolling away in job logs.

CPU-container caveat: wall-clock numbers here validate *ranking logic*
(mapping overhead vs kernel time trade-offs) at reduced scale; the TPU
performance story lives in the dry-run roofline (EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import argparse
import inspect
import json
import platform
import statistics
import subprocess
import sys
import time
import traceback


def _git_sha() -> str:
    try:
        return subprocess.run(["git", "rev-parse", "HEAD"],
                              capture_output=True, text=True,
                              timeout=10).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def _metadata(tiny: bool) -> dict:
    import jax
    return {
        "timestamp_utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "git_sha": _git_sha(),
        "tiny": tiny,
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "python": platform.python_version(),
        "platform": platform.platform(),
    }


def _row_dict(record: tuple) -> dict:
    name, us, derived = record
    return {"name": name, "us_per_call": float(us), "derived": derived}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke scale for every suite that supports it")
    ap.add_argument("--out", default=None, metavar="BENCH_CI.json",
                    help="write the consolidated perf artifact here")
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names to run (default all)")
    ap.add_argument("--hosts", type=int, default=2,
                    help="localhost worker count for the fleet_serving suite")
    args = ap.parse_args(argv)

    from benchmarks import (bench_generator, bench_graph, bench_hybrid,
                            bench_inference, bench_kmap, bench_serving,
                            bench_sorted, bench_splits, bench_streaming,
                            bench_training, common)

    suites = [
        ("kmap_engine", bench_kmap.run),
        ("serving_engine", bench_serving.run),
        ("streaming_serving", bench_streaming.run),
        ("fig14_inference", bench_inference.run),
        ("fig15_training", bench_training.run),
        ("tab34_sorted", bench_sorted.run),
        ("tab5_splits", bench_splits.run),
        ("fig18_hybrid", bench_hybrid.run),
        ("fig16_graph", bench_graph.run),
        ("fig8_generator", bench_generator.run),
    ]
    # Opt-in suites: spawn subprocesses (localhost fleet workers), so they
    # run only when explicitly named in --only, never by default.
    opt_in = [
        ("fleet_serving", bench_serving.run_fleet),
    ]
    if args.only:
        keep = {s.strip() for s in args.only.split(",")}
        unknown = keep - {name for name, _ in suites + opt_in}
        if unknown:
            raise SystemExit(f"unknown suites: {sorted(unknown)}")
        suites = [(n, f) for n, f in suites + opt_in if n in keep]

    print("name,us_per_call,derived")
    failures = []
    report = {"meta": _metadata(args.tiny), "suites": {}}
    for name, fn in suites:
        start = len(common.RECORDS)
        t0 = time.perf_counter()
        try:
            params = inspect.signature(fn).parameters
            kw = {}
            if args.tiny and "tiny" in params:
                kw["tiny"] = True
            if "hosts" in params:
                kw["hosts"] = args.hosts
            fn(**kw)
            ok = True
        except Exception:
            failures.append(name)
            ok = False
            traceback.print_exc()
        rows = [_row_dict(r) for r in common.RECORDS[start:]]
        timed = [r["us_per_call"] for r in rows if r["us_per_call"] > 0]
        report["suites"][name] = {
            "ok": ok,
            "wall_s": round(time.perf_counter() - t0, 3),
            "median_us": statistics.median(timed) if timed else None,
            "rows": rows,
        }
    report["failures"] = failures

    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.out} ({len(report['suites'])} suites)",
              file=sys.stderr)

    if failures:
        print(f"FAILED suites: {failures}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == '__main__':
    main()
