"""Paper Table 5 — enlarging the implicit-GEMM design space by number of
splits: tuner restricted to {1}, {1,2}, {0,1,2,3,4}."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import dataflows as df
from repro.core.autotuner import Autotuner, partition_groups, timeit_fn
from repro.core.sparse_conv import TrainDataflowConfig
from repro.models import minkunet


def run():
    cfg = minkunet.MinkUNetConfig(width=0.25, blocks_per_stage=1)
    stx = common.seg_scene()
    params = minkunet.init_params(cfg, jax.random.PRNGKey(0))
    maps = minkunet.build_maps(stx)
    sigs = minkunet.layer_signatures(cfg)
    groups = partition_groups(sigs)
    sig_of = {g.name: sigs[g.layer_names[0]] for g in groups}

    def measure(assign):
        amap = {sig_of[k]: TrainDataflowConfig.bind_all(v) for k, v in assign.items()}
        fn = jax.jit(lambda p: minkunet.apply(p, stx, cfg, maps, assignment=amap))
        return timeit_fn(lambda: jax.block_until_ready(fn(params)), warmup=1, iters=2)

    spaces = {
        "splits={1}": [1],
        "splits={1,2}": [1, 2],
        "splits={0..4}": [0, 1, 2, 3, 4],
    }
    base = None
    for name, splits in spaces.items():
        space = [df.DataflowConfig("implicit_gemm", n_splits=s) for s in splits]
        best = Autotuner(groups, space, measure).tune()
        amap = {sig_of[k]: TrainDataflowConfig.bind_all(v) for k, v in best.items()}
        fn = jax.jit(lambda p: minkunet.apply(p, stx, cfg, maps, assignment=amap))
        us = common.time_fn(lambda: fn(params))
        base = base or us
        common.emit(f"tab5/SK-M/{name}", us, f"speedup_vs_split1={base / us:.2f}x")


if __name__ == "__main__":
    run()
