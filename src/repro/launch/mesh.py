"""Production mesh definitions and serving-device helpers.

Functions, not module-level constants — importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before any jax init).

Capability note: ``jax.sharding.AxisType`` (and ``jax.make_mesh``'s
``axis_types=`` kwarg) only exist in newer jax releases; on older runtimes
(e.g. the 0.4.37 CI environment) meshes are built without explicit axis
types, which is the same ``Auto`` default those releases used implicitly.
"""
from __future__ import annotations

import os
from typing import Optional, Sequence

import jax

from repro.models.lm_common import ShardCtx


def _axis_type_kwargs(n_axes: int) -> dict:
    """``axis_types=(Auto,)*n`` where the running jax supports it, else {}."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh (tests, elastic restore)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_axis_type_kwargs(len(axes)))


def make_ctx(mesh, fsdp: bool = False) -> ShardCtx:
    axes = mesh.axis_names
    batch = tuple(a for a in axes if a in ("pod", "data"))
    return ShardCtx(mesh=mesh, batch=batch, model="model",
                    model_size=mesh.shape["model"], fsdp=fsdp)


# --------------------------------------------------------------- serving tier

def host_device_flag(n: int) -> str:
    """The XLA flag that splits the host platform into ``n`` virtual devices
    (how the multi-device serving tier runs in CPU CI):
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``."""
    assert n >= 1
    return f"--xla_force_host_platform_device_count={n}"


def serving_devices(n: Optional[int] = None) -> Sequence[jax.Device]:
    """The first ``n`` jax devices for the sharded serving tier.

    ``n=None`` takes every visible device.  Raises with an actionable hint
    (the ``XLA_FLAGS`` virtual-device split) when fewer than ``n`` devices
    are attached — serving must never silently run N workers on one device
    and report it as sharded throughput.
    """
    devs = jax.devices()
    if n is None:
        return list(devs)
    if n < 1:
        raise ValueError(f"need at least one serving device, got n={n}")
    if len(devs) < n:
        raise RuntimeError(
            f"{n} serving devices requested but only {len(devs)} attached; "
            f"for host-platform virtual devices set "
            f"XLA_FLAGS={host_device_flag(n)!r} before the first jax import "
            f"(current XLA_FLAGS={os.environ.get('XLA_FLAGS', '')!r})")
    return list(devs[:n])


def make_serving_mesh(n: Optional[int] = None):
    """1-D ``("serve",)`` mesh over the serving devices — the device roster
    the ``serve.DeviceRouter`` shards its bucket-ladder workers across."""
    devs = serving_devices(n)
    return jax.make_mesh((len(devs),), ("serve",), devices=devs,
                         **_axis_type_kwargs(1))


# TPU v5e hardware constants for the roofline (per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link
