"""Paper Fig. 15 + Fig. 22 — training-step latency: bound vs decoupled
fwd/dgrad/wgrad dataflows, and the two binding schemes."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import dataflows as df
from repro.core.autotuner import TrainingAutotuner, partition_groups, timeit_fn
from repro.core.sparse_conv import TrainDataflowConfig
from repro.models import minkunet


def run():
    cfg = minkunet.MinkUNetConfig(width=0.25, blocks_per_stage=1, num_classes=8)
    stx = common.seg_scene(n=1500)
    params = minkunet.init_params(cfg, jax.random.PRNGKey(0))
    maps = minkunet.build_maps(stx)
    sigs = minkunet.layer_signatures(cfg)
    labels = jax.random.randint(jax.random.PRNGKey(1), (stx.capacity,), 0, 8)

    def train_step(amap):
        def loss(p):
            lg = minkunet.apply(p, stx, cfg, maps, assignment=amap)
            ls = jax.nn.log_softmax(lg)[jnp.arange(stx.capacity), labels]
            return -jnp.sum(jnp.where(stx.valid_mask, ls, 0))

        return jax.jit(lambda p: jax.grad(loss)(p))

    lats = {}
    for name, c in common.SYSTEMS.items():
        amap = {s: TrainDataflowConfig.bind_all(c) for s in set(sigs.values())}
        fn = train_step(amap)
        lats[f"bound/{name}"] = common.time_fn(lambda: fn(params), iters=2)

    # decoupled: tuned with each binding scheme (paper Fig. 13 / Fig. 22).
    # Two-candidate space keeps the CPU-container tuning time sane; the
    # ranking logic is identical at larger |space|.
    groups = partition_groups(sigs)
    sig_of = {g.name: sigs[g.layer_names[0]] for g in groups}
    space = [df.DataflowConfig("gather_scatter"),
             df.DataflowConfig("implicit_gemm", n_splits=1)]

    def measure(assign):
        amap = {sig_of[k]: v for k, v in assign.items()}
        fn = train_step(amap)
        return timeit_fn(lambda: jax.block_until_ready(fn(params)), warmup=1, iters=2)

    for scheme in ("bind_all", "bind_fwd_dgrad", "bind_dgrad_wgrad"):
        best = TrainingAutotuner(groups, space, measure, scheme).tune()
        amap = {sig_of[k]: v for k, v in best.items()}
        fn = train_step(amap)
        lats[f"tuned/{scheme}"] = common.time_fn(lambda: fn(params), iters=2)

    worst = max(lats.values())
    for name, us in lats.items():
        common.emit(f"fig15/SK-M-train/{name}", us, f"speedup_vs_worst={worst / us:.2f}x")


if __name__ == "__main__":
    run()
