import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest

jax.config.update("jax_platforms", "cpu")

try:
    import hypothesis
    import hypothesis.strategies as _hst
except ModuleNotFoundError:  # pragma: no cover - exercised in minimal envs
    hypothesis = None
    _hst = None


def property_test(argnames, cases, strategies, max_examples=15):
    """Property-test decorator that degrades gracefully without hypothesis.

    With ``hypothesis`` installed (requirements-dev.txt) the test runs under
    ``@given(**strategies(st))``; without it, it runs as a plain parametrize
    over the deterministic ``cases`` so the suite still collects and covers
    the path.

    argnames:   "a,b,c" — pytest parametrize signature (fallback mode).
    cases:      deterministic fallback tuples matching ``argnames``.
    strategies: callable ``st_module -> dict`` of hypothesis strategies
                (lazy so the module is only touched when present).
    """
    def deco(f):
        if hypothesis is None:
            return pytest.mark.parametrize(argnames, cases)(f)
        return hypothesis.settings(max_examples=max_examples, deadline=None)(
            hypothesis.given(**strategies(_hst))(f))
    return deco
