"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On this CPU container it trains *reduced* configs end-to-end (the full
configs are dry-run-only); on a real fleet the same entrypoint runs the full
mesh with the XLA latency-hiding-scheduler flags below.
"""
from __future__ import annotations

import os

# Compute/communication overlap: enable XLA's latency-hiding scheduler and
# async collectives when we are on a real accelerator fleet.
if os.environ.get("REPRO_REAL_FLEET"):
    os.environ.setdefault("LIBTPU_INIT_ARGS", " ".join([
        "--xla_enable_async_all_gather=true",
        "--xla_enable_async_collective_permute=true",
        "--xla_tpu_enable_async_collective_fusion=true",
        "--xla_latency_hiding_scheduler_rerun=2",
    ]))

import argparse
import contextlib
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import obs
from repro.configs import base as cfgbase
from repro.data.synthetic import token_batches
from repro.launch import mesh as meshlib
from repro.launch.steps import batch_pspecs, make_train_step
from repro.models import api
from repro.models.lm_common import NO_SHARD
from repro.train import optimizer as opt
from repro.train.loop import LoopConfig, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="train the reduced config (CPU container default)")
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--mesh", default="none", choices=["none", "single", "multi"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--trace", default=None, metavar="OUT",
                    help="trace the training loop: Chrome trace-event JSON "
                         "(or .jsonl event log) with per-step/checkpoint "
                         "spans, plus an XLA profile in OUT.xprof/ when the "
                         "jax profiler is available")
    args = ap.parse_args()

    cfg = cfgbase.get_arch(args.arch)
    if args.reduced:
        cfg = cfgbase.reduced(cfg)

    if args.mesh == "none":
        mesh, ctx = None, NO_SHARD
    else:
        mesh = meshlib.make_production_mesh(multi_pod=args.mesh == "multi")
        ctx = meshlib.make_ctx(mesh)

    params = api.init_params(cfg, jax.random.PRNGKey(0))
    ocfg = opt.AdamWConfig(lr=args.lr)
    state = opt.init_opt_state(params, ocfg)
    if mesh is not None:
        pspecs = api.param_pspecs(cfg, params, ctx)
        shd = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                           is_leaf=lambda x: isinstance(x, P))
        params = jax.device_put(params, shd)

    def raw_step(params, state, batch):
        loss, grads = jax.value_and_grad(partial(api.loss_fn, cfg, ctx=ctx))(params, batch)
        p2, s2, gnorm = opt.adamw_update(params, grads, state, ocfg)
        return p2, s2, {"loss": loss, "grad_norm": gnorm}

    step = jax.jit(raw_step, donate_argnums=(0, 1))
    data = token_batches(0, args.batch, args.seq, cfg.vocab)

    def wrap(it):
        for b in it:
            if not cfg.embed_input:
                emb = jax.nn.one_hot(b["tokens"] % cfg.d_model, cfg.d_model, dtype=cfg.jdtype)
                b = {"embeds": emb, "labels": b["labels"]}
            if cfg.cross_every:
                b["img_emb"] = jnp.zeros((args.batch, cfg.n_img_tokens, cfg.d_model), cfg.jdtype)
            yield b

    lcfg = LoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                      ckpt_dir=args.ckpt_dir, log_every=10)
    if args.trace:
        obs.enable()
    profiler = (obs.jax_profile(args.trace + ".xprof")
                if args.trace else contextlib.nullcontext(False))
    with profiler as profiling:
        params, state, report = train_loop(step, params, state, wrap(data),
                                           lcfg)
    print(f"done: {report.steps_run} steps, final metrics {report.last_metrics}, "
          f"stragglers={report.straggler_steps}, "
          f"mean_step={sum(report.step_times) / max(len(report.step_times), 1):.3f}s")
    if args.trace:
        path = obs.export(obs.get_tracer(), args.trace)
        snap = obs.get_tracer().snapshot()
        print(f"trace: {snap['spans']} spans -> {path}"
              + (f" (+ XLA profile in {args.trace}.xprof/)"
                 if profiling else ""))


if __name__ == "__main__":
    main()
