"""Pure-jnp oracle for the implicit-GEMM sparse convolution kernel.

out[n] = Σ_k  x[m[n, k]] @ w[k]      (m[n, k] == -1 contributes zero)

This is the dense-GEMM-with-sparse-iterator formulation of paper §3.1
(X^{im2col-in} never materialized here either: the gather is fused by XLA).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def implicit_gemm_ref(x: jax.Array, w: jax.Array, m: jax.Array,
                      acc_dtype=jnp.float32) -> jax.Array:
    """x: (N_in, Cin); w: (KD, Cin, Cout); m: (N_out, KD) int32 → (N_out, Cout)."""
    n_out, kd = m.shape
    cout = w.shape[-1]

    def body(acc, k):
        idx = m[:, k]
        rows = jnp.where((idx >= 0)[:, None], x[jnp.clip(idx, 0)], 0)
        return acc + jnp.dot(rows.astype(acc_dtype), w[k].astype(acc_dtype)), None

    acc0 = jnp.zeros((n_out, cout), acc_dtype)
    acc, _ = jax.lax.scan(body, acc0, jnp.arange(kd))
    return acc.astype(x.dtype)
