"""Sparse serving engine: bucketed dynamic batching, scene-granular and
streaming map reuse, and persisted tuned plans (see engine.py for the
architecture)."""
from repro.serve.batcher import (PackedBatch, Scene, SceneBatcher, SceneDelta,
                                 SceneResult, apply_delta, scene_from_tensor)
from repro.serve.bucketing import BucketLadder
from repro.serve.engine import ARCHS, Engine, EngineStats
from repro.serve.plans import PlanRegistry

__all__ = ["ARCHS", "BucketLadder", "Engine", "EngineStats", "PackedBatch",
           "PlanRegistry", "Scene", "SceneBatcher", "SceneDelta",
           "SceneResult", "apply_delta", "scene_from_tensor"]
