"""Sparse Kernel Generator (paper §3).

The paper's generator takes a *dense* tensor-compiler GEMM template and makes
it sparse by injecting one level of indirect addressing at the operand-A load.
In this JAX port the "constant gray code" is the Pallas kernel body, the
"blue compiler-generated MMA subroutine" is `jnp.dot` lowered by Mosaic onto
the MXU, and the "red template" is the SMEM-index + async-DMA preamble — a
few dozen lines in `kernels/implicit_gemm` / `kernels/fetch_on_demand`
instead of SpConv v2's 40k-LoC metaprogrammer.

What remains tunable is exactly what the paper argues is sufficient: the
**tile sizes** (paper Fig. 8 shows tile-size-only tuning reaches ≥ cuBLAS
utilization).  This module is the factory that materializes a callable from a
``DataflowConfig`` and implements **adaptive tiling** (paper §6.2): pick the
tile pair by workload MACs.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp

from repro.core import dataflows as df
from repro.core.kmap import KernelMap

# Two tile regimes, as in the paper's adaptive tiling (up to 1.6× from
# switching between a small and a large tile set).
SMALL_TILES = (64, 128)    # (tile_m, tile_n) — underutilized workloads
LARGE_TILES = (256, 128)   # large-MAC workloads
# MXU alignment: tile_n multiple of 128, tile_m multiple of 8.
TILE_M_CHOICES = (32, 64, 128, 256)
TILE_N_CHOICES = (128, 256)


def estimate_macs(kmap: KernelMap, cin: int, cout: int) -> float:
    """Effective MACs of a sparse conv layer (Σ_δ |M_δ| · Cin · Cout)."""
    return float(jnp.sum(kmap.ws_count)) * cin * cout


def adaptive_tiles(kmap: KernelMap, cin: int, cout: int,
                   threshold_macs: float = 5e8) -> tuple[int, int]:
    """Paper §6.2: MAC-dependent tile selection."""
    return LARGE_TILES if estimate_macs(kmap, cin, cout) >= threshold_macs else SMALL_TILES


def generate(cfg: df.DataflowConfig) -> Callable:
    """Materialize a sparse-conv callable ``f(x, w, kmap, plan=None)`` for a
    dataflow configuration.  The generator's entire "design space" beyond the
    dataflow choice is (tile_m, tile_n, n_splits) — nothing else needs to be
    re-emitted, which is the paper's core engineering claim."""
    def f(x, w, kmap, plan=None):
        return df.sparse_conv_forward(x, w, kmap, cfg, plan=plan)

    return f


def design_space(include_pallas: bool = False,
                 splits=(0, 1, 2, 3, 4)) -> list[df.DataflowConfig]:
    """Enumerate the TorchSparse++ design space (paper Fig. 9): a superset of
    SpConv v2 (which has only sorted implicit GEMM with 1-2 splits)."""
    backend = "pallas" if include_pallas else "xla"
    space = [df.DataflowConfig("gather_scatter", backend="xla"),
             df.DataflowConfig("fetch_on_demand", backend=backend)]
    for s in splits:
        if include_pallas:
            for tm, tn in (SMALL_TILES, LARGE_TILES):
                space.append(df.DataflowConfig("implicit_gemm", n_splits=s,
                                               tile_m=tm, tile_n=tn, backend=backend))
        else:
            space.append(df.DataflowConfig("implicit_gemm", n_splits=s, backend=backend))
    return space


def spconv_v2_space() -> list[df.DataflowConfig]:
    """The restricted baseline space (sorted implicit GEMM, split ∈ {1, 2})."""
    return [df.DataflowConfig("implicit_gemm", n_splits=1),
            df.DataflowConfig("implicit_gemm", n_splits=2)]
