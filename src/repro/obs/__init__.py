"""Phase-level tracing & metrics (spans, counters, exporters, profiler
hooks) — see trace.py for the core, export.py for artifact formats,
profile.py for the optional XLA-level bracket."""
from repro.obs.export import chrome_trace, export, export_chrome, export_jsonl
from repro.obs.profile import has_jax_profiler, jax_profile
from repro.obs.trace import (NOOP_SPAN, EventRecord, SpanRecord, Tracer,
                             count, disable, enable, event, gauge, get_tracer,
                             record_span, set_tracer, span)

__all__ = ["NOOP_SPAN", "EventRecord", "SpanRecord", "Tracer", "chrome_trace",
           "count", "disable", "enable", "event", "export", "export_chrome",
           "export_jsonl", "gauge", "get_tracer", "has_jax_profiler",
           "jax_profile", "record_span", "set_tracer", "span"]
