"""Jit'd wrapper: splits + sorting + padding around the implicit-GEMM kernel.

The Sparse Kernel Generator (core/generator.py) picks ``tile_m/tile_n`` and
the Sparse Autotuner picks ``n_splits``/``sorted``/``worklist``; this
wrapper is the glue that turns a (KernelMap, SplitPlan) pair into
pallas_call invocations plus the split-sum reduction of paper Fig. 10.

Two launch geometries:

* dense grid — ``(m_tiles, n_tiles, KD_split)``, empty (tile, δ) pairs
  gated off per step by the occupancy scalar (``@pl.when``);
* worklist (``worklist=True``) — the occupied (m_tile, δ) pairs are
  compacted host-side from the ``SplitPlan`` occupancy (fused into
  ``make_split_plan(tile_m=...)``) and the grid runs over *only* those —
  Spira-style structure-exploiting tile skipping.  Needs concrete
  occupancy to size the grid, so under ``jit`` tracing it falls back to
  the dense grid (bit-identical math; the tuner stamps what ran).

Requested tiles are clamped to divisors of the actual shapes
(``gcd(tile, dim)``) so any tuner-proposed config runs on any layer —
small-channel layers get narrower tiles instead of an assertion.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kmap import KernelMap, SplitPlan
from repro.kernels.common import default_interpret
from repro.kernels.implicit_gemm.implicit_gemm import (
    WL_FIRST, WL_LAST, WL_VALID, implicit_gemm_pallas,
    implicit_gemm_worklist_pallas)


def _build_worklist(occ: np.ndarray):
    """Compact a concrete (n_tiles, KD_split) occupancy into the sorted
    worklist arrays.  Returns ``None`` for an empty split, else
    ``(wl_tile, wl_delta, wl_flags, tile_visited)`` with the entry count
    padded to a multiple of 8 (pads repeat the last real entry, flags 0 —
    no compute, no write) to bound shape-specialized recompiles."""
    ts, ds = np.nonzero(occ)          # row-major ⇒ sorted by (tile, δ)
    wn = ts.size
    if wn == 0:
        return None
    wcap = -(-wn // 8) * 8
    wl_tile = np.concatenate([ts, np.full(wcap - wn, ts[-1])]).astype(np.int32)
    wl_delta = np.concatenate([ds, np.full(wcap - wn, ds[-1])]).astype(np.int32)
    new_tile = np.empty(wn, bool)
    new_tile[0] = True
    np.not_equal(ts[1:], ts[:-1], out=new_tile[1:])
    flags = np.zeros(wcap, np.int32)
    flags[:wn] |= WL_VALID
    flags[:wn] |= np.where(new_tile, WL_FIRST, 0)
    flags[: wn - 1] |= np.where(new_tile[1:], WL_LAST, 0)
    flags[wn - 1] |= WL_LAST
    return wl_tile, wl_delta, flags, occ.any(axis=1)


def implicit_gemm(x: jax.Array, w: jax.Array, kmap: KernelMap, plan: SplitPlan,
                  *, tile_m: int = 128, tile_n: int = 128,
                  worklist: bool = False,
                  interpret: bool | None = None) -> jax.Array:
    """Full sparse conv via (split, sorted) implicit GEMM. Returns (N_out_cap, Cout)."""
    if interpret is None:
        interpret = default_interpret()
    cap = kmap.capacity
    cout = w.shape[-1]
    tile_m = math.gcd(tile_m, cap)
    tile_n = math.gcd(tile_n, cout)
    n_tiles = cap // tile_m
    out = jnp.zeros((cap, cout), x.dtype)
    for s, (a, b) in enumerate(plan.ranges):
        order = plan.order[s]
        midx = kmap.m_out[order][:, a:b]
        occ3 = (midx.reshape(n_tiles, tile_m, b - a) >= 0).any(axis=1)
        use_wl = worklist and not isinstance(occ3, jax.core.Tracer)
        if use_wl:
            if plan.occupancy is not None and plan.tile_m == tile_m \
                    and not isinstance(plan.occupancy, jax.core.Tracer):
                occ_np = np.asarray(plan.occupancy[s][:, a:b]) != 0
            else:
                occ_np = np.asarray(occ3)
            wl = _build_worklist(occ_np)
            if wl is None:
                continue                      # empty split contributes zero
            wl_tile, wl_delta, wl_flags, visited = wl
            partial = implicit_gemm_worklist_pallas(
                jnp.asarray(wl_tile), jnp.asarray(wl_delta),
                jnp.asarray(wl_flags),
                midx.reshape(n_tiles, tile_m, b - a)[wl_tile, :, wl_delta],
                x, w[a:b], n_tiles_m=n_tiles, tile_m=tile_m, tile_n=tile_n,
                interpret=interpret)
            # tiles with no entries were never scheduled: their output
            # blocks are uninitialized — zero them (they have no neighbors
            # in this split, so zero IS their partial sum)
            row_ok = jnp.asarray(np.repeat(visited, tile_m))
            partial = jnp.where(row_ok[:, None], partial, 0)
        else:
            partial = implicit_gemm_pallas(midx, occ3.astype(jnp.int32), x,
                                           w[a:b], tile_m=tile_m,
                                           tile_n=tile_n, interpret=interpret)
        out = out + partial[plan.inv_order[s]]
    return out
