"""The Sparse Autotuner end to end on the execution-plan IR: declare →
compile → tune, on MinkUNet (inference) and the training tuner with both
binding schemes (paper Fig. 13).  The tuners consume and produce
``core.plan.NetworkPlan``s — the same artifact the serving engine persists.

    PYTHONPATH=src python examples/autotune.py
"""
import jax
import jax.numpy as jnp

from repro.core import dataflows as df
from repro.core.autotuner import timeit_fn
from repro.core.plan import PlanTuner, TrainingPlanTuner
from repro.data.synthetic import lidar_scene
from repro.models import minkunet


def main():
    cfg = minkunet.MinkUNetConfig(width=0.25, blocks_per_stage=1)
    st = lidar_scene(jax.random.PRNGKey(0), 1500, 2048, 4, extent=40.0, voxel=0.5)
    params = minkunet.init_params(cfg, jax.random.PRNGKey(1))
    nplan = minkunet.network_plan(cfg)
    maps = nplan.build_maps(st)
    groups = nplan.groups()
    print(f"{len(nplan.layers)} conv layers → {len(groups)} map-sharing groups")

    space = [df.DataflowConfig("gather_scatter"),
             df.DataflowConfig("fetch_on_demand"),
             df.DataflowConfig("implicit_gemm", n_splits=0),
             df.DataflowConfig("implicit_gemm", n_splits=1),
             df.DataflowConfig("implicit_gemm", n_splits=2)]

    def measure(candidate):
        fn = jax.jit(lambda p: candidate.apply(p, st, maps))
        return timeit_fn(lambda: jax.block_until_ready(fn(params)), warmup=1, iters=2)

    tuned = PlanTuner(nplan, space, measure).tune()
    print("\nper-group inference assignment:")
    for sig, c3 in sorted(tuned.assignment().items(), key=str):
        c = c3.fwd
        n_layers = sum(1 for lp in tuned.layers if lp.sig == sig)
        print(f"  {sig}: {c.dataflow} splits={c.n_splits} ({n_layers} layers)")
    base, best = measure(nplan), measure(tuned)
    print(f"default {base * 1e3:.1f} ms → tuned {best * 1e3:.1f} ms "
          f"({base / best:.2f}x)")

    # training tuner: both binding schemes (paper Fig. 13)
    labels = jnp.zeros((st.capacity,), jnp.int32)

    def measure_train(candidate):
        def loss(p):
            lg = candidate.apply(p, st, maps)
            return -jnp.sum(jax.nn.log_softmax(lg)[jnp.arange(st.capacity), labels])

        fn = jax.jit(lambda p: jax.grad(loss)(p))
        return timeit_fn(lambda: jax.block_until_ready(fn(params)), warmup=1, iters=2)

    small = space[:3]
    for scheme in ("bind_fwd_dgrad", "bind_dgrad_wgrad"):
        out = TrainingPlanTuner(nplan, small, measure_train, scheme).tune()
        lat = measure_train(out)
        print(f"training scheme {scheme}: {lat * 1e3:.1f} ms/step")


if __name__ == "__main__":
    main()
