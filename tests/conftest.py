import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest

jax.config.update("jax_platforms", "cpu")

try:
    import hypothesis
    import hypothesis.strategies as _hst
except ModuleNotFoundError:  # pragma: no cover - exercised in minimal envs
    hypothesis = None
    _hst = None


# --------------------------------------------------------- capability probes
#
# The repo targets current jax APIs; CI pins jax 0.4.37 (see ci.yml), where
# some of them don't exist yet.  Each probe names ONE api gap; tests that
# need it are skip-marked with the probe's reason so the suite is green on
# the pinned runtime and a *new* failure is never hidden inside known-red.

def _probe_pallas_supported() -> bool:
    """repro.kernels.common.pallas_supported() — true when either spelling
    of the TPU compiler-params class (``pltpu.CompilerParams`` on current
    jax, ``pltpu.TPUCompilerParams`` on 0.4.x) exists; the kernels route
    through ``common.tpu_compiler_params`` which papers over the rename, so
    on jax 0.4.37 the kernel suites now really run (interpret mode)."""
    try:
        from repro.kernels.common import pallas_supported
    except Exception:  # pragma: no cover - pallas missing entirely
        return False
    return pallas_supported()


HAS_PALLAS = _probe_pallas_supported()
# The other 0.4.37 gaps this PR met — jax.sharding.AxisType and
# jax.lax.axis_size — need no skip probes: launch/mesh.py and
# train/compression.py carry runtime fallbacks, so those tests really pass.

#: test files whose every case drives a Pallas kernel through
#: common.tpu_compiler_params (run in interpret mode off-TPU)
_PALLAS_KERNEL_FILES = frozenset(
    ["test_kernels.py", "test_ssd_kernel.py", "test_wgrad_kernel.py",
     "test_radix_kernel.py"])

_PALLAS_SKIP = pytest.mark.skip(
    reason="this jax has neither pltpu.CompilerParams nor the old "
           "TPUCompilerParams spelling — pallas tier unlaunchable")


def pytest_collection_modifyitems(config, items):
    if HAS_PALLAS:
        return
    for item in items:
        if os.path.basename(str(item.fspath)) in _PALLAS_KERNEL_FILES:
            item.add_marker(_PALLAS_SKIP)


def property_test(argnames, cases, strategies, max_examples=15):
    """Property-test decorator that degrades gracefully without hypothesis.

    With ``hypothesis`` installed (requirements-dev.txt) the test runs under
    ``@given(**strategies(st))``; without it, it runs as a plain parametrize
    over the deterministic ``cases`` so the suite still collects and covers
    the path.

    argnames:   "a,b,c" — pytest parametrize signature (fallback mode).
    cases:      deterministic fallback tuples matching ``argnames``.
    strategies: callable ``st_module -> dict`` of hypothesis strategies
                (lazy so the module is only touched when present).
    """
    def deco(f):
        if hypothesis is None:
            return pytest.mark.parametrize(argnames, cases)(f)
        return hypothesis.settings(max_examples=max_examples, deadline=None)(
            hypothesis.given(**strategies(_hst))(f))
    return deco
