"""SparseConv module: dense-conv oracle, custom_vjp gradients under every
dataflow binding, and the paper's models (MinkUNet / CenterPoint)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dataflows as df
from repro.core import kmap as km
from repro.core.sparse_conv import (ConvSpec, TrainDataflowConfig, apply_conv,
                                    conv_kmap, init_conv, sparse_conv_apply)
from repro.core.sparse_tensor import to_dense, voxelize
from repro.models import centerpoint, minkunet
from tests.test_kmap import random_tensor


def test_dense_conv_oracle():
    """Sparse conv == dense conv_general_dilated at the sparse sites."""
    stx = random_tensor(0, n=120, cap=128, channels=4, extent=8)
    kmap = km.build_kmap(stx, 3, 1)
    w = jax.random.normal(jax.random.PRNGKey(2), (27, 4, 8)) * 0.2
    y = df.sparse_conv_forward(stx.feats, w, kmap, df.DataflowConfig("gather_scatter"))

    dense = to_dense(stx, (8, 8, 8), 1)                       # (1, 8,8,8, C)
    offs = np.asarray(km.kernel_offsets(3, 3))
    wd = jnp.zeros((3, 3, 3, 4, 8))
    for i, o in enumerate(offs):
        wd = wd.at[o[0] + 1, o[1] + 1, o[2] + 1].set(w[i])
    out = jax.lax.conv_general_dilated(
        dense.transpose(0, 4, 1, 2, 3), wd.transpose(4, 3, 0, 1, 2),
        (1, 1, 1), "SAME").transpose(0, 2, 3, 4, 1)
    n = int(kmap.n_out)
    oc = np.asarray(kmap.out_coords[:n])
    ref = out[oc[:, 0], oc[:, 1], oc[:, 2], oc[:, 3]]
    np.testing.assert_allclose(np.asarray(y)[:n], ref, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("dflow", ["gather_scatter", "fetch_on_demand", "implicit_gemm"])
def test_custom_vjp_matches_autodiff(dflow):
    stx = random_tensor(1, n=80, cap=96, channels=4, extent=7)
    kmap = km.build_kmap(stx, 3, 1)
    w = jax.random.normal(jax.random.PRNGKey(3), (27, 4, 8)) * 0.2
    cfg3 = TrainDataflowConfig.bind_all(df.DataflowConfig(dflow))

    def f(feats, w):
        return jnp.sum(sparse_conv_apply(feats, w, kmap, cfg3) ** 2)

    gx, gw = jax.grad(f, argnums=(0, 1))(stx.feats, w)

    def f_ref(feats, w):  # pure autodiff through the gather-scatter path
        return jnp.sum(df.sparse_conv_forward(feats, w, kmap,
                                              df.DataflowConfig("gather_scatter")) ** 2)

    gx_r, gw_r = jax.grad(f_ref, argnums=(0, 1))(stx.feats, w)
    np.testing.assert_allclose(gx, gx_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(gw, gw_r, rtol=1e-4, atol=1e-5)


def test_decoupled_bindings_same_math():
    """Mixed fwd/dgrad/wgrad dataflows change nothing numerically."""
    stx = random_tensor(2, n=70, cap=96, channels=4, extent=7)
    kmap = km.build_kmap(stx, 3, 1)
    w = jax.random.normal(jax.random.PRNGKey(4), (27, 4, 8)) * 0.2
    mixed = TrainDataflowConfig(fwd=df.DataflowConfig("implicit_gemm", n_splits=2),
                                dgrad=df.DataflowConfig("gather_scatter"),
                                wgrad=df.DataflowConfig("fetch_on_demand"))
    bound = TrainDataflowConfig.bind_all(df.DataflowConfig("gather_scatter"))

    def loss(cfg3):
        def f(feats, w):
            return jnp.sum(sparse_conv_apply(feats, w, kmap, cfg3) ** 2)

        return jax.grad(f, argnums=(0, 1))(stx.feats, w)

    g1, g2 = loss(mixed), loss(bound)
    np.testing.assert_allclose(g1[0], g2[0], rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(g1[1], g2[1], rtol=1e-4, atol=1e-5)


def test_strided_and_transposed_roundtrip_shapes():
    stx = random_tensor(3, n=100, cap=128, channels=8, extent=12)
    spec_d = ConvSpec(8, 16, 2, stride=2)
    kd = conv_kmap(stx, spec_d)
    p = init_conv(jax.random.PRNGKey(0), spec_d)
    down = apply_conv(p, stx, kd)
    assert down.stride == 2
    spec_u = ConvSpec(16, 8, 2, stride=2, transposed=True)
    ku = conv_kmap(down, spec_u, cached_fine=stx, cached_fwd=kd)
    pu = init_conv(jax.random.PRNGKey(1), spec_u)
    up = apply_conv(pu, down, ku)
    assert up.stride == 1
    assert up.feats.shape == (stx.capacity, 8)
    assert int(up.num_valid) == int(stx.num_valid)
    assert bool(jnp.isfinite(up.feats).all())


def test_minkunet_forward_and_grad():
    cfg = minkunet.MinkUNetConfig(in_channels=4, num_classes=5, width=0.25,
                                  blocks_per_stage=1)
    stx = random_tensor(4, n=200, cap=256, channels=4, extent=16)
    params = minkunet.init_params(cfg, jax.random.PRNGKey(0))
    logits = minkunet.apply(params, stx, cfg)
    assert logits.shape == (256, 5)
    assert bool(jnp.isfinite(logits).all())

    labels = jnp.zeros((256,), jnp.int32)

    def loss(p):
        lg = minkunet.apply(p, stx, cfg)
        mask = stx.valid_mask
        ls = jax.nn.log_softmax(lg)[jnp.arange(256), labels]
        return -jnp.sum(jnp.where(mask, ls, 0)) / jnp.maximum(stx.num_valid, 1)

    g = jax.grad(loss)(params)
    gn = sum(float(jnp.sum(jnp.abs(v))) for v in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0


def test_minkunet_dataflow_assignment_invariance():
    cfg = minkunet.MinkUNetConfig(width=0.25, blocks_per_stage=1)
    stx = random_tensor(5, n=150, cap=256, channels=4, extent=16)
    params = minkunet.init_params(cfg, jax.random.PRNGKey(0))
    maps = minkunet.build_maps(stx)
    base = minkunet.apply(params, stx, cfg, maps)
    alt = {sig: TrainDataflowConfig.bind_all(df.DataflowConfig("fetch_on_demand"))
           for sig in set(minkunet.layer_signatures(cfg).values())}
    other = minkunet.apply(params, stx, cfg, maps, assignment=alt)
    np.testing.assert_allclose(base, other, rtol=1e-3, atol=1e-4)


def test_centerpoint_forward():
    cfg = centerpoint.CenterPointConfig(width=0.5)
    stx = random_tensor(6, n=200, cap=256, channels=5, extent=20)
    params = centerpoint.init_params(cfg, jax.random.PRNGKey(0))
    out = centerpoint.apply(params, stx, cfg)
    assert out.shape[0] == 256
    assert bool(jnp.isfinite(out).all())
