"""Pure-jnp oracle for the implicit-GEMM sparse convolution kernel.

out[n] = Σ_k  x[m[n, k]] @ w[k]      (m[n, k] == -1 contributes zero)

This is the dense-GEMM-with-sparse-iterator formulation of paper §3.1
(X^{im2col-in} never materialized here either: the gather is fused by XLA).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def implicit_gemm_ref(x: jax.Array, w: jax.Array, m: jax.Array,
                      acc_dtype=jnp.float32, compute_dtype=None,
                      out_dtype=None) -> jax.Array:
    """x: (N_in, Cin); w: (KD, Cin, Cout); m: (N_out, KD) int32 → (N_out, Cout).

    ``compute_dtype`` (default: ``acc_dtype``) is the GEMM operand dtype —
    bf16 under the mixed-precision policy — while partial sums always
    accumulate in ``acc_dtype``.  ``out_dtype`` defaults to ``x.dtype``."""
    from repro.core.precision import gemm_operand

    n_out, kd = m.shape
    cout = w.shape[-1]
    ct = acc_dtype if compute_dtype is None else compute_dtype
    # round/cast the loop-invariant operands once, not per δ iteration
    xq, wq = gemm_operand(x, ct, acc_dtype), gemm_operand(w, ct, acc_dtype)

    def body(acc, k):
        idx = m[:, k]
        rows = jnp.where((idx >= 0)[:, None], xq[jnp.clip(idx, 0)], 0)
        return acc + jnp.dot(rows, wq[k],
                             preferred_element_type=acc_dtype), None

    acc0 = jnp.zeros((n_out, cout), acc_dtype)
    acc, _ = jax.lax.scan(body, acc0, jnp.arange(kd))
    return acc.astype(x.dtype if out_dtype is None else out_dtype)
