"""The paper's detection workload: CenterPoint sparse backbone on Waymo-like
synthetic scenes (WM-C in Fig. 14/15; SparseConv layers only)."""
from repro.models.centerpoint import CenterPointConfig

CONFIG = CenterPointConfig(in_channels=5, channels=(16, 32, 64, 128))
CONFIG_BENCH = CenterPointConfig(in_channels=5, channels=(16, 32, 64, 128), width=0.5)
