"""Pure-jnp oracle for blockwise (flash) attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mha_ref(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True,
            scale: float | None = None) -> jax.Array:
    """q: (B, H, S, D); k/v: (B, Hkv, T, D) with H % Hkv == 0 (GQA)."""
    b, h, s, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    g = h // hkv
    qq = q.reshape(b, hkv, g, s, d)
    scale = scale if scale is not None else d ** -0.5
    logits = jnp.einsum("bhgsd,bhtd->bhgst", qq.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, t), bool), k=t - s)
        logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhgst,bhtd->bhgsd", p, v.astype(jnp.float32))
    return o.reshape(b, h, s, d).astype(q.dtype)
