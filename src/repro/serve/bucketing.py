"""Capacity buckets for the sparse serving engine.

JAX traces static shapes, so a serving engine that accepted every scene at
its natural size would recompile per point count — unbounded compile churn.
Instead, requests are packed into a small *ladder* of static ``Nmax``
capacities (the classic bucketed-batching trick from NMT serving, applied to
voxel counts): each batch is padded up to the smallest bucket that fits, so
the number of distinct compiled executors is bounded by the ladder length
and amortizes to zero over a long request stream.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class BucketLadder:
    """A strictly-ascending ladder of static row capacities.

    capacities: ascending static Nmax values; every compiled executor is
        keyed by one of them.
    max_batch: scenes per packed batch (declared as the batched tensor's
        ``batch_bound``, so the packed-key engine budgets batch bits once).
    """

    capacities: Tuple[int, ...]
    max_batch: int = 8

    def __post_init__(self):
        caps = tuple(int(c) for c in self.capacities)
        assert caps and all(c > 0 for c in caps), caps
        assert list(caps) == sorted(set(caps)), f"ladder must ascend: {caps}"
        assert self.max_batch >= 1
        object.__setattr__(self, "capacities", caps)

    @property
    def max_capacity(self) -> int:
        return self.capacities[-1]

    def select(self, n_rows: int) -> int:
        """Smallest bucket capacity that fits ``n_rows`` (deterministic).

        Raises ValueError when even the largest bucket is too small — the
        caller decides whether to reject or split the request.
        """
        for cap in self.capacities:
            if n_rows <= cap:
                return cap
        raise ValueError(
            f"{n_rows} rows exceed the largest bucket ({self.max_capacity}); "
            f"ladder={self.capacities}")

    def group_capacity(self, sizes) -> int:
        """Bucket capacity a FIFO group of scene sizes will be padded to —
        the *padded* row count, which is what a batch actually costs a
        device and therefore what the router's load score charges."""
        return self.select(sum(sizes))

    @staticmethod
    def geometric(base: int, steps: int, growth: int = 2,
                  max_batch: int = 8) -> "BucketLadder":
        """``(base, base*growth, …)`` — the default ladder shape: jit
        recompiles are O(steps) while padding waste stays < growth×."""
        assert base > 0 and steps >= 1 and growth >= 2
        return BucketLadder(tuple(base * growth ** i for i in range(steps)),
                            max_batch=max_batch)
