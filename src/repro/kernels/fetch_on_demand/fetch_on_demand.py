"""Fetch-on-demand sparse convolution as a single fused Pallas TPU kernel.

Paper §2.2.2: gather + GEMM + scatter fused into one kernel; inputs are
fetched on demand into on-chip memory, partial sums are scattered straight to
the output without a DRAM scatter buffer.  PCEngine's "block fusion" (the
host δ-loop becoming a parallel dimension) maps to the leading grid axis.

TPU adaptation (DESIGN.md §2): the paper needs atomics because CUDA thread
blocks race on output rows.  A Pallas TPU grid runs *sequentially* on a core,
so the read-modify-write scatter (DMA out-row → VMEM, add, DMA back) is
race-free by construction; the cost — Σ_δ |M_δ| output-row writes, 4-10× the
output size — is exactly the write-amplification the paper attributes to this
dataflow, and is what the Autotuner trades off against implicit GEMM.

The output is accumulated in place via ``input_output_aliases`` (caller
passes the zero-initialized buffer).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import common


def _kernel(wsin_ref, wsout_ref, x_ref, w_ref, acc_in_ref, o_ref,
            scratch, obuf, ybuf, sems, osems, *, tile_r: int, cin: int):
    del acc_in_ref  # aliased with o_ref

    # 1) gather input rows for this tile of (in, out) pairs
    for r in range(tile_r):
        idx = wsin_ref[0, r]

        @pl.when(idx >= 0)
        def _start():
            pltpu.make_async_copy(x_ref.at[idx], scratch.at[r], sems.at[r]).start()

        @pl.when(idx < 0)
        def _zero():
            scratch[r, :] = jnp.zeros((cin,), scratch.dtype)

    # 2) fetch current output rows (read-modify-write scatter; race-free
    #    because a TPU Pallas grid executes sequentially on a core).  Within
    #    one δ every output row appears at most once, so tile-internal rows
    #    never collide either.
    for r in range(tile_r):
        odx = wsout_ref[0, r]

        @pl.when(odx >= 0)
        def _ostart():
            pltpu.make_async_copy(o_ref.at[odx], obuf.at[r], osems.at[r]).start()

    for r in range(tile_r):
        idx = wsin_ref[0, r]

        @pl.when(idx >= 0)
        def _wait():
            pltpu.make_async_copy(x_ref.at[idx], scratch.at[r], sems.at[r]).wait()

    # 3) on-chip MMA
    ybuf[...] = jnp.dot(scratch[...], w_ref[0],
                        preferred_element_type=jnp.float32)

    # 4) scatter partial sums straight back to the output rows
    for r in range(tile_r):
        odx = wsout_ref[0, r]

        @pl.when(odx >= 0)
        def _owait():
            pltpu.make_async_copy(o_ref.at[odx], obuf.at[r], osems.at[r]).wait()

    obuf[...] = (obuf[...].astype(jnp.float32) + ybuf[...]).astype(obuf.dtype)

    for r in range(tile_r):
        odx = wsout_ref[0, r]

        @pl.when(odx >= 0)
        def _wb():
            pltpu.make_async_copy(obuf.at[r], o_ref.at[odx], osems.at[r]).start()

    for r in range(tile_r):
        odx = wsout_ref[0, r]

        @pl.when(odx >= 0)
        def _wb_wait():
            pltpu.make_async_copy(obuf.at[r], o_ref.at[odx], osems.at[r]).wait()


@functools.partial(jax.jit, static_argnames=("tile_r", "interpret"))
def fetch_on_demand_pallas(ws_in: jax.Array, ws_out: jax.Array, x: jax.Array,
                           w: jax.Array, out0: jax.Array, *, tile_r: int = 128,
                           interpret: bool = True) -> jax.Array:
    """ws_in/ws_out: (KD, cap) int32 pair lists (-1 pad, compacted to front);
    x: (N_in, Cin); w: (KD, Cin, Cout); out0: zero-init (N_out, Cout).
    Returns out0 + sparse_conv(x, w)."""
    kd, cap = ws_in.shape
    _, cin = x.shape
    cout = w.shape[-1]
    assert cap % tile_r == 0
    grid = (kd, cap // tile_r)

    kernel = functools.partial(_kernel, tile_r=tile_r, cin=cin)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile_r), lambda k, r: (k, r), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, tile_r), lambda k, r: (k, r), memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec((1, cin, cout), lambda k, r: (k, 0, 0)),
            pl.BlockSpec(memory_space=pl.ANY),   # aliased accumulator
        ],
        out_specs=pl.BlockSpec(memory_space=pl.ANY),
        out_shape=jax.ShapeDtypeStruct(out0.shape, out0.dtype),
        scratch_shapes=[
            pltpu.VMEM((tile_r, cin), x.dtype),
            pltpu.VMEM((tile_r, cout), out0.dtype),
            pltpu.VMEM((tile_r, cout), jnp.float32),
            pltpu.SemaphoreType.DMA((tile_r,)),
            pltpu.SemaphoreType.DMA((tile_r,)),
        ],
        input_output_aliases={4: 0},
        interpret=interpret,
        compiler_params=common.tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary"),
            interpret=interpret),
    )(ws_in, ws_out, x, w, out0)
